"""Model graphs: manual MLP backprop vs autodiff, transformer sanity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M


@pytest.fixture(scope="module")
def mlp_setup():
    cfg = M.MLP_CONFIGS["mlp_base"]
    rng = np.random.default_rng(0)
    params = [jnp.array(p) for p in M.mlp_init(cfg)]
    x = jnp.array(rng.standard_normal((cfg.batch, cfg.dims[0])).astype(np.float32))
    y = jnp.array(rng.integers(0, cfg.dims[-1], cfg.batch).astype(np.int32))
    return cfg, params, x, y


def test_mlp_manual_grads_match_autodiff(mlp_setup):
    cfg, params, x, y = mlp_setup
    loss, grads = M.mlp_step(cfg, params, x, y)
    gref = jax.grad(lambda ps: M.mlp_step(cfg, ps, x, y)[0])(params)
    for a, b in zip(grads, gref):
        np.testing.assert_allclose(np.array(a), np.array(b), atol=1e-6)


def test_mlp_kfac_stats_shapes_and_psd(mlp_setup):
    cfg, params, x, y = mlp_setup
    loss, grads, stats = M.mlp_step(cfg, params, x, y, with_kfac=True)
    assert len(stats) == 2 * cfg.layers
    for i in range(cfg.layers):
        r_stat, l_stat = stats[2 * i], stats[2 * i + 1]
        assert r_stat.shape == (cfg.dims[i], cfg.dims[i])
        assert l_stat.shape == (cfg.dims[i + 1], cfg.dims[i + 1])
        for s in (r_stat, l_stat):
            w = np.linalg.eigvalsh(np.array(s))
            assert w.min() > -1e-4, "K-FAC stats must be PSD"


def test_mlp_loss_at_init(mlp_setup):
    cfg, params, x, y = mlp_setup
    loss, _ = M.mlp_step(cfg, params, x, y)
    # roughly uniform logits => loss ~ log(classes)
    assert abs(float(loss) - np.log(cfg.dims[-1])) < 2.0


def test_mlp_accuracy_counts(mlp_setup):
    cfg, params, x, y = mlp_setup
    loss, correct = M.mlp_accuracy(cfg, params, x, y)
    assert 0 <= int(correct) <= cfg.batch


def test_mlp_one_sgd_step_reduces_loss(mlp_setup):
    cfg, params, x, y = mlp_setup
    loss0, grads = M.mlp_step(cfg, params, x, y)
    params2 = [p - 0.1 * g for p, g in zip(params, grads)]
    loss1, _ = M.mlp_step(cfg, params2, x, y)
    assert float(loss1) < float(loss0)


@pytest.fixture(scope="module")
def tlm_setup():
    cfg = M.TLM_CONFIGS["tlm_tiny"]
    rng = np.random.default_rng(1)
    params = [jnp.array(p) for p in M.tlm_init(cfg)]
    toks = jnp.array(
        rng.integers(0, cfg.vocab, (cfg.batch, cfg.seq + 1)).astype(np.int32))
    return cfg, params, toks


def test_tlm_loss_at_init(tlm_setup):
    cfg, params, toks = tlm_setup
    loss = M.tlm_loss(cfg, params, toks)
    assert abs(float(loss) - np.log(cfg.vocab)) < 0.5


def test_tlm_grads_cover_all_params(tlm_setup):
    cfg, params, toks = tlm_setup
    loss, grads = M.tlm_step(cfg, params, toks)
    specs = M.tlm_param_specs(cfg)
    assert len(grads) == len(specs)
    for (name, shape), g in zip(specs, grads):
        assert g.shape == shape, name
        assert np.all(np.isfinite(np.array(g))), name
    # embedding must receive gradient (tied head)
    assert float(jnp.linalg.norm(grads[0])) > 0


def test_tlm_one_step_reduces_loss(tlm_setup):
    cfg, params, toks = tlm_setup
    loss0, grads = M.tlm_step(cfg, params, toks)
    params2 = [p - 0.5 * g for p, g in zip(params, grads)]
    loss1 = M.tlm_loss(cfg, params2, toks)
    assert float(loss1) < float(loss0)


def test_tlm_causality(tlm_setup):
    """Changing a future token must not change earlier positions' loss
    contribution — check via per-position logits path: loss w.r.t. prefix."""
    cfg, params, toks = tlm_setup
    t2 = toks.at[:, -1].set((toks[:, -1] + 1) % cfg.vocab)
    # losses differ only through the last target; compare partial forward
    # by masking: run both and check loss changes (target changed) but
    # gradients w.r.t. pos embedding at position 0 barely change.
    _, g1 = M.tlm_step(cfg, params, toks)
    _, g2 = M.tlm_step(cfg, params, t2)
    pos_idx = [n for n, _ in M.tlm_param_specs(cfg)].index("pos")
    d0 = float(jnp.max(jnp.abs(g1[pos_idx][0] - g2[pos_idx][0])))
    dl = float(jnp.max(jnp.abs(g1[pos_idx][-1] - g2[pos_idx][-1])))
    assert dl > d0


def test_param_counts():
    cfg = M.TLM_CONFIGS["tlm_small"]
    n = M.tlm_param_count(cfg)
    assert 3_000_000 < n < 4_000_000, n
