"""L1 Pallas linalg kernels vs pure-jnp oracles."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import linalg as kl
from compile.kernels import ref


@settings(max_examples=20, deadline=None)
@given(
    m=st.integers(1, 200),
    k=st.integers(1, 200),
    n=st.integers(1, 200),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_matches_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    a = jnp.array(rng.standard_normal((m, k)).astype(np.float32))
    b = jnp.array(rng.standard_normal((k, n)).astype(np.float32))
    got = np.array(kl.matmul(a, b))
    want = np.array(ref.matmul_ref(a, b))
    np.testing.assert_allclose(got, want, atol=1e-4 * max(1, k))


@settings(max_examples=10, deadline=None)
@given(n=st.sampled_from([8, 32, 64, 100]), seed=st.integers(0, 2**31 - 1))
def test_bjorck_step_and_sandwich(n, seed):
    rng = np.random.default_rng(seed)
    v = jnp.array(rng.standard_normal((n, n)).astype(np.float32) * 0.1)
    np.testing.assert_allclose(
        np.array(kl.bjorck_step(v)), np.array(ref.bjorck_step_ref(v)),
        atol=1e-4)
    d = jnp.array(rng.standard_normal(n).astype(np.float32))
    np.testing.assert_allclose(
        np.array(kl.sandwich(v, d)), np.array(ref.sandwich_ref(v, d)),
        atol=1e-4)


def test_bjorck_rectifies_quantized_orthogonal():
    """Eq. 2 improves ‖VᵀV − I‖ for a perturbed orthogonal matrix (§3.2)."""
    rng = np.random.default_rng(0)
    n = 64
    q, _ = np.linalg.qr(rng.standard_normal((n, n)))
    v = jnp.array((q + 0.02 * rng.standard_normal((n, n))).astype(np.float32))

    def dev(x):
        x = np.array(x)
        return np.linalg.norm(x.T @ x - np.eye(n))

    d0 = dev(v)
    d1 = dev(kl.bjorck(v, 1))
    d2 = dev(kl.bjorck(v, 2))
    assert d1 < 0.5 * d0
    assert d2 < d1


def test_cgs2_orthogonalizes_ill_conditioned():
    """CGS2 must survive the wide spectra QR handles (unlike Newton-Schulz)."""
    rng = np.random.default_rng(1)
    n = 64
    q, _ = np.linalg.qr(rng.standard_normal((n, n)))
    lam = np.logspace(-6, 1, n)
    x = jnp.array((q * lam).astype(np.float32))  # extremely skewed columns
    qq = np.array(kl.orthogonalize_cgs2(x))
    assert np.linalg.norm(qq.T @ qq - np.eye(n)) < 1e-3


def test_cgs2_preserves_column_space():
    rng = np.random.default_rng(2)
    n = 32
    x = rng.standard_normal((n, n)).astype(np.float32)
    qq = np.array(kl.orthogonalize_cgs2(jnp.array(x)))
    # Q R' = X for some upper-triangular R' => Qᵀ X is upper triangular
    r = qq.T @ x
    lower = np.tril(r, -1)
    assert np.max(np.abs(lower)) < 1e-3 * np.max(np.abs(r))


def test_scale_cols():
    rng = np.random.default_rng(3)
    v = jnp.array(rng.standard_normal((16, 16)).astype(np.float32))
    d = jnp.array(rng.standard_normal(16).astype(np.float32))
    np.testing.assert_allclose(
        np.array(kl.scale_cols(v, d)), np.array(v) * np.array(d)[None, :],
        rtol=1e-6)
