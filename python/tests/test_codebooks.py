"""Codebook construction vs the paper's Appendix C tables."""

import numpy as np
import pytest

from compile.quantizer import codebook, dt_codebook, linear2_codebook

# Appendix C, verbatim.
DT4_PAPER = [-0.8875, -0.6625, -0.4375, -0.2125, -0.0775, -0.0325, -0.0055,
             0.0000, 0.0055, 0.0325, 0.0775, 0.2125, 0.4375, 0.6625, 0.8875,
             1.0000]
DT3_PAPER = [-0.7750, -0.3250, -0.0550, 0.0000, 0.0550, 0.3250, 0.7750,
             1.0000]
L24_PAPER = [-1.0000, -0.7511, -0.5378, -0.3600, -0.2178, -0.1111, -0.0400,
             0.0000, 0.0044, 0.0400, 0.1111, 0.2178, 0.3600, 0.5378, 0.7511,
             1.0000]
L23_PAPER = [-1.0000, -0.5102, -0.1837, 0.0000, 0.0204, 0.1837, 0.5102,
             1.0000]


def test_dt4_matches_paper():
    np.testing.assert_allclose(dt_codebook(4), DT4_PAPER, atol=1e-7)


def test_dt3_matches_paper():
    np.testing.assert_allclose(dt_codebook(3), DT3_PAPER, atol=1e-7)


def test_linear2_4_matches_paper():
    np.testing.assert_allclose(linear2_codebook(4), L24_PAPER, atol=5e-5)


def test_linear2_3_matches_paper():
    np.testing.assert_allclose(linear2_codebook(3), L23_PAPER, atol=5e-5)


@pytest.mark.parametrize("mapping", ["dt", "linear2", "linear"])
@pytest.mark.parametrize("bits", [3, 4, 8])
def test_codebook_properties(mapping, bits):
    cb = codebook(mapping, bits)
    assert cb.shape == (2**bits,)
    assert np.all(np.diff(cb) > 0), "codebook must be strictly sorted"
    assert cb.min() >= -1.0 and cb.max() <= 1.0
    if mapping in ("dt", "linear2"):
        assert 0.0 in cb, "zero must be representable"
    assert cb[-1] == 1.0


def test_unknown_mapping_raises():
    with pytest.raises(ValueError):
        codebook("bogus", 4)
