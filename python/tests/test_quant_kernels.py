"""L1 Pallas quantization kernels vs the pure-jnp oracle (hypothesis sweeps)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import quant as kq
from compile.kernels import ref
from compile.quantizer import codebook

CB4 = jnp.array(codebook("linear2", 4))
CB_DT4 = jnp.array(codebook("dt", 4))
CB8 = jnp.array(codebook("dt", 8))


def _rand(rng, shape, scale=1.0):
    return (rng.standard_normal(shape) * scale).astype(np.float32)


@settings(max_examples=25, deadline=None)
@given(
    nblocks=st.integers(1, 40),
    block=st.sampled_from([32, 64]),
    seed=st.integers(0, 2**31 - 1),
    scale=st.sampled_from([1e-6, 1e-2, 1.0, 1e3]),
)
def test_quantize_matches_ref(nblocks, block, seed, scale):
    rng = np.random.default_rng(seed)
    x = jnp.array(_rand(rng, (nblocks, block), scale))
    for cb in (CB4, CB8):
        ck, sk = kq.quantize_blocks(x, cb)
        cr, sr = ref.quantize_ref(x, cb)
        np.testing.assert_array_equal(np.array(ck), np.array(cr))
        np.testing.assert_allclose(np.array(sk), np.array(sr), rtol=1e-6)
        dk = kq.dequantize_blocks(ck, sk, cb)
        dr = ref.dequantize_ref(cr, sr, cb)
        np.testing.assert_allclose(np.array(dk), np.array(dr), rtol=1e-6)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), nblocks=st.integers(1, 16))
def test_roundtrip_error_bound(seed, nblocks):
    """Dequantized value within half the largest codebook gap × block scale."""
    rng = np.random.default_rng(seed)
    x = jnp.array(_rand(rng, (nblocks, 64)))
    for cb in (CB4, CB_DT4):
        c, s = kq.quantize_blocks(x, cb)
        d = kq.dequantize_blocks(c, s, cb)
        gap = float(np.max(np.diff(np.array(cb)))) / 2.0
        bound = gap * np.array(s)[:, None] + 1e-6
        assert np.all(np.abs(np.array(d) - np.array(x)) <= bound)


def test_exact_codebook_values_roundtrip():
    """Values exactly on codebook entries (scaled) must roundtrip exactly."""
    cb = CB4
    scales = np.array([0.5, 2.0, 7.25], np.float32)
    x = np.stack([np.resize(np.array(cb), 64) * s for s in scales])
    c, s = kq.quantize_blocks(jnp.array(x), cb)
    # absmax of each block is max|cb|*scale = scale (cb max is 1.0)
    d = kq.dequantize_blocks(c, s, cb)
    np.testing.assert_allclose(np.array(d), x, rtol=1e-6)


def test_zero_block_scale_one():
    x = jnp.zeros((3, 64))
    c, s = kq.quantize_blocks(x, CB4)
    np.testing.assert_array_equal(np.array(s), np.ones(3, np.float32))
    d = kq.dequantize_blocks(c, s, CB4)
    np.testing.assert_array_equal(np.array(d), np.zeros((3, 64), np.float32))


@pytest.mark.parametrize("n,block", [(64, 64), (128, 64), (32, 32)])
def test_matrix_cols_roundtrip_shape(n, block):
    rng = np.random.default_rng(0)
    u = jnp.array(_rand(rng, (n, n)))
    c, s = kq.quantize_matrix_cols(u, CB4, block)
    cr, sr = ref.quantize_matrix_cols_ref(u, CB4, block)
    np.testing.assert_array_equal(np.array(c), np.array(cr))
    d = kq.dequantize_matrix_cols(c, s, (n, n), CB4, block)
    assert d.shape == (n, n)
    np.testing.assert_allclose(
        np.array(d), np.array(ref.dequantize_matrix_cols_ref(cr, sr, (n, n), CB4, block)),
        rtol=1e-6)


def test_column_blocking_is_per_column():
    """A huge entry in one column must not affect other columns' scales."""
    n = 64
    u = np.full((n, n), 0.01, np.float32)
    u[0, 0] = 100.0
    c, s = kq.quantize_matrix_cols(jnp.array(u), CB4, 64)
    d = np.array(kq.dequantize_matrix_cols(c, s, (n, n), CB4, 64))
    # column 1.. should be reconstructed well despite column 0's outlier
    assert np.max(np.abs(d[:, 1:] - u[:, 1:])) < 0.005
