"""L2 Shampoo math: matrix roots, subspace iteration, PU/PIRU invariants."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import shampoo as sh
from compile.quantizer import codebook

CB = jnp.array(codebook("linear2", 4))


def _pd_matrix(n, cond=1e4, seed=0):
    rng = np.random.default_rng(seed)
    q, _ = np.linalg.qr(rng.standard_normal((n, n)))
    lam = np.logspace(0, -np.log10(cond), n)
    return jnp.array(((q * lam) @ q.T).astype(np.float32)), q, lam


def test_power_iteration():
    a, _, lam = _pd_matrix(48, cond=100)
    est = float(sh.power_iteration(a, iters=50))
    assert abs(est - lam[0]) / lam[0] < 1e-3


@pytest.mark.parametrize("p", [2, 4])
def test_schur_newton_vs_eigh(p):
    a, q, lam = _pd_matrix(48, cond=1e3, seed=3)
    x = np.array(sh.schur_newton_invroot(a, p, iters=30))
    want = (q * lam ** (-1.0 / p)) @ q.T
    rel = np.linalg.norm(x - want) / np.linalg.norm(want)
    assert rel < 5e-3, rel


def test_subspace_iteration_warm():
    a, q, lam = _pd_matrix(64, cond=1e4, seed=4)
    rng = np.random.default_rng(5)
    v0 = jnp.array((q + 0.01 * rng.standard_normal((64, 64))).astype(np.float32))
    lam_est, p = sh.subspace_iteration(a, v0, iters=2)
    pn = np.array(p)
    assert np.linalg.norm(pn.T @ pn - np.eye(64)) < 1e-4
    rec = np.array(sh.kl.sandwich(p, lam_est))
    rel = np.linalg.norm(rec - np.array(a)) / np.linalg.norm(np.array(a))
    assert rel < 0.02, rel


def test_pu_tracks_exact_ema_spectrum():
    """PU's top eigenvalues track the exact 32-bit EMA's.

    Uses a *fixed* gradient statistic so the EMA converges to a stationary
    basis — the regime warm-started subspace iteration is built for (real
    training has strongly correlated consecutive GGᵀ; fully random ones
    rotate the basis too fast for the paper's single rSVD iteration too)."""
    n = 64
    rng = np.random.default_rng(6)
    lam = jnp.full((n,), 1e-6, jnp.float32)
    codes, scales = sh.quant_eigen(jnp.eye(n, dtype=jnp.float32), CB)
    l_exact = np.eye(n, dtype=np.float32) * 1e-6
    g = rng.standard_normal((n, 32)).astype(np.float32)
    m_stat = g @ g.T
    for step in range(8):
        lam, codes, scales = sh.pu_quantized(
            lam, codes, scales, jnp.array(m_stat), 0.95, CB,
            t1=1, sub_iters=2, orth_iters=0)
        l_exact = 0.95 * l_exact + 0.05 * m_stat
    top_exact = np.sort(np.linalg.eigvalsh(l_exact))[::-1][:8]
    top_q = np.sort(np.array(lam))[::-1][:8]
    # 4-bit requantization each PU compounds through the EMA: the paper's own
    # dynamic analysis (Fig. 7) measures NRE 0.05-0.2 of L₄ vs L₃₂ during
    # training; we see a stable ~13% deficit here.
    np.testing.assert_allclose(top_q, top_exact, rtol=0.25)
    assert np.all(top_q > 0.5 * top_exact[0] * (top_exact / top_exact[0]) ** 2)


def test_piru_matches_exact_inverse_root():
    n = 64
    a, q, lam_true = _pd_matrix(n, cond=1e4, seed=8)
    # quantize the true eigenbasis, then PIRU
    codes, scales = sh.quant_eigen(jnp.array(q.astype(np.float32)), CB)
    lam = jnp.array(lam_true.astype(np.float32))
    eps = 1e-4
    diag, c, s = sh.piru_quantized(lam, codes, scales, eps, CB,
                                   t2=4, exponent=-0.25)
    got = np.array(sh.dequant_invroot(diag, c, s, n, CB))
    ridge = lam_true.max() * eps
    want = (q * (lam_true + ridge) ** -0.25) @ q.T
    rel = np.linalg.norm(got - want) / np.linalg.norm(want)
    # 4-bit quantization: paper's Table 1 shows NRE ~0.03-0.09 at this regime
    assert rel < 0.15, rel
    # diagonal is stored in 32-bit but computed from the rectified quantized
    # basis, so it carries (smaller) quantization error
    np.testing.assert_allclose(np.diag(got), np.diag(want) * np.ones(n),
                               rtol=0.10)


@pytest.mark.parametrize("exponent", [-1.0, -0.5, -0.25])
def test_piru_exponents(exponent):
    n = 64
    a, q, lam_true = _pd_matrix(n, cond=100, seed=9)
    codes, scales = sh.quant_eigen(jnp.array(q.astype(np.float32)), CB)
    lam = jnp.array(lam_true.astype(np.float32))
    diag, c, s = sh.piru_quantized(lam, codes, scales, 1e-4, CB,
                                   t2=2, exponent=exponent)
    got = np.array(sh.dequant_invroot(diag, c, s, n, CB))
    ridge = lam_true.max() * 1e-4
    want = (q * (lam_true + ridge) ** exponent) @ q.T
    rel = np.linalg.norm(got - want) / np.linalg.norm(want)
    assert rel < 0.15, (exponent, rel)


def test_graft_preserves_gradient_norm():
    rng = np.random.default_rng(10)
    g = jnp.array(rng.standard_normal((32, 48)).astype(np.float32))
    gh = jnp.array(rng.standard_normal((32, 48)).astype(np.float32) * 17.0)
    out = sh.graft(g, gh)
    assert abs(float(jnp.linalg.norm(out)) - float(jnp.linalg.norm(g))) < 1e-3


def test_precondition_4bit_identity_states():
    """With Â = I states, preconditioning is the identity (up to graft=1)."""
    n = 64
    rng = np.random.default_rng(11)
    diag = jnp.ones((n,), jnp.float32)
    codes, scales = sh.quant_eigen(jnp.zeros((n, n), jnp.float32), CB)
    g = jnp.array(rng.standard_normal((n, n)).astype(np.float32))
    out = sh.precondition_4bit(g, diag, codes, scales, diag, codes, scales, CB)
    np.testing.assert_allclose(np.array(out), np.array(g), atol=1e-5)


def test_precondition_caspr_identity_states():
    """CASPR with Â = I: J = 2G, Ĝ = 4G, grafted back to ‖G‖."""
    n = 64
    rng = np.random.default_rng(12)
    diag = jnp.ones((n,), jnp.float32)
    codes, scales = sh.quant_eigen(jnp.zeros((n, n), jnp.float32), CB)
    g = jnp.array(rng.standard_normal((n, n)).astype(np.float32))
    out = sh.precondition_caspr_4bit(g, diag, codes, scales, diag, codes,
                                     scales, CB)
    np.testing.assert_allclose(np.array(out), np.array(g), atol=1e-5)


def test_naive_arm_roundtrip():
    n = 64
    a, q, lam_true = _pd_matrix(n, cond=1e3, seed=13)
    diag, codes, scales = sh.quant_sym(a, CB)
    got = np.array(sh.dequant_sym(diag, codes, scales, n, CB))
    np.testing.assert_allclose(np.diag(got), np.diag(np.array(a)), rtol=1e-6)
    rel = np.linalg.norm(got - np.array(a)) / np.linalg.norm(np.array(a))
    # ~0.09 for a random-basis PD matrix at 4-bit (Table 1's NRE in A itself
    # is ~0.02; the inverse-4th-root blowup is what the paper is about)
    assert rel < 0.2, rel


def test_naive_invroot_worse_than_eigen_path():
    """The paper's core claim (§3.1): quantizing A is much worse than
    quantizing U for the inverse 4-th root, on an ill-conditioned matrix."""
    n = 128
    a, q, lam_true = _pd_matrix(n, cond=3e4, seed=14)
    ridge = lam_true.max() * 1e-4
    want = (q * (lam_true + ridge) ** -0.25) @ q.T

    # naive: quantize A, Schur-Newton
    diag, codes, scales = sh.quant_sym(a, CB)
    dn, cn, sn = sh.invroot_naive(diag, codes, scales, 1e-4, CB, iters=30)
    got_naive = np.array(sh.dequant_sym(dn, cn, sn, n, CB))
    nre_naive = np.linalg.norm(got_naive - want) / np.linalg.norm(want)

    # ours: quantize U, eigen path
    codes, scales = sh.quant_eigen(jnp.array(q.astype(np.float32)), CB)
    d4, c4, s4 = sh.piru_quantized(jnp.array(lam_true.astype(np.float32)),
                                   codes, scales, 1e-4, CB, t2=4,
                                   exponent=-0.25)
    got_ours = np.array(sh.dequant_invroot(d4, c4, s4, n, CB))
    nre_ours = np.linalg.norm(got_ours - want) / np.linalg.norm(want)

    assert nre_ours < 0.5 * nre_naive, (nre_ours, nre_naive)


def test_dense_baseline():
    a, q, lam_true = _pd_matrix(48, cond=1e3, seed=15)
    l1 = sh.pu_dense(a, a, 0.95)
    np.testing.assert_allclose(np.array(l1), np.array(a), rtol=1e-6)
    inv = np.array(sh.invroot_dense(a, 1e-4, iters=30))
    ridge = lam_true.max() * 1e-4
    want = (q * (lam_true + ridge) ** -0.25) @ q.T
    rel = np.linalg.norm(inv - want) / np.linalg.norm(want)
    assert rel < 1e-2, rel


def test_gram():
    rng = np.random.default_rng(16)
    g = jnp.array(rng.standard_normal((24, 40)).astype(np.float32))
    l, r = sh.gram(g)
    np.testing.assert_allclose(np.array(l), np.array(g) @ np.array(g).T,
                               atol=1e-4)
    np.testing.assert_allclose(np.array(r), np.array(g).T @ np.array(g),
                               atol=1e-4)
