"""L2: the 4-bit Shampoo optimizer math (Algorithms 1–4 of the paper),
written in JAX on top of the L1 Pallas kernels, AOT-lowered by aot.py.

Entry points (all matmul-only — no LAPACK custom-calls, so the HLO text
loads in xla_extension 0.5.1):

  * ``power_iteration``      — λ_max estimate (Algorithm 4 line 8)
  * ``schur_newton_invroot`` — coupled Newton A^{-1/p} (Algorithm 4 line 9)
  * ``subspace_iteration``   — warm-started randomized-SVD substitute
                               (Appendix B, eq. 4 with a polar-factor
                               orthogonalizer instead of QR)
  * ``pu_quantized``         — Algorithm 1 (Preconditioner Update)
  * ``piru_quantized``       — Algorithm 2 (Inverse-4th-Root Update); the
                               exponent generalizes to -1/2 (AdaBK) and
                               -1 (K-FAC) per Algorithm 5
  * ``precondition_4bit``    — Algorithm 3 lines 13–14 (dequant + L̂GR̂ + graft)
  * ``precondition_caspr_*`` — CASPR variant (Appendix A)
  * naive / dense arms       — quantize-A-itself (the paper's strawman) and
                               the 32-bit baseline (Algorithm 4)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from compile.kernels import linalg as kl
from compile.kernels import quant as kq

# ---------------------------------------------------------------------------
# Matrix-root toolbox (matmul-only)
# ---------------------------------------------------------------------------


def power_iteration(a: jnp.ndarray, iters: int = 10) -> jnp.ndarray:
    """λ_max of a PSD matrix via power iteration (fixed deterministic start)."""
    n = a.shape[0]
    v0 = jnp.ones((n, 1), jnp.float32) / jnp.sqrt(n).astype(jnp.float32)

    def body(_, v):
        w = kl.matmul(a, v)
        return w / jnp.maximum(jnp.linalg.norm(w), 1e-30)

    v = jax.lax.fori_loop(0, iters, body, v0)
    return jnp.squeeze(v.T @ kl.matmul(a, v))


def schur_newton_invroot(a: jnp.ndarray, p: int, iters: int = 20,
                         lam_max: jnp.ndarray | None = None) -> jnp.ndarray:
    """A^{-1/p} for PD A by the coupled Newton (Schur–Newton) iteration
    [Guo & Higham 2006]:   X ← X·T,  M ← Tᵖ·M,  T = ((p+1)I − M)/p,
    with M₀ = A/λ_max, X₀ = λ_max^{-1/p}·I. Converges since spec(M₀) ⊆ (0,1].
    """
    n = a.shape[0]
    if lam_max is None:
        lam_max = power_iteration(a)
    z = 1.0 / jnp.maximum(lam_max, 1e-30)
    eye = jnp.eye(n, dtype=jnp.float32)
    m0 = z * a
    x0 = z ** (1.0 / p) * eye
    err0 = jnp.max(jnp.abs(m0 - eye))

    def body(_, carry):
        # Best-iterate selection: a quantized (hence possibly indefinite)
        # input makes the iteration diverge on the negative eigendirections
        # — the instability the paper observes for the naive arm (Table 3 /
        # Fig. 8). We track ‖M−I‖∞ and keep the best X seen, freezing the
        # state if the candidate goes non-finite.
        x, m, best_x, best_err = carry
        t = ((p + 1.0) * eye - m) / p
        x_new = kl.matmul(x, t)
        # Tᵖ by repeated squaring for p ∈ {2, 4}; generic fallback otherwise.
        if p == 2:
            tp = kl.matmul(t, t)
        elif p == 4:
            t2 = kl.matmul(t, t)
            tp = kl.matmul(t2, t2)
        else:
            tp = t
            for _i in range(p - 1):
                tp = kl.matmul(tp, t)
        m_new = kl.matmul(tp, m)
        err = jnp.max(jnp.abs(m_new - eye))
        ok = jnp.isfinite(err)
        x = jnp.where(ok, x_new, x)
        m = jnp.where(ok, m_new, m)
        better = ok & (err < best_err)
        best_x = jnp.where(better, x_new, best_x)
        best_err = jnp.where(better, err, best_err)
        return x, m, best_x, best_err

    _, _, x, _ = jax.lax.fori_loop(0, iters, body, (x0, m0, x0, err0))
    # Symmetrize: X should be symmetric for symmetric A; round-off breaks it.
    return 0.5 * (x + x.T)


def subspace_iteration(a: jnp.ndarray, v: jnp.ndarray, iters: int,
                       orth_iters: int = 0):
    """Warm-started subspace (orthogonal) iteration: P ← Orth(A·P).

    The paper's randomized SVD (Appendix B eq. 4) with CGS2 replacing QR —
    matmul-only, LAPACK-free (DESIGN.md decision 4). `orth_iters` is kept
    for API stability but unused. Returns (eigenvalues diag(PᵀAP), P).
    """
    del orth_iters
    for _ in range(iters):
        v = kl.orthogonalize_cgs2(kl.matmul(a, v))
    av = kl.matmul(a, v)
    lam = jnp.sum(v * av, axis=0)
    return lam, v


# ---------------------------------------------------------------------------
# Quantized state helpers
# ---------------------------------------------------------------------------


def _qblock(n: int) -> int:
    """Quantization block size for an order-n matrix: blocks stay within one
    column (§3.3), so the block is min(64, n)."""
    return min(64, n)


def dequant_eigen(codes, scales, n: int, cb):
    """Dequantize an order-n eigenvector matrix stored column-blocked."""
    return kq.dequantize_matrix_cols(codes, scales, (n, n), cb, _qblock(n))


def quant_eigen(u, cb):
    n = u.shape[0]
    return kq.quantize_matrix_cols(u, cb, _qblock(n))


# ---------------------------------------------------------------------------
# 4-bit Shampoo (ours): Algorithms 1-3
# ---------------------------------------------------------------------------


def pu_quantized(lam, codes, scales, m_stat, beta, cb, *, t1: int,
                 sub_iters: int, orth_iters: int):
    """Algorithm 1 (PU): rebuild A = β·VΛVᵀ + (1−β)·M from the quantized
    eigenbasis, re-diagonalize by warm-started subspace iteration, requantize.
    """
    n = lam.shape[0]
    v = dequant_eigen(codes, scales, n, cb)
    v = kl.bjorck(v, t1)
    a = beta * kl.sandwich(v, lam) + (1.0 - beta) * m_stat
    lam_new, p = subspace_iteration(a, v, sub_iters, orth_iters)
    codes_new, scales_new = quant_eigen(p, cb)
    return lam_new, codes_new, scales_new


def piru_quantized(lam, codes, scales, eps, cb, *, t2: int, exponent: float):
    """Algorithm 2 (PIRU): Â = V(Λ + max{λ}εI)ˢVᵀ, stored as
    (diag(Â), Q(Â − Diag(diag(Â)))). exponent s = −1/4 for Shampoo,
    −1/2 for AdaBK, −1 for K-FAC (Algorithm 5)."""
    n = lam.shape[0]
    v = dequant_eigen(codes, scales, n, cb)
    v = kl.bjorck(v, t2)
    ridge = jnp.max(lam) * eps
    d = jnp.power(jnp.maximum(lam + ridge, 1e-30), exponent)
    a_hat = kl.sandwich(v, d)
    diag = jnp.diagonal(a_hat)
    off = a_hat - jnp.diag(diag)
    codes_new, scales_new = quant_eigen(off, cb)
    return diag, codes_new, scales_new


def dequant_invroot(diag, codes, scales, n: int, cb):
    """Rebuild Â = Diag(a) + D(off-diag codes) (Algorithm 3 line 13)."""
    off = dequant_eigen(codes, scales, n, cb)
    return off - jnp.diag(jnp.diagonal(off)) + jnp.diag(diag)


def graft(g, g_hat):
    """Grafting trick (Algorithm 3 line 14): G̃ = Ĝ·(‖G‖_F/‖Ĝ‖_F)."""
    ng = jnp.linalg.norm(g)
    nh = jnp.maximum(jnp.linalg.norm(g_hat), 1e-30)
    return g_hat * (ng / nh)


def precondition_4bit(g, l_diag, l_codes, l_scales, r_diag, r_codes,
                      r_scales, cb):
    """Algorithm 3 lines 13–14 with 4-bit states on both sides."""
    m, n = g.shape
    l_hat = dequant_invroot(l_diag, l_codes, l_scales, m, cb)
    r_hat = dequant_invroot(r_diag, r_codes, r_scales, n, cb)
    g_hat = kl.matmul(kl.matmul(l_hat, g), r_hat)
    return graft(g, g_hat)


def precondition_caspr_4bit(g, l_diag, l_codes, l_scales, r_diag, r_codes,
                            r_scales, cb):
    """CASPR variant (Appendix A): J = L̂G + GR̂; Ĝ = L̂J + JR̂, grafted."""
    m, n = g.shape
    l_hat = dequant_invroot(l_diag, l_codes, l_scales, m, cb)
    r_hat = dequant_invroot(r_diag, r_codes, r_scales, n, cb)
    j = kl.matmul(l_hat, g) + kl.matmul(g, r_hat)
    g_hat = kl.matmul(l_hat, j) + kl.matmul(j, r_hat)
    return graft(g, g_hat)


# ---------------------------------------------------------------------------
# Naive 4-bit arm: quantize the preconditioner itself (paper's §3.1 strawman;
# diagonal stored separately in 32-bit — the "slightly improved" naive).
# ---------------------------------------------------------------------------


def quant_sym(a, cb):
    """Quantize a symmetric matrix excluding its diagonal."""
    n = a.shape[0]
    diag = jnp.diagonal(a)
    off = a - jnp.diag(diag)
    codes, scales = kq.quantize_matrix_cols(off, cb, _qblock(n))
    return diag, codes, scales


def dequant_sym(diag, codes, scales, n, cb):
    off = kq.dequantize_matrix_cols(codes, scales, (n, n), cb, _qblock(n))
    off = off - jnp.diag(jnp.diagonal(off))
    return off + jnp.diag(diag)


def pu_naive(diag, codes, scales, m_stat, beta, cb):
    """Naive arm PU: A ← β·D(Ā) + (1−β)·M, requantize A directly."""
    n = diag.shape[0]
    a = dequant_sym(diag, codes, scales, n, cb)
    a = beta * a + (1.0 - beta) * m_stat
    return quant_sym(a, cb)


def invroot_naive(diag, codes, scales, eps, cb, *, p: int = 4,
                  iters: int = 16):
    """Naive arm inverse root: Schur–Newton on the dequantized preconditioner
    (Algorithm 4 lines 8–9), result requantized."""
    n = diag.shape[0]
    a = dequant_sym(diag, codes, scales, n, cb)
    lam_max = power_iteration(a)
    a_hat = schur_newton_invroot(a + lam_max * eps * jnp.eye(n), p,
                                 iters=iters, lam_max=lam_max * (1 + eps))
    return quant_sym(a_hat, cb)


# ---------------------------------------------------------------------------
# Dense 32-bit baseline (Algorithm 4)
# ---------------------------------------------------------------------------


def pu_dense(l, m_stat, beta):
    return beta * l + (1.0 - beta) * m_stat


def invroot_dense(l, eps, *, p: int = 4, iters: int = 16):
    n = l.shape[0]
    lam_max = power_iteration(l)
    return schur_newton_invroot(l + lam_max * eps * jnp.eye(n), p,
                                iters=iters, lam_max=lam_max * (1 + eps))


def precondition_dense(g, l_hat, r_hat):
    return graft(g, kl.matmul(kl.matmul(l_hat, g), r_hat))


def precondition_caspr_dense(g, l_hat, r_hat):
    j = kl.matmul(l_hat, g) + kl.matmul(g, r_hat)
    return graft(g, kl.matmul(l_hat, j) + kl.matmul(j, r_hat))


def gram(g):
    """(G·Gᵀ, Gᵀ·G) statistics for PU (Algorithm 3 line 6)."""
    return kl.matmul(g, g.T), kl.matmul(g.T, g)
