"""AOT compiler: lowers every L2 entry point to HLO *text* artifacts that the
Rust runtime loads via `HloModuleProto::from_text_file` (see
/opt/xla-example/load_hlo — text, never .serialize(): jax ≥ 0.5 emits 64-bit
instruction ids which xla_extension 0.5.1 rejects; the text parser reassigns
ids).

Outputs (under artifacts/):
  * <name>.hlo.txt       — one per entry point
  * manifest.json        — input/output specs per artifact + model metadata,
                           consumed by rust/src/runtime/registry.rs
  * golden/<name>.json   — deterministic input/output pairs for the Rust
                           integration tests

Run: ``python -m compile.aot --out-dir ../artifacts`` (the Makefile drives
this; it is a no-op at runtime — python is never on the training path).

Hyperparameters baked statically follow the paper's Appendix G defaults:
t1=1, t2=4 (rectification iterations), one randomized-SVD iteration for
Shampoo/CASPR and two for K-FAC/AdaBK, 10-iteration power iteration,
15-iteration Schur–Newton. β, ε and learning-rate scalars stay runtime
inputs so no schedule is baked in.

The runtime codebook input is always 16 entries (4-bit). 3-bit runs pad
their 8-entry codebook by repeating the last value: argmin picks the first
occurrence, so emitted codes stay in [0, 8) and both sides dequantize
consistently. 8-bit appears only in the error-analysis benches, which run
natively in Rust.
"""

from __future__ import annotations

import argparse
import itertools
import json
import os
from typing import Callable, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from compile import model as M
from compile import optim1
from compile import shampoo as sh

# Paper Appendix G defaults (static).
T1_RECT = 1
T2_RECT = 4
SUB_ITERS_SHAMPOO = 1
SUB_ITERS_KFAC = 2
SCHUR_ITERS = 15
CB_LEN = 16  # runtime codebook entries (4-bit; 3-bit padded)

ALL_BUCKETS = (32, 64, 128)
QUANT_BUCKETS = (64, 128)  # paper: matrices smaller than 4096 elems stay 32-bit
KFAC_ORDERS = (128, 256)   # K-FAC/AdaBK precondition whole MLP layers

F32 = jnp.float32
U8 = jnp.uint8
I32 = jnp.int32


def _qspec(n: int):
    """(codes, scales) ShapeDtypeStructs for an order-n column-blocked matrix."""
    qb = min(64, n)
    nb = n * n // qb
    return (jax.ShapeDtypeStruct((nb, qb), U8),
            jax.ShapeDtypeStruct((nb,), jnp.float32))


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


class Registry:
    def __init__(self):
        self.entries = {}

    def add(self, name: str, fn: Callable, in_specs: Sequence[Tuple[str, jax.ShapeDtypeStruct]],
            out_names: Sequence[str], golden: bool = False):
        assert name not in self.entries, name
        self.entries[name] = dict(fn=fn, in_specs=list(in_specs),
                                  out_names=list(out_names), golden=golden)


REG = Registry()


def _spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def register_bucket_artifacts():
    cb_spec = _spec((CB_LEN,))

    for n in QUANT_BUCKETS + (256,):
        codes, scales = _qspec(n)
        lam = _spec((n,))
        mat = _spec((n, n))
        scalar = _spec(())

        sub_iters = SUB_ITERS_SHAMPOO if n != 256 else SUB_ITERS_KFAC
        REG.add(
            f"pu_{n}",
            (lambda si: lambda l, c, s, m, beta, cb: sh.pu_quantized(
                l, c, s, m, beta, cb, t1=T1_RECT, sub_iters=si,
                orth_iters=0))(sub_iters),
            [("lam", lam), ("codes", codes), ("scales", scales),
             ("m_stat", mat), ("beta", scalar), ("cb", cb_spec)],
            ["lam", "codes", "scales"], golden=(n == 64))
        # K-FAC/AdaBK also need the two-iteration PU at order 128
        if n == 128:
            REG.add(
                f"pu_kfac_{n}",
                lambda l, c, s, m, beta, cb: sh.pu_quantized(
                    l, c, s, m, beta, cb, t1=T1_RECT,
                    sub_iters=SUB_ITERS_KFAC, orth_iters=0),
                [("lam", lam), ("codes", codes), ("scales", scales),
                 ("m_stat", mat), ("beta", scalar), ("cb", cb_spec)],
                ["lam", "codes", "scales"])

        for tag, expo in (("", -0.25), ("_e2", -0.5), ("_e1", -1.0)):
            REG.add(
                f"piru{tag}_{n}",
                (lambda e: lambda l, c, s, eps, cb: sh.piru_quantized(
                    l, c, s, eps, cb, t2=T2_RECT, exponent=e))(expo),
                [("lam", lam), ("codes", codes), ("scales", scales),
                 ("eps", scalar), ("cb", cb_spec)],
                ["diag", "codes", "scales"], golden=(n == 64 and tag == ""))

        REG.add(
            f"pu_naive_{n}",
            lambda d, c, s, m, beta, cb: sh.pu_naive(d, c, s, m, beta, cb),
            [("diag", lam), ("codes", codes), ("scales", scales),
             ("m_stat", mat), ("beta", scalar), ("cb", cb_spec)],
            ["diag", "codes", "scales"])
        REG.add(
            f"invroot_naive_{n}",
            lambda d, c, s, eps, cb: sh.invroot_naive(
                d, c, s, eps, cb, p=4, iters=SCHUR_ITERS),
            [("diag", lam), ("codes", codes), ("scales", scales),
             ("eps", scalar), ("cb", cb_spec)],
            ["diag", "codes", "scales"])

        REG.add(f"quant_cols_{n}",
                lambda u, cb: sh.quant_eigen(u, cb),
                [("u", mat), ("cb", cb_spec)],
                ["codes", "scales"], golden=(n == 64))
        REG.add(f"dequant_cols_{n}",
                (lambda nn: lambda c, s, cb: sh.dequant_eigen(c, s, nn, cb))(n),
                [("codes", codes), ("scales", scales), ("cb", cb_spec)],
                ["u"], golden=(n == 64))

    for n in ALL_BUCKETS + (256,):
        mat = _spec((n, n))
        scalar = _spec(())
        REG.add(f"pu_dense_{n}",
                lambda l, m, beta: sh.pu_dense(l, m, beta),
                [("l", mat), ("m_stat", mat), ("beta", scalar)], ["l"])
        for tag, p in (("", 4), ("_e2", 2), ("_e1", 1)):
            REG.add(
                f"invroot_dense{tag}_{n}",
                (lambda pp: lambda l, eps: sh.invroot_dense(
                    l, eps, p=pp, iters=SCHUR_ITERS))(p),
                [("l", mat), ("eps", scalar)], ["lhat"],
                golden=(n == 64 and tag == ""))


def register_pair_artifacts():
    cb_spec = _spec((CB_LEN,))
    for m, n in itertools.product(ALL_BUCKETS, ALL_BUCKETS):
        g = _spec((m, n))
        REG.add(f"gram_{m}x{n}", lambda gg: sh.gram(gg),
                [("g", g)], ["l", "r"], golden=(m == 64 and n == 128))
        REG.add(f"precond32_{m}x{n}",
                lambda gg, lh, rh: sh.precondition_dense(gg, lh, rh),
                [("g", g), ("lhat", _spec((m, m))), ("rhat", _spec((n, n)))],
                ["gt"], golden=(m == 32 and n == 32))
        REG.add(f"caspr32_{m}x{n}",
                lambda gg, lh, rh: sh.precondition_caspr_dense(gg, lh, rh),
                [("g", g), ("lhat", _spec((m, m))), ("rhat", _spec((n, n)))],
                ["gt"])

    for m, n in itertools.product(QUANT_BUCKETS, QUANT_BUCKETS):
        g = _spec((m, n))
        lc, ls = _qspec(m)
        rc, rs = _qspec(n)
        common = [("g", g), ("l_diag", _spec((m,))), ("l_codes", lc),
                  ("l_scales", ls), ("r_diag", _spec((n,))), ("r_codes", rc),
                  ("r_scales", rs), ("cb", cb_spec)]
        REG.add(f"precond4_{m}x{n}",
                lambda gg, ld, lcc, lss, rd, rcc, rss, cb:
                sh.precondition_4bit(gg, ld, lcc, lss, rd, rcc, rss, cb),
                common, ["gt"], golden=(m == 64 and n == 64))
        REG.add(f"caspr4_{m}x{n}",
                lambda gg, ld, lcc, lss, rd, rcc, rss, cb:
                sh.precondition_caspr_4bit(gg, ld, lcc, lss, rd, rcc, rss, cb),
                common, ["gt"])


def register_model_artifacts():
    # MLP (always emits K-FAC statistics; Rust ignores them when not needed)
    cfg = M.MLP_CONFIGS["mlp_base"]
    pspecs = M.mlp_param_specs(cfg)
    p_in = [(nm, _spec(shape)) for nm, shape in pspecs]
    x = _spec((cfg.batch, cfg.dims[0]))
    y = jax.ShapeDtypeStruct((cfg.batch,), I32)

    def mlp_step_fn(*args):
        params = list(args[:-2])
        loss, grads, stats = M.mlp_step(cfg, params, args[-2], args[-1],
                                        with_kfac=True)
        return (loss, *grads, *stats)

    stat_names = []
    for i in range(cfg.layers):
        stat_names += [f"stat_r{i}", f"stat_l{i}"]
    REG.add("mlp_base_step", mlp_step_fn,
            p_in + [("x", x), ("y", y)],
            ["loss"] + [f"grad_{nm}" for nm, _ in pspecs] + stat_names)

    def mlp_eval_fn(*args):
        params = list(args[:-2])
        return M.mlp_accuracy(cfg, params, args[-2], args[-1])

    REG.add("mlp_base_eval", mlp_eval_fn, p_in + [("x", x), ("y", y)],
            ["loss", "correct"])

    # Transformer LMs
    for name, tcfg in M.TLM_CONFIGS.items():
        pspecs = M.tlm_param_specs(tcfg)
        p_in = [(nm, _spec(shape)) for nm, shape in pspecs]
        toks = jax.ShapeDtypeStruct((tcfg.batch, tcfg.seq + 1), I32)

        def step_fn(*args, _cfg=tcfg):
            params = list(args[:-1])
            loss, grads = M.tlm_step(_cfg, params, args[-1])
            return (loss, *grads)

        REG.add(f"{name}_step", step_fn, p_in + [("tokens", toks)],
                ["loss"] + [f"grad_{nm}" for nm, _ in pspecs])

        def eval_fn(*args, _cfg=tcfg):
            params = list(args[:-1])
            return (M.tlm_loss(_cfg, params, args[-1]),)

        REG.add(f"{name}_eval", eval_fn, p_in + [("tokens", toks)], ["loss"])


def register_optim_artifacts():
    n = 4096
    v = _spec((n,))
    s = _spec(())
    REG.add("sgdm_update_4096",
            lambda p, b, g, lr, mom, wd: optim1.sgdm_update(p, b, g, lr, mom, wd),
            [("p", v), ("buf", v), ("g", v), ("lr", s), ("momentum", s),
             ("wd", s)],
            ["p", "buf"], golden=True)
    REG.add("adamw_update_4096",
            lambda p, m, vv, g, step, lr, b1, b2, eps, wd:
            optim1.adamw_update(p, m, vv, g, step, lr, b1, b2, eps, wd),
            [("p", v), ("m", v), ("v", v), ("g", v), ("step", s), ("lr", s),
             ("beta1", s), ("beta2", s), ("eps", s), ("wd", s)],
            ["p", "m", "v"], golden=True)


def _golden_inputs(in_specs, seed=1234):
    """Deterministic inputs: float arrays from a seeded generator; codes from
    quantizing such arrays would be arbitrary u8 — we use uniform ints."""
    rng = np.random.default_rng(seed)
    out = {}
    for name, spec in in_specs:
        if spec.dtype == U8:
            out[name] = rng.integers(0, CB_LEN, spec.shape).astype(np.uint8)
        elif spec.dtype == I32:
            out[name] = rng.integers(0, 100, spec.shape).astype(np.int32)
        elif name == "cb":
            from compile.quantizer import codebook
            out[name] = codebook("linear2", 4).astype(np.float32)
        elif name in ("beta",):
            out[name] = np.float32(0.95)
        elif name in ("eps",):
            out[name] = np.float32(1e-4)
        elif name in ("lr",):
            out[name] = np.float32(1e-3)
        elif name in ("momentum", "beta1"):
            out[name] = np.float32(0.9)
        elif name in ("beta2",):
            out[name] = np.float32(0.999)
        elif name in ("wd",):
            out[name] = np.float32(0.01)
        elif name in ("step",):
            out[name] = np.float32(7.0)
        elif name in ("m_stat", "l"):
            # PD matrix
            d = spec.shape[0]
            b = rng.standard_normal((d, d + 8)).astype(np.float32)
            out[name] = (b @ b.T / d).astype(np.float32)
        elif name in ("lam", "diag"):
            out[name] = np.abs(rng.standard_normal(spec.shape)).astype(np.float32) + 0.1
        elif name in ("scales", "l_scales", "r_scales"):
            out[name] = (np.abs(rng.standard_normal(spec.shape)) * 0.1 + 0.01).astype(np.float32)
        elif name == "v":  # AdamW second moment must be nonnegative
            out[name] = (rng.standard_normal(spec.shape).astype(np.float32) ** 2) * 0.01
        elif name in ("l_diag", "r_diag"):
            out[name] = (np.abs(rng.standard_normal(spec.shape)) + 0.5).astype(np.float32)
        elif name in ("lhat", "rhat"):
            d = spec.shape[0]
            b = rng.standard_normal((d, d)).astype(np.float32) * 0.05
            out[name] = (np.eye(d, dtype=np.float32) + 0.5 * (b + b.T))
        else:
            out[name] = rng.standard_normal(spec.shape).astype(np.float32)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None,
                    help="comma-separated artifact names (debugging)")
    ap.add_argument("--skip-models", action="store_true")
    args = ap.parse_args()

    register_bucket_artifacts()
    register_pair_artifacts()
    if not args.skip_models:
        register_model_artifacts()
    register_optim_artifacts()

    os.makedirs(args.out_dir, exist_ok=True)
    golden_dir = os.path.join(args.out_dir, "golden")
    os.makedirs(golden_dir, exist_ok=True)

    manifest = {
        "block_size": 64,
        "cb_len": CB_LEN,
        "buckets": list(ALL_BUCKETS),
        "quant_buckets": list(QUANT_BUCKETS),
        "kfac_orders": list(KFAC_ORDERS),
        "defaults": {"t1": T1_RECT, "t2": T2_RECT,
                     "sub_iters": SUB_ITERS_SHAMPOO,
                     "schur_iters": SCHUR_ITERS},
        "artifacts": {},
        "models": {},
    }

    cfg = M.MLP_CONFIGS["mlp_base"]
    manifest["models"]["mlp_base"] = {
        "kind": "mlp", "dims": list(cfg.dims), "batch": cfg.batch,
        "classes": cfg.dims[-1],
        "params": [{"name": nm, "shape": list(shape)}
                   for nm, shape in M.mlp_param_specs(cfg)],
        "step": "mlp_base_step", "eval": "mlp_base_eval",
    }
    for name, tcfg in M.TLM_CONFIGS.items():
        manifest["models"][name] = {
            "kind": "tlm", "vocab": tcfg.vocab, "d_model": tcfg.d_model,
            "n_layers": tcfg.n_layers, "n_heads": tcfg.n_heads,
            "d_ff": tcfg.d_ff, "seq": tcfg.seq, "batch": tcfg.batch,
            "param_count": M.tlm_param_count(tcfg),
            "params": [{"name": nm, "shape": list(shape)}
                       for nm, shape in M.tlm_param_specs(tcfg)],
            "step": f"{name}_step", "eval": f"{name}_eval",
        }

    only = set(args.only.split(",")) if args.only else None
    names = [n for n in REG.entries if only is None or n in only]
    for i, name in enumerate(names):
        ent = REG.entries[name]
        specs = [s for _, s in ent["in_specs"]]
        lowered = jax.jit(ent["fn"]).lower(*specs)
        text = to_hlo_text(lowered)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)

        out_shapes = jax.eval_shape(ent["fn"], *specs)
        if not isinstance(out_shapes, (tuple, list)):
            out_shapes = (out_shapes,)
        manifest["artifacts"][name] = {
            "file": f"{name}.hlo.txt",
            "inputs": [{"name": nm, "shape": list(s.shape),
                        "dtype": str(s.dtype)} for nm, s in ent["in_specs"]],
            "outputs": [{"name": onm, "shape": list(s.shape),
                         "dtype": str(s.dtype)}
                        for onm, s in zip(ent["out_names"], out_shapes)],
        }
        print(f"[{i+1}/{len(names)}] {name}: {len(text)} chars, "
              f"{len(ent['in_specs'])} in / {len(ent['out_names'])} out")

        if ent["golden"]:
            gin = _golden_inputs(ent["in_specs"])
            outs = jax.jit(ent["fn"])(*[jnp.array(gin[nm])
                                        for nm, _ in ent["in_specs"]])
            if not isinstance(outs, (tuple, list)):
                outs = (outs,)
            gj = {
                "inputs": {nm: {"shape": list(np.shape(gin[nm])),
                                "dtype": str(np.asarray(gin[nm]).dtype),
                                "data": np.asarray(gin[nm]).ravel().tolist()}
                           for nm, _ in ent["in_specs"]},
                "outputs": [{"name": onm,
                             "shape": list(np.shape(o)),
                             "dtype": str(np.asarray(o).dtype),
                             "data": np.asarray(o).ravel().tolist()}
                            for onm, o in zip(ent["out_names"], outs)],
            }
            with open(os.path.join(golden_dir, f"{name}.json"), "w") as f:
                json.dump(gj, f)

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {len(names)} artifacts + manifest to {args.out_dir}")


if __name__ == "__main__":
    main()
