"""Quantization codebooks and block-wise (de)quantizers for 4-bit Shampoo.

Implements the quantizer Q = (I∘N, M) of the paper (§2.2):
  * N — block-wise normalization, block size 64 by default; for eigenvector
    matrices blocks stay within a single column (paper §3.3), which the
    matrix wrappers below guarantee by quantizing U in column-major order.
  * I — nearest-codebook-entry argmin, executed by the Pallas kernel
    (kernels/quant.py) on the build path and mirrored exactly by
    ``rust/src/quant`` at runtime.
  * M — per-block absmax scales.

Codebooks (paper §3.3 + Appendix C):
  * dynamic tree (DT) quantization for any bitwidth b >= 2,
  * linear square (Linear-2) quantization, eq. (3),
  * plain linear quantization (reference).
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

BLOCK_SIZE = 64  # paper: block-wise normalization with block size 64 (4-bit)

# ---------------------------------------------------------------------------
# Codebooks
# ---------------------------------------------------------------------------


def dt_codebook(bits: int) -> np.ndarray:
    """Dynamic tree quantization mapping (Appendix C).

    Maps T_b onto {0, 1} ∪ G with G = {±q_k × 10^-E}:
      b = 2 + E + F;  q_k = (p_k + p_{k+1}) / 2;  p_j = 0.9 j / 2^F + 0.1.
    For b=4 this reproduces the 16-entry table in Appendix C exactly.
    """
    if bits < 2:
        raise ValueError("DT quantization needs bits >= 2")
    values = {0.0, 1.0}
    for e in range(bits - 1):  # E in {0, ..., b-2}, F = b-2-E
        f = bits - 2 - e
        p = [0.9 * j / (2**f) + 0.1 for j in range(2**f + 1)]
        for k in range(2**f):
            q = 0.5 * (p[k] + p[k + 1]) * 10.0 ** (-e)
            values.add(q)
            values.add(-q)
    out = np.array(sorted(values), dtype=np.float32)
    assert out.shape[0] == 2**bits, (bits, out.shape)
    return out


def linear2_codebook(bits: int) -> np.ndarray:
    """Linear square (Linear-2) quantization mapping, eq. (3)."""
    n = 2**bits
    j = np.arange(n, dtype=np.float64)
    base = -1.0 + 2.0 * j / (n - 1)
    mid = 2 ** (bits - 1) - 1
    out = np.where(j < mid, -(base**2), np.where(j == mid, 0.0, base**2))
    return out.astype(np.float32)


def linear_codebook(bits: int) -> np.ndarray:
    """Plain linear quantization mapping (reference arm)."""
    n = 2**bits
    j = np.arange(n, dtype=np.float64)
    return (-1.0 + 2.0 * j / (n - 1)).astype(np.float32)


_CODEBOOKS = {
    "dt": dt_codebook,
    "linear2": linear2_codebook,
    "linear": linear_codebook,
}


def codebook(mapping: str, bits: int) -> np.ndarray:
    """Return the sorted codebook for a mapping name ('dt'|'linear2'|'linear')."""
    try:
        fn = _CODEBOOKS[mapping]
    except KeyError as e:  # pragma: no cover - defensive
        raise ValueError(f"unknown quantization mapping {mapping!r}") from e
    return fn(bits)


# ---------------------------------------------------------------------------
# Block-wise quantize / dequantize (pure-jnp; the Pallas kernels in
# kernels/quant.py implement exactly this contract and are tested against it)
# ---------------------------------------------------------------------------


def blocks_of(x: jnp.ndarray, block: int = BLOCK_SIZE) -> jnp.ndarray:
    """Reshape a flat vector (length divisible by `block`) to (nblocks, block)."""
    assert x.ndim == 1 and x.shape[0] % block == 0, x.shape
    return x.reshape(-1, block)


def quantize_ref(x2d: jnp.ndarray, cb: jnp.ndarray):
    """Reference block-wise quantizer over (nblocks, block) input.

    Returns (codes uint8 (nblocks, block), scales f32 (nblocks,)).
    Zero blocks get scale 1.0 so dequantization is exact for them.
    """
    absmax = jnp.max(jnp.abs(x2d), axis=1)
    scale = jnp.where(absmax > 0, absmax, 1.0)
    normed = x2d / scale[:, None]
    dist = jnp.abs(normed[:, :, None] - cb[None, None, :])
    codes = jnp.argmin(dist, axis=2).astype(jnp.uint8)
    return codes, scale.astype(jnp.float32)


def dequantize_ref(codes: jnp.ndarray, scale: jnp.ndarray, cb: jnp.ndarray):
    """Reference block-wise dequantizer: R(codes) ⊙ scales."""
    return jnp.take(cb, codes.astype(jnp.int32)) * scale[:, None]


def quantize_matrix_cols_ref(u: jnp.ndarray, cb: jnp.ndarray, block: int = BLOCK_SIZE):
    """Quantize a matrix with blocks running down columns (paper §3.3).

    U is (n, m); we quantize U^T row-blocks, i.e. each block of 64 consecutive
    entries comes from one column of U.
    """
    n, m = u.shape
    assert n % block == 0, (u.shape, block)
    x2d = u.T.reshape(-1, block)
    return quantize_ref(x2d, cb)


def dequantize_matrix_cols_ref(codes, scale, shape, cb, block: int = BLOCK_SIZE):
    n, m = shape
    flat = dequantize_ref(codes, scale, cb)
    return flat.reshape(m, n).T
