"""L2: model compute graphs (forward/backward) lowered to HLO artifacts.

Two model families, mirroring the paper's two evaluation domains at
laptop scale (DESIGN.md §Substitutions):

  * ``mlp``        — image-classification proxy (Table 2 CNN rows, Table 4
                     K-FAC/AdaBK rows). Backprop is written out manually so
                     the train step can also emit the K-FAC statistics
                     X·Xᵀ (layer inputs) and Y·Yᵀ (pre-activation output
                     gradients) that Algorithm 5 consumes.
  * ``transformer``— decoder-only pre-LN LM (Table 2 ViT/Swin rows, Table 12
                     GPT-2/LLaMA rows). Grads via jax.value_and_grad.

Parameters cross the Rust boundary as a flat, name-ordered list of f32
arrays; ``*_param_specs`` defines that order and is written into
artifacts/manifest.json.
"""

from __future__ import annotations

import dataclasses
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# MLP classifier with manual backprop + K-FAC statistics
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MlpConfig:
    name: str
    dims: Tuple[int, ...]  # (in, hidden..., classes)
    batch: int

    @property
    def layers(self) -> int:
        return len(self.dims) - 1


MLP_CONFIGS = {
    # 128 -> 256 -> 256 -> 128 classes: every weight is bucket-shaped, so the
    # K-FAC/AdaBK path (which preconditions whole layers, Appendix G)
    # needs only bucket-order preconditioners.
    "mlp_base": MlpConfig("mlp_base", (128, 256, 256, 128), 128),
}


def mlp_param_specs(cfg: MlpConfig):
    specs = []
    for i in range(cfg.layers):
        specs.append((f"w{i}", (cfg.dims[i], cfg.dims[i + 1])))
        specs.append((f"b{i}", (cfg.dims[i + 1],)))
    return specs


def mlp_init(cfg: MlpConfig, seed: int = 0):
    rng = np.random.default_rng(seed)
    params = []
    for i in range(cfg.layers):
        fan_in = cfg.dims[i]
        w = rng.standard_normal((fan_in, cfg.dims[i + 1])) * np.sqrt(2.0 / fan_in)
        params.append(w.astype(np.float32))
        params.append(np.zeros((cfg.dims[i + 1],), np.float32))
    return params


def _softmax_xent(logits, labels):
    """Mean cross-entropy; returns (loss, dlogits) — dlogits already /batch."""
    zmax = jnp.max(logits, axis=1, keepdims=True)
    z = logits - zmax
    logsumexp = jnp.log(jnp.sum(jnp.exp(z), axis=1, keepdims=True))
    logp = z - logsumexp
    bs = logits.shape[0]
    loss = -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))
    probs = jnp.exp(logp)
    onehot = jax.nn.one_hot(labels, logits.shape[1], dtype=logits.dtype)
    return loss, (probs - onehot) / bs


def mlp_step(cfg: MlpConfig, params: List[jnp.ndarray], x, y,
             with_kfac: bool = False):
    """Forward + manual backward. Returns (loss, grads[, kfac_stats]).

    kfac_stats per layer: (XᵀX/bs, δYᵀδY·bs) — Algorithm 5's R and L
    statistics for layer i (activation second moment and pre-activation
    gradient second moment; the ·bs undoes the 1/bs folded into dlogits so
    the statistic matches E[y yᵀ] over the batch).
    """
    ws = params[0::2]
    bs_ = params[1::2]
    acts = [x]
    pre = []
    h = x
    for i in range(cfg.layers):
        z = h @ ws[i] + bs_[i][None, :]
        pre.append(z)
        h = jax.nn.relu(z) if i < cfg.layers - 1 else z
        acts.append(h)
    loss, dz = _softmax_xent(acts[-1], y)

    grads = [None] * (2 * cfg.layers)
    stats = []
    batch = x.shape[0]
    for i in reversed(range(cfg.layers)):
        a_in = acts[i]
        grads[2 * i] = a_in.T @ dz
        grads[2 * i + 1] = jnp.sum(dz, axis=0)
        if with_kfac:
            stats.append((a_in.T @ a_in / batch, dz.T @ dz * batch))
        if i > 0:
            da = dz @ ws[i].T
            dz = da * (pre[i - 1] > 0).astype(da.dtype)
    if with_kfac:
        stats = stats[::-1]
        flat_stats = [s for pair in stats for s in pair]
        return loss, grads, flat_stats
    return loss, grads


def mlp_accuracy(cfg: MlpConfig, params, x, y):
    """Eval helper: (mean loss, #correct) on one batch."""
    h = x
    ws = params[0::2]
    bs_ = params[1::2]
    for i in range(cfg.layers):
        z = h @ ws[i] + bs_[i][None, :]
        h = jax.nn.relu(z) if i < cfg.layers - 1 else z
    loss, _ = _softmax_xent(h, y)
    correct = jnp.sum((jnp.argmax(h, axis=1) == y).astype(jnp.int32))
    return loss, correct


# ---------------------------------------------------------------------------
# Decoder-only transformer LM (pre-LN, learned positions, tied head)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TlmConfig:
    name: str
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int
    d_ff: int
    seq: int
    batch: int

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads


TLM_CONFIGS = {
    "tlm_tiny": TlmConfig("tlm_tiny", 256, 128, 2, 4, 512, 64, 8),
    "tlm_small": TlmConfig("tlm_small", 512, 256, 4, 8, 1024, 128, 8),
    "tlm_medium": TlmConfig("tlm_medium", 2048, 512, 8, 8, 2048, 128, 4),
}


def tlm_param_specs(cfg: TlmConfig):
    d, f = cfg.d_model, cfg.d_ff
    specs = [("embed", (cfg.vocab, d)), ("pos", (cfg.seq, d))]
    for i in range(cfg.n_layers):
        specs += [
            (f"l{i}.ln1_g", (d,)), (f"l{i}.ln1_b", (d,)),
            (f"l{i}.wqkv", (d, 3 * d)), (f"l{i}.wo", (d, d)),
            (f"l{i}.ln2_g", (d,)), (f"l{i}.ln2_b", (d,)),
            (f"l{i}.w1", (d, f)), (f"l{i}.w2", (f, d)),
        ]
    specs += [("lnf_g", (d,)), ("lnf_b", (d,))]
    return specs


def tlm_init(cfg: TlmConfig, seed: int = 0):
    rng = np.random.default_rng(seed)
    params = []
    for name, shape in tlm_param_specs(cfg):
        if name.endswith("_g"):
            params.append(np.ones(shape, np.float32))
        elif name.endswith("_b"):
            params.append(np.zeros(shape, np.float32))
        else:
            std = 0.02
            if name.endswith(".wo") or name.endswith(".w2"):
                std = 0.02 / np.sqrt(2.0 * cfg.n_layers)
            params.append((rng.standard_normal(shape) * std).astype(np.float32))
    return params


def _layernorm(x, g, b, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


def _attention(x, wqkv, wo, cfg: TlmConfig):
    b, t, d = x.shape
    qkv = x @ wqkv  # (b, t, 3d)
    q, k, v = jnp.split(qkv, 3, axis=-1)

    def heads(z):
        return z.reshape(b, t, cfg.n_heads, cfg.head_dim).transpose(0, 2, 1, 3)

    q, k, v = heads(q), heads(k), heads(v)
    att = (q @ k.transpose(0, 1, 3, 2)) / np.sqrt(cfg.head_dim)
    mask = jnp.tril(jnp.ones((t, t), bool))
    att = jnp.where(mask[None, None], att, -1e9)
    att = jax.nn.softmax(att, axis=-1)
    out = (att @ v).transpose(0, 2, 1, 3).reshape(b, t, d)
    return out @ wo


def tlm_loss(cfg: TlmConfig, params: List[jnp.ndarray], tokens):
    """Next-token cross-entropy. tokens: (batch, seq+1) int32."""
    names = [n for n, _ in tlm_param_specs(cfg)]
    p = dict(zip(names, params))
    inp, tgt = tokens[:, :-1], tokens[:, 1:]
    x = p["embed"][inp] + p["pos"][None, : inp.shape[1]]
    for i in range(cfg.n_layers):
        h = _layernorm(x, p[f"l{i}.ln1_g"], p[f"l{i}.ln1_b"])
        x = x + _attention(h, p[f"l{i}.wqkv"], p[f"l{i}.wo"], cfg)
        h = _layernorm(x, p[f"l{i}.ln2_g"], p[f"l{i}.ln2_b"])
        h = jax.nn.gelu(h @ p[f"l{i}.w1"]) @ p[f"l{i}.w2"]
        x = x + h
    x = _layernorm(x, p["lnf_g"], p["lnf_b"])
    logits = x @ p["embed"].T  # tied head
    b, t, v = logits.shape
    loss, _ = _softmax_xent(logits.reshape(b * t, v), tgt.reshape(b * t))
    return loss


def tlm_step(cfg: TlmConfig, params, tokens):
    loss, grads = jax.value_and_grad(
        lambda ps: tlm_loss(cfg, ps, tokens))(list(params))
    return loss, grads


def tlm_param_count(cfg: TlmConfig) -> int:
    return sum(int(np.prod(s)) for _, s in tlm_param_specs(cfg))
