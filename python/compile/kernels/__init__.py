"""L1 Pallas kernels (build-time only; lowered into HLO artifacts)."""
from . import quant, linalg, ref  # noqa: F401
