"""Pure-jnp oracles for every L1 Pallas kernel.

The pytest suite asserts allclose between these and the kernels in
quant.py / linalg.py across shape/dtype sweeps (hypothesis). These oracles
are also what the Rust-side quantizer is cross-checked against via the
golden vectors emitted by aot.py.
"""

from __future__ import annotations

import jax.numpy as jnp

from compile.quantizer import (  # re-exported single source of truth
    quantize_ref,
    dequantize_ref,
    quantize_matrix_cols_ref,
    dequantize_matrix_cols_ref,
)

__all__ = [
    "quantize_ref",
    "dequantize_ref",
    "quantize_matrix_cols_ref",
    "dequantize_matrix_cols_ref",
    "matmul_ref",
    "sandwich_ref",
    "bjorck_step_ref",
    "bjorck_ref",
    "colnorm_orthogonalize_ref",
]


def matmul_ref(a, b):
    return jnp.matmul(a.astype(jnp.float32), b.astype(jnp.float32))


def sandwich_ref(v, d):
    return (v * d[None, :]) @ v.T


def bjorck_step_ref(v):
    return 1.5 * v - 0.5 * (v @ (v.T @ v))


def bjorck_ref(v, iters):
    for _ in range(iters):
        v = bjorck_step_ref(v)
    return v


def colnorm_orthogonalize_ref(x, iters):
    norms = jnp.sqrt(jnp.sum(x * x, axis=0))
    x = x / jnp.maximum(norms, 1e-30)[None, :]
    return bjorck_ref(x, iters)
