"""L1 Pallas kernels: tiled matmul and the symmetric building blocks of the
preconditioner pipeline.

The paper's hot spots beyond quantization are all dense matrix products:
  * V · Λ · Vᵀ (preconditioner reconstruction, Algorithms 1/2),
  * the Björck orthonormalization step V ← 1.5V − 0.5·V·VᵀV (eq. 2),
  * the preconditioned gradient L̂ · G · R̂ (Algorithm 3 line 14).

On TPU these map to the MXU systolic array: we tile for 128×128 MXU passes
(bm=bn=bk=128 default) with a VMEM-resident accumulator, replacing the
paper's cuBLAS calls (DESIGN.md §Hardware-Adaptation). Preconditioner orders
are bucketed to {32, 64, 128}, so most products are a single MXU tile.

interpret=True throughout — see kernels/quant.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INTERPRET = True
DEFAULT_TILE = 128


def _matmul_kernel(a_ref, b_ref, o_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                          preferred_element_type=jnp.float32)


def _pad2(x, bm, bn):
    m, n = x.shape
    pm, pn = (-m) % bm, (-n) % bn
    if pm or pn:
        x = jnp.pad(x, ((0, pm), (0, pn)))
    return x


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn"))
def matmul(a: jnp.ndarray, b: jnp.ndarray, bm: int = DEFAULT_TILE,
           bk: int = DEFAULT_TILE, bn: int = DEFAULT_TILE) -> jnp.ndarray:
    """Tiled Pallas matmul, f32 accumulate; pads to tile multiples and crops."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    ap = _pad2(a.astype(jnp.float32), bm, bk)
    bp = _pad2(b.astype(jnp.float32), bk, bn)
    gm, gk = ap.shape[0] // bm, ap.shape[1] // bk
    gn = bp.shape[1] // bn
    out = pl.pallas_call(
        _matmul_kernel,
        grid=(gm, gn, gk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((ap.shape[0], bp.shape[1]), jnp.float32),
        interpret=INTERPRET,
    )(ap, bp)
    return out[:m, :n]


def _scale_cols_kernel(v_ref, d_ref, o_ref):
    o_ref[...] = v_ref[...] * d_ref[...][None, :]


@jax.jit
def scale_cols(v: jnp.ndarray, d: jnp.ndarray) -> jnp.ndarray:
    """V · Diag(d) as a single-tile elementwise Pallas kernel."""
    n, m = v.shape
    return pl.pallas_call(
        _scale_cols_kernel,
        out_shape=jax.ShapeDtypeStruct((n, m), jnp.float32),
        interpret=INTERPRET,
    )(v.astype(jnp.float32), d.astype(jnp.float32))


def sandwich(v: jnp.ndarray, d: jnp.ndarray) -> jnp.ndarray:
    """V · Diag(d) · Vᵀ using the Pallas kernels (preconditioner rebuild)."""
    return matmul(scale_cols(v, d), v.T)


def bjorck_step(v: jnp.ndarray) -> jnp.ndarray:
    """One Björck orthonormalization step: V ← 1.5·V − 0.5·V·(VᵀV)  (eq. 2)."""
    g = matmul(v.T, v)
    return 1.5 * v - 0.5 * matmul(v, g)


def bjorck(v: jnp.ndarray, iters: int) -> jnp.ndarray:
    """`iters` rectification steps (t₁/t₂ of Algorithms 1/2). Unrolled: iters
    is small (1–4 in the paper) and unrolling lets XLA fuse the scalings."""
    for _ in range(iters):
        v = bjorck_step(v)
    return v


def colnorm_orthogonalize(x: jnp.ndarray, iters: int) -> jnp.ndarray:
    """Column-normalize then Björck/Newton–Schulz steps.

    Only valid when the columns of x are already near-orthogonal (e.g.
    rectifying a dequantized eigenvector matrix). NOT used inside subspace
    iteration: with the ill-conditioned spectra Shampoo preconditioners have
    (Figure 2 of the paper), A·P has strongly correlated columns and
    Newton–Schulz diverges — see orthogonalize_cgs2 below.
    """
    norms = jnp.sqrt(jnp.sum(x * x, axis=0))
    x = x / jnp.maximum(norms, 1e-30)[None, :]
    return bjorck(x, iters)


def orthogonalize_cgs2(x: jnp.ndarray) -> jnp.ndarray:
    """QR orthogonalization via classical Gram–Schmidt with reorthogonalization
    (CGS2, "twice is enough" [Björck]).

    This replaces `torch.linalg.qr` inside the paper's randomized SVD
    (Appendix B, eq. 4): subspace iteration only needs *some* orthogonalizer
    of A·P — the column space is unchanged. CGS2 is matmul/matvec-only, so it
    lowers to plain HLO (no LAPACK custom-calls, which xla_extension 0.5.1
    cannot load from HLO text), and unlike Newton–Schulz it handles the
    near-rank-deficient columns produced by Shampoo's wide spectra.

    Columns whose residual vanishes (exact rank deficiency, e.g. padded
    blocks) are left with near-zero norm rather than replaced: downstream
    they are always weighted by the matching ≈0 eigenvalue.
    """
    n, m = x.shape

    def body(j, q):
        v = jax.lax.dynamic_slice(x, (0, j), (n, 1))
        mask = (jnp.arange(m) < j).astype(x.dtype)[None, :]
        qm = q * mask
        for _ in range(2):  # CGS2: project out prior columns twice
            v = v - qm @ (qm.T @ v)
        v = v / jnp.maximum(jnp.linalg.norm(v), 1e-30)
        return jax.lax.dynamic_update_slice(q, v, (0, j))

    q0 = jnp.zeros_like(x)
    return jax.lax.fori_loop(0, m, body, q0)
