"""L1 Pallas kernels: block-wise quantization / dequantization.

These are the numeric-format hot spots of 4-bit Shampoo. Each quantization
block (64 elements, paper §2.2/G) is normalized by its absmax and snapped to
the nearest codebook entry. The grid runs over tiles of quantization blocks;
the codebook (16 entries at 4-bit) is small enough to live in VMEM
replicated across the grid, so the argmin is a fully vectorized
(tile × block × 2^b) broadcast — the TPU analogue of the paper's CUDA
elementwise kernels (see DESIGN.md §Hardware-Adaptation).

All kernels run interpret=True: CPU PJRT cannot execute Mosaic custom-calls,
and interpret-mode lowers to plain HLO which the Rust runtime loads.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INTERPRET = True
# Tile of quantization blocks processed per grid step. 8 blocks × 64 elems
# × (4B input + 1B codes) + 16-entry codebook ≈ 2.6 KiB VMEM — the argmin
# broadcast tensor (8, 64, 16) f32 is 32 KiB, well inside a ~16 MiB VMEM
# budget; chosen small to overlap HBM↔VMEM streaming of many blocks.
TILE_BLOCKS = 8


def _quantize_kernel(x_ref, cb_ref, codes_ref, scale_ref):
    x = x_ref[...]  # (t, B)
    absmax = jnp.max(jnp.abs(x), axis=1)
    scale = jnp.where(absmax > 0, absmax, 1.0)
    normed = x / scale[:, None]
    # Nearest codebook entry; ties resolve to the lowest index, matching the
    # Rust runtime quantizer and the pure-jnp reference.
    dist = jnp.abs(normed[:, :, None] - cb_ref[...][None, None, :])
    codes_ref[...] = jnp.argmin(dist, axis=2).astype(jnp.uint8)
    scale_ref[...] = scale.astype(jnp.float32)


def _dequantize_kernel(codes_ref, scale_ref, cb_ref, out_ref):
    codes = codes_ref[...].astype(jnp.int32)
    out_ref[...] = jnp.take(cb_ref[...], codes) * scale_ref[...][:, None]


def _pad_blocks(x2d, tile):
    nb = x2d.shape[0]
    pad = (-nb) % tile
    if pad:
        x2d = jnp.pad(x2d, ((0, pad), (0, 0)))
    return x2d, nb


@functools.partial(jax.jit, static_argnames=("tile",))
def quantize_blocks(x2d: jnp.ndarray, cb: jnp.ndarray, tile: int = TILE_BLOCKS):
    """Quantize (nblocks, block) f32 -> (codes uint8, scales f32[nblocks])."""
    x2d = x2d.astype(jnp.float32)
    xp, nb = _pad_blocks(x2d, tile)
    nbp, blk = xp.shape
    grid = (nbp // tile,)
    codes, scale = pl.pallas_call(
        _quantize_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, blk), lambda i: (i, 0)),
            pl.BlockSpec((cb.shape[0],), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((tile, blk), lambda i: (i, 0)),
            pl.BlockSpec((tile,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nbp, blk), jnp.uint8),
            jax.ShapeDtypeStruct((nbp,), jnp.float32),
        ],
        interpret=INTERPRET,
    )(xp, cb.astype(jnp.float32))
    return codes[:nb], scale[:nb]


@functools.partial(jax.jit, static_argnames=("tile",))
def dequantize_blocks(codes: jnp.ndarray, scale: jnp.ndarray, cb: jnp.ndarray,
                      tile: int = TILE_BLOCKS):
    """Dequantize (codes uint8 (nb, B), scales (nb,)) -> f32 (nb, B)."""
    cp, nb = _pad_blocks(codes, tile)
    sp = jnp.pad(scale, (0, cp.shape[0] - nb))
    nbp, blk = cp.shape
    grid = (nbp // tile,)
    out = pl.pallas_call(
        _dequantize_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, blk), lambda i: (i, 0)),
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((cb.shape[0],), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((tile, blk), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nbp, blk), jnp.float32),
        interpret=INTERPRET,
    )(cp, sp.astype(jnp.float32), cb.astype(jnp.float32))
    return out[:nb]


def quantize_matrix_cols(u: jnp.ndarray, cb: jnp.ndarray, block: int = 64):
    """Quantize a matrix with quantization blocks inside columns (§3.3)."""
    n, m = u.shape
    assert n % block == 0, (u.shape, block)
    return quantize_blocks(u.T.reshape(-1, block), cb)


def dequantize_matrix_cols(codes, scale, shape, cb, block: int = 64):
    n, m = shape
    flat = dequantize_blocks(codes, scale, cb)
    return flat.reshape(m, n).T
