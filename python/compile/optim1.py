"""First-order optimizer updates (the F of eq. (1)) as elementwise JAX
functions.

These exist for two reasons:
  1. cross-check artifacts: the Rust coordinator runs its own native
     elementwise implementations on the hot path (DESIGN.md decision 7) and
     the integration tests assert bit-level agreement against these lowered
     versions;
  2. the perturbed-Shampoo regret bench reuses them.

All hyperparameters are runtime scalar *inputs* so the artifacts do not bake
in a learning-rate schedule.
"""

from __future__ import annotations

import jax.numpy as jnp


def sgdm_update(p, buf, g, lr, momentum, wd):
    """SGD with momentum, classic (non-decoupled) weight decay, PyTorch
    semantics: buf ← μ·buf + (g + wd·p); p ← p − lr·buf."""
    g = g + wd * p
    buf = momentum * buf + g
    return p - lr * buf, buf


def adamw_update(p, m, v, g, step, lr, beta1, beta2, eps, wd):
    """AdamW with decoupled weight decay and bias correction."""
    m = beta1 * m + (1.0 - beta1) * g
    v = beta2 * v + (1.0 - beta2) * g * g
    mh = m / (1.0 - beta1**step)
    vh = v / (1.0 - beta2**step)
    p = p - lr * (mh / (jnp.sqrt(vh) + eps) + wd * p)
    return p, m, v


def nadamw_update(p, m, v, g, step, lr, beta1, beta2, eps, wd):
    """NAdamW [Dozat 2016]: Nesterov momentum inside AdamW."""
    m = beta1 * m + (1.0 - beta1) * g
    v = beta2 * v + (1.0 - beta2) * g * g
    mh = (beta1 * m + (1.0 - beta1) * g) / (1.0 - beta1 ** (step + 1.0))
    vh = v / (1.0 - beta2**step)
    p = p - lr * (mh / (jnp.sqrt(vh) + eps) + wd * p)
    return p, m, v


def adagrad_update(p, acc, g, lr, eps, wd):
    """Adagrad with classic weight decay."""
    g = g + wd * p
    acc = acc + g * g
    return p - lr * g / (jnp.sqrt(acc) + eps), acc
