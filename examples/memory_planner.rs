//! Reproduces Table 13: maximum batch size for LLaMA2-7B training under an
//! 80 GiB budget across optimizers, using the same byte-accounting model as
//! the live coordinator (validated at small scale in the integration tests).
//!
//!   cargo run --release --example memory_planner

use shampoo4::coordinator::memory::{plan, OptimizerPlan, PlannedModel};

fn main() {
    let budget = 81920usize * 1024 * 1024; // the paper's A800 (81,920 MB)
    let m = PlannedModel::llama2_7b();
    println!(
        "== Table 13: {} ({:.2}B params), context 256, budget 81,920 MB ==\n",
        m.name,
        m.param_count() as f64 / 1e9
    );
    let arms = [
        ("8-bit AdamW", plan(&m, OptimizerPlan::Adam { bits: 8 })),
        (
            "8-bit AdamW + 32-bit Shampoo",
            plan(&m, OptimizerPlan::AdamShampoo {
                adam_bits: 8,
                shampoo_bits: 32,
                max_order: 2048,
            }),
        ),
        (
            "8-bit AdamW + 4-bit Shampoo (our)",
            plan(&m, OptimizerPlan::AdamShampoo {
                adam_bits: 8,
                shampoo_bits: 4,
                max_order: 2048,
            }),
        ),
    ];
    println!(
        "{:<36} {:>7} {:>12} {:>6}",
        "Optimizer", "Batch", "TMC (MB)", "fits"
    );
    for (name, p) in &arms {
        println!(
            "  [states: adam {:.0} MB, shampoo {:.0} MB]",
            p.adam_bytes as f64 / 1048576.0,
            p.shampoo_bytes as f64 / 1048576.0
        );
        for batch in [2usize, 64, 128, 256] {
            let total = p.total_at_batch(batch);
            println!(
                "{:<36} {:>7} {:>12.0} {:>6}",
                name,
                batch,
                total as f64 / 1048576.0,
                if total <= budget { "yes" } else { "OOM" }
            );
        }
        println!("{:<36} max batch under budget: {}\n", name, p.max_batch(budget));
    }
    println!(
        "paper's Table 13 shape: 8-bit AdamW fits 128 (OOM at 256); \
         +32-bit Shampoo OOMs even at batch 2; +4-bit Shampoo fits 64 (OOM at 128)."
    );
}
