//! Quickstart: train a small MLP classifier with SGDM + 4-bit Shampoo and
//! compare memory against the 32-bit baseline. Runs hermetically on the
//! HostBackend (uses PJRT artifacts instead when built with --features pjrt
//! and artifacts/ exists).
//!
//!   cargo run --release --example quickstart

#![allow(clippy::field_reassign_with_default)]

use anyhow::Result;
use shampoo4::config::{FirstOrderKind, RunConfig, SecondOrderKind};
use shampoo4::coordinator::Trainer;
use shampoo4::runtime::default_backend;

fn main() -> Result<()> {
    let rt = default_backend(std::path::Path::new("artifacts"))?;
    let rt = rt.as_ref();

    let mut cfg = RunConfig::default();
    cfg.name = "quickstart".into();
    cfg.model = "mlp_base".into();
    cfg.steps = 150;
    cfg.first.kind = FirstOrderKind::Sgdm;
    cfg.first.lr = 0.05;
    cfg.first.weight_decay = 5e-4;
    cfg.second.kind = SecondOrderKind::Shampoo;
    cfg.second.quant.bits = 4; // the paper's headline configuration
    cfg.second.update_precond_every = 10;
    cfg.second.update_invroot_every = 50;
    cfg.eval_every = 50;

    println!("== SGDM + 4-bit Shampoo (ours) ==");
    let mut t4 = Trainer::new(rt, cfg.clone())?;
    let r4 = t4.train(rt, None)?;
    report(&r4);

    println!("\n== SGDM + 32-bit Shampoo (baseline) ==");
    cfg.second.quant.bits = 32;
    cfg.name = "quickstart32".into();
    let mut t32 = Trainer::new(rt, cfg)?;
    let r32 = t32.train(rt, None)?;
    report(&r32);

    let saved = 1.0
        - r4.memory.second_order_bytes as f64 / r32.memory.second_order_bytes as f64;
    println!(
        "\n4-bit Shampoo second-order state: {:.2} MB vs {:.2} MB (saves {:.0}%)",
        r4.memory.second_order_bytes as f64 / 1048576.0,
        r32.memory.second_order_bytes as f64 / 1048576.0,
        saved * 100.0
    );
    Ok(())
}

fn report(r: &shampoo4::coordinator::TrainResult) {
    for (s, l) in &r.losses {
        if s % 50 == 0 || *s == 1 {
            println!("  step {s:>4}  loss {l:.4}");
        }
    }
    if let Some(e) = &r.final_eval {
        println!(
            "  final: loss {:.4}  acc {}  wall {:.1}s  optimizer {:.2} MB",
            e.loss,
            e.accuracy.map(|a| format!("{:.2}%", a * 100.0)).unwrap_or_default(),
            r.wall_secs,
            r.memory.optimizer_mb()
        );
    }
}
