//! Reproduces the paper's Table 1 (plus the 8-bit rows of Table 7 and the
//! excluded-diagonal variant of Table 6) on the two order-1200 matrices:
//! A₁ — spectrum-matched "real" preconditioner (cond ≈ 37235, Figure 6),
//! A₂ — the paper's synthetic two-level spectrum.
//!
//!   cargo run --release --example quant_error_analysis -- [--n 1200]

use anyhow::Result;
use shampoo4::errors::{quant_error_in_power, spectrum, QuantScheme, QuantTarget};
use shampoo4::quant::Mapping;
use shampoo4::util::cli::Args;
use shampoo4::util::rng::Rng;

fn main() -> Result<()> {
    let args = Args::parse_from(std::env::args().skip(1), &["skip-8bit"]);
    let n = args.get_usize("n", 1200);
    let mut rng = Rng::new(args.get_usize("seed", 0) as u64);

    println!("building A1 (cond≈37235 log-linear) and A2 (two-level, c=1000) at order {n}...");
    let a1 = spectrum::synthetic_loglinear(n, 37235.0, &mut rng);
    let a2 = spectrum::synthetic_two_level(n, 1000.0, 1e-3, n / 20, &mut rng);

    println!("\n== Table 1: quantization errors in A^(-1/4) ==");
    println!("{:<8} {:<9} {:>4} {:>3} {:>4} {:>8} {:>8}", "matrix", "mapping", "bit", "QM", "OR", "NRE", "AE(deg)");
    for (mname, a) in [("A1", &a1), ("A2", &a2)] {
        for mapping in [Mapping::Dt, Mapping::Linear2] {
            let rows: Vec<(u32, QuantTarget, usize)> = vec![
                (8, QuantTarget::Precond, 0),
                (4, QuantTarget::Precond, 0),
                (4, QuantTarget::Eigen, 0),
                (4, QuantTarget::Eigen, 1),
            ];
            for (bits, target, rect) in rows {
                if bits == 8 && args.flag("skip-8bit") {
                    continue;
                }
                let block = if bits == 8 { 256 } else { 64 };
                let row = quant_error_in_power(
                    a,
                    -0.25,
                    QuantScheme { mapping, bits, target, rectify: rect, block },
                    false,
                );
                println!(
                    "{:<8} {:<9} {:>4} {:>3} {:>4} {:>8.4} {:>8.4}",
                    mname,
                    mapping.name(),
                    bits,
                    if target == QuantTarget::Eigen { "U" } else { "A" },
                    if rect > 0 { "yes" } else { "no" },
                    row.nre,
                    row.ae_deg
                );
            }
        }
    }

    println!("\n== Table 6 variant: errors in A^(-1/4) − Diag(diag) (4-bit) ==");
    println!("{:<8} {:<9} {:>3} {:>4} {:>8} {:>8}", "matrix", "mapping", "QM", "OR", "NRE", "AE(deg)");
    for (mname, a) in [("A1", &a1), ("A2", &a2)] {
        for mapping in [Mapping::Dt, Mapping::Linear2] {
            for (target, rect) in [
                (QuantTarget::Precond, 0),
                (QuantTarget::Eigen, 0),
                (QuantTarget::Eigen, 1),
            ] {
                let row = quant_error_in_power(
                    a,
                    -0.25,
                    QuantScheme { mapping, bits: 4, target, rectify: rect, block: 64 },
                    true,
                );
                println!(
                    "{:<8} {:<9} {:>3} {:>4} {:>8.4} {:>8.4}",
                    mname,
                    mapping.name(),
                    if target == QuantTarget::Eigen { "U" } else { "A" },
                    if rect > 0 { "yes" } else { "no" },
                    row.nre,
                    row.ae_deg
                );
            }
        }
    }
    Ok(())
}
