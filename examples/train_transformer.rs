//! End-to-end driver (deliverable (b) / EXPERIMENTS.md §E2E): train the
//! transformer LM on the synthetic bigram corpus with AdamW + 4-bit Shampoo,
//! logging the full loss curve and validation perplexity. Runs on any
//! backend — the hermetic HostBackend by default, or the full three-layer
//! stack (Rust coordinator → AOT HLO artifacts → PJRT CPU) with
//! --features pjrt and compiled artifacts.
//!
//!   cargo run --release --example train_transformer -- [--model tlm_small]
//!       [--steps 400] [--bits 4] [--backend host|pjrt|auto] [--out runs/e2e]

#![allow(clippy::field_reassign_with_default)]

use std::path::PathBuf;

use anyhow::Result;
use shampoo4::config::{FirstOrderKind, RunConfig, Schedule, SecondOrderKind};
use shampoo4::coordinator::Trainer;
use shampoo4::runtime::backend_by_name;
use shampoo4::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::parse_from(std::env::args().skip(1), &[]);
    let model = args.get_or("model", "tlm_small").to_string();
    let steps = args.get_usize("steps", 400);
    let bits = args.get_usize("bits", 4) as u32;
    let out = PathBuf::from(args.get_or("out", "runs/e2e"));

    let rt = backend_by_name(
        args.get_or("backend", "auto"),
        std::path::Path::new(args.get_or("artifact-dir", "artifacts")),
    )?;
    let rt = rt.as_ref();

    let mut cfg = RunConfig::default();
    cfg.name = format!("e2e_{model}_{bits}bit");
    cfg.model = model.clone();
    cfg.steps = steps;
    cfg.first.kind = FirstOrderKind::AdamW;
    cfg.first.lr = args.get_f64("lr", 2e-3) as f32;
    cfg.first.weight_decay = 0.05;
    cfg.second.kind = SecondOrderKind::Shampoo;
    cfg.second.quant.bits = bits;
    // T1/T2 scaled from the paper's (100, 500) to the shorter run
    cfg.second.update_precond_every = args.get_usize("t1", 25);
    cfg.second.update_invroot_every = args.get_usize("t2", 50);
    cfg.schedule = Schedule::Cosine { warmup: steps / 20 };
    cfg.eval_every = args.get_usize("eval-every", 50);
    cfg.eval_batches = 4;
    cfg.log_every = 10;

    let mut trainer = Trainer::new(rt, cfg)?;
    let m = trainer.memory_report();
    let nparams = trainer.model.param_count();
    println!(
        "model={model} params={nparams} ({:.1}M) bits={bits} steps={steps}",
        nparams as f64 / 1e6
    );
    println!(
        "memory: params {:.1}MB + grads {:.1}MB + F-state {:.1}MB + Shampoo-state {:.1}MB = {:.1}MB",
        m.params_bytes as f64 / 1048576.0,
        m.grads_bytes as f64 / 1048576.0,
        m.first_order_bytes as f64 / 1048576.0,
        m.second_order_bytes as f64 / 1048576.0,
        m.total_mb()
    );

    let res = trainer.train(rt, Some(&out.join("metrics.csv")))?;
    trainer.save_checkpoint(&out.join("checkpoint.bin"), steps)?;

    println!("\nloss curve (every 50 steps):");
    for (s, l) in &res.losses {
        if s % 50 == 0 || *s == 1 {
            println!("  step {s:>5}  train loss {l:.4}");
        }
    }
    println!("\nvalidation:");
    for e in &res.evals {
        println!(
            "  step {:>5}  val loss {:.4}  ppl {:.1}",
            e.step,
            e.loss,
            (e.loss as f64).exp()
        );
    }
    if let Some(e) = &res.final_eval {
        println!(
            "\nfinal: val loss {:.4} (ppl {:.1})  wall {:.1}s  ({:.2} s/step)",
            e.loss,
            (e.loss as f64).exp(),
            res.wall_secs,
            res.wall_secs / steps as f64
        );
    }
    println!(
        "metrics: {}  checkpoint: {}",
        out.join("metrics.csv").display(),
        out.join("checkpoint.bin").display()
    );
    Ok(())
}
