//! Ablation sweeps (Tables 3, 8, 9, 10, 11 at laptop scale): quantization
//! technique ablation on the transformer LM, plus the extra-optimizer
//! comparison arms (NAdamW, Adagrad, schedule-free, M-FAC).
//!
//!   cargo run --release --example ablation_sweep -- [--table3] [--extras]
//!       [--steps 150] [--model tlm_tiny]
//!
//! With no selector flags, runs both suites.

#![allow(clippy::field_reassign_with_default)]

use anyhow::Result;
use shampoo4::config::{FirstOrderKind, RunConfig, Schedule, SecondOrderKind};
use shampoo4::coordinator::Trainer;
use shampoo4::quant::Mapping;
use shampoo4::runtime::{backend_by_name, Backend};
use shampoo4::util::cli::Args;

fn base_cfg(model: &str, steps: usize) -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.model = model.to_string();
    cfg.steps = steps;
    cfg.first.kind = FirstOrderKind::AdamW;
    cfg.first.lr = 2e-3;
    cfg.first.weight_decay = 0.05;
    cfg.second.kind = SecondOrderKind::Shampoo;
    cfg.second.update_precond_every = 20;
    cfg.second.update_invroot_every = 40;
    cfg.schedule = Schedule::Cosine { warmup: steps / 20 };
    cfg.eval_every = 0;
    cfg.eval_batches = 4;
    cfg.log_every = steps / 10;
    cfg
}

fn run(rt: &dyn Backend, cfg: RunConfig) -> Result<(f32, f32, f64, f64)> {
    let mut t = Trainer::new(rt, cfg)?;
    let res = t.train(rt, None)?;
    let train_loss = res.losses.last().map(|(_, l)| *l).unwrap_or(f32::NAN);
    let eval_loss = res.final_eval.as_ref().map(|e| e.loss).unwrap_or(f32::NAN);
    Ok((train_loss, eval_loss, res.wall_secs, res.memory.optimizer_mb()))
}

fn main() -> Result<()> {
    let args = Args::parse_from(std::env::args().skip(1), &["table3", "extras"]);
    let steps = args.get_usize("steps", 150);
    let model = args.get_or("model", "tlm_tiny").to_string();
    let rt = backend_by_name(
        args.get_or("backend", "auto"),
        std::path::Path::new(args.get_or("artifact-dir", "artifacts")),
    )?;
    let rt = rt.as_ref();
    let both = !args.flag("table3") && !args.flag("extras");

    if args.flag("table3") || both {
        println!("== Table 3 (ablation): AdamW + Shampoo on {model}, {steps} steps ==");
        println!(
            "{:<10} {:>4} {:>3} {:>4} {:>9} {:>9} {:>8} {:>9}",
            "mapping", "bits", "QM", "OR", "trainloss", "evalloss", "wall(s)", "opt(MB)"
        );
        let arms: Vec<(Mapping, u32, bool, bool)> = vec![
            (Mapping::Linear2, 4, false, false), // QM = A (naive)
            (Mapping::Dt, 4, true, false),
            (Mapping::Linear2, 4, true, false),
            (Mapping::Linear2, 4, true, true),
            (Mapping::Linear2, 3, false, false),
            (Mapping::Dt, 3, true, true),
            (Mapping::Linear2, 3, true, false),
            (Mapping::Linear2, 3, true, true),
            (Mapping::Linear2, 32, true, true), // 32-bit reference
        ];
        for (mapping, bits, eigen, rect) in arms {
            let mut cfg = base_cfg(&model, steps);
            cfg.second.quant.mapping = mapping;
            cfg.second.quant.bits = bits;
            cfg.second.quant.quantize_eigen = eigen;
            cfg.second.quant.rectify = rect;
            cfg.name = format!(
                "t3_{}_{}b_{}_{}",
                mapping.name(),
                bits,
                if eigen { "U" } else { "A" },
                rect
            );
            match run(rt, cfg) {
                Ok((tl, el, wall, mb)) => println!(
                    "{:<10} {:>4} {:>3} {:>4} {:>9.4} {:>9.4} {:>8.1} {:>9.2}",
                    mapping.name(),
                    bits,
                    if eigen { "U" } else { "A" },
                    if rect { "yes" } else { "no" },
                    tl,
                    el,
                    wall,
                    mb
                ),
                Err(e) => println!(
                    "{:<10} {:>4} {:>3} {:>4}  FAILED: {e}",
                    mapping.name(),
                    bits,
                    if eigen { "U" } else { "A" },
                    rect
                ),
            }
        }
    }

    if args.flag("extras") || both {
        println!("\n== Tables 9/10/11 (extra optimizers) on mlp_base, {steps} steps ==");
        println!(
            "{:<22} {:>7} {:>9} {:>8} {:>9}",
            "optimizer", "acc(%)", "evalloss", "wall(s)", "opt(MB)"
        );
        let arms: Vec<(FirstOrderKind, f32, SecondOrderKind)> = vec![
            (FirstOrderKind::Sgdm, 0.05, SecondOrderKind::None),
            (FirstOrderKind::AdamW, 1e-3, SecondOrderKind::None),
            (FirstOrderKind::NAdamW, 1e-3, SecondOrderKind::None),
            (FirstOrderKind::Adagrad, 0.01, SecondOrderKind::None),
            (FirstOrderKind::SgdScheduleFree, 0.5, SecondOrderKind::None),
            (FirstOrderKind::AdamWScheduleFree, 2e-3, SecondOrderKind::None),
            (FirstOrderKind::MFac, 0.05, SecondOrderKind::None),
            (FirstOrderKind::Adagrad, 0.01, SecondOrderKind::Shampoo),
            (FirstOrderKind::AdamW, 1e-3, SecondOrderKind::Shampoo),
        ];
        for (f, lr, second) in arms {
            let mut cfg = base_cfg("mlp_base", steps);
            cfg.first.kind = f;
            cfg.first.lr = lr;
            cfg.first.weight_decay = if matches!(f, FirstOrderKind::Sgdm) { 5e-4 } else { 0.05 };
            cfg.second.kind = second;
            cfg.name = format!("extras_{}_{}", f.name(), second.name());
            let label = if second == SecondOrderKind::None {
                f.name().to_string()
            } else {
                format!("{} + 4-bit {}", f.name(), second.name())
            };
            let mut t = Trainer::new(rt, cfg)?;
            let res = t.train(rt, None)?;
            let e = res.final_eval.as_ref().unwrap();
            println!(
                "{:<22} {:>7.2} {:>9.4} {:>8.1} {:>9.2}",
                label,
                e.accuracy.unwrap_or(0.0) * 100.0,
                e.loss,
                res.wall_secs,
                res.memory.optimizer_mb()
            );
        }
    }
    Ok(())
}
