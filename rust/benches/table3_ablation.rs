//! Bench: regenerates Table 3 — quantization-technique ablation
//! (QM ∈ {A, U} × OR × {DT, Linear-2} × {3, 4}-bit) on the transformer LM.
//! Delegates to the same arms as examples/ablation_sweep.rs but sized for
//! `cargo bench` (SHAMPOO4_BENCH_STEPS, default 120).

#![allow(clippy::field_reassign_with_default)]

use anyhow::Result;
use shampoo4::config::{FirstOrderKind, RunConfig, Schedule, SecondOrderKind};
use shampoo4::coordinator::Trainer;
use shampoo4::quant::Mapping;
use shampoo4::runtime::default_backend;

fn main() -> Result<()> {
    let steps: usize = std::env::var("SHAMPOO4_BENCH_STEPS")
        .ok().and_then(|v| v.parse().ok()).unwrap_or(120);
    let rt = default_backend(std::path::Path::new("artifacts"))?;
    let rt = rt.as_ref();
    println!("# Table 3 @ tlm_tiny, {steps} steps (paper: Swin-Tiny, 100 epochs)");
    println!("{:<10} {:>4} {:>3} {:>4} {:>9} {:>9}", "mapping", "bits", "QM", "OR", "TL", "VL");
    let arms: Vec<(Mapping, u32, bool, bool)> = vec![
        (Mapping::Linear2, 4, false, false),
        (Mapping::Dt, 4, true, false),
        (Mapping::Linear2, 4, true, false),
        (Mapping::Linear2, 4, true, true),
        (Mapping::Linear2, 3, false, false),
        (Mapping::Dt, 3, true, false),
        (Mapping::Linear2, 3, true, false),
        (Mapping::Linear2, 3, true, true),
    ];
    for (mapping, bits, eigen, rect) in arms {
        let mut cfg = RunConfig::default();
        cfg.name = format!("t3b_{}_{bits}_{eigen}_{rect}", mapping.name());
        cfg.model = "tlm_tiny".into();
        cfg.steps = steps;
        cfg.first.kind = FirstOrderKind::AdamW;
        cfg.first.lr = 2e-3;
        cfg.second.kind = SecondOrderKind::Shampoo;
        cfg.second.quant.mapping = mapping;
        cfg.second.quant.bits = bits;
        cfg.second.quant.quantize_eigen = eigen;
        cfg.second.quant.rectify = rect;
        cfg.second.update_precond_every = 20;
        cfg.second.update_invroot_every = 40;
        cfg.schedule = Schedule::Cosine { warmup: steps / 20 };
        cfg.eval_every = 0;
        cfg.eval_batches = 4;
        cfg.log_every = steps;
        let row = (|| -> Result<(f32, f32)> {
            let mut t = Trainer::new(rt, cfg.clone())?;
            let res = t.train(rt, None)?;
            Ok((
                res.losses.last().map(|(_, l)| *l).unwrap_or(f32::NAN),
                res.final_eval.map(|e| e.loss).unwrap_or(f32::NAN),
            ))
        })();
        match row {
            Ok((tl, vl)) => println!(
                "{:<10} {:>4} {:>3} {:>4} {:>9.4} {:>9.4}",
                mapping.name(), bits, if eigen { "U" } else { "A" },
                if rect { "yes" } else { "no" }, tl, vl
            ),
            Err(e) => println!(
                "{:<10} {:>4} {:>3} {:>4}  NaN/FAILED ({e})",
                mapping.name(), bits, if eigen { "U" } else { "A" },
                if rect { "yes" } else { "no" }
            ),
        }
    }
    Ok(())
}
