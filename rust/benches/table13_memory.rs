//! Bench: regenerates Table 13 — LLaMA2-7B max batch under 80 GiB across
//! optimizers, via the analytic memory planner (same accounting model as
//! the live state manager) — extended with the StateCodec first-order arms:
//! AdamW moments at 32/8/4-bit, alone and stacked under 4-bit Shampoo.
//! Machine-readable summary: bench_out/BENCH_state_codec.json.

use shampoo4::coordinator::memory::{plan, MemoryPlan, OptimizerPlan, PlannedModel};
use shampoo4::util::json::Json;

struct Arm {
    label: &'static str,
    adam_bits: u32,
    /// 0 = no Shampoo stacked on top
    shampoo_bits: u32,
    plan: MemoryPlan,
}

fn main() {
    let budget = 81920usize * 1024 * 1024;
    let m = PlannedModel::llama2_7b();
    println!(
        "# Table 13: {} ({:.2}B params), 80GiB A800, ctx 256",
        m.name,
        m.param_count() as f64 / 1e9
    );
    println!("{:<36} {:>7} {:>12} {:>6}", "Optimizer", "Batch", "TMC(MB)", "fits");
    let adam = |bits| plan(&m, OptimizerPlan::Adam { bits });
    let stacked = |adam_bits, shampoo_bits| {
        plan(&m, OptimizerPlan::AdamShampoo { adam_bits, shampoo_bits, max_order: 2048 })
    };
    let arms = [
        Arm { label: "32-bit AdamW", adam_bits: 32, shampoo_bits: 0, plan: adam(32) },
        Arm { label: "8-bit AdamW", adam_bits: 8, shampoo_bits: 0, plan: adam(8) },
        Arm { label: "4-bit AdamW", adam_bits: 4, shampoo_bits: 0, plan: adam(4) },
        Arm {
            label: "8-bit AdamW + 32-bit Shampoo",
            adam_bits: 8,
            shampoo_bits: 32,
            plan: stacked(8, 32),
        },
        Arm {
            label: "32-bit AdamW + 4-bit Shampoo",
            adam_bits: 32,
            shampoo_bits: 4,
            plan: stacked(32, 4),
        },
        Arm {
            label: "8-bit AdamW + 4-bit Shampoo (our)",
            adam_bits: 8,
            shampoo_bits: 4,
            plan: stacked(8, 4),
        },
        Arm {
            label: "4-bit AdamW + 4-bit Shampoo",
            adam_bits: 4,
            shampoo_bits: 4,
            plan: stacked(4, 4),
        },
    ];
    let mut rows = Vec::new();
    for arm in &arms {
        for batch in [2usize, 64, 128, 256] {
            let total = arm.plan.total_at_batch(batch);
            println!(
                "{:<36} {:>7} {:>12.0} {:>6}",
                arm.label,
                batch,
                total as f64 / 1048576.0,
                if total <= budget { "yes" } else { "OOM" }
            );
        }
        let max_batch = arm.plan.max_batch(budget);
        println!("{:<36} max batch: {}", arm.label, max_batch);
        rows.push(Json::obj(vec![
            ("optimizer", Json::Str(arm.label.to_string())),
            ("adam_bits", Json::Num(arm.adam_bits as f64)),
            ("shampoo_bits", Json::Num(arm.shampoo_bits as f64)),
            (
                "first_order_mb",
                Json::Num(arm.plan.adam_bytes as f64 / 1048576.0),
            ),
            (
                "second_order_mb",
                Json::Num(arm.plan.shampoo_bytes as f64 / 1048576.0),
            ),
            ("max_batch", Json::Num(max_batch as f64)),
        ]));
    }
    let out = Json::obj(vec![
        ("model", Json::Str(m.name.clone())),
        ("budget_mb", Json::Num(budget as f64 / 1048576.0)),
        ("rows", Json::Arr(rows)),
    ]);
    std::fs::create_dir_all("bench_out").ok();
    match std::fs::write("bench_out/BENCH_state_codec.json", out.to_string()) {
        Ok(()) => println!("# wrote bench_out/BENCH_state_codec.json"),
        Err(e) => println!("# could not write bench_out/BENCH_state_codec.json: {e}"),
    }

    // ---- mixed-policy arms (the codec policy layer: per-buffer bitwidths) --
    println!("\n# Mixed codec-policy arms (m / v at independent bitwidths)");
    println!("{:<44} {:>12} {:>12} {:>9}", "Policy", "first(MB)", "second(MB)", "maxbatch");
    let policy_arms = [
        ("m=q4,v=q8", 4u32, 8u32, 0u32),
        ("m=q4,v=q4", 4, 4, 0),
        ("m=q4,v=q8 + 4-bit Shampoo", 4, 8, 4),
        ("m=q4,v=q8 + 32-bit Shampoo", 4, 8, 32),
        ("m=q4-sr,v=q8 + 4-bit Shampoo", 4, 8, 4),
    ];
    let mut policy_rows = Vec::new();
    for &(label, m_bits, v_bits, shampoo_bits) in &policy_arms {
        let p = plan(
            &m,
            OptimizerPlan::AdamPolicy { m_bits, v_bits, shampoo_bits, max_order: 2048 },
        );
        let max_batch = p.max_batch(budget);
        println!(
            "{:<44} {:>12.0} {:>12.0} {:>9}",
            label,
            p.adam_bytes as f64 / 1048576.0,
            p.shampoo_bytes as f64 / 1048576.0,
            max_batch
        );
        policy_rows.push(Json::obj(vec![
            ("policy", Json::Str(label.to_string())),
            ("m_bits", Json::Num(m_bits as f64)),
            ("v_bits", Json::Num(v_bits as f64)),
            ("shampoo_bits", Json::Num(shampoo_bits as f64)),
            ("first_order_mb", Json::Num(p.adam_bytes as f64 / 1048576.0)),
            ("second_order_mb", Json::Num(p.shampoo_bytes as f64 / 1048576.0)),
            ("max_batch", Json::Num(max_batch as f64)),
        ]));
    }
    let policy_out = Json::obj(vec![
        ("model", Json::Str(m.name.clone())),
        ("budget_mb", Json::Num(budget as f64 / 1048576.0)),
        ("rows", Json::Arr(policy_rows)),
    ]);
    match std::fs::write("bench_out/BENCH_codec_policy.json", policy_out.to_string()) {
        Ok(()) => println!("# wrote bench_out/BENCH_codec_policy.json"),
        Err(e) => println!("# could not write bench_out/BENCH_codec_policy.json: {e}"),
    }
    println!("# paper: AdamW fits 128 / OOM 256; +32-bit Shampoo OOM@2; +4-bit fits 64 / OOM 128");
    println!("# codec arms: 4-bit moments shave ~45 GB off 32-bit AdamW states at 7B scale");
    println!("# policy arms: m=q4,v=q8 splits the difference — Li et al.'s sweet spot");
}
