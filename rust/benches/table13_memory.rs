//! Bench: regenerates Table 13 — LLaMA2-7B max batch under 80 GiB across
//! optimizers, via the analytic memory planner (same accounting model as
//! the live state manager).

use shampoo4::coordinator::memory::{plan, OptimizerPlan, PlannedModel};

fn main() {
    let budget = 81920usize * 1024 * 1024;
    let m = PlannedModel::llama2_7b();
    println!(
        "# Table 13: {} ({:.2}B params), 80GiB A800, ctx 256",
        m.name,
        m.param_count() as f64 / 1e9
    );
    println!("{:<36} {:>7} {:>12} {:>6}", "Optimizer", "Batch", "TMC(MB)", "fits");
    let arms = [
        ("8-bit AdamW", plan(&m, OptimizerPlan::Adam { bits: 8 })),
        ("8-bit AdamW + 32-bit Shampoo",
         plan(&m, OptimizerPlan::AdamShampoo { adam_bits: 8, shampoo_bits: 32, max_order: 2048 })),
        ("8-bit AdamW + 4-bit Shampoo (our)",
         plan(&m, OptimizerPlan::AdamShampoo { adam_bits: 8, shampoo_bits: 4, max_order: 2048 })),
    ];
    for (name, p) in &arms {
        for batch in [2usize, 64, 128, 256] {
            let total = p.total_at_batch(batch);
            println!("{:<36} {:>7} {:>12.0} {:>6}", name, batch,
                     total as f64 / 1048576.0, if total <= budget { "yes" } else { "OOM" });
        }
        println!("{:<36} max batch: {}", name, p.max_batch(budget));
    }
    println!("# paper: AdamW fits 128 / OOM 256; +32-bit Shampoo OOM@2; +4-bit fits 64 / OOM 128");
}
