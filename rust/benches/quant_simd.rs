//! Quant-kernel throughput harness: scalar vs chunked vs SIMD arms for the
//! block-wise quantizer (encode + decode at q2/q3/q4/q8) and the bit-pack
//! lanes (1/2/4/8-bit), with bytes/second columns.
//!
//!   cargo bench --bench quant_simd                  # scalar + chunked arms
//!   cargo bench --bench quant_simd --features simd  # + explicit SIMD arms
//!
//! Normal runs append a machine-readable run record (rows + derived
//! speedups) to `BENCH_quant_simd.json` at the repo root — the committed
//! baseline the SIMD rewrite is judged against. Set `QUANT_BENCH_SMOKE=1`
//! (CI) for short measurement windows and a throwaway output file under
//! `bench_out/` so the committed baseline is never overwritten by a noisy
//! smoke run.

use shampoo4::quant::{
    codebook, dequantize_chunked, dequantize_scalar, pack_bits_chunked, quantize_chunked,
    quantize_scalar, unpack_bits_into_chunked, Mapping, BLOCK,
};
#[cfg(feature = "simd")]
use shampoo4::quant::{dequantize_simd, quantize_simd};
use shampoo4::util::json::Json;
use shampoo4::util::rng::Rng;
use shampoo4::util::timer::BenchRunner;

/// Repo-root baseline file (normal mode appends a run record here).
const OUT_PATH: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_quant_simd.json");
/// Most recent run records kept in the baseline's `runs` array.
const KEEP_RUNS: usize = 20;

fn arch() -> &'static str {
    #[cfg(feature = "simd")]
    {
        shampoo4::quant::simd::simd_arch()
    }
    #[cfg(not(feature = "simd"))]
    {
        "disabled"
    }
}

/// Time one arm, print its throughput row, and record it as a JSON row.
fn row(runner: &BenchRunner, rows: &mut Vec<Json>, name: &str, bytes: usize, f: impl FnMut()) {
    let s = runner.run(name, f);
    println!("{}", s.throughput_report(bytes));
    rows.push(Json::obj(vec![
        ("name", Json::Str(name.to_string())),
        ("mean_ns", Json::Num(s.mean_ns)),
        ("p50_ns", Json::Num(s.p50_ns)),
        ("min_ns", Json::Num(s.min_ns)),
        ("bytes", Json::Num(bytes as f64)),
        ("bytes_per_sec", Json::Num(s.bytes_per_sec(bytes))),
    ]));
}

fn mean_of(rows: &[Json], name: &str) -> Option<f64> {
    rows.iter()
        .find(|r| r.get("name").and_then(|v| v.as_str()) == Some(name))
        .and_then(|r| r.get("mean_ns").and_then(|v| v.as_f64()))
}

/// `a / b` as a speedup (how many times faster `b` is than `a`).
fn speedup(a: Option<f64>, b: Option<f64>) -> Json {
    match (a, b) {
        (Some(a), Some(b)) if b > 0.0 => Json::Num(a / b),
        _ => Json::Null,
    }
}

fn main() {
    let smoke = std::env::var("QUANT_BENCH_SMOKE").is_ok_and(|v| v == "1");
    let (runner, n) = if smoke {
        (BenchRunner::quick(), 1usize << 16)
    } else {
        (BenchRunner::default(), 1usize << 20)
    };
    let simd_on = cfg!(feature = "simd");
    println!("# quant throughput harness: n={n} f32 elems, simd={simd_on}, arch={}", arch());
    let mut rng = Rng::new(42);
    let x: Vec<f32> = rng.normal_vec(n);
    let fbytes = n * 4; // payload one encode reads / one decode writes
    let mut rows: Vec<Json> = Vec::new();

    // ---- block quantizer: encode + decode at every bitwidth class ---------
    // q3 exercises the generic bit-cursor pack path; the byte-aligned widths
    // exercise the chunked fast paths and (with --features simd) the
    // SSE2/SWAR lanes.
    for (label, mapping, bits) in [
        ("q2-dt", Mapping::Dt, 2u32),
        ("q3-dt", Mapping::Dt, 3),
        ("q4-linear2", Mapping::Linear2, 4),
        ("q8-dt", Mapping::Dt, 8),
    ] {
        let cb = codebook(mapping, bits);
        let q = quantize_chunked(&x, &cb, bits, BLOCK);
        row(&runner, &mut rows, &format!("{label}/encode scalar"), fbytes, || {
            std::hint::black_box(quantize_scalar(std::hint::black_box(&x), &cb, bits, BLOCK));
        });
        row(&runner, &mut rows, &format!("{label}/encode chunked"), fbytes, || {
            std::hint::black_box(quantize_chunked(std::hint::black_box(&x), &cb, bits, BLOCK));
        });
        #[cfg(feature = "simd")]
        row(&runner, &mut rows, &format!("{label}/encode simd"), fbytes, || {
            std::hint::black_box(quantize_simd(std::hint::black_box(&x), &cb, bits, BLOCK));
        });
        row(&runner, &mut rows, &format!("{label}/decode scalar"), fbytes, || {
            std::hint::black_box(dequantize_scalar(std::hint::black_box(&q), &cb));
        });
        row(&runner, &mut rows, &format!("{label}/decode chunked"), fbytes, || {
            std::hint::black_box(dequantize_chunked(std::hint::black_box(&q), &cb));
        });
        #[cfg(feature = "simd")]
        row(&runner, &mut rows, &format!("{label}/decode simd"), fbytes, || {
            std::hint::black_box(dequantize_simd(std::hint::black_box(&q), &cb));
        });
    }

    // ---- raw pack lanes ---------------------------------------------------
    for bits in [1u32, 2, 4, 8] {
        let codes: Vec<u8> = (0..n).map(|_| rng.below(1usize << bits) as u8).collect();
        let packed = pack_bits_chunked(&codes, bits);
        let mut out = vec![0u8; n];
        row(&runner, &mut rows, &format!("pack{bits}/chunked"), n, || {
            std::hint::black_box(pack_bits_chunked(std::hint::black_box(&codes), bits));
        });
        #[cfg(feature = "simd")]
        row(&runner, &mut rows, &format!("pack{bits}/simd"), n, || {
            std::hint::black_box(shampoo4::quant::simd::pack_bits_simd(
                std::hint::black_box(&codes),
                bits,
            ));
        });
        row(&runner, &mut rows, &format!("unpack{bits}/chunked"), n, || {
            unpack_bits_into_chunked(std::hint::black_box(&packed), bits, &mut out);
            std::hint::black_box(&out);
        });
        #[cfg(feature = "simd")]
        row(&runner, &mut rows, &format!("unpack{bits}/simd"), n, || {
            shampoo4::quant::simd::unpack_bits_into_simd(
                std::hint::black_box(&packed),
                bits,
                &mut out,
            );
            std::hint::black_box(&out);
        });
    }

    // ---- derived speedups (the acceptance numbers) ------------------------
    let enc_scalar = mean_of(&rows, "q4-linear2/encode scalar");
    let derived = Json::obj(vec![
        (
            "q4_encode_speedup_simd_vs_scalar",
            speedup(enc_scalar, mean_of(&rows, "q4-linear2/encode simd")),
        ),
        (
            "q4_encode_speedup_chunked_vs_scalar",
            speedup(enc_scalar, mean_of(&rows, "q4-linear2/encode chunked")),
        ),
        (
            "q4_decode_speedup_simd_vs_scalar",
            speedup(
                mean_of(&rows, "q4-linear2/decode scalar"),
                mean_of(&rows, "q4-linear2/decode simd"),
            ),
        ),
    ]);
    for (k, v) in derived.as_obj().unwrap() {
        match v.as_f64() {
            Some(r) => println!("# {k}: {r:.2}x"),
            None => println!("# {k}: n/a (build with --features simd)"),
        }
    }

    let timestamp = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    let run = Json::obj(vec![
        ("timestamp_unix", Json::Num(timestamp as f64)),
        ("smoke", Json::Bool(smoke)),
        ("simd_enabled", Json::Bool(simd_on)),
        ("simd_arch", Json::Str(arch().to_string())),
        ("n", Json::Num(n as f64)),
        ("rows", Json::Arr(rows)),
        ("derived", derived),
    ]);

    if smoke {
        // throwaway output: never touches the committed baseline
        std::fs::create_dir_all("bench_out").ok();
        let out = Json::obj(vec![("runs", Json::Arr(vec![run]))]);
        match std::fs::write("bench_out/BENCH_quant_simd.smoke.json", out.to_string()) {
            Ok(()) => println!("# wrote bench_out/BENCH_quant_simd.smoke.json (smoke mode)"),
            Err(e) => println!("# could not write smoke output: {e}"),
        }
        return;
    }

    // merge into the committed baseline: keep the last KEEP_RUNS records
    let mut runs: Vec<Json> = std::fs::read_to_string(OUT_PATH)
        .ok()
        .and_then(|s| Json::parse(&s).ok())
        .and_then(|j| j.get("runs").and_then(|r| r.as_arr().map(|a| a.to_vec())))
        .unwrap_or_default();
    runs.push(run);
    let excess = runs.len().saturating_sub(KEEP_RUNS);
    let runs = runs.split_off(excess);
    let note = "quant throughput baseline; regenerate with \
                `cargo bench --bench quant_simd --features simd` (and once without \
                --features simd for the scalar/chunked-only arms)";
    let out = Json::obj(vec![
        ("_note", Json::Str(note.to_string())),
        ("runs", Json::Arr(runs)),
    ]);
    match std::fs::write(OUT_PATH, out.to_string()) {
        Ok(()) => println!("# appended run to BENCH_quant_simd.json (repo root)"),
        Err(e) => println!("# could not write BENCH_quant_simd.json: {e}"),
    }
}
