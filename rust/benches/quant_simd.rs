//! Quant-kernel throughput harness: scalar vs chunked vs every detected
//! SIMD lane for the block-wise quantizer (deterministic + stochastic
//! encode, decode, at q2/q3/q4/q8) and the bit-pack lanes (1/2/4/8-bit),
//! with bytes/second columns.
//!
//!   cargo bench --bench quant_simd                  # scalar + chunked arms
//!   cargo bench --bench quant_simd --features simd  # + one row per lane
//!
//! With `--features simd`, vector rows are emitted per *detected* lane
//! (`simd[sse2]`, `simd[avx2]`, `simd[neon]`) via the forced-lane entry
//! points, so one run on an AVX2 host measures both x86 lanes side by
//! side. Every JSON row carries a `lane` field (`"ref"` for the
//! scalar/chunked reference arms); the harness refuses to append a run
//! record whose rows are missing it.
//!
//! Normal runs append a machine-readable run record (rows + derived
//! speedups) to `BENCH_quant_simd.json` at the repo root — the committed
//! baseline the SIMD rewrite is judged against. Set `QUANT_BENCH_SMOKE=1`
//! (CI) for short measurement windows and a throwaway output file under
//! `bench_out/` so the committed baseline is never overwritten by a noisy
//! smoke run.

use shampoo4::quant::{
    codebook, dequantize_chunked, dequantize_scalar, pack_bits_chunked, quantize_chunked,
    quantize_scalar, try_quantize_stochastic_scalar, unpack_bits_into_chunked, Mapping, BLOCK,
};
use shampoo4::util::json::Json;
use shampoo4::util::rng::Rng;
use shampoo4::util::timer::BenchRunner;
use std::collections::BTreeMap;

/// Repo-root baseline file (normal mode appends a run record here).
const OUT_PATH: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_quant_simd.json");
/// Most recent run records kept in the baseline's `runs` array.
const KEEP_RUNS: usize = 20;

fn arch() -> &'static str {
    #[cfg(feature = "simd")]
    {
        shampoo4::quant::simd::simd_arch()
    }
    #[cfg(not(feature = "simd"))]
    {
        "disabled"
    }
}

/// Vector lanes to bench: every detected lane except the forced-scalar
/// fallback (which routes to the chunked reference paths already measured
/// by the `ref` rows).
#[cfg(feature = "simd")]
fn bench_lanes() -> Vec<shampoo4::quant::simd::Lane> {
    shampoo4::quant::simd::detected_lanes()
        .into_iter()
        .filter(|&l| l != shampoo4::quant::simd::Lane::Scalar)
        .collect()
}

/// Time one arm, print its throughput row, and record it as a JSON row.
/// `lane` is the registry lane the row measures, or `"ref"` for the
/// scalar/chunked reference arms.
fn row(
    runner: &BenchRunner,
    rows: &mut Vec<Json>,
    name: &str,
    lane: &str,
    bytes: usize,
    f: impl FnMut(),
) {
    let s = runner.run(name, f);
    println!("{}", s.throughput_report(bytes));
    rows.push(Json::obj(vec![
        ("name", Json::Str(name.to_string())),
        ("lane", Json::Str(lane.to_string())),
        ("mean_ns", Json::Num(s.mean_ns)),
        ("p50_ns", Json::Num(s.p50_ns)),
        ("min_ns", Json::Num(s.min_ns)),
        ("bytes", Json::Num(bytes as f64)),
        ("bytes_per_sec", Json::Num(s.bytes_per_sec(bytes))),
    ]));
}

fn mean_of(rows: &[Json], name: &str) -> Option<f64> {
    rows.iter()
        .find(|r| r.get("name").and_then(|v| v.as_str()) == Some(name))
        .and_then(|r| r.get("mean_ns").and_then(|v| v.as_f64()))
}

/// `a / b` as a speedup (how many times faster `b` is than `a`).
fn speedup(a: Option<f64>, b: Option<f64>) -> Json {
    match (a, b) {
        (Some(a), Some(b)) if b > 0.0 => Json::Num(a / b),
        _ => Json::Null,
    }
}

/// The lane-field schema guard: every row of a run record must carry a
/// non-empty `lane` string, or the record is refused (exit 1) rather than
/// appended to the committed baseline.
fn rows_all_have_lane(run: &Json) -> bool {
    run.get("rows")
        .and_then(|r| r.as_arr())
        .is_some_and(|rows| {
            rows.iter().all(|r| {
                r.get("lane").and_then(|l| l.as_str()).is_some_and(|l| !l.is_empty())
            })
        })
}

fn main() {
    let smoke = std::env::var("QUANT_BENCH_SMOKE").is_ok_and(|v| v == "1");
    let (runner, n) = if smoke {
        (BenchRunner::quick(), 1usize << 16)
    } else {
        (BenchRunner::default(), 1usize << 20)
    };
    let simd_on = cfg!(feature = "simd");
    println!("# quant throughput harness: n={n} f32 elems, simd={simd_on}, arch={}", arch());
    let mut rng = Rng::new(42);
    let x: Vec<f32> = rng.normal_vec(n);
    let fbytes = n * 4; // payload one encode reads / one decode writes
    let mut rows: Vec<Json> = Vec::new();

    // ---- block quantizer: encode + decode at every bitwidth class ---------
    // q3 exercises the generic bit-cursor pack path; the byte-aligned widths
    // exercise the chunked fast paths and (with --features simd) one row
    // per detected vector lane via the forced-lane entry points.
    for (label, mapping, bits) in [
        ("q2-dt", Mapping::Dt, 2u32),
        ("q3-dt", Mapping::Dt, 3),
        ("q4-linear2", Mapping::Linear2, 4),
        ("q8-dt", Mapping::Dt, 8),
    ] {
        let cb = codebook(mapping, bits);
        let q = quantize_chunked(&x, &cb, bits, BLOCK);
        row(&runner, &mut rows, &format!("{label}/encode scalar"), "ref", fbytes, || {
            std::hint::black_box(quantize_scalar(std::hint::black_box(&x), &cb, bits, BLOCK));
        });
        row(&runner, &mut rows, &format!("{label}/encode chunked"), "ref", fbytes, || {
            std::hint::black_box(quantize_chunked(std::hint::black_box(&x), &cb, bits, BLOCK));
        });
        #[cfg(feature = "simd")]
        for lane in bench_lanes() {
            let name = format!("{label}/encode simd[{lane}]");
            row(&runner, &mut rows, &name, lane.name(), fbytes, || {
                std::hint::black_box(shampoo4::quant::quantize_lane(
                    std::hint::black_box(&x),
                    &cb,
                    bits,
                    BLOCK,
                    lane,
                ));
            });
        }
        // stochastic-rounding encode: the second hot loop the lane registry
        // vectorizes (bracket + fraction pass); the RNG stream advances
        // identically on every arm
        let mut sr_rng = Rng::new(7);
        row(&runner, &mut rows, &format!("{label}/encode-sr scalar"), "ref", fbytes, || {
            std::hint::black_box(
                try_quantize_stochastic_scalar(
                    std::hint::black_box(&x),
                    &cb,
                    bits,
                    BLOCK,
                    &mut sr_rng,
                )
                .unwrap(),
            );
        });
        #[cfg(feature = "simd")]
        for lane in bench_lanes() {
            let name = format!("{label}/encode-sr simd[{lane}]");
            let mut lane_rng = Rng::new(7);
            row(&runner, &mut rows, &name, lane.name(), fbytes, || {
                std::hint::black_box(
                    shampoo4::quant::try_quantize_stochastic_lane(
                        std::hint::black_box(&x),
                        &cb,
                        bits,
                        BLOCK,
                        &mut lane_rng,
                        lane,
                    )
                    .unwrap(),
                );
            });
        }
        row(&runner, &mut rows, &format!("{label}/decode scalar"), "ref", fbytes, || {
            std::hint::black_box(dequantize_scalar(std::hint::black_box(&q), &cb));
        });
        row(&runner, &mut rows, &format!("{label}/decode chunked"), "ref", fbytes, || {
            std::hint::black_box(dequantize_chunked(std::hint::black_box(&q), &cb));
        });
        #[cfg(feature = "simd")]
        for lane in bench_lanes() {
            let name = format!("{label}/decode simd[{lane}]");
            row(&runner, &mut rows, &name, lane.name(), fbytes, || {
                std::hint::black_box(shampoo4::quant::dequantize_lane(
                    std::hint::black_box(&q),
                    &cb,
                    lane,
                ));
            });
        }
    }

    // ---- raw pack lanes ---------------------------------------------------
    for bits in [1u32, 2, 4, 8] {
        let codes: Vec<u8> = (0..n).map(|_| rng.below(1usize << bits) as u8).collect();
        let packed = pack_bits_chunked(&codes, bits);
        let mut out = vec![0u8; n];
        row(&runner, &mut rows, &format!("pack{bits}/chunked"), "ref", n, || {
            std::hint::black_box(pack_bits_chunked(std::hint::black_box(&codes), bits));
        });
        #[cfg(feature = "simd")]
        for lane in bench_lanes() {
            let name = format!("pack{bits}/simd[{lane}]");
            row(&runner, &mut rows, &name, lane.name(), n, || {
                std::hint::black_box(shampoo4::quant::simd::pack_bits_lane(
                    lane,
                    std::hint::black_box(&codes),
                    bits,
                ));
            });
        }
        row(&runner, &mut rows, &format!("unpack{bits}/chunked"), "ref", n, || {
            unpack_bits_into_chunked(std::hint::black_box(&packed), bits, &mut out);
            std::hint::black_box(&out);
        });
        #[cfg(feature = "simd")]
        for lane in bench_lanes() {
            let name = format!("unpack{bits}/simd[{lane}]");
            row(&runner, &mut rows, &name, lane.name(), n, || {
                shampoo4::quant::simd::unpack_bits_into_lane(
                    lane,
                    std::hint::black_box(&packed),
                    bits,
                    &mut out,
                );
                std::hint::black_box(&out);
            });
        }
    }

    // ---- derived speedups (the acceptance numbers) ------------------------
    // per lane: q4 + q8 encode/decode and the SR encode vs the scalar
    // reference; plus the AVX2-vs-SSE2 widening ratios on hosts with both
    let mut derived: BTreeMap<String, Json> = BTreeMap::new();
    derived.insert(
        "q4_encode_speedup_chunked_vs_scalar".to_string(),
        speedup(
            mean_of(&rows, "q4-linear2/encode scalar"),
            mean_of(&rows, "q4-linear2/encode chunked"),
        ),
    );
    #[cfg(feature = "simd")]
    for lane in bench_lanes() {
        for (short, label) in [("q4", "q4-linear2"), ("q8", "q8-dt")] {
            derived.insert(
                format!("{short}_encode_speedup_{lane}_vs_scalar"),
                speedup(
                    mean_of(&rows, &format!("{label}/encode scalar")),
                    mean_of(&rows, &format!("{label}/encode simd[{lane}]")),
                ),
            );
            derived.insert(
                format!("{short}_decode_speedup_{lane}_vs_scalar"),
                speedup(
                    mean_of(&rows, &format!("{label}/decode scalar")),
                    mean_of(&rows, &format!("{label}/decode simd[{lane}]")),
                ),
            );
            derived.insert(
                format!("{short}_sr_encode_speedup_{lane}_vs_scalar"),
                speedup(
                    mean_of(&rows, &format!("{label}/encode-sr scalar")),
                    mean_of(&rows, &format!("{label}/encode-sr simd[{lane}]")),
                ),
            );
        }
    }
    #[cfg(feature = "simd")]
    for (short, label) in [("q4", "q4-linear2"), ("q8", "q8-dt")] {
        derived.insert(
            format!("{short}_encode_speedup_avx2_vs_sse2"),
            speedup(
                mean_of(&rows, &format!("{label}/encode simd[sse2]")),
                mean_of(&rows, &format!("{label}/encode simd[avx2]")),
            ),
        );
    }
    let derived = Json::Obj(derived);
    for (k, v) in derived.as_obj().unwrap() {
        match v.as_f64() {
            Some(r) => println!("# {k}: {r:.2}x"),
            None => println!("# {k}: n/a (lane not detected or simd disabled)"),
        }
    }

    let timestamp = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    let run = Json::obj(vec![
        ("timestamp_unix", Json::Num(timestamp as f64)),
        ("smoke", Json::Bool(smoke)),
        ("simd_enabled", Json::Bool(simd_on)),
        ("simd_arch", Json::Str(arch().to_string())),
        ("n", Json::Num(n as f64)),
        ("rows", Json::Arr(rows)),
        ("derived", derived),
    ]);
    if !rows_all_have_lane(&run) {
        eprintln!("# refusing to record: a row is missing its `lane` field");
        std::process::exit(1);
    }

    if smoke {
        // throwaway output: never touches the committed baseline
        std::fs::create_dir_all("bench_out").ok();
        let out = Json::obj(vec![("runs", Json::Arr(vec![run]))]);
        match std::fs::write("bench_out/BENCH_quant_simd.smoke.json", out.to_string()) {
            Ok(()) => println!("# wrote bench_out/BENCH_quant_simd.smoke.json (smoke mode)"),
            Err(e) => println!("# could not write smoke output: {e}"),
        }
        return;
    }

    // merge into the committed baseline: keep the last KEEP_RUNS records
    let mut runs: Vec<Json> = std::fs::read_to_string(OUT_PATH)
        .ok()
        .and_then(|s| Json::parse(&s).ok())
        .and_then(|j| j.get("runs").and_then(|r| r.as_arr().map(|a| a.to_vec())))
        .unwrap_or_default();
    runs.push(run);
    let excess = runs.len().saturating_sub(KEEP_RUNS);
    let runs = runs.split_off(excess);
    let note = "quant throughput baseline; regenerate with \
                `cargo bench --bench quant_simd --features simd` (and once without \
                --features simd for the scalar/chunked-only arms); every row carries \
                a `lane` field (`ref` = scalar/chunked reference arms)";
    let out = Json::obj(vec![
        ("_note", Json::Str(note.to_string())),
        ("runs", Json::Arr(runs)),
    ]);
    match std::fs::write(OUT_PATH, out.to_string()) {
        Ok(()) => println!("# appended run to BENCH_quant_simd.json (repo root)"),
        Err(e) => println!("# could not write BENCH_quant_simd.json: {e}"),
    }
}
