//! Hot-path micro-benchmarks (§Perf instrument): times every stage of the
//! per-step pipeline so the optimization log in EXPERIMENTS.md §Perf has
//! before/after numbers.
//!
//!   cargo bench --bench hotpath_micro
//!
//! Stages: native quantize/dequantize + bit packing, partitioner
//! extract/scatter, native first-order update, host matmul, and the PJRT
//! artifact executions (gram, precond4, pu, piru, model step).

#![allow(clippy::field_reassign_with_default)]

use shampoo4::config::RunConfig;
use shampoo4::coordinator::scheduler::Scheduler;
use shampoo4::coordinator::Trainer;
use shampoo4::linalg::Mat;
use shampoo4::quant::{
    codebook, dequantize, dequantize_scalar, pack_bits, quantize, quantize_scalar,
    unpack_bits, Mapping,
};
use shampoo4::runtime::{default_backend, Backend, HostTensor};
use shampoo4::util::rng::Rng;
use shampoo4::util::timer::BenchRunner;

fn main() {
    let runner = BenchRunner::default();
    let mut rng = Rng::new(0);
    let cb = codebook(Mapping::Linear2, 4);

    // ---- native quantizer -------------------------------------------------
    // chunked (branch-free lanes + batched pack) vs the scalar reference —
    // the per-buffer codec policy rides these kernels on every StateBuf
    // store/load, so the gap here is the policy layer's per-step overhead
    let x: Vec<f32> = rng.normal_vec(128 * 128);
    let q = quantize(&x, &cb, 4, 64);
    println!("{}", runner.run("quant/chunked quantize 128x128", || {
        std::hint::black_box(quantize(std::hint::black_box(&x), &cb, 4, 64));
    }).report());
    println!("{}", runner.run("quant/scalar quantize 128x128", || {
        std::hint::black_box(quantize_scalar(std::hint::black_box(&x), &cb, 4, 64));
    }).report());
    println!("{}", runner.run("quant/chunked dequantize 128x128", || {
        std::hint::black_box(dequantize(std::hint::black_box(&q), &cb));
    }).report());
    println!("{}", runner.run("quant/scalar dequantize 128x128", || {
        std::hint::black_box(dequantize_scalar(std::hint::black_box(&q), &cb));
    }).report());
    let cb8 = codebook(Mapping::Dt, 8);
    let q8 = quantize(&x, &cb8, 8, 64);
    println!("{}", runner.run("quant/chunked quantize 128x128 q8", || {
        std::hint::black_box(quantize(std::hint::black_box(&x), &cb8, 8, 64));
    }).report());
    println!("{}", runner.run("quant/scalar quantize 128x128 q8", || {
        std::hint::black_box(quantize_scalar(std::hint::black_box(&x), &cb8, 8, 64));
    }).report());
    println!("{}", runner.run("quant/chunked dequantize 128x128 q8", || {
        std::hint::black_box(dequantize(std::hint::black_box(&q8), &cb8));
    }).report());
    println!("{}", runner.run("quant/scalar dequantize 128x128 q8", || {
        std::hint::black_box(dequantize_scalar(std::hint::black_box(&q8), &cb8));
    }).report());
    let codes = q.codes_u8();
    println!("{}", runner.run("quant/pack_bits 16k codes", || {
        std::hint::black_box(pack_bits(std::hint::black_box(&codes), 4));
    }).report());
    println!("{}", runner.run("quant/unpack_bits 16k codes", || {
        std::hint::black_box(unpack_bits(std::hint::black_box(&q.packed), 4, codes.len()));
    }).report());

    // explicit SIMD arms (--features simd); the full scalar/chunked/SIMD
    // throughput matrix lives in `cargo bench --bench quant_simd`
    #[cfg(feature = "simd")]
    {
        use shampoo4::quant::{dequantize_simd, quantize_simd};
        println!("{}", runner.run("quant/simd quantize 128x128", || {
            std::hint::black_box(quantize_simd(std::hint::black_box(&x), &cb, 4, 64));
        }).report());
        println!("{}", runner.run("quant/simd dequantize 128x128", || {
            std::hint::black_box(dequantize_simd(std::hint::black_box(&q), &cb));
        }).report());
    }

    // ---- host linalg --------------------------------------------------------
    let a = Mat::randn(128, 128, &mut rng);
    let b = Mat::randn(128, 128, &mut rng);
    println!("{}", runner.run("linalg/matmul 128x128 host", || {
        std::hint::black_box(a.matmul(std::hint::black_box(&b)));
    }).report());
    let sym = b.gram();
    println!("{}", runner.run("linalg/eigh 128 (tred2/tqli)", || {
        std::hint::black_box(shampoo4::linalg::eigh(std::hint::black_box(&sym)));
    }).report());

    // ---- first-order update -------------------------------------------------
    let n = 1 << 20;
    let mut params = rng.normal_vec(n);
    let grad = rng.normal_vec(n);
    let mut adamw = shampoo4::optim::AdamW::new(n, 0.9, 0.999, 1e-8, 0.01);
    use shampoo4::optim::FirstOrder;
    println!("{}", runner.run("optim/adamw native 1M params", || {
        adamw.step(&mut params, &grad, 1e-3);
    }).report());
    // the same update par-chunked over the persistent pool (bit-identical)
    let sched4 = Scheduler::new(4);
    println!("{}", runner.run("optim/adamw chunked 1M params, 4 workers", || {
        adamw.step_par(&mut params, &grad, 1e-3, &sched4);
    }).report());

    // ---- artifact executions (HostBackend or PJRT, whichever is active) ----
    let rt = default_backend(std::path::Path::new("artifacts")).unwrap();
    let rt = rt.as_ref();
    let g128 = HostTensor::f32(&[128, 128], rng.normal_vec(128 * 128));
    println!("{}", runner.run("backend/gram_128x128", || {
        std::hint::black_box(rt.execute("gram_128x128", &[g128.clone()]).unwrap());
    }).report());

    // precond4 with identity-ish states (SideState stores through the
    // StateCodec layer; the 16-entry runtime codebook comes from the codec)
    let cfg2 = shampoo4::config::SecondOrderConfig::default();
    let codec = shampoo4::quant::codec_for(cfg2.quant.bits, cfg2.quant.mapping);
    let side = shampoo4::coordinator::state::SideState::new(128, &cfg2, &codec);
    let cbrt: Vec<f32> = side.runtime_codebook().unwrap().to_vec();
    let mut inputs = vec![g128.clone()];
    inputs.extend(side.invroot_inputs().unwrap());
    inputs.extend(side.invroot_inputs().unwrap());
    inputs.push(HostTensor::f32(&[16], cbrt.clone()));
    println!("{}", runner.run("backend/precond4_128x128", || {
        std::hint::black_box(rt.execute("precond4_128x128", &inputs).unwrap());
    }).report());

    let mut pu_inputs = side.pu_inputs().unwrap();
    pu_inputs.push(HostTensor::f32(&[128, 128], sym.data.clone()));
    pu_inputs.push(HostTensor::scalar_f32(0.95));
    pu_inputs.push(HostTensor::f32(&[16], cbrt.clone()));
    let slow = BenchRunner::quick();
    println!("{}", slow.run("backend/pu_128 (T1 path)", || {
        std::hint::black_box(rt.execute("pu_128", &pu_inputs).unwrap());
    }).report());

    let mut piru_inputs = side.pu_inputs().unwrap();
    piru_inputs.push(HostTensor::scalar_f32(1e-4));
    piru_inputs.push(HostTensor::f32(&[16], cbrt));
    println!("{}", slow.run("backend/piru_128 (T2 path)", || {
        std::hint::black_box(rt.execute("piru_128", &piru_inputs).unwrap());
    }).report());

    // ---- state codecs -------------------------------------------------------
    // the per-step first-order overhead of codec storage: decode + encode of
    // a 1M-element moment buffer at each bitwidth
    {
        use std::sync::Arc;

        use shampoo4::quant::{codec_for, StateCodec, StochasticRound};
        let xs = rng.normal_vec(1 << 20);
        let sr: Arc<dyn StateCodec> = Arc::new(StochasticRound::new(Mapping::Dt, 4, 0));
        for (label, codec) in [
            ("codec/fp32 1M roundtrip", codec_for(32, Mapping::Dt)),
            ("codec/bf16 1M roundtrip", codec_for(16, Mapping::Dt)),
            ("codec/q8-dt 1M roundtrip", codec_for(8, Mapping::Dt)),
            ("codec/q4-dt 1M roundtrip", codec_for(4, Mapping::Dt)),
            ("codec/q4-dt-sr 1M roundtrip", sr),
        ] {
            let enc = codec.encode(&xs);
            println!("{}", slow.run(label, || {
                let d = codec.decode(std::hint::black_box(&enc));
                std::hint::black_box(codec.encode(&d));
            }).report());
        }
    }

    // ---- full training step ----------------------------------------------
    let mut cfg = RunConfig::default();
    cfg.model = "mlp_base".into();
    cfg.steps = 1;
    cfg.eval_every = 0;
    cfg.eval_batches = 0;
    let trainer = Trainer::new(rt, cfg).unwrap();
    let batch = trainer.model.make_batch(&trainer.data, false, 0);
    println!("{}", slow.run("backend/mlp_base_step (fwd+bwd+stats)", || {
        std::hint::black_box(trainer.model.step(rt, &batch).unwrap());
    }).report());

    // ---- parallel block engine ---------------------------------------------
    // Arc-backed tensor clone: the per-step precondition re-submits the
    // cached state tensors by clone — must be a refcount bump (ns), not a
    // 64 KiB payload copy (µs).
    let big = HostTensor::f32(&[128, 128], rng.normal_vec(128 * 128));
    assert!(big.shares_buffer(&big.clone()), "HostTensor::clone must alias its buffer");
    println!("{}", runner.run("engine/HostTensor clone 128x128 (Arc)", || {
        std::hint::black_box(big.clone());
    }).report());

    // scheduler fan-out over block-sized matmul tasks: serial vs 4 workers
    // (the pool is persistent — these rows include zero thread spawns)
    let base: Vec<Mat> = (0..8).map(|_| Mat::randn(128, 128, &mut rng)).collect();
    for workers in [1usize, 4] {
        let sched = Scheduler::new(workers);
        let mut items = base.clone();
        let label = format!("engine/8x matmul128 tasks, {workers} worker(s)");
        println!("{}", slow.run(&label, || {
            let outs = sched
                .par_map_mut(&mut items, |_, m| Ok(std::hint::black_box(m.matmul(m))))
                .unwrap();
            std::hint::black_box(outs);
        }).report());
    }

    // pipelined background path: submit + completion-barrier round trip for
    // an empty job — the fixed overhead a cross-step refresh pays on top of
    // its actual PU/PIRU work
    let pipe_sched = Scheduler::pipelined(4);
    println!("{}", runner.run("engine/background spawn+barrier round trip", || {
        let (tx, rx) = std::sync::mpsc::channel();
        assert!(pipe_sched.spawn(Box::new(move || {
            let _ = tx.send(());
        })));
        rx.recv().unwrap();
    }).report());

    println!("\nper-step budget at T1=100/T2=500 (mlp_base, 8 blocks):");
    println!("  every step:  model_step + 8×precond4 + flat adamw");
    println!("  every T1:    + 8×(gram + 2×pu)");
    println!("  every T2:    + 8×(2×piru)  — or 1 cohort/step when staggered");
    println!("  per-block work fans across shampoo.parallelism workers; with");
    println!("  --pipeline the T1/T2 lines run on the persistent pool and");
    println!("  overlap the next steps' model work (roots swap in <= max_lag");
    println!("  steps later); see table2_training for end-to-end rows +");
    println!("  BENCH_parallel.json");
}
