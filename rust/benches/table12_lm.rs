//! Bench: regenerates Table 12 / Figure 10 — language modeling with
//! AdamW vs +32-bit Shampoo vs +4-bit naive vs +4-bit ours on the
//! transformer LM over the synthetic bigram corpus.
//! SHAMPOO4_BENCH_STEPS (default 200); curves land in bench_out/.

#![allow(clippy::field_reassign_with_default)]

use anyhow::Result;
use shampoo4::config::{FirstOrderKind, RunConfig, Schedule, SecondOrderKind};
use shampoo4::coordinator::Trainer;
use shampoo4::runtime::default_backend;

fn main() -> Result<()> {
    let steps: usize = std::env::var("SHAMPOO4_BENCH_STEPS")
        .ok().and_then(|v| v.parse().ok()).unwrap_or(200);
    let rt = default_backend(std::path::Path::new("artifacts"))?;
    let rt = rt.as_ref();
    std::fs::create_dir_all("bench_out").ok();
    println!("# Table 12 @ tlm_tiny, {steps} steps (paper: GPT2-124M/LLaMA-130M)");
    println!("{:<34} {:>8} {:>9} {:>10}", "Optimizer", "VL", "WCT(s)", "opt(MB)");
    // (label, bits, quantize_eigen); bits=0 -> no shampoo
    let arms: Vec<(&str, u32, bool, f32)> = vec![
        ("AdamW", 0, true, 1.5),
        ("AdamW + 32-bit Shampoo", 32, true, 1.0),
        ("AdamW + 4-bit Shampoo (naive)", 4, false, 1.0),
        ("AdamW + 4-bit Shampoo (our)", 4, true, 1.0),
    ];
    for (label, bits, eigen, mult) in arms {
        let mut cfg = RunConfig::default();
        cfg.name = format!("t12_{}", label.replace(' ', "_"));
        cfg.model = "tlm_tiny".into();
        cfg.steps = (steps as f32 * mult) as usize;
        cfg.first.kind = FirstOrderKind::AdamW;
        cfg.first.lr = 2e-3;
        cfg.first.weight_decay = 0.05;
        cfg.second.kind = if bits == 0 { SecondOrderKind::None } else { SecondOrderKind::Shampoo };
        cfg.second.quant.bits = if bits == 0 { 4 } else { bits };
        cfg.second.quant.quantize_eigen = eigen;
        cfg.second.update_precond_every = 10;
        cfg.second.update_invroot_every = 30;
        cfg.schedule = Schedule::Cosine { warmup: cfg.steps / 10 };
        cfg.eval_every = (cfg.steps / 5).max(1);
        cfg.eval_batches = 4;
        cfg.log_every = (cfg.steps / 20).max(1);
        let mut t = Trainer::new(rt, cfg.clone())?;
        let csv = format!("bench_out/{}.csv", cfg.name);
        let res = t.train(rt, Some(std::path::Path::new(&csv)))?;
        let e = res.final_eval.as_ref().unwrap();
        println!(
            "{:<34} {:>8.4} {:>9.1} {:>10.2}",
            label,
            e.loss,
            res.wall_secs,
            res.memory.optimizer_mb()
        );
    }
    println!("# curves (Figure 10): bench_out/t12_*.csv");
    Ok(())
}
