//! Bench: validates Theorem 1 — the regret of perturbed Shampoo
//! (Algorithm 6, quantization modeled as the perturbation g) stays below
//! the paper's bound
//!   √(2r)·D·[2^{1/4}·m·ρ_T^{1/4} + tr(L̃_T^{1/4})]·[2^{1/4}·n·μ_T^{1/4} + tr(R̃_T^{1/4})]
//! on an online convex problem (linear losses over a bounded domain).

use shampoo4::linalg::{invroot_eigh, Mat};
use shampoo4::quant::{codebook, dequantize_matrix_cols, quantize_matrix_cols, Mapping};
use shampoo4::util::rng::Rng;

fn spectral_norm(a: &Mat) -> f64 {
    shampoo4::linalg::power_iteration(a, 50).abs() as f64
}

fn main() {
    let (m, n, t_max) = (16usize, 24usize, 150usize);
    let mut rng = Rng::new(7);
    let cb = codebook(Mapping::Linear2, 4);
    let eps = 1e-4f32;

    // online linear losses f_t(W) = <G_t, W>, domain ‖W‖_F ≤ 1;
    // comparator W* = argmin <ΣG_t, W> over the ball.
    let grads: Vec<Mat> = (0..t_max).map(|_| Mat::randn(m, n, &mut rng).scale(0.5)).collect();
    let gsum = grads.iter().fold(Mat::zeros(m, n), |acc, g| acc.add(g));
    let wstar = gsum.scale(-(1.0 / gsum.frobenius()) as f32);

    let mut w = Mat::zeros(m, n);
    let mut l = Mat::zeros(m, m);
    let mut r = Mat::zeros(n, n);
    let (mut rho, mut mu) = (0.0f64, 0.0f64);
    let mut regret = 0.0f64;
    let rank = m.min(n) as f64;
    let d_bound = 2.0f64; // ‖W_t − W*‖_F ≤ diam of the unit ball
    let eta = (d_bound / (2.0 * rank).sqrt()) as f32;

    println!("# Theorem 1: perturbed-Shampoo regret vs bound ({m}x{n}, T={t_max})");
    println!("t,regret,bound,rho,mu");
    for (t, g) in grads.iter().enumerate() {
        regret += (g.inner(&w) - g.inner(&wstar)) as f64;

        // J_t = L + GGᵀ, then perturb by 4-bit quantization (g of Alg. 6)
        let j = l.add(&g.gram());
        let k = r.add(&g.gram_t());
        let lq = quantize_pd(&j, &cb);
        let kq = quantize_pd(&k, &cb);
        rho += spectral_norm(&j.sub(&lq));
        mu += spectral_norm(&k.sub(&kq));
        l = lq;
        r = kq;

        // W ← Π_ball( W − η·(ρI+L)^{-1/4}·G·(μI+R)^{-1/4} )
        let li = invroot_eigh(&l.add_scaled_eye((eps as f64 + rho) as f32), 4.0, 1e-30);
        let ri = invroot_eigh(&r.add_scaled_eye((eps as f64 + mu) as f32), 4.0, 1e-30);
        let step = li.matmul(g).matmul(&ri).scale(eta);
        w = w.sub(&step);
        let norm = w.frobenius();
        if norm > 1.0 {
            w = w.scale((1.0 / norm) as f32);
        }

        if (t + 1) % 25 == 0 || t + 1 == t_max {
            let ltil = l.add_scaled_eye(eps);
            let rtil = r.add_scaled_eye(eps);
            let tr_l: f64 = shampoo4::linalg::eigh(&ltil)
                .vals.iter().map(|&x| (x.max(0.0) as f64).powf(0.25)).sum();
            let tr_r: f64 = shampoo4::linalg::eigh(&rtil)
                .vals.iter().map(|&x| (x.max(0.0) as f64).powf(0.25)).sum();
            let bound = (2.0 * rank).sqrt()
                * d_bound
                * (2f64.powf(0.25) * m as f64 * rho.powf(0.25) + tr_l)
                * (2f64.powf(0.25) * n as f64 * mu.powf(0.25) + tr_r);
            println!("{}, {regret:.2}, {bound:.2}, {rho:.3}, {mu:.3}", t + 1);
            assert!(
                regret <= bound,
                "regret {regret} exceeded Theorem-1 bound {bound} at t={}",
                t + 1
            );
        }
    }
    println!("# regret stayed below the Theorem-1 bound (bound is slack, as the paper notes)");
}

/// 4-bit quantization of a PD matrix (diag exact) — the perturbation g.
fn quantize_pd(a: &Mat, cb: &[f32]) -> Mat {
    let n = a.rows;
    let diag = a.diagonal();
    let mut off = a.clone();
    for i in 0..n {
        off[(i, i)] = 0.0;
    }
    let q = quantize_matrix_cols(&off.data, n, cb, 4);
    let mut out = Mat::from_vec(n, n, dequantize_matrix_cols(&q, n, cb));
    out.symmetrize();
    for i in 0..n {
        out[(i, i)] = diag[i];
    }
    out
}
