//! Bench: regenerates Table 2 (+ Figures 1/4 as CSV curves) at laptop
//! scale — test accuracy / validation loss, wall-clock time, and exact
//! optimizer memory for {F, F + 32-bit Shampoo, F + 4-bit Shampoo} on the
//! MLP classifier (CNN stand-in) and the tiny transformer LM (ViT/Swin
//! stand-in). First-order arms run 1.5× the steps, like the paper's
//! 1.2–1.5× epochs.
//!
//! SHAMPOO4_BENCH_STEPS overrides the per-arm second-order step count
//! (default 200).
//!
//! A second section exercises the parallel block engine: the 4-bit Shampoo
//! arm re-run serial vs `parallelism = 4`, batch vs staggered PIRU, and
//! synchronous vs cross-step pipelined (`shampoo.pipeline`) refreshes, with
//! wall-clock + worst-step rows printed and the machine-readable summary
//! written to bench_out/BENCH_parallel.json.
//!
//! A third section exercises the sharded block engine: single-process vs
//! `--shards {2,4}` (sync + pipelined), reporting bytes-on-wire per refresh
//! round and the codec-vs-fp32 state wire-format ratio to
//! bench_out/BENCH_shard.json, and appending a timestamped run record to
//! the committed `BENCH_shard.json` baseline at the repo root.
//!
//! SHAMPOO4_BENCH_SECTION selects which section runs: `table2`,
//! `parallel`, `shard`, or `all` (default). The nightly bench-baseline
//! job runs `SHAMPOO4_BENCH_SECTION=shard` so the committed baseline
//! accumulates records without paying for the full Table 2 sweep.

#![allow(clippy::field_reassign_with_default)]

use anyhow::Result;
use shampoo4::config::{FirstOrderKind, RunConfig, Schedule, SecondOrderKind};
use shampoo4::coordinator::{TrainResult, Trainer};
use shampoo4::runtime::{default_backend, Backend};
use shampoo4::util::json::Json;

fn steps_default() -> usize {
    std::env::var("SHAMPOO4_BENCH_STEPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(200)
}

/// Section filter (`SHAMPOO4_BENCH_SECTION`): `table2` / `parallel` /
/// `shard` run one section; anything else (or unset) runs all three.
fn section() -> String {
    std::env::var("SHAMPOO4_BENCH_SECTION").unwrap_or_else(|_| "all".to_string())
}

fn section_on(name: &str) -> bool {
    let s = section();
    s == "all" || s == name
}

/// Repo-root shard baseline (every shard-section run appends here).
const SHARD_OUT_PATH: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_shard.json");
/// Most recent run records kept in the shard baseline's `runs` array.
const SHARD_KEEP_RUNS: usize = 20;

struct Arm {
    label: &'static str,
    model: &'static str,
    f: FirstOrderKind,
    lr: f32,
    bits: u32, // 0 = no shampoo
    steps_mult: f32,
}

fn main() -> Result<()> {
    let rt = default_backend(std::path::Path::new("artifacts"))?;
    let rt = rt.as_ref();
    let steps = steps_default();
    if !section_on("table2") {
        println!("# SHAMPOO4_BENCH_SECTION={} — skipping Table 2 arms", section());
        if section_on("parallel") {
            parallel_engine_rows(rt, steps)?;
        }
        if section_on("shard") {
            shard_engine_rows(rt, steps)?;
        }
        return Ok(());
    }
    #[rustfmt::skip]
    let arms = [
        Arm { label: "SGDM", model: "mlp_base", f: FirstOrderKind::Sgdm, lr: 0.05, bits: 0, steps_mult: 1.5 },
        Arm { label: "SGDM + 32-bit Shampoo", model: "mlp_base", f: FirstOrderKind::Sgdm, lr: 0.05, bits: 32, steps_mult: 1.0 },
        Arm { label: "SGDM + 4-bit Shampoo (our)", model: "mlp_base", f: FirstOrderKind::Sgdm, lr: 0.05, bits: 4, steps_mult: 1.0 },
        Arm { label: "AdamW", model: "tlm_tiny", f: FirstOrderKind::AdamW, lr: 2e-3, bits: 0, steps_mult: 1.5 },
        Arm { label: "AdamW + 32-bit Shampoo", model: "tlm_tiny", f: FirstOrderKind::AdamW, lr: 2e-3, bits: 32, steps_mult: 1.0 },
        Arm { label: "AdamW + 4-bit Shampoo (our)", model: "tlm_tiny", f: FirstOrderKind::AdamW, lr: 2e-3, bits: 4, steps_mult: 1.0 },
    ];
    println!("# Table 2 @ {steps} second-order steps (paper: 100-300 epochs)");
    println!(
        "{:<30} {:<10} {:>8} {:>9} {:>8} {:>10} {:>10}",
        "Optimizer", "Model", "TA(%)", "VL", "WCT(s)", "opt(MB)", "total(MB)"
    );
    std::fs::create_dir_all("bench_out").ok();
    for arm in &arms {
        let mut cfg = RunConfig::default();
        cfg.name = format!("table2_{}_{}", arm.model, arm.label.replace(' ', "_"));
        cfg.model = arm.model.to_string();
        cfg.steps = (steps as f32 * arm.steps_mult) as usize;
        cfg.first.kind = arm.f;
        cfg.first.lr = arm.lr;
        cfg.first.weight_decay = if arm.f == FirstOrderKind::Sgdm { 5e-4 } else { 0.05 };
        cfg.second.kind =
            if arm.bits == 0 { SecondOrderKind::None } else { SecondOrderKind::Shampoo };
        cfg.second.quant.bits = if arm.bits == 0 { 4 } else { arm.bits };
        cfg.second.update_precond_every = 10;
        cfg.second.update_invroot_every = 30;
        cfg.schedule = Schedule::Cosine { warmup: cfg.steps / 20 };
        cfg.eval_every = (cfg.steps / 4).max(1);
        cfg.eval_batches = 8;
        cfg.log_every = (cfg.steps / 20).max(1);
        let mut t = Trainer::new(rt, cfg.clone())?;
        let res = t.train(
            rt,
            Some(std::path::Path::new(&format!("bench_out/{}.csv", cfg.name))),
        )?;
        let e = res.final_eval.as_ref().unwrap();
        println!(
            "{:<30} {:<10} {:>8} {:>9.4} {:>8.1} {:>10.2} {:>10.2}",
            arm.label,
            arm.model,
            e.accuracy.map(|a| format!("{:.2}", a * 100.0)).unwrap_or("-".into()),
            e.loss,
            res.wall_secs,
            res.memory.optimizer_mb(),
            res.memory.total_mb()
        );
    }
    println!("# curves (Figures 1/4): bench_out/table2_*.csv");

    if section_on("parallel") {
        parallel_engine_rows(rt, steps)?;
    }
    if section_on("shard") {
        shard_engine_rows(rt, steps)?;
    }
    Ok(())
}

/// Serial-vs-parallel, stagger-vs-batch, and sync-vs-pipelined wall-time
/// rows for the 4-bit Shampoo MLP arm, plus bench_out/BENCH_parallel.json.
fn parallel_engine_rows(rt: &dyn Backend, steps: usize) -> Result<()> {
    let run_engine = |parallelism: usize, stagger: bool, pipeline: bool| -> Result<TrainResult> {
        let mut cfg = RunConfig::default();
        cfg.name = format!(
            "table2_engine_p{parallelism}{}{}",
            if stagger { "_stagger" } else { "" },
            if pipeline { "_pipeline" } else { "" }
        );
        cfg.model = "mlp_base".into();
        cfg.steps = steps;
        cfg.first.kind = FirstOrderKind::Sgdm;
        cfg.first.lr = 0.05;
        cfg.first.weight_decay = 5e-4;
        cfg.second.kind = SecondOrderKind::Shampoo;
        cfg.second.update_precond_every = 10;
        cfg.second.update_invroot_every = 30;
        cfg.second.parallelism = parallelism;
        cfg.second.stagger_invroots = stagger;
        cfg.second.pipeline = pipeline;
        cfg.schedule = Schedule::Cosine { warmup: steps / 20 };
        cfg.eval_every = 0;
        cfg.eval_batches = 8;
        cfg.log_every = (steps / 20).max(1);
        Trainer::new(rt, cfg)?.train(rt, None)
    };

    println!("\n# Parallel block engine @ {steps} steps (mlp_base, 4-bit Shampoo, T2=30)");
    println!(
        "# NOTE: for pipelined arms, pu(s)/piru(s) are summed background\n\
         # thread-seconds (work moved off the step), not coordinator wall time\n\
         # — compare arms on WCT and max step, not on those columns."
    );
    println!(
        "{:<28} {:>8} {:>12} {:>9} {:>9} {:>9} {:>9}",
        "Engine", "WCT(s)", "max step(ms)", "pu(s)", "piru(s)", "precond(s)", "stall(s)"
    );
    let mut results: Vec<(&str, TrainResult)> = Vec::new();
    for (label, parallelism, stagger, pipeline) in [
        ("serial, batch PIRU", 1, false, false),
        ("parallel=4, batch PIRU", 4, false, false),
        ("parallel=4, staggered PIRU", 4, true, false),
        ("parallel=4, pipelined", 4, false, true),
        ("parallel=4, pipe+stagger", 4, true, true),
    ] {
        let res = run_engine(parallelism, stagger, pipeline)?;
        println!(
            "{:<28} {:>8.2} {:>12.2} {:>9.3} {:>9.3} {:>9.3} {:>9.3}",
            label,
            res.wall_secs,
            res.timings.max_step_secs * 1e3,
            res.timings.pu_secs,
            res.timings.piru_secs,
            res.timings.precond_secs,
            res.timings.pipeline_stall_secs
        );
        results.push((label, res));
    }

    let arm = |res: &TrainResult| {
        Json::obj(vec![
            ("wall_secs", Json::Num(res.wall_secs)),
            ("max_step_secs", Json::Num(res.timings.max_step_secs)),
            ("pu_secs", Json::Num(res.timings.pu_secs)),
            ("piru_secs", Json::Num(res.timings.piru_secs)),
            ("precond_secs", Json::Num(res.timings.precond_secs)),
            ("pipeline_stall_secs", Json::Num(res.timings.pipeline_stall_secs)),
            ("pipeline_refreshes", Json::Num(res.timings.pipeline_refreshes as f64)),
            (
                "final_eval_loss",
                Json::Num(res.final_loss().map(|l| l as f64).unwrap_or(f64::NAN)),
            ),
        ])
    };
    let (serial, par4, stag4, pipe4, pipestag4) =
        (&results[0].1, &results[1].1, &results[2].1, &results[3].1, &results[4].1);
    let j = Json::obj(vec![
        ("bench", Json::Str("table2_training/parallel_engine".into())),
        ("model", Json::Str("mlp_base".into())),
        ("steps", Json::Num(steps as f64)),
        (
            "note",
            Json::Str(
                "pipelined arms report pu_secs/piru_secs as summed background \
                 thread-seconds, not wall time; compare on wall_secs/max_step_secs"
                    .into(),
            ),
        ),
        ("serial_batch", arm(serial)),
        ("parallel4_batch", arm(par4)),
        ("parallel4_stagger", arm(stag4)),
        ("parallel4_pipeline", arm(pipe4)),
        ("parallel4_pipeline_stagger", arm(pipestag4)),
        ("speedup_parallel4", Json::Num(serial.wall_secs / par4.wall_secs.max(1e-12))),
        (
            "max_step_stagger_over_batch",
            Json::Num(stag4.timings.max_step_secs / par4.timings.max_step_secs.max(1e-12)),
        ),
        (
            "max_step_pipeline_over_batch",
            Json::Num(pipe4.timings.max_step_secs / par4.timings.max_step_secs.max(1e-12)),
        ),
        (
            "wall_pipeline_over_batch",
            Json::Num(pipe4.wall_secs / par4.wall_secs.max(1e-12)),
        ),
    ]);
    std::fs::create_dir_all("bench_out")?;
    std::fs::write("bench_out/BENCH_parallel.json", j.to_string())?;
    println!(
        "# speedup(parallel=4) = {:.2}x, max-step stagger/batch = {:.2}, \
         max-step pipeline/batch = {:.2} -> {}",
        serial.wall_secs / par4.wall_secs.max(1e-12),
        stag4.timings.max_step_secs / par4.timings.max_step_secs.max(1e-12),
        pipe4.timings.max_step_secs / par4.timings.max_step_secs.max(1e-12),
        "bench_out/BENCH_parallel.json"
    );
    Ok(())
}

/// Sharded block engine rows for the 4-bit Shampoo MLP arm: single-process
/// vs `--shards {2,4}` (sync and pipelined), with wall time, worst step,
/// and bytes-on-wire per refresh round — the codec-byte wire format
/// against what an fp32 wire format would ship. Writes
/// bench_out/BENCH_shard.json (schema committed at repo root).
fn shard_engine_rows(rt: &dyn Backend, steps: usize) -> Result<()> {
    let run_engine = |shards: usize, pipeline: bool| -> Result<TrainResult> {
        let mut cfg = RunConfig::default();
        cfg.name =
            format!("table2_shard{shards}{}", if pipeline { "_pipeline" } else { "" });
        cfg.model = "mlp_base".into();
        cfg.steps = steps;
        cfg.first.kind = FirstOrderKind::Sgdm;
        cfg.first.lr = 0.05;
        cfg.first.weight_decay = 5e-4;
        cfg.second.kind = SecondOrderKind::Shampoo;
        cfg.second.update_precond_every = 10;
        cfg.second.update_invroot_every = 30;
        cfg.second.parallelism = 2;
        cfg.second.shards = shards;
        cfg.second.pipeline = pipeline;
        cfg.schedule = Schedule::Cosine { warmup: steps / 20 };
        cfg.eval_every = 0;
        cfg.eval_batches = 8;
        cfg.log_every = (steps / 20).max(1);
        Trainer::new(rt, cfg)?.train(rt, None)
    };

    println!("\n# Sharded block engine @ {steps} steps (mlp_base, 4-bit Shampoo, T2=30)");
    println!(
        "{:<28} {:>8} {:>12} {:>7} {:>12} {:>12} {:>10}",
        "Engine", "WCT(s)", "max step(ms)", "rounds", "wire(KiB)", "state(KiB)", "vs fp32"
    );
    let mut results: Vec<(&str, TrainResult)> = Vec::new();
    for (label, shards, pipeline) in [
        ("single-process", 1, false),
        ("shards=2", 2, false),
        ("shards=4", 4, false),
        ("shards=2, pipelined", 2, true),
    ] {
        let res = run_engine(shards, pipeline)?;
        let tm = &res.timings;
        let ratio = tm.shard_state_fp32_bytes as f64 / tm.shard_state_bytes.max(1) as f64;
        println!(
            "{:<28} {:>8.2} {:>12.2} {:>7} {:>12.1} {:>12.1} {:>9.1}x",
            label,
            res.wall_secs,
            tm.max_step_secs * 1e3,
            tm.shard_rounds,
            tm.shard_wire_bytes as f64 / 1024.0,
            tm.shard_state_bytes as f64 / 1024.0,
            ratio
        );
        results.push((label, res));
    }

    let arm = |res: &TrainResult| {
        let tm = &res.timings;
        Json::obj(vec![
            ("wall_secs", Json::Num(res.wall_secs)),
            ("max_step_secs", Json::Num(tm.max_step_secs)),
            ("shard_rounds", Json::Num(tm.shard_rounds as f64)),
            ("wire_bytes", Json::Num(tm.shard_wire_bytes as f64)),
            ("state_bytes", Json::Num(tm.shard_state_bytes as f64)),
            ("state_fp32_bytes", Json::Num(tm.shard_state_fp32_bytes as f64)),
            (
                "wire_bytes_per_round",
                Json::Num(tm.shard_wire_bytes as f64 / tm.shard_rounds.max(1) as f64),
            ),
            (
                "final_eval_loss",
                Json::Num(res.final_loss().map(|l| l as f64).unwrap_or(f64::NAN)),
            ),
        ])
    };
    let (single, sh2, sh4, sh2pipe) =
        (&results[0].1, &results[1].1, &results[2].1, &results[3].1);
    let state_ratio = sh2.timings.shard_state_fp32_bytes as f64
        / sh2.timings.shard_state_bytes.max(1) as f64;
    let j = Json::obj(vec![
        ("bench", Json::Str("table2_training/shard_engine".into())),
        ("model", Json::Str("mlp_base".into())),
        ("steps", Json::Num(steps as f64)),
        (
            "note",
            Json::Str(
                "wire format ratio compares the state traffic (refreshed \
                 back-buffers) as codec bytes vs an fp32 wire format; request \
                 traffic (fp32 gradient frames) is format-invariant"
                    .into(),
            ),
        ),
        ("single_process", arm(single)),
        ("shards2", arm(sh2)),
        ("shards4", arm(sh4)),
        ("shards2_pipeline", arm(sh2pipe)),
        ("state_codec_over_fp32", Json::Num(state_ratio)),
        (
            "max_step_shards2_over_single",
            Json::Num(sh2.timings.max_step_secs / single.timings.max_step_secs.max(1e-12)),
        ),
        ("wall_shards2_over_single", Json::Num(sh2.wall_secs / single.wall_secs.max(1e-12))),
    ]);
    std::fs::create_dir_all("bench_out")?;
    std::fs::write("bench_out/BENCH_shard.json", j.to_string())?;
    println!(
        "# state wire codec/fp32 = {:.1}x smaller, shards=2 wall/single = {:.2} -> {}",
        state_ratio,
        sh2.wall_secs / single.wall_secs.max(1e-12),
        "bench_out/BENCH_shard.json"
    );

    // append a timestamped record to the committed repo-root baseline,
    // keeping the last SHARD_KEEP_RUNS (the nightly bench-baseline job
    // commits the result)
    let timestamp = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    let run = match j {
        Json::Obj(mut m) => {
            m.insert("timestamp_unix".to_string(), Json::Num(timestamp as f64));
            Json::Obj(m)
        }
        other => other,
    };
    let mut runs: Vec<Json> = std::fs::read_to_string(SHARD_OUT_PATH)
        .ok()
        .and_then(|s| Json::parse(&s).ok())
        .and_then(|p| p.get("runs").and_then(|r| r.as_arr().map(|a| a.to_vec())))
        .unwrap_or_default();
    runs.push(run);
    let excess = runs.len().saturating_sub(SHARD_KEEP_RUNS);
    let runs = runs.split_off(excess);
    let note = "sharded-engine wall-clock + wire-format baseline; regenerate with \
                `SHAMPOO4_BENCH_SECTION=shard cargo bench --bench table2_training \
                --features simd` (appends a timestamped record, keeps the last 20)";
    let out = Json::obj(vec![
        ("_note", Json::Str(note.to_string())),
        ("runs", Json::Arr(runs)),
    ]);
    match std::fs::write(SHARD_OUT_PATH, out.to_string()) {
        Ok(()) => println!("# appended run to BENCH_shard.json (repo root)"),
        Err(e) => println!("# could not write BENCH_shard.json: {e}"),
    }
    Ok(())
}
