//! Bench: regenerates Table 1 (and the Table 5 DT/Linear-2 comparison) —
//! quantization errors in A^{-1/4} for QM ∈ {A, U}, OR on/off, at 4-bit and
//! 8-bit, on the spectrum-matched A₁ and the synthetic two-level A₂.
//!
//! Order defaults to 512 to keep `cargo bench` snappy; the
//! quant_error_analysis example runs the paper's exact order 1200.
//! Set SHAMPOO4_T1_ORDER=1200 to match the paper here.

use shampoo4::errors::{quant_error_in_power, spectrum, QuantScheme, QuantTarget};
use shampoo4::quant::Mapping;
use shampoo4::util::rng::Rng;
use shampoo4::util::timer::Stopwatch;

fn main() {
    let n: usize = std::env::var("SHAMPOO4_T1_ORDER")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(512);
    let mut rng = Rng::new(0);
    let sw = Stopwatch::start();
    let a1 = spectrum::synthetic_loglinear(n, 37235.0, &mut rng);
    let a2 = spectrum::synthetic_two_level(n, 1000.0, 1e-3, n / 20, &mut rng);
    println!("# Table 1 @ order {n} (paper: 1200); setup {:.1}s", sw.secs());
    println!(
        "{:<4} {:<9} {:>4} {:>3} {:>4} {:>8} {:>8}  {}",
        "mat",
        "mapping",
        "bit",
        "QM",
        "OR",
        "NRE",
        "AE",
        "(paper 4-bit A1: A/U/U+OR = 0.62/0.05-0.07/0.03-0.05)"
    );
    for (mname, a) in [("A1", &a1), ("A2", &a2)] {
        for mapping in [Mapping::Dt, Mapping::Linear2] {
            for (bits, target, rect, block) in [
                (8u32, QuantTarget::Precond, 0usize, 256usize),
                (4, QuantTarget::Precond, 0, 64),
                (4, QuantTarget::Eigen, 0, 64),
                (4, QuantTarget::Eigen, 1, 64),
            ] {
                let row = quant_error_in_power(
                    a,
                    -0.25,
                    QuantScheme { mapping, bits, target, rectify: rect, block },
                    false,
                );
                println!(
                    "{:<4} {:<9} {:>4} {:>3} {:>4} {:>8.4} {:>8.4}",
                    mname,
                    mapping.name(),
                    bits,
                    if target == QuantTarget::Eigen { "U" } else { "A" },
                    if rect > 0 { "yes" } else { "no" },
                    row.nre,
                    row.ae_deg
                );
            }
        }
    }
    println!("# total {:.1}s", sw.secs());
}
