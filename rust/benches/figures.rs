//! Bench: regenerates the paper's standalone figures as CSV series under
//! bench_out/:
//!   Figure 2 — singular-value distributions, real vs 4-bit-quantized A
//!   Figure 3 — rectification error vs s and t₂
//!   Figure 5 — DT vs Linear-2 codebooks at 3/4-bit
//!   Figure 6 — quantization error vs spectrum contraction coefficient τ
//! (Figures 1/4/9/10 are the loss/accuracy curves of the training benches —
//! their CSVs come from table2_training / table12_lm metrics files.)

use std::io::Write;

use shampoo4::errors::{quant_error_in_power, rectification_error, spectrum,
                       QuantScheme, QuantTarget};
use shampoo4::linalg::eigh;
use shampoo4::quant::{codebook, dequantize_matrix_cols, quantize_matrix_cols, Mapping};
use shampoo4::util::rng::Rng;

fn out(name: &str) -> std::fs::File {
    std::fs::create_dir_all("bench_out").unwrap();
    std::fs::File::create(format!("bench_out/{name}")).unwrap()
}

fn main() {
    let n: usize = std::env::var("SHAMPOO4_FIG_ORDER")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(384);
    let mut rng = Rng::new(0);
    let a1 = spectrum::synthetic_loglinear(n, 37235.0, &mut rng);
    let a2 = spectrum::synthetic_two_level(n, 1000.0, 1e-3, n / 20, &mut rng);

    // ---- Figure 2: spectra of A and its 4-bit compression -----------------
    let mut f = out("figure2_spectra.csv");
    writeln!(f, "matrix,kind,idx,log10_singular_value").unwrap();
    let cb = codebook(Mapping::Dt, 4);
    for (mname, a) in [("A1", &a1), ("A2", &a2)] {
        let real = eigh(a);
        // quantize A (excl. diag) like the naive arm, then re-decompose
        let nn = a.rows;
        let diag = a.diagonal();
        let mut off = a.clone();
        for i in 0..nn {
            off[(i, i)] = 0.0;
        }
        let q = quantize_matrix_cols(&off.data, nn, &cb, 4);
        let mut aq = shampoo4::linalg::Mat::from_vec(nn, nn, dequantize_matrix_cols(&q, nn, &cb));
        aq.symmetrize();
        for i in 0..nn {
            aq[(i, i)] = diag[i];
        }
        let quan = eigh(&aq);
        for (i, &v) in real.vals.iter().enumerate() {
            writeln!(f, "{mname},real,{i},{}", (v.max(1e-12) as f64).log10()).unwrap();
        }
        for (i, &v) in quan.vals.iter().enumerate() {
            writeln!(f, "{mname},quan,{i},{}", (v.abs().max(1e-12) as f64).log10()).unwrap();
        }
        let neg = quan.vals.iter().filter(|&&v| v < 0.0).count();
        println!(
            "figure2: {mname}: {neg}/{nn} eigenvalues pushed negative by 4-bit quantization of A"
        );
    }

    // ---- Figure 3: rectification error vs s and t2 ------------------------
    let mut f = out("figure3_rectify.csv");
    writeln!(f, "s,t2,log10_mean_err").unwrap();
    println!("figure3: mean elementwise error of (VΛ^sVᵀ)^(-1/s)(VΛVᵀ) vs I");
    for s in [-1.0, -0.5, -0.25, -0.125] {
        for t2 in [0usize, 1, 2, 4, 8] {
            let e = rectification_error(&a1, s, t2, Mapping::Linear2, 4);
            writeln!(f, "{s},{t2},{}", e.max(1e-300).log10()).unwrap();
            if t2 == 0 || t2 == 4 {
                println!("  s={s:>6} t2={t2}: mean err {e:.3e}");
            }
        }
    }

    // ---- Figure 5: codebooks ----------------------------------------------
    let mut f = out("figure5_codebooks.csv");
    writeln!(f, "mapping,bits,j,value").unwrap();
    for mapping in [Mapping::Dt, Mapping::Linear2] {
        for bits in [3u32, 4] {
            for (j, v) in codebook(mapping, bits).iter().enumerate() {
                writeln!(f, "{},{bits},{j},{v}", mapping.name()).unwrap();
            }
        }
    }
    println!("figure5: codebooks written");

    // ---- Figure 6: contraction sweep ---------------------------------------
    let mut f = out("figure6_contraction.csv");
    writeln!(f, "log2_tau,cond,qm,nre,ae_deg").unwrap();
    let base_vals = spectrum::loglinear_spectrum(n, 37235.0);
    println!("figure6: error vs contraction coefficient (QM=U with OR vs QM=A)");
    for k in 0..8 {
        let tau = 2f64.powi(-(2 * k) as i32); // 1, 1/4, ..., 1/16384
        let vals = spectrum::contract_spectrum(&base_vals, tau);
        let a = spectrum::pd_from_spectrum(&vals, &mut rng);
        let cond = spectrum::cond(&vals);
        for (qm, target, rect) in [("A", QuantTarget::Precond, 0), ("U", QuantTarget::Eigen, 1)] {
            let row = quant_error_in_power(
                &a,
                -0.25,
                QuantScheme {
                    mapping: Mapping::Linear2,
                    bits: 4,
                    target,
                    rectify: rect,
                    block: 64,
                },
                false,
            );
            writeln!(
                f,
                "{},{cond:.1},{qm},{:.5},{:.4}",
                (tau.log2()) as i32,
                row.nre,
                row.ae_deg
            )
            .unwrap();
            if k % 2 == 0 {
                println!(
                    "  tau=2^{:>3} cond={cond:>9.1} QM={qm}: NRE {:.4} AE {:.3}°",
                    tau.log2() as i32,
                    row.nre,
                    row.ae_deg
                );
            }
        }
    }
    println!("figures written to bench_out/");
}
