//! Bench: regenerates Table 4 — applying the 4-bit quantization techniques
//! to K-FAC, AdaBK, and CASPR (vs their 32-bit versions) on the MLP
//! classifier (the K-FAC family needs per-layer activation statistics).
//! SHAMPOO4_BENCH_STEPS (default 150).

#![allow(clippy::field_reassign_with_default)]

use anyhow::Result;
use shampoo4::config::{FirstOrderKind, RunConfig, Schedule, SecondOrderKind};
use shampoo4::coordinator::Trainer;
use shampoo4::runtime::default_backend;

fn main() -> Result<()> {
    let steps: usize = std::env::var("SHAMPOO4_BENCH_STEPS")
        .ok().and_then(|v| v.parse().ok()).unwrap_or(150);
    let rt = default_backend(std::path::Path::new("artifacts"))?;
    let rt = rt.as_ref();
    println!("# Table 4 @ mlp_base, {steps} steps (paper: Swin-Tiny/CIFAR-100)");
    println!("{:<28} {:>7} {:>9} {:>9} {:>10}", "Optimizer", "TA(%)", "VL", "WCT(s)", "opt(MB)");
    let arms: Vec<(SecondOrderKind, u32)> = vec![
        (SecondOrderKind::KFac, 32),
        (SecondOrderKind::KFac, 4),
        (SecondOrderKind::AdaBk, 32),
        (SecondOrderKind::AdaBk, 4),
        (SecondOrderKind::Caspr, 32),
        (SecondOrderKind::Caspr, 4),
        (SecondOrderKind::Shampoo, 4),
    ];
    for (kind, bits) in arms {
        let mut cfg = RunConfig::default();
        cfg.name = format!("t4_{}_{bits}", kind.name());
        cfg.model = "mlp_base".into();
        cfg.steps = steps;
        cfg.first.kind = FirstOrderKind::AdamW;
        cfg.first.lr = 1e-3;
        cfg.second.kind = kind;
        cfg.second.quant.bits = bits;
        // paper: K-FAC/AdaBK use beta=0.9 and longer intervals
        if matches!(kind, SecondOrderKind::KFac | SecondOrderKind::AdaBk) {
            cfg.second.beta = 0.9;
            cfg.second.eps = if kind == SecondOrderKind::KFac { 0.1 } else { 0.001 };
        }
        cfg.second.update_precond_every = 20;
        cfg.second.update_invroot_every = 60;
        cfg.schedule = Schedule::Cosine { warmup: steps / 20 };
        cfg.eval_every = 0;
        cfg.eval_batches = 8;
        cfg.log_every = steps;
        let mut t = Trainer::new(rt, cfg)?;
        let res = t.train(rt, None)?;
        let e = res.final_eval.as_ref().unwrap();
        println!(
            "{:<28} {:>7.2} {:>9.4} {:>9.1} {:>10.2}",
            format!("AdamW+{}-bit {}", bits, kind.name()),
            e.accuracy.unwrap_or(0.0) * 100.0,
            e.loss,
            res.wall_secs,
            res.memory.optimizer_mb()
        );
    }
    Ok(())
}
