//! Meta-tests over the real tree: the workspace must lint clean, and every
//! `// lint:allow` annotation that exists anywhere must name a registered
//! rule and carry a reason. This is the same walk CI's blocking
//! `cargo run -p shampoo-lint` step performs, so `cargo test` catches a
//! dirty tree before the lint job does.

use std::path::PathBuf;

fn repo_root() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("..").join("..")
}

#[test]
fn workspace_tree_lints_clean() {
    let report = shampoo_lint::lint_tree(&repo_root()).expect("walk workspace tree");
    assert!(report.files > 20, "suspiciously few files scanned: {}", report.files);
    assert!(
        report.violations.is_empty(),
        "tree has lint violations:\n{}",
        shampoo_lint::render(&report)
    );
}

#[test]
fn every_allow_annotation_is_well_formed() {
    let report = shampoo_lint::lint_tree(&repo_root()).expect("walk workspace tree");
    for a in &report.allows {
        assert!(
            shampoo_lint::rule_exists(&a.rule),
            "{}:{}: lint:allow names unknown rule `{}`",
            a.file,
            a.line,
            a.rule
        );
        assert!(
            a.reason.len() >= 3,
            "{}:{}: lint:allow({}) carries no reason",
            a.file,
            a.line,
            a.rule
        );
    }
}

#[test]
fn rule_catalog_is_consistent() {
    // every rule has a non-empty description and a unique name
    let mut names: Vec<&str> = shampoo_lint::RULES.iter().map(|(n, _)| *n).collect();
    names.sort_unstable();
    let before = names.len();
    names.dedup();
    assert_eq!(before, names.len(), "duplicate rule names");
    for (name, desc) in shampoo_lint::RULES {
        assert!(!name.is_empty() && !desc.is_empty());
    }
}
