//! Fixture tests: every rule must fire on a violating snippet and stay
//! quiet on the clean twin. Snippets live in raw strings, so the linter's
//! own scanner (which strips string literals) never trips over this file
//! when it walks the real tree.

use shampoo_lint::{lint_source, FileReport};

fn rules_fired(r: &FileReport) -> Vec<&'static str> {
    let mut v: Vec<&'static str> = r.violations.iter().map(|x| x.rule).collect();
    v.sort_unstable();
    v.dedup();
    v
}

// --- unsafe-safety / unsafe-module ----------------------------------------

#[test]
fn unsafe_without_safety_fires_in_allowlisted_module() {
    let src = r#"
pub fn f(p: *const f32) -> f32 {
    unsafe { *p }
}
"#;
    let r = lint_source("rust/src/quant/simd/sse2.rs", src);
    assert_eq!(rules_fired(&r), vec!["unsafe-safety"]);
}

#[test]
fn unsafe_with_safety_comment_is_clean() {
    let src = r#"
pub fn f(p: *const f32) -> f32 {
    // SAFETY: caller guarantees p is valid and aligned for reads.
    unsafe { *p }
}
"#;
    let r = lint_source("rust/src/quant/simd/sse2.rs", src);
    assert!(r.violations.is_empty(), "{:?}", r.violations);
}

#[test]
fn unsafe_trailing_safety_comment_is_clean() {
    let src = "pub fn f(p: *const f32) -> f32 { unsafe { *p } } // SAFETY: p valid.\n";
    let r = lint_source("rust/src/quant/simd/sse2.rs", src);
    assert!(r.violations.is_empty(), "{:?}", r.violations);
}

#[test]
fn unsafe_outside_allowlist_fires_module_rule() {
    let src = r#"
pub fn f(p: *const f32) -> f32 {
    // SAFETY: caller guarantees p is valid.
    unsafe { *p }
}
"#;
    let r = lint_source("rust/src/quant/codec.rs", src);
    assert_eq!(rules_fired(&r), vec!["unsafe-module"]);
}

#[test]
fn unsafe_in_lane_registry_module_fires_module_rule() {
    // the dispatch/registry module of the simd directory is deliberately
    // NOT allowlisted: only the per-ISA kernel files may hold unsafe, so
    // unsafe creeping into mod.rs (or the old single-file path) is caught
    let src = r#"
pub fn f(p: *const f32) -> f32 {
    // SAFETY: caller guarantees p is valid.
    unsafe { *p }
}
"#;
    for path in ["rust/src/quant/simd/mod.rs", "rust/src/quant/simd.rs"] {
        let r = lint_source(path, src);
        assert_eq!(rules_fired(&r), vec!["unsafe-module"], "{path}");
    }
}

#[test]
fn unsafe_in_tests_still_needs_safety() {
    let src = r#"
fn t(p: *const u8) -> u8 {
    unsafe { *p }
}
"#;
    let r = lint_source("rust/tests/some_test.rs", src);
    assert!(rules_fired(&r).contains(&"unsafe-safety"));
}

#[test]
fn the_word_unsafe_in_a_string_is_not_code() {
    let src = "pub fn f() -> &'static str { \"unsafe\" }\n";
    let r = lint_source("rust/src/quant/codec.rs", src);
    assert!(r.violations.is_empty(), "{:?}", r.violations);
}

// --- atomic-ordering ------------------------------------------------------

#[test]
fn atomic_without_rationale_fires() {
    let src = r#"
pub fn f(a: &std::sync::atomic::AtomicUsize) -> usize {
    a.load(std::sync::atomic::Ordering::Relaxed)
}
"#;
    let r = lint_source("rust/src/runtime/host/mod.rs", src);
    assert_eq!(rules_fired(&r), vec!["atomic-ordering"]);
}

#[test]
fn atomic_with_rationale_is_clean() {
    let src = r#"
pub fn f(a: &std::sync::atomic::AtomicUsize) -> usize {
    // ordering: monotone counter read, no synchronizes-with edge needed.
    a.load(std::sync::atomic::Ordering::Relaxed)
}
"#;
    let r = lint_source("rust/src/runtime/host/mod.rs", src);
    assert!(r.violations.is_empty(), "{:?}", r.violations);
}

#[test]
fn atomic_bare_imported_ordering_fires() {
    let src = r#"
use std::sync::atomic::Ordering::Relaxed;
pub fn f(a: &std::sync::atomic::AtomicUsize) -> usize {
    // ordering: counter only.
    a.load(Relaxed)
}
"#;
    let r = lint_source("rust/src/runtime/host/mod.rs", src);
    assert_eq!(rules_fired(&r), vec!["atomic-ordering"]);
}

#[test]
fn non_atomic_load_method_is_not_flagged() {
    // Manifest::load(dir) / config.load(path): no Ordering token in sight
    let src = r#"
pub fn f(dir: &std::path::Path) -> std::io::Result<String> {
    std::fs::read_to_string(dir.join("manifest.json"))
}
pub fn g(m: &M) { m.load(3); }
"#;
    let r = lint_source("rust/src/runtime/host/mod.rs", src);
    assert!(r.violations.is_empty(), "{:?}", r.violations);
}

#[test]
fn atomic_in_cfg_test_region_is_exempt() {
    let src = r#"
#[cfg(test)]
mod tests {
    use std::sync::atomic::{AtomicUsize, Ordering};
    #[test]
    fn t() {
        let a = AtomicUsize::new(0);
        a.store(1, Ordering::SeqCst);
    }
}
"#;
    let r = lint_source("rust/src/runtime/host/mod.rs", src);
    assert!(r.violations.is_empty(), "{:?}", r.violations);
}

// --- det-hash -------------------------------------------------------------

#[test]
fn hashmap_in_determinism_module_fires() {
    let src = "use std::collections::HashMap;\n";
    let r = lint_source("rust/src/coordinator/merge.rs", src);
    assert_eq!(rules_fired(&r), vec!["det-hash"]);
}

#[test]
fn hashmap_outside_determinism_modules_is_fine() {
    let src = "use std::collections::HashMap;\n";
    let r = lint_source("rust/src/runtime/registry.rs", src);
    assert!(r.violations.is_empty(), "{:?}", r.violations);
}

#[test]
fn btreemap_in_determinism_module_is_fine() {
    let src = "use std::collections::BTreeMap;\n";
    let r = lint_source("rust/src/coordinator/merge.rs", src);
    assert!(r.violations.is_empty(), "{:?}", r.violations);
}

// --- det-wallclock --------------------------------------------------------

#[test]
fn instant_now_in_determinism_module_fires() {
    let src = r#"
pub fn f() -> std::time::Instant {
    std::time::Instant::now()
}
"#;
    let r = lint_source("rust/src/coordinator/trainer.rs", src);
    assert_eq!(rules_fired(&r), vec!["det-wallclock"]);
}

#[test]
fn stopwatch_in_determinism_module_is_clean() {
    let src = r#"
pub fn f(sw: &crate::util::timer::Stopwatch) -> f64 {
    sw.secs()
}
"#;
    let r = lint_source("rust/src/coordinator/trainer.rs", src);
    assert!(r.violations.is_empty(), "{:?}", r.violations);
}

#[test]
fn instant_now_in_blessed_timer_module_is_fine() {
    let src = "pub fn now() -> std::time::Instant { std::time::Instant::now() }\n";
    let r = lint_source("rust/src/util/timer.rs", src);
    assert!(r.violations.is_empty(), "{:?}", r.violations);
}

// --- det-rand -------------------------------------------------------------

#[test]
fn thread_rng_in_determinism_module_fires() {
    let src = "pub fn f() { let _r = thread_rng(); }\n";
    let r = lint_source("rust/src/quant/policy.rs", src);
    assert_eq!(rules_fired(&r), vec!["det-rand"]);
}

#[test]
fn seeded_rng_in_determinism_module_is_clean() {
    let src = "pub fn f(seed: u64) { let _r = crate::util::rng::SplitMix64::new(seed); }\n";
    let r = lint_source("rust/src/quant/policy.rs", src);
    assert!(r.violations.is_empty(), "{:?}", r.violations);
}

// --- lock-unwrap ----------------------------------------------------------

#[test]
fn lock_unwrap_in_scheduler_fires() {
    let src = r#"
pub fn f(m: &std::sync::Mutex<Vec<u32>>) -> usize {
    m.lock().unwrap().len()
}
"#;
    let r = lint_source("rust/src/coordinator/scheduler.rs", src);
    assert_eq!(rules_fired(&r), vec!["lock-unwrap"]);
}

#[test]
fn lock_unwrap_in_checkpoint_fires() {
    // checkpoint.rs is under the lock discipline too: a BufWriter
    // into_inner() (the fsync seam) must not be unwrapped bare
    let src = r#"
pub fn f(w: std::io::BufWriter<std::fs::File>) -> std::fs::File {
    w.into_inner().unwrap()
}
"#;
    let r = lint_source("rust/src/coordinator/checkpoint.rs", src);
    assert_eq!(rules_fired(&r), vec!["lock-unwrap"]);
}

#[test]
fn lock_expect_split_across_lines_fires() {
    let src = r#"
pub fn f(m: &std::sync::Mutex<Vec<u32>>) -> usize {
    m.lock()
        .expect("queue lock")
        .len()
}
"#;
    let r = lint_source("rust/src/coordinator/shard.rs", src);
    assert_eq!(rules_fired(&r), vec!["lock-unwrap"]);
}

#[test]
fn channel_recv_unwrap_in_shard_fires() {
    let src = r#"
pub fn f(rx: &std::sync::mpsc::Receiver<u8>) -> u8 {
    rx.recv().unwrap()
}
"#;
    let r = lint_source("rust/src/coordinator/shard.rs", src);
    assert_eq!(rules_fired(&r), vec!["lock-unwrap"]);
}

#[test]
fn expect_with_channel_op_on_next_line_fires() {
    // the unwrap line is visibly unfinished (no trailing `;`), so the
    // continuation — where the channel op actually appears — is part of
    // the detection window
    let src = r#"
pub fn f(tx: &Option<std::sync::mpsc::Sender<u8>>) {
    tx.as_ref().expect("sender live until drop")
        .send(7)
        .ok();
}
"#;
    let r = lint_source("rust/src/coordinator/shard.rs", src);
    assert_eq!(rules_fired(&r), vec!["lock-unwrap"]);
}

#[test]
fn finished_unwrap_before_unrelated_send_is_clean() {
    // here the unwrap statement ends in `;`, so the send on the next
    // statement must not be pulled into the window
    let src = r#"
pub fn f(s: &str, tx: &std::sync::mpsc::Sender<u32>) {
    let n = s.parse::<u32>().unwrap();
    tx.send(n).ok();
}
"#;
    let r = lint_source("rust/src/coordinator/scheduler.rs", src);
    assert!(r.violations.is_empty(), "{:?}", r.violations);
}

#[test]
fn poison_recovery_is_clean() {
    let src = r#"
pub fn f(m: &std::sync::Mutex<Vec<u32>>) -> usize {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner).len()
}
"#;
    let r = lint_source("rust/src/coordinator/scheduler.rs", src);
    assert!(r.violations.is_empty(), "{:?}", r.violations);
}

#[test]
fn non_lock_unwrap_in_scheduler_is_fine() {
    let src = r#"
pub fn f(s: &str) -> u32 {
    s.parse::<u32>().unwrap()
}
"#;
    let r = lint_source("rust/src/coordinator/scheduler.rs", src);
    assert!(r.violations.is_empty(), "{:?}", r.violations);
}

#[test]
fn lock_unwrap_outside_named_files_is_fine() {
    let src = r#"
pub fn f(m: &std::sync::Mutex<Vec<u32>>) -> usize {
    m.lock().unwrap().len()
}
"#;
    let r = lint_source("rust/src/coordinator/second_order.rs", src);
    assert!(r.violations.is_empty(), "{:?}", r.violations);
}

// --- allow annotations ----------------------------------------------------

#[test]
fn trailing_allow_suppresses_and_is_counted() {
    let src = r#"
pub fn f(m: &std::sync::Mutex<Vec<u32>>) -> usize {
    m.lock().unwrap().len() // lint:allow(lock-unwrap) test-only helper, poison impossible
}
"#;
    let r = lint_source("rust/src/coordinator/scheduler.rs", src);
    assert!(r.violations.is_empty(), "{:?}", r.violations);
    assert_eq!(r.allows.len(), 1);
    assert_eq!(r.allows[0].rule, "lock-unwrap");
    assert!(r.allows[0].reason.contains("poison impossible"));
}

#[test]
fn standalone_allow_governs_next_code_line() {
    let src = r#"
pub fn f(m: &std::sync::Mutex<Vec<u32>>) -> usize {
    // lint:allow(lock-unwrap) single-threaded setup path, cannot poison

    m.lock().unwrap().len()
}
"#;
    let r = lint_source("rust/src/coordinator/scheduler.rs", src);
    assert!(r.violations.is_empty(), "{:?}", r.violations);
    assert_eq!(r.allows.len(), 1);
}

#[test]
fn allow_of_wrong_rule_does_not_suppress() {
    let src = r#"
pub fn f(m: &std::sync::Mutex<Vec<u32>>) -> usize {
    m.lock().unwrap().len() // lint:allow(det-hash) mismatched rule name
}
"#;
    let r = lint_source("rust/src/coordinator/scheduler.rs", src);
    assert_eq!(rules_fired(&r), vec!["lock-unwrap"]);
}

#[test]
fn allow_with_unknown_rule_is_a_grammar_violation() {
    let src = "pub fn f() {} // lint:allow(no-such-rule) whatever\n";
    let r = lint_source("rust/src/coordinator/scheduler.rs", src);
    assert_eq!(rules_fired(&r), vec!["allow-grammar"]);
}

#[test]
fn allow_without_reason_is_a_grammar_violation() {
    let src = "pub fn f() {} // lint:allow(lock-unwrap)\n";
    let r = lint_source("rust/src/coordinator/scheduler.rs", src);
    assert_eq!(rules_fired(&r), vec!["allow-grammar"]);
}

// --- scanner robustness ---------------------------------------------------

#[test]
fn raw_strings_and_char_literals_are_stripped() {
    let src = r##"
pub fn f<'a>(x: &'a str) -> (char, &'a str) {
    let c = '{';
    let s = r#"unsafe HashMap Instant::now .lock().unwrap()"#;
    (c, s)
}
"##;
    let r = lint_source("rust/src/coordinator/scheduler.rs", src);
    assert!(r.violations.is_empty(), "{:?}", r.violations);
}

#[test]
fn nested_block_comments_are_stripped() {
    let src = "/* outer /* unsafe inner */ still comment unsafe */ pub fn f() {}\n";
    let r = lint_source("rust/src/coordinator/merge.rs", src);
    assert!(r.violations.is_empty(), "{:?}", r.violations);
}

#[test]
fn violation_line_numbers_are_one_based_and_exact() {
    let src = "\n\nuse std::collections::HashMap;\n";
    let r = lint_source("rust/src/coordinator/merge.rs", src);
    assert_eq!(r.violations.len(), 1);
    assert_eq!(r.violations[0].line, 3);
}
