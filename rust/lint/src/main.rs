//! CLI entry point: `cargo run -p shampoo-lint [repo_root]`.
//!
//! Walks the workspace source trees, prints every violation and the full
//! allow-annotation inventory, and exits non-zero if any rule fired — the
//! blocking CI contract.

use std::path::PathBuf;
use std::process::ExitCode;

fn main() -> ExitCode {
    // default root: two levels above this crate's manifest (rust/lint -> repo)
    let root = std::env::args().nth(1).map(PathBuf::from).unwrap_or_else(|| {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("..").join("..")
    });
    let report = match shampoo_lint::lint_tree(&root) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("shampoo-lint: cannot walk {}: {e}", root.display());
            return ExitCode::from(2);
        }
    };
    print!("{}", shampoo_lint::render(&report));
    if report.violations.is_empty() {
        ExitCode::SUCCESS
    } else {
        ExitCode::FAILURE
    }
}
