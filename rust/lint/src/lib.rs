//! The in-workspace invariant linter (`cargo run -p shampoo-lint`).
//!
//! `clippy` enforces general Rust hygiene; this crate enforces the
//! *repo-specific* contracts that the concurrent engine stakes its
//! correctness on but that no general-purpose tool can express:
//!
//! * **`unsafe-safety`** — every `unsafe` block/impl carries a `// SAFETY:`
//!   comment explaining why the invariants hold (backed crate-wide by
//!   `clippy::undocumented_unsafe_blocks`; this linter re-checks it so the
//!   gate also covers tests/benches and non-clippy runs).
//! * **`unsafe-module`** — `unsafe` is confined to an explicit module
//!   allowlist (the `quant/simd/{sse2,avx2,neon}.rs` lane kernels,
//!   `coordinator/scheduler.rs`, `coordinator/second_order.rs`). The lane
//!   registry itself (`quant/simd/mod.rs`) is deliberately NOT listed:
//!   dispatch, detection, and the SWAR folds stay safe code. New unsafe
//!   code must either live in a listed file or change the list in a
//!   reviewed diff.
//! * **`atomic-ordering`** — every atomic load/store/RMW spells its
//!   `Ordering::` path explicitly (no bare `Relaxed` imports) and carries a
//!   one-line `// ordering:` rationale at the call site.
//! * **`det-hash`** — determinism-contract modules (`coordinator/*`,
//!   `quant/*`) may not use `HashMap`/`HashSet`/`RandomState` at all:
//!   iteration order would leak nondeterminism into merge/swap paths, and
//!   the bit-reproducibility contract (sharded == pipelined == serial)
//!   cannot survive that.
//! * **`det-wallclock`** — determinism modules read the wall clock only
//!   through `util::timer` (`Stopwatch`), whose results may feed
//!   `StepTimings` telemetry but never control flow. Raw `Instant::now` /
//!   `SystemTime` reads are flagged.
//! * **`det-rand`** — determinism modules may not touch ambient/unseeded
//!   randomness (`thread_rng`, `from_entropy`, `rand::random`,
//!   `getrandom`); all streams fork from the run seed via `util::rng`.
//! * **`lock-unwrap`** — `coordinator/{scheduler,shard,checkpoint}.rs`
//!   may not call bare `.unwrap()`/`.expect()` on lock/channel results
//!   (mutex poison, condvar waits, `send`/`recv`, buffered-writer
//!   `into_inner`): those must propagate a typed
//!   [`ScheduleError`](https://docs.rs/) / shard error-ack, recover
//!   deliberately (`unwrap_or_else(PoisonError::into_inner)` with a
//!   rationale), or carry an allow annotation.
//!
//! # Allow annotations
//!
//! A violation that is intentional carries a site-level annotation — the
//! marker `lint:allow` immediately followed by the rule name in
//! parentheses and a one-line reason (see `ARCHITECTURE.md` §6 for the
//! grammar spelled out; this doc avoids writing a literal annotation,
//! which the linter would otherwise pick up right here) —
//! either trailing on the offending line or on the comment line directly
//! above it. The linter counts every annotation, validates that the rule
//! name exists and the reason is non-empty (`allow-grammar` violations are
//! not themselves allowable), and reports the full list in its summary —
//! so the set of blessed exceptions is always visible in CI logs.
//!
//! # Scanner
//!
//! A lightweight line-oriented token scanner, not a parser: comments and
//! string/char literals are stripped (line + nested block comments, plain
//! and raw strings, char-vs-lifetime disambiguation) so rules match only
//! real code tokens, and `#[cfg(test)]`-gated regions plus `tests/` and
//! `benches/` trees are tracked so test scaffolding is exempt from the
//! rules that target production invariants (test code still answers for
//! `unsafe`). This is deliberately simple enough to audit by eye — the
//! linter guards the engine, so the linter itself must be boring.

use std::path::{Path, PathBuf};

/// One enforced rule: `(name, what it enforces)`.
pub const RULES: &[(&str, &str)] = &[
    ("unsafe-safety", "every `unsafe` block/impl carries a `// SAFETY:` comment"),
    (
        "unsafe-module",
        "`unsafe` is confined to the quant/simd/{sse2,avx2,neon}.rs lane \
         kernels, coordinator/scheduler.rs, coordinator/second_order.rs \
         (the quant/simd/mod.rs registry stays safe code)",
    ),
    (
        "atomic-ordering",
        "atomic ops spell `Ordering::` explicitly and carry a `// ordering:` rationale",
    ),
    (
        "det-hash",
        "determinism modules (coordinator/*, quant/*) must not use \
         HashMap/HashSet (unordered iteration)",
    ),
    (
        "det-wallclock",
        "determinism modules read wall-clock only via util::timer, never \
         Instant::now/SystemTime directly",
    ),
    (
        "det-rand",
        "determinism modules must not use ambient/unseeded randomness",
    ),
    (
        "lock-unwrap",
        "no bare .unwrap()/.expect() on lock/channel results in \
         coordinator/{scheduler,shard,checkpoint}.rs",
    ),
    (
        "allow-grammar",
        "every lint:allow(<rule>) names an existing rule and carries a reason \
         (meta-rule; not itself allowable)",
    ),
];

/// Modules permitted to contain `unsafe` code (path suffixes). Only the
/// per-ISA lane kernel files qualify — the lane registry/dispatch module
/// (`src/quant/simd/mod.rs`) must stay safe code.
pub const UNSAFE_ALLOWLIST: &[&str] = &[
    "src/quant/simd/sse2.rs",
    "src/quant/simd/avx2.rs",
    "src/quant/simd/neon.rs",
    "src/coordinator/scheduler.rs",
    "src/coordinator/second_order.rs",
];

/// Files under the lock-discipline rule (path suffixes).
pub const LOCK_DISCIPLINE_FILES: &[&str] = &[
    "src/coordinator/scheduler.rs",
    "src/coordinator/shard.rs",
    "src/coordinator/checkpoint.rs",
];

/// One rule violation at a source location.
#[derive(Debug, Clone)]
pub struct Violation {
    /// Repo-relative path (forward slashes).
    pub file: String,
    /// 1-based line number.
    pub line: usize,
    /// Name of the violated rule (see [`RULES`]).
    pub rule: &'static str,
    /// Human-readable description of the specific violation.
    pub message: String,
}

/// One allow annotation (`lint:allow` + rule + reason) found in the tree.
#[derive(Debug, Clone)]
pub struct AllowSite {
    /// Repo-relative path (forward slashes).
    pub file: String,
    /// 1-based line number of the annotation comment.
    pub line: usize,
    /// Rule name inside the parentheses.
    pub rule: String,
    /// Free text after the closing parenthesis.
    pub reason: String,
}

/// Result of linting one source file.
#[derive(Debug, Default)]
pub struct FileReport {
    /// Violations found (allow-annotated sites excluded).
    pub violations: Vec<Violation>,
    /// Every allow annotation in the file, used or not.
    pub allows: Vec<AllowSite>,
}

/// Result of linting a whole tree.
#[derive(Debug, Default)]
pub struct LintReport {
    /// Files scanned.
    pub files: usize,
    /// All violations across the tree.
    pub violations: Vec<Violation>,
    /// All allow annotations across the tree.
    pub allows: Vec<AllowSite>,
}

/// True iff `name` is a registered rule (see [`RULES`]).
pub fn rule_exists(name: &str) -> bool {
    RULES.iter().any(|(n, _)| *n == name)
}

// ---------------------------------------------------------------------------
// scanner: strip comments/strings, keep comment text per line
// ---------------------------------------------------------------------------

/// One scanned source line: code with comments and literal *contents*
/// removed (string literals collapse to `""`), plus the comment text.
#[derive(Debug, Default, Clone)]
struct ScanLine {
    code: String,
    comment: String,
}

/// Split source into per-line (code, comment) pairs. Handles line
/// comments, nested block comments, plain strings (with `\"` escapes and
/// backslash-newline continuations), raw strings (`r".."`, `r#".."#`,
/// `br#".."#`), and char-literal-vs-lifetime disambiguation.
fn split_source(src: &str) -> Vec<ScanLine> {
    #[derive(Clone, Copy, PartialEq)]
    enum Mode {
        Code,
        LineComment,
        BlockComment(u32),
        Str,
        RawStr(u32),
    }
    let chars: Vec<char> = src.chars().collect();
    let n = chars.len();
    let mut lines = Vec::new();
    let mut cur = ScanLine::default();
    let mut mode = Mode::Code;
    let mut prev_ident = false;
    let mut i = 0usize;
    while i < n {
        let c = chars[i];
        if c == '\n' {
            if mode == Mode::LineComment {
                mode = Mode::Code;
            }
            lines.push(std::mem::take(&mut cur));
            prev_ident = false;
            i += 1;
            continue;
        }
        match mode {
            Mode::Code => {
                let next = chars.get(i + 1).copied();
                if c == '/' && next == Some('/') {
                    mode = Mode::LineComment;
                    i += 2;
                    continue;
                }
                if c == '/' && next == Some('*') {
                    mode = Mode::BlockComment(1);
                    i += 2;
                    continue;
                }
                if c == '"' {
                    mode = Mode::Str;
                    cur.code.push('"');
                    prev_ident = false;
                    i += 1;
                    continue;
                }
                // raw strings: r"..", r#".."#, br#".."# — only when the `r`
                // does not continue an identifier
                let raw_at = if c == 'r' && !prev_ident {
                    Some(i + 1)
                } else if c == 'b' && !prev_ident && next == Some('r') {
                    Some(i + 2)
                } else {
                    None
                };
                if let Some(mut j) = raw_at {
                    let mut hashes = 0u32;
                    while j < n && chars[j] == '#' {
                        hashes += 1;
                        j += 1;
                    }
                    if j < n && chars[j] == '"' {
                        mode = Mode::RawStr(hashes);
                        cur.code.push('"');
                        prev_ident = false;
                        i = j + 1;
                        continue;
                    }
                }
                if c == '\'' {
                    // char literal vs lifetime: '\x' escapes and 'c' single
                    // chars are literals; anything else is a lifetime tick
                    if next == Some('\\') {
                        let mut j = i + 2;
                        while j < n && chars[j] != '\'' && chars[j] != '\n' {
                            j += 1;
                        }
                        cur.code.push_str("' '");
                        prev_ident = false;
                        i = if j < n && chars[j] == '\'' { j + 1 } else { j };
                        continue;
                    }
                    if chars.get(i + 2).copied() == Some('\'') && next != Some('\'') {
                        cur.code.push_str("' '");
                        prev_ident = false;
                        i += 3;
                        continue;
                    }
                }
                cur.code.push(c);
                prev_ident = c.is_alphanumeric() || c == '_';
                i += 1;
            }
            Mode::LineComment => {
                cur.comment.push(c);
                i += 1;
            }
            Mode::BlockComment(depth) => {
                let next = chars.get(i + 1).copied();
                if c == '*' && next == Some('/') {
                    mode = if depth == 1 { Mode::Code } else { Mode::BlockComment(depth - 1) };
                    i += 2;
                } else if c == '/' && next == Some('*') {
                    mode = Mode::BlockComment(depth + 1);
                    i += 2;
                } else {
                    cur.comment.push(c);
                    i += 1;
                }
            }
            Mode::Str => {
                if c == '\\' {
                    // keep backslash-newline continuations on their own
                    // lines so line numbering never drifts
                    if chars.get(i + 1).copied() == Some('\n') {
                        i += 1;
                    } else {
                        i += 2;
                    }
                    continue;
                }
                if c == '"' {
                    cur.code.push('"');
                    mode = Mode::Code;
                }
                i += 1;
            }
            Mode::RawStr(hashes) => {
                if c == '"' {
                    let mut ok = true;
                    for k in 0..hashes as usize {
                        if chars.get(i + 1 + k).copied() != Some('#') {
                            ok = false;
                            break;
                        }
                    }
                    if ok {
                        cur.code.push('"');
                        mode = Mode::Code;
                        i += 1 + hashes as usize;
                        continue;
                    }
                }
                i += 1;
            }
        }
    }
    lines.push(cur);
    lines
}

/// Per-line flags: is the line inside a `#[cfg(test)]`-gated region?
/// Tracks brace depth on stripped code, so braces inside strings/comments
/// never confuse the region bounds.
fn test_region_flags(lines: &[ScanLine]) -> Vec<bool> {
    let mut flags = vec![false; lines.len()];
    let mut depth: i64 = 0;
    let mut pending = false;
    let mut region_parent_depth: Option<i64> = None;
    for (idx, l) in lines.iter().enumerate() {
        if region_parent_depth.is_some() || pending {
            flags[idx] = true;
        }
        if l.code.contains("#[cfg(test)]") || l.code.contains("#[cfg(all(test") {
            pending = true;
            flags[idx] = true;
        }
        for ch in l.code.chars() {
            match ch {
                '{' => {
                    depth += 1;
                    if pending {
                        region_parent_depth = Some(depth - 1);
                        pending = false;
                    }
                }
                '}' => {
                    depth -= 1;
                    if let Some(d) = region_parent_depth {
                        if depth <= d {
                            region_parent_depth = None;
                        }
                    }
                }
                _ => {}
            }
        }
    }
    flags
}

/// Does `code` contain `word` with non-identifier characters (or edges) on
/// both sides?
fn contains_word(code: &str, word: &str) -> bool {
    let mut start = 0usize;
    while let Some(pos) = code[start..].find(word) {
        let at = start + pos;
        let before_ok = at == 0
            || !code[..at]
                .chars()
                .next_back()
                .map(|c| c.is_alphanumeric() || c == '_')
                .unwrap_or(false);
        let after = code[at + word.len()..].chars().next();
        let after_ok = !after.map(|c| c.is_alphanumeric() || c == '_').unwrap_or(false);
        if before_ok && after_ok {
            return true;
        }
        start = at + word.len();
    }
    false
}

/// Is the marker (`SAFETY:` / `ordering:`) present on this line's comment
/// or in the contiguous comment/attribute/blank block directly above?
fn has_marker(lines: &[ScanLine], idx: usize, marker: &str) -> bool {
    if lines[idx].comment.contains(marker) {
        return true;
    }
    let mut j = idx;
    let mut looked = 0;
    while j > 0 && looked < 12 {
        j -= 1;
        looked += 1;
        let code = lines[j].code.trim();
        if !code.is_empty() && !code.starts_with("#[") {
            // a continuation head (`let x =`, an open delimiter, a trailing
            // comma/operator) doesn't end the comment block: the marker may
            // sit above the whole statement the flagged line belongs to
            const CONT: &[&str] = &["=", "(", "{", ",", "+", "&&", "||", "=>"];
            if !CONT.iter().any(|c| code.ends_with(c)) {
                return false; // hit real code: the comment block ended
            }
        }
        if lines[j].comment.contains(marker) {
            return true;
        }
    }
    false
}

// ---------------------------------------------------------------------------
// rule engine
// ---------------------------------------------------------------------------

const ATOMIC_OPS: &[&str] = &[
    ".load(",
    ".store(",
    ".swap(",
    ".fetch_add(",
    ".fetch_sub(",
    ".fetch_and(",
    ".fetch_or(",
    ".fetch_xor(",
    ".fetch_max(",
    ".fetch_min(",
    ".fetch_update(",
    ".compare_exchange",
];

const BARE_ORDERINGS: &[&str] = &["Relaxed", "SeqCst", "Acquire", "Release", "AcqRel"];

const LOCK_CHANNEL_PATTERNS: &[&str] = &[
    ".lock(",
    ".read()",
    ".write()",
    ".wait(",
    ".wait_timeout(",
    ".send(",
    ".recv(",
    ".try_recv(",
    ".recv_timeout(",
    ".into_inner(",
    ".join()",
];

const RAND_TOKENS: &[&str] = &["thread_rng", "from_entropy", "getrandom"];

fn is_test_path(rel: &str) -> bool {
    rel.split('/').any(|seg| seg == "tests" || seg == "benches")
}

fn is_det_module(rel: &str) -> bool {
    rel.contains("src/coordinator/") || rel.contains("src/quant/")
}

fn suffix_match(rel: &str, suffixes: &[&str]) -> bool {
    suffixes.iter().any(|s| rel.ends_with(s))
}

/// Lint one source file. `rel_path` is the repo-relative path with forward
/// slashes — rule scoping (allowlists, determinism modules, test trees)
/// keys off it, so fixture tests can probe any scope by labeling their
/// snippet accordingly.
pub fn lint_source(rel_path: &str, src: &str) -> FileReport {
    let lines = split_source(src);
    let test_flags = test_region_flags(&lines);
    let file_is_test = is_test_path(rel_path);
    let det = is_det_module(rel_path) && !file_is_test;
    let lock_scope = suffix_match(rel_path, LOCK_DISCIPLINE_FILES) && !file_is_test;
    let unsafe_ok = suffix_match(rel_path, UNSAFE_ALLOWLIST);

    let mut report = FileReport::default();

    // pass 1: collect allow annotations and attach each to the line it
    // governs (its own line when it trails code, else the next code line)
    let mut allowed: Vec<Vec<usize>> = vec![Vec::new(); lines.len()];
    for (idx, l) in lines.iter().enumerate() {
        let Some(pos) = l.comment.find("lint:allow(") else { continue };
        let rest = &l.comment[pos + "lint:allow(".len()..];
        let (rule, reason) = match rest.find(')') {
            Some(close) => (rest[..close].trim().to_string(), rest[close + 1..].trim().to_string()),
            None => (rest.trim().to_string(), String::new()),
        };
        let site = report.allows.len();
        report.allows.push(AllowSite {
            file: rel_path.to_string(),
            line: idx + 1,
            rule: rule.clone(),
            reason: reason.clone(),
        });
        if !rule_exists(&rule) || rule == "allow-grammar" {
            report.violations.push(Violation {
                file: rel_path.to_string(),
                line: idx + 1,
                rule: "allow-grammar",
                message: format!("lint:allow names unknown rule `{rule}`"),
            });
        } else if reason.len() < 3 {
            report.violations.push(Violation {
                file: rel_path.to_string(),
                line: idx + 1,
                rule: "allow-grammar",
                message: format!("lint:allow({rule}) carries no reason"),
            });
        }
        // attach to this line if it has code, else the next line with code
        let mut target = idx;
        if lines[idx].code.trim().is_empty() {
            let mut j = idx + 1;
            while j < lines.len() && lines[j].code.trim().is_empty() {
                j += 1;
            }
            if j < lines.len() {
                target = j;
            }
        }
        allowed[target].push(site);
    }

    let is_allowed = |allows: &[AllowSite], site_ids: &[usize], rule: &str| -> bool {
        site_ids.iter().any(|&s| allows[s].rule == rule)
    };

    // pass 2: the rules
    for (idx, l) in lines.iter().enumerate() {
        let code = &l.code;
        let lineno = idx + 1;
        let in_test = file_is_test || test_flags[idx];
        let mut push = |report: &mut FileReport, rule: &'static str, message: String| {
            if !is_allowed(&report.allows, &allowed[idx], rule) {
                report.violations.push(Violation {
                    file: rel_path.to_string(),
                    line: lineno,
                    rule,
                    message,
                });
            }
        };

        // unsafe rules apply everywhere, tests included
        if contains_word(code, "unsafe") {
            if !unsafe_ok {
                push(
                    &mut report,
                    "unsafe-module",
                    format!("`unsafe` outside the allowlisted modules ({rel_path})"),
                );
            }
            if !has_marker(&lines, idx, "SAFETY:") {
                push(
                    &mut report,
                    "unsafe-safety",
                    "`unsafe` without a `// SAFETY:` comment".to_string(),
                );
            }
        }

        if in_test {
            continue;
        }

        // atomic-ordering: src-wide, non-test
        if ATOMIC_OPS.iter().any(|op| code.contains(op)) {
            let mut window = code.clone();
            for w in lines.iter().skip(idx + 1).take(2) {
                window.push(' ');
                window.push_str(&w.code);
            }
            if window.contains("Ordering::") {
                if !has_marker(&lines, idx, "ordering:") {
                    push(
                        &mut report,
                        "atomic-ordering",
                        "atomic op without a `// ordering:` rationale".to_string(),
                    );
                }
            } else if BARE_ORDERINGS.iter().any(|o| contains_word(&window, o)) {
                push(
                    &mut report,
                    "atomic-ordering",
                    "atomic op must spell `Ordering::` explicitly".to_string(),
                );
            }
        }

        if det {
            for tok in ["HashMap", "HashSet", "RandomState"] {
                if contains_word(code, tok) {
                    push(
                        &mut report,
                        "det-hash",
                        format!("`{tok}` in a determinism module (unordered iteration)"),
                    );
                    break;
                }
            }
            if code.contains("Instant::now") || contains_word(code, "SystemTime") {
                push(
                    &mut report,
                    "det-wallclock",
                    "raw wall-clock read in a determinism module (use util::timer)".to_string(),
                );
            }
            if RAND_TOKENS.iter().any(|t| contains_word(code, t))
                || code.contains("rand::random")
            {
                push(
                    &mut report,
                    "det-rand",
                    "ambient/unseeded randomness in a determinism module".to_string(),
                );
            }
        }

        if lock_scope && (code.contains(".unwrap()") || code.contains(".expect(")) {
            let mut window = String::new();
            if idx > 0 {
                window.push_str(&lines[idx - 1].code);
                window.push(' ');
            }
            window.push_str(code);
            // an unwrap/expect whose lock/channel call continues on the next
            // line (`...expect("live").` / newline / `.send(msg)`): pull the
            // continuation in, but only when this line is visibly unfinished,
            // so an unrelated channel op on the following statement does not
            // trip the rule
            let unfinished = !matches!(
                code.trim_end().chars().last(),
                Some(';') | Some('{') | Some('}') | None
            );
            if unfinished {
                if let Some(next) = lines.get(idx + 1) {
                    window.push(' ');
                    window.push_str(&next.code);
                }
            }
            if LOCK_CHANNEL_PATTERNS.iter().any(|p| window.contains(p)) {
                push(
                    &mut report,
                    "lock-unwrap",
                    "bare unwrap/expect on a lock/channel result (propagate a typed \
                     error or recover deliberately)"
                        .to_string(),
                );
            }
        }
    }
    report
}

// ---------------------------------------------------------------------------
// tree walking
// ---------------------------------------------------------------------------

/// Directories scanned relative to the repo root.
pub const SCAN_DIRS: &[&str] = &[
    "rust/src",
    "rust/tests",
    "rust/benches",
    "rust/lint/src",
    "rust/lint/tests",
    "rust/xla-stub/src",
];

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    let mut entries: Vec<PathBuf> =
        std::fs::read_dir(dir)?.filter_map(|e| e.ok().map(|e| e.path())).collect();
    entries.sort();
    for p in entries {
        if p.is_dir() {
            collect_rs(&p, out)?;
        } else if p.extension().map(|e| e == "rs").unwrap_or(false) {
            out.push(p);
        }
    }
    Ok(())
}

/// Lint every `.rs` file under [`SCAN_DIRS`] below `repo_root`.
pub fn lint_tree(repo_root: &Path) -> std::io::Result<LintReport> {
    let mut report = LintReport::default();
    for dir in SCAN_DIRS {
        let d = repo_root.join(dir);
        if !d.is_dir() {
            continue;
        }
        let mut files = Vec::new();
        collect_rs(&d, &mut files)?;
        for f in files {
            let src = std::fs::read_to_string(&f)?;
            let rel = f
                .strip_prefix(repo_root)
                .unwrap_or(&f)
                .to_string_lossy()
                .replace('\\', "/");
            let fr = lint_source(&rel, &src);
            report.files += 1;
            report.violations.extend(fr.violations);
            report.allows.extend(fr.allows);
        }
    }
    Ok(report)
}

/// Render the report the way `main` prints it (tests assert on pieces).
pub fn render(report: &LintReport) -> String {
    let mut out = String::new();
    for v in &report.violations {
        out.push_str(&format!("{}:{}: [{}] {}\n", v.file, v.line, v.rule, v.message));
    }
    if !report.allows.is_empty() {
        out.push_str(&format!("{} lint:allow annotation(s):\n", report.allows.len()));
        for a in &report.allows {
            out.push_str(&format!(
                "  {}:{}: allow({}) — {}\n",
                a.file, a.line, a.rule, a.reason
            ));
        }
    }
    out.push_str(&format!(
        "{} file(s) scanned, {} violation(s)\n",
        report.files,
        report.violations.len()
    ));
    out
}
