//! Quantization-substrate coverage (property + golden tests):
//!  * quantize→dequantize roundtrip error bounded by ½ · max codebook gap ·
//!    block absmax, for every mapping and bits ∈ {2, 3, 4, 8};
//!  * pack_bits/unpack_bits identity at every supported bitwidth;
//!  * codebooks match the paper's Appendix C tables verbatim
//!    (mirroring python/tests/test_codebooks.py).

use shampoo4::quant::{
    codebook, dequantize, pack_bits, packed_len, quantize, runtime_codebook, unpack_bits,
    Boundaries, Mapping,
};
use shampoo4::util::prop;

#[test]
fn roundtrip_error_bounded_all_mappings_and_bits() {
    for mapping in [Mapping::Dt, Mapping::Linear2, Mapping::Linear] {
        for bits in [2u32, 3, 4, 8] {
            let cb = codebook(mapping, bits);
            let max_gap = cb.windows(2).map(|w| w[1] - w[0]).fold(0.0f32, f32::max);
            prop::check(&format!("roundtrip {mapping:?}/{bits}"), 10, |rng| {
                let nblocks = 1 + rng.below(6);
                let block = 64;
                let x: Vec<f32> =
                    (0..nblocks * block).map(|_| rng.normal_f32() * 0.7).collect();
                let q = quantize(&x, &cb, bits, block);
                if q.packed.len() != packed_len(x.len(), bits) {
                    return Err(format!("packed {} bytes", q.packed.len()));
                }
                let d = dequantize(&q, &cb);
                for (b, chunk) in x.chunks(block).enumerate() {
                    let absmax = chunk.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
                    let scale = if absmax > 0.0 { absmax } else { 1.0 };
                    let bound = 0.5 * max_gap * scale + 1e-6;
                    for (i, (&xv, &dv)) in chunk.iter().zip(&d[b * block..]).enumerate() {
                        if (xv - dv).abs() > bound {
                            return Err(format!(
                                "{mapping:?}/{bits} block {b} elem {i}: {xv} vs {dv}, bound {bound}"
                            ));
                        }
                    }
                }
                Ok(())
            });
        }
    }
}

#[test]
fn pack_unpack_identity_all_bitwidths() {
    for bits in [2u32, 3, 4, 8] {
        prop::check(&format!("pack/unpack {bits}-bit"), 20, |rng| {
            let n = 1 + rng.below(500);
            let codes: Vec<u8> = (0..n).map(|_| rng.below(1 << bits) as u8).collect();
            let packed = pack_bits(&codes, bits);
            if packed.len() != packed_len(n, bits) {
                return Err(format!("{bits}-bit: {} bytes for {n} codes", packed.len()));
            }
            let back = unpack_bits(&packed, bits, n);
            if back != codes {
                return Err(format!("{bits}-bit roundtrip mismatch at n={n}"));
            }
            Ok(())
        });
    }
}

// Appendix C tables, verbatim (same fixtures as python/tests/test_codebooks.py).
const DT4_PAPER: [f32; 16] = [
    -0.8875, -0.6625, -0.4375, -0.2125, -0.0775, -0.0325, -0.0055, 0.0, 0.0055, 0.0325, 0.0775,
    0.2125, 0.4375, 0.6625, 0.8875, 1.0,
];
const DT3_PAPER: [f32; 8] = [-0.775, -0.325, -0.055, 0.0, 0.055, 0.325, 0.775, 1.0];
const L24_PAPER: [f32; 16] = [
    -1.0, -0.7511, -0.5378, -0.36, -0.2178, -0.1111, -0.04, 0.0, 0.0044, 0.04, 0.1111, 0.2178,
    0.36, 0.5378, 0.7511, 1.0,
];
const L23_PAPER: [f32; 8] = [-1.0, -0.5102, -0.1837, 0.0, 0.0204, 0.1837, 0.5102, 1.0];

fn assert_table(got: &[f32], want: &[f32], tol: f32, label: &str) {
    assert_eq!(got.len(), want.len(), "{label}: length");
    for (i, (a, b)) in got.iter().zip(want).enumerate() {
        assert!((a - b).abs() < tol, "{label}[{i}]: {a} vs {b}");
    }
}

#[test]
fn golden_codebooks_match_appendix_c() {
    assert_table(&codebook(Mapping::Dt, 4), &DT4_PAPER, 1e-6, "DT-4");
    assert_table(&codebook(Mapping::Dt, 3), &DT3_PAPER, 1e-6, "DT-3");
    assert_table(&codebook(Mapping::Linear2, 4), &L24_PAPER, 5e-5, "Linear2-4");
    assert_table(&codebook(Mapping::Linear2, 3), &L23_PAPER, 5e-5, "Linear2-3");
}

#[test]
fn codebook_structural_properties() {
    for mapping in [Mapping::Dt, Mapping::Linear2, Mapping::Linear] {
        for bits in [3u32, 4, 8] {
            let cb = codebook(mapping, bits);
            assert_eq!(cb.len(), 1 << bits, "{mapping:?}/{bits}: size");
            assert!(
                cb.windows(2).all(|w| w[0] < w[1]),
                "{mapping:?}/{bits}: must be strictly sorted"
            );
            assert!(cb[0] >= -1.0 && *cb.last().unwrap() <= 1.0, "{mapping:?}/{bits}: range");
            assert_eq!(*cb.last().unwrap(), 1.0, "{mapping:?}/{bits}: max is 1");
            if mapping != Mapping::Linear {
                assert!(cb.contains(&0.0), "{mapping:?}/{bits}: zero representable");
            }
        }
    }
}

#[test]
fn padded_runtime_codebooks_emit_low_codes() {
    // 3-bit books are padded to 16 entries; canonical-index boundaries keep
    // every emitted code < 8 so true-bitwidth packing stays valid.
    for mapping in [Mapping::Dt, Mapping::Linear2] {
        let cb = runtime_codebook(mapping, 3);
        assert_eq!(cb.len(), 16);
        let bounds = Boundaries::new(&cb);
        prop::check(&format!("padded {mapping:?}"), 10, |rng| {
            for _ in 0..100 {
                let x = rng.normal_f32();
                let c = bounds.nearest(x);
                if c >= 8 {
                    return Err(format!("x={x} -> code {c}"));
                }
            }
            Ok(())
        });
    }
}
