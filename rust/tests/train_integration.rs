//! End-to-end integration over the live backend: short training runs per
//! arm, checkpoint roundtrip, native-vs-artifact first-order cross-check,
//! and live-vs-planner memory accounting.
//!
//! Runs on the hermetic HostBackend — no Python artifacts, no XLA, no
//! skips. (The PJRT path reuses the same coordinator code behind
//! --features pjrt and is exercised by runtime_integration's golden tests.)

#![allow(clippy::field_reassign_with_default)]

use shampoo4::config::{FirstOrderKind, RunConfig, SecondOrderKind};
use shampoo4::coordinator::Trainer;
use shampoo4::optim::FirstOrder;
use shampoo4::runtime::{Backend, HostBackend, HostTensor};

fn backend() -> HostBackend {
    HostBackend::new()
}

fn base_cfg(steps: usize) -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.model = "mlp_base".into();
    cfg.steps = steps;
    cfg.first.kind = FirstOrderKind::Sgdm;
    cfg.first.lr = 0.05;
    cfg.first.weight_decay = 5e-4;
    cfg.second.update_precond_every = 10;
    cfg.second.update_invroot_every = 20;
    cfg.eval_every = 0;
    cfg.eval_batches = 4;
    cfg.log_every = 5;
    cfg
}

#[test]
fn mlp_4bit_shampoo_learns() {
    let rt = backend();
    let mut cfg = base_cfg(40);
    cfg.name = "it_4bit".into();
    let mut t = Trainer::new(&rt, cfg).unwrap();
    let res = t.train(&rt, None).unwrap();
    let first = res.losses.first().unwrap().1;
    let last = res.losses.last().unwrap().1;
    assert!(last < first - 1.0, "loss {first} -> {last}");
    let acc = res.final_eval.unwrap().accuracy.unwrap();
    assert!(acc > 0.3, "accuracy {acc}");
    assert_eq!(res.host_fallbacks, 0, "mlp must run fully on artifacts");
}

#[test]
fn four_bit_memory_below_32bit_and_quality_close() {
    let rt = backend();
    let mut c4 = base_cfg(60);
    c4.name = "it_mem4".into();
    let mut c32 = base_cfg(60);
    c32.name = "it_mem32".into();
    c32.second.quant.bits = 32;
    let r4 = Trainer::new(&rt, c4).unwrap().train(&rt, None).unwrap();
    let r32 = Trainer::new(&rt, c32).unwrap().train(&rt, None).unwrap();
    let ratio = r32.memory.second_order_bytes as f64 / r4.memory.second_order_bytes as f64;
    assert!(ratio > 5.5, "second-order memory ratio {ratio}");
    let a4 = r4.final_eval.unwrap().accuracy.unwrap();
    let a32 = r32.final_eval.unwrap().accuracy.unwrap();
    assert!(a4 > 0.5, "4-bit accuracy {a4}");
    assert!(a32 > 0.5, "32-bit accuracy {a32}");
    assert!((a4 - a32).abs() < 0.15, "4-bit {a4} vs 32-bit {a32}");
}

#[test]
fn live_second_order_bytes_match_planner_model() {
    let rt = backend();
    let cfg = base_cfg(1);
    let t = Trainer::new(&rt, cfg).unwrap();
    let live = t.memory_report().second_order_bytes;
    // planner arithmetic for the same blocks: mlp_base has w0 128x256,
    // w1 256x256, w2 256x128 -> blocks of order 128 only
    let planned: usize = [(128, 256), (256, 256), (256, 128)]
        .iter()
        .map(|&(r, c)| shampoo4::coordinator::memory::shampoo_block_bytes(r, c, 4, 128))
        .sum();
    assert_eq!(live, planned, "live {live} vs planned {planned}");
}

#[test]
fn checkpoint_roundtrip_resumes_identically() {
    let rt = backend();
    let dir = std::env::temp_dir().join("shampoo4_ckpt_test");
    let ckpt = dir.join("ck.bin");
    let mut cfg = base_cfg(10);
    cfg.name = "it_ckpt".into();
    cfg.second.kind = SecondOrderKind::None;
    let mut t = Trainer::new(&rt, cfg.clone()).unwrap();
    t.train(&rt, None).unwrap();
    t.save_checkpoint(&ckpt, 10).unwrap();
    let want = t.model.params.clone();
    let mut t2 = Trainer::new(&rt, cfg).unwrap();
    let step = t2.load_checkpoint(&ckpt).unwrap();
    assert_eq!(step, 10);
    assert_eq!(t2.model.params, want);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn checkpoint_resume_is_equivalent_to_straight_run() {
    // optimizer state (AdamW moments + step counter) rides along in the
    // checkpoint, so 10 steps + save/load + 10 steps must be bit-identical
    // to 20 straight steps
    let rt = backend();
    let dir = std::env::temp_dir().join("shampoo4_resume_test");
    let ckpt = dir.join("ck.bin");
    let mut cfg = base_cfg(20);
    cfg.name = "it_resume".into();
    cfg.first.kind = FirstOrderKind::AdamW;
    cfg.first.lr = 1e-3;
    cfg.first.weight_decay = 0.05;
    cfg.second.kind = SecondOrderKind::None;
    cfg.schedule = shampoo4::config::Schedule::Constant;

    let mut straight = Trainer::new(&rt, cfg.clone()).unwrap();
    straight.train(&rt, None).unwrap();

    let mut first_half_cfg = cfg.clone();
    first_half_cfg.steps = 10;
    let mut first_half = Trainer::new(&rt, first_half_cfg).unwrap();
    first_half.train(&rt, None).unwrap();
    first_half.save_checkpoint(&ckpt, 10).unwrap();

    let mut resumed = Trainer::new(&rt, cfg).unwrap();
    assert_eq!(resumed.load_checkpoint(&ckpt).unwrap(), 10);
    assert_eq!(resumed.model.params, first_half.model.params);
    let res = resumed.train(&rt, None).unwrap(); // continues at step 11
    assert_eq!(res.timings.steps, 10, "resume must run only the back half");
    assert_eq!(
        resumed.model.params, straight.model.params,
        "resumed run diverged from the straight run"
    );
    std::fs::remove_dir_all(&dir).ok();
}

/// Save at step k mid-run, resume, and demand a bit-identical trajectory
/// vs the uninterrupted run — second-order preconditioner state rides in
/// the checkpoint as raw codec bytes, so there is no requantization error
/// and no re-warm.
fn check_second_order_resume(kind: SecondOrderKind) {
    let rt = backend();
    let dir = std::env::temp_dir().join(format!("shampoo4_so_resume_{}", kind.name()));
    let ckpt = dir.join("ck.bin");
    let mut cfg = base_cfg(20);
    cfg.name = format!("it_so_resume_{}", kind.name());
    cfg.second.kind = kind;
    cfg.second.update_precond_every = 4;
    cfg.second.update_invroot_every = 8;
    cfg.schedule = shampoo4::config::Schedule::Constant;
    cfg.log_every = 1;

    let mut straight = Trainer::new(&rt, cfg.clone()).unwrap();
    let r_straight = straight.train(&rt, None).unwrap();

    let mut half_cfg = cfg.clone();
    half_cfg.steps = 10;
    let mut first_half = Trainer::new(&rt, half_cfg).unwrap();
    first_half.train(&rt, None).unwrap();
    first_half.save_checkpoint(&ckpt, 10).unwrap();

    let mut resumed = Trainer::new(&rt, cfg).unwrap();
    assert_eq!(resumed.load_checkpoint(&ckpt).unwrap(), 10);
    let r_resumed = resumed.train(&rt, None).unwrap();
    assert_eq!(r_resumed.timings.steps, 10, "resume must run only the back half");

    let bits = |v: &[Vec<f32>]| -> Vec<Vec<u32>> {
        v.iter().map(|p| p.iter().map(|x| x.to_bits()).collect()).collect()
    };
    assert_eq!(
        bits(&resumed.model.params),
        bits(&straight.model.params),
        "{}: resumed parameters diverged from the straight run",
        kind.name()
    );
    let tail: Vec<(usize, u32)> = r_straight
        .losses
        .iter()
        .filter(|(s, _)| *s > 10)
        .map(|&(s, l)| (s, l.to_bits()))
        .collect();
    let resumed_losses: Vec<(usize, u32)> =
        r_resumed.losses.iter().map(|&(s, l)| (s, l.to_bits())).collect();
    assert_eq!(resumed_losses, tail, "{}: resumed losses diverged", kind.name());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn shampoo_checkpoint_resume_is_bit_identical() {
    check_second_order_resume(SecondOrderKind::Shampoo);
}

#[test]
fn kfac_checkpoint_resume_is_bit_identical() {
    check_second_order_resume(SecondOrderKind::KFac);
}

#[test]
fn quantized_first_order_states_learn_and_shrink_memory() {
    // --first-order-bits 4: AdamW with 4-bit DT moments (Table 13 baseline
    // regime) must still learn, and its state bytes must reflect true
    // bit-packed storage
    let rt = backend();
    let mut cfg = base_cfg(40);
    cfg.name = "it_fo4".into();
    cfg.first.kind = FirstOrderKind::AdamW;
    cfg.first.lr = 1e-3;
    cfg.first.bits = 4;
    cfg.first.mapping = shampoo4::quant::Mapping::Dt;
    cfg.second.kind = SecondOrderKind::None;
    let mut t = Trainer::new(&rt, cfg).unwrap();
    let res = t.train(&rt, None).unwrap();
    let first = res.losses.first().unwrap().1;
    let last = res.losses.last().unwrap().1;
    assert!(last.is_finite() && last < first, "loss {first} -> {last}");
    // fp32 AdamW states would be 2 × params_bytes; 4-bit ≈ 0.28 ×
    let fp32_states = 2 * res.memory.params_bytes;
    assert!(
        res.memory.first_order_bytes * 6 < fp32_states,
        "4-bit states {} vs fp32 {}",
        res.memory.first_order_bytes,
        fp32_states
    );
}

#[test]
fn quantized_first_order_resume_is_exact() {
    // 10 + save/load + 10 must equal 20 straight steps bitwise even with
    // 4-bit moments: the checkpoint persists the encoded bytes verbatim
    let rt = backend();
    let dir = std::env::temp_dir().join("shampoo4_fo4_resume");
    let ckpt = dir.join("ck.bin");
    let mut cfg = base_cfg(20);
    cfg.name = "it_fo4_resume".into();
    cfg.first.kind = FirstOrderKind::AdamW;
    cfg.first.lr = 1e-3;
    cfg.first.bits = 4;
    cfg.second.kind = SecondOrderKind::None;
    cfg.schedule = shampoo4::config::Schedule::Constant;

    let mut straight = Trainer::new(&rt, cfg.clone()).unwrap();
    straight.train(&rt, None).unwrap();

    let mut half_cfg = cfg.clone();
    half_cfg.steps = 10;
    let mut first_half = Trainer::new(&rt, half_cfg).unwrap();
    first_half.train(&rt, None).unwrap();
    first_half.save_checkpoint(&ckpt, 10).unwrap();

    let mut resumed = Trainer::new(&rt, cfg).unwrap();
    assert_eq!(resumed.load_checkpoint(&ckpt).unwrap(), 10);
    resumed.train(&rt, None).unwrap();
    assert_eq!(resumed.model.params, straight.model.params);
    std::fs::remove_dir_all(&dir).ok();
}

/// Build the Li et al. mixed policy: m at 4-bit DT, v at 8-bit DT.
fn mixed_policy_entries() -> Vec<(shampoo4::quant::BufferRole, shampoo4::quant::CodecSpec)> {
    use shampoo4::quant::{BufferRole, CodecSpec, Mapping};
    vec![
        (BufferRole::Momentum, CodecSpec::parse("q4-dt", Mapping::Dt).unwrap()),
        (BufferRole::SecondMoment, CodecSpec::parse("q8-dt", Mapping::Dt).unwrap()),
    ]
}

#[test]
fn mixed_policy_trains_checkpoints_and_resumes_bit_identically() {
    // the acceptance run: m=q4,v=q8 AdamW under q4-eigenvector Shampoo must
    // train, checkpoint, and resume on the exact trajectory of an
    // uninterrupted run — per-buffer codec bytes persist verbatim
    let rt = backend();
    let dir = std::env::temp_dir().join("shampoo4_policy_resume");
    let ckpt = dir.join("ck.bin");
    let mut cfg = base_cfg(20);
    cfg.name = "it_policy".into();
    cfg.first.kind = FirstOrderKind::AdamW;
    cfg.first.lr = 1e-3;
    cfg.quant_policy = mixed_policy_entries();
    cfg.second.update_precond_every = 4;
    cfg.second.update_invroot_every = 8;
    cfg.schedule = shampoo4::config::Schedule::Constant;

    let mut straight = Trainer::new(&rt, cfg.clone()).unwrap();
    let r_straight = straight.train(&rt, None).unwrap();
    assert!(r_straight.losses.last().unwrap().1.is_finite());

    let mut half_cfg = cfg.clone();
    half_cfg.steps = 10;
    let mut first_half = Trainer::new(&rt, half_cfg).unwrap();
    first_half.train(&rt, None).unwrap();
    first_half.save_checkpoint(&ckpt, 10).unwrap();

    let mut resumed = Trainer::new(&rt, cfg).unwrap();
    assert_eq!(resumed.load_checkpoint(&ckpt).unwrap(), 10);
    resumed.train(&rt, None).unwrap();
    let bits = |v: &[Vec<f32>]| -> Vec<Vec<u32>> {
        v.iter().map(|p| p.iter().map(|x| x.to_bits()).collect()).collect()
    };
    assert_eq!(
        bits(&resumed.model.params),
        bits(&straight.model.params),
        "mixed-policy resume diverged from the straight run"
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn mixed_policy_buffers_have_distinct_bitwidths() {
    // m at 4-bit must cost roughly half of v at 8-bit, and the pair must sit
    // strictly between uniform q4 and uniform q8 AdamW states
    use shampoo4::quant::{BufferRole, CodecSpec};
    let rt = backend();
    let mk = |policy: Vec<(BufferRole, CodecSpec)>, bits: u32| {
        let mut cfg = base_cfg(1);
        cfg.name = "it_policy_bytes".into();
        cfg.first.kind = FirstOrderKind::AdamW;
        cfg.first.bits = bits;
        cfg.quant_policy = policy;
        cfg.second.kind = SecondOrderKind::None;
        Trainer::new(&rt, cfg).unwrap().memory_report().first_order_bytes
    };
    let mixed = mk(mixed_policy_entries(), 32);
    let q4 = mk(Vec::new(), 4);
    let q8 = mk(Vec::new(), 8);
    assert!(mixed > q4, "mixed {mixed} vs q4 {q4}");
    assert!(mixed < q8, "mixed {mixed} vs q8 {q8}");
}

#[test]
fn policy_overrides_second_order_codec() {
    // quant.bits = 32 (dense fallback) + an eigen=q4 policy entry: the
    // policy must win — the run's second-order state shrinks to 4-bit and
    // the live sides report the policy codec
    use shampoo4::quant::{BufferRole, CodecSpec, Mapping};
    let rt = backend();
    let mk = |policy: Vec<(BufferRole, CodecSpec)>, bits: u32| {
        let mut cfg = base_cfg(1);
        cfg.name = "it_policy_so".into();
        cfg.second.quant.bits = bits;
        cfg.quant_policy = policy;
        Trainer::new(&rt, cfg).unwrap()
    };
    let eigen_q4 = CodecSpec::parse("q4-linear2", Mapping::Dt).unwrap();
    let t_policy = mk(vec![(BufferRole::EigenVectors, eigen_q4)], 32);
    let t_dense = mk(Vec::new(), 32);
    let b_policy = t_policy.memory_report().second_order_bytes;
    let b_dense = t_dense.memory_report().second_order_bytes;
    assert!(
        b_dense as f64 / b_policy as f64 > 5.5,
        "policy did not shrink second-order state: {b_policy} vs dense {b_dense}"
    );
    let block = &t_policy.second.as_ref().unwrap().blocks[0];
    assert_eq!(block.left.codec_name(), "q4-linear2");
    assert_eq!(block.right.codec_name(), "q4-linear2");
}

#[test]
fn stochastic_rounding_policy_run_is_seed_reproducible() {
    // m=q4-dt-sr: two runs with the same seed must be bit-identical (the
    // per-buffer rounding streams derive from the run seed), and the run
    // must still learn
    use shampoo4::quant::{BufferRole, CodecSpec, Mapping};
    let rt = backend();
    let mk_cfg = || {
        let mut cfg = base_cfg(25);
        cfg.name = "it_sr".into();
        cfg.first.kind = FirstOrderKind::AdamW;
        cfg.first.lr = 1e-3;
        cfg.quant_policy = vec![(
            BufferRole::Momentum,
            CodecSpec::parse("q4-dt-sr", Mapping::Dt).unwrap(),
        )];
        cfg.second.kind = SecondOrderKind::None;
        cfg
    };
    let mut a = Trainer::new(&rt, mk_cfg()).unwrap();
    let ra = a.train(&rt, None).unwrap();
    let mut b = Trainer::new(&rt, mk_cfg()).unwrap();
    b.train(&rt, None).unwrap();
    assert_eq!(a.model.params, b.model.params, "same seed must replay the SR stream");
    let first = ra.losses.first().unwrap().1;
    let last = ra.losses.last().unwrap().1;
    assert!(last.is_finite() && last < first, "SR run did not learn: {first} -> {last}");
}

#[test]
fn checkpoint_rejects_mismatched_policy() {
    // a m=q4,v=q8 checkpoint must not load into a uniform-q4 run: the
    // per-buffer codec names recorded in the header catch the mismatch
    let rt = backend();
    let dir = std::env::temp_dir().join("shampoo4_policy_mismatch");
    let ckpt = dir.join("ck.bin");
    let mut cfg = base_cfg(1);
    cfg.name = "it_policy_mismatch".into();
    cfg.first.kind = FirstOrderKind::AdamW;
    cfg.quant_policy = mixed_policy_entries();
    cfg.second.kind = SecondOrderKind::None;
    let t = Trainer::new(&rt, cfg.clone()).unwrap();
    t.save_checkpoint(&ckpt, 1).unwrap();
    let mut cfg2 = cfg;
    cfg2.quant_policy.clear();
    cfg2.first.bits = 4; // uniform q4: v buffer codec no longer matches
    let mut t2 = Trainer::new(&rt, cfg2).unwrap();
    let err = t2.load_checkpoint(&ckpt).unwrap_err().to_string();
    assert!(err.contains("codec"), "{err}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn checkpoint_rejects_mismatched_first_order_codec() {
    // a 4-bit-states checkpoint must not silently load into an fp32 run
    let rt = backend();
    let dir = std::env::temp_dir().join("shampoo4_fo_codec_mismatch");
    let ckpt = dir.join("ck.bin");
    let mut cfg = base_cfg(1);
    cfg.name = "it_fo_codec".into();
    cfg.first.kind = FirstOrderKind::AdamW;
    cfg.first.bits = 4;
    cfg.second.kind = SecondOrderKind::None;
    let t = Trainer::new(&rt, cfg.clone()).unwrap();
    t.save_checkpoint(&ckpt, 1).unwrap();
    let mut cfg2 = cfg;
    cfg2.first.bits = 32;
    let mut t2 = Trainer::new(&rt, cfg2).unwrap();
    let err = t2.load_checkpoint(&ckpt).unwrap_err().to_string();
    assert!(err.contains("codec"), "{err}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn checkpoint_rejects_mismatched_optimizer() {
    let rt = backend();
    let dir = std::env::temp_dir().join("shampoo4_ckpt_opt_test");
    let ckpt = dir.join("ck.bin");
    let mut cfg = base_cfg(1);
    cfg.name = "it_ckpt_opt".into();
    cfg.second.kind = SecondOrderKind::None;
    let t = Trainer::new(&rt, cfg.clone()).unwrap();
    t.save_checkpoint(&ckpt, 1).unwrap(); // SGDM state
    let mut cfg2 = cfg;
    cfg2.first.kind = FirstOrderKind::AdamW;
    let mut t2 = Trainer::new(&rt, cfg2).unwrap();
    let err = t2.load_checkpoint(&ckpt).unwrap_err().to_string();
    assert!(err.contains("SGDM"), "{err}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn checkpoint_rejects_wrong_model() {
    let rt = backend();
    let dir = std::env::temp_dir().join("shampoo4_ckpt_test2");
    let ckpt = dir.join("ck.bin");
    let mut cfg = base_cfg(1);
    cfg.name = "it_ckpt2".into();
    cfg.second.kind = SecondOrderKind::None;
    let t = Trainer::new(&rt, cfg).unwrap();
    t.save_checkpoint(&ckpt, 1).unwrap();
    let mut cfg2 = base_cfg(1);
    cfg2.model = "tlm_tiny".into();
    let mut t2 = Trainer::new(&rt, cfg2).unwrap();
    assert!(t2.load_checkpoint(&ckpt).is_err());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn native_adamw_matches_artifact_version() {
    let rt = backend();
    let n = 4096;
    let mut rng = shampoo4::util::rng::Rng::new(11);
    let p0 = rng.normal_vec(n);
    let m0 = rng.normal_vec(n);
    let v0: Vec<f32> = rng.normal_vec(n).iter().map(|x| x * x * 0.01).collect();
    let g = rng.normal_vec(n);
    let (lr, b1, b2, eps, wd, step) = (1e-3f32, 0.9f32, 0.999f32, 1e-8f32, 0.01f32, 7u64);

    // artifact
    let outs = rt
        .execute(
            "adamw_update_4096",
            &[
                HostTensor::f32(&[n], p0.clone()),
                HostTensor::f32(&[n], m0.clone()),
                HostTensor::f32(&[n], v0.clone()),
                HostTensor::f32(&[n], g.clone()),
                HostTensor::scalar_f32(step as f32),
                HostTensor::scalar_f32(lr),
                HostTensor::scalar_f32(b1),
                HostTensor::scalar_f32(b2),
                HostTensor::scalar_f32(eps),
                HostTensor::scalar_f32(wd),
            ],
        )
        .unwrap();
    let p_art = outs[0].as_f32().unwrap();

    // native: the artifact computes ONE update with the given (m, v) and
    // bias-correction at `step`; recreate elementwise.
    let mut p_nat = p0.clone();
    let mut m = m0.clone();
    let mut v = v0.clone();
    let t = step as f32;
    for i in 0..n {
        m[i] = b1 * m[i] + (1.0 - b1) * g[i];
        v[i] = b2 * v[i] + (1.0 - b2) * g[i] * g[i];
        let mh = m[i] / (1.0 - b1.powf(t));
        let vh = v[i] / (1.0 - b2.powf(t));
        p_nat[i] -= lr * (mh / (vh.sqrt() + eps) + wd * p_nat[i]);
    }
    for i in 0..n {
        assert!(
            (p_nat[i] - p_art[i]).abs() < 1e-5,
            "elem {i}: native {} vs artifact {}",
            p_nat[i],
            p_art[i]
        );
    }
    // and the Trainer's optimizer implements exactly this formula (step=1)
    let mut opt = shampoo4::optim::AdamW::new(n, b1, b2, eps, wd);
    let mut p2 = p0.clone();
    opt.step(&mut p2, &g, lr);
    assert!(p2.iter().all(|x| x.is_finite()));
}

#[test]
fn naive_arm_runs_and_uses_naive_artifacts() {
    let rt = backend();
    let mut cfg = base_cfg(25);
    cfg.name = "it_naive".into();
    cfg.second.quant.quantize_eigen = false;
    let mut t = Trainer::new(&rt, cfg).unwrap();
    let res = t.train(&rt, None).unwrap();
    assert!(res.losses.last().unwrap().1 < res.losses.first().unwrap().1);
    let stats = rt.stats();
    assert!(stats.keys().any(|k| k.starts_with("pu_naive_")), "{:?}", stats.keys());
}

#[test]
fn shadow_mode_produces_error_rows() {
    let rt = backend();
    let mut cfg = base_cfg(40);
    cfg.name = "it_shadow".into();
    cfg.shadow_quant_error = true;
    let mut t = Trainer::new(&rt, cfg).unwrap();
    let res = t.train(&rt, None).unwrap();
    assert!(!res.shadow_rows.is_empty());
    for r in &res.shadow_rows {
        assert!(r.nre_precond.is_finite() && r.nre_precond < 1.5, "{r:?}");
        assert!(r.nre_invroot.is_finite(), "{r:?}");
    }
}

#[test]
fn tlm_tiny_one_shampoo_cycle() {
    let rt = backend();
    let mut cfg = base_cfg(12);
    cfg.name = "it_tlm".into();
    cfg.model = "tlm_tiny".into();
    cfg.first.kind = FirstOrderKind::AdamW;
    cfg.first.lr = 2e-3;
    cfg.second.update_precond_every = 5;
    cfg.second.update_invroot_every = 10;
    let mut t = Trainer::new(&rt, cfg).unwrap();
    let res = t.train(&rt, None).unwrap();
    assert!(res.final_eval.unwrap().loss.is_finite());
    assert_eq!(res.host_fallbacks, 0);
}

#[test]
fn tlm_loss_decreases_from_uniform() {
    // ln(vocab) = ln 256 ≈ 5.55 at init; a few AdamW steps must move it down
    let rt = backend();
    let mut cfg = base_cfg(15);
    cfg.name = "it_tlm_learns".into();
    cfg.model = "tlm_tiny".into();
    cfg.first.kind = FirstOrderKind::AdamW;
    cfg.first.lr = 2e-3;
    cfg.first.weight_decay = 0.05;
    cfg.second.kind = SecondOrderKind::None;
    cfg.schedule = shampoo4::config::Schedule::Constant;
    cfg.log_every = 1;
    let mut t = Trainer::new(&rt, cfg).unwrap();
    let res = t.train(&rt, None).unwrap();
    let first = res.losses.first().unwrap().1;
    let last = res.losses.last().unwrap().1;
    assert!(first > 4.5 && first < 7.0, "init loss {first} should be near ln(256)");
    assert!(last < first, "loss {first} -> {last}");
}

#[test]
fn pjrt_backend_is_feature_gated() {
    // without the feature the name resolves to a helpful error, not a panic
    let err = shampoo4::runtime::backend_by_name("pjrt", std::path::Path::new("artifacts"));
    #[cfg(not(feature = "pjrt"))]
    assert!(err.is_err());
    #[cfg(feature = "pjrt")]
    let _ = err; // with the feature, construction depends on artifacts/
}
