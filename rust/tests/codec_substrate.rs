//! StateCodec substrate coverage (property + structural tests), one suite
//! for every shipped codec:
//!  * encode→decode error bounded by the codec's resolution (codebook gap ·
//!    block absmax for quantized codecs; 0 for fp32; 2^-8 relative for bf16);
//!  * `state_bytes(len)` equals the serialized byte length for odd lengths
//!    and block sizes (including empty and partial trailing blocks);
//!  * serialize→deserialize round-trip is exact: the encoded bytes ARE the
//!    checkpoint payload, and re-decoding through a registry-resolved codec
//!    is bit-identical.

use std::sync::Arc;

use shampoo4::quant::{
    codec_by_name, codec_for, packed_len, BlockQuant, Mapping, StateCodec,
    StochasticRound,
};
use shampoo4::util::prop;

fn all_codecs() -> Vec<Arc<dyn StateCodec>> {
    vec![
        codec_for(32, Mapping::Dt),      // Fp32
        codec_for(16, Mapping::Dt),      // Bf16
        codec_for(8, Mapping::Dt),       // Q8
        codec_for(8, Mapping::Linear2),
        codec_for(4, Mapping::Linear2),  // Q4Linear2
        codec_for(4, Mapping::Dt),       // Q4Dt
        codec_for(3, Mapping::Dt),
        Arc::new(StochasticRound::new(Mapping::Linear2, 4, 11)), // q4-linear2-sr
    ]
}

#[test]
fn encode_decode_error_bounded_by_resolution() {
    for codec in all_codecs() {
        prop::check(&format!("codec {} roundtrip bound", codec.name()), 10, |rng| {
            let n = 1 + rng.below(300);
            let x: Vec<f32> = (0..n).map(|_| rng.normal_f32() * 0.8).collect();
            let e = codec.encode(&x);
            let d = codec.decode(&e);
            if d.len() != n {
                return Err(format!("decoded {} elems, expected {n}", d.len()));
            }
            // quantized codecs scale per block of 64; dense codecs are
            // covered by the same bound since |x| <= block absmax
            for (b, chunk) in x.chunks(64).enumerate() {
                let absmax = chunk.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
                let bound = codec.resolution(absmax);
                for (i, (&xv, &dv)) in chunk.iter().zip(&d[b * 64..]).enumerate() {
                    if (xv - dv).abs() > bound {
                        return Err(format!(
                            "{} block {b} elem {i}: {xv} vs {dv}, bound {bound}",
                            codec.name()
                        ));
                    }
                }
            }
            Ok(())
        });
    }
}

#[test]
fn state_bytes_matches_serialized_length() {
    for codec in all_codecs() {
        for n in [0usize, 1, 7, 63, 64, 65, 127, 128, 1000] {
            let x: Vec<f32> = (0..n).map(|i| (i as f32 * 0.37).sin()).collect();
            let e = codec.encode(&x);
            assert_eq!(e.len, n, "{}: encoded len", codec.name());
            assert_eq!(
                e.bytes.len(),
                codec.state_bytes(n),
                "{}: state_bytes({n})",
                codec.name()
            );
        }
    }
}

#[test]
fn state_bytes_matches_planner_arithmetic() {
    // the Table 13 planner's per-element model and the live codec agree
    let q4 = codec_for(4, Mapping::Dt);
    let q8 = codec_for(8, Mapping::Dt);
    for n in [64usize, 1000, 1 << 20] {
        assert_eq!(q4.state_bytes(n), packed_len(n, 4) + n.div_ceil(64) * 4);
        assert_eq!(q8.state_bytes(n), packed_len(n, 8) + n.div_ceil(64) * 4);
    }
}

#[test]
fn serialize_deserialize_roundtrip_is_exact() {
    for codec in all_codecs() {
        prop::check(&format!("codec {} serialize exact", codec.name()), 10, |rng| {
            let n = 1 + rng.below(400);
            let x: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
            let e = codec.encode(&x);
            let d1 = codec.decode(&e);
            // "persist" the raw bytes and reload through the name registry
            let reloaded = codec_by_name(&codec.name()).map_err(|e| e.to_string())?;
            let e2 = shampoo4::quant::EncodedVec { bytes: e.bytes.clone(), len: e.len };
            let d2 = reloaded.decode(&e2);
            let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<u32>>();
            if bits(&d1) != bits(&d2) {
                return Err(format!("{}: reload not bit-identical", codec.name()));
            }
            Ok(())
        });
    }
}

#[test]
fn odd_block_sizes_roundtrip() {
    for block in [1usize, 7, 33, 64, 100] {
        let codec = BlockQuant::with_block(Mapping::Linear2, 4, block);
        for n in [1usize, block - 1, block, block + 1, 3 * block + 2] {
            if n == 0 {
                continue;
            }
            let x: Vec<f32> = (0..n).map(|i| ((i * 7 % 13) as f32 - 6.0) * 0.1).collect();
            let e = codec.encode(&x);
            assert_eq!(e.bytes.len(), codec.state_bytes(n), "block {block} n {n}");
            let d = codec.decode(&e);
            assert_eq!(d.len(), n);
            let bound = codec.resolution(0.7);
            for (a, b) in x.iter().zip(&d) {
                assert!((a - b).abs() <= bound, "block {block} n {n}: {a} vs {b}");
            }
        }
    }
}

#[test]
fn stochastic_rounding_is_unbiased_over_seeds() {
    // the SOLO property: E[decode(encode(x))] = x inside the codebook range,
    // so the mean signed error over many independent rounding streams must
    // vanish — this is what keeps low-bit EMA dynamics from drifting
    let mut rng = shampoo4::util::rng::Rng::new(3);
    let n = 256usize;
    let x: Vec<f32> = (0..n).map(|_| rng.normal_f32() * 0.5).collect();
    let seeds = 400u64;
    let mut err_sum = vec![0.0f64; n];
    for seed in 0..seeds {
        let c = StochasticRound::new(Mapping::Linear2, 4, seed);
        let d = c.decode(&c.encode(&x));
        for i in 0..n {
            err_sum[i] += (d[i] - x[i]) as f64;
        }
    }
    let overall: f64 = err_sum.iter().sum::<f64>() / (seeds as f64 * n as f64);
    assert!(overall.abs() < 4e-3, "mean signed error {overall} did not vanish");
    // per-element means stay small too (each element has 400 samples)
    let mut worst = 0.0f64;
    for e in &err_sum {
        worst = worst.max((e / seeds as f64).abs());
    }
    assert!(worst < 0.08, "worst per-element mean error {worst}");
}

#[test]
fn stochastic_rounding_is_reproducible_for_fixed_seed() {
    // fixed seed ⇒ the exact same rounding stream, call after call — the
    // reproducibility contract the policy layer's per-buffer seeding rests on
    let mut rng = shampoo4::util::rng::Rng::new(4);
    let x: Vec<f32> = (0..300).map(|_| rng.normal_f32()).collect();
    let a = StochasticRound::new(Mapping::Dt, 4, 123);
    let b = StochasticRound::new(Mapping::Dt, 4, 123);
    for call in 0..4 {
        let (ea, eb) = (a.encode(&x), b.encode(&x));
        assert_eq!(ea.bytes, eb.bytes, "call {call} diverged under the same seed");
    }
    // and the registry round-trips the name with a deterministic decode
    let restored = codec_by_name("q4-dt-sr").unwrap();
    let e = a.encode(&x);
    let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<u32>>();
    assert_eq!(bits(&a.decode(&e)), bits(&restored.decode(&e)));
}

#[test]
fn fp32_codec_is_bitwise_identity() {
    let c = codec_for(32, Mapping::Dt);
    let x = vec![0.0f32, -0.0, 1.5e-42, f32::MAX, -f32::MIN_POSITIVE, 3.14159];
    let d = c.decode(&c.encode(&x));
    assert_eq!(
        x.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
        d.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
    );
}
