//! StateCodec substrate coverage (property + structural tests), one suite
//! for every shipped codec:
//!  * encode→decode error bounded by the codec's resolution (codebook gap ·
//!    block absmax for quantized codecs; 0 for fp32; 2^-8 relative for bf16);
//!  * `state_bytes(len)` equals the serialized byte length for odd lengths
//!    and block sizes (including empty and partial trailing blocks);
//!  * serialize→deserialize round-trip is exact: the encoded bytes ARE the
//!    checkpoint payload, and re-decoding through a registry-resolved codec
//!    is bit-identical;
//!  * adversarial inputs: non-finite values are typed `try_encode` errors on
//!    every quantized codec, and corrupted checkpoint payloads are rejected
//!    by `validate_payload` at ingest instead of silently decoding to junk;
//!  * non-multiple-of-64 matrix orders round-trip through
//!    `encode_matrix`/`decode_matrix` with column blocking intact;
//!  * under `--features simd` the dispatcher arms stay bit-identical to the
//!    scalar reference all the way through the codec serialization layer.

use std::sync::Arc;

use shampoo4::quant::{
    codec_by_name, codec_for, packed_len, BlockQuant, EncodedVec, Mapping, StateBuf,
    StateCodec, StochasticRound,
};
use shampoo4::util::prop;

fn all_codecs() -> Vec<Arc<dyn StateCodec>> {
    vec![
        codec_for(32, Mapping::Dt),      // Fp32
        codec_for(16, Mapping::Dt),      // Bf16
        codec_for(8, Mapping::Dt),       // Q8
        codec_for(8, Mapping::Linear2),
        codec_for(4, Mapping::Linear2),  // Q4Linear2
        codec_for(4, Mapping::Dt),       // Q4Dt
        codec_for(3, Mapping::Dt),
        Arc::new(StochasticRound::new(Mapping::Linear2, 4, 11)), // q4-linear2-sr
    ]
}

#[test]
fn encode_decode_error_bounded_by_resolution() {
    for codec in all_codecs() {
        prop::check(&format!("codec {} roundtrip bound", codec.name()), 10, |rng| {
            let n = 1 + rng.below(300);
            let x: Vec<f32> = (0..n).map(|_| rng.normal_f32() * 0.8).collect();
            let e = codec.encode(&x);
            let d = codec.decode(&e);
            if d.len() != n {
                return Err(format!("decoded {} elems, expected {n}", d.len()));
            }
            // quantized codecs scale per block of 64; dense codecs are
            // covered by the same bound since |x| <= block absmax
            for (b, chunk) in x.chunks(64).enumerate() {
                let absmax = chunk.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
                let bound = codec.resolution(absmax);
                for (i, (&xv, &dv)) in chunk.iter().zip(&d[b * 64..]).enumerate() {
                    if (xv - dv).abs() > bound {
                        return Err(format!(
                            "{} block {b} elem {i}: {xv} vs {dv}, bound {bound}",
                            codec.name()
                        ));
                    }
                }
            }
            Ok(())
        });
    }
}

#[test]
fn state_bytes_matches_serialized_length() {
    for codec in all_codecs() {
        for n in [0usize, 1, 7, 63, 64, 65, 127, 128, 1000] {
            let x: Vec<f32> = (0..n).map(|i| (i as f32 * 0.37).sin()).collect();
            let e = codec.encode(&x);
            assert_eq!(e.len, n, "{}: encoded len", codec.name());
            assert_eq!(
                e.bytes.len(),
                codec.state_bytes(n),
                "{}: state_bytes({n})",
                codec.name()
            );
        }
    }
}

#[test]
fn state_bytes_matches_planner_arithmetic() {
    // the Table 13 planner's per-element model and the live codec agree
    let q4 = codec_for(4, Mapping::Dt);
    let q8 = codec_for(8, Mapping::Dt);
    for n in [64usize, 1000, 1 << 20] {
        assert_eq!(q4.state_bytes(n), packed_len(n, 4) + n.div_ceil(64) * 4);
        assert_eq!(q8.state_bytes(n), packed_len(n, 8) + n.div_ceil(64) * 4);
    }
}

#[test]
fn serialize_deserialize_roundtrip_is_exact() {
    for codec in all_codecs() {
        prop::check(&format!("codec {} serialize exact", codec.name()), 10, |rng| {
            let n = 1 + rng.below(400);
            let x: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
            let e = codec.encode(&x);
            let d1 = codec.decode(&e);
            // "persist" the raw bytes and reload through the name registry
            let reloaded = codec_by_name(&codec.name()).map_err(|e| e.to_string())?;
            let e2 = shampoo4::quant::EncodedVec { bytes: e.bytes.clone(), len: e.len };
            let d2 = reloaded.decode(&e2);
            let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<u32>>();
            if bits(&d1) != bits(&d2) {
                return Err(format!("{}: reload not bit-identical", codec.name()));
            }
            Ok(())
        });
    }
}

#[test]
fn odd_block_sizes_roundtrip() {
    for block in [1usize, 7, 33, 64, 100] {
        let codec = BlockQuant::with_block(Mapping::Linear2, 4, block);
        for n in [1usize, block - 1, block, block + 1, 3 * block + 2] {
            if n == 0 {
                continue;
            }
            let x: Vec<f32> = (0..n).map(|i| ((i * 7 % 13) as f32 - 6.0) * 0.1).collect();
            let e = codec.encode(&x);
            assert_eq!(e.bytes.len(), codec.state_bytes(n), "block {block} n {n}");
            let d = codec.decode(&e);
            assert_eq!(d.len(), n);
            let bound = codec.resolution(0.7);
            for (a, b) in x.iter().zip(&d) {
                assert!((a - b).abs() <= bound, "block {block} n {n}: {a} vs {b}");
            }
        }
    }
}

#[test]
fn stochastic_rounding_is_unbiased_over_seeds() {
    // the SOLO property: E[decode(encode(x))] = x inside the codebook range,
    // so the mean signed error over many independent rounding streams must
    // vanish — this is what keeps low-bit EMA dynamics from drifting
    let mut rng = shampoo4::util::rng::Rng::new(3);
    let n = 256usize;
    let x: Vec<f32> = (0..n).map(|_| rng.normal_f32() * 0.5).collect();
    let seeds = 400u64;
    let mut err_sum = vec![0.0f64; n];
    for seed in 0..seeds {
        let c = StochasticRound::new(Mapping::Linear2, 4, seed);
        let d = c.decode(&c.encode(&x));
        for i in 0..n {
            err_sum[i] += (d[i] - x[i]) as f64;
        }
    }
    let overall: f64 = err_sum.iter().sum::<f64>() / (seeds as f64 * n as f64);
    assert!(overall.abs() < 4e-3, "mean signed error {overall} did not vanish");
    // per-element means stay small too (each element has 400 samples)
    let mut worst = 0.0f64;
    for e in &err_sum {
        worst = worst.max((e / seeds as f64).abs());
    }
    assert!(worst < 0.08, "worst per-element mean error {worst}");
}

#[test]
fn stochastic_rounding_is_reproducible_for_fixed_seed() {
    // fixed seed ⇒ the exact same rounding stream, call after call — the
    // reproducibility contract the policy layer's per-buffer seeding rests on
    let mut rng = shampoo4::util::rng::Rng::new(4);
    let x: Vec<f32> = (0..300).map(|_| rng.normal_f32()).collect();
    let a = StochasticRound::new(Mapping::Dt, 4, 123);
    let b = StochasticRound::new(Mapping::Dt, 4, 123);
    for call in 0..4 {
        let (ea, eb) = (a.encode(&x), b.encode(&x));
        assert_eq!(ea.bytes, eb.bytes, "call {call} diverged under the same seed");
    }
    // and the registry round-trips the name with a deterministic decode
    let restored = codec_by_name("q4-dt-sr").unwrap();
    let e = a.encode(&x);
    let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<u32>>();
    assert_eq!(bits(&a.decode(&e)), bits(&restored.decode(&e)));
}

#[test]
fn try_encode_rejects_non_finite_on_quantized_codecs() {
    let mut base: Vec<f32> = (0..130).map(|i| (i as f32 * 0.1).sin()).collect();
    for bad in [f32::NAN, f32::INFINITY, f32::NEG_INFINITY] {
        base[77] = bad;
        for codec in all_codecs() {
            let r = codec.try_encode(&base);
            if codec.bits() >= 16 {
                // dense codecs store non-finite values verbatim
                let e = r.expect("dense codecs never fail");
                assert_eq!(e.len, base.len());
            } else {
                // NaN would be dropped by the absmax fold; ±Inf collapses
                // the block scale — both must be refused, not absorbed
                let err = r.expect_err(&format!("{} accepted {bad}", codec.name()));
                assert!(err.to_string().contains("non-finite"), "{err}");
            }
        }
    }
}

#[test]
fn adversarial_finite_floats_stay_finite_through_quantized_codecs() {
    // zeros, signed zeros, subnormal-scale, and full-range magnitudes: the
    // scale path must never overflow or emit NaN for finite input
    let x = vec![
        0.0f32,
        -0.0,
        f32::MIN_POSITIVE,
        -f32::MIN_POSITIVE,
        1.5e-42, // subnormal
        f32::MAX,
        -f32::MAX,
        1e-30,
    ];
    for codec in all_codecs() {
        if codec.bits() >= 16 {
            continue; // bf16 legitimately rounds f32::MAX to +Inf
        }
        let e = codec.try_encode(&x).unwrap_or_else(|err| panic!("{}: {err}", codec.name()));
        codec.validate_payload(&e).unwrap();
        let d = codec.decode(&e);
        assert_eq!(d.len(), x.len());
        for (i, v) in d.iter().enumerate() {
            assert!(v.is_finite(), "{} elem {i} decoded to {v}", codec.name());
        }
    }
}

#[test]
fn validate_payload_accepts_every_valid_payload() {
    for codec in all_codecs() {
        for n in [0usize, 1, 63, 64, 65, 200] {
            let x: Vec<f32> = (0..n).map(|i| (i as f32 * 0.37).cos()).collect();
            let e = codec.encode(&x);
            codec
                .validate_payload(&e)
                .unwrap_or_else(|err| panic!("{} n={n}: {err}", codec.name()));
        }
    }
}

#[test]
fn validate_payload_rejects_corrupt_checkpoint_payloads() {
    let q4 = codec_for(4, Mapping::Linear2);
    let x: Vec<f32> = (0..130).map(|i| (i as f32 * 0.2).sin()).collect();
    let e = q4.encode(&x);
    let split = packed_len(130, 4);

    // a NaN scale would silently poison its whole block on decode
    let mut bad = e.clone();
    bad.bytes[split..split + 4].copy_from_slice(&f32::NAN.to_le_bytes());
    let err = q4.validate_payload(&bad).unwrap_err().to_string();
    assert!(err.contains("non-finite scale"), "{err}");

    // truncated payload
    let mut short = e.clone();
    short.bytes.pop();
    assert!(q4.validate_payload(&short).is_err(), "truncated payload accepted");

    // ragged scale region (scale bytes not a whole number of f32s)
    let mut ragged = e.clone();
    ragged.bytes.extend_from_slice(&[0, 0]);
    assert!(q4.validate_payload(&ragged).is_err(), "ragged payload accepted");

    // empty payload claiming a non-empty buffer
    let empty = EncodedVec { bytes: vec![], len: 130 };
    assert!(q4.validate_payload(&empty).is_err(), "empty payload accepted");

    // the stochastic wrapper delegates to the same checks
    let sr = StochasticRound::new(Mapping::Linear2, 4, 5);
    assert!(sr.validate_payload(&bad).is_err());
}

#[test]
fn statebuf_restore_rejects_corrupt_checkpoint_payloads() {
    let mut b = StateBuf::zeros(130, codec_for(4, Mapping::Dt));
    let mut snap = b.encoded().clone();
    let split = packed_len(130, 4);
    snap.bytes[split..split + 4].copy_from_slice(&f32::INFINITY.to_le_bytes());
    let err = b.restore(snap).unwrap_err().to_string();
    assert!(err.contains("non-finite scale"), "{err}");
    // the buffer keeps its original contents after a rejected restore
    assert!(b.load().iter().all(|&v| v == 0.0));
}

#[test]
fn matrix_codec_handles_non_multiple_of_64_orders() {
    // n=96 blocks at 48, n=100 at 50 (largest divisor ≤ 64); prime n=101
    // falls back to per-column chunking — all must keep the §3.3 guarantee
    // that a huge entry in one column cannot pollute any other column
    let c = BlockQuant::q4_linear2();
    for n in [96usize, 100, 101] {
        let mut a = vec![0.01f32; n * n];
        a[0] = 100.0;
        let e = c.encode_matrix(&a, n);
        assert_eq!(e.bytes.len(), c.matrix_state_bytes(n), "n={n}: matrix_state_bytes");
        c.validate_payload(&e).unwrap_or_else(|err| panic!("n={n}: {err}"));
        let d = c.decode_matrix(&e, n);
        for i in 0..n {
            for j in 1..n {
                assert!((d[i * n + j] - 0.01).abs() < 0.005, "n={n} leak at ({i},{j})");
            }
        }
        assert!(d[0] > 50.0, "n={n}: spike in column 0 lost");
    }
}

#[cfg(feature = "simd")]
#[test]
fn codec_encode_is_bit_identical_to_scalar_reference_under_simd() {
    // with --features simd, codec.encode routes through the SIMD arms; the
    // serialized payload must still match the scalar reference byte-for-byte
    // (the equivalence contract that makes the feature checkpoint-safe)
    use shampoo4::quant::{codebook, quantize_scalar};
    let mut rng = shampoo4::util::rng::Rng::new(9);
    let arms =
        [(2u32, Mapping::Dt), (3, Mapping::Dt), (4, Mapping::Linear2), (8, Mapping::Dt)];
    for (bits, mapping) in arms {
        let codec = codec_for(bits, mapping);
        for n in [1usize, 63, 64, 65, 500] {
            let x: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
            let e = codec.encode(&x);
            let q = quantize_scalar(&x, &codebook(mapping, bits), bits, 64);
            let split = packed_len(n, bits);
            assert_eq!(&e.bytes[..split], &q.packed[..], "codes bits={bits} n={n}");
            let scales: Vec<u32> = e.bytes[split..]
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes(c.try_into().unwrap()).to_bits())
                .collect();
            let want: Vec<u32> = q.scales.iter().map(|s| s.to_bits()).collect();
            assert_eq!(scales, want, "scales bits={bits} n={n}");
        }
    }
}

#[test]
fn fp32_codec_is_bitwise_identity() {
    let c = codec_for(32, Mapping::Dt);
    let x = vec![0.0f32, -0.0, 1.5e-42, f32::MAX, -f32::MIN_POSITIVE, 3.14159];
    let d = c.decode(&c.encode(&x));
    assert_eq!(
        x.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
        d.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
    );
}
