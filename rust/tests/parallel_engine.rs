//! Parallel block engine: determinism and scheduling guarantees.
//!
//! * `parallelism = N` must produce bit-identical parameters and losses to
//!   `parallelism = 1` for every second-order arm (Shampoo, CASPR, K-FAC) —
//!   the scheduler's index-ordered merge makes thread count a pure
//!   performance knob.
//! * Staggered inverse-root cohorts do the same work per T2 interval at
//!   different steps, so they are *not* bit-identical to batch PIRU, but
//!   must converge to the same quality.
//! * Cached precondition inputs must alias the optimizer state (Arc-backed
//!   tensors), not deep-copy it per step.
//! * The cross-step pipeline (`shampoo.pipeline`) must be bit-reproducible
//!   at any parallelism (deterministic barriers + double-buffer swaps),
//!   land within the stagger-style quality tolerance of the synchronous
//!   engine, and shut the persistent pool down cleanly when a background
//!   refresh fails mid-train (abort flag propagates, no hung threads).

#![allow(clippy::field_reassign_with_default)]

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};

use anyhow::Result;
use shampoo4::config::{FirstOrderKind, RunConfig, SecondOrderKind};
use shampoo4::coordinator::{TrainResult, Trainer};
use shampoo4::runtime::{Backend, ExecStats, HostBackend, HostTensor, Manifest};

fn engine_cfg(kind: SecondOrderKind, parallelism: usize, stagger: bool, steps: usize) -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.name = format!(
        "pe_{}_{parallelism}{}",
        kind.name(),
        if stagger { "_stagger" } else { "" }
    );
    cfg.model = "mlp_base".into();
    cfg.steps = steps;
    cfg.first.kind = FirstOrderKind::Sgdm;
    cfg.first.lr = 0.05;
    cfg.first.weight_decay = 5e-4;
    cfg.second.kind = kind;
    cfg.second.update_precond_every = 5;
    cfg.second.update_invroot_every = 10;
    cfg.second.parallelism = parallelism;
    cfg.second.stagger_invroots = stagger;
    cfg.eval_every = 0;
    cfg.eval_batches = 4;
    cfg.log_every = 1;
    cfg
}

fn run(cfg: RunConfig) -> (Vec<Vec<f32>>, TrainResult) {
    let rt = HostBackend::new();
    let mut t = Trainer::new(&rt, cfg).unwrap();
    let res = t.train(&rt, None).unwrap();
    (t.model.params.clone(), res)
}

/// Exact f32 bit patterns (NaN-proof equality).
fn param_bits(params: &[Vec<f32>]) -> Vec<Vec<u32>> {
    params.iter().map(|p| p.iter().map(|x| x.to_bits()).collect()).collect()
}

fn loss_bits(losses: &[(usize, f32)]) -> Vec<(usize, u32)> {
    losses.iter().map(|&(s, l)| (s, l.to_bits())).collect()
}

fn assert_bit_identical(kind: SecondOrderKind, steps: usize) {
    let (p1, r1) = run(engine_cfg(kind, 1, false, steps));
    let (p4, r4) = run(engine_cfg(kind, 4, false, steps));
    assert_eq!(
        loss_bits(&r1.losses),
        loss_bits(&r4.losses),
        "{}: losses diverge between parallelism 1 and 4",
        kind.name()
    );
    assert_eq!(
        param_bits(&p1),
        param_bits(&p4),
        "{}: parameters diverge between parallelism 1 and 4",
        kind.name()
    );
    // the run must actually have learned something for the comparison to
    // mean anything (guards against a silently dead second-order path)
    assert!(
        r1.losses.last().unwrap().1.is_finite(),
        "{}: training produced non-finite loss",
        kind.name()
    );
}

#[test]
fn shampoo_parallelism_is_bit_identical() {
    assert_bit_identical(SecondOrderKind::Shampoo, 22);
}

#[test]
fn caspr_parallelism_is_bit_identical() {
    assert_bit_identical(SecondOrderKind::Caspr, 22);
}

#[test]
fn kfac_parallelism_is_bit_identical() {
    assert_bit_identical(SecondOrderKind::KFac, 12);
}

#[test]
fn staggered_parallelism_is_bit_identical_too() {
    // determinism must hold under the staggered schedule as well
    let (p1, r1) = run(engine_cfg(SecondOrderKind::Shampoo, 1, true, 22));
    let (p4, r4) = run(engine_cfg(SecondOrderKind::Shampoo, 4, true, 22));
    assert_eq!(loss_bits(&r1.losses), loss_bits(&r4.losses));
    assert_eq!(param_bits(&p1), param_bits(&p4));
}

#[test]
fn staggered_piru_matches_batch_quality() {
    let steps = 60;
    let (_, batch) = run(engine_cfg(SecondOrderKind::Shampoo, 2, false, steps));
    let (_, stag) = run(engine_cfg(SecondOrderKind::Shampoo, 2, true, steps));
    // staggered PIRU must do real inverse-root work...
    assert!(stag.timings.piru_secs > 0.0, "staggered run never ran PIRU");
    // ...and land at the same quality as the batch schedule
    let eb = batch.final_eval.as_ref().unwrap();
    let es = stag.final_eval.as_ref().unwrap();
    assert!(eb.accuracy.unwrap() > 0.3, "batch arm did not learn");
    assert!(es.accuracy.unwrap() > 0.3, "staggered arm did not learn");
    assert!(
        (eb.loss - es.loss).abs() < 0.5,
        "staggered eval loss {} vs batch {} drifted apart",
        es.loss,
        eb.loss
    );
}

#[test]
fn timings_account_every_stage() {
    let (_, res) = run(engine_cfg(SecondOrderKind::Shampoo, 2, false, 20));
    let tm = &res.timings;
    assert_eq!(tm.steps, 20);
    assert!(tm.model_step_secs > 0.0);
    assert!(tm.pu_secs > 0.0, "T1=5 over 20 steps must hit PU");
    assert!(tm.piru_secs > 0.0, "T2=10 over 20 steps must hit PIRU");
    assert!(tm.precond_secs > 0.0);
    assert!(tm.first_order_secs > 0.0);
    assert!(tm.max_step_secs > 0.0 && tm.max_step_index >= 1);
    assert!(tm.second_order_secs() <= res.wall_secs);
}

fn pipeline_cfg(parallelism: usize, pipeline: bool, steps: usize) -> RunConfig {
    let mut cfg = engine_cfg(SecondOrderKind::Shampoo, parallelism, false, steps);
    cfg.name = format!("pipe_{parallelism}_{pipeline}");
    cfg.second.pipeline = pipeline;
    cfg.second.pipeline_max_lag = 3;
    cfg
}

#[test]
fn pipeline_off_is_the_default_and_engine_unchanged() {
    // `--pipeline` off must leave the PR 2 engine exactly as it was: the
    // default config does not pipeline, and a pipeline=false run is the
    // same code path (and therefore bit-identical) at any parallelism —
    // covered by the assert_bit_identical tests above against this default
    let cfg = RunConfig::default();
    assert!(!cfg.second.pipeline);
    let (p_off, r_off) = run(pipeline_cfg(2, false, 22));
    let (p_base, r_base) = run(engine_cfg(SecondOrderKind::Shampoo, 2, false, 22));
    assert_eq!(loss_bits(&r_off.losses), loss_bits(&r_base.losses));
    assert_eq!(param_bits(&p_off), param_bits(&p_base));
    assert_eq!(r_off.timings.pipeline_refreshes, 0);
}

#[test]
fn pipelined_runs_are_bit_reproducible_across_parallelism() {
    // barriers fire at deterministic steps and swaps happen in block-index
    // order, so the pipelined trajectory is a pure function of the config —
    // worker count must not change a single bit
    let (p1, r1) = run(pipeline_cfg(1, true, 22));
    let (p4, r4) = run(pipeline_cfg(4, true, 22));
    assert!(r1.timings.pipeline_refreshes > 0, "pipeline never submitted a refresh");
    assert_eq!(r1.timings.pipeline_refreshes, r4.timings.pipeline_refreshes);
    assert_eq!(loss_bits(&r1.losses), loss_bits(&r4.losses));
    assert_eq!(param_bits(&p1), param_bits(&p4));
}

#[test]
fn pipelined_quality_matches_sync_engine() {
    // the pipeline trades bounded staleness (preconditioning with roots up
    // to max_lag steps old) for overlap — same tolerance regime as the
    // staggered schedule, so quality must match the synchronous engine
    let steps = 60;
    let (_, sync) = run(pipeline_cfg(2, false, steps));
    let (_, pipe) = run(pipeline_cfg(2, true, steps));
    assert!(pipe.timings.pipeline_refreshes > 0, "pipeline never ran");
    assert!(pipe.timings.pu_secs > 0.0, "background PU time was never accounted");
    assert!(pipe.timings.piru_secs > 0.0, "background PIRU time was never accounted");
    let es = sync.final_eval.as_ref().unwrap();
    let ep = pipe.final_eval.as_ref().unwrap();
    assert!(es.accuracy.unwrap() > 0.3, "sync arm did not learn");
    assert!(ep.accuracy.unwrap() > 0.3, "pipelined arm did not learn");
    assert!(
        (es.loss - ep.loss).abs() < 0.5,
        "pipelined eval loss {} vs sync {} drifted apart",
        ep.loss,
        es.loss
    );
}

#[test]
fn adaptive_pipeline_completes_early_and_matches_quality() {
    // `pipeline_adaptive`: with a lag bound far beyond the refresh cadence,
    // finished refreshes must swap in at the next step's barrier (the pool
    // goes idle between refreshes on this small model) instead of waiting
    // out the bound — and quality stays in the sync engine's regime
    let steps = 60;
    let mut cfg = pipeline_cfg(4, true, steps);
    cfg.name = "pipe_adaptive".into();
    // long refresh intervals + a generous lag bound: the pool has many
    // cheap steps to finish each refresh, so only the adaptive barrier can
    // be the thing that swaps it in early
    cfg.second.update_precond_every = 10;
    cfg.second.update_invroot_every = 20;
    cfg.second.pipeline_max_lag = 50;
    cfg.second.pipeline_adaptive = true;
    let (_, adaptive) = run(cfg);
    assert!(adaptive.timings.pipeline_refreshes > 0, "pipeline never ran");
    assert!(
        adaptive.timings.pipeline_early_completes > 0,
        "adaptive barrier never completed a refresh early (refreshes: {})",
        adaptive.timings.pipeline_refreshes
    );
    assert!(
        adaptive.timings.pipeline_early_completes <= adaptive.timings.pipeline_refreshes,
        "more early completions than refreshes"
    );
    let mut sync_cfg = pipeline_cfg(2, false, steps);
    sync_cfg.second.update_precond_every = 10;
    sync_cfg.second.update_invroot_every = 20;
    let (_, sync) = run(sync_cfg);
    let ea = adaptive.final_eval.as_ref().unwrap();
    let es = sync.final_eval.as_ref().unwrap();
    assert!(ea.accuracy.unwrap() > 0.3, "adaptive arm did not learn");
    assert!(
        (ea.loss - es.loss).abs() < 0.5,
        "adaptive eval loss {} vs sync {} drifted apart",
        ea.loss,
        es.loss
    );
}

/// HostBackend wrapper that injects a failure into the N-th execution of a
/// matching artifact — exercises the pipeline's error path from a pool
/// thread.
struct FailingBackend {
    inner: HostBackend,
    needle: &'static str,
    fail_after: usize,
    seen: AtomicUsize,
}

impl Backend for FailingBackend {
    fn platform(&self) -> String {
        "failing-host".into()
    }

    fn manifest(&self) -> &Manifest {
        self.inner.manifest()
    }

    fn execute(&self, name: &str, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        if name.contains(self.needle)
            && self.seen.fetch_add(1, Ordering::SeqCst) >= self.fail_after
        {
            anyhow::bail!("injected failure on {name}");
        }
        self.inner.execute(name, inputs)
    }

    fn stats(&self) -> HashMap<String, ExecStats> {
        self.inner.stats()
    }
}

#[test]
fn pipeline_mid_train_error_shuts_down_cleanly() {
    // a background refresh fails on a pool thread: the error must surface
    // from `train` (lowest-index block wins), the abort flag must stop the
    // remaining jobs, and dropping the trainer must join every pool thread
    // — if anything hung, this test would deadlock on drop
    let rt = FailingBackend {
        inner: HostBackend::new(),
        needle: "gram_", // PU statistics: executed inside the background jobs
        fail_after: 3,
        seen: AtomicUsize::new(0),
    };
    let mut t = Trainer::new(&rt, pipeline_cfg(2, true, 30)).unwrap();
    let err = t.train(&rt, None).expect_err("injected failure must fail the run");
    let chain = format!("{err:#}");
    assert!(
        chain.contains("injected failure") || chain.contains("pipelined refresh"),
        "unexpected error chain: {chain}"
    );
    drop(t); // graceful pool shutdown: joins every worker, no hang
}

#[test]
fn precondition_inputs_share_state_buffers() {
    // the §Perf satellite: per-step precondition must alias cached state via
    // Arc, not clone it — O(1) tensor clones are the contract the parallel
    // engine's task submissions rely on
    let t = HostTensor::f32(&[64, 64], vec![0.5; 64 * 64]);
    let submitted: Vec<HostTensor> = (0..8).map(|_| t.clone()).collect();
    for s in &submitted {
        assert!(t.shares_buffer(s), "HostTensor::clone must share, not copy");
    }
}
