//! Fault-injection harness for the v1 streaming checkpoint format.
//!
//! Saves a real training checkpoint, then attacks it byte by byte:
//! truncation at every frame boundary (and one byte either side), a
//! bit-flip inside every payload frame (first and last byte), and
//! bit-flips in both header lines. Every mutation must be rejected with a
//! typed error naming the corrupt buffer / offset / header — never a
//! silent zero-decode — and a failed load must leave the prior trainer
//! state (parameters, first-order buffers + counters, second-order sides)
//! bit-for-bit untouched.

#![allow(clippy::field_reassign_with_default)]

use std::fs;
use std::path::PathBuf;

use shampoo4::config::{FirstOrderKind, RunConfig, SecondOrderKind};
use shampoo4::coordinator::{CheckpointFile, Trainer};
use shampoo4::runtime::HostBackend;

fn tdir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("shampoo4_faults_{name}"));
    let _ = fs::remove_dir_all(&dir);
    fs::create_dir_all(&dir).unwrap();
    dir
}

fn cfg() -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.name = "it_faults".into();
    cfg.model = "mlp_base".into();
    cfg.steps = 10;
    cfg.first.kind = FirstOrderKind::Sgdm;
    cfg.first.lr = 0.05;
    cfg.second.kind = SecondOrderKind::Shampoo;
    cfg.second.update_precond_every = 4;
    cfg.second.update_invroot_every = 8;
    cfg.eval_every = 0;
    cfg.eval_batches = 0;
    cfg.log_every = 5;
    cfg
}

/// Bit-exact fingerprint of everything a checkpoint load may touch.
type Fingerprint = (Vec<Vec<u32>>, Vec<(String, Vec<u8>, usize)>, Vec<f64>, Vec<Vec<u8>>, usize);

fn fingerprint(t: &Trainer) -> Fingerprint {
    let params: Vec<Vec<u32>> =
        t.model.params.iter().map(|p| p.iter().map(|x| x.to_bits()).collect()).collect();
    let snap = t.first.export_state();
    let buffers: Vec<(String, Vec<u8>, usize)> =
        snap.buffers.iter().map(|(c, e)| (c.clone(), e.bytes.clone(), e.len)).collect();
    let sides: Vec<Vec<u8>> = t
        .second
        .as_ref()
        .map(|s| {
            s.blocks
                .iter()
                .flat_map(|b| [b.left.serialize(), b.right.serialize()])
                .collect()
        })
        .unwrap_or_default();
    (params, buffers, snap.counters.clone(), sides, t.model.param_count())
}

/// Overwrite the checkpoint with `mutated`, demand that loading it fails
/// with a message naming one of `must_name`, and that the failed load left
/// the victim trainer's state untouched.
fn reject(
    ckpt: &std::path::Path,
    victim: &mut Trainer,
    before: &Fingerprint,
    label: &str,
    mutated: &[u8],
    must_name: &[&str],
) {
    fs::write(ckpt, mutated).unwrap();
    let err = match victim.load_checkpoint(ckpt) {
        Ok(step) => panic!("{label}: corrupt checkpoint silently restored (step {step})"),
        Err(e) => e,
    };
    let msg = format!("{err:#}").to_lowercase();
    assert!(
        must_name.iter().any(|n| msg.contains(&n.to_lowercase())),
        "{label}: error does not name the fault (wanted one of {must_name:?}): {msg}"
    );
    assert_eq!(
        &fingerprint(victim),
        before,
        "{label}: failed load mutated trainer state"
    );
}

#[test]
fn every_injected_fault_is_rejected_and_leaves_state_untouched() {
    let rt = HostBackend::new();
    let dir = tdir("matrix");
    let ckpt = dir.join("ck.bin");

    let mut t = Trainer::new(&rt, cfg()).unwrap();
    t.train(&rt, None).unwrap();
    t.save_checkpoint(&ckpt, 10).unwrap();

    // map the file: header end + every frame's absolute [start, end)
    let view = CheckpointFile::open(&ckpt).unwrap();
    let payload = view.payload_offset();
    let manifest: Vec<(String, u64, u64)> = view
        .header
        .manifest
        .iter()
        .map(|e| (e.role.clone(), e.offset, e.bytes))
        .collect();
    assert!(
        manifest.iter().any(|(r, _, _)| r.starts_with("so.")),
        "run must produce second-order frames for the harness to attack"
    );
    drop(view);
    let clean = fs::read(&ckpt).unwrap();
    let full = clean.len() as u64;

    // the victim holds freshly initialized state that every failed load
    // must leave exactly alone
    let mut victim = Trainer::new(&rt, cfg()).unwrap();
    let before = fingerprint(&victim);

    // 1. truncation at every frame boundary and one byte either side
    // (the only valid length is the full file)
    let mut boundaries: Vec<u64> = manifest.iter().map(|(_, off, _)| payload + off).collect();
    boundaries.push(full);
    for b in boundaries {
        for cut in [b.saturating_sub(1), b, b + 1] {
            if cut >= full {
                continue;
            }
            reject(
                &ckpt,
                &mut victim,
                &before,
                &format!("truncate@{cut}"),
                &clean[..cut as usize],
                &["truncat", "header", "checksum"],
            );
        }
    }

    // 2. one flipped byte inside every frame (first and last byte) must be
    // rejected with an error naming that exact buffer
    for (role, off, bytes) in &manifest {
        assert!(*bytes > 0, "frame {role} is empty");
        for pos in [payload + off, payload + off + bytes - 1] {
            let mut m = clean.clone();
            m[pos as usize] ^= 0x01;
            reject(
                &ckpt,
                &mut victim,
                &before,
                &format!("bitflip {role}@{pos}"),
                &m,
                &[role],
            );
        }
    }

    // 3. a flipped byte in either header line is as fatal as payload damage
    let nl1 = clean.iter().position(|&b| b == b'\n').unwrap();
    for pos in [2usize, nl1 + 2] {
        let mut m = clean.clone();
        m[pos] ^= 0x01;
        reject(
            &ckpt,
            &mut victim,
            &before,
            &format!("header bitflip@{pos}"),
            &m,
            &["header"],
        );
    }

    // 4. trailing garbage past the manifest is rejected too
    let mut longer = clean.clone();
    longer.push(0xAA);
    reject(&ckpt, &mut victim, &before, "append 1 byte", &longer, &["trailing"]);

    // 5. after all that abuse, the pristine bytes still restore
    fs::write(&ckpt, &clean).unwrap();
    assert_eq!(victim.load_checkpoint(&ckpt).unwrap(), 10);
    assert_eq!(
        fingerprint(&victim).0,
        fingerprint(&t).0,
        "clean restore must reproduce the saved parameters"
    );
    fs::remove_dir_all(&dir).ok();
}

#[test]
fn delta_chain_faults_name_the_parent() {
    // corrupting a *parent* frame that a delta child delegates to must fail
    // the child's load with an error naming the chain / the frame
    let rt = HostBackend::new();
    let dir = tdir("delta");
    let base = dir.join("base.bin");
    let child = dir.join("child.bin");

    let mut c8 = cfg();
    c8.steps = 8;
    let mut t8 = Trainer::new(&rt, c8).unwrap();
    t8.train(&rt, None).unwrap();
    t8.save_checkpoint(&base, 8).unwrap();

    let mut c10 = cfg();
    c10.steps = 10;
    let mut t10 = Trainer::new(&rt, c10).unwrap();
    assert_eq!(t10.load_checkpoint(&base).unwrap(), 8);
    t10.train(&rt, None).unwrap();
    t10.save_checkpoint_delta(&child, 10, &base).unwrap();

    // no precond/invroot refresh ran between step 8 and 10, so the side
    // frames must delegate to the parent
    let view = CheckpointFile::open(&child).unwrap();
    let delegated: Vec<String> = view
        .header
        .manifest
        .iter()
        .filter(|e| e.in_parent)
        .map(|e| e.role.clone())
        .collect();
    assert!(
        delegated.iter().any(|r| r.starts_with("so.")),
        "expected second-order frames to be delta-shared, manifest: {:?}",
        view.header.manifest.iter().map(|e| (&e.role, e.in_parent)).collect::<Vec<_>>()
    );
    let (ppath, poff, _) = view.frame_location(&delegated[0]).unwrap();
    assert_eq!(ppath, base, "delegated frame must resolve into the parent file");
    drop(view);

    // flip one byte of the delegated frame inside the PARENT file
    let mut pbytes = fs::read(&base).unwrap();
    pbytes[poff as usize] ^= 0x01;
    fs::write(&base, &pbytes).unwrap();

    let mut victim = Trainer::new(&rt, cfg()).unwrap();
    let before = fingerprint(&victim);
    let err = victim.load_checkpoint(&child).unwrap_err();
    let msg = format!("{err:#}").to_lowercase();
    assert!(
        msg.contains(&delegated[0].to_lowercase()) || msg.contains("checksum"),
        "parent corruption not named: {msg}"
    );
    assert_eq!(fingerprint(&victim), before, "failed chain load mutated trainer state");

    // deleting the parent breaks the chain with a named error
    fs::remove_file(&base).unwrap();
    let err = victim.load_checkpoint(&child).unwrap_err();
    let msg = format!("{err:#}").to_lowercase();
    assert!(msg.contains("parent chain"), "missing parent not named: {msg}");
    fs::remove_dir_all(&dir).ok();
}
