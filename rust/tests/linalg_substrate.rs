//! Linear-algebra substrate coverage for the paper's two load-bearing
//! claims about the quantized-eigenbasis pipeline:
//!  * Björck orthogonality rectification (eq. 2) restores ‖VᵀV − I‖_F of a
//!    4-bit-quantized eigenvector matrix below tolerance (Figure 3);
//!  * the eig-based inverse 4-th root matches the dense Schur–Newton
//!    reference on SPD fixtures (Algorithm 4 cross-check).

use shampoo4::linalg::{
    bjorck, eigh, invroot_eigh, orthogonality_error, orthogonalize_cgs2, random_orthogonal,
    schur_newton_invroot, Mat,
};
use shampoo4::quant::{
    dequantize_matrix_cols, quantize_matrix_cols, runtime_codebook, Mapping,
};
use shampoo4::util::prop;
use shampoo4::util::rng::Rng;

#[test]
fn bjorck_rectifies_quantized_eigenbasis() {
    // calibrated on order-128 fixtures: 4-bit quantization degrades
    // orthogonality to ~1.7; one step brings it < 0.5, two < 0.05, four ≈ 0
    let cb = runtime_codebook(Mapping::Linear2, 4);
    prop::check("björck after 4-bit quantization", 5, |rng| {
        // column-blocked quantization needs n² divisible by the 64-block
        let n = 96 + 8 * rng.below(9);
        let q = random_orthogonal(n, rng);
        let qv = quantize_matrix_cols(&q.data, n, &cb, 4);
        let v = Mat::from_vec(n, n, dequantize_matrix_cols(&qv, n, &cb));
        let e0 = orthogonality_error(&v);
        let e1 = orthogonality_error(&bjorck(&v, 1));
        let e2 = orthogonality_error(&bjorck(&v, 2));
        let e4 = orthogonality_error(&bjorck(&v, 4));
        if e0 < 0.5 {
            return Err(format!("quantization too benign: e0={e0}"));
        }
        if !(e1 < 0.5 * e0 && e2 < 0.05 && e4 < 1e-3) {
            return Err(format!("e0={e0} e1={e1} e2={e2} e4={e4}"));
        }
        Ok(())
    });
}

#[test]
fn cgs2_orthogonalizes_preserving_leading_span() {
    prop::check("CGS2", 10, |rng| {
        let n = 16 + rng.below(48);
        let a = Mat::randn(n, n, rng);
        let q = orthogonalize_cgs2(&a);
        let e = orthogonality_error(&q);
        if e > 1e-3 {
            return Err(format!("orth err {e}"));
        }
        // first column is the normalized first column of a
        let norm: f64 = (0..n).map(|i| (a[(i, 0)] as f64).powi(2)).sum::<f64>().sqrt();
        for i in 0..n {
            let want = (a[(i, 0)] as f64 / norm) as f32;
            if (q[(i, 0)] - want).abs() > 1e-4 {
                return Err(format!("col0[{i}]: {} vs {want}", q[(i, 0)]));
            }
        }
        Ok(())
    });
}

fn spd_fixture(n: usize, rng: &mut Rng) -> (Mat, Mat, Vec<f32>) {
    let q = random_orthogonal(n, rng);
    // log-spaced spectrum over ~3 decades, the regime Shampoo sees
    let vals: Vec<f32> =
        (0..n).map(|i| (10.0f32).powf(-1.5 + 3.0 * i as f32 / (n - 1) as f32)).collect();
    (Mat::sandwich(&q, &vals), q, vals)
}

#[test]
fn eig_invroot_matches_dense_reference_on_spd_fixtures() {
    prop::check("eigh A^{-1/4} vs Schur–Newton", 4, |rng| {
        let n = 24 + rng.below(40);
        let (a, q, vals) = spd_fixture(n, rng);
        let via_eig = invroot_eigh(&a, 4.0, 1e-12);
        let via_newton = schur_newton_invroot(&a, 4, 40);
        let rel = via_eig.sub(&via_newton).frobenius() / via_eig.frobenius();
        if rel > 2e-2 {
            return Err(format!("eigh vs newton rel err {rel}"));
        }
        // and both match the analytic construction Q·Λ^{-1/4}·Qᵀ
        let exact_vals: Vec<f32> = vals.iter().map(|&l| l.powf(-0.25)).collect();
        let exact = Mat::sandwich(&q, &exact_vals);
        let rel2 = via_eig.sub(&exact).frobenius() / exact.frobenius();
        if rel2 > 1e-2 {
            return Err(format!("eigh vs analytic rel err {rel2}"));
        }
        Ok(())
    });
}

#[test]
fn eigh_recovers_planted_spectrum() {
    prop::check("eigh spectrum", 5, |rng| {
        let n = 16 + rng.below(48);
        let (a, _, mut vals) = spd_fixture(n, rng);
        let e = eigh(&a);
        vals.sort_by(|x, y| x.partial_cmp(y).unwrap());
        for (got, want) in e.vals.iter().zip(&vals) {
            if (got - want).abs() > 1e-3 * (1.0 + want.abs()) {
                return Err(format!("{got} vs {want}"));
            }
        }
        Ok(())
    });
}
