//! Sharded block engine: determinism, wire accounting, and resume
//! portability guarantees.
//!
//! * `shards = N` must produce bit-identical losses, parameters, AND
//!   serialized second-order state (preconditioners + inverse roots, raw
//!   codec bytes) to `shards = 1` for every second-order arm — gradients
//!   ship as lossless fp32 frames, PU/PIRU are pure per-block functions,
//!   and results swap in block-index order at the same barriers, so the
//!   shard count is a pure deployment knob.
//! * The same holds with the cross-step pipeline on: the shard round
//!   replaces the in-process background jobs behind identical
//!   deterministic barriers.
//! * Checkpoints store second-order state in global block order, so a run
//!   saved at one shard count must resume bit-identically at another.
//! * The reply traffic (refreshed back-buffers) must ship as codec bytes:
//!   for 4-bit sides the state wire cost must be ≥ 4× below what an fp32
//!   wire format would ship.

#![allow(clippy::field_reassign_with_default)]

use shampoo4::config::{FirstOrderKind, RunConfig, SecondOrderKind};
use shampoo4::coordinator::{TrainResult, Trainer};
use shampoo4::runtime::HostBackend;

fn shard_cfg(kind: SecondOrderKind, shards: usize, steps: usize) -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.name = format!("se_{}_{shards}", kind.name());
    cfg.model = "mlp_base".into();
    cfg.steps = steps;
    cfg.first.kind = FirstOrderKind::Sgdm;
    cfg.first.lr = 0.05;
    cfg.first.weight_decay = 5e-4;
    cfg.second.kind = kind;
    cfg.second.update_precond_every = 5;
    cfg.second.update_invroot_every = 10;
    cfg.second.shards = shards;
    cfg.eval_every = 0;
    cfg.eval_batches = 4;
    cfg.log_every = 1;
    cfg
}

/// Train to completion; return (params, second-order state blob, result).
fn run(cfg: RunConfig) -> (Vec<Vec<f32>>, Vec<u8>, TrainResult) {
    let rt = HostBackend::new();
    let mut t = Trainer::new(&rt, cfg).unwrap();
    let res = t.train(&rt, None).unwrap();
    let blob = t.second.as_ref().map(|s| s.serialize_state()).unwrap_or_default();
    (t.model.params.clone(), blob, res)
}

/// Exact f32 bit patterns (NaN-proof equality).
fn param_bits(params: &[Vec<f32>]) -> Vec<Vec<u32>> {
    params.iter().map(|p| p.iter().map(|x| x.to_bits()).collect()).collect()
}

fn loss_bits(losses: &[(usize, f32)]) -> Vec<(usize, u32)> {
    losses.iter().map(|&(s, l)| (s, l.to_bits())).collect()
}

/// shards ∈ {1, 2, 4} must agree bit-for-bit: losses, parameters, and the
/// serialized preconditioner/inverse-root state itself.
fn assert_shards_bit_identical(kind: SecondOrderKind, steps: usize) {
    let (p1, s1, r1) = run(shard_cfg(kind, 1, steps));
    assert!(
        r1.losses.last().unwrap().1.is_finite(),
        "{}: baseline produced non-finite loss",
        kind.name()
    );
    for shards in [2usize, 4] {
        let (pn, sn, rn) = run(shard_cfg(kind, shards, steps));
        assert_eq!(
            loss_bits(&r1.losses),
            loss_bits(&rn.losses),
            "{}: losses diverge between shards=1 and shards={shards}",
            kind.name()
        );
        assert_eq!(
            param_bits(&p1),
            param_bits(&pn),
            "{}: parameters diverge between shards=1 and shards={shards}",
            kind.name()
        );
        assert_eq!(
            s1, sn,
            "{}: serialized second-order state diverges between shards=1 and \
             shards={shards}",
            kind.name()
        );
        assert!(rn.timings.shard_rounds > 0, "sharded run never dispatched a round");
        assert_eq!(r1.timings.shard_rounds, 0, "shards=1 must not build the shard engine");
    }
}

#[test]
fn shampoo_shards_are_bit_identical() {
    assert_shards_bit_identical(SecondOrderKind::Shampoo, 22);
}

#[test]
fn caspr_shards_are_bit_identical() {
    assert_shards_bit_identical(SecondOrderKind::Caspr, 22);
}

#[test]
fn kfac_shards_are_bit_identical() {
    assert_shards_bit_identical(SecondOrderKind::KFac, 12);
}

#[test]
fn pipelined_shards_are_bit_identical() {
    // with `shampoo.pipeline` on, the shard round replaces the in-process
    // background jobs but fires at the same deterministic barriers — the
    // pipelined trajectory must not depend on the shard count either
    let mk = |shards: usize| {
        let mut cfg = shard_cfg(SecondOrderKind::Shampoo, shards, 22);
        cfg.name = format!("se_pipe_{shards}");
        cfg.second.pipeline = true;
        cfg.second.pipeline_max_lag = 3;
        cfg
    };
    let (p1, s1, r1) = run(mk(1));
    let (p2, s2, r2) = run(mk(2));
    assert!(r1.timings.pipeline_refreshes > 0, "pipeline never submitted a refresh");
    assert_eq!(r1.timings.pipeline_refreshes, r2.timings.pipeline_refreshes);
    assert!(r2.timings.shard_rounds > 0, "sharded pipeline never dispatched a round");
    assert_eq!(loss_bits(&r1.losses), loss_bits(&r2.losses));
    assert_eq!(param_bits(&p1), param_bits(&p2));
    assert_eq!(s1, s2, "pipelined second-order state diverges across shard counts");
}

#[test]
fn checkpoint_resumes_across_shard_counts() {
    // checkpoints store second-order state in global block order and the
    // round-robin assignment is a pure function of (block_idx, shards), so
    // a run saved at shards=2 must resume bit-identically at shards=4
    let rt = HostBackend::new();
    let dir = std::env::temp_dir().join("shampoo4_shard_resume_test");
    std::fs::create_dir_all(&dir).unwrap();
    let ckpt = dir.join("ck.bin");

    let mut cfg = shard_cfg(SecondOrderKind::Shampoo, 1, 20);
    cfg.name = "se_resume".into();
    cfg.second.update_precond_every = 4;
    cfg.second.update_invroot_every = 8;
    cfg.schedule = shampoo4::config::Schedule::Constant;

    let mut straight = Trainer::new(&rt, cfg.clone()).unwrap();
    straight.train(&rt, None).unwrap();

    let mut half_cfg = cfg.clone();
    half_cfg.steps = 10;
    half_cfg.second.shards = 2;
    let mut first_half = Trainer::new(&rt, half_cfg).unwrap();
    first_half.train(&rt, None).unwrap();
    first_half.save_checkpoint(&ckpt, 10).unwrap();

    let mut resume_cfg = cfg.clone();
    resume_cfg.second.shards = 4;
    let mut resumed = Trainer::new(&rt, resume_cfg).unwrap();
    assert_eq!(resumed.load_checkpoint(&ckpt).unwrap(), 10);
    let r = resumed.train(&rt, None).unwrap();
    assert_eq!(r.timings.steps, 10, "resume must run only the back half");
    assert_eq!(
        param_bits(&resumed.model.params),
        param_bits(&straight.model.params),
        "shards=2 checkpoint resumed at shards=4 diverged from the unsharded run"
    );
    assert_eq!(
        resumed.second.as_ref().unwrap().serialize_state(),
        straight.second.as_ref().unwrap().serialize_state(),
        "second-order state diverged across the shard-count change"
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn shard_wire_is_codec_compressed() {
    // the refreshed back-buffers must travel as raw codec bytes: with the
    // default 4-bit sides, the state traffic must be at least 4x below the
    // fp32 wire format (the paper's at-rest compression carried onto the
    // wire), and all counters must be self-consistent
    let (_, _, res) = run(shard_cfg(SecondOrderKind::Shampoo, 2, 22));
    let tm = &res.timings;
    assert!(tm.shard_rounds > 0, "no shard rounds dispatched");
    assert!(tm.shard_state_bytes > 0, "no state traffic accounted");
    assert!(
        tm.shard_wire_bytes > tm.shard_state_bytes,
        "total wire must include request traffic on top of state traffic"
    );
    let ratio = tm.shard_state_fp32_bytes as f64 / tm.shard_state_bytes as f64;
    assert!(
        ratio >= 4.0,
        "4-bit state wire must be >= 4x below fp32 wire, got {ratio:.2}x \
         ({} vs {} bytes)",
        tm.shard_state_bytes,
        tm.shard_state_fp32_bytes
    );
}

#[test]
fn shard_engine_error_reports_backend_name() {
    // a shard worker that cannot construct its backend must surface a
    // descriptive error at the first barrier (construction sync), not hang
    let mut cfg = shard_cfg(SecondOrderKind::Shampoo, 2, 5);
    cfg.name = "se_bad_backend".into();
    cfg.backend = "pjrt".into(); // not compiled in default builds
    let rt = HostBackend::new();
    let err = match Trainer::new(&rt, cfg) {
        Err(e) => format!("{e:#}"),
        Ok(mut t) => match t.train(&rt, None) {
            Err(e) => format!("{e:#}"),
            Ok(_) => panic!("shard engine trained against an unavailable backend"),
        },
    };
    assert!(
        err.contains("pjrt") || err.contains("backend"),
        "unexpected error chain: {err}"
    );
}
