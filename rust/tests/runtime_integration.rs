//! Backend-level integration: every artifact the manifest declares executes
//! on the HostBackend with spec-conformant inputs and returns
//! spec-conformant outputs, and the numeric semantics of the PU → PIRU →
//! precondition pipeline match host linear-algebra references on SPD
//! fixtures. Runs hermetically — no Python artifacts, no XLA, no skips.
//!
//! With --features pjrt and a compiled artifacts/ directory, the golden
//! vectors emitted by aot.py are additionally validated (pjrt module below).

use shampoo4::linalg::{random_orthogonal, Mat};
use shampoo4::quant::{runtime_codebook, Mapping};
use shampoo4::runtime::{Backend, HostBackend, HostTensor, IoSpec};
use shampoo4::util::rng::Rng;

/// Deterministic spec-conformant inputs, mirroring aot.py _golden_inputs.
fn synth_input(io: &IoSpec, rng: &mut Rng) -> HostTensor {
    let numel: usize = io.shape.iter().product();
    match io.dtype.as_str() {
        "uint8" => HostTensor::u8(&io.shape, (0..numel).map(|_| rng.below(16) as u8).collect()),
        "int32" => HostTensor::i32(&io.shape, (0..numel).map(|_| rng.below(100) as i32).collect()),
        _ => match io.name.as_str() {
            "cb" => HostTensor::f32(&io.shape, runtime_codebook(Mapping::Linear2, 4)),
            "beta" => HostTensor::scalar_f32(0.95),
            "eps" => HostTensor::scalar_f32(1e-4),
            "lr" => HostTensor::scalar_f32(1e-3),
            "momentum" | "beta1" => HostTensor::scalar_f32(0.9),
            "beta2" => HostTensor::scalar_f32(0.999),
            "wd" => HostTensor::scalar_f32(0.01),
            "step" => HostTensor::scalar_f32(7.0),
            "m_stat" | "l" => {
                // PD matrix: B·Bᵀ/d with B (d, d+8)
                let d = io.shape[0];
                let b = Mat::randn(d, d + 8, rng);
                HostTensor::f32(&io.shape, b.gram().scale(1.0 / d as f32).data)
            }
            "lam" | "diag" => HostTensor::f32(
                &io.shape,
                (0..numel).map(|_| rng.normal_f32().abs() + 0.1).collect(),
            ),
            "scales" | "l_scales" | "r_scales" => HostTensor::f32(
                &io.shape,
                (0..numel).map(|_| rng.normal_f32().abs() * 0.1 + 0.01).collect(),
            ),
            "v" => HostTensor::f32(
                &io.shape,
                (0..numel).map(|_| rng.normal_f32().powi(2) * 0.01).collect(),
            ),
            "l_diag" | "r_diag" => HostTensor::f32(
                &io.shape,
                (0..numel).map(|_| rng.normal_f32().abs() + 0.5).collect(),
            ),
            "lhat" | "rhat" => {
                let d = io.shape[0];
                let mut b = Mat::randn(d, d, rng).scale(0.05);
                b.symmetrize();
                HostTensor::f32(&io.shape, Mat::eye(d).add(&b.scale(0.5)).data)
            }
            _ => HostTensor::f32(&io.shape, rng.normal_vec(numel)),
        },
    }
}

#[test]
fn every_artifact_executes_and_matches_output_specs() {
    let rt = HostBackend::new();
    let mut names: Vec<String> = rt.manifest().artifacts.keys().cloned().collect();
    names.sort();
    let mut rng = Rng::new(1234);
    let mut checked = 0usize;
    for name in names {
        if name.starts_with("tlm_small") {
            continue; // spec-identical to tlm_tiny, just slower
        }
        let spec = rt.spec(&name).unwrap().clone();
        let inputs: Vec<HostTensor> =
            spec.inputs.iter().map(|io| synth_input(io, &mut rng)).collect();
        let outputs = rt.execute(&name, &inputs).unwrap_or_else(|e| panic!("{name}: {e}"));
        assert_eq!(outputs.len(), spec.outputs.len(), "{name}: output arity");
        for (o, io) in outputs.iter().zip(&spec.outputs) {
            assert_eq!(o.shape, io.shape, "{name}.{}: output shape", io.name);
            assert_eq!(o.data.dtype_name(), io.dtype, "{name}.{}: output dtype", io.name);
            if let Ok(v) = o.as_f32() {
                assert!(v.iter().all(|x| x.is_finite()), "{name}.{}: non-finite", io.name);
            }
        }
        checked += 1;
    }
    assert!(checked >= 80, "expected >=80 artifacts, checked {checked}");
}

#[test]
fn input_validation_rejects_wrong_shapes_and_dtypes() {
    let rt = HostBackend::new();
    // gram_64x128 expects one f32 (64, 128) input
    let bad_shape = HostTensor::zeros_f32(&[64, 64]);
    assert!(rt.execute("gram_64x128", &[bad_shape]).is_err());
    let bad_dtype = HostTensor::i32(&[64, 128], vec![0; 64 * 128]);
    assert!(rt.execute("gram_64x128", &[bad_dtype]).is_err());
    assert!(rt.execute("gram_64x128", &[]).is_err());
}

#[test]
fn gram_matches_host_reference() {
    let rt = HostBackend::new();
    let mut rng = Rng::new(7);
    let g = Mat::randn(64, 128, &mut rng);
    let outs = rt.execute("gram_64x128", &[HostTensor::f32(&[64, 128], g.data.clone())]).unwrap();
    let l = Mat::from_vec(64, 64, outs[0].as_f32().unwrap().to_vec());
    let r = Mat::from_vec(128, 128, outs[1].as_f32().unwrap().to_vec());
    let l_ref = g.matmul(&g.transpose());
    let r_ref = g.transpose().matmul(&g);
    assert!(l.sub(&l_ref).frobenius() < 1e-3 * (1.0 + l_ref.frobenius()));
    assert!(r.sub(&r_ref).frobenius() < 1e-3 * (1.0 + r_ref.frobenius()));
}

#[test]
fn precond32_with_identity_states_grafts_to_g() {
    let rt = HostBackend::new();
    let mut rng = Rng::new(9);
    let g = Mat::randn(32, 64, &mut rng);
    let outs = rt
        .execute(
            "precond32_32x64",
            &[
                HostTensor::f32(&[32, 64], g.data.clone()),
                HostTensor::f32(&[32, 32], Mat::eye(32).data),
                HostTensor::f32(&[64, 64], Mat::eye(64).data),
            ],
        )
        .unwrap();
    let gt = outs[0].as_f32().unwrap();
    for (a, b) in gt.iter().zip(&g.data) {
        assert!((a - b).abs() < 1e-4, "{a} vs {b}");
    }
}

/// Drive the quantized state machine the way the coordinator does:
/// quant_cols (init) → repeated PU at β=0 (pure subspace iteration) → PIRU,
/// then check both reconstructions against exact eigendecomposition
/// references on an SPD fixture with spectrum 1..64.
#[test]
fn pu_piru_pipeline_tracks_eigendecomposition() {
    let rt = HostBackend::new();
    let n = 64usize;
    let mut rng = Rng::new(3);
    let q = random_orthogonal(n, &mut rng);
    let vals: Vec<f32> = (1..=n).map(|i| i as f32).collect();
    let a = Mat::sandwich(&q, &vals);
    let cb = runtime_codebook(Mapping::Linear2, 4);
    let cb_t = HostTensor::f32(&[16], cb.clone());

    // initial state: eigenbasis = quantized identity, λ = ε
    let init = rt
        .execute("quant_cols_64", &[HostTensor::f32(&[n, n], Mat::eye(n).data), cb_t.clone()])
        .unwrap();
    let mut lam = HostTensor::f32(&[n], vec![1e-4; n]);
    let mut codes = init[0].clone();
    let mut scales = init[1].clone();

    let a_t = HostTensor::f32(&[n, n], a.data.clone());
    for _ in 0..40 {
        let outs = rt
            .execute(
                "pu_64",
                &[
                    lam.clone(),
                    codes.clone(),
                    scales.clone(),
                    a_t.clone(),
                    HostTensor::scalar_f32(0.0), // β=0: track A exactly
                    cb_t.clone(),
                ],
            )
            .unwrap();
        lam = outs[0].clone();
        codes = outs[1].clone();
        scales = outs[2].clone();
    }

    // reconstruct VΛVᵀ from the quantized state
    let v_out = rt
        .execute("dequant_cols_64", &[codes.clone(), scales.clone(), cb_t.clone()])
        .unwrap();
    let v = Mat::from_vec(n, n, v_out[0].as_f32().unwrap().to_vec());
    let recon = Mat::sandwich(&v, lam.as_f32().unwrap());
    let nre_pu = recon.sub(&a).frobenius() / a.frobenius();
    assert!(nre_pu < 0.25, "PU reconstruction NRE {nre_pu}");

    // PIRU: Â vs the exact (A + λmax·ε·I)^{-1/4}
    let piru = rt
        .execute(
            "piru_64",
            &[lam, codes, scales, HostTensor::scalar_f32(1e-4), cb_t.clone()],
        )
        .unwrap();
    let off_out = rt.execute("dequant_cols_64", &[piru[1].clone(), piru[2].clone(), cb_t]).unwrap();
    let mut a_hat = Mat::from_vec(n, n, off_out[0].as_f32().unwrap().to_vec());
    for (i, &d) in piru[0].as_f32().unwrap().iter().enumerate() {
        a_hat[(i, i)] = d;
    }
    let ridge = n as f32 * 1e-4;
    let exact_vals: Vec<f32> = vals.iter().map(|&l| (l + ridge).powf(-0.25)).collect();
    let exact = Mat::sandwich(&q, &exact_vals);
    let nre_piru = a_hat.sub(&exact).frobenius() / exact.frobenius();
    assert!(nre_piru < 0.1, "PIRU NRE {nre_piru}");
}

/// Naive arm: quantize A directly (β=0 PU), Schur–Newton inverse root.
#[test]
fn naive_arm_roundtrip_tracks_reference() {
    let rt = HostBackend::new();
    let n = 64usize;
    let mut rng = Rng::new(5);
    let q = random_orthogonal(n, &mut rng);
    let vals: Vec<f32> = (1..=n).map(|i| i as f32).collect();
    let a = Mat::sandwich(&q, &vals);
    let cb = runtime_codebook(Mapping::Linear2, 4);
    let cb_t = HostTensor::f32(&[16], cb);
    let qb = 64.min(n);
    let nb = n * n / qb;

    // β=0 PU from a zero state quantizes A itself
    let outs = rt
        .execute(
            "pu_naive_64",
            &[
                HostTensor::f32(&[n], vec![0.0; n]),
                HostTensor::u8(&[nb, qb], vec![7; n * n]), // code 7 = 0.0 in linear2-4
                HostTensor::f32(&[nb], vec![1.0; nb]),
                HostTensor::f32(&[n, n], a.data.clone()),
                HostTensor::scalar_f32(0.0),
                cb_t.clone(),
            ],
        )
        .unwrap();
    let rebuild = |diag: &HostTensor, codes: &HostTensor, scales: &HostTensor| {
        let off = rt
            .execute("dequant_cols_64", &[codes.clone(), scales.clone(), cb_t.clone()])
            .unwrap();
        let mut m = Mat::from_vec(n, n, off[0].as_f32().unwrap().to_vec());
        for (i, &d) in diag.as_f32().unwrap().iter().enumerate() {
            m[(i, i)] = d;
        }
        m
    };
    let a_rec = rebuild(&outs[0], &outs[1], &outs[2]);
    let nre_a = a_rec.sub(&a).frobenius() / a.frobenius();
    assert!(nre_a < 0.2, "naive A reconstruction NRE {nre_a}");

    let inv = rt
        .execute(
            "invroot_naive_64",
            &[
                outs[0].clone(),
                outs[1].clone(),
                outs[2].clone(),
                HostTensor::scalar_f32(1e-4),
                cb_t.clone(),
            ],
        )
        .unwrap();
    let a_hat = rebuild(&inv[0], &inv[1], &inv[2]);
    let ridge = n as f32 * 1e-4;
    let exact_vals: Vec<f32> = vals.iter().map(|&l| (l + ridge).powf(-0.25)).collect();
    let exact = Mat::sandwich(&q, &exact_vals);
    let nre = a_hat.sub(&exact).frobenius() / exact.frobenius();
    assert!(nre < 0.2, "naive invroot NRE {nre}");
}

#[test]
fn sgdm_artifact_matches_formula() {
    let rt = HostBackend::new();
    let n = 4096;
    let mut rng = Rng::new(17);
    let p0 = rng.normal_vec(n);
    let b0 = rng.normal_vec(n);
    let g = rng.normal_vec(n);
    let (lr, mom, wd) = (0.05f32, 0.9f32, 5e-4f32);
    let outs = rt
        .execute(
            "sgdm_update_4096",
            &[
                HostTensor::f32(&[n], p0.clone()),
                HostTensor::f32(&[n], b0.clone()),
                HostTensor::f32(&[n], g.clone()),
                HostTensor::scalar_f32(lr),
                HostTensor::scalar_f32(mom),
                HostTensor::scalar_f32(wd),
            ],
        )
        .unwrap();
    let p_art = outs[0].as_f32().unwrap();
    let b_art = outs[1].as_f32().unwrap();
    for i in 0..n {
        let gi = g[i] + wd * p0[i];
        let bi = mom * b0[i] + gi;
        assert!((b_art[i] - bi).abs() < 1e-6);
        assert!((p_art[i] - (p0[i] - lr * bi)).abs() < 1e-6);
    }
}

#[test]
fn backends_share_manifest_schema() {
    // the host manifest round-trips through the same validation the PJRT
    // registry uses, and serves the models the trainer asks for
    let rt = HostBackend::new();
    let m = rt.manifest();
    assert_eq!(m.cb_len, 16);
    assert_eq!(m.block_size, 64);
    for model in m.models.values() {
        assert!(m.artifacts.contains_key(&model.step), "missing step {}", model.step);
        assert!(m.artifacts.contains_key(&model.eval), "missing eval {}", model.eval);
        let step = &m.artifacts[&model.step];
        // inputs = params + data tensors; outputs start with loss + grads
        assert_eq!(&step.outputs[0].name, "loss");
        assert!(step.outputs.len() > model.params.len());
    }
}

/// Golden-vector validation against aot.py output (PJRT builds only).
#[cfg(feature = "pjrt")]
mod pjrt_golden {
    use std::path::Path;

    use shampoo4::runtime::{Backend, HostTensor, PjrtBackend};
    use shampoo4::util::json::Json;

    fn artifact_dir() -> Option<&'static Path> {
        let p = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if p.join("manifest.json").exists() {
            Some(Box::leak(p.into_boxed_path()))
        } else {
            eprintln!("artifacts/ missing; run `make artifacts` first — skipping");
            None
        }
    }

    fn tensor_from_golden(spec: &Json) -> HostTensor {
        let shape = spec.get("shape").unwrap().usize_vec().unwrap();
        let dtype = spec.get("dtype").unwrap().as_str().unwrap();
        let data = spec.get("data").unwrap();
        match dtype {
            "float32" => HostTensor::f32(&shape, data.f32_vec().unwrap()),
            "int32" => HostTensor::i32(
                &shape,
                data.as_arr().unwrap().iter().map(|x| x.as_f64().unwrap() as i32).collect(),
            ),
            "uint8" => HostTensor::u8(
                &shape,
                data.as_arr().unwrap().iter().map(|x| x.as_f64().unwrap() as u8).collect(),
            ),
            other => panic!("dtype {other}"),
        }
    }

    #[test]
    fn golden_artifacts_match() {
        let Some(dir) = artifact_dir() else { return };
        let rt = PjrtBackend::new(dir).expect("pjrt backend");
        let golden_dir = dir.join("golden");
        let mut checked = 0;
        for entry in std::fs::read_dir(&golden_dir).expect("golden dir") {
            let path = entry.unwrap().path();
            if path.extension().and_then(|e| e.to_str()) != Some("json") {
                continue;
            }
            let name = path.file_stem().unwrap().to_str().unwrap().to_string();
            if !rt.has_artifact(&name) {
                continue;
            }
            let g = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
            let spec = rt.spec(&name).unwrap().clone();
            let inputs: Vec<HostTensor> = spec
                .inputs
                .iter()
                .map(|io| tensor_from_golden(g.get("inputs").unwrap().get(&io.name).unwrap()))
                .collect();
            let outputs = rt.execute(&name, &inputs).unwrap();
            let want = g.get("outputs").unwrap().as_arr().unwrap();
            assert_eq!(outputs.len(), want.len(), "{name}: output arity");
            for (o, w) in outputs.iter().zip(want) {
                let wt = tensor_from_golden(w);
                assert_eq!(o.shape, wt.shape, "{name}: output shape");
                match (&o.data, &wt.data) {
                    (
                        shampoo4::runtime::TensorData::F32(a),
                        shampoo4::runtime::TensorData::F32(b),
                    ) => {
                        for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
                            let both_nan = x.is_nan() && y.is_nan();
                            assert!(
                                both_nan || (x - y).abs() <= 1e-4 + 1e-4 * y.abs(),
                                "{name} out[{i}]: {x} vs {y}"
                            );
                        }
                    }
                    (
                        shampoo4::runtime::TensorData::U8(a),
                        shampoo4::runtime::TensorData::U8(b),
                    ) => {
                        assert_eq!(a, b, "{name}: u8 codes differ");
                    }
                    _ => panic!("{name}: dtype mismatch"),
                }
            }
            checked += 1;
        }
        assert!(checked >= 5, "expected >=5 golden artifacts, checked {checked}");
    }
}
