//! Integration: load AOT artifacts in the PJRT runtime and validate
//! numerics against the golden vectors emitted by aot.py.
//! Requires `make artifacts` to have run (skips otherwise).

use std::path::Path;

use shampoo4::runtime::{HostTensor, Runtime};
use shampoo4::util::json::Json;

fn artifact_dir() -> Option<&'static Path> {
    let p = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if p.join("manifest.json").exists() {
        Some(Box::leak(p.into_boxed_path()))
    } else {
        eprintln!("artifacts/ missing; run `make artifacts` first — skipping");
        None
    }
}

fn tensor_from_golden(spec: &Json) -> HostTensor {
    let shape = spec.get("shape").unwrap().usize_vec().unwrap();
    let dtype = spec.get("dtype").unwrap().as_str().unwrap();
    let data = spec.get("data").unwrap();
    match dtype {
        "float32" => HostTensor::f32(&shape, data.f32_vec().unwrap()),
        "int32" => HostTensor::i32(
            &shape,
            data.as_arr().unwrap().iter().map(|x| x.as_f64().unwrap() as i32).collect(),
        ),
        "uint8" => HostTensor::u8(
            &shape,
            data.as_arr().unwrap().iter().map(|x| x.as_f64().unwrap() as u8).collect(),
        ),
        other => panic!("dtype {other}"),
    }
}

#[test]
fn golden_artifacts_match() {
    let Some(dir) = artifact_dir() else { return };
    let rt = Runtime::new(dir).expect("runtime");
    let golden_dir = dir.join("golden");
    let mut checked = 0;
    for entry in std::fs::read_dir(&golden_dir).expect("golden dir") {
        let path = entry.unwrap().path();
        if path.extension().and_then(|e| e.to_str()) != Some("json") {
            continue;
        }
        let name = path.file_stem().unwrap().to_str().unwrap().to_string();
        if !rt.has_artifact(&name) {
            continue;
        }
        let g = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        let spec = rt.spec(&name).unwrap().clone();
        let inputs: Vec<HostTensor> = spec
            .inputs
            .iter()
            .map(|io| tensor_from_golden(g.get("inputs").unwrap().get(&io.name).unwrap()))
            .collect();
        let outputs = rt.execute(&name, &inputs).unwrap();
        let want = g.get("outputs").unwrap().as_arr().unwrap();
        assert_eq!(outputs.len(), want.len(), "{name}: output arity");
        for (o, w) in outputs.iter().zip(want) {
            let wt = tensor_from_golden(w);
            assert_eq!(o.shape, wt.shape, "{name}: output shape");
            match (&o.data, &wt.data) {
                (shampoo4::runtime::TensorData::F32(a), shampoo4::runtime::TensorData::F32(b)) => {
                    for (i, (x, y)) in a.iter().zip(b).enumerate() {
                        let both_nan = x.is_nan() && y.is_nan();
                        assert!(
                            both_nan || (x - y).abs() <= 1e-4 + 1e-4 * y.abs(),
                            "{name} out[{i}]: {x} vs {y}"
                        );
                    }
                }
                (shampoo4::runtime::TensorData::U8(a), shampoo4::runtime::TensorData::U8(b)) => {
                    assert_eq!(a, b, "{name}: u8 codes differ");
                }
                _ => panic!("{name}: dtype mismatch"),
            }
        }
        checked += 1;
    }
    assert!(checked >= 5, "expected >=5 golden artifacts, checked {checked}");
}
