//! Kill-and-resume property tests for the v1 streaming checkpoint format,
//! plus the concurrent StateServer stress test.
//!
//! The matrix: save at step k under shampoo / caspr / kfac crossed with
//! the pipelined engine, the sharded engine (N = 2), and a mixed
//! per-buffer `--quant-policy` — then resume through a monolithic save AND
//! through a delta chain, train m more steps, and demand bit-identical
//! parameters to the uninterrupted run. Delta restores must equal
//! monolithic restores exactly; a depth-2 chain must resolve delegated
//! frames all the way to the root file.

#![allow(clippy::field_reassign_with_default)]

use std::collections::BTreeMap;
use std::fs;
use std::path::PathBuf;
use std::sync::Arc;

use shampoo4::config::{FirstOrderKind, RunConfig, SecondOrderKind};
use shampoo4::coordinator::{CheckpointFile, StateServer, Trainer};
use shampoo4::runtime::HostBackend;
use shampoo4::util::rng::Rng;

fn tdir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("shampoo4_stream_{name}"));
    let _ = fs::remove_dir_all(&dir);
    fs::create_dir_all(&dir).unwrap();
    dir
}

fn base_cfg(name: &str, kind: SecondOrderKind, steps: usize) -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.name = name.to_string();
    cfg.model = "mlp_base".into();
    cfg.steps = steps;
    cfg.first.kind = FirstOrderKind::Sgdm;
    cfg.first.lr = 0.05;
    cfg.second.kind = kind;
    cfg.second.update_precond_every = 4;
    cfg.second.update_invroot_every = 8;
    cfg.schedule = shampoo4::config::Schedule::Constant;
    cfg.eval_every = 0;
    cfg.eval_batches = 0;
    cfg.log_every = 1;
    cfg
}

fn bits(params: &[Vec<f32>]) -> Vec<Vec<u32>> {
    params.iter().map(|p| p.iter().map(|x| x.to_bits()).collect()).collect()
}

/// The kill-and-resume property: train straight to k+m; separately train to
/// k, checkpoint (monolithic AND as a delta chain: a parent at step 8 plus
/// a delta at k = 10), resume each, train m more — every arm must land on
/// bit-identical parameters.
fn check_resume(label: &str, cfg: RunConfig) {
    let rt = HostBackend::new();
    let dir = tdir(label);
    let mono = dir.join("mono.bin");
    let parent = dir.join("parent.bin");
    let delta = dir.join("delta.bin");

    let mut straight = Trainer::new(&rt, cfg.clone()).unwrap();
    straight.train(&rt, None).unwrap();

    // monolithic save at k = 10
    let mut c10 = cfg.clone();
    c10.steps = 10;
    let mut half = Trainer::new(&rt, c10.clone()).unwrap();
    half.train(&rt, None).unwrap();
    half.save_checkpoint(&mono, 10).unwrap();

    // delta chain: parent at step 8, delta at k = 10 (no PU/PIRU refresh
    // falls in (8, 10], so the second-order side frames must be delegated,
    // not rewritten)
    let mut c8 = cfg.clone();
    c8.steps = 8;
    let mut t8 = Trainer::new(&rt, c8).unwrap();
    t8.train(&rt, None).unwrap();
    t8.save_checkpoint(&parent, 8).unwrap();
    let mut t10 = Trainer::new(&rt, c10).unwrap();
    assert_eq!(t10.load_checkpoint(&parent).unwrap(), 8);
    t10.train(&rt, None).unwrap();
    t10.save_checkpoint_delta(&delta, 10, &parent).unwrap();

    let view = CheckpointFile::open(&delta).unwrap();
    assert!(
        view.header.manifest.iter().any(|e| e.in_parent && e.role.starts_with("so.")),
        "{label}: delta did not delegate any second-order frame: {:?}",
        view.header.manifest.iter().map(|e| (&e.role, e.in_parent)).collect::<Vec<_>>()
    );
    drop(view);

    // resume via the monolithic file
    let mut rm = Trainer::new(&rt, cfg.clone()).unwrap();
    assert_eq!(rm.load_checkpoint(&mono).unwrap(), 10);
    assert_eq!(bits(&rm.model.params), bits(&half.model.params), "{label}: mono restore");

    // resume via the delta chain: the restored state must equal the
    // monolithic restore bit for bit
    let mut rd = Trainer::new(&rt, cfg.clone()).unwrap();
    assert_eq!(rd.load_checkpoint(&delta).unwrap(), 10);
    assert_eq!(
        bits(&rd.model.params),
        bits(&rm.model.params),
        "{label}: delta restore differs from monolithic restore"
    );

    // train m = 10 more steps from each; both must rejoin the straight run
    let r = rm.train(&rt, None).unwrap();
    assert_eq!(r.timings.steps, 10, "{label}: mono resume must run only the back half");
    let r = rd.train(&rt, None).unwrap();
    assert_eq!(r.timings.steps, 10, "{label}: delta resume must run only the back half");
    assert_eq!(
        bits(&rm.model.params),
        bits(&straight.model.params),
        "{label}: monolithic resume diverged from the straight run"
    );
    assert_eq!(
        bits(&rd.model.params),
        bits(&straight.model.params),
        "{label}: delta-chain resume diverged from the straight run"
    );
    fs::remove_dir_all(&dir).ok();
}

#[test]
fn shampoo_pipelined_resumes_bit_identically_via_mono_and_delta() {
    let mut cfg = base_cfg("st_shampoo_pipe", SecondOrderKind::Shampoo, 20);
    cfg.second.pipeline = true;
    cfg.second.parallelism = 2;
    check_resume("shampoo+pipeline", cfg);
}

#[test]
fn caspr_sharded_resumes_bit_identically_via_mono_and_delta() {
    let mut cfg = base_cfg("st_caspr_sh2", SecondOrderKind::Caspr, 20);
    cfg.second.shards = 2;
    check_resume("caspr+shards2", cfg);
}

#[test]
fn kfac_mixed_policy_resumes_bit_identically_via_mono_and_delta() {
    use shampoo4::quant::{BufferRole, CodecSpec, Mapping};
    let mut cfg = base_cfg("st_kfac_policy", SecondOrderKind::KFac, 20);
    cfg.first.kind = FirstOrderKind::AdamW;
    cfg.first.lr = 1e-3;
    cfg.quant_policy = vec![
        (BufferRole::Momentum, CodecSpec::parse("q4-dt", Mapping::Dt).unwrap()),
        (BufferRole::SecondMoment, CodecSpec::parse("q8-dt", Mapping::Dt).unwrap()),
    ];
    check_resume("kfac+policy", cfg);
}

#[test]
fn depth_two_delta_chain_resolves_to_the_root() {
    let rt = HostBackend::new();
    let dir = tdir("chain2");
    let root = dir.join("root.bin");
    let child = dir.join("child.bin");
    let grand = dir.join("grand.bin");

    let mut c8 = base_cfg("st_chain2", SecondOrderKind::Shampoo, 8);
    let mut t8 = Trainer::new(&rt, c8.clone()).unwrap();
    t8.train(&rt, None).unwrap();
    t8.save_checkpoint(&root, 8).unwrap();

    c8.steps = 10;
    let mut t10 = Trainer::new(&rt, c8.clone()).unwrap();
    assert_eq!(t10.load_checkpoint(&root).unwrap(), 8);
    t10.train(&rt, None).unwrap();
    t10.save_checkpoint_delta(&child, 10, &root).unwrap();

    c8.steps = 11;
    let mut t11 = Trainer::new(&rt, c8.clone()).unwrap();
    assert_eq!(t11.load_checkpoint(&child).unwrap(), 10);
    t11.train(&rt, None).unwrap();
    t11.save_checkpoint_delta(&grand, 11, &child).unwrap();

    // a side frame delegated twice must resolve into the root file
    let view = CheckpointFile::open(&grand).unwrap();
    let so_role = view
        .header
        .manifest
        .iter()
        .find(|e| e.in_parent && e.role.starts_with("so."))
        .map(|e| e.role.clone())
        .expect("grandchild must delegate side frames");
    let (path, _, _) = view.frame_location(&so_role).unwrap();
    assert_eq!(path, root, "depth-2 delegation must resolve to the root file");
    drop(view);

    // restoring through the depth-2 chain reproduces the saved state
    let mut r = Trainer::new(&rt, c8).unwrap();
    assert_eq!(r.load_checkpoint(&grand).unwrap(), 11);
    assert_eq!(bits(&r.model.params), bits(&t11.model.params));
    fs::remove_dir_all(&dir).ok();
}

#[test]
fn state_server_concurrent_slices_match_full_decode() {
    use shampoo4::quant::{BufferRole, CodecSpec, Mapping};
    let rt = HostBackend::new();
    let dir = tdir("server");
    let ckpt = dir.join("ck.bin");

    // mixed policy gives the server fp32 (params), q4 and q8 (moments)
    // frames plus opaque side-state frames to refuse
    let mut cfg = base_cfg("st_server", SecondOrderKind::Shampoo, 8);
    cfg.first.kind = FirstOrderKind::AdamW;
    cfg.first.lr = 1e-3;
    cfg.quant_policy = vec![
        (BufferRole::Momentum, CodecSpec::parse("q4-dt", Mapping::Dt).unwrap()),
        (BufferRole::SecondMoment, CodecSpec::parse("q8-dt", Mapping::Dt).unwrap()),
    ];
    let mut t = Trainer::new(&rt, cfg).unwrap();
    t.train(&rt, None).unwrap();
    t.save_checkpoint(&ckpt, 8).unwrap();

    let srv = Arc::new(StateServer::open(&ckpt).unwrap());
    let roles: Vec<String> = srv
        .roles()
        .into_iter()
        .filter(|r| srv.frame_len(r).unwrap() > 0)
        .collect();
    assert!(roles.iter().any(|r| r.starts_with("param.")));
    assert!(roles.iter().any(|r| r.starts_with("opt.")));
    let full: Arc<BTreeMap<String, Vec<f32>>> = Arc::new(
        roles.iter().map(|r| (r.clone(), srv.serve_all(r).unwrap())).collect(),
    );

    // ≥ 8 reader threads pulling seeded-random slices, each checked
    // bit-for-bit against the single-threaded full decode
    let threads: Vec<_> = (0..8u64)
        .map(|tid| {
            let srv = Arc::clone(&srv);
            let full = Arc::clone(&full);
            let roles = roles.clone();
            std::thread::spawn(move || {
                let mut rng = Rng::new(0xC0FFEE + tid);
                for _ in 0..200 {
                    let role = &roles[rng.below(roles.len())];
                    let want = &full[role];
                    let start = rng.below(want.len());
                    let count = rng.below(want.len() - start + 1);
                    let got = srv.serve_slice(role, start, count).unwrap();
                    let got: Vec<u32> = got.iter().map(|x| x.to_bits()).collect();
                    let want: Vec<u32> =
                        want[start..start + count].iter().map(|x| x.to_bits()).collect();
                    assert_eq!(got, want, "{role} [{start}, +{count})");
                }
            })
        })
        .collect();
    for h in threads {
        h.join().unwrap();
    }

    // opaque side frames refuse decoded serving but hand out raw bytes
    let side = srv
        .roles()
        .into_iter()
        .find(|r| r.starts_with("so."))
        .expect("run must produce side frames");
    let err = srv.serve_slice(&side, 0, 1).unwrap_err();
    assert!(format!("{err:#}").contains("opaque"), "{err:#}");
    assert!(!srv.read_raw(&side).unwrap().is_empty());
    fs::remove_dir_all(&dir).ok();
}
