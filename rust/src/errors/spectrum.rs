//! Spectrum construction for the paper's error analyses.
//!
//! The paper's A₁ is a real preconditioner from a Swin-Tiny run; we provide
//! (a) a spectrum-matched synthetic (log-linear decay with the Figure-6
//! condition number ≈ 37235) and (b) harvested spectra from our own training
//! runs (saved by the coordinator's shadow mode). A₂ is the paper's exact
//! two-level construction.

use crate::linalg::{qr::random_orthogonal, Mat};
use crate::util::rng::Rng;

/// Log-linearly decaying spectrum: λ_i = λmax · cond^{-i/(n-1)}.
pub fn loglinear_spectrum(n: usize, cond: f64) -> Vec<f32> {
    (0..n)
        .map(|i| (cond.powf(-(i as f64) / (n as f64 - 1.0))) as f32)
        .collect()
}

/// Paper's A₂: two distinct eigenvalues (m large ones = c·λ, n small = λ).
pub fn two_level_spectrum(n: usize, c: f64, lam: f64, m_large: usize) -> Vec<f32> {
    (0..n)
        .map(|i| if i < m_large { (c * lam) as f32 } else { lam as f32 })
        .collect()
}

/// PD matrix with the given spectrum and a random orthogonal eigenbasis.
pub fn pd_from_spectrum(vals: &[f32], rng: &mut Rng) -> Mat {
    let q = random_orthogonal(vals.len(), rng);
    Mat::sandwich(&q, vals)
}

/// Spectrum-matched A₁ analogue: cond(A) ≈ 37235 (Figure 6), log-linear.
pub fn synthetic_loglinear(n: usize, cond: f64, rng: &mut Rng) -> Mat {
    pd_from_spectrum(&loglinear_spectrum(n, cond), rng)
}

/// Paper's synthetic A₂.
pub fn synthetic_two_level(n: usize, c: f64, lam: f64, m_large: usize, rng: &mut Rng) -> Mat {
    pd_from_spectrum(&two_level_spectrum(n, c, lam, m_large), rng)
}

/// Contract a spectrum toward its minimum (Figure 6):
/// h(λ) = τ·(λ − λmin) + λmin.
pub fn contract_spectrum(vals: &[f32], tau: f64) -> Vec<f32> {
    let lam_min = vals.iter().cloned().fold(f32::INFINITY, f32::min) as f64;
    vals.iter()
        .map(|&l| (tau * (l as f64 - lam_min) + lam_min) as f32)
        .collect()
}

/// Condition number of a spectrum.
pub fn cond(vals: &[f32]) -> f64 {
    let mx = vals.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
    let mn = vals.iter().cloned().fold(f32::INFINITY, f32::min) as f64;
    mx / mn.max(1e-300)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::eigh;

    #[test]
    fn loglinear_has_requested_cond() {
        let s = loglinear_spectrum(100, 37235.0);
        assert!((cond(&s) - 37235.0).abs() / 37235.0 < 1e-3);
        assert!(s.windows(2).all(|w| w[0] >= w[1]));
    }

    #[test]
    fn two_level_counts() {
        let s = two_level_spectrum(10, 1000.0, 1e-3, 3);
        assert_eq!(s.iter().filter(|&&x| x > 0.5).count(), 3);
        assert!((cond(&s) - 1000.0).abs() < 1e-6 * 1000.0);
    }

    #[test]
    fn pd_from_spectrum_has_spectrum() {
        let mut rng = Rng::new(1);
        let vals = loglinear_spectrum(48, 100.0);
        let a = pd_from_spectrum(&vals, &mut rng);
        let mut got = eigh(&a).vals;
        got.reverse(); // descending like vals
        for (g, w) in got.iter().zip(&vals) {
            assert!((g - w).abs() < 1e-3 * w.abs().max(1e-3), "{g} vs {w}");
        }
    }

    #[test]
    fn contraction_shrinks_cond() {
        let s = loglinear_spectrum(64, 1e4);
        let c = contract_spectrum(&s, 0.01);
        assert!(cond(&c) < cond(&s) / 50.0);
        // tau = 1 is identity
        let id = contract_spectrum(&s, 1.0);
        for (a, b) in id.iter().zip(&s) {
            assert!((a - b).abs() < 1e-6);
        }
    }
}
