//! Quantization-error analysis: the machinery behind Tables 1/5/6/7 and
//! Figures 2/3/6 of the paper.
//!
//! Everything here is exact host-side math (order-1200 eigendecompositions
//! via `linalg::eigh`), independent of the artifacts — it validates the
//! *numeric format*, while the runtime path validates the *system*.

/// Synthetic spectra matching the paper's test matrices (A1/A2).
pub mod spectrum;

use crate::linalg::{bjorck, eigh, Mat};
use crate::quant::{dequantize_matrix_cols, quantize_matrix_cols, Mapping};

/// Normwise relative error ‖X−Y‖_F / ‖Y‖_F (paper §3.1).
pub fn nre(x: &Mat, y: &Mat) -> f64 {
    x.sub(y).frobenius() / y.frobenius().max(1e-300)
}

/// Angle error in degrees: arccos(⟨X,Y⟩/(‖X‖‖Y‖)) (paper §3.1).
pub fn angle_error_deg(x: &Mat, y: &Mat) -> f64 {
    let c = x.inner(y) / (x.frobenius() * y.frobenius()).max(1e-300);
    c.clamp(-1.0, 1.0).acos().to_degrees()
}

/// Which matrix is quantized (Table 1 "QM" column).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QuantTarget {
    /// The preconditioner A itself (diagonal kept in 32-bit — the paper's
    /// "slightly improved" naive arm).
    Precond,
    /// The eigenvector matrix U (ours).
    Eigen,
}

/// One quantization configuration under analysis (a Table-1 row).
#[derive(Debug, Clone, Copy)]
pub struct QuantScheme {
    /// Codebook mapping.
    pub mapping: Mapping,
    /// Storage bits per element.
    pub bits: u32,
    /// Which matrix is quantized.
    pub target: QuantTarget,
    /// Björck rectification iterations (0 = no OR).
    pub rectify: usize,
    /// Quantization block length.
    pub block: usize,
}

/// Result row of the Table-1 experiment.
#[derive(Debug, Clone)]
pub struct ErrorRow {
    /// The scheme measured.
    pub scheme: QuantScheme,
    /// Normwise relative error in f(A).
    pub nre: f64,
    /// Angle error in degrees in f(A).
    pub ae_deg: f64,
}

/// Quantization errors in f(A) = A^s of scheme at PD matrix A (Table 1,
/// s = -1/4). `exclude_diag_in_f`: measure in f(A) − Diag(diag(f(A)))
/// instead (Table 6).
pub fn quant_error_in_power(
    a: &Mat,
    s: f64,
    scheme: QuantScheme,
    exclude_diag_in_f: bool,
) -> ErrorRow {
    let n = a.rows;
    let cb = crate::quant::codebook(scheme.mapping, scheme.bits);
    let e = eigh(a);
    let f_exact = e.matrix_power(s, 1e-30);

    let f_quant = match scheme.target {
        QuantTarget::Precond => {
            // quantize A excluding its diagonal, then recompute the power
            let diag = a.diagonal();
            let mut off = a.clone();
            for i in 0..n {
                off[(i, i)] = 0.0;
            }
            let q = quantize_matrix_cols(&off.data, n, &cb, scheme.bits);
            let mut aq = Mat::from_vec(n, n, dequantize_matrix_cols(&q, n, &cb));
            // restore exact diagonal, resymmetrize (column-blocked
            // quantization breaks symmetry slightly)
            aq.symmetrize();
            for i in 0..n {
                aq[(i, i)] = diag[i];
            }
            // The paper defines A^s via SVD (§2 Notations): Λ holds
            // *singular values*, so eigenvalues pushed negative by
            // quantization enter as their magnitudes.
            eigh(&aq).apply_fn(|x| x.abs().max(1e-30).powf(s))
        }
        QuantTarget::Eigen => {
            let q = quantize_matrix_cols(&e.vecs.data, n, &cb, scheme.bits);
            let mut v = Mat::from_vec(n, n, dequantize_matrix_cols(&q, n, &cb));
            if scheme.rectify > 0 {
                v = bjorck(&v, scheme.rectify);
            }
            let d: Vec<f32> = e
                .vals
                .iter()
                .map(|&x| (x as f64).max(1e-30).powf(s) as f32)
                .collect();
            Mat::sandwich(&v, &d)
        }
    };

    let (fx, fy) = if exclude_diag_in_f {
        (strip_diag(&f_quant), strip_diag(&f_exact))
    } else {
        (f_quant, f_exact)
    };
    ErrorRow {
        scheme,
        nre: nre(&fx, &fy),
        ae_deg: angle_error_deg(&fx, &fy),
    }
}

fn strip_diag(a: &Mat) -> Mat {
    let mut out = a.clone();
    for i in 0..a.rows {
        out[(i, i)] = 0.0;
    }
    out
}

/// Figure 3: elementwise mean error between (VΛˢVᵀ)^{-1/s}·(VΛVᵀ) and I,
/// where V is the rectified quantized eigenbasis.
pub fn rectification_error(a: &Mat, s: f64, t2: usize, mapping: Mapping, bits: u32) -> f64 {
    let n = a.rows;
    let cb = crate::quant::codebook(mapping, bits);
    let e = eigh(a);
    let q = quantize_matrix_cols(&e.vecs.data, n, &cb, bits);
    let mut v = Mat::from_vec(n, n, dequantize_matrix_cols(&q, n, &cb));
    if t2 > 0 {
        v = bjorck(&v, t2);
    }
    let ds: Vec<f32> = e
        .vals
        .iter()
        .map(|&x| (x as f64).max(1e-30).powf(s) as f32)
        .collect();
    let vs = Mat::sandwich(&v, &ds);
    // (VΛˢVᵀ)^{-1/s}
    let inv = eigh(&vs).matrix_power(-1.0 / s, 1e-30);
    let va = Mat::sandwich(&v, &e.vals);
    let prod = inv.matmul(&va);
    let eye = Mat::eye(n);
    let diff = prod.sub(&eye);
    diff.data.iter().map(|&x| x.abs() as f64).sum::<f64>() / (n * n) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn metrics_basic() {
        let a = Mat::eye(4);
        let b = Mat::eye(4).scale(1.1);
        assert!(nre(&b, &a) > 0.09 && nre(&b, &a) < 0.11);
        assert!(angle_error_deg(&b, &a) < 1e-2); // parallel matrices
        let c = Mat::from_vec(2, 2, vec![0.0, 1.0, -1.0, 0.0]);
        let d = Mat::from_vec(2, 2, vec![1.0, 0.0, 0.0, 1.0]);
        assert!((angle_error_deg(&c, &d) - 90.0).abs() < 1e-2);
    }

    #[test]
    fn eigen_quantization_beats_precond_on_wide_spectrum() {
        // The paper's central claim (§3.1/§4) at a laptop-scale order.
        let mut rng = Rng::new(42);
        let a = spectrum::synthetic_two_level(256, 1000.0, 1e-3, 4, &mut rng);
        let base = QuantScheme {
            mapping: Mapping::Dt,
            bits: 4,
            target: QuantTarget::Precond,
            rectify: 0,
            block: 64,
        };
        let row_a = quant_error_in_power(&a, -0.25, base, false);
        let row_u = quant_error_in_power(
            &a,
            -0.25,
            QuantScheme { target: QuantTarget::Eigen, ..base },
            false,
        );
        assert!(
            row_u.nre < 0.5 * row_a.nre,
            "eigen {} vs precond {}",
            row_u.nre,
            row_a.nre
        );
    }

    #[test]
    fn rectification_reduces_error() {
        let mut rng = Rng::new(43);
        let a = spectrum::synthetic_loglinear(128, 3e4, &mut rng);
        let base = QuantScheme {
            mapping: Mapping::Linear2,
            bits: 4,
            target: QuantTarget::Eigen,
            rectify: 0,
            block: 64,
        };
        let without = quant_error_in_power(&a, -0.25, base, false);
        let with = quant_error_in_power(
            &a,
            -0.25,
            QuantScheme { rectify: 1, ..base },
            false,
        );
        assert!(with.nre < without.nre, "{} vs {}", with.nre, without.nre);
    }

    #[test]
    fn rectification_error_decreases_with_t2() {
        let mut rng = Rng::new(44);
        let a = spectrum::synthetic_loglinear(96, 1e4, &mut rng);
        let e0 = rectification_error(&a, -0.25, 0, Mapping::Linear2, 4);
        let e4 = rectification_error(&a, -0.25, 4, Mapping::Linear2, 4);
        assert!(e4 < e0, "{e4} vs {e0}");
    }
}
