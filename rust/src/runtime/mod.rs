//! PJRT runtime: loads AOT-compiled HLO-text artifacts (emitted by
//! python/compile/aot.py) and executes them on the CPU PJRT client.
//!
//! Wiring follows /opt/xla-example/load_hlo: `HloModuleProto::from_text_file`
//! → `XlaComputation::from_proto` → `client.compile` → `execute`. Artifacts
//! are compiled lazily on first use and cached for the process lifetime.

pub mod literal;
pub mod registry;

pub use literal::{HostTensor, TensorData};
pub use registry::{ArtifactSpec, IoSpec, Manifest, ModelSpec, Runtime};
