//! Execution backends behind one seam.
//!
//! Every consumer (trainer, second-order orchestration, benches) talks to a
//! [`Backend`]: named artifacts in, host tensors out. Two implementations:
//!
//!  * [`HostBackend`] — pure Rust, always available. Executes the PU / PIRU /
//!    precondition / model-step artifact semantics natively on the in-tree
//!    `linalg` + `quant` substrates against a synthesized manifest. This is
//!    the hermetic default: `cargo test` trains real models with it.
//!  * `PjrtBackend` (feature `pjrt`) — loads AOT-compiled HLO-text artifacts
//!    emitted by python/compile/aot.py and executes them on a PJRT client
//!    (`HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//!    `client.compile` → `execute`).
//!
//! Both validate inputs against the same [`Manifest`] spec and expose the
//! same per-artifact [`ExecStats`], so they are drop-in interchangeable.

/// Pure-Rust backend executing every artifact natively.
pub mod host;
/// Host tensors, the unit crossing every backend boundary.
pub mod literal;
/// The artifact/model manifest shared by every backend.
pub mod manifest;
/// PJRT artifact registry (feature `pjrt`).
#[cfg(feature = "pjrt")]
pub mod registry;

use std::collections::HashMap;
use std::path::Path;

use anyhow::Result;

pub use host::HostBackend;
pub use literal::{HostTensor, TensorData};
pub use manifest::{ArtifactSpec, ExecStats, IoSpec, Manifest, ModelSpec, ParamSpec};
#[cfg(feature = "pjrt")]
pub use registry::PjrtBackend;

/// The execution seam: everything the coordinator needs from a runtime.
///
/// `Send + Sync` is part of the contract: the coordinator's parallel block
/// engine (`coordinator::scheduler`) fans per-block PU / PIRU / precondition
/// tasks across worker threads that all execute against one shared backend,
/// so implementations must keep their bookkeeping behind interior-mutability
/// primitives that are thread-safe (`Mutex` / atomics, not `RefCell`).
pub trait Backend: Send + Sync {
    /// Human-readable platform tag ("host-cpu", PJRT platform name, ...).
    fn platform(&self) -> String;

    /// The artifact/model manifest this backend serves.
    fn manifest(&self) -> &Manifest;

    /// Execute an artifact by name. Inputs must match the manifest order.
    fn execute(&self, name: &str, inputs: &[HostTensor]) -> Result<Vec<HostTensor>>;

    /// Snapshot of per-artifact execution statistics.
    fn stats(&self) -> HashMap<String, ExecStats>;

    /// Whether the manifest serves an artifact by this name.
    fn has_artifact(&self, name: &str) -> bool {
        self.manifest().artifacts.contains_key(name)
    }

    /// The manifest spec for a named artifact.
    fn spec(&self, name: &str) -> Result<&ArtifactSpec> {
        self.manifest()
            .artifacts
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("unknown artifact {name}"))
    }

    /// Total wall-clock seconds spent inside execute calls.
    fn total_exec_secs(&self) -> f64 {
        self.stats().values().map(|s| s.total_secs).sum()
    }
}

/// Preferred backend for an artifact directory: PJRT when the build has the
/// feature, compiled artifacts exist, and the client comes up; the hermetic
/// host backend otherwise. Use `backend_by_name("pjrt", ..)` to surface PJRT
/// construction errors instead of falling back.
pub fn default_backend(artifact_dir: &Path) -> Result<Box<dyn Backend>> {
    #[cfg(feature = "pjrt")]
    if artifact_dir.join("manifest.json").exists() {
        match PjrtBackend::new(artifact_dir) {
            Ok(b) => return Ok(Box::new(b)),
            Err(e) => eprintln!("auto backend: pjrt unavailable ({e}); using host"),
        }
    }
    let _ = artifact_dir;
    Ok(Box::new(HostBackend::new()))
}

/// Backend by config/CLI name: "host", "pjrt", or "auto".
pub fn backend_by_name(name: &str, artifact_dir: &Path) -> Result<Box<dyn Backend>> {
    match name {
        "host" => Ok(Box::new(HostBackend::new())),
        "pjrt" => pjrt_backend(artifact_dir),
        "auto" | "" => default_backend(artifact_dir),
        other => anyhow::bail!("unknown backend {other:?} (expected host|pjrt|auto)"),
    }
}

#[cfg(feature = "pjrt")]
fn pjrt_backend(artifact_dir: &Path) -> Result<Box<dyn Backend>> {
    Ok(Box::new(PjrtBackend::new(artifact_dir)?))
}

#[cfg(not(feature = "pjrt"))]
fn pjrt_backend(_artifact_dir: &Path) -> Result<Box<dyn Backend>> {
    anyhow::bail!("this build has no `pjrt` feature; rebuild with --features pjrt")
}
