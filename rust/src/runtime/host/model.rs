//! Native model step/eval execution — the Rust mirror of
//! python/compile/model.py (MLP with manual backprop + K-FAC statistics;
//! decoder-only pre-LN transformer LM with hand-written backprop, validated
//! against finite differences).

use anyhow::{bail, Result};

use super::ops::mat2;
use crate::linalg::Mat;
use crate::runtime::literal::HostTensor;
use crate::runtime::manifest::ModelSpec;

// ---- shared pieces --------------------------------------------------------

/// Mean softmax cross-entropy; returns (loss, dlogits) with the 1/batch
/// already folded into dlogits (like python _softmax_xent).
fn softmax_xent(logits: &Mat, labels: &[i32]) -> Result<(f32, Mat)> {
    let (bs, c) = (logits.rows, logits.cols);
    let mut d = Mat::zeros(bs, c);
    let inv_bs = 1.0 / bs as f32;
    let mut loss = 0.0f64;
    for r in 0..bs {
        let row = logits.row(r);
        let yi = labels[r] as usize;
        if yi >= c {
            bail!("label {} out of range for {c} classes", labels[r]);
        }
        let zmax = row.iter().fold(f32::NEG_INFINITY, |m, &x| m.max(x));
        let mut sum = 0.0f64;
        for &x in row {
            sum += ((x - zmax) as f64).exp();
        }
        let lse = sum.ln();
        loss -= (row[yi] - zmax) as f64 - lse;
        let drow = d.row_mut(r);
        for (j, &x) in row.iter().enumerate() {
            drow[j] = (((x - zmax) as f64 - lse).exp() as f32) * inv_bs;
        }
        drow[yi] -= inv_bs;
    }
    Ok(((loss / bs as f64) as f32, d))
}

fn col_sums(m: &Mat) -> Vec<f32> {
    let mut out = vec![0.0f32; m.cols];
    for r in 0..m.rows {
        for (o, &x) in out.iter_mut().zip(m.row(r)) {
            *o += x;
        }
    }
    out
}

// ---- MLP ------------------------------------------------------------------

struct MlpForward {
    acts: Vec<Mat>,
    pre: Vec<Mat>,
}

fn mlp_forward(spec: &ModelSpec, inputs: &[HostTensor], x: Mat) -> Result<MlpForward> {
    let layers = spec.dims.len() - 1;
    let mut acts = vec![x];
    let mut pre = Vec::with_capacity(layers);
    for i in 0..layers {
        let w = mat2(&inputs[2 * i])?;
        let b = inputs[2 * i + 1].as_f32()?;
        let mut z = acts[i].matmul(&w);
        for r in 0..z.rows {
            for (zj, &bj) in z.row_mut(r).iter_mut().zip(b) {
                *zj += bj;
            }
        }
        pre.push(z.clone());
        if i < layers - 1 {
            for v in z.data.iter_mut() {
                *v = v.max(0.0);
            }
        }
        acts.push(z);
    }
    Ok(MlpForward { acts, pre })
}

/// mlp_*_step: forward + manual backward + the K-FAC statistics
/// (XᵀX/bs, δYᵀδY·bs) per layer. Output order matches aot.py:
/// loss, grad_w0, grad_b0, ..., stat_r0, stat_l0, stat_r1, ...
pub fn mlp_step(spec: &ModelSpec, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
    let layers = spec.dims.len() - 1;
    let np = 2 * layers;
    let bsz = spec.batch;
    let x = Mat::from_vec(bsz, spec.dims[0], inputs[np].as_f32()?.to_vec());
    let y = inputs[np + 1].as_i32()?;
    let fwd = mlp_forward(spec, inputs, x)?;
    let (loss, mut dz) = softmax_xent(&fwd.acts[layers], y)?;

    let mut grads: Vec<Option<HostTensor>> = (0..np).map(|_| None).collect();
    let mut stats_rev: Vec<(Mat, Mat)> = Vec::with_capacity(layers);
    for i in (0..layers).rev() {
        let a_in = &fwd.acts[i];
        let gw = a_in.transpose().matmul(&dz);
        let gb = col_sums(&dz);
        grads[2 * i] = Some(HostTensor::f32(&[gw.rows, gw.cols], gw.data));
        grads[2 * i + 1] = Some(HostTensor::f32(&[gb.len()], gb));
        // K-FAC statistics for layer i (Algorithm 5's R and L)
        let r_stat = a_in.gram_t().scale(1.0 / bsz as f32);
        let l_stat = dz.gram_t().scale(bsz as f32);
        stats_rev.push((r_stat, l_stat));
        if i > 0 {
            let w = mat2(&inputs[2 * i])?;
            let mut da = dz.matmul(&w.transpose());
            let pre_prev = &fwd.pre[i - 1];
            for (dv, &pv) in da.data.iter_mut().zip(&pre_prev.data) {
                if pv <= 0.0 {
                    *dv = 0.0;
                }
            }
            dz = da;
        }
    }

    let mut outs = vec![HostTensor::scalar_f32(loss)];
    outs.extend(grads.into_iter().map(|g| g.unwrap()));
    for (r, l) in stats_rev.into_iter().rev() {
        outs.push(HostTensor::f32(&[r.rows, r.cols], r.data));
        outs.push(HostTensor::f32(&[l.rows, l.cols], l.data));
    }
    Ok(outs)
}

/// mlp_*_eval: (mean loss, #correct).
pub fn mlp_eval(spec: &ModelSpec, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
    let layers = spec.dims.len() - 1;
    let np = 2 * layers;
    let bsz = spec.batch;
    let x = Mat::from_vec(bsz, spec.dims[0], inputs[np].as_f32()?.to_vec());
    let y = inputs[np + 1].as_i32()?;
    let fwd = mlp_forward(spec, inputs, x)?;
    let logits = &fwd.acts[layers];
    let (loss, _) = softmax_xent(logits, y)?;
    let mut correct = 0i32;
    for (r, &yi) in y.iter().enumerate() {
        let row = logits.row(r);
        let mut best = 0usize;
        for (j, &v) in row.iter().enumerate() {
            if v > row[best] {
                best = j;
            }
        }
        if best == yi as usize {
            correct += 1;
        }
    }
    Ok(vec![HostTensor::scalar_f32(loss), HostTensor::i32(&[], vec![correct])])
}

// ---- transformer LM -------------------------------------------------------

const SQRT_2_OVER_PI: f32 = 0.797_884_6;
const LN_EPS: f32 = 1e-5;

fn gelu(x: f32) -> f32 {
    0.5 * x * (1.0 + (SQRT_2_OVER_PI * (x + 0.044715 * x * x * x)).tanh())
}

fn dgelu(x: f32) -> f32 {
    let t = (SQRT_2_OVER_PI * (x + 0.044715 * x * x * x)).tanh();
    let dt = (1.0 - t * t) * SQRT_2_OVER_PI * (1.0 + 3.0 * 0.044715 * x * x);
    0.5 * (1.0 + t) + 0.5 * x * dt
}

/// Row-wise LayerNorm. Returns (y, xhat, 1/σ per row).
fn layernorm_fwd(x: &Mat, g: &[f32], b: &[f32]) -> (Mat, Mat, Vec<f32>) {
    let (n, d) = (x.rows, x.cols);
    let mut y = Mat::zeros(n, d);
    let mut xhat = Mat::zeros(n, d);
    let mut istd = Vec::with_capacity(n);
    for r in 0..n {
        let row = x.row(r);
        let mu = row.iter().map(|&v| v as f64).sum::<f64>() / d as f64;
        let var = row.iter().map(|&v| (v as f64 - mu) * (v as f64 - mu)).sum::<f64>() / d as f64;
        let is = 1.0 / (var + LN_EPS as f64).sqrt();
        istd.push(is as f32);
        for j in 0..d {
            let xh = ((row[j] as f64 - mu) * is) as f32;
            xhat[(r, j)] = xh;
            y[(r, j)] = xh * g[j] + b[j];
        }
    }
    (y, xhat, istd)
}

/// LayerNorm backward. Accumulates (dg, db), returns dx.
fn layernorm_bwd(
    dy: &Mat,
    xhat: &Mat,
    istd: &[f32],
    g: &[f32],
    dg: &mut [f32],
    db: &mut [f32],
) -> Mat {
    let (n, d) = (dy.rows, dy.cols);
    let mut dx = Mat::zeros(n, d);
    for r in 0..n {
        let dyr = dy.row(r);
        let xhr = xhat.row(r);
        let mut m1 = 0.0f64;
        let mut m2 = 0.0f64;
        for j in 0..d {
            let dxh = (dyr[j] * g[j]) as f64;
            m1 += dxh;
            m2 += dxh * xhr[j] as f64;
            dg[j] += dyr[j] * xhr[j];
            db[j] += dyr[j];
        }
        m1 /= d as f64;
        m2 /= d as f64;
        let dxr = dx.row_mut(r);
        for j in 0..d {
            let dxh = (dyr[j] * g[j]) as f64;
            dxr[j] = (istd[r] as f64 * (dxh - m1 - xhr[j] as f64 * m2)) as f32;
        }
    }
    dx
}

struct TlmDims {
    b: usize,
    t: usize,
    d: usize,
    h: usize,
    hd: usize,
    vocab: usize,
    layers: usize,
}

fn tlm_dims(spec: &ModelSpec) -> Result<TlmDims> {
    let d = spec.params[0].shape[1]; // embed (V, d)
    let layers = spec.params.iter().filter(|p| p.name.ends_with(".wqkv")).count();
    let h = spec.heads.max(1);
    if d % h != 0 {
        bail!("d_model {d} not divisible by {h} heads");
    }
    Ok(TlmDims { b: spec.batch, t: spec.seq, d, h, hd: d / h, vocab: spec.vocab, layers })
}

struct LayerCache {
    h1: Mat,
    xhat1: Mat,
    istd1: Vec<f32>,
    qkv: Mat,
    /// softmax attention weights, (b·h·t + t_query)·t + t_key layout
    atts: Vec<f32>,
    attn_out: Mat,
    h2: Mat,
    xhat2: Mat,
    istd2: Vec<f32>,
    u: Mat,
    act: Mat,
}

/// Causal single-layer attention forward. Returns (attn_out, atts).
fn attention_fwd(qkv: &Mat, dm: &TlmDims) -> (Mat, Vec<f32>) {
    let (bt, d, t, h, hd) = (qkv.rows, dm.d, dm.t, dm.h, dm.hd);
    let scale = 1.0 / (hd as f32).sqrt();
    let mut out = Mat::zeros(bt, d);
    let mut atts = vec![0.0f32; dm.b * h * t * t];
    let mut row = vec![0.0f32; t];
    for b in 0..dm.b {
        for hh in 0..h {
            let att_base = (b * h + hh) * t * t;
            for tq in 0..t {
                let rq = (b * t + tq) * 3 * d + hh * hd;
                // scores over keys 0..=tq, max-subtracted softmax
                let mut mx = f32::NEG_INFINITY;
                for (tk, rv) in row.iter_mut().enumerate().take(tq + 1) {
                    let rk = (b * t + tk) * 3 * d + d + hh * hd;
                    let mut dot = 0.0f32;
                    for c in 0..hd {
                        dot += qkv.data[rq + c] * qkv.data[rk + c];
                    }
                    let s = dot * scale;
                    *rv = s;
                    mx = mx.max(s);
                }
                let mut sum = 0.0f64;
                for rv in row.iter_mut().take(tq + 1) {
                    let e = ((*rv - mx) as f64).exp();
                    *rv = e as f32;
                    sum += e;
                }
                let inv = (1.0 / sum) as f32;
                let orow = (b * t + tq) * d + hh * hd;
                for tk in 0..=tq {
                    let a = row[tk] * inv;
                    atts[att_base + tq * t + tk] = a;
                    let rv = (b * t + tk) * 3 * d + 2 * d + hh * hd;
                    for c in 0..hd {
                        out.data[orow + c] += a * qkv.data[rv + c];
                    }
                }
            }
        }
    }
    (out, atts)
}

/// Attention backward: d(attn_out) → d(qkv).
fn attention_bwd(dout: &Mat, qkv: &Mat, atts: &[f32], dm: &TlmDims) -> Mat {
    let (d, t, h, hd) = (dm.d, dm.t, dm.h, dm.hd);
    let scale = 1.0 / (hd as f32).sqrt();
    let mut dqkv = Mat::zeros(qkv.rows, 3 * d);
    let mut datt = vec![0.0f32; t];
    for b in 0..dm.b {
        for hh in 0..h {
            let att_base = (b * h + hh) * t * t;
            for tq in 0..t {
                let do_row = (b * t + tq) * d + hh * hd;
                // dV[tk] += att[tq,tk]·dO[tq]  and  dAtt[tq,tk] = dO[tq]·V[tk]
                let mut tmp = 0.0f64;
                for tk in 0..=tq {
                    let a = atts[att_base + tq * t + tk];
                    let rv = (b * t + tk) * 3 * d + 2 * d + hh * hd;
                    let mut da = 0.0f32;
                    for c in 0..hd {
                        let g = dout.data[do_row + c];
                        dqkv.data[rv + c] += a * g;
                        da += g * qkv.data[rv + c];
                    }
                    datt[tk] = da;
                    tmp += (da * a) as f64;
                }
                // dS = att ⊙ (dAtt − Σ dAtt⊙att); dQ += dS·K·s; dK += dS·Q·s
                let rq = (b * t + tq) * 3 * d + hh * hd;
                for tk in 0..=tq {
                    let a = atts[att_base + tq * t + tk];
                    let ds = a * (datt[tk] - tmp as f32) * scale;
                    if ds == 0.0 {
                        continue;
                    }
                    let rk = (b * t + tk) * 3 * d + d + hh * hd;
                    for c in 0..hd {
                        dqkv.data[rq + c] += ds * qkv.data[rk + c];
                        dqkv.data[rk + c] += ds * qkv.data[rq + c];
                    }
                }
            }
        }
    }
    dqkv
}

struct TlmForward {
    caches: Vec<LayerCache>,
    xf: Mat,
    xhatf: Mat,
    istdf: Vec<f32>,
    logits: Mat,
    inp: Vec<usize>,
    tgt: Vec<i32>,
}

fn tlm_forward(
    spec: &ModelSpec,
    inputs: &[HostTensor],
    dm: &TlmDims,
    with_caches: bool,
) -> Result<TlmForward> {
    let np = spec.params.len();
    let tokens = inputs[np].as_i32()?;
    let (b, t, d) = (dm.b, dm.t, dm.d);
    let bt = b * t;
    let embed = mat2(&inputs[0])?;
    let pos = mat2(&inputs[1])?;
    let mut inp = Vec::with_capacity(bt);
    let mut tgt = Vec::with_capacity(bt);
    let mut x = Mat::zeros(bt, d);
    for bb in 0..b {
        for tt in 0..t {
            let tok = tokens[bb * (t + 1) + tt];
            if tok < 0 || tok as usize >= dm.vocab {
                bail!("token {tok} out of vocab range {}", dm.vocab);
            }
            inp.push(tok as usize);
            tgt.push(tokens[bb * (t + 1) + tt + 1]);
            let r = bb * t + tt;
            let xr = x.row_mut(r);
            xr.copy_from_slice(embed.row(tok as usize));
            for (xv, &pv) in xr.iter_mut().zip(pos.row(tt)) {
                *xv += pv;
            }
        }
    }

    let mut caches = Vec::with_capacity(if with_caches { dm.layers } else { 0 });
    for i in 0..dm.layers {
        let base = 2 + 8 * i;
        let ln1_g = inputs[base].as_f32()?;
        let ln1_b = inputs[base + 1].as_f32()?;
        let wqkv = mat2(&inputs[base + 2])?;
        let wo = mat2(&inputs[base + 3])?;
        let ln2_g = inputs[base + 4].as_f32()?;
        let ln2_b = inputs[base + 5].as_f32()?;
        let w1 = mat2(&inputs[base + 6])?;
        let w2 = mat2(&inputs[base + 7])?;

        let (h1, xhat1, istd1) = layernorm_fwd(&x, ln1_g, ln1_b);
        let qkv = h1.matmul(&wqkv);
        let (attn_out, atts) = attention_fwd(&qkv, dm);
        let proj = attn_out.matmul(&wo);
        let x_mid = x.add(&proj);

        let (h2, xhat2, istd2) = layernorm_fwd(&x_mid, ln2_g, ln2_b);
        let u = h2.matmul(&w1);
        let mut act = u.clone();
        for v in act.data.iter_mut() {
            *v = gelu(*v);
        }
        let f_out = act.matmul(&w2);
        x = x_mid.add(&f_out);

        if with_caches {
            caches.push(LayerCache {
                h1,
                xhat1,
                istd1,
                qkv,
                atts,
                attn_out,
                h2,
                xhat2,
                istd2,
                u,
                act,
            });
        }
    }

    let lnf_g = inputs[np - 2].as_f32()?;
    let lnf_b = inputs[np - 1].as_f32()?;
    let (xf, xhatf, istdf) = layernorm_fwd(&x, lnf_g, lnf_b);
    let logits = xf.matmul(&embed.transpose()); // tied head
    Ok(TlmForward { caches, xf, xhatf, istdf, logits, inp, tgt })
}

/// tlm_*_step: next-token cross-entropy loss + gradients for every
/// parameter, in manifest order.
pub fn tlm_step(spec: &ModelSpec, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
    let dm = tlm_dims(spec)?;
    let np = spec.params.len();
    let fwd = tlm_forward(spec, inputs, &dm, true)?;
    let (loss, dlogits) = softmax_xent(&fwd.logits, &fwd.tgt)?;

    let embed = mat2(&inputs[0])?;
    let mut grads: Vec<Vec<f32>> =
        spec.params.iter().map(|p| vec![0.0f32; p.shape.iter().product()]).collect();

    // tied head: logits = xf·embedᵀ
    let d_embed_head = dlogits.transpose().matmul(&fwd.xf);
    grads[0].copy_from_slice(&d_embed_head.data);
    let dxf = dlogits.matmul(&embed);
    let (gf, bf) = grads.split_at_mut(np - 1);
    let mut dx = layernorm_bwd(
        &dxf,
        &fwd.xhatf,
        &fwd.istdf,
        inputs[np - 2].as_f32()?,
        &mut gf[np - 2],
        &mut bf[0],
    );

    for i in (0..dm.layers).rev() {
        let base = 2 + 8 * i;
        let wqkv = mat2(&inputs[base + 2])?;
        let wo = mat2(&inputs[base + 3])?;
        let w1 = mat2(&inputs[base + 6])?;
        let w2 = mat2(&inputs[base + 7])?;
        let cc = &fwd.caches[i];

        // MLP branch: x = x_mid + gelu(LN2(x_mid)·w1)·w2
        let dact = dx.matmul(&w2.transpose());
        let dw2 = cc.act.transpose().matmul(&dx);
        grads[base + 7].copy_from_slice(&dw2.data);
        let mut du = dact;
        for (dv, &uv) in du.data.iter_mut().zip(&cc.u.data) {
            *dv *= dgelu(uv);
        }
        let dw1 = cc.h2.transpose().matmul(&du);
        grads[base + 6].copy_from_slice(&dw1.data);
        let dh2 = du.matmul(&w1.transpose());
        {
            let (ga, gb) = grads.split_at_mut(base + 5);
            let dx2 = layernorm_bwd(
                &dh2,
                &cc.xhat2,
                &cc.istd2,
                inputs[base + 4].as_f32()?,
                &mut ga[base + 4],
                &mut gb[0],
            );
            dx = dx.add(&dx2);
        }

        // attention branch: x_mid = x_in + (attn_out·wo)
        let dwo = cc.attn_out.transpose().matmul(&dx);
        grads[base + 3].copy_from_slice(&dwo.data);
        let dattn_out = dx.matmul(&wo.transpose());
        let dqkv = attention_bwd(&dattn_out, &cc.qkv, &cc.atts, &dm);
        let dwqkv = cc.h1.transpose().matmul(&dqkv);
        grads[base + 2].copy_from_slice(&dwqkv.data);
        let dh1 = dqkv.matmul(&wqkv.transpose());
        {
            let (ga, gb) = grads.split_at_mut(base + 1);
            let dx1 = layernorm_bwd(
                &dh1,
                &cc.xhat1,
                &cc.istd1,
                inputs[base].as_f32()?,
                &mut ga[base],
                &mut gb[0],
            );
            dx = dx.add(&dx1);
        }
    }

    // embedding gather + learned positions
    for (r, &tok) in fwd.inp.iter().enumerate() {
        let row = dx.row(r);
        let ebase = tok * dm.d;
        for (c, &v) in row.iter().enumerate() {
            grads[0][ebase + c] += v;
        }
        let pbase = (r % dm.t) * dm.d;
        for (c, &v) in row.iter().enumerate() {
            grads[1][pbase + c] += v;
        }
    }

    let mut outs = vec![HostTensor::scalar_f32(loss)];
    for (g, p) in grads.into_iter().zip(&spec.params) {
        outs.push(HostTensor::f32(&p.shape, g));
    }
    Ok(outs)
}

/// tlm_*_eval: loss only.
pub fn tlm_eval(spec: &ModelSpec, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
    let dm = tlm_dims(spec)?;
    let fwd = tlm_forward(spec, inputs, &dm, false)?;
    let (loss, _) = softmax_xent(&fwd.logits, &fwd.tgt)?;
    Ok(vec![HostTensor::scalar_f32(loss)])
}
