//! Native execution of the Shampoo / quantizer / first-order artifact
//! semantics (the Rust mirror of python/compile/shampoo.py and optim1.py),
//! built on the in-tree `linalg` and `quant` substrates.
//!
//! Boundary format: quantized square matrices travel as
//! (codes u8 [n²/qb, qb] column-blocked, scales f32 [n²/qb]) with
//! qb the `matrix_layout` block (n when n ≤ 64, else the largest divisor
//! of n ≤ 64), plus the 16-entry runtime codebook — identical to the
//! AOT artifacts, so backends are interchangeable per call.

use anyhow::{bail, Context, Result};

use crate::linalg::{bjorck, orthogonalize_cgs2, power_iteration, schur_newton_invroot, Mat};
use crate::quant::{dequantize_matrix_cols, pack_bits, quantize_matrix_cols, QuantizedVec};
use crate::runtime::literal::HostTensor;

// ---- boundary marshaling --------------------------------------------------

/// Interpret a 2-D f32 tensor as a dense matrix.
pub fn mat2(t: &HostTensor) -> Result<Mat> {
    if t.shape.len() != 2 {
        bail!("expected 2-D tensor, got shape {:?}", t.shape);
    }
    Ok(Mat::from_vec(t.shape[0], t.shape[1], t.as_f32()?.to_vec()))
}

/// Read a scalar f32 input.
pub fn scalar(t: &HostTensor) -> Result<f32> {
    Ok(t.as_f32()?[0])
}

fn mat_tensor(m: &Mat) -> HostTensor {
    HostTensor::f32(&[m.rows, m.cols], m.data.clone())
}

/// Rebuild a column-blocked quantized order-n matrix from boundary tensors.
pub fn dequant_cols(codes: &HostTensor, scales: &HostTensor, cb: &[f32]) -> Result<Mat> {
    let raw = codes.as_u8()?;
    let qb = *codes.shape.last().context("codes must be 2-D")?;
    let n = (raw.len() as f64).sqrt().round() as usize;
    if n * n != raw.len() {
        bail!("codes length {} is not a square", raw.len());
    }
    // value-range check: shape validation can't see this, and release-mode
    // pack_bits would silently bleed out-of-range codes into neighbors
    if let Some(&c) = raw.iter().find(|&&c| (c as usize) >= cb.len()) {
        bail!("code {c} out of range for {}-entry codebook", cb.len());
    }
    let q = QuantizedVec {
        packed: pack_bits(raw, 4),
        scales: scales.as_f32()?.to_vec(),
        len: raw.len(),
        bits: 4,
        block: qb,
        col: None,
    };
    Ok(Mat::from_vec(n, n, dequantize_matrix_cols(&q, n, cb)))
}

/// Quantize an order-n matrix into boundary tensors (codes, scales).
pub fn quant_cols_tensors(a: &Mat, cb: &[f32]) -> (HostTensor, HostTensor) {
    let n = a.rows;
    let q = quantize_matrix_cols(&a.data, n, cb, 4);
    // the artifact boundary is a rectangular (nblocks, block) grid; every
    // order with a usable divisor block has one (per-column fallback
    // layouts — prime n > 64 — have no grid and cannot travel here)
    assert!(q.col.is_none(), "order {n} has no rectangular block grid");
    let qb = q.block;
    let nb = q.scales.len();
    (HostTensor::u8(&[nb, qb], q.codes_u8()), HostTensor::f32(&[nb], q.scales))
}

/// Grafting trick (Algorithm 3 line 14): G̃ = Ĝ·(‖G‖_F/‖Ĝ‖_F).
fn graft(g: &Mat, ghat: Mat) -> Mat {
    let ng = g.frobenius();
    let nh = ghat.frobenius().max(1e-30);
    ghat.scale((ng / nh) as f32)
}

fn zero_diag(mut a: Mat) -> Mat {
    for i in 0..a.rows {
        a[(i, i)] = 0.0;
    }
    a
}

/// Rebuild Â = Diag(diag) + offdiag(codes) (Algorithm 3 line 13).
fn dequant_invroot(
    diag: &[f32],
    codes: &HostTensor,
    scales: &HostTensor,
    cb: &[f32],
) -> Result<Mat> {
    let mut m = dequant_cols(codes, scales, cb)?;
    for (i, &d) in diag.iter().enumerate() {
        m[(i, i)] = d;
    }
    Ok(m)
}

/// Split a symmetric matrix into (32-bit diag, quantized off-diagonal).
fn quant_sym(a: &Mat, cb: &[f32]) -> Vec<HostTensor> {
    let diag = a.diagonal();
    let off = zero_diag(a.clone());
    let (codes, scales) = quant_cols_tensors(&off, cb);
    vec![HostTensor::f32(&[diag.len()], diag), codes, scales]
}

// ---- Shampoo artifact families -------------------------------------------

/// gram_{m}x{n}: (G·Gᵀ, Gᵀ·G) statistics (Algorithm 3 line 6).
pub fn gram(g: &Mat) -> Vec<HostTensor> {
    vec![mat_tensor(&g.gram()), mat_tensor(&g.gram_t())]
}

/// pu_{n} / pu_kfac_128 — Algorithm 1 (PU): rebuild A = β·VΛVᵀ + (1−β)·M
/// from the quantized eigenbasis, re-diagonalize by warm-started subspace
/// iteration (CGS2 orthogonalizer), requantize.
pub fn pu_quantized(
    lam: &[f32],
    codes: &HostTensor,
    scales: &HostTensor,
    m_stat: &Mat,
    beta: f32,
    cb: &[f32],
    sub_iters: usize,
) -> Result<Vec<HostTensor>> {
    let v = dequant_cols(codes, scales, cb)?;
    let mut v = bjorck(&v, 1);
    let a = Mat::sandwich(&v, lam).scale(beta).add(&m_stat.scale(1.0 - beta));
    for _ in 0..sub_iters {
        v = orthogonalize_cgs2(&a.matmul(&v));
    }
    let av = a.matmul(&v);
    let n = lam.len();
    let lam_new: Vec<f32> = (0..n)
        .map(|j| (0..n).map(|i| v[(i, j)] as f64 * av[(i, j)] as f64).sum::<f64>() as f32)
        .collect();
    let (codes_new, scales_new) = quant_cols_tensors(&v, cb);
    Ok(vec![HostTensor::f32(&[n], lam_new), codes_new, scales_new])
}

/// piru{,_e2,_e1}_{n} — Algorithm 2 (PIRU): Â = V(Λ + max{λ}εI)ˢVᵀ stored as
/// (diag(Â), Q(Â − Diag(diag Â))). s = −1/4 Shampoo, −1/2 AdaBK, −1 K-FAC.
pub fn piru_quantized(
    lam: &[f32],
    codes: &HostTensor,
    scales: &HostTensor,
    eps: f32,
    cb: &[f32],
    exponent: f32,
) -> Result<Vec<HostTensor>> {
    let v = dequant_cols(codes, scales, cb)?;
    let v = bjorck(&v, 4);
    let lam_max = lam.iter().fold(f32::NEG_INFINITY, |m, &x| m.max(x));
    let ridge = lam_max * eps;
    let d: Vec<f32> = lam.iter().map(|&l| (l + ridge).max(1e-30).powf(exponent)).collect();
    let a_hat = Mat::sandwich(&v, &d);
    Ok(quant_sym(&a_hat, cb))
}

/// pu_naive_{n}: A ← β·D(Ā) + (1−β)·M on the directly-quantized arm.
pub fn pu_naive(
    diag: &[f32],
    codes: &HostTensor,
    scales: &HostTensor,
    m_stat: &Mat,
    beta: f32,
    cb: &[f32],
) -> Result<Vec<HostTensor>> {
    let a = dequant_invroot(diag, codes, scales, cb)?;
    let a = a.scale(beta).add(&m_stat.scale(1.0 - beta));
    Ok(quant_sym(&a, cb))
}

/// invroot_naive_{n}: Schur–Newton A^{-1/4} of the dequantized
/// preconditioner, requantized (Algorithm 4 lines 8–9 on the naive arm).
pub fn invroot_naive(
    diag: &[f32],
    codes: &HostTensor,
    scales: &HostTensor,
    eps: f32,
    cb: &[f32],
) -> Result<Vec<HostTensor>> {
    let a = dequant_invroot(diag, codes, scales, cb)?;
    let lam_max = power_iteration(&a, 10).max(1e-30);
    let a_hat = schur_newton_invroot(&a.add_scaled_eye(lam_max * eps), 4, 15);
    Ok(quant_sym(&a_hat, cb))
}

/// pu_dense_{n}: L ← β·L + (1−β)·M (Algorithm 4, 32-bit baseline).
pub fn pu_dense(l: &Mat, m_stat: &Mat, beta: f32) -> Vec<HostTensor> {
    vec![mat_tensor(&l.scale(beta).add(&m_stat.scale(1.0 - beta)))]
}

/// invroot_dense{,_e2,_e1}_{n}: (L + λmax·ε·I)^{-1/p} by Schur–Newton.
pub fn invroot_dense(l: &Mat, eps: f32, p: u32) -> Vec<HostTensor> {
    let lam_max = power_iteration(l, 10).max(1e-30);
    vec![mat_tensor(&schur_newton_invroot(&l.add_scaled_eye(lam_max * eps), p, 15))]
}

/// precond32_{m}x{n} / caspr32_{m}x{n}: grafted L̂GR̂ (or the CASPR variant).
pub fn precond_dense(g: &Mat, lhat: &Mat, rhat: &Mat, caspr: bool) -> Vec<HostTensor> {
    let ghat = if caspr {
        let j = lhat.matmul(g).add(&g.matmul(rhat));
        lhat.matmul(&j).add(&j.matmul(rhat))
    } else {
        lhat.matmul(g).matmul(rhat)
    };
    vec![mat_tensor(&graft(g, ghat))]
}

/// precond4_{m}x{n} / caspr4_{m}x{n}: 4-bit states on both sides.
#[allow(clippy::too_many_arguments)]
pub fn precond_4bit(
    g: &Mat,
    l_diag: &[f32],
    l_codes: &HostTensor,
    l_scales: &HostTensor,
    r_diag: &[f32],
    r_codes: &HostTensor,
    r_scales: &HostTensor,
    cb: &[f32],
    caspr: bool,
) -> Result<Vec<HostTensor>> {
    let lhat = dequant_invroot(l_diag, l_codes, l_scales, cb)?;
    let rhat = dequant_invroot(r_diag, r_codes, r_scales, cb)?;
    Ok(precond_dense(g, &lhat, &rhat, caspr))
}

// ---- first-order updates --------------------------------------------------

/// sgdm_update_4096: classic (non-decoupled) weight decay, PyTorch semantics.
pub fn sgdm_update(
    p: &[f32],
    buf: &[f32],
    g: &[f32],
    lr: f32,
    momentum: f32,
    wd: f32,
) -> Vec<HostTensor> {
    let n = p.len();
    let mut p_new = Vec::with_capacity(n);
    let mut b_new = Vec::with_capacity(n);
    for i in 0..n {
        let gi = g[i] + wd * p[i];
        let bi = momentum * buf[i] + gi;
        p_new.push(p[i] - lr * bi);
        b_new.push(bi);
    }
    vec![HostTensor::f32(&[n], p_new), HostTensor::f32(&[n], b_new)]
}

/// adamw_update_4096: decoupled weight decay + bias correction.
#[allow(clippy::too_many_arguments)]
pub fn adamw_update(
    p: &[f32],
    m: &[f32],
    v: &[f32],
    g: &[f32],
    step: f32,
    lr: f32,
    beta1: f32,
    beta2: f32,
    eps: f32,
    wd: f32,
) -> Vec<HostTensor> {
    let n = p.len();
    let bc1 = 1.0 - beta1.powf(step);
    let bc2 = 1.0 - beta2.powf(step);
    let mut p_new = Vec::with_capacity(n);
    let mut m_new = Vec::with_capacity(n);
    let mut v_new = Vec::with_capacity(n);
    for i in 0..n {
        let mi = beta1 * m[i] + (1.0 - beta1) * g[i];
        let vi = beta2 * v[i] + (1.0 - beta2) * g[i] * g[i];
        let mh = mi / bc1;
        let vh = vi / bc2;
        p_new.push(p[i] - lr * (mh / (vh.sqrt() + eps) + wd * p[i]));
        m_new.push(mi);
        v_new.push(vi);
    }
    vec![
        HostTensor::f32(&[n], p_new),
        HostTensor::f32(&[n], m_new),
        HostTensor::f32(&[n], v_new),
    ]
}
