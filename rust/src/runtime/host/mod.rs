//! The pure-Rust execution backend: serves every artifact the AOT pipeline
//! would emit — PU / PIRU / inverse roots / preconditioning / model steps /
//! first-order updates — natively on the in-tree `linalg`, `quant`, and
//! model substrates, against a manifest synthesized to match aot.py exactly
//! (same names, same I/O specs, same bucket set). No Python, no XLA.

/// Native MLP + transformer-LM step/eval (hand-written fwd/bwd).
pub mod model;
/// Native Shampoo/quantizer artifact semantics on `linalg` + `quant`.
pub mod ops;

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

use anyhow::{bail, Result};

use super::literal::HostTensor;
use super::manifest::{ArtifactSpec, ExecStats, IoSpec, Manifest, ModelSpec, ParamSpec};
use super::Backend;
use crate::util::timer::Stopwatch;

/// Bucket orders every backend serves (mirrors aot.py ALL_BUCKETS).
pub const ALL_BUCKETS: [usize; 3] = [32, 64, 128];
/// Orders with quantized-state artifacts (paper: ≥ 4096 elements).
pub const QUANT_BUCKETS: [usize; 2] = [64, 128];
/// K-FAC/AdaBK whole-layer orders add 256 to the bucket artifacts.
const BUCKETS_WITH_KFAC: [usize; 3] = [64, 128, 256];
const DENSE_BUCKETS: [usize; 4] = [32, 64, 128, 256];
const CB_LEN: usize = 16;

/// Per-artifact execution tally: `(calls, total nanoseconds)`. Lock-free so
/// concurrent `execute` calls never contend on a stats mutex.
type StatCell = Arc<(AtomicU64, AtomicU64)>;

/// The hermetic pure-Rust [`Backend`]: always available, trains real
/// models with zero external dependencies.
pub struct HostBackend {
    manifest: Manifest,
    // `execute` is called concurrently by the parallel block engine's
    // workers and the shard workers' schedulers; dispatch itself is pure,
    // and the tally is atomic counters behind an RwLock'd map — the steady
    // state (every artifact already seen) is a read lock + two relaxed
    // atomic adds, with the write lock taken once per artifact name.
    stats: RwLock<HashMap<String, StatCell>>,
}

impl HostBackend {
    /// Backend over the synthesized manifest (no filesystem access).
    pub fn new() -> Self {
        Self { manifest: synthetic_manifest(), stats: RwLock::new(HashMap::new()) }
    }

    /// The counter cell for artifact `name` (insert-once on first sight).
    fn stat_cell(&self, name: &str) -> StatCell {
        if let Some(cell) = self.stats.read().expect("stats lock").get(name) {
            return Arc::clone(cell);
        }
        let mut map = self.stats.write().expect("stats lock");
        Arc::clone(
            map.entry(name.to_string())
                .or_insert_with(|| Arc::new((AtomicU64::new(0), AtomicU64::new(0)))),
        )
    }

    fn dispatch(&self, name: &str, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        for m in self.manifest.models.values() {
            if m.step == name {
                return match m.kind.as_str() {
                    "mlp" => model::mlp_step(m, inputs),
                    "tlm" => model::tlm_step(m, inputs),
                    other => bail!("unknown model kind {other}"),
                };
            }
            if m.eval == name {
                return match m.kind.as_str() {
                    "mlp" => model::mlp_eval(m, inputs),
                    "tlm" => model::tlm_eval(m, inputs),
                    other => bail!("unknown model kind {other}"),
                };
            }
        }
        if name.starts_with("gram_") {
            return Ok(ops::gram(&ops::mat2(&inputs[0])?));
        }
        if name.starts_with("pu_dense_") {
            return Ok(ops::pu_dense(
                &ops::mat2(&inputs[0])?,
                &ops::mat2(&inputs[1])?,
                ops::scalar(&inputs[2])?,
            ));
        }
        if name.starts_with("invroot_dense") {
            let p = if name.contains("_e1_") {
                1
            } else if name.contains("_e2_") {
                2
            } else {
                4
            };
            return Ok(ops::invroot_dense(&ops::mat2(&inputs[0])?, ops::scalar(&inputs[1])?, p));
        }
        if name.starts_with("pu_naive_") {
            return ops::pu_naive(
                inputs[0].as_f32()?,
                &inputs[1],
                &inputs[2],
                &ops::mat2(&inputs[3])?,
                ops::scalar(&inputs[4])?,
                inputs[5].as_f32()?,
            );
        }
        if name.starts_with("invroot_naive_") {
            return ops::invroot_naive(
                inputs[0].as_f32()?,
                &inputs[1],
                &inputs[2],
                ops::scalar(&inputs[3])?,
                inputs[4].as_f32()?,
            );
        }
        if name.starts_with("pu_") {
            // aot.py: Shampoo/CASPR use one subspace iteration, K-FAC/AdaBK
            // (order 256 + the dedicated kfac artifact) use two.
            let sub_iters = if name == "pu_kfac_128" || name.ends_with("_256") { 2 } else { 1 };
            return ops::pu_quantized(
                inputs[0].as_f32()?,
                &inputs[1],
                &inputs[2],
                &ops::mat2(&inputs[3])?,
                ops::scalar(&inputs[4])?,
                inputs[5].as_f32()?,
                sub_iters,
            );
        }
        if name.starts_with("piru") {
            let expo = if name.starts_with("piru_e1_") {
                -1.0
            } else if name.starts_with("piru_e2_") {
                -0.5
            } else {
                -0.25
            };
            return ops::piru_quantized(
                inputs[0].as_f32()?,
                &inputs[1],
                &inputs[2],
                ops::scalar(&inputs[3])?,
                inputs[4].as_f32()?,
                expo,
            );
        }
        if name.starts_with("precond32_") || name.starts_with("caspr32_") {
            return Ok(ops::precond_dense(
                &ops::mat2(&inputs[0])?,
                &ops::mat2(&inputs[1])?,
                &ops::mat2(&inputs[2])?,
                name.starts_with("caspr"),
            ));
        }
        if name.starts_with("precond4_") || name.starts_with("caspr4_") {
            return ops::precond_4bit(
                &ops::mat2(&inputs[0])?,
                inputs[1].as_f32()?,
                &inputs[2],
                &inputs[3],
                inputs[4].as_f32()?,
                &inputs[5],
                &inputs[6],
                inputs[7].as_f32()?,
                name.starts_with("caspr"),
            );
        }
        if name.starts_with("quant_cols_") {
            let (c, s) = ops::quant_cols_tensors(&ops::mat2(&inputs[0])?, inputs[1].as_f32()?);
            return Ok(vec![c, s]);
        }
        if name.starts_with("dequant_cols_") {
            let m = ops::dequant_cols(&inputs[0], &inputs[1], inputs[2].as_f32()?)?;
            return Ok(vec![HostTensor::f32(&[m.rows, m.cols], m.data)]);
        }
        if name == "sgdm_update_4096" {
            return Ok(ops::sgdm_update(
                inputs[0].as_f32()?,
                inputs[1].as_f32()?,
                inputs[2].as_f32()?,
                ops::scalar(&inputs[3])?,
                ops::scalar(&inputs[4])?,
                ops::scalar(&inputs[5])?,
            ));
        }
        if name == "adamw_update_4096" {
            return Ok(ops::adamw_update(
                inputs[0].as_f32()?,
                inputs[1].as_f32()?,
                inputs[2].as_f32()?,
                inputs[3].as_f32()?,
                ops::scalar(&inputs[4])?,
                ops::scalar(&inputs[5])?,
                ops::scalar(&inputs[6])?,
                ops::scalar(&inputs[7])?,
                ops::scalar(&inputs[8])?,
                ops::scalar(&inputs[9])?,
            ));
        }
        bail!("HostBackend has no implementation for artifact {name}")
    }
}

impl Default for HostBackend {
    fn default() -> Self {
        Self::new()
    }
}

impl Backend for HostBackend {
    fn platform(&self) -> String {
        "host-cpu".to_string()
    }

    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn execute(&self, name: &str, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        self.manifest.validate_inputs(name, inputs)?;
        let t0 = Stopwatch::start();
        let outs = self.dispatch(name, inputs)?;
        let cell = self.stat_cell(name);
        // ordering: Relaxed — independent telemetry counters; readers take
        // a consistent-enough snapshot for reporting, nothing synchronizes
        cell.0.fetch_add(1, Ordering::Relaxed);
        // ordering: Relaxed — same telemetry contract as the call counter
        cell.1.fetch_add(t0.nanos(), Ordering::Relaxed);
        Ok(outs)
    }

    fn stats(&self) -> HashMap<String, ExecStats> {
        self.stats
            .read()
            .expect("stats lock")
            .iter()
            .map(|(name, cell)| {
                (
                    name.clone(),
                    ExecStats {
                        // ordering: Relaxed — see the telemetry note above
                        calls: cell.0.load(Ordering::Relaxed),
                        total_secs: cell.1.load(Ordering::Relaxed) as f64 / 1e9,
                        compile_secs: 0.0,
                    },
                )
            })
            .collect()
    }
}

// ---- manifest synthesis (mirrors aot.py registration) ---------------------

fn f32s(name: &str, shape: &[usize]) -> IoSpec {
    IoSpec::new(name, shape, "float32")
}

fn i32s(name: &str, shape: &[usize]) -> IoSpec {
    IoSpec::new(name, shape, "int32")
}

fn u8s(name: &str, shape: &[usize]) -> IoSpec {
    IoSpec::new(name, shape, "uint8")
}

/// (codes, scales) shapes for an order-n column-blocked quantized matrix.
fn qshapes(n: usize) -> (Vec<usize>, Vec<usize>) {
    let qb = 64.min(n);
    let nb = n * n / qb;
    (vec![nb, qb], vec![nb])
}

struct Reg(HashMap<String, ArtifactSpec>);

impl Reg {
    fn add(&mut self, name: &str, inputs: Vec<IoSpec>, outputs: Vec<IoSpec>) {
        let prev =
            self.0.insert(name.to_string(), ArtifactSpec { file: String::new(), inputs, outputs });
        debug_assert!(prev.is_none(), "duplicate artifact {name}");
    }
}

fn tlm_param_specs(vocab: usize, d: usize, layers: usize, ff: usize, seq: usize) -> Vec<ParamSpec> {
    let mut v = vec![
        ParamSpec { name: "embed".into(), shape: vec![vocab, d] },
        ParamSpec { name: "pos".into(), shape: vec![seq, d] },
    ];
    for i in 0..layers {
        for (suffix, shape) in [
            ("ln1_g", vec![d]),
            ("ln1_b", vec![d]),
            ("wqkv", vec![d, 3 * d]),
            ("wo", vec![d, d]),
            ("ln2_g", vec![d]),
            ("ln2_b", vec![d]),
            ("w1", vec![d, ff]),
            ("w2", vec![ff, d]),
        ] {
            v.push(ParamSpec { name: format!("l{i}.{suffix}"), shape });
        }
    }
    v.push(ParamSpec { name: "lnf_g".into(), shape: vec![d] });
    v.push(ParamSpec { name: "lnf_b".into(), shape: vec![d] });
    v
}

fn register_model(reg: &mut Reg, models: &mut HashMap<String, ModelSpec>, spec: ModelSpec) {
    let p_in: Vec<IoSpec> = spec.params.iter().map(|p| f32s(&p.name, &p.shape)).collect();
    let grads: Vec<IoSpec> =
        spec.params.iter().map(|p| f32s(&format!("grad_{}", p.name), &p.shape)).collect();
    match spec.kind.as_str() {
        "mlp" => {
            let mut inputs = p_in;
            inputs.push(f32s("x", &[spec.batch, spec.dims[0]]));
            inputs.push(i32s("y", &[spec.batch]));
            let mut step_out = vec![f32s("loss", &[])];
            step_out.extend(grads);
            for i in 0..spec.dims.len() - 1 {
                step_out.push(f32s(&format!("stat_r{i}"), &[spec.dims[i], spec.dims[i]]));
                step_out.push(f32s(&format!("stat_l{i}"), &[spec.dims[i + 1], spec.dims[i + 1]]));
            }
            reg.add(&spec.step, inputs.clone(), step_out);
            reg.add(&spec.eval, inputs, vec![f32s("loss", &[]), i32s("correct", &[])]);
        }
        "tlm" => {
            let mut inputs = p_in;
            inputs.push(i32s("tokens", &[spec.batch, spec.seq + 1]));
            let mut step_out = vec![f32s("loss", &[])];
            step_out.extend(grads);
            reg.add(&spec.step, inputs.clone(), step_out);
            reg.add(&spec.eval, inputs, vec![f32s("loss", &[])]);
        }
        other => unreachable!("unknown model kind {other}"),
    }
    models.insert(spec.step.trim_end_matches("_step").to_string(), spec);
}

fn mlp_model() -> ModelSpec {
    let dims = vec![128usize, 256, 256, 128];
    let mut params = Vec::new();
    for i in 0..dims.len() - 1 {
        params.push(ParamSpec { name: format!("w{i}"), shape: vec![dims[i], dims[i + 1]] });
        params.push(ParamSpec { name: format!("b{i}"), shape: vec![dims[i + 1]] });
    }
    let param_count = params.iter().map(|p| p.shape.iter().product::<usize>()).sum();
    ModelSpec {
        kind: "mlp".into(),
        params,
        step: "mlp_base_step".into(),
        eval: "mlp_base_eval".into(),
        batch: 128,
        classes: *dims.last().unwrap(),
        dims,
        vocab: 0,
        seq: 0,
        heads: 0,
        param_count,
    }
}

#[allow(clippy::too_many_arguments)]
fn tlm_model(
    name: &str,
    vocab: usize,
    d: usize,
    layers: usize,
    heads: usize,
    ff: usize,
    seq: usize,
    batch: usize,
) -> ModelSpec {
    let params = tlm_param_specs(vocab, d, layers, ff, seq);
    let param_count = params.iter().map(|p| p.shape.iter().product::<usize>()).sum();
    ModelSpec {
        kind: "tlm".into(),
        params,
        step: format!("{name}_step"),
        eval: format!("{name}_eval"),
        batch,
        dims: Vec::new(),
        classes: 0,
        vocab,
        seq,
        heads,
        param_count,
    }
}

fn synthetic_manifest() -> Manifest {
    let mut reg = Reg(HashMap::new());
    let cb = f32s("cb", &[CB_LEN]);

    // bucket artifacts (quantized + naive + dense state families)
    for n in BUCKETS_WITH_KFAC {
        let (cshape, sshape) = qshapes(n);
        let lam = f32s("lam", &[n]);
        let codes = u8s("codes", &cshape);
        let scales = f32s("scales", &sshape);
        let mat = f32s("m_stat", &[n, n]);
        let quant_state = || vec![lam.clone(), codes.clone(), scales.clone()];
        let quant_out = || {
            vec![f32s("lam", &[n]), u8s("codes", &cshape), f32s("scales", &sshape)]
        };
        let diag_out = || {
            vec![f32s("diag", &[n]), u8s("codes", &cshape), f32s("scales", &sshape)]
        };

        let mut pu_in = quant_state();
        pu_in.extend([mat.clone(), f32s("beta", &[]), cb.clone()]);
        reg.add(&format!("pu_{n}"), pu_in.clone(), quant_out());
        if n == 128 {
            reg.add("pu_kfac_128", pu_in.clone(), quant_out());
        }
        for tag in ["", "_e2", "_e1"] {
            let mut piru_in = quant_state();
            piru_in.extend([f32s("eps", &[]), cb.clone()]);
            reg.add(&format!("piru{tag}_{n}"), piru_in, diag_out());
        }
        let mut naive_pu_in = vec![f32s("diag", &[n]), codes.clone(), scales.clone()];
        naive_pu_in.extend([mat.clone(), f32s("beta", &[]), cb.clone()]);
        reg.add(&format!("pu_naive_{n}"), naive_pu_in, diag_out());
        let mut naive_ir_in = vec![f32s("diag", &[n]), codes.clone(), scales.clone()];
        naive_ir_in.extend([f32s("eps", &[]), cb.clone()]);
        reg.add(&format!("invroot_naive_{n}"), naive_ir_in, diag_out());

        reg.add(
            &format!("quant_cols_{n}"),
            vec![f32s("u", &[n, n]), cb.clone()],
            vec![u8s("codes", &cshape), f32s("scales", &sshape)],
        );
        reg.add(
            &format!("dequant_cols_{n}"),
            vec![codes.clone(), scales.clone(), cb.clone()],
            vec![f32s("u", &[n, n])],
        );
    }
    for n in DENSE_BUCKETS {
        reg.add(
            &format!("pu_dense_{n}"),
            vec![f32s("l", &[n, n]), f32s("m_stat", &[n, n]), f32s("beta", &[])],
            vec![f32s("l", &[n, n])],
        );
        for tag in ["", "_e2", "_e1"] {
            reg.add(
                &format!("invroot_dense{tag}_{n}"),
                vec![f32s("l", &[n, n]), f32s("eps", &[])],
                vec![f32s("lhat", &[n, n])],
            );
        }
    }

    // pair artifacts (gram + preconditioning)
    for m in ALL_BUCKETS {
        for n in ALL_BUCKETS {
            reg.add(
                &format!("gram_{m}x{n}"),
                vec![f32s("g", &[m, n])],
                vec![f32s("l", &[m, m]), f32s("r", &[n, n])],
            );
            let dense_in = vec![f32s("g", &[m, n]), f32s("lhat", &[m, m]), f32s("rhat", &[n, n])];
            reg.add(&format!("precond32_{m}x{n}"), dense_in.clone(), vec![f32s("gt", &[m, n])]);
            reg.add(&format!("caspr32_{m}x{n}"), dense_in, vec![f32s("gt", &[m, n])]);
        }
    }
    for m in QUANT_BUCKETS {
        for n in QUANT_BUCKETS {
            let (lc, ls) = qshapes(m);
            let (rc, rs) = qshapes(n);
            let quant_in = vec![
                f32s("g", &[m, n]),
                f32s("l_diag", &[m]),
                u8s("l_codes", &lc),
                f32s("l_scales", &ls),
                f32s("r_diag", &[n]),
                u8s("r_codes", &rc),
                f32s("r_scales", &rs),
                cb.clone(),
            ];
            reg.add(&format!("precond4_{m}x{n}"), quant_in.clone(), vec![f32s("gt", &[m, n])]);
            reg.add(&format!("caspr4_{m}x{n}"), quant_in, vec![f32s("gt", &[m, n])]);
        }
    }

    // first-order updates
    let v4096 = |name: &str| f32s(name, &[4096]);
    reg.add(
        "sgdm_update_4096",
        vec![
            v4096("p"),
            v4096("buf"),
            v4096("g"),
            f32s("lr", &[]),
            f32s("momentum", &[]),
            f32s("wd", &[]),
        ],
        vec![v4096("p"), v4096("buf")],
    );
    reg.add(
        "adamw_update_4096",
        vec![
            v4096("p"),
            v4096("m"),
            v4096("v"),
            v4096("g"),
            f32s("step", &[]),
            f32s("lr", &[]),
            f32s("beta1", &[]),
            f32s("beta2", &[]),
            f32s("eps", &[]),
            f32s("wd", &[]),
        ],
        vec![v4096("p"), v4096("m"), v4096("v")],
    );

    // models (laptop-scale stand-ins; mirrors python/compile/model.py)
    let mut models = HashMap::new();
    register_model(&mut reg, &mut models, mlp_model());
    register_model(&mut reg, &mut models, tlm_model("tlm_tiny", 256, 128, 2, 4, 512, 64, 8));
    register_model(&mut reg, &mut models, tlm_model("tlm_small", 512, 256, 4, 8, 1024, 128, 8));

    Manifest {
        block_size: 64,
        cb_len: CB_LEN,
        buckets: ALL_BUCKETS.to_vec(),
        quant_buckets: QUANT_BUCKETS.to_vec(),
        artifacts: reg.0,
        models,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_registers_expected_families() {
        let m = synthetic_manifest();
        for name in [
            "pu_64",
            "pu_128",
            "pu_256",
            "pu_kfac_128",
            "piru_64",
            "piru_e2_128",
            "piru_e1_256",
            "pu_naive_128",
            "invroot_naive_64",
            "pu_dense_32",
            "invroot_dense_128",
            "invroot_dense_e1_256",
            "gram_64x128",
            "precond32_32x32",
            "caspr32_128x64",
            "precond4_64x128",
            "caspr4_128x128",
            "quant_cols_64",
            "dequant_cols_128",
            "sgdm_update_4096",
            "adamw_update_4096",
            "mlp_base_step",
            "mlp_base_eval",
            "tlm_tiny_step",
            "tlm_small_eval",
        ] {
            assert!(m.artifacts.contains_key(name), "missing {name}");
        }
        assert_eq!(m.models["mlp_base"].kind, "mlp");
        assert_eq!(m.models["tlm_tiny"].heads, 4);
        let per_layer = 4 * 128 + 128 * 384 + 128 * 128 + 128 * 512 + 512 * 128;
        let tlm_tiny_params = 256 * 128 + 64 * 128 + 2 * per_layer + 2 * 128;
        assert_eq!(m.models["tlm_tiny"].param_count, tlm_tiny_params);
        assert_eq!(m.buckets, vec![32, 64, 128]);
    }

    #[test]
    fn unknown_artifact_is_rejected() {
        let b = HostBackend::new();
        assert!(b.execute("bogus_artifact", &[]).is_err());
    }
}
