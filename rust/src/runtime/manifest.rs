//! Artifact/model manifest shared by every backend: the PJRT registry parses
//! it from artifacts/manifest.json (emitted by python/compile/aot.py), the
//! host backend synthesizes the identical structure in memory.

use std::collections::HashMap;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use super::literal::HostTensor;
use crate::util::json::Json;

/// One input or output of an artifact, as recorded by aot.py.
#[derive(Debug, Clone)]
pub struct IoSpec {
    /// Logical name recorded by the compiler.
    pub name: String,
    /// Row-major shape (empty = scalar).
    pub shape: Vec<usize>,
    /// Manifest dtype string ("float32" / "int32" / "uint8").
    pub dtype: String,
}

impl IoSpec {
    /// Build a spec in place (host backend's synthesized manifest).
    pub fn new(name: &str, shape: &[usize], dtype: &str) -> Self {
        Self { name: name.to_string(), shape: shape.to_vec(), dtype: dtype.to_string() }
    }

    fn from_json(j: &Json) -> Result<Self> {
        Ok(Self {
            name: j.get("name").and_then(Json::as_str).context("io.name")?.to_string(),
            shape: j.get("shape").and_then(Json::usize_vec).context("io.shape")?,
            dtype: j.get("dtype").and_then(Json::as_str).context("io.dtype")?.to_string(),
        })
    }
}

/// One compiled artifact: its file and typed I/O signature.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    /// HLO-text file name (PJRT backend; unused on host).
    pub file: String,
    /// Input signature, in call order.
    pub inputs: Vec<IoSpec>,
    /// Output signature, in result order.
    pub outputs: Vec<IoSpec>,
}

/// One model parameter tensor.
#[derive(Debug, Clone)]
pub struct ParamSpec {
    /// Parameter name.
    pub name: String,
    /// Row-major shape.
    pub shape: Vec<usize>,
}

/// One trainable model served by a backend.
#[derive(Debug, Clone)]
pub struct ModelSpec {
    /// Model family ("mlp" / "tlm").
    pub kind: String,
    /// Parameter tensors, in flattening order.
    pub params: Vec<ParamSpec>,
    /// Name of the fwd+bwd step artifact.
    pub step: String,
    /// Name of the eval artifact.
    pub eval: String,
    /// Batch size the artifacts were compiled for.
    pub batch: usize,
    /// Layer dims (MLP) / architecture dims (transformer).
    pub dims: Vec<usize>,
    /// Classifier classes (0 for LMs).
    pub classes: usize,
    /// Vocabulary size (0 for classifiers).
    pub vocab: usize,
    /// Sequence length (0 for classifiers).
    pub seq: usize,
    /// attention heads (transformer models; 0 otherwise)
    pub heads: usize,
    /// Total scalar parameters.
    pub param_count: usize,
}

/// Everything a backend serves: artifacts, models, and the quantization
/// grid they were compiled against.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// Quantization block length the kernels assume.
    pub block_size: usize,
    /// Codebook length the quantized kernels assume (16).
    pub cb_len: usize,
    /// Preconditioner bucket orders.
    pub buckets: Vec<usize>,
    /// Bucket orders with quantized kernels.
    pub quant_buckets: Vec<usize>,
    /// Artifact specs by name.
    pub artifacts: HashMap<String, ArtifactSpec>,
    /// Model specs by name.
    pub models: HashMap<String, ModelSpec>,
}

impl Manifest {
    /// Parse `dir`/manifest.json.
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.json");
        let text =
            std::fs::read_to_string(&path).with_context(|| format!("reading {}", path.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("{e}"))?;
        Self::from_json(&j)
    }

    /// Parse a manifest from its JSON document.
    pub fn from_json(j: &Json) -> Result<Self> {
        let mut artifacts = HashMap::new();
        for (name, a) in j.get("artifacts").and_then(Json::as_obj).context("artifacts")? {
            let inputs = a
                .get("inputs")
                .and_then(Json::as_arr)
                .context("inputs")?
                .iter()
                .map(IoSpec::from_json)
                .collect::<Result<Vec<_>>>()?;
            let outputs = a
                .get("outputs")
                .and_then(Json::as_arr)
                .context("outputs")?
                .iter()
                .map(IoSpec::from_json)
                .collect::<Result<Vec<_>>>()?;
            artifacts.insert(
                name.clone(),
                ArtifactSpec {
                    file: a.get("file").and_then(Json::as_str).context("file")?.to_string(),
                    inputs,
                    outputs,
                },
            );
        }
        let mut models = HashMap::new();
        for (name, m) in j.get("models").and_then(Json::as_obj).context("models")? {
            let params = m
                .get("params")
                .and_then(Json::as_arr)
                .context("params")?
                .iter()
                .map(|p| {
                    Ok(ParamSpec {
                        name: p.get("name").and_then(Json::as_str).context("p.name")?.to_string(),
                        shape: p.get("shape").and_then(Json::usize_vec).context("p.shape")?,
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            let us = |k: &str| m.get(k).and_then(Json::as_usize).unwrap_or(0);
            models.insert(
                name.clone(),
                ModelSpec {
                    kind: m.get("kind").and_then(Json::as_str).context("kind")?.to_string(),
                    params,
                    step: m.get("step").and_then(Json::as_str).context("step")?.to_string(),
                    eval: m.get("eval").and_then(Json::as_str).context("eval")?.to_string(),
                    batch: us("batch"),
                    dims: m.get("dims").and_then(Json::usize_vec).unwrap_or_default(),
                    classes: us("classes"),
                    vocab: us("vocab"),
                    seq: us("seq"),
                    heads: us("n_heads"),
                    param_count: us("param_count"),
                },
            );
        }
        Ok(Self {
            block_size: j.get("block_size").and_then(Json::as_usize).context("block_size")?,
            cb_len: j.get("cb_len").and_then(Json::as_usize).context("cb_len")?,
            buckets: j.get("buckets").and_then(Json::usize_vec).context("buckets")?,
            quant_buckets: j
                .get("quant_buckets")
                .and_then(Json::usize_vec)
                .context("quant_buckets")?,
            artifacts,
            models,
        })
    }

    /// Validate `inputs` against an artifact's spec (arity, shape, dtype) —
    /// shared by every backend so shape bugs surface identically everywhere.
    pub fn validate_inputs(&self, name: &str, inputs: &[HostTensor]) -> Result<()> {
        let spec = self
            .artifacts
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact {name}"))?;
        if spec.inputs.len() != inputs.len() {
            bail!("{name}: expected {} inputs, got {}", spec.inputs.len(), inputs.len());
        }
        for (io, t) in spec.inputs.iter().zip(inputs) {
            if io.shape != t.shape {
                bail!(
                    "{name}.{}: shape mismatch, manifest {:?} vs input {:?}",
                    io.name,
                    io.shape,
                    t.shape
                );
            }
            if io.dtype != t.data.dtype_name() {
                bail!(
                    "{name}.{}: dtype mismatch, manifest {} vs input {}",
                    io.name,
                    io.dtype,
                    t.data.dtype_name()
                );
            }
        }
        Ok(())
    }
}

/// Cumulative per-artifact execution statistics (hot-path observability).
#[derive(Debug, Default, Clone)]
pub struct ExecStats {
    /// Executions of this artifact.
    pub calls: u64,
    /// Wall seconds inside execute calls.
    pub total_secs: f64,
    /// One-time compile seconds (PJRT; 0 on host).
    pub compile_secs: f64,
}
