//! Host-side tensors, the unit crossing every backend boundary.
//!
//! The coordinator keeps all state in plain Rust buffers (`HostTensor`). The
//! host backend consumes them directly; the PJRT backend (feature `pjrt`)
//! marshals them into `xla::Literal`s at the artifact boundary: f32 and i32
//! go through `vec1().reshape()`; u8 (quantization codes) has no `NativeType`
//! impl in the xla crate, so it uses `create_from_shape` + `copy_raw_from`.

use anyhow::{bail, Result};

/// Typed host buffer.
#[derive(Debug, Clone, PartialEq)]
pub enum TensorData {
    F32(Vec<f32>),
    I32(Vec<i32>),
    U8(Vec<u8>),
}

impl TensorData {
    pub fn len(&self) -> usize {
        match self {
            TensorData::F32(v) => v.len(),
            TensorData::I32(v) => v.len(),
            TensorData::U8(v) => v.len(),
        }
    }

    #[allow(clippy::len_zero)]
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn dtype_name(&self) -> &'static str {
        match self {
            TensorData::F32(_) => "float32",
            TensorData::I32(_) => "int32",
            TensorData::U8(_) => "uint8",
        }
    }
}

/// A shaped host tensor (row-major), the unit crossing the PJRT boundary.
#[derive(Debug, Clone, PartialEq)]
pub struct HostTensor {
    pub shape: Vec<usize>,
    pub data: TensorData,
}

impl HostTensor {
    pub fn f32(shape: &[usize], data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Self { shape: shape.to_vec(), data: TensorData::F32(data) }
    }

    pub fn i32(shape: &[usize], data: Vec<i32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Self { shape: shape.to_vec(), data: TensorData::I32(data) }
    }

    pub fn u8(shape: &[usize], data: Vec<u8>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Self { shape: shape.to_vec(), data: TensorData::U8(data) }
    }

    pub fn scalar_f32(x: f32) -> Self {
        Self { shape: vec![], data: TensorData::F32(vec![x]) }
    }

    pub fn zeros_f32(shape: &[usize]) -> Self {
        Self::f32(shape, vec![0.0; shape.iter().product()])
    }

    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match &self.data {
            TensorData::F32(v) => Ok(v),
            other => bail!("expected f32 tensor, got {}", other.dtype_name()),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match &self.data {
            TensorData::I32(v) => Ok(v),
            other => bail!("expected i32 tensor, got {}", other.dtype_name()),
        }
    }

    pub fn as_u8(&self) -> Result<&[u8]> {
        match &self.data {
            TensorData::U8(v) => Ok(v),
            other => bail!("expected u8 tensor, got {}", other.dtype_name()),
        }
    }

    pub fn into_f32(self) -> Result<Vec<f32>> {
        match self.data {
            TensorData::F32(v) => Ok(v),
            other => bail!("expected f32 tensor, got {}", other.dtype_name()),
        }
    }

    /// Convert into an XLA literal.
    #[cfg(feature = "pjrt")]
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
        let lit = match &self.data {
            TensorData::F32(v) => {
                if self.shape.is_empty() {
                    xla::Literal::scalar(v[0])
                } else {
                    xla::Literal::vec1(v).reshape(&dims)?
                }
            }
            TensorData::I32(v) => {
                if self.shape.is_empty() {
                    xla::Literal::scalar(v[0])
                } else {
                    xla::Literal::vec1(v).reshape(&dims)?
                }
            }
            TensorData::U8(v) => {
                let dims_us: Vec<usize> = self.shape.clone();
                let mut lit = xla::Literal::create_from_shape(
                    xla::PrimitiveType::U8,
                    &dims_us,
                );
                lit.copy_raw_from(v)?;
                lit
            }
        };
        Ok(lit)
    }

    /// Convert from an XLA literal (f32 / i32 / u8 supported).
    #[cfg(feature = "pjrt")]
    pub fn from_literal(lit: &xla::Literal) -> Result<Self> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        let data = match shape.ty() {
            xla::ElementType::F32 => TensorData::F32(lit.to_vec::<f32>()?),
            xla::ElementType::S32 => TensorData::I32(lit.to_vec::<i32>()?),
            xla::ElementType::U8 => TensorData::U8(lit.to_vec::<u8>()?),
            ty => bail!("unsupported artifact output element type {ty:?}"),
        };
        Ok(Self { shape: dims, data })
    }
}
