//! Host-side tensors, the unit crossing every backend boundary.
//!
//! The coordinator keeps all state in plain Rust buffers (`HostTensor`). The
//! host backend consumes them directly; the PJRT backend (feature `pjrt`)
//! marshals them into `xla::Literal`s at the artifact boundary: f32 and i32
//! go through `vec1().reshape()`; u8 (quantization codes) has no `NativeType`
//! impl in the xla crate, so it uses `create_from_shape` + `copy_raw_from`.
//!
//! Buffers are `Arc`-backed so tensors are cheap to share across the parallel
//! block engine's worker threads: `clone()` bumps a refcount instead of
//! copying the payload, and the cached precondition inputs in
//! `SecondOrder::precondition` alias the optimizer state rather than deep-
//! copying it every step.

use std::sync::Arc;

use anyhow::{bail, Result};

/// Typed host buffer (shared, immutable once constructed).
#[derive(Debug, Clone, PartialEq)]
pub enum TensorData {
    /// 32-bit floats (parameters, gradients, scales, ...).
    F32(Arc<Vec<f32>>),
    /// 32-bit ints (labels, tokens).
    I32(Arc<Vec<i32>>),
    /// Bytes (quantization codes).
    U8(Arc<Vec<u8>>),
}

impl TensorData {
    /// Element count.
    pub fn len(&self) -> usize {
        match self {
            TensorData::F32(v) => v.len(),
            TensorData::I32(v) => v.len(),
            TensorData::U8(v) => v.len(),
        }
    }

    /// True when the buffer has no elements.
    #[allow(clippy::len_zero)]
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Manifest dtype string ("float32" / "int32" / "uint8").
    pub fn dtype_name(&self) -> &'static str {
        match self {
            TensorData::F32(_) => "float32",
            TensorData::I32(_) => "int32",
            TensorData::U8(_) => "uint8",
        }
    }
}

/// A shaped host tensor (row-major), the unit crossing the PJRT boundary.
#[derive(Debug, Clone, PartialEq)]
pub struct HostTensor {
    /// Row-major dimensions (empty = scalar).
    pub shape: Vec<usize>,
    /// The shared payload.
    pub data: TensorData,
}

impl HostTensor {
    /// f32 tensor from a shape and flat data (lengths must agree).
    pub fn f32(shape: &[usize], data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Self { shape: shape.to_vec(), data: TensorData::F32(Arc::new(data)) }
    }

    /// i32 tensor from a shape and flat data (lengths must agree).
    pub fn i32(shape: &[usize], data: Vec<i32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Self { shape: shape.to_vec(), data: TensorData::I32(Arc::new(data)) }
    }

    /// u8 tensor from a shape and flat data (lengths must agree).
    pub fn u8(shape: &[usize], data: Vec<u8>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Self { shape: shape.to_vec(), data: TensorData::U8(Arc::new(data)) }
    }

    /// Rank-0 f32 scalar.
    pub fn scalar_f32(x: f32) -> Self {
        Self { shape: vec![], data: TensorData::F32(Arc::new(vec![x])) }
    }

    /// All-zero f32 tensor of the given shape.
    pub fn zeros_f32(shape: &[usize]) -> Self {
        Self::f32(shape, vec![0.0; shape.iter().product()])
    }

    /// Total element count.
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    /// Borrow the payload as f32 (errors on other dtypes).
    pub fn as_f32(&self) -> Result<&[f32]> {
        match &self.data {
            TensorData::F32(v) => Ok(v),
            other => bail!("expected f32 tensor, got {}", other.dtype_name()),
        }
    }

    /// Borrow the payload as i32 (errors on other dtypes).
    pub fn as_i32(&self) -> Result<&[i32]> {
        match &self.data {
            TensorData::I32(v) => Ok(v),
            other => bail!("expected i32 tensor, got {}", other.dtype_name()),
        }
    }

    /// Borrow the payload as u8 (errors on other dtypes).
    pub fn as_u8(&self) -> Result<&[u8]> {
        match &self.data {
            TensorData::U8(v) => Ok(v),
            other => bail!("expected u8 tensor, got {}", other.dtype_name()),
        }
    }

    /// Take the f32 buffer out. Zero-copy when this tensor is the sole owner;
    /// clones the payload when the buffer is still shared.
    pub fn into_f32(self) -> Result<Vec<f32>> {
        match self.data {
            TensorData::F32(v) => Ok(Arc::try_unwrap(v).unwrap_or_else(|a| (*a).clone())),
            other => bail!("expected f32 tensor, got {}", other.dtype_name()),
        }
    }

    /// True when two tensors alias the same underlying buffer (diagnostics:
    /// asserts that clones share state instead of deep-copying it).
    pub fn shares_buffer(&self, other: &HostTensor) -> bool {
        match (&self.data, &other.data) {
            (TensorData::F32(a), TensorData::F32(b)) => Arc::ptr_eq(a, b),
            (TensorData::I32(a), TensorData::I32(b)) => Arc::ptr_eq(a, b),
            (TensorData::U8(a), TensorData::U8(b)) => Arc::ptr_eq(a, b),
            _ => false,
        }
    }

    /// Convert into an XLA literal.
    #[cfg(feature = "pjrt")]
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
        let lit = match &self.data {
            TensorData::F32(v) => {
                if self.shape.is_empty() {
                    xla::Literal::scalar(v[0])
                } else {
                    xla::Literal::vec1(v.as_slice()).reshape(&dims)?
                }
            }
            TensorData::I32(v) => {
                if self.shape.is_empty() {
                    xla::Literal::scalar(v[0])
                } else {
                    xla::Literal::vec1(v.as_slice()).reshape(&dims)?
                }
            }
            TensorData::U8(v) => {
                let dims_us: Vec<usize> = self.shape.clone();
                let mut lit = xla::Literal::create_from_shape(xla::PrimitiveType::U8, &dims_us);
                lit.copy_raw_from(v.as_slice())?;
                lit
            }
        };
        Ok(lit)
    }

    /// Convert from an XLA literal (f32 / i32 / u8 supported).
    #[cfg(feature = "pjrt")]
    pub fn from_literal(lit: &xla::Literal) -> Result<Self> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        let data = match shape.ty() {
            xla::ElementType::F32 => TensorData::F32(Arc::new(lit.to_vec::<f32>()?)),
            xla::ElementType::S32 => TensorData::I32(Arc::new(lit.to_vec::<i32>()?)),
            xla::ElementType::U8 => TensorData::U8(Arc::new(lit.to_vec::<u8>()?)),
            ty => bail!("unsupported artifact output element type {ty:?}"),
        };
        Ok(Self { shape: dims, data })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clone_shares_buffer_not_payload() {
        // §Perf: `inv_cache` tensors are cloned into every precondition call;
        // with Arc-backed buffers that clone must alias, not copy.
        let t = HostTensor::f32(&[128, 128], vec![1.0; 128 * 128]);
        let c = t.clone();
        assert!(t.shares_buffer(&c));
        assert_eq!(t.as_f32().unwrap().as_ptr(), c.as_f32().unwrap().as_ptr());
        let u = HostTensor::u8(&[4], vec![1, 2, 3, 4]);
        assert!(u.shares_buffer(&u.clone()));
        assert!(!t.shares_buffer(&u));
    }

    #[test]
    fn into_f32_is_zero_copy_for_sole_owner() {
        let t = HostTensor::f32(&[3], vec![1.0, 2.0, 3.0]);
        let ptr = t.as_f32().unwrap().as_ptr();
        let v = t.into_f32().unwrap();
        assert_eq!(v.as_ptr(), ptr); // sole owner: buffer moved, not copied
        assert_eq!(v, vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn into_f32_falls_back_to_copy_when_shared() {
        let t = HostTensor::f32(&[2], vec![4.0, 5.0]);
        let keep = t.clone();
        let v = t.into_f32().unwrap();
        assert_eq!(v, vec![4.0, 5.0]);
        assert_eq!(keep.as_f32().unwrap(), &[4.0, 5.0]);
    }

    #[test]
    fn dtype_mismatch_errors() {
        let t = HostTensor::i32(&[1], vec![7]);
        assert!(t.as_f32().is_err());
        assert!(t.clone().into_f32().is_err());
        assert_eq!(t.as_i32().unwrap(), &[7]);
    }
}
