//! PJRT artifact backend (feature `pjrt`): parses artifacts/manifest.json,
//! lazily compiles HLO text into PJRT executables, and dispatches executions
//! by artifact name.
//!
//! Wiring follows /opt/xla-example/load_hlo: `HloModuleProto::from_text_file`
//! → `XlaComputation::from_proto` → `client.compile` → `execute`. Artifacts
//! are compiled lazily on first use and cached for the process lifetime.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, Result};

use super::literal::HostTensor;
use super::manifest::{ExecStats, Manifest};
use super::Backend;
use crate::util::timer::Stopwatch;

/// The PJRT backend: one CPU client + lazily compiled executables.
///
/// `Backend: Send + Sync` note: the compile cache and stats sit behind
/// `Mutex`es, but executions do NOT serialize on them — the cache stores
/// `Arc`-wrapped executables, `execute` clones the handle and releases the
/// lock before submitting, so concurrent callers (the parallel block
/// engine, shard workers) only contend for the map lookup. (When swapping
/// the stub for the real xla-rs crate, its client/executable handles must
/// be wrapped if they are not `Send + Sync`.)
pub struct PjrtBackend {
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: Manifest,
    exes: Mutex<HashMap<String, Arc<xla::PjRtLoadedExecutable>>>,
    stats: Mutex<HashMap<String, ExecStats>>,
}

impl PjrtBackend {
    /// Load the manifest from `artifact_dir` and bring up a CPU PJRT client.
    pub fn new(artifact_dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(artifact_dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("{e:?}"))?;
        Ok(Self {
            client,
            dir: artifact_dir.to_path_buf(),
            manifest,
            exes: Mutex::new(HashMap::new()),
            stats: Mutex::new(HashMap::new()),
        })
    }

    /// The `name` executable, compiling (and caching) it on first use. The
    /// returned `Arc` keeps the executable alive independent of the cache
    /// lock, so callers execute without holding it.
    fn compiled(&self, name: &str) -> Result<Arc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.exes.lock().expect("exes lock").get(name) {
            return Ok(Arc::clone(exe));
        }
        let spec = self
            .manifest
            .artifacts
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact {name}"))?;
        let path = self.dir.join(&spec.file);
        let t0 = Stopwatch::start();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("loading {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
        let dt = t0.secs();
        // under a compile race the first insert wins and every caller shares
        // its executable; the loser's compile time still lands in stats
        let exe = Arc::clone(
            self.exes
                .lock()
                .expect("exes lock")
                .entry(name.to_string())
                .or_insert_with(|| Arc::new(exe)),
        );
        self.stats.lock().expect("stats lock").entry(name.to_string()).or_default().compile_secs +=
            dt;
        Ok(exe)
    }
}

impl Backend for PjrtBackend {
    fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Execute an artifact by name. Inputs must match the manifest order.
    /// The executable handle is cloned out of the cache first, so device
    /// submission runs with no lock held and concurrent executions overlap.
    fn execute(&self, name: &str, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        self.manifest.validate_inputs(name, inputs)?;
        let exe = self.compiled(name)?;
        let lits: Vec<xla::Literal> = inputs.iter().map(|t| t.to_literal()).collect::<Result<_>>()?;
        let t0 = Stopwatch::start();
        let result =
            exe.execute::<xla::Literal>(&lits).map_err(|e| anyhow!("executing {name}: {e:?}"))?;
        let out_lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching {name} output: {e:?}"))?;
        // aot.py lowers with return_tuple=True: always a tuple, even 1-ary.
        let parts = out_lit.to_tuple().map_err(|e| anyhow!("{e:?}"))?;
        let outs: Vec<HostTensor> =
            parts.iter().map(HostTensor::from_literal).collect::<Result<_>>()?;
        let dt = t0.secs();
        let mut stats = self.stats.lock().expect("stats lock");
        let ent = stats.entry(name.to_string()).or_default();
        ent.calls += 1;
        ent.total_secs += dt;
        Ok(outs)
    }

    fn stats(&self) -> HashMap<String, ExecStats> {
        self.stats.lock().expect("stats lock").clone()
    }
}
