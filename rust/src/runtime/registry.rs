//! Artifact registry: parses artifacts/manifest.json, lazily compiles HLO
//! text into PJRT executables, and dispatches executions by artifact name.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use super::literal::HostTensor;
use crate::util::json::Json;

/// One input or output of an artifact, as recorded by aot.py.
#[derive(Debug, Clone)]
pub struct IoSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl IoSpec {
    fn from_json(j: &Json) -> Result<Self> {
        Ok(Self {
            name: j.get("name").and_then(Json::as_str).context("io.name")?.to_string(),
            shape: j.get("shape").and_then(Json::usize_vec).context("io.shape")?,
            dtype: j.get("dtype").and_then(Json::as_str).context("io.dtype")?.to_string(),
        })
    }
}

#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub file: String,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
}

#[derive(Debug, Clone)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

#[derive(Debug, Clone)]
pub struct ModelSpec {
    pub kind: String,
    pub params: Vec<ParamSpec>,
    pub step: String,
    pub eval: String,
    pub batch: usize,
    pub dims: Vec<usize>,
    pub classes: usize,
    pub vocab: usize,
    pub seq: usize,
    pub param_count: usize,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub block_size: usize,
    pub cb_len: usize,
    pub buckets: Vec<usize>,
    pub quant_buckets: Vec<usize>,
    pub artifacts: HashMap<String, ArtifactSpec>,
    pub models: HashMap<String, ModelSpec>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("{e}"))?;
        Self::from_json(&j)
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        let mut artifacts = HashMap::new();
        for (name, a) in j.get("artifacts").and_then(Json::as_obj).context("artifacts")? {
            let inputs = a
                .get("inputs")
                .and_then(Json::as_arr)
                .context("inputs")?
                .iter()
                .map(IoSpec::from_json)
                .collect::<Result<Vec<_>>>()?;
            let outputs = a
                .get("outputs")
                .and_then(Json::as_arr)
                .context("outputs")?
                .iter()
                .map(IoSpec::from_json)
                .collect::<Result<Vec<_>>>()?;
            artifacts.insert(
                name.clone(),
                ArtifactSpec {
                    file: a.get("file").and_then(Json::as_str).context("file")?.to_string(),
                    inputs,
                    outputs,
                },
            );
        }
        let mut models = HashMap::new();
        for (name, m) in j.get("models").and_then(Json::as_obj).context("models")? {
            let params = m
                .get("params")
                .and_then(Json::as_arr)
                .context("params")?
                .iter()
                .map(|p| {
                    Ok(ParamSpec {
                        name: p.get("name").and_then(Json::as_str).context("p.name")?.to_string(),
                        shape: p.get("shape").and_then(Json::usize_vec).context("p.shape")?,
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            let us = |k: &str| m.get(k).and_then(Json::as_usize).unwrap_or(0);
            models.insert(
                name.clone(),
                ModelSpec {
                    kind: m.get("kind").and_then(Json::as_str).context("kind")?.to_string(),
                    params,
                    step: m.get("step").and_then(Json::as_str).context("step")?.to_string(),
                    eval: m.get("eval").and_then(Json::as_str).context("eval")?.to_string(),
                    batch: us("batch"),
                    dims: m.get("dims").and_then(Json::usize_vec).unwrap_or_default(),
                    classes: us("classes"),
                    vocab: us("vocab"),
                    seq: us("seq"),
                    param_count: us("param_count"),
                },
            );
        }
        Ok(Self {
            block_size: j.get("block_size").and_then(Json::as_usize).context("block_size")?,
            cb_len: j.get("cb_len").and_then(Json::as_usize).context("cb_len")?,
            buckets: j.get("buckets").and_then(Json::usize_vec).context("buckets")?,
            quant_buckets: j
                .get("quant_buckets")
                .and_then(Json::usize_vec)
                .context("quant_buckets")?,
            artifacts,
            models,
        })
    }
}

/// Cumulative per-artifact execution statistics (hot-path observability).
#[derive(Debug, Default, Clone)]
pub struct ExecStats {
    pub calls: u64,
    pub total_secs: f64,
    pub compile_secs: f64,
}

/// The PJRT runtime: one CPU client + lazily compiled executables.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    pub manifest: Manifest,
    exes: RefCell<HashMap<String, xla::PjRtLoadedExecutable>>,
    stats: RefCell<HashMap<String, ExecStats>>,
}

impl Runtime {
    pub fn new(artifact_dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(artifact_dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("{e:?}"))?;
        Ok(Self {
            client,
            dir: artifact_dir.to_path_buf(),
            manifest,
            exes: RefCell::new(HashMap::new()),
            stats: RefCell::new(HashMap::new()),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn has_artifact(&self, name: &str) -> bool {
        self.manifest.artifacts.contains_key(name)
    }

    pub fn spec(&self, name: &str) -> Result<&ArtifactSpec> {
        self.manifest
            .artifacts
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact {name}"))
    }

    fn ensure_compiled(&self, name: &str) -> Result<()> {
        if self.exes.borrow().contains_key(name) {
            return Ok(());
        }
        let spec = self.spec(name)?;
        let path = self.dir.join(&spec.file);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("loading {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
        let dt = t0.elapsed().as_secs_f64();
        self.exes.borrow_mut().insert(name.to_string(), exe);
        self.stats
            .borrow_mut()
            .entry(name.to_string())
            .or_default()
            .compile_secs += dt;
        Ok(())
    }

    /// Validate inputs against the manifest spec (shape + dtype).
    fn check_inputs(&self, name: &str, inputs: &[HostTensor]) -> Result<()> {
        let spec = self.spec(name)?;
        if spec.inputs.len() != inputs.len() {
            bail!(
                "{name}: expected {} inputs, got {}",
                spec.inputs.len(),
                inputs.len()
            );
        }
        for (io, t) in spec.inputs.iter().zip(inputs) {
            if io.shape != t.shape {
                bail!(
                    "{name}.{}: shape mismatch, manifest {:?} vs input {:?}",
                    io.name, io.shape, t.shape
                );
            }
            if io.dtype != t.data.dtype_name() {
                bail!(
                    "{name}.{}: dtype mismatch, manifest {} vs input {}",
                    io.name, io.dtype, t.data.dtype_name()
                );
            }
        }
        Ok(())
    }

    /// Execute an artifact by name. Inputs must match the manifest order.
    pub fn execute(&self, name: &str, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        self.check_inputs(name, inputs)?;
        self.ensure_compiled(name)?;
        let lits: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<_>>()?;
        let t0 = Instant::now();
        let exes = self.exes.borrow();
        let exe = exes.get(name).unwrap();
        let result = exe
            .execute::<xla::Literal>(&lits)
            .map_err(|e| anyhow!("executing {name}: {e:?}"))?;
        let out_lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching {name} output: {e:?}"))?;
        // aot.py lowers with return_tuple=True: always a tuple, even 1-ary.
        let parts = out_lit.to_tuple().map_err(|e| anyhow!("{e:?}"))?;
        let outs: Vec<HostTensor> = parts
            .iter()
            .map(HostTensor::from_literal)
            .collect::<Result<_>>()?;
        let dt = t0.elapsed().as_secs_f64();
        let mut stats = self.stats.borrow_mut();
        let ent = stats.entry(name.to_string()).or_default();
        ent.calls += 1;
        ent.total_secs += dt;
        Ok(outs)
    }

    /// Snapshot of per-artifact execution statistics.
    pub fn stats(&self) -> HashMap<String, ExecStats> {
        self.stats.borrow().clone()
    }

    /// Total wall-clock seconds spent inside PJRT execute calls.
    pub fn total_exec_secs(&self) -> f64 {
        self.stats.borrow().values().map(|s| s.total_secs).sum()
    }
}
