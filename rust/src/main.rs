//! shampoo4 — CLI launcher for the 4-bit Shampoo training framework.
//!
//! Subcommands:
//!   train        [--config cfg.toml] [--model M] [--steps N] [--optimizer F]
//!                [--shampoo-bits 4|32] [--kind shampoo|caspr|kfac|adabk]
//!                [--mapping linear2|dt] [--quantize-eigen true|false]
//!                [--first-order-bits 4|8|16|32] [--first-order-mapping dt|linear2]
//!                (StateCodec policy for first-order moment buffers — 4-bit
//!                AdamW/SGDM states, the Table 13 memory baseline regime)
//!                [--quant-policy m=q4,v=q8,...]
//!                (per-buffer codec policy: role=codec pairs overriding the
//!                single knobs role by role; roles m/v/left/right/eigen,
//!                codecs fp32|bf16|q2..q8[-mapping][-sr] — -sr = stochastic
//!                rounding, seeded from --seed)
//!                [--backend host|pjrt|auto] [--out runs/NAME]
//!                [--resume ckpt.bin]  (load a checkpoint, continue at step+1)
//!                [--shadow-quant-error]
//!                [--parallelism N] [--stagger-invroots]
//!                (parallel block engine: N worker threads for per-block
//!                PU/PIRU/precondition, bit-identical to serial; staggered
//!                inverse-root cohorts flatten the T2-step wall-time spike)
//!                [--pipeline] [--pipeline-max-lag K] [--pipeline-adaptive]
//!                (cross-step pipelining: PU/PIRU refreshes run on the
//!                persistent pool and overlap subsequent model steps;
//!                preconditioning tolerates roots up to K steps stale —
//!                double-buffered swap, deterministic barriers; adaptive
//!                swaps finished refreshes in early when the pool is idle)
//!                [--shards N]
//!                (sharded block engine: partition second-order blocks
//!                round-robin across N shard workers, each with its own
//!                Backend instance; requests/replies travel as codec-encoded
//!                bytes and results are bit-identical to --shards 1)
//!                [--checkpoint-delta] [--checkpoint-chunk-bytes N]
//!                (streaming checkpoints: the final save is written frame by
//!                frame in N-byte chunks; with --checkpoint-delta and a v1
//!                --resume parent, unchanged frames are referenced from the
//!                parent instead of rewritten)
//!   quant-error  [--n 1200] [--bits 4] [--block 64]
//!                (Table 1/5/6/7, Figures 2/3/5/6 — see benches for the
//!                full sweeps)
//!   memory-plan  [--budget-mb 81920]  (Table 13)
//!   artifacts    — list served artifacts and model specs
//!
//! Python never runs here: the default HostBackend executes everything
//! natively; AOT artifacts are only needed for --backend pjrt.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use shampoo4::config::{FirstOrderKind, RunConfig, SecondOrderKind};
use shampoo4::coordinator::memory::{plan, OptimizerPlan, PlannedModel};
use shampoo4::coordinator::Trainer;
use shampoo4::quant::Mapping;
use shampoo4::runtime::{backend_by_name, Backend};
use shampoo4::util::cli::Args;

const BOOL_FLAGS: &[&str] = &[
    "shadow-quant-error",
    "stagger-invroots",
    "pipeline",
    "pipeline-adaptive",
    "checkpoint-delta",
    "help",
    "quiet",
];

fn main() -> Result<()> {
    let args = Args::parse(BOOL_FLAGS);
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "train" => cmd_train(&args),
        "quant-error" => cmd_quant_error(&args),
        "memory-plan" => cmd_memory_plan(&args),
        "artifacts" => cmd_artifacts(&args),
        _ => {
            print_help();
            Ok(())
        }
    }
}

fn print_help() {
    println!(
        "shampoo4 — 4-bit Shampoo training framework (NeurIPS 2024 reproduction)\n\
         \n\
         USAGE: shampoo4 <train|quant-error|memory-plan|artifacts> [options]\n\
         \n\
         train        run a training job (see configs/*.toml presets)\n\
         quant-error  quantization error analysis (Table 1 family)\n\
         memory-plan  analytic LLaMA2-7B memory table (Table 13)\n\
         artifacts    list AOT artifacts and models\n"
    );
}

fn artifact_dir(args: &Args) -> PathBuf {
    PathBuf::from(args.get_or("artifact-dir", "artifacts"))
}

/// Apply `--flag` overrides on top of a parsed (or default) run config.
pub fn apply_cli_overrides(cfg: &mut RunConfig, args: &Args) -> Result<()> {
    if let Some(m) = args.get("model") {
        cfg.model = m.to_string();
    }
    if let Some(s) = args.get("steps") {
        cfg.steps = s.parse().context("--steps")?;
    }
    if let Some(s) = args.get("seed") {
        cfg.seed = s.parse().context("--seed")?;
    }
    if let Some(o) = args.get("optimizer") {
        cfg.first.kind = FirstOrderKind::parse(o)?;
    }
    if let Some(lr) = args.get("lr") {
        cfg.first.lr = lr.parse().context("--lr")?;
    }
    if let Some(k) = args.get("kind") {
        cfg.second.kind = SecondOrderKind::parse(k)?;
    }
    if let Some(b) = args.get("shampoo-bits") {
        cfg.second.quant.bits = b.parse().context("--shampoo-bits")?;
    }
    if let Some(m) = args.get("mapping") {
        cfg.second.quant.mapping = Mapping::parse_named(m).context("--mapping")?;
    }
    if let Some(v) = args.get("quantize-eigen") {
        cfg.second.quant.quantize_eigen = v == "true";
    }
    if let Some(b) = args.get("first-order-bits") {
        cfg.first.bits = b.parse().context("--first-order-bits")?;
    }
    if let Some(m) = args.get("first-order-mapping") {
        cfg.first.mapping = Mapping::parse_named(m).context("--first-order-mapping")?;
    }
    if let Some(p) = args.get("quant-policy") {
        // appended after any TOML entries: later entries win on lookup, so
        // the CLI overrides the config file role by role
        cfg.quant_policy.extend(
            shampoo4::quant::parse_policy_overrides(
                p,
                cfg.first.mapping,
                cfg.second.quant.mapping,
            )
            .context("--quant-policy")?,
        );
    }
    if let Some(v) = args.get("rectify") {
        cfg.second.quant.rectify = v == "true";
    }
    if let Some(v) = args.get("t1") {
        cfg.second.update_precond_every = v.parse().context("--t1")?;
    }
    if let Some(v) = args.get("t2") {
        cfg.second.update_invroot_every = v.parse().context("--t2")?;
    }
    if let Some(v) = args.get("eps") {
        cfg.second.eps = v.parse().context("--eps")?;
    }
    if let Some(v) = args.get("eval-every") {
        cfg.eval_every = v.parse().context("--eval-every")?;
    }
    if args.flag("shadow-quant-error") {
        cfg.shadow_quant_error = true;
    }
    if let Some(p) = args.get("parallelism") {
        cfg.second.parallelism = p.parse::<usize>().context("--parallelism")?.max(1);
    }
    if args.flag("stagger-invroots") {
        cfg.second.stagger_invroots = true;
    }
    if args.flag("pipeline") {
        cfg.second.pipeline = true;
    }
    if let Some(k) = args.get("pipeline-max-lag") {
        cfg.second.pipeline_max_lag =
            k.parse::<usize>().context("--pipeline-max-lag")?.max(1);
    }
    if args.flag("pipeline-adaptive") {
        cfg.second.pipeline_adaptive = true;
    }
    if let Some(n) = args.get("shards") {
        cfg.second.shards = n.parse::<usize>().context("--shards")?.max(1);
    }
    if args.flag("checkpoint-delta") {
        cfg.checkpoint_delta = true;
    }
    if let Some(b) = args.get("checkpoint-chunk-bytes") {
        cfg.checkpoint_chunk_bytes =
            b.parse::<usize>().context("--checkpoint-chunk-bytes")?;
    }
    if let Some(d) = args.get("artifact-dir") {
        cfg.artifact_dir = d.to_string();
    }
    if let Some(b) = args.get("backend") {
        cfg.backend = b.to_string();
    }
    if let Some(n) = args.get("name") {
        cfg.name = n.to_string();
    }
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let mut cfg = match args.get("config") {
        Some(p) => RunConfig::from_file(Path::new(p))?,
        None => RunConfig::default(),
    };
    apply_cli_overrides(&mut cfg, args)?;
    cfg.validate()?;
    // Pre-validate the SHAMPOO4_SIMD_LANE override so a typo or a lane the
    // host cannot run surfaces as a clean CLI error instead of a panic the
    // first time a quant kernel dispatches.
    #[cfg(feature = "simd")]
    {
        use shampoo4::quant::simd;
        simd::lane_from_env().map_err(|e| anyhow::anyhow!(e))?;
        println!("simd-lane: {} ({})", simd::active_lane(), simd::simd_arch());
    }
    let dir = artifact_dir(args);
    let rt = backend_by_name(&cfg.backend, &dir)?;
    let rt = rt.as_ref();
    println!(
        "platform={} model={} steps={} F={}@{}bit second={} bits={} mapping={} \
         parallelism={} shards={} piru={} engine={}",
        rt.platform(),
        cfg.model,
        cfg.steps,
        cfg.first.kind.name(),
        cfg.first.bits,
        cfg.second.kind.name(),
        cfg.second.quant.bits,
        cfg.second.quant.mapping.name(),
        cfg.second.parallelism,
        cfg.second.shards,
        if cfg.second.stagger_invroots { "staggered" } else { "batch" },
        if cfg.second.pipeline {
            format!("pipelined(lag<={})", cfg.second.pipeline_max_lag)
        } else {
            "sync".to_string()
        },
    );
    let policy_summary = cfg.codec_policy().summary();
    if !policy_summary.is_empty() {
        println!("quant-policy: {policy_summary}");
    }
    let out_dir = PathBuf::from(args.get_or("out", &format!("runs/{}", cfg.name)));
    let mut trainer = Trainer::new(rt, cfg.clone())?;
    let mut resume_path: Option<PathBuf> = None;
    if let Some(ckpt) = args.get("resume") {
        let step = trainer.load_checkpoint(Path::new(ckpt))?;
        println!("resumed from {ckpt} at step {step} (continuing to {})", cfg.steps);
        resume_path = Some(PathBuf::from(ckpt));
    }
    let mem0 = trainer.memory_report();
    println!(
        "params={:.2}MB first-order={:.2}MB second-order={:.2}MB total={:.2}MB",
        mem0.params_bytes as f64 / 1048576.0,
        mem0.first_order_bytes as f64 / 1048576.0,
        mem0.second_order_bytes as f64 / 1048576.0,
        mem0.total_mb()
    );
    let res = trainer.train(rt, Some(&out_dir.join("metrics.csv")))?;
    let ckpt_path = out_dir.join("checkpoint.bin");
    // --checkpoint-delta: write a delta against the checkpoint we resumed
    // from, provided it is a v1 streaming file (v0 blobs have no manifest to
    // delta against). Falls back to a monolithic save otherwise.
    let delta_parent = resume_path.filter(|p| {
        cfg.checkpoint_delta
            && !p.as_path().eq(ckpt_path.as_path())
            && matches!(
                shampoo4::coordinator::checkpoint::probe_version(p),
                Ok(Some(_))
            )
    });
    match delta_parent {
        Some(parent) => trainer.save_checkpoint_delta(&ckpt_path, cfg.steps, &parent)?,
        None => trainer.save_checkpoint(&ckpt_path, cfg.steps)?,
    }
    for (step, loss) in res.losses.iter().rev().take(5).rev() {
        println!("step {step:>6} loss {loss:.4}");
    }
    if let Some(e) = &res.final_eval {
        match e.accuracy {
            Some(a) => println!(
                "final eval: loss {:.4} acc {:.2}%  (wall {:.1}s)",
                e.loss,
                a * 100.0,
                res.wall_secs
            ),
            None => println!("final eval: loss {:.4}  (wall {:.1}s)", e.loss, res.wall_secs),
        }
    }
    if !res.shadow_rows.is_empty() {
        println!("step,nre_precond,ae_precond,nre_invroot,ae_invroot");
        for r in &res.shadow_rows {
            println!(
                "{},{:.4},{:.3},{:.4},{:.3}",
                r.step, r.nre_precond, r.ae_precond_deg, r.nre_invroot, r.ae_invroot_deg
            );
        }
    }
    println!("timings: {}", res.timings.summary());
    println!(
        "memory: total={:.2}MB optimizer={:.2}MB host_fallback_preconds={}",
        res.memory.total_mb(),
        res.memory.optimizer_mb(),
        res.host_fallbacks
    );
    Ok(())
}

fn cmd_quant_error(args: &Args) -> Result<()> {
    use shampoo4::errors::{quant_error_in_power, spectrum, QuantScheme, QuantTarget};
    use shampoo4::util::rng::Rng;

    let n = args.get_usize("n", 1200);
    let bits = args.get_usize("bits", 4) as u32;
    let block = args.get_usize("block", 64);
    let mut rng = Rng::new(args.get_usize("seed", 0) as u64);
    println!("building A1 (spectrum-matched real, cond≈37235) and A2 (two-level), order {n}");
    let a1 = spectrum::synthetic_loglinear(n, 37235.0, &mut rng);
    let a2 = spectrum::synthetic_two_level(n, 1000.0, 1e-3, n / 20, &mut rng);
    println!("matrix,mapping,bits,qm,or,nre,ae_deg");
    for (mname, a) in [("A1", &a1), ("A2", &a2)] {
        for mapping in [Mapping::Dt, Mapping::Linear2] {
            for (target, rect) in [
                (QuantTarget::Precond, 0),
                (QuantTarget::Eigen, 0),
                (QuantTarget::Eigen, 1),
            ] {
                let row = quant_error_in_power(
                    a,
                    -0.25,
                    QuantScheme { mapping, bits, target, rectify: rect, block },
                    false,
                );
                println!(
                    "{mname},{},{bits},{},{},{:.4},{:.4}",
                    mapping.name(),
                    if target == QuantTarget::Eigen { "U" } else { "A" },
                    if rect > 0 { "yes" } else { "no" },
                    row.nre,
                    row.ae_deg
                );
            }
        }
    }
    Ok(())
}

fn cmd_memory_plan(args: &Args) -> Result<()> {
    let budget = args.get_usize("budget-mb", 81920) * 1024 * 1024;
    let m = PlannedModel::llama2_7b();
    println!(
        "model {} ({:.2}B params), budget {:.0} MB",
        m.name,
        m.param_count() as f64 / 1e9,
        budget as f64 / 1048576.0
    );
    println!("optimizer,batch,total_mb,fits");
    let plans = [
        ("8-bit AdamW", plan(&m, OptimizerPlan::Adam { bits: 8 })),
        (
            "8-bit AdamW + 32-bit Shampoo",
            plan(
                &m,
                OptimizerPlan::AdamShampoo { adam_bits: 8, shampoo_bits: 32, max_order: 2048 },
            ),
        ),
        (
            "8-bit AdamW + 4-bit Shampoo (our)",
            plan(&m, OptimizerPlan::AdamShampoo { adam_bits: 8, shampoo_bits: 4, max_order: 2048 }),
        ),
    ];
    for (name, p) in &plans {
        for batch in [2usize, 64, 128, 256] {
            let total = p.total_at_batch(batch);
            println!(
                "{name},{batch},{:.0},{}",
                total as f64 / 1048576.0,
                if total <= budget { "yes" } else { "OOM" }
            );
        }
        println!("{name},max_batch,{},-", p.max_batch(budget));
    }
    Ok(())
}

fn cmd_artifacts(args: &Args) -> Result<()> {
    let dir = artifact_dir(args);
    let rt = backend_by_name(args.get_or("backend", "auto"), &dir)?;
    let manifest = rt.manifest();
    let mut names: Vec<_> = manifest.artifacts.keys().collect();
    names.sort();
    println!("platform {}: {} artifacts:", rt.platform(), names.len());
    for n in names {
        let s = &manifest.artifacts[n];
        println!("  {n}  ({} in / {} out)", s.inputs.len(), s.outputs.len());
    }
    println!("models:");
    for (name, m) in &manifest.models {
        println!("  {name}: kind={} params={} batch={}", m.kind, m.params.len(), m.batch);
    }
    Ok(())
}
