//! M-FAC [Frantar et al. 2021]: matrix-free inverse-Hessian-vector products
//! from a window of m recent gradients — the Appendix H.1 comparison arm
//! (Table 11). The paper's point is that M-FAC's m dense gradient copies
//! make it far *less* memory-efficient than 4-bit Shampoo; we reproduce
//! that by exact state accounting.
//!
//! H ≈ λI + (1/m)·Σ g_i g_iᵀ = λI + (1/m)GᵀG with G the m×d gradient
//! window. By Woodbury:
//!   H⁻¹v = (1/λ)·[ v − Gᵀ·(mλ·I_m + G·Gᵀ)⁻¹·G·v ].
//! The m×m solve is exact Gaussian elimination (m ≤ 64).

use anyhow::{bail, Result};

use super::first_order::FirstOrder;

pub struct MFac {
    /// ring buffer of the last m gradients (each d long)
    grads: Vec<Vec<f32>>,
    head: usize,
    filled: usize,
    m: usize,
    pub damp: f32,
    pub momentum: f32,
    buf: Vec<f32>,
    pub weight_decay: f32,
}

impl MFac {
    pub fn new(dim: usize, m: usize, damp: f32, momentum: f32, weight_decay: f32) -> Self {
        Self {
            grads: Vec::new(),
            head: 0,
            filled: 0,
            m,
            damp,
            momentum,
            buf: vec![0.0; dim],
            weight_decay,
        }
    }

    fn push_grad(&mut self, g: &[f32]) {
        if self.grads.len() < self.m {
            self.grads.push(g.to_vec());
            self.filled = self.grads.len();
        } else {
            self.grads[self.head].copy_from_slice(g);
            self.head = (self.head + 1) % self.m;
            self.filled = self.m;
        }
    }

    /// H⁻¹·v via Woodbury with the current window.
    fn ihvp(&self, v: &[f32]) -> Vec<f32> {
        let k = self.filled;
        if k == 0 {
            return v.iter().map(|x| x / self.damp).collect();
        }
        // Gv (k) and GGᵀ (k×k)
        let mut gv = vec![0.0f64; k];
        let mut ggt = vec![0.0f64; k * k];
        for i in 0..k {
            let gi = &self.grads[i];
            gv[i] = gi.iter().zip(v).map(|(&a, &b)| a as f64 * b as f64).sum();
            for j in i..k {
                let gj = &self.grads[j];
                let dot: f64 = gi.iter().zip(gj).map(|(&a, &b)| a as f64 * b as f64).sum();
                ggt[i * k + j] = dot;
                ggt[j * k + i] = dot;
            }
        }
        // A = m·λ·I + GGᵀ ;  solve A·x = Gv
        let mlam = self.m as f64 * self.damp as f64;
        for i in 0..k {
            ggt[i * k + i] += mlam;
        }
        let x = solve_small(&mut ggt, &mut gv, k);
        // out = (v − Gᵀx)/λ
        let mut out = v.to_vec();
        for i in 0..k {
            let xi = x[i] as f32;
            if xi != 0.0 {
                for (o, &gi) in out.iter_mut().zip(&self.grads[i]) {
                    *o -= xi * gi;
                }
            }
        }
        let inv = 1.0 / self.damp;
        for o in out.iter_mut() {
            *o *= inv;
        }
        out
    }
}

/// Gaussian elimination with partial pivoting; consumes a and b.
fn solve_small(a: &mut [f64], b: &mut [f64], n: usize) -> Vec<f64> {
    for col in 0..n {
        // pivot
        let mut piv = col;
        for r in (col + 1)..n {
            if a[r * n + col].abs() > a[piv * n + col].abs() {
                piv = r;
            }
        }
        if piv != col {
            for c in 0..n {
                a.swap(col * n + c, piv * n + c);
            }
            b.swap(col, piv);
        }
        let d = a[col * n + col];
        if d.abs() < 1e-300 {
            continue; // singular direction; Woodbury damping should prevent
        }
        for r in (col + 1)..n {
            let f = a[r * n + col] / d;
            if f == 0.0 {
                continue;
            }
            for c in col..n {
                a[r * n + c] -= f * a[col * n + c];
            }
            b[r] -= f * b[col];
        }
    }
    let mut x = vec![0.0f64; n];
    for col in (0..n).rev() {
        let mut acc = b[col];
        for c in (col + 1)..n {
            acc -= a[col * n + c] * x[c];
        }
        let d = a[col * n + col];
        x[col] = if d.abs() < 1e-300 { 0.0 } else { acc / d };
    }
    x
}

impl FirstOrder for MFac {
    fn step(&mut self, params: &mut [f32], grad: &[f32], lr: f32) {
        let g: Vec<f32> = grad
            .iter()
            .zip(params.iter())
            .map(|(&g, &p)| g + self.weight_decay * p)
            .collect();
        self.push_grad(&g);
        let update = self.ihvp(&g);
        for i in 0..params.len() {
            self.buf[i] = self.momentum * self.buf[i] + update[i];
            params[i] -= lr * self.buf[i];
        }
    }

    fn state_bytes(&self) -> usize {
        // the m dense gradient copies dominate — the paper's Table 11 point
        self.grads.iter().map(|g| g.len() * 4).sum::<usize>() + self.buf.len() * 4
    }

    fn name(&self) -> &'static str {
        "M-FAC"
    }

    fn export_state(&self) -> (Vec<Vec<f32>>, Vec<f64>) {
        // momentum buffer first, then the gradient window in ring order
        let mut bufs = vec![self.buf.clone()];
        bufs.extend(self.grads.iter().cloned());
        (bufs, vec![self.head as f64])
    }

    fn import_state(&mut self, mut buffers: Vec<Vec<f32>>, counters: &[f64]) -> Result<()> {
        if buffers.is_empty() {
            bail!("M-FAC: missing momentum buffer");
        }
        let buf = buffers.remove(0);
        if buf.len() != self.buf.len() {
            bail!("M-FAC: momentum buffer has {} elems, expected {}", buf.len(), self.buf.len());
        }
        if buffers.len() > self.m {
            bail!("M-FAC: {} window gradients exceed window size {}", buffers.len(), self.m);
        }
        if let Some(g) = buffers.iter().find(|g| g.len() != buf.len()) {
            bail!("M-FAC: window gradient has {} elems, expected {}", g.len(), buf.len());
        }
        self.buf = buf;
        self.filled = buffers.len();
        self.grads = buffers;
        self.head = (counters.first().copied().unwrap_or(0.0) as usize) % self.m.max(1);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn solve_small_known_system() {
        // [[2,1],[1,3]] x = [3,5] -> x = [0.8, 1.4]
        let mut a = vec![2.0, 1.0, 1.0, 3.0];
        let mut b = vec![3.0, 5.0];
        let x = solve_small(&mut a, &mut b, 2);
        assert!((x[0] - 0.8).abs() < 1e-12);
        assert!((x[1] - 1.4).abs() < 1e-12);
    }

    #[test]
    fn ihvp_matches_direct_inverse() {
        // small dim: build H densely and compare
        let mut rng = Rng::new(3);
        let d = 6;
        let m = 4;
        let mut opt = MFac::new(d, m, 0.5, 0.0, 0.0);
        let grads: Vec<Vec<f32>> = (0..m).map(|_| rng.normal_vec(d)).collect();
        for g in &grads {
            opt.push_grad(g);
        }
        let v = rng.normal_vec(d);
        let got = opt.ihvp(&v);
        // dense H = λI + (1/m)ΣggT
        let mut h = vec![0.0f64; d * d];
        for i in 0..d {
            h[i * d + i] = 0.5;
        }
        for g in &grads {
            for i in 0..d {
                for j in 0..d {
                    h[i * d + j] += g[i] as f64 * g[j] as f64 / m as f64;
                }
            }
        }
        let mut rhs: Vec<f64> = v.iter().map(|&x| x as f64).collect();
        let want = solve_small(&mut h, &mut rhs, d);
        for (a, b) in got.iter().zip(&want) {
            assert!((*a as f64 - b).abs() < 1e-3, "{a} vs {b}");
        }
    }

    #[test]
    fn converges_on_quadratic() {
        let target = [1.0f32, -2.0, 3.0, 0.5];
        let mut opt = MFac::new(4, 8, 0.1, 0.9, 0.0);
        let mut p = vec![0.0f32; 4];
        for _ in 0..300 {
            let g: Vec<f32> = p.iter().zip(&target).map(|(a, b)| a - b).collect();
            opt.step(&mut p, &g, 0.05);
        }
        let err: f32 = p.iter().zip(&target).map(|(a, b)| (a - b).abs()).sum();
        assert!(err < 0.05, "{err}");
    }

    #[test]
    fn state_roundtrips_through_export_import() {
        let mut rng = Rng::new(9);
        let grads: Vec<Vec<f32>> = (0..9).map(|_| rng.normal_vec(6)).collect();
        let mut a = MFac::new(6, 3, 0.1, 0.9, 0.01);
        let mut p = vec![0.0f32; 6];
        for g in &grads[..5] {
            a.step(&mut p, g, 0.01);
        }
        let (bufs, counters) = a.export_state();
        assert_eq!(bufs.len(), 1 + 3); // momentum + full window
        let mut b = MFac::new(6, 3, 0.1, 0.9, 0.01);
        b.import_state(bufs, &counters).unwrap();
        let mut pa = p.clone();
        let mut pb = p;
        for g in &grads[5..] {
            a.step(&mut pa, g, 0.01);
            b.step(&mut pb, g, 0.01);
        }
        assert_eq!(pa, pb, "resumed M-FAC diverged");
    }

    #[test]
    fn state_bytes_grow_with_window() {
        let mut opt = MFac::new(100, 8, 0.1, 0.9, 0.0);
        assert_eq!(opt.state_bytes(), 400); // just momentum
        for _ in 0..10 {
            opt.push_grad(&[0.0; 100]);
        }
        // 8 gradient copies * 400 B + momentum 400 B
        assert_eq!(opt.state_bytes(), 8 * 400 + 400);
    }
}
