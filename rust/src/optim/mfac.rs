//! M-FAC [Frantar et al. 2021]: matrix-free inverse-Hessian-vector products
//! from a window of m recent gradients — the Appendix H.1 comparison arm
//! (Table 11). The paper's point is that M-FAC's m dense gradient copies
//! make it far *less* memory-efficient than 4-bit Shampoo; we reproduce
//! that by exact state accounting.
//!
//! H ≈ λI + (1/m)·Σ g_i g_iᵀ = λI + (1/m)GᵀG with G the m×d gradient
//! window. By Woodbury:
//!   H⁻¹v = (1/λ)·[ v − Gᵀ·(mλ·I_m + G·Gᵀ)⁻¹·G·v ].
//! The m×m solve is exact Gaussian elimination (m ≤ 64).

use anyhow::{bail, Result};

use crate::coordinator::scheduler::Scheduler;
use crate::quant::{fp32, StateBuf, StateCodec};

use super::first_order::{FirstOrder, StateSnapshot};

/// M-FAC optimizer state: gradient window + momentum buffer.
pub struct MFac {
    /// ring buffer of the last m gradients (each d long). Pinned to the
    /// `Fp32` codec: the window feeds an exact Woodbury solve, and its
    /// dense size IS the Table 11 memory point being reproduced.
    grads: Vec<StateBuf>,
    head: usize,
    m: usize,
    /// Woodbury damping λ.
    pub damp: f32,
    /// Momentum on the update direction.
    pub momentum: f32,
    buf: StateBuf,
    /// Weight-decay coefficient (added to the gradient).
    pub weight_decay: f32,
}

impl MFac {
    /// M-FAC over `dim` parameters with an m-gradient window.
    pub fn new(dim: usize, m: usize, damp: f32, momentum: f32, weight_decay: f32) -> Self {
        Self {
            grads: Vec::new(),
            head: 0,
            m,
            damp,
            momentum,
            buf: StateBuf::zeros(dim, fp32()),
            weight_decay,
        }
    }

    fn push_grad(&mut self, g: &[f32]) {
        if self.grads.len() < self.m {
            let mut b = StateBuf::zeros(g.len(), fp32());
            b.store(g);
            self.grads.push(b);
        } else {
            self.grads[self.head].store(g);
            self.head = (self.head + 1) % self.m;
        }
    }

    /// H⁻¹·v via Woodbury with the decoded window.
    fn ihvp(&self, window: &[Vec<f32>], v: &[f32]) -> Vec<f32> {
        let k = window.len();
        if k == 0 {
            return v.iter().map(|x| x / self.damp).collect();
        }
        // Gv (k) and GGᵀ (k×k)
        let mut gv = vec![0.0f64; k];
        let mut ggt = vec![0.0f64; k * k];
        for i in 0..k {
            let gi = &window[i];
            gv[i] = gi.iter().zip(v).map(|(&a, &b)| a as f64 * b as f64).sum();
            for j in i..k {
                let gj = &window[j];
                let dot: f64 = gi.iter().zip(gj).map(|(&a, &b)| a as f64 * b as f64).sum();
                ggt[i * k + j] = dot;
                ggt[j * k + i] = dot;
            }
        }
        // A = m·λ·I + GGᵀ ;  solve A·x = Gv
        let mlam = self.m as f64 * self.damp as f64;
        for i in 0..k {
            ggt[i * k + i] += mlam;
        }
        let x = solve_small(&mut ggt, &mut gv, k);
        // out = (v − Gᵀx)/λ
        let mut out = v.to_vec();
        for i in 0..k {
            let xi = x[i] as f32;
            if xi != 0.0 {
                for (o, &gi) in out.iter_mut().zip(&window[i]) {
                    *o -= xi * gi;
                }
            }
        }
        let inv = 1.0 / self.damp;
        for o in out.iter_mut() {
            *o *= inv;
        }
        out
    }

    /// Decode the whole ring (fp32 → exact) for one Woodbury solve.
    fn window(&self) -> Vec<Vec<f32>> {
        self.grads.iter().map(|b| b.load()).collect()
    }
}

/// Gaussian elimination with partial pivoting; consumes a and b.
fn solve_small(a: &mut [f64], b: &mut [f64], n: usize) -> Vec<f64> {
    for col in 0..n {
        // pivot
        let mut piv = col;
        for r in (col + 1)..n {
            if a[r * n + col].abs() > a[piv * n + col].abs() {
                piv = r;
            }
        }
        if piv != col {
            for c in 0..n {
                a.swap(col * n + c, piv * n + c);
            }
            b.swap(col, piv);
        }
        let d = a[col * n + col];
        if d.abs() < 1e-300 {
            continue; // singular direction; Woodbury damping should prevent
        }
        for r in (col + 1)..n {
            let f = a[r * n + col] / d;
            if f == 0.0 {
                continue;
            }
            for c in col..n {
                a[r * n + c] -= f * a[col * n + c];
            }
            b[r] -= f * b[col];
        }
    }
    let mut x = vec![0.0f64; n];
    for col in (0..n).rev() {
        let mut acc = b[col];
        for c in (col + 1)..n {
            acc -= a[col * n + c] * x[c];
        }
        let d = a[col * n + col];
        x[col] = if d.abs() < 1e-300 { 0.0 } else { acc / d };
    }
    x
}

impl FirstOrder for MFac {
    // M-FAC's cost is the Woodbury solve (window dot products), not the
    // elementwise tail, so the update stays serial regardless of `sched`.
    fn step_par(&mut self, params: &mut [f32], grad: &[f32], lr: f32, _sched: &Scheduler) {
        let g: Vec<f32> = grad
            .iter()
            .zip(params.iter())
            .map(|(&g, &p)| g + self.weight_decay * p)
            .collect();
        self.push_grad(&g);
        let window = self.window();
        let update = self.ihvp(&window, &g);
        let mut buf = self.buf.load();
        for i in 0..params.len() {
            buf[i] = self.momentum * buf[i] + update[i];
            params[i] -= lr * buf[i];
        }
        self.buf.store(&buf);
    }

    fn state_bytes(&self) -> usize {
        // the m dense gradient copies dominate — the paper's Table 11 point
        self.grads.iter().map(|g| g.state_bytes()).sum::<usize>() + self.buf.state_bytes()
    }

    fn name(&self) -> &'static str {
        "M-FAC"
    }

    fn export_state(&self) -> StateSnapshot {
        // momentum buffer first, then the gradient window in ring order
        let mut buffers = vec![(self.buf.codec().name(), self.buf.encoded().clone())];
        for g in &self.grads {
            buffers.push((g.codec().name(), g.encoded().clone()));
        }
        StateSnapshot { buffers, counters: vec![self.head as f64] }
    }

    fn import_state(&mut self, snap: StateSnapshot) -> Result<()> {
        // validate everything before mutating anything (atomic restore)
        let mut it = snap.buffers.into_iter();
        let Some((name, enc)) = it.next() else {
            bail!("M-FAC: missing momentum buffer")
        };
        if name != self.buf.codec().name() {
            bail!("M-FAC: momentum buffer saved with codec {name}, optimizer uses {}",
                  self.buf.codec().name());
        }
        let dim = self.buf.len();
        if enc.len != dim || enc.bytes.len() != self.buf.codec().state_bytes(dim) {
            bail!("M-FAC: momentum buffer has {} elems, expected {dim}", enc.len);
        }
        let rest: Vec<_> = it.collect();
        if rest.len() > self.m {
            bail!("M-FAC: {} window gradients exceed window size {}", rest.len(), self.m);
        }
        let mut grads = Vec::with_capacity(rest.len());
        for (i, (name, genc)) in rest.into_iter().enumerate() {
            if name != "fp32" {
                bail!("M-FAC: window gradient {i} saved with codec {name}, expected fp32");
            }
            if genc.len != dim {
                bail!("M-FAC: window gradient {i} has {} elems, expected {dim}", genc.len);
            }
            let mut b = StateBuf::zeros(dim, fp32());
            b.restore(genc)
                .map_err(|e| anyhow::anyhow!("M-FAC: window gradient {i}: {e}"))?;
            grads.push(b);
        }
        self.buf.restore(enc).expect("validated above");
        self.grads = grads;
        self.head = (snap.counters.first().copied().unwrap_or(0.0) as usize) % self.m.max(1);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn solve_small_known_system() {
        // [[2,1],[1,3]] x = [3,5] -> x = [0.8, 1.4]
        let mut a = vec![2.0, 1.0, 1.0, 3.0];
        let mut b = vec![3.0, 5.0];
        let x = solve_small(&mut a, &mut b, 2);
        assert!((x[0] - 0.8).abs() < 1e-12);
        assert!((x[1] - 1.4).abs() < 1e-12);
    }

    #[test]
    fn ihvp_matches_direct_inverse() {
        // small dim: build H densely and compare
        let mut rng = Rng::new(3);
        let d = 6;
        let m = 4;
        let mut opt = MFac::new(d, m, 0.5, 0.0, 0.0);
        let grads: Vec<Vec<f32>> = (0..m).map(|_| rng.normal_vec(d)).collect();
        for g in &grads {
            opt.push_grad(g);
        }
        let v = rng.normal_vec(d);
        let got = opt.ihvp(&opt.window(), &v);
        // dense H = λI + (1/m)ΣggT
        let mut h = vec![0.0f64; d * d];
        for i in 0..d {
            h[i * d + i] = 0.5;
        }
        for g in &grads {
            for i in 0..d {
                for j in 0..d {
                    h[i * d + j] += g[i] as f64 * g[j] as f64 / m as f64;
                }
            }
        }
        let mut rhs: Vec<f64> = v.iter().map(|&x| x as f64).collect();
        let want = solve_small(&mut h, &mut rhs, d);
        for (a, b) in got.iter().zip(&want) {
            assert!((*a as f64 - b).abs() < 1e-3, "{a} vs {b}");
        }
    }

    #[test]
    fn converges_on_quadratic() {
        let target = [1.0f32, -2.0, 3.0, 0.5];
        let mut opt = MFac::new(4, 8, 0.1, 0.9, 0.0);
        let mut p = vec![0.0f32; 4];
        for _ in 0..300 {
            let g: Vec<f32> = p.iter().zip(&target).map(|(a, b)| a - b).collect();
            opt.step(&mut p, &g, 0.05);
        }
        let err: f32 = p.iter().zip(&target).map(|(a, b)| (a - b).abs()).sum();
        assert!(err < 0.05, "{err}");
    }

    #[test]
    fn state_roundtrips_through_export_import() {
        let mut rng = Rng::new(9);
        let grads: Vec<Vec<f32>> = (0..9).map(|_| rng.normal_vec(6)).collect();
        let mut a = MFac::new(6, 3, 0.1, 0.9, 0.01);
        let mut p = vec![0.0f32; 6];
        for g in &grads[..5] {
            a.step(&mut p, g, 0.01);
        }
        let snap = a.export_state();
        assert_eq!(snap.buffers.len(), 1 + 3); // momentum + full window
        let mut b = MFac::new(6, 3, 0.1, 0.9, 0.01);
        b.import_state(snap).unwrap();
        let mut pa = p.clone();
        let mut pb = p;
        for g in &grads[5..] {
            a.step(&mut pa, g, 0.01);
            b.step(&mut pb, g, 0.01);
        }
        assert_eq!(pa, pb, "resumed M-FAC diverged");
    }

    #[test]
    fn state_bytes_grow_with_window() {
        let mut opt = MFac::new(100, 8, 0.1, 0.9, 0.0);
        assert_eq!(opt.state_bytes(), 400); // just momentum
        for _ in 0..10 {
            opt.push_grad(&[0.0; 100]);
        }
        // 8 gradient copies * 400 B + momentum 400 B
        assert_eq!(opt.state_bytes(), 8 * 400 + 400);
    }
}
