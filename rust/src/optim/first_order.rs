//! First-order optimizers F (eq. 1) — native Rust elementwise hot path
//! (DESIGN.md decision 7), cross-checked against the L2 artifact versions in
//! rust/tests/runtime_integration.rs.
//!
//! Implemented: SGDM, AdamW, NAdamW, Adagrad (the paper's Fs), plus the
//! comparison arms of Appendix H: schedule-free SGD/AdamW [Defazio et al.]
//! and M-FAC (separate module).
//!
//! Every moment buffer lives in a [`StateBuf`] — codec-encoded storage
//! behind the `first_order.bits` / `first_order.mapping` policy — so the
//! same optimizers run with fp32, bf16, 8-bit, or 4-bit states (the
//! Table 13 memory baselines of Dettmers et al. 2021 / Li et al. 2023).
//! With the default `Fp32` codec every trajectory is bit-identical to
//! direct f32 storage; quantized codecs decode → update → re-encode each
//! step, which *is* the low-bit optimizer algorithm.

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::quant::{fp32, EncodedVec, StateBuf, StateCodec};

/// Serialized optimizer state: codec-encoded buffers (codec name + payload)
/// plus scalar counters. Checkpoints persist the payload bytes verbatim, so
/// export → import round-trips are bit-exact even for quantized states —
/// a resumed run continues the exact trajectory.
#[derive(Debug, Clone)]
pub struct StateSnapshot {
    pub buffers: Vec<(String, EncodedVec)>,
    pub counters: Vec<f64>,
}

/// A first-order optimizer over a flat parameter vector.
pub trait FirstOrder {
    /// One update. `params` holds the *training* iterate (for schedule-free
    /// methods this is the gradient point y); `grad` its gradient; `lr` the
    /// scheduled learning rate.
    fn step(&mut self, params: &mut [f32], grad: &[f32], lr: f32);

    /// Parameters to use for evaluation (schedule-free returns the average).
    fn eval_params(&self, current: &[f32]) -> Vec<f32> {
        current.to_vec()
    }

    /// Exact optimizer-state bytes (for the Table 2/13 memory accounting).
    fn state_bytes(&self) -> usize;

    fn name(&self) -> &'static str;

    /// Snapshot the full mutable state as codec-encoded buffers + scalar
    /// counters — enough for `import_state` on an identically configured
    /// optimizer to resume bit-identically. Buffer/counter order is each
    /// optimizer's contract; checkpoints persist both.
    fn export_state(&self) -> StateSnapshot;

    /// Restore a snapshot produced by [`FirstOrder::export_state`].
    fn import_state(&mut self, snap: StateSnapshot) -> Result<()>;
}

/// Shared export helper: encoded buffers in declaration order + counters.
fn snapshot(bufs: &[&StateBuf], counters: Vec<f64>) -> StateSnapshot {
    StateSnapshot {
        buffers: bufs
            .iter()
            .map(|b| (b.codec().name(), b.encoded().clone()))
            .collect(),
        counters,
    }
}

/// Shared validation + restore for `import_state` impls: buffer count,
/// codec identity, and payload lengths. Validates EVERY buffer before
/// mutating any, so a failed import leaves the optimizer untouched.
/// Returns the snapshot's counters.
fn restore_buffers(
    who: &str,
    bufs: &mut [&mut StateBuf],
    snap: StateSnapshot,
) -> Result<Vec<f64>> {
    if snap.buffers.len() != bufs.len() {
        bail!(
            "{who}: expected {} state buffers, got {}",
            bufs.len(),
            snap.buffers.len()
        );
    }
    for (i, ((name, enc), buf)) in snap.buffers.iter().zip(bufs.iter()).enumerate() {
        if *name != buf.codec().name() {
            bail!(
                "{who}: state buffer {i} was saved with codec {name}, optimizer uses {}",
                buf.codec().name()
            );
        }
        if enc.len != buf.len() || enc.bytes.len() != buf.codec().state_bytes(enc.len) {
            bail!(
                "{who}: state buffer {i} payload is ({} elems, {} bytes), expected \
                 ({} elems, {} bytes)",
                enc.len,
                enc.bytes.len(),
                buf.len(),
                buf.codec().state_bytes(buf.len())
            );
        }
    }
    for ((_, enc), buf) in snap.buffers.into_iter().zip(bufs.iter_mut()) {
        buf.restore(enc).expect("validated above");
    }
    Ok(snap.counters)
}

// ---------------------------------------------------------------------------

pub struct Sgdm {
    buf: StateBuf,
    pub momentum: f32,
    pub weight_decay: f32,
}

impl Sgdm {
    pub fn new(n: usize, momentum: f32, weight_decay: f32) -> Self {
        Self { buf: StateBuf::zeros(n, fp32()), momentum, weight_decay }
    }

    /// Store the momentum buffer through `codec` (the `first_order.bits`
    /// policy). States are zero at construction, so this is lossless.
    pub fn with_codec(mut self, codec: Arc<dyn StateCodec>) -> Self {
        self.buf = StateBuf::zeros(self.buf.len(), codec);
        self
    }
}

impl FirstOrder for Sgdm {
    fn step(&mut self, params: &mut [f32], grad: &[f32], lr: f32) {
        let mut buf = self.buf.load();
        for i in 0..params.len() {
            let g = grad[i] + self.weight_decay * params[i];
            buf[i] = self.momentum * buf[i] + g;
            params[i] -= lr * buf[i];
        }
        self.buf.store(&buf);
    }

    fn state_bytes(&self) -> usize {
        self.buf.state_bytes()
    }

    fn name(&self) -> &'static str {
        "SGDM"
    }

    fn export_state(&self) -> StateSnapshot {
        snapshot(&[&self.buf], Vec::new())
    }

    fn import_state(&mut self, snap: StateSnapshot) -> Result<()> {
        restore_buffers("SGDM", &mut [&mut self.buf], snap)?;
        Ok(())
    }
}

// ---------------------------------------------------------------------------

pub struct AdamW {
    m: StateBuf,
    v: StateBuf,
    step: u64,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub weight_decay: f32,
    pub nesterov: bool,
}

impl AdamW {
    pub fn new(n: usize, beta1: f32, beta2: f32, eps: f32, weight_decay: f32) -> Self {
        Self {
            m: StateBuf::zeros(n, fp32()),
            v: StateBuf::zeros(n, fp32()),
            step: 0,
            beta1,
            beta2,
            eps,
            weight_decay,
            nesterov: false,
        }
    }

    /// NAdamW [Dozat 2016]: Nesterov momentum inside AdamW.
    pub fn nadamw(n: usize, beta1: f32, beta2: f32, eps: f32, weight_decay: f32) -> Self {
        Self { nesterov: true, ..Self::new(n, beta1, beta2, eps, weight_decay) }
    }

    /// Store both moments through `codec` (the `first_order.bits` policy).
    pub fn with_codec(mut self, codec: Arc<dyn StateCodec>) -> Self {
        let n = self.m.len();
        self.m = StateBuf::zeros(n, codec.clone());
        self.v = StateBuf::zeros(n, codec);
        self
    }
}

impl FirstOrder for AdamW {
    fn step(&mut self, params: &mut [f32], grad: &[f32], lr: f32) {
        self.step += 1;
        let t = self.step as f32;
        let bc1 = 1.0 - self.beta1.powf(t);
        let bc2 = 1.0 - self.beta2.powf(t);
        let bc1_next = 1.0 - self.beta1.powf(t + 1.0);
        let mut m = self.m.load();
        let mut v = self.v.load();
        for i in 0..params.len() {
            let g = grad[i];
            m[i] = self.beta1 * m[i] + (1.0 - self.beta1) * g;
            v[i] = self.beta2 * v[i] + (1.0 - self.beta2) * g * g;
            let mh = if self.nesterov {
                (self.beta1 * m[i] + (1.0 - self.beta1) * g) / bc1_next
            } else {
                m[i] / bc1
            };
            let vh = v[i] / bc2;
            params[i] -= lr * (mh / (vh.sqrt() + self.eps) + self.weight_decay * params[i]);
        }
        self.m.store(&m);
        self.v.store(&v);
    }

    fn state_bytes(&self) -> usize {
        self.m.state_bytes() + self.v.state_bytes()
    }

    fn name(&self) -> &'static str {
        if self.nesterov { "NAdamW" } else { "AdamW" }
    }

    fn export_state(&self) -> StateSnapshot {
        snapshot(&[&self.m, &self.v], vec![self.step as f64])
    }

    fn import_state(&mut self, snap: StateSnapshot) -> Result<()> {
        let who = self.name();
        let Some(&step) = snap.counters.first() else {
            bail!("{who}: missing step counter")
        };
        restore_buffers(who, &mut [&mut self.m, &mut self.v], snap)?;
        self.step = step as u64;
        Ok(())
    }
}

// ---------------------------------------------------------------------------

pub struct Adagrad {
    acc: StateBuf,
    pub eps: f32,
    pub weight_decay: f32,
}

impl Adagrad {
    pub fn new(n: usize, eps: f32, weight_decay: f32) -> Self {
        Self { acc: StateBuf::zeros(n, fp32()), eps, weight_decay }
    }

    /// Store the accumulator through `codec` (the `first_order.bits` policy).
    pub fn with_codec(mut self, codec: Arc<dyn StateCodec>) -> Self {
        self.acc = StateBuf::zeros(self.acc.len(), codec);
        self
    }
}

impl FirstOrder for Adagrad {
    fn step(&mut self, params: &mut [f32], grad: &[f32], lr: f32) {
        let mut acc = self.acc.load();
        for i in 0..params.len() {
            let g = grad[i] + self.weight_decay * params[i];
            acc[i] += g * g;
            params[i] -= lr * g / (acc[i].sqrt() + self.eps);
        }
        self.acc.store(&acc);
    }

    fn state_bytes(&self) -> usize {
        self.acc.state_bytes()
    }

    fn name(&self) -> &'static str {
        "Adagrad"
    }

    fn export_state(&self) -> StateSnapshot {
        snapshot(&[&self.acc], Vec::new())
    }

    fn import_state(&mut self, snap: StateSnapshot) -> Result<()> {
        restore_buffers("Adagrad", &mut [&mut self.acc], snap)?;
        Ok(())
    }
}

// ---------------------------------------------------------------------------

/// Schedule-free optimizers [Defazio et al. 2024, "The Road Less Scheduled"]
/// — the Appendix H.1 comparison arm (Table 9). The caller's parameter
/// buffer holds y_t = (1−β)·z_t + β·x_t (the gradient point); `eval_params`
/// returns the Polyak-style average x_t.
///
/// The z/x iterate copies are pinned to the `Fp32` codec — quantizing the
/// averaged iterate corrupts the Polyak average itself, not just a moment —
/// so the `first_order.bits` policy applies to the AdamW v moment only.
pub struct ScheduleFree {
    z: StateBuf,
    x: StateBuf,
    t: u64,
    pub beta: f32,
    pub weight_decay: f32,
    /// Some => AdamW-normalized base step (beta2, eps); None => SGD.
    adam: Option<(f32, f32, StateBuf)>,
    warmup: u64,
    lr_sum_sq: f64,
    initialized: bool,
}

impl ScheduleFree {
    pub fn sgd(n: usize, beta: f32, weight_decay: f32, warmup: usize) -> Self {
        Self {
            z: StateBuf::zeros(n, fp32()),
            x: StateBuf::zeros(n, fp32()),
            t: 0,
            beta,
            weight_decay,
            adam: None,
            warmup: warmup as u64,
            lr_sum_sq: 0.0,
            initialized: false,
        }
    }

    pub fn adamw(n: usize, beta: f32, beta2: f32, eps: f32, weight_decay: f32,
                 warmup: usize) -> Self {
        Self {
            adam: Some((beta2, eps, StateBuf::zeros(n, fp32()))),
            ..Self::sgd(n, beta, weight_decay, warmup)
        }
    }

    /// Store the v moment (AdamW variant) through `codec`; z/x stay fp32.
    pub fn with_codec(mut self, codec: Arc<dyn StateCodec>) -> Self {
        if let Some((_, _, v)) = &mut self.adam {
            *v = StateBuf::zeros(v.len(), codec);
        }
        self
    }
}

impl FirstOrder for ScheduleFree {
    fn step(&mut self, params: &mut [f32], grad: &[f32], lr: f32) {
        if !self.initialized {
            self.z.store(params);
            self.x.store(params);
            self.initialized = true;
        }
        self.t += 1;
        // internal warmup ramp (the method is schedule-free, warmup excepted)
        let ramp = (self.t as f32 / self.warmup.max(1) as f32).min(1.0);
        let gamma = lr * ramp;
        // weight x by γ² (paper's recommended weighting)
        self.lr_sum_sq += (gamma as f64) * (gamma as f64);
        let c = if self.lr_sum_sq > 0.0 {
            ((gamma as f64) * (gamma as f64) / self.lr_sum_sq) as f32
        } else {
            1.0
        };
        let bc2 = self.adam.as_ref().map(|(b2, _, _)| 1.0 - b2.powf(self.t as f32));
        let mut z = self.z.load();
        let mut x = self.x.load();
        let mut adam = self
            .adam
            .as_ref()
            .map(|(b2, eps, vb)| (*b2, *eps, vb.load()));
        for i in 0..params.len() {
            let g = grad[i] + self.weight_decay * params[i];
            let step_dir = match &mut adam {
                None => g,
                Some((b2, eps, v)) => {
                    v[i] = *b2 * v[i] + (1.0 - *b2) * g * g;
                    let vh = v[i] / bc2.unwrap();
                    g / (vh.sqrt() + *eps)
                }
            };
            z[i] -= gamma * step_dir;
            x[i] = (1.0 - c) * x[i] + c * z[i];
            // next gradient point y = (1−β)z + βx
            params[i] = (1.0 - self.beta) * z[i] + self.beta * x[i];
        }
        self.z.store(&z);
        self.x.store(&x);
        if let (Some((_, _, vb)), Some((_, _, v))) = (&mut self.adam, &adam) {
            vb.store(v);
        }
    }

    fn eval_params(&self, current: &[f32]) -> Vec<f32> {
        if self.initialized {
            self.x.load()
        } else {
            current.to_vec()
        }
    }

    fn state_bytes(&self) -> usize {
        let base = self.z.state_bytes() + self.x.state_bytes();
        base + self.adam.as_ref().map(|(_, _, v)| v.state_bytes()).unwrap_or(0)
    }

    fn name(&self) -> &'static str {
        if self.adam.is_some() { "AdamWScheduleFree" } else { "SGDScheduleFree" }
    }

    fn export_state(&self) -> StateSnapshot {
        let mut bufs = vec![&self.z, &self.x];
        if let Some((_, _, v)) = &self.adam {
            bufs.push(v);
        }
        let init = if self.initialized { 1.0 } else { 0.0 };
        snapshot(&bufs, vec![self.t as f64, self.lr_sum_sq, init])
    }

    fn import_state(&mut self, snap: StateSnapshot) -> Result<()> {
        let who = self.name();
        if snap.counters.len() < 3 {
            bail!("{who}: expected 3 counters, got {}", snap.counters.len());
        }
        let (t, lr_sum_sq, init) = (snap.counters[0], snap.counters[1], snap.counters[2]);
        let mut bufs: Vec<&mut StateBuf> = vec![&mut self.z, &mut self.x];
        if let Some((_, _, v)) = &mut self.adam {
            bufs.push(v);
        }
        restore_buffers(who, &mut bufs, snap)?;
        self.t = t as u64;
        self.lr_sum_sq = lr_sum_sq;
        self.initialized = init != 0.0;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{codec_for, Mapping};

    /// Quadratic f(x) = ½‖x − x*‖²: every optimizer must converge.
    fn run_quadratic(opt: &mut dyn FirstOrder, lr: f32, steps: usize) -> f32 {
        let target = [1.0f32, -2.0, 3.0, 0.5];
        let mut p = vec![0.0f32; 4];
        for _ in 0..steps {
            let g: Vec<f32> = p.iter().zip(&target).map(|(a, b)| a - b).collect();
            opt.step(&mut p, &g, lr);
        }
        let ev = opt.eval_params(&p);
        ev.iter()
            .zip(&target)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f32>()
            .sqrt()
    }

    #[test]
    fn sgdm_converges() {
        let mut o = Sgdm::new(4, 0.9, 0.0);
        assert!(run_quadratic(&mut o, 0.05, 300) < 1e-3);
    }

    #[test]
    fn adamw_converges() {
        let mut o = AdamW::new(4, 0.9, 0.999, 1e-8, 0.0);
        assert!(run_quadratic(&mut o, 0.05, 800) < 1e-2);
    }

    #[test]
    fn nadamw_converges() {
        let mut o = AdamW::nadamw(4, 0.9, 0.999, 1e-8, 0.0);
        assert!(run_quadratic(&mut o, 0.05, 800) < 1e-2);
    }

    #[test]
    fn adagrad_converges() {
        let mut o = Adagrad::new(4, 1e-10, 0.0);
        assert!(run_quadratic(&mut o, 0.5, 800) < 1e-2);
    }

    #[test]
    fn schedule_free_sgd_converges() {
        let mut o = ScheduleFree::sgd(4, 0.9, 0.0, 10);
        assert!(run_quadratic(&mut o, 0.1, 600) < 1e-2);
    }

    #[test]
    fn schedule_free_adamw_converges() {
        let mut o = ScheduleFree::adamw(4, 0.9, 0.999, 1e-8, 0.0, 10);
        assert!(run_quadratic(&mut o, 0.05, 800) < 2e-2);
    }

    #[test]
    fn quantized_moments_still_converge() {
        // 8-bit moments track fp32 closely; 4-bit moments are noisier but
        // must still drive the quadratic loss down hard (the paper's point:
        // low-bit states trade a little accuracy for a lot of memory)
        let mut q8 = AdamW::new(4, 0.9, 0.999, 1e-8, 0.0)
            .with_codec(codec_for(8, Mapping::Dt));
        assert!(run_quadratic(&mut q8, 0.05, 800) < 0.1);
        let mut q4 = AdamW::new(4, 0.9, 0.999, 1e-8, 0.0)
            .with_codec(codec_for(4, Mapping::Dt));
        let dist = run_quadratic(&mut q4, 0.05, 800);
        assert!(dist < 1.0, "4-bit AdamW stalled at distance {dist}");
        let mut s8 = Sgdm::new(4, 0.9, 0.0).with_codec(codec_for(8, Mapping::Dt));
        assert!(run_quadratic(&mut s8, 0.05, 400) < 0.1);
    }

    #[test]
    fn adamw_matches_reference_formula() {
        // hand-computed single AdamW step
        let mut o = AdamW::new(1, 0.9, 0.999, 1e-8, 0.01);
        let mut p = vec![1.0f32];
        o.step(&mut p, &[0.5], 0.1);
        // m=0.05, v=0.00025/..., mh=0.05/0.1=0.5, vh=0.00025/0.001=0.25
        // p = 1 - 0.1*(0.5/(0.5+1e-8) + 0.01*1) = 1 - 0.1*1.00999 ≈ 0.899
        assert!((p[0] - 0.899).abs() < 1e-3, "{}", p[0]);
    }

    #[test]
    fn weight_decay_shrinks_params() {
        let mut o = Sgdm::new(1, 0.0, 0.1);
        let mut p = vec![1.0f32];
        o.step(&mut p, &[0.0], 0.5);
        assert!(p[0] < 1.0);
    }

    /// Drive `a` some steps, snapshot into `b`, then both must evolve
    /// bit-identically.
    fn check_state_roundtrip(a: &mut dyn FirstOrder, b: &mut dyn FirstOrder, lr: f32) {
        let target = [1.0f32, -2.0, 3.0, 0.5];
        let mut p = vec![0.0f32; 4];
        for _ in 0..7 {
            let g: Vec<f32> = p.iter().zip(&target).map(|(x, t)| x - t).collect();
            a.step(&mut p, &g, lr);
        }
        b.import_state(a.export_state()).unwrap();
        let mut pa = p.clone();
        let mut pb = p;
        for _ in 0..5 {
            let ga: Vec<f32> = pa.iter().zip(&target).map(|(x, t)| x - t).collect();
            a.step(&mut pa, &ga, lr);
            let gb: Vec<f32> = pb.iter().zip(&target).map(|(x, t)| x - t).collect();
            b.step(&mut pb, &gb, lr);
        }
        assert_eq!(pa, pb, "resumed optimizer diverged");
        assert_eq!(a.eval_params(&pa), b.eval_params(&pb));
    }

    #[test]
    fn state_roundtrips_bit_identically() {
        check_state_roundtrip(
            &mut Sgdm::new(4, 0.9, 0.01),
            &mut Sgdm::new(4, 0.9, 0.01),
            0.05,
        );
        check_state_roundtrip(
            &mut AdamW::new(4, 0.9, 0.999, 1e-8, 0.01),
            &mut AdamW::new(4, 0.9, 0.999, 1e-8, 0.01),
            0.05,
        );
        check_state_roundtrip(
            &mut Adagrad::new(4, 1e-10, 0.0),
            &mut Adagrad::new(4, 1e-10, 0.0),
            0.1,
        );
        check_state_roundtrip(
            &mut ScheduleFree::adamw(4, 0.9, 0.999, 1e-8, 0.0, 5),
            &mut ScheduleFree::adamw(4, 0.9, 0.999, 1e-8, 0.0, 5),
            0.05,
        );
    }

    #[test]
    fn quantized_state_roundtrips_bit_identically() {
        // encoded bytes are the checkpoint payload, so resume is exact at
        // ANY bitwidth — no requantization error
        let q4 = || codec_for(4, Mapping::Dt);
        check_state_roundtrip(
            &mut AdamW::new(4, 0.9, 0.999, 1e-8, 0.01).with_codec(q4()),
            &mut AdamW::new(4, 0.9, 0.999, 1e-8, 0.01).with_codec(q4()),
            0.05,
        );
        let q8 = || codec_for(8, Mapping::Linear2);
        check_state_roundtrip(
            &mut Sgdm::new(4, 0.9, 0.01).with_codec(q8()),
            &mut Sgdm::new(4, 0.9, 0.01).with_codec(q8()),
            0.05,
        );
    }

    #[test]
    fn import_rejects_mismatched_buffers() {
        use crate::quant::Fp32;
        let snap = |bufs: Vec<Vec<f32>>, counters: Vec<f64>| StateSnapshot {
            buffers: bufs
                .iter()
                .map(|b| ("fp32".to_string(), Fp32.encode(b)))
                .collect(),
            counters,
        };
        let mut o = AdamW::new(4, 0.9, 0.999, 1e-8, 0.0);
        // one buffer short
        assert!(o.import_state(snap(vec![vec![0.0; 4]], vec![1.0])).is_err());
        // bad length
        assert!(o
            .import_state(snap(vec![vec![0.0; 3], vec![0.0; 4]], vec![1.0]))
            .is_err());
        // no counter
        assert!(o
            .import_state(snap(vec![vec![0.0; 4], vec![0.0; 4]], Vec::new()))
            .is_err());
        assert!(o
            .import_state(snap(vec![vec![0.0; 4], vec![0.0; 4]], vec![3.0]))
            .is_ok());
        // codec mismatch: fp32 snapshot into a q4-configured optimizer
        let mut q = AdamW::new(4, 0.9, 0.999, 1e-8, 0.0)
            .with_codec(codec_for(4, Mapping::Dt));
        let err = q
            .import_state(snap(vec![vec![0.0; 4], vec![0.0; 4]], vec![3.0]))
            .unwrap_err()
            .to_string();
        assert!(err.contains("codec"), "{err}");
    }

    #[test]
    fn state_bytes() {
        assert_eq!(Sgdm::new(10, 0.9, 0.0).state_bytes(), 40);
        assert_eq!(AdamW::new(10, 0.9, 0.999, 1e-8, 0.0).state_bytes(), 80);
        assert_eq!(ScheduleFree::sgd(10, 0.9, 0.0, 1).state_bytes(), 80);
        assert_eq!(
            ScheduleFree::adamw(10, 0.9, 0.999, 1e-8, 0.0, 1).state_bytes(),
            120
        );
        // 4-bit moments: 2 × (64 packed + 8 scale) bytes for n=128 vs 1024
        let q4 = AdamW::new(128, 0.9, 0.999, 1e-8, 0.0)
            .with_codec(codec_for(4, Mapping::Dt));
        assert_eq!(q4.state_bytes(), 2 * (64 + 8));
    }
}
