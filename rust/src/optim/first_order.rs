//! First-order optimizers F (eq. 1) — native Rust elementwise hot path
//! (DESIGN.md decision 7), cross-checked against the L2 artifact versions in
//! rust/tests/runtime_integration.rs.
//!
//! Implemented: SGDM, AdamW, NAdamW, Adagrad (the paper's Fs), plus the
//! comparison arms of Appendix H: schedule-free SGD/AdamW [Defazio et al.]
//! and M-FAC (separate module).
//!
//! Every moment buffer lives in a [`StateBuf`] — codec-encoded storage
//! behind the `first_order.bits` / `first_order.mapping` policy — so the
//! same optimizers run with fp32, bf16, 8-bit, or 4-bit states (the
//! Table 13 memory baselines of Dettmers et al. 2021 / Li et al. 2023).
//! With the default `Fp32` codec every trajectory is bit-identical to
//! direct f32 storage; quantized codecs decode → update → re-encode each
//! step, which *is* the low-bit optimizer algorithm.
//!
//! The elementwise hot loop is index-independent, so
//! [`FirstOrder::step_par`] chunks it across the parallel block engine's
//! persistent pool (`par_elementwise`) — bit-identical to the serial loop
//! at any worker count, and overlappable with the engine's background
//! PU/PIRU jobs.

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::coordinator::scheduler::Scheduler;
use crate::quant::{fp32, EncodedVec, StateBuf, StateCodec};

/// Serialized optimizer state: codec-encoded buffers (codec name + payload)
/// plus scalar counters. Checkpoints persist the payload bytes verbatim, so
/// export → import round-trips are bit-exact even for quantized states —
/// a resumed run continues the exact trajectory.
#[derive(Debug, Clone)]
pub struct StateSnapshot {
    /// (codec name, encoded payload) per state buffer, in each optimizer's
    /// declaration order.
    pub buffers: Vec<(String, EncodedVec)>,
    /// Scalar counters (step counts, accumulated sums, init flags).
    pub counters: Vec<f64>,
}

/// A first-order optimizer over a flat parameter vector.
pub trait FirstOrder {
    /// One update, with the elementwise hot loop chunked across `sched`'s
    /// persistent pool (the trainer passes the same engine that drives the
    /// per-block second-order work). The update is index-independent, so
    /// any worker count is bit-identical to the serial loop; with an inline
    /// scheduler (or a small model) this *is* the serial loop.
    ///
    /// `params` holds the *training* iterate (for schedule-free methods
    /// this is the gradient point y); `grad` its gradient; `lr` the
    /// scheduled learning rate.
    fn step_par(&mut self, params: &mut [f32], grad: &[f32], lr: f32, sched: &Scheduler);

    /// One update on the calling thread only — [`FirstOrder::step_par`]
    /// with an inline scheduler.
    fn step(&mut self, params: &mut [f32], grad: &[f32], lr: f32) {
        self.step_par(params, grad, lr, &Scheduler::inline());
    }

    /// Parameters to use for evaluation (schedule-free returns the average).
    fn eval_params(&self, current: &[f32]) -> Vec<f32> {
        current.to_vec()
    }

    /// Exact optimizer-state bytes (for the Table 2/13 memory accounting).
    fn state_bytes(&self) -> usize;

    /// Canonical display name (Table 2/4 row labels, checkpoint identity).
    fn name(&self) -> &'static str;

    /// Snapshot the full mutable state as codec-encoded buffers + scalar
    /// counters — enough for `import_state` on an identically configured
    /// optimizer to resume bit-identically. Buffer/counter order is each
    /// optimizer's contract; checkpoints persist both.
    fn export_state(&self) -> StateSnapshot;

    /// Restore a snapshot produced by [`FirstOrder::export_state`].
    fn import_state(&mut self, snap: StateSnapshot) -> Result<()>;
}

/// Shared export helper: encoded buffers in declaration order + counters.
fn snapshot(bufs: &[&StateBuf], counters: Vec<f64>) -> StateSnapshot {
    StateSnapshot {
        buffers: bufs
            .iter()
            .map(|b| (b.codec().name(), b.encoded().clone()))
            .collect(),
        counters,
    }
}

/// Shared validation + restore for `import_state` impls: buffer count,
/// codec identity, and payload lengths. Validates EVERY buffer before
/// mutating any, so a failed import leaves the optimizer untouched.
/// Returns the snapshot's counters.
fn restore_buffers(
    who: &str,
    bufs: &mut [&mut StateBuf],
    snap: StateSnapshot,
) -> Result<Vec<f64>> {
    if snap.buffers.len() != bufs.len() {
        bail!(
            "{who}: expected {} state buffers, got {}",
            bufs.len(),
            snap.buffers.len()
        );
    }
    for (i, ((name, enc), buf)) in snap.buffers.iter().zip(bufs.iter()).enumerate() {
        if *name != buf.codec().name() {
            bail!(
                "{who}: state buffer {i} was saved with codec {name}, optimizer uses {}",
                buf.codec().name()
            );
        }
        if enc.len != buf.len() || enc.bytes.len() != buf.codec().state_bytes(enc.len) {
            bail!(
                "{who}: state buffer {i} payload is ({} elems, {} bytes), expected \
                 ({} elems, {} bytes)",
                enc.len,
                enc.bytes.len(),
                buf.len(),
                buf.codec().state_bytes(buf.len())
            );
        }
    }
    for ((_, enc), buf) in snap.buffers.into_iter().zip(bufs.iter_mut()) {
        buf.restore(enc).expect("validated above");
    }
    Ok(snap.counters)
}

/// Below this many parameters the chunked path is pure overhead — the whole
/// update runs inline on the caller.
const MIN_PAR_CHUNK: usize = 16 * 1024;

/// Run the elementwise update `f(params, grad, state_chunks)` over equal
/// index ranges, fanned across `sched`'s persistent pool. Every moment
/// buffer in `state` is split at the same offsets as `params`/`grad`, so
/// `f` sees aligned chunks. The update must be index-independent (every
/// optimizer here is), which makes any worker count bit-identical to the
/// serial loop — chunking changes *where* an element is updated, never the
/// arithmetic.
fn par_elementwise<F>(
    sched: &Scheduler,
    params: &mut [f32],
    grad: &[f32],
    state: Vec<&mut [f32]>,
    f: F,
) where
    F: Fn(&mut [f32], &[f32], &mut [&mut [f32]]) + Sync,
{
    let n = params.len();
    let lanes = sched.workers();
    if sched.pool_threads() == 0 || lanes <= 1 || n < 2 * MIN_PAR_CHUNK {
        let mut state = state;
        f(params, grad, &mut state);
        return;
    }
    struct Chunk<'a> {
        p: &'a mut [f32],
        g: &'a [f32],
        s: Vec<&'a mut [f32]>,
    }
    let chunk_len = n.div_ceil(lanes).max(MIN_PAR_CHUNK);
    let mut chunks: Vec<Chunk> = Vec::with_capacity(lanes);
    let mut rest_p = params;
    let mut rest_g = grad;
    let mut rest_s = state;
    while !rest_p.is_empty() {
        let k = chunk_len.min(rest_p.len());
        let taken = std::mem::take(&mut rest_p);
        let (p, tail_p) = taken.split_at_mut(k);
        rest_p = tail_p;
        let (g, tail_g) = rest_g.split_at(k);
        rest_g = tail_g;
        let mut s = Vec::with_capacity(rest_s.len());
        let mut tail_s = Vec::with_capacity(rest_s.len());
        for buf in rest_s {
            let (head, tail) = buf.split_at_mut(k);
            s.push(head);
            tail_s.push(tail);
        }
        rest_s = tail_s;
        chunks.push(Chunk { p, g, s });
    }
    sched
        .par_map_mut(&mut chunks, |_, c| {
            f(c.p, c.g, &mut c.s);
            Ok(())
        })
        .expect("elementwise chunk tasks are infallible");
}

// ---------------------------------------------------------------------------

/// SGD with momentum and (coupled) weight decay.
pub struct Sgdm {
    buf: StateBuf,
    /// Momentum coefficient.
    pub momentum: f32,
    /// Weight-decay coefficient (added to the gradient).
    pub weight_decay: f32,
}

impl Sgdm {
    /// SGDM over `n` parameters with fp32 moment storage.
    pub fn new(n: usize, momentum: f32, weight_decay: f32) -> Self {
        Self { buf: StateBuf::zeros(n, fp32()), momentum, weight_decay }
    }

    /// Store the momentum buffer through `codec` (the `first_order.bits`
    /// policy). States are zero at construction, so this is lossless.
    pub fn with_codec(mut self, codec: Arc<dyn StateCodec>) -> Self {
        self.buf = StateBuf::zeros(self.buf.len(), codec);
        self
    }
}

impl FirstOrder for Sgdm {
    fn step_par(&mut self, params: &mut [f32], grad: &[f32], lr: f32, sched: &Scheduler) {
        let mut buf = self.buf.load();
        let (momentum, wd) = (self.momentum, self.weight_decay);
        par_elementwise(
            sched,
            params,
            grad,
            vec![&mut buf],
            |p: &mut [f32], g: &[f32], s: &mut [&mut [f32]]| {
                let b = &mut *s[0];
                for i in 0..p.len() {
                    let gi = g[i] + wd * p[i];
                    b[i] = momentum * b[i] + gi;
                    p[i] -= lr * b[i];
                }
            },
        );
        self.buf.store(&buf);
    }

    fn state_bytes(&self) -> usize {
        self.buf.state_bytes()
    }

    fn name(&self) -> &'static str {
        "SGDM"
    }

    fn export_state(&self) -> StateSnapshot {
        snapshot(&[&self.buf], Vec::new())
    }

    fn import_state(&mut self, snap: StateSnapshot) -> Result<()> {
        restore_buffers("SGDM", &mut [&mut self.buf], snap)?;
        Ok(())
    }
}

// ---------------------------------------------------------------------------

/// AdamW (decoupled weight decay), with optional Nesterov momentum
/// (NAdamW).
pub struct AdamW {
    m: StateBuf,
    v: StateBuf,
    step: u64,
    /// First-moment EMA decay β₁.
    pub beta1: f32,
    /// Second-moment EMA decay β₂.
    pub beta2: f32,
    /// Denominator dampening ε.
    pub eps: f32,
    /// Decoupled weight-decay coefficient.
    pub weight_decay: f32,
    /// Nesterov momentum (the NAdamW variant).
    pub nesterov: bool,
}

impl AdamW {
    /// AdamW over `n` parameters with fp32 moment storage.
    pub fn new(n: usize, beta1: f32, beta2: f32, eps: f32, weight_decay: f32) -> Self {
        Self {
            m: StateBuf::zeros(n, fp32()),
            v: StateBuf::zeros(n, fp32()),
            step: 0,
            beta1,
            beta2,
            eps,
            weight_decay,
            nesterov: false,
        }
    }

    /// NAdamW [Dozat 2016]: Nesterov momentum inside AdamW.
    pub fn nadamw(n: usize, beta1: f32, beta2: f32, eps: f32, weight_decay: f32) -> Self {
        Self { nesterov: true, ..Self::new(n, beta1, beta2, eps, weight_decay) }
    }

    /// Store both moments through `codec` (the single-knob
    /// `first_order.bits` policy).
    pub fn with_codec(self, codec: Arc<dyn StateCodec>) -> Self {
        self.with_moment_codecs(codec.clone(), codec)
    }

    /// Store m and v through *separate* codecs — the per-buffer codec
    /// policy (Li et al.'s m-at-4-bit / v-at-8-bit regime resolves the
    /// `Momentum` and `SecondMoment` roles independently).
    pub fn with_moment_codecs(
        mut self,
        m_codec: Arc<dyn StateCodec>,
        v_codec: Arc<dyn StateCodec>,
    ) -> Self {
        let n = self.m.len();
        self.m = StateBuf::zeros(n, m_codec);
        self.v = StateBuf::zeros(n, v_codec);
        self
    }
}

impl FirstOrder for AdamW {
    fn step_par(&mut self, params: &mut [f32], grad: &[f32], lr: f32, sched: &Scheduler) {
        self.step += 1;
        let t = self.step as f32;
        let bc1 = 1.0 - self.beta1.powf(t);
        let bc2 = 1.0 - self.beta2.powf(t);
        let bc1_next = 1.0 - self.beta1.powf(t + 1.0);
        let (beta1, beta2, eps, wd, nesterov) =
            (self.beta1, self.beta2, self.eps, self.weight_decay, self.nesterov);
        let mut m = self.m.load();
        let mut v = self.v.load();
        par_elementwise(
            sched,
            params,
            grad,
            vec![&mut m, &mut v],
            |p: &mut [f32], g: &[f32], s: &mut [&mut [f32]]| {
                let (sm, sv) = s.split_at_mut(1);
                let (m, v) = (&mut *sm[0], &mut *sv[0]);
                for i in 0..p.len() {
                    let gi = g[i];
                    m[i] = beta1 * m[i] + (1.0 - beta1) * gi;
                    v[i] = beta2 * v[i] + (1.0 - beta2) * gi * gi;
                    let mh = if nesterov {
                        (beta1 * m[i] + (1.0 - beta1) * gi) / bc1_next
                    } else {
                        m[i] / bc1
                    };
                    let vh = v[i] / bc2;
                    p[i] -= lr * (mh / (vh.sqrt() + eps) + wd * p[i]);
                }
            },
        );
        self.m.store(&m);
        self.v.store(&v);
    }

    fn state_bytes(&self) -> usize {
        self.m.state_bytes() + self.v.state_bytes()
    }

    fn name(&self) -> &'static str {
        if self.nesterov { "NAdamW" } else { "AdamW" }
    }

    fn export_state(&self) -> StateSnapshot {
        snapshot(&[&self.m, &self.v], vec![self.step as f64])
    }

    fn import_state(&mut self, snap: StateSnapshot) -> Result<()> {
        let who = self.name();
        let Some(&step) = snap.counters.first() else {
            bail!("{who}: missing step counter")
        };
        restore_buffers(who, &mut [&mut self.m, &mut self.v], snap)?;
        self.step = step as u64;
        Ok(())
    }
}

// ---------------------------------------------------------------------------

/// Adagrad (per-coordinate accumulated squared gradients).
pub struct Adagrad {
    acc: StateBuf,
    /// Denominator dampening ε.
    pub eps: f32,
    /// Weight-decay coefficient (added to the gradient).
    pub weight_decay: f32,
}

impl Adagrad {
    /// Adagrad over `n` parameters with fp32 accumulator storage.
    pub fn new(n: usize, eps: f32, weight_decay: f32) -> Self {
        Self { acc: StateBuf::zeros(n, fp32()), eps, weight_decay }
    }

    /// Store the accumulator through `codec` (the `first_order.bits` policy).
    pub fn with_codec(mut self, codec: Arc<dyn StateCodec>) -> Self {
        self.acc = StateBuf::zeros(self.acc.len(), codec);
        self
    }
}

impl FirstOrder for Adagrad {
    fn step_par(&mut self, params: &mut [f32], grad: &[f32], lr: f32, sched: &Scheduler) {
        let mut acc = self.acc.load();
        let (eps, wd) = (self.eps, self.weight_decay);
        par_elementwise(
            sched,
            params,
            grad,
            vec![&mut acc],
            |p: &mut [f32], g: &[f32], s: &mut [&mut [f32]]| {
                let a = &mut *s[0];
                for i in 0..p.len() {
                    let gi = g[i] + wd * p[i];
                    a[i] += gi * gi;
                    p[i] -= lr * gi / (a[i].sqrt() + eps);
                }
            },
        );
        self.acc.store(&acc);
    }

    fn state_bytes(&self) -> usize {
        self.acc.state_bytes()
    }

    fn name(&self) -> &'static str {
        "Adagrad"
    }

    fn export_state(&self) -> StateSnapshot {
        snapshot(&[&self.acc], Vec::new())
    }

    fn import_state(&mut self, snap: StateSnapshot) -> Result<()> {
        restore_buffers("Adagrad", &mut [&mut self.acc], snap)?;
        Ok(())
    }
}

// ---------------------------------------------------------------------------

/// Schedule-free optimizers [Defazio et al. 2024, "The Road Less Scheduled"]
/// — the Appendix H.1 comparison arm (Table 9). The caller's parameter
/// buffer holds y_t = (1−β)·z_t + β·x_t (the gradient point); `eval_params`
/// returns the Polyak-style average x_t.
///
/// The z/x iterate copies are pinned to the `Fp32` codec — quantizing the
/// averaged iterate corrupts the Polyak average itself, not just a moment —
/// so the `first_order.bits` policy applies to the AdamW v moment only.
pub struct ScheduleFree {
    z: StateBuf,
    x: StateBuf,
    t: u64,
    /// Interpolation β between z and the average x for the gradient point.
    pub beta: f32,
    /// Weight-decay coefficient (added to the gradient).
    pub weight_decay: f32,
    /// Some => AdamW-normalized base step (beta2, eps); None => SGD.
    adam: Option<(f32, f32, StateBuf)>,
    warmup: u64,
    lr_sum_sq: f64,
    initialized: bool,
}

impl ScheduleFree {
    /// Schedule-free SGD over `n` parameters.
    pub fn sgd(n: usize, beta: f32, weight_decay: f32, warmup: usize) -> Self {
        Self {
            z: StateBuf::zeros(n, fp32()),
            x: StateBuf::zeros(n, fp32()),
            t: 0,
            beta,
            weight_decay,
            adam: None,
            warmup: warmup as u64,
            lr_sum_sq: 0.0,
            initialized: false,
        }
    }

    /// Schedule-free AdamW over `n` parameters.
    pub fn adamw(n: usize, beta: f32, beta2: f32, eps: f32, weight_decay: f32,
                 warmup: usize) -> Self {
        Self {
            adam: Some((beta2, eps, StateBuf::zeros(n, fp32()))),
            ..Self::sgd(n, beta, weight_decay, warmup)
        }
    }

    /// Store the v moment (AdamW variant) through `codec`; z/x stay fp32.
    pub fn with_codec(mut self, codec: Arc<dyn StateCodec>) -> Self {
        if let Some((_, _, v)) = &mut self.adam {
            *v = StateBuf::zeros(v.len(), codec);
        }
        self
    }
}

impl FirstOrder for ScheduleFree {
    fn step_par(&mut self, params: &mut [f32], grad: &[f32], lr: f32, sched: &Scheduler) {
        if !self.initialized {
            self.z.store(params);
            self.x.store(params);
            self.initialized = true;
        }
        self.t += 1;
        // internal warmup ramp (the method is schedule-free, warmup excepted)
        let ramp = (self.t as f32 / self.warmup.max(1) as f32).min(1.0);
        let gamma = lr * ramp;
        // weight x by γ² (paper's recommended weighting)
        self.lr_sum_sq += (gamma as f64) * (gamma as f64);
        let c = if self.lr_sum_sq > 0.0 {
            ((gamma as f64) * (gamma as f64) / self.lr_sum_sq) as f32
        } else {
            1.0
        };
        let (beta, wd) = (self.beta, self.weight_decay);
        let mut z = self.z.load();
        let mut x = self.x.load();
        let mut adam = self
            .adam
            .as_ref()
            .map(|(b2, eps, vb)| (*b2, *eps, vb.load()));
        match adam.as_mut() {
            None => par_elementwise(
                sched,
                params,
                grad,
                vec![&mut z, &mut x],
                |p: &mut [f32], g: &[f32], s: &mut [&mut [f32]]| {
                    let (sz, sx) = s.split_at_mut(1);
                    let (z, x) = (&mut *sz[0], &mut *sx[0]);
                    for i in 0..p.len() {
                        let gi = g[i] + wd * p[i];
                        z[i] -= gamma * gi;
                        x[i] = (1.0 - c) * x[i] + c * z[i];
                        // next gradient point y = (1−β)z + βx
                        p[i] = (1.0 - beta) * z[i] + beta * x[i];
                    }
                },
            ),
            Some((b2, eps, v)) => {
                let (b2, eps) = (*b2, *eps);
                let bc2 = 1.0 - b2.powf(self.t as f32);
                par_elementwise(
                    sched,
                    params,
                    grad,
                    vec![&mut z, &mut x, &mut v[..]],
                    |p: &mut [f32], g: &[f32], s: &mut [&mut [f32]]| {
                        let (sz, rest) = s.split_at_mut(1);
                        let (sx, sv) = rest.split_at_mut(1);
                        let (z, x, v) = (&mut *sz[0], &mut *sx[0], &mut *sv[0]);
                        for i in 0..p.len() {
                            let gi = g[i] + wd * p[i];
                            v[i] = b2 * v[i] + (1.0 - b2) * gi * gi;
                            let vh = v[i] / bc2;
                            let step_dir = gi / (vh.sqrt() + eps);
                            z[i] -= gamma * step_dir;
                            x[i] = (1.0 - c) * x[i] + c * z[i];
                            // next gradient point y = (1−β)z + βx
                            p[i] = (1.0 - beta) * z[i] + beta * x[i];
                        }
                    },
                );
            }
        }
        self.z.store(&z);
        self.x.store(&x);
        if let (Some((_, _, vb)), Some((_, _, v))) = (&mut self.adam, &adam) {
            vb.store(v);
        }
    }

    fn eval_params(&self, current: &[f32]) -> Vec<f32> {
        if self.initialized {
            self.x.load()
        } else {
            current.to_vec()
        }
    }

    fn state_bytes(&self) -> usize {
        let base = self.z.state_bytes() + self.x.state_bytes();
        base + self.adam.as_ref().map(|(_, _, v)| v.state_bytes()).unwrap_or(0)
    }

    fn name(&self) -> &'static str {
        if self.adam.is_some() { "AdamWScheduleFree" } else { "SGDScheduleFree" }
    }

    fn export_state(&self) -> StateSnapshot {
        let mut bufs = vec![&self.z, &self.x];
        if let Some((_, _, v)) = &self.adam {
            bufs.push(v);
        }
        let init = if self.initialized { 1.0 } else { 0.0 };
        snapshot(&bufs, vec![self.t as f64, self.lr_sum_sq, init])
    }

    fn import_state(&mut self, snap: StateSnapshot) -> Result<()> {
        let who = self.name();
        if snap.counters.len() < 3 {
            bail!("{who}: expected 3 counters, got {}", snap.counters.len());
        }
        let (t, lr_sum_sq, init) = (snap.counters[0], snap.counters[1], snap.counters[2]);
        let mut bufs: Vec<&mut StateBuf> = vec![&mut self.z, &mut self.x];
        if let Some((_, _, v)) = &mut self.adam {
            bufs.push(v);
        }
        restore_buffers(who, &mut bufs, snap)?;
        self.t = t as u64;
        self.lr_sum_sq = lr_sum_sq;
        self.initialized = init != 0.0;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{codec_for, Mapping};

    /// Quadratic f(x) = ½‖x − x*‖²: every optimizer must converge.
    fn run_quadratic(opt: &mut dyn FirstOrder, lr: f32, steps: usize) -> f32 {
        let target = [1.0f32, -2.0, 3.0, 0.5];
        let mut p = vec![0.0f32; 4];
        for _ in 0..steps {
            let g: Vec<f32> = p.iter().zip(&target).map(|(a, b)| a - b).collect();
            opt.step(&mut p, &g, lr);
        }
        let ev = opt.eval_params(&p);
        ev.iter()
            .zip(&target)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f32>()
            .sqrt()
    }

    #[test]
    fn sgdm_converges() {
        let mut o = Sgdm::new(4, 0.9, 0.0);
        assert!(run_quadratic(&mut o, 0.05, 300) < 1e-3);
    }

    #[test]
    fn adamw_converges() {
        let mut o = AdamW::new(4, 0.9, 0.999, 1e-8, 0.0);
        assert!(run_quadratic(&mut o, 0.05, 800) < 1e-2);
    }

    #[test]
    fn nadamw_converges() {
        let mut o = AdamW::nadamw(4, 0.9, 0.999, 1e-8, 0.0);
        assert!(run_quadratic(&mut o, 0.05, 800) < 1e-2);
    }

    #[test]
    fn adagrad_converges() {
        let mut o = Adagrad::new(4, 1e-10, 0.0);
        assert!(run_quadratic(&mut o, 0.5, 800) < 1e-2);
    }

    #[test]
    fn schedule_free_sgd_converges() {
        let mut o = ScheduleFree::sgd(4, 0.9, 0.0, 10);
        assert!(run_quadratic(&mut o, 0.1, 600) < 1e-2);
    }

    #[test]
    fn schedule_free_adamw_converges() {
        let mut o = ScheduleFree::adamw(4, 0.9, 0.999, 1e-8, 0.0, 10);
        assert!(run_quadratic(&mut o, 0.05, 800) < 2e-2);
    }

    #[test]
    fn quantized_moments_still_converge() {
        // 8-bit moments track fp32 closely; 4-bit moments are noisier but
        // must still drive the quadratic loss down hard (the paper's point:
        // low-bit states trade a little accuracy for a lot of memory)
        let mut q8 = AdamW::new(4, 0.9, 0.999, 1e-8, 0.0)
            .with_codec(codec_for(8, Mapping::Dt));
        assert!(run_quadratic(&mut q8, 0.05, 800) < 0.1);
        let mut q4 = AdamW::new(4, 0.9, 0.999, 1e-8, 0.0)
            .with_codec(codec_for(4, Mapping::Dt));
        let dist = run_quadratic(&mut q4, 0.05, 800);
        assert!(dist < 1.0, "4-bit AdamW stalled at distance {dist}");
        let mut s8 = Sgdm::new(4, 0.9, 0.0).with_codec(codec_for(8, Mapping::Dt));
        assert!(run_quadratic(&mut s8, 0.05, 400) < 0.1);
    }

    #[test]
    fn adamw_matches_reference_formula() {
        // hand-computed single AdamW step
        let mut o = AdamW::new(1, 0.9, 0.999, 1e-8, 0.01);
        let mut p = vec![1.0f32];
        o.step(&mut p, &[0.5], 0.1);
        // m=0.05, v=0.00025/..., mh=0.05/0.1=0.5, vh=0.00025/0.001=0.25
        // p = 1 - 0.1*(0.5/(0.5+1e-8) + 0.01*1) = 1 - 0.1*1.00999 ≈ 0.899
        assert!((p[0] - 0.899).abs() < 1e-3, "{}", p[0]);
    }

    #[test]
    fn weight_decay_shrinks_params() {
        let mut o = Sgdm::new(1, 0.0, 0.1);
        let mut p = vec![1.0f32];
        o.step(&mut p, &[0.0], 0.5);
        assert!(p[0] < 1.0);
    }

    /// Drive `a` some steps, snapshot into `b`, then both must evolve
    /// bit-identically.
    fn check_state_roundtrip(a: &mut dyn FirstOrder, b: &mut dyn FirstOrder, lr: f32) {
        let target = [1.0f32, -2.0, 3.0, 0.5];
        let mut p = vec![0.0f32; 4];
        for _ in 0..7 {
            let g: Vec<f32> = p.iter().zip(&target).map(|(x, t)| x - t).collect();
            a.step(&mut p, &g, lr);
        }
        b.import_state(a.export_state()).unwrap();
        let mut pa = p.clone();
        let mut pb = p;
        for _ in 0..5 {
            let ga: Vec<f32> = pa.iter().zip(&target).map(|(x, t)| x - t).collect();
            a.step(&mut pa, &ga, lr);
            let gb: Vec<f32> = pb.iter().zip(&target).map(|(x, t)| x - t).collect();
            b.step(&mut pb, &gb, lr);
        }
        assert_eq!(pa, pb, "resumed optimizer diverged");
        assert_eq!(a.eval_params(&pa), b.eval_params(&pb));
    }

    #[test]
    fn state_roundtrips_bit_identically() {
        check_state_roundtrip(
            &mut Sgdm::new(4, 0.9, 0.01),
            &mut Sgdm::new(4, 0.9, 0.01),
            0.05,
        );
        check_state_roundtrip(
            &mut AdamW::new(4, 0.9, 0.999, 1e-8, 0.01),
            &mut AdamW::new(4, 0.9, 0.999, 1e-8, 0.01),
            0.05,
        );
        check_state_roundtrip(
            &mut Adagrad::new(4, 1e-10, 0.0),
            &mut Adagrad::new(4, 1e-10, 0.0),
            0.1,
        );
        check_state_roundtrip(
            &mut ScheduleFree::adamw(4, 0.9, 0.999, 1e-8, 0.0, 5),
            &mut ScheduleFree::adamw(4, 0.9, 0.999, 1e-8, 0.0, 5),
            0.05,
        );
    }

    #[test]
    fn quantized_state_roundtrips_bit_identically() {
        // encoded bytes are the checkpoint payload, so resume is exact at
        // ANY bitwidth — no requantization error
        let q4 = || codec_for(4, Mapping::Dt);
        check_state_roundtrip(
            &mut AdamW::new(4, 0.9, 0.999, 1e-8, 0.01).with_codec(q4()),
            &mut AdamW::new(4, 0.9, 0.999, 1e-8, 0.01).with_codec(q4()),
            0.05,
        );
        let q8 = || codec_for(8, Mapping::Linear2);
        check_state_roundtrip(
            &mut Sgdm::new(4, 0.9, 0.01).with_codec(q8()),
            &mut Sgdm::new(4, 0.9, 0.01).with_codec(q8()),
            0.05,
        );
    }

    /// Drive `serial` with `step` and `chunked` with `step_par` over the
    /// pooled scheduler; the parameter bit patterns must match exactly.
    fn assert_chunked_bit_identical(
        name: &str,
        serial: &mut dyn FirstOrder,
        chunked: &mut dyn FirstOrder,
        n: usize,
        sched: &Scheduler,
    ) {
        let grad: Vec<f32> = (0..n).map(|i| ((i % 97) as f32 - 48.0) * 1e-3).collect();
        let init: Vec<f32> = (0..n).map(|i| ((i % 53) as f32 - 26.0) * 1e-2).collect();
        let mut ps = init.clone();
        let mut pc = init;
        for _ in 0..3 {
            serial.step(&mut ps, &grad, 1e-3);
            chunked.step_par(&mut pc, &grad, 1e-3, sched);
        }
        let bits = |p: &[f32]| p.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&ps), bits(&pc), "{name}: chunked update diverged from serial");
    }

    #[test]
    fn chunked_step_par_is_bit_identical_to_serial() {
        // the flat update must not change by a single bit when fanned
        // across the persistent pool — chunking only moves where an element
        // is updated, never the arithmetic
        let n = 3 * MIN_PAR_CHUNK + 137; // force several uneven chunks
        let sched = Scheduler::new(4);
        assert!(sched.pool_threads() > 0);
        assert_chunked_bit_identical(
            "sgdm",
            &mut Sgdm::new(n, 0.9, 0.01),
            &mut Sgdm::new(n, 0.9, 0.01),
            n,
            &sched,
        );
        assert_chunked_bit_identical(
            "adamw",
            &mut AdamW::new(n, 0.9, 0.999, 1e-8, 0.01),
            &mut AdamW::new(n, 0.9, 0.999, 1e-8, 0.01),
            n,
            &sched,
        );
        assert_chunked_bit_identical(
            "nadamw",
            &mut AdamW::nadamw(n, 0.9, 0.999, 1e-8, 0.01),
            &mut AdamW::nadamw(n, 0.9, 0.999, 1e-8, 0.01),
            n,
            &sched,
        );
        assert_chunked_bit_identical(
            "adagrad",
            &mut Adagrad::new(n, 1e-10, 0.01),
            &mut Adagrad::new(n, 1e-10, 0.01),
            n,
            &sched,
        );
        assert_chunked_bit_identical(
            "sf-adamw",
            &mut ScheduleFree::adamw(n, 0.9, 0.999, 1e-8, 0.0, 5),
            &mut ScheduleFree::adamw(n, 0.9, 0.999, 1e-8, 0.0, 5),
            n,
            &sched,
        );
        assert_chunked_bit_identical(
            "sf-sgd",
            &mut ScheduleFree::sgd(n, 0.9, 0.0, 5),
            &mut ScheduleFree::sgd(n, 0.9, 0.0, 5),
            n,
            &sched,
        );
    }

    #[test]
    fn import_rejects_mismatched_buffers() {
        use crate::quant::Fp32;
        let snap = |bufs: Vec<Vec<f32>>, counters: Vec<f64>| StateSnapshot {
            buffers: bufs
                .iter()
                .map(|b| ("fp32".to_string(), Fp32.encode(b)))
                .collect(),
            counters,
        };
        let mut o = AdamW::new(4, 0.9, 0.999, 1e-8, 0.0);
        // one buffer short
        assert!(o.import_state(snap(vec![vec![0.0; 4]], vec![1.0])).is_err());
        // bad length
        assert!(o
            .import_state(snap(vec![vec![0.0; 3], vec![0.0; 4]], vec![1.0]))
            .is_err());
        // no counter
        assert!(o
            .import_state(snap(vec![vec![0.0; 4], vec![0.0; 4]], Vec::new()))
            .is_err());
        assert!(o
            .import_state(snap(vec![vec![0.0; 4], vec![0.0; 4]], vec![3.0]))
            .is_ok());
        // codec mismatch: fp32 snapshot into a q4-configured optimizer
        let mut q = AdamW::new(4, 0.9, 0.999, 1e-8, 0.0)
            .with_codec(codec_for(4, Mapping::Dt));
        let err = q
            .import_state(snap(vec![vec![0.0; 4], vec![0.0; 4]], vec![3.0]))
            .unwrap_err()
            .to_string();
        assert!(err.contains("codec"), "{err}");
    }

    #[test]
    fn state_bytes() {
        assert_eq!(Sgdm::new(10, 0.9, 0.0).state_bytes(), 40);
        assert_eq!(AdamW::new(10, 0.9, 0.999, 1e-8, 0.0).state_bytes(), 80);
        assert_eq!(ScheduleFree::sgd(10, 0.9, 0.0, 1).state_bytes(), 80);
        assert_eq!(
            ScheduleFree::adamw(10, 0.9, 0.999, 1e-8, 0.0, 1).state_bytes(),
            120
        );
        // 4-bit moments: 2 × (64 packed + 8 scale) bytes for n=128 vs 1024
        let q4 = AdamW::new(128, 0.9, 0.999, 1e-8, 0.0)
            .with_codec(codec_for(4, Mapping::Dt));
        assert_eq!(q4.state_bytes(), 2 * (64 + 8));
        // per-buffer policy: m at 4-bit (72 B) + v at 8-bit (136 B)
        let mixed = AdamW::new(128, 0.9, 0.999, 1e-8, 0.0)
            .with_moment_codecs(codec_for(4, Mapping::Dt), codec_for(8, Mapping::Dt));
        assert_eq!(mixed.state_bytes(), (64 + 8) + (128 + 8));
    }

    #[test]
    fn mixed_moment_codecs_converge_and_roundtrip() {
        // the Li et al. regime end-to-end at optimizer level: m=q4, v=q8
        let mixed = || {
            AdamW::new(4, 0.9, 0.999, 1e-8, 0.01)
                .with_moment_codecs(codec_for(4, Mapping::Dt), codec_for(8, Mapping::Dt))
        };
        let mut o = mixed();
        assert!(run_quadratic(&mut o, 0.05, 800) < 1.0);
        check_state_roundtrip(&mut mixed(), &mut mixed(), 0.05);
    }
}
