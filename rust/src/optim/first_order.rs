//! First-order optimizers F (eq. 1) — native Rust elementwise hot path
//! (DESIGN.md decision 7), cross-checked against the L2 artifact versions in
//! rust/tests/runtime_integration.rs.
//!
//! Implemented: SGDM, AdamW, NAdamW, Adagrad (the paper's Fs), plus the
//! comparison arms of Appendix H: schedule-free SGD/AdamW [Defazio et al.]
//! and M-FAC (separate module).

use anyhow::{bail, Result};

/// A first-order optimizer over a flat parameter vector.
pub trait FirstOrder {
    /// One update. `params` holds the *training* iterate (for schedule-free
    /// methods this is the gradient point y); `grad` its gradient; `lr` the
    /// scheduled learning rate.
    fn step(&mut self, params: &mut [f32], grad: &[f32], lr: f32);

    /// Parameters to use for evaluation (schedule-free returns the average).
    fn eval_params(&self, current: &[f32]) -> Vec<f32> {
        current.to_vec()
    }

    /// Exact optimizer-state bytes (for the Table 2/13 memory accounting).
    fn state_bytes(&self) -> usize;

    fn name(&self) -> &'static str;

    /// Snapshot the full mutable state as (ordered f32 buffers, scalar
    /// counters) — enough for `import_state` on an identically configured
    /// optimizer to resume bit-identically. Buffer/counter order is each
    /// optimizer's contract; checkpoints persist both.
    fn export_state(&self) -> (Vec<Vec<f32>>, Vec<f64>);

    /// Restore a snapshot produced by [`FirstOrder::export_state`].
    fn import_state(&mut self, buffers: Vec<Vec<f32>>, counters: &[f64]) -> Result<()>;
}

/// Shared validation for `import_state` impls: buffer count + lengths.
fn check_buffers(who: &str, buffers: &[Vec<f32>], lens: &[usize]) -> Result<()> {
    if buffers.len() != lens.len() {
        bail!("{who}: expected {} state buffers, got {}", lens.len(), buffers.len());
    }
    for (i, (b, &n)) in buffers.iter().zip(lens).enumerate() {
        if b.len() != n {
            bail!("{who}: state buffer {i} has {} elems, expected {n}", b.len());
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------------

pub struct Sgdm {
    buf: Vec<f32>,
    pub momentum: f32,
    pub weight_decay: f32,
}

impl Sgdm {
    pub fn new(n: usize, momentum: f32, weight_decay: f32) -> Self {
        Self { buf: vec![0.0; n], momentum, weight_decay }
    }
}

impl FirstOrder for Sgdm {
    fn step(&mut self, params: &mut [f32], grad: &[f32], lr: f32) {
        for i in 0..params.len() {
            let g = grad[i] + self.weight_decay * params[i];
            self.buf[i] = self.momentum * self.buf[i] + g;
            params[i] -= lr * self.buf[i];
        }
    }

    fn state_bytes(&self) -> usize {
        self.buf.len() * 4
    }

    fn name(&self) -> &'static str {
        "SGDM"
    }

    fn export_state(&self) -> (Vec<Vec<f32>>, Vec<f64>) {
        (vec![self.buf.clone()], Vec::new())
    }

    fn import_state(&mut self, mut buffers: Vec<Vec<f32>>, _counters: &[f64]) -> Result<()> {
        check_buffers("SGDM", &buffers, &[self.buf.len()])?;
        self.buf = buffers.remove(0);
        Ok(())
    }
}

// ---------------------------------------------------------------------------

pub struct AdamW {
    m: Vec<f32>,
    v: Vec<f32>,
    step: u64,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub weight_decay: f32,
    pub nesterov: bool,
}

impl AdamW {
    pub fn new(n: usize, beta1: f32, beta2: f32, eps: f32, weight_decay: f32) -> Self {
        Self {
            m: vec![0.0; n],
            v: vec![0.0; n],
            step: 0,
            beta1,
            beta2,
            eps,
            weight_decay,
            nesterov: false,
        }
    }

    /// NAdamW [Dozat 2016]: Nesterov momentum inside AdamW.
    pub fn nadamw(n: usize, beta1: f32, beta2: f32, eps: f32, weight_decay: f32) -> Self {
        Self { nesterov: true, ..Self::new(n, beta1, beta2, eps, weight_decay) }
    }
}

impl FirstOrder for AdamW {
    fn step(&mut self, params: &mut [f32], grad: &[f32], lr: f32) {
        self.step += 1;
        let t = self.step as f32;
        let bc1 = 1.0 - self.beta1.powf(t);
        let bc2 = 1.0 - self.beta2.powf(t);
        let bc1_next = 1.0 - self.beta1.powf(t + 1.0);
        for i in 0..params.len() {
            let g = grad[i];
            self.m[i] = self.beta1 * self.m[i] + (1.0 - self.beta1) * g;
            self.v[i] = self.beta2 * self.v[i] + (1.0 - self.beta2) * g * g;
            let mh = if self.nesterov {
                (self.beta1 * self.m[i] + (1.0 - self.beta1) * g) / bc1_next
            } else {
                self.m[i] / bc1
            };
            let vh = self.v[i] / bc2;
            params[i] -= lr * (mh / (vh.sqrt() + self.eps) + self.weight_decay * params[i]);
        }
    }

    fn state_bytes(&self) -> usize {
        (self.m.len() + self.v.len()) * 4
    }

    fn name(&self) -> &'static str {
        if self.nesterov { "NAdamW" } else { "AdamW" }
    }

    fn export_state(&self) -> (Vec<Vec<f32>>, Vec<f64>) {
        (vec![self.m.clone(), self.v.clone()], vec![self.step as f64])
    }

    fn import_state(&mut self, mut buffers: Vec<Vec<f32>>, counters: &[f64]) -> Result<()> {
        check_buffers(self.name(), &buffers, &[self.m.len(), self.v.len()])?;
        let Some(&step) = counters.first() else {
            bail!("{}: missing step counter", self.name())
        };
        self.v = buffers.pop().unwrap();
        self.m = buffers.pop().unwrap();
        self.step = step as u64;
        Ok(())
    }
}

// ---------------------------------------------------------------------------

pub struct Adagrad {
    acc: Vec<f32>,
    pub eps: f32,
    pub weight_decay: f32,
}

impl Adagrad {
    pub fn new(n: usize, eps: f32, weight_decay: f32) -> Self {
        Self { acc: vec![0.0; n], eps, weight_decay }
    }
}

impl FirstOrder for Adagrad {
    fn step(&mut self, params: &mut [f32], grad: &[f32], lr: f32) {
        for i in 0..params.len() {
            let g = grad[i] + self.weight_decay * params[i];
            self.acc[i] += g * g;
            params[i] -= lr * g / (self.acc[i].sqrt() + self.eps);
        }
    }

    fn state_bytes(&self) -> usize {
        self.acc.len() * 4
    }

    fn name(&self) -> &'static str {
        "Adagrad"
    }

    fn export_state(&self) -> (Vec<Vec<f32>>, Vec<f64>) {
        (vec![self.acc.clone()], Vec::new())
    }

    fn import_state(&mut self, mut buffers: Vec<Vec<f32>>, _counters: &[f64]) -> Result<()> {
        check_buffers("Adagrad", &buffers, &[self.acc.len()])?;
        self.acc = buffers.remove(0);
        Ok(())
    }
}

// ---------------------------------------------------------------------------

/// Schedule-free optimizers [Defazio et al. 2024, "The Road Less Scheduled"]
/// — the Appendix H.1 comparison arm (Table 9). The caller's parameter
/// buffer holds y_t = (1−β)·z_t + β·x_t (the gradient point); `eval_params`
/// returns the Polyak-style average x_t.
pub struct ScheduleFree {
    z: Vec<f32>,
    x: Vec<f32>,
    t: u64,
    pub beta: f32,
    pub weight_decay: f32,
    /// Some => AdamW-normalized base step (beta2, eps); None => SGD.
    adam: Option<(f32, f32, Vec<f32>)>,
    warmup: u64,
    lr_sum_sq: f64,
    initialized: bool,
}

impl ScheduleFree {
    pub fn sgd(n: usize, beta: f32, weight_decay: f32, warmup: usize) -> Self {
        Self {
            z: vec![0.0; n],
            x: vec![0.0; n],
            t: 0,
            beta,
            weight_decay,
            adam: None,
            warmup: warmup as u64,
            lr_sum_sq: 0.0,
            initialized: false,
        }
    }

    pub fn adamw(n: usize, beta: f32, beta2: f32, eps: f32, weight_decay: f32,
                 warmup: usize) -> Self {
        Self {
            adam: Some((beta2, eps, vec![0.0; n])),
            ..Self::sgd(n, beta, weight_decay, warmup)
        }
    }
}

impl FirstOrder for ScheduleFree {
    fn step(&mut self, params: &mut [f32], grad: &[f32], lr: f32) {
        if !self.initialized {
            self.z.copy_from_slice(params);
            self.x.copy_from_slice(params);
            self.initialized = true;
        }
        self.t += 1;
        // internal warmup ramp (the method is schedule-free, warmup excepted)
        let ramp = (self.t as f32 / self.warmup.max(1) as f32).min(1.0);
        let gamma = lr * ramp;
        // weight x by γ² (paper's recommended weighting)
        self.lr_sum_sq += (gamma as f64) * (gamma as f64);
        let c = if self.lr_sum_sq > 0.0 {
            ((gamma as f64) * (gamma as f64) / self.lr_sum_sq) as f32
        } else {
            1.0
        };
        let bc2 = self.adam.as_ref().map(|(b2, _, _)| 1.0 - b2.powf(self.t as f32));
        for i in 0..params.len() {
            let g = grad[i] + self.weight_decay * params[i];
            let step_dir = match &mut self.adam {
                None => g,
                Some((b2, eps, v)) => {
                    v[i] = *b2 * v[i] + (1.0 - *b2) * g * g;
                    let vh = v[i] / bc2.unwrap();
                    g / (vh.sqrt() + *eps)
                }
            };
            self.z[i] -= gamma * step_dir;
            self.x[i] = (1.0 - c) * self.x[i] + c * self.z[i];
            // next gradient point y = (1−β)z + βx
            params[i] = (1.0 - self.beta) * self.z[i] + self.beta * self.x[i];
        }
    }

    fn eval_params(&self, current: &[f32]) -> Vec<f32> {
        if self.initialized {
            self.x.clone()
        } else {
            current.to_vec()
        }
    }

    fn state_bytes(&self) -> usize {
        let base = (self.z.len() + self.x.len()) * 4;
        base + self.adam.as_ref().map(|(_, _, v)| v.len() * 4).unwrap_or(0)
    }

    fn name(&self) -> &'static str {
        if self.adam.is_some() { "AdamWScheduleFree" } else { "SGDScheduleFree" }
    }

    fn export_state(&self) -> (Vec<Vec<f32>>, Vec<f64>) {
        let mut bufs = vec![self.z.clone(), self.x.clone()];
        if let Some((_, _, v)) = &self.adam {
            bufs.push(v.clone());
        }
        let init = if self.initialized { 1.0 } else { 0.0 };
        (bufs, vec![self.t as f64, self.lr_sum_sq, init])
    }

    fn import_state(&mut self, mut buffers: Vec<Vec<f32>>, counters: &[f64]) -> Result<()> {
        let mut lens = vec![self.z.len(), self.x.len()];
        if let Some((_, _, v)) = &self.adam {
            lens.push(v.len());
        }
        check_buffers(self.name(), &buffers, &lens)?;
        if counters.len() < 3 {
            bail!("{}: expected 3 counters, got {}", self.name(), counters.len());
        }
        if let Some((_, _, v)) = &mut self.adam {
            *v = buffers.pop().unwrap();
        }
        self.x = buffers.pop().unwrap();
        self.z = buffers.pop().unwrap();
        self.t = counters[0] as u64;
        self.lr_sum_sq = counters[1];
        self.initialized = counters[2] != 0.0;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Quadratic f(x) = ½‖x − x*‖²: every optimizer must converge.
    fn run_quadratic(opt: &mut dyn FirstOrder, lr: f32, steps: usize) -> f32 {
        let target = [1.0f32, -2.0, 3.0, 0.5];
        let mut p = vec![0.0f32; 4];
        for _ in 0..steps {
            let g: Vec<f32> = p.iter().zip(&target).map(|(a, b)| a - b).collect();
            opt.step(&mut p, &g, lr);
        }
        let ev = opt.eval_params(&p);
        ev.iter()
            .zip(&target)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f32>()
            .sqrt()
    }

    #[test]
    fn sgdm_converges() {
        let mut o = Sgdm::new(4, 0.9, 0.0);
        assert!(run_quadratic(&mut o, 0.05, 300) < 1e-3);
    }

    #[test]
    fn adamw_converges() {
        let mut o = AdamW::new(4, 0.9, 0.999, 1e-8, 0.0);
        assert!(run_quadratic(&mut o, 0.05, 800) < 1e-2);
    }

    #[test]
    fn nadamw_converges() {
        let mut o = AdamW::nadamw(4, 0.9, 0.999, 1e-8, 0.0);
        assert!(run_quadratic(&mut o, 0.05, 800) < 1e-2);
    }

    #[test]
    fn adagrad_converges() {
        let mut o = Adagrad::new(4, 1e-10, 0.0);
        assert!(run_quadratic(&mut o, 0.5, 800) < 1e-2);
    }

    #[test]
    fn schedule_free_sgd_converges() {
        let mut o = ScheduleFree::sgd(4, 0.9, 0.0, 10);
        assert!(run_quadratic(&mut o, 0.1, 600) < 1e-2);
    }

    #[test]
    fn schedule_free_adamw_converges() {
        let mut o = ScheduleFree::adamw(4, 0.9, 0.999, 1e-8, 0.0, 10);
        assert!(run_quadratic(&mut o, 0.05, 800) < 2e-2);
    }

    #[test]
    fn adamw_matches_reference_formula() {
        // hand-computed single AdamW step
        let mut o = AdamW::new(1, 0.9, 0.999, 1e-8, 0.01);
        let mut p = vec![1.0f32];
        o.step(&mut p, &[0.5], 0.1);
        // m=0.05, v=0.00025/..., mh=0.05/0.1=0.5, vh=0.00025/0.001=0.25
        // p = 1 - 0.1*(0.5/(0.5+1e-8) + 0.01*1) = 1 - 0.1*1.00999 ≈ 0.899
        assert!((p[0] - 0.899).abs() < 1e-3, "{}", p[0]);
    }

    #[test]
    fn weight_decay_shrinks_params() {
        let mut o = Sgdm::new(1, 0.0, 0.1);
        let mut p = vec![1.0f32];
        o.step(&mut p, &[0.0], 0.5);
        assert!(p[0] < 1.0);
    }

    /// Drive `a` some steps, snapshot into `b`, then both must evolve
    /// bit-identically.
    fn check_state_roundtrip(a: &mut dyn FirstOrder, b: &mut dyn FirstOrder, lr: f32) {
        let target = [1.0f32, -2.0, 3.0, 0.5];
        let mut p = vec![0.0f32; 4];
        for _ in 0..7 {
            let g: Vec<f32> = p.iter().zip(&target).map(|(x, t)| x - t).collect();
            a.step(&mut p, &g, lr);
        }
        let (bufs, counters) = a.export_state();
        b.import_state(bufs, &counters).unwrap();
        let mut pa = p.clone();
        let mut pb = p;
        for _ in 0..5 {
            let ga: Vec<f32> = pa.iter().zip(&target).map(|(x, t)| x - t).collect();
            a.step(&mut pa, &ga, lr);
            let gb: Vec<f32> = pb.iter().zip(&target).map(|(x, t)| x - t).collect();
            b.step(&mut pb, &gb, lr);
        }
        assert_eq!(pa, pb, "resumed optimizer diverged");
        assert_eq!(a.eval_params(&pa), b.eval_params(&pb));
    }

    #[test]
    fn state_roundtrips_bit_identically() {
        check_state_roundtrip(
            &mut Sgdm::new(4, 0.9, 0.01),
            &mut Sgdm::new(4, 0.9, 0.01),
            0.05,
        );
        check_state_roundtrip(
            &mut AdamW::new(4, 0.9, 0.999, 1e-8, 0.01),
            &mut AdamW::new(4, 0.9, 0.999, 1e-8, 0.01),
            0.05,
        );
        check_state_roundtrip(
            &mut Adagrad::new(4, 1e-10, 0.0),
            &mut Adagrad::new(4, 1e-10, 0.0),
            0.1,
        );
        check_state_roundtrip(
            &mut ScheduleFree::adamw(4, 0.9, 0.999, 1e-8, 0.0, 5),
            &mut ScheduleFree::adamw(4, 0.9, 0.999, 1e-8, 0.0, 5),
            0.05,
        );
    }

    #[test]
    fn import_rejects_mismatched_buffers() {
        let mut o = AdamW::new(4, 0.9, 0.999, 1e-8, 0.0);
        assert!(o.import_state(vec![vec![0.0; 4]], &[1.0]).is_err()); // one buffer short
        assert!(o.import_state(vec![vec![0.0; 3], vec![0.0; 4]], &[1.0]).is_err()); // bad len
        assert!(o.import_state(vec![vec![0.0; 4], vec![0.0; 4]], &[]).is_err()); // no counter
        assert!(o.import_state(vec![vec![0.0; 4], vec![0.0; 4]], &[3.0]).is_ok());
    }

    #[test]
    fn state_bytes() {
        assert_eq!(Sgdm::new(10, 0.9, 0.0).state_bytes(), 40);
        assert_eq!(AdamW::new(10, 0.9, 0.999, 1e-8, 0.0).state_bytes(), 80);
        assert_eq!(ScheduleFree::sgd(10, 0.9, 0.0, 1).state_bytes(), 80);
        assert_eq!(
            ScheduleFree::adamw(10, 0.9, 0.999, 1e-8, 0.0, 1).state_bytes(),
            120
        );
    }
}
