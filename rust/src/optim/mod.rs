//! Optimizers: native first-order hot path (F of eq. 1) and the comparison
//! arms of Appendix H. Second-order preconditioning lives in `coordinator`
//! (it orchestrates the AOT artifacts).

pub mod first_order;
pub mod mfac;

pub use first_order::{Adagrad, AdamW, FirstOrder, ScheduleFree, Sgdm};
pub use mfac::MFac;

use crate::config::{FirstOrderConfig, FirstOrderKind};

/// Build a first-order optimizer for an n-parameter model.
pub fn build_first_order(cfg: &FirstOrderConfig, n: usize, warmup: usize) -> Box<dyn FirstOrder> {
    match cfg.kind {
        FirstOrderKind::Sgdm => Box::new(Sgdm::new(n, cfg.momentum, cfg.weight_decay)),
        FirstOrderKind::AdamW => {
            Box::new(AdamW::new(n, cfg.beta1, cfg.beta2, cfg.eps, cfg.weight_decay))
        }
        FirstOrderKind::NAdamW => {
            Box::new(AdamW::nadamw(n, cfg.beta1, cfg.beta2, cfg.eps, cfg.weight_decay))
        }
        FirstOrderKind::Adagrad => Box::new(Adagrad::new(n, 1e-10, cfg.weight_decay)),
        FirstOrderKind::SgdScheduleFree => {
            Box::new(ScheduleFree::sgd(n, 0.9, cfg.weight_decay, warmup))
        }
        FirstOrderKind::AdamWScheduleFree => Box::new(ScheduleFree::adamw(
            n,
            0.9,
            cfg.beta2,
            cfg.eps,
            cfg.weight_decay,
            warmup,
        )),
        FirstOrderKind::MFac => Box::new(MFac::new(
            n,
            cfg.mfac_m,
            0.1,
            cfg.momentum,
            cfg.weight_decay,
        )),
    }
}
