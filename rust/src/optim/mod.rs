//! Optimizers: native first-order hot path (F of eq. 1) and the comparison
//! arms of Appendix H. Second-order preconditioning lives in `coordinator`
//! (it orchestrates the AOT artifacts).

/// The native elementwise optimizers (SGDM, AdamW, Adagrad,
/// schedule-free) and the [`FirstOrder`] trait they implement.
pub mod first_order;
/// M-FAC (matrix-free inverse-Hessian-vector products), Table 11 arm.
pub mod mfac;

pub use first_order::{Adagrad, AdamW, FirstOrder, ScheduleFree, Sgdm, StateSnapshot};
pub use mfac::MFac;

use crate::config::{FirstOrderConfig, FirstOrderKind};
use crate::quant::{BufferRole, CodecPolicy, CodecSpec};

/// Build a first-order optimizer for an n-parameter model. Every moment
/// buffer resolves its storage codec through the per-buffer `policy`:
/// first-moment buffers (AdamW m, SGDM momentum) through the `Momentum`
/// role, second-moment buffers (AdamW v, the Adagrad accumulator, the
/// schedule-free v) through `SecondMoment`; roles without a policy entry
/// fall back to the legacy `first_order.bits` / `first_order.mapping`
/// single knob, so pre-policy configs behave unchanged. (M-FAC's dense
/// gradient window is exempt by design — its memory footprint is the
/// Table 11 comparison point; schedule-free z/x iterates stay pinned fp32.)
pub fn build_first_order(
    cfg: &FirstOrderConfig,
    policy: &CodecPolicy,
    n: usize,
    warmup: usize,
) -> Box<dyn FirstOrder> {
    let fallback = CodecSpec::plain(cfg.bits, cfg.mapping);
    let m_codec = || policy.codec(BufferRole::Momentum, fallback);
    let v_codec = || policy.codec(BufferRole::SecondMoment, fallback);
    match cfg.kind {
        FirstOrderKind::Sgdm => {
            Box::new(Sgdm::new(n, cfg.momentum, cfg.weight_decay).with_codec(m_codec()))
        }
        FirstOrderKind::AdamW => Box::new(
            AdamW::new(n, cfg.beta1, cfg.beta2, cfg.eps, cfg.weight_decay)
                .with_moment_codecs(m_codec(), v_codec()),
        ),
        FirstOrderKind::NAdamW => Box::new(
            AdamW::nadamw(n, cfg.beta1, cfg.beta2, cfg.eps, cfg.weight_decay)
                .with_moment_codecs(m_codec(), v_codec()),
        ),
        FirstOrderKind::Adagrad => {
            Box::new(Adagrad::new(n, 1e-10, cfg.weight_decay).with_codec(v_codec()))
        }
        FirstOrderKind::SgdScheduleFree => {
            Box::new(ScheduleFree::sgd(n, 0.9, cfg.weight_decay, warmup).with_codec(v_codec()))
        }
        FirstOrderKind::AdamWScheduleFree => Box::new(
            ScheduleFree::adamw(n, 0.9, cfg.beta2, cfg.eps, cfg.weight_decay, warmup)
                .with_codec(v_codec()),
        ),
        FirstOrderKind::MFac => Box::new(MFac::new(
            n,
            cfg.mfac_m,
            0.1,
            cfg.momentum,
            cfg.weight_decay,
        )),
    }
}
