//! 4-bit Shampoo: memory-efficient second-order network training
//! (reproduction of Wang, Li, Zhou, Huang — NeurIPS 2024).
//!
//! Three-layer architecture (see DESIGN.md):
//!  * L1 — Pallas quantization/matmul kernels (python/compile/kernels),
//!  * L2 — JAX Shampoo math + model graphs, AOT-lowered to HLO text,
//!  * L3 — this crate: the training coordinator, quantized optimizer-state
//!    management, synthetic data pipelines, and a pluggable execution
//!    [`runtime::Backend`] — the hermetic pure-Rust [`runtime::HostBackend`]
//!    by default, the PJRT artifact registry behind `--features pjrt`.

// Style allowances for dense numeric code: index loops over several buffers
// at once and config structs populated field-by-field from parsed documents.
#![allow(
    clippy::field_reassign_with_default,
    clippy::needless_range_loop,
    clippy::manual_memcpy,
    clippy::too_many_arguments,
    clippy::inherent_to_string
)]
// Docs are part of the build contract: CI runs `cargo doc --no-deps` with
// `RUSTDOCFLAGS="-D warnings"`, so an undocumented public item fails the
// build instead of silently drifting (see docs/ARCHITECTURE.md).
#![warn(missing_docs)]
// Every `unsafe` block must carry a `// SAFETY:` comment. The in-workspace
// `shampoo-lint` binary enforces the same rule (plus the unsafe-module
// allowlist) over tests and benches; this attribute makes the compiler
// back it inside the crate. CI runs clippy with `-D warnings`, so a bare
// unsafe block fails the build.
#![warn(clippy::undocumented_unsafe_blocks)]

/// Run configuration: TOML/CLI parsing into one [`config::RunConfig`].
pub mod config;
/// L3 training coordinator: partitioner, block states, Algorithm-3
/// orchestration, the parallel block engine, and the trainer.
pub mod coordinator;
/// Synthetic data pipelines (vision classification + bigram LM corpora).
pub mod data;
/// Quantization-error analyses (NRE / angle error, Tables 1/5/6/7).
pub mod errors;
/// Dense f32 linear algebra: eigh, QR/CGS2, Björck, Schur–Newton roots.
pub mod linalg;
/// Native first-order optimizers F (eq. 1) and comparison arms.
pub mod optim;
/// Quantization substrate: codebooks, block-wise quantizer, bit packing,
/// and the [`quant::StateCodec`] storage layer.
pub mod quant;
/// Execution backends behind one [`runtime::Backend`] seam.
pub mod runtime;
/// In-tree utility substrates (CLI args, JSON, TOML, RNG, timers).
pub mod util;
