//! 4-bit Shampoo: memory-efficient second-order network training
//! (reproduction of Wang, Li, Zhou, Huang — NeurIPS 2024).
//!
//! Three-layer architecture (see DESIGN.md):
//!  * L1 — Pallas quantization/matmul kernels (python/compile/kernels),
//!  * L2 — JAX Shampoo math + model graphs, AOT-lowered to HLO text,
//!  * L3 — this crate: the training coordinator, quantized optimizer-state
//!    management, synthetic data pipelines, and the PJRT runtime.

pub mod config;
pub mod coordinator;
pub mod data;
pub mod errors;
pub mod linalg;
pub mod optim;
pub mod quant;
pub mod runtime;
pub mod util;
