//! 4-bit Shampoo: memory-efficient second-order network training
//! (reproduction of Wang, Li, Zhou, Huang — NeurIPS 2024).
//!
//! Three-layer architecture (see DESIGN.md):
//!  * L1 — Pallas quantization/matmul kernels (python/compile/kernels),
//!  * L2 — JAX Shampoo math + model graphs, AOT-lowered to HLO text,
//!  * L3 — this crate: the training coordinator, quantized optimizer-state
//!    management, synthetic data pipelines, and a pluggable execution
//!    [`runtime::Backend`] — the hermetic pure-Rust [`runtime::HostBackend`]
//!    by default, the PJRT artifact registry behind `--features pjrt`.

// Style allowances for dense numeric code: index loops over several buffers
// at once and config structs populated field-by-field from parsed documents.
#![allow(
    clippy::field_reassign_with_default,
    clippy::needless_range_loop,
    clippy::manual_memcpy,
    clippy::too_many_arguments,
    clippy::inherent_to_string
)]

pub mod config;
pub mod coordinator;
pub mod data;
pub mod errors;
pub mod linalg;
pub mod optim;
pub mod quant;
pub mod runtime;
pub mod util;
