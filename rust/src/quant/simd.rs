//! Explicit SIMD lanes for the quant hot loops (`--features simd`).
//!
//! Every kernel here is a *bit-identical* rewrite of the corresponding
//! chunked kernel in [`blockwise`](super::blockwise) /
//! [`pack`](super::pack) / [`Boundaries::nearest_block`] — the property
//! suite asserts scalar == chunked == SIMD at every bitwidth, mapping,
//! block size, and odd length, so enabling the feature can never change
//! codes, scales, packed bytes, or decoded values.
//!
//! Lane strategy (stable Rust — no nightly `portable_simd`):
//!  * **x86_64**: SSE2 intrinsics (`std::arch::x86_64`). SSE2 is part of
//!    the x86_64 baseline, so there is no runtime feature detection and
//!    no `target_feature` gating — the intrinsics are unconditionally
//!    sound to call.
//!  * **2/1-bit pack lanes**: u64 SWAR (shift-mask folds that pack 8
//!    codes per word) — portable, branch-free, and identical on every
//!    arch.
//!  * **other arches**: scalar tails double as the full implementation,
//!    so the `simd` feature builds (and stays bit-identical) everywhere.
//!
//! Why SIMD can be exact here: the encode pipeline is `abs` / `max` /
//! `mul` / `cmplt` / integer adds — none of which reassociate rounding
//! (f32 max is order-insensitive for finite inputs, and non-finite
//! blocks are rejected before the fold is used). The counting kernel
//! computes `#{mids strictly below x}` exactly like the chunked lane,
//! which is exactly `partition_point(|m| m < x)` — tie semantics
//! included.
//!
//! [`Boundaries::nearest_block`]: super::codebook::Boundaries::nearest_block

use super::pack::{pack_bits_chunked, packed_len, unpack_bits_into_chunked};

/// Name of the active lane backend, for bench/JSON provenance.
pub fn simd_arch() -> &'static str {
    if cfg!(target_arch = "x86_64") {
        "sse2+swar"
    } else {
        "portable-swar"
    }
}

// ---------------------------------------------------------------------------
// f32 block lanes: absmax, finiteness, normalize
// ---------------------------------------------------------------------------

/// Max |x| over the slice (0.0 for an empty slice). Identical to the
/// scalar `fold(0.0, |m, v| m.max(v.abs()))` for finite inputs — callers
/// must reject non-finite blocks (see [`all_finite`]) before trusting it.
pub fn absmax(xs: &[f32]) -> f32 {
    #[cfg(target_arch = "x86_64")]
    {
        use std::arch::x86_64::*;
        let mut i = 0usize;
        let mut r = 0.0f32;
        if xs.len() >= 4 {
            // SAFETY: SSE2 is part of the x86_64 baseline (no feature
            // detection needed), and every `loadu` reads 4 f32s at offset
            // `i` with `i + 4 <= xs.len()` — always in bounds, and `loadu`
            // tolerates any alignment.
            unsafe {
                let signbit = _mm_set1_ps(-0.0);
                let mut m = _mm_setzero_ps();
                while i + 4 <= xs.len() {
                    let v = _mm_loadu_ps(xs.as_ptr().add(i));
                    m = _mm_max_ps(m, _mm_andnot_ps(signbit, v));
                    i += 4;
                }
                let m = _mm_max_ps(m, _mm_movehl_ps(m, m));
                let m = _mm_max_ss(m, _mm_shuffle_ps::<0x55>(m, m));
                r = _mm_cvtss_f32(m);
            }
        }
        for &v in &xs[i..] {
            r = r.max(v.abs());
        }
        r
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        xs.iter().fold(0.0f32, |m, &v| m.max(v.abs()))
    }
}

/// True iff every element is finite. Branch-free: accumulates `v * 0.0`
/// (exactly ±0.0 for finite `v`, NaN for ±Inf/NaN — a fold LLVM cannot
/// constant-fold away without fast-math) and tests the sum against 0.0.
pub fn all_finite(xs: &[f32]) -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        use std::arch::x86_64::*;
        let mut i = 0usize;
        let mut s = 0.0f32;
        if xs.len() >= 4 {
            // SAFETY: baseline SSE2; unaligned 4-wide loads stay in bounds
            // via the `i + 4 <= xs.len()` loop guard.
            unsafe {
                let zero = _mm_setzero_ps();
                let mut acc = zero;
                while i + 4 <= xs.len() {
                    let v = _mm_loadu_ps(xs.as_ptr().add(i));
                    acc = _mm_add_ps(acc, _mm_mul_ps(v, zero));
                    i += 4;
                }
                let a = _mm_add_ps(acc, _mm_movehl_ps(acc, acc));
                let a = _mm_add_ss(a, _mm_shuffle_ps::<0x55>(a, a));
                s = _mm_cvtss_f32(a);
            }
        }
        for &v in &xs[i..] {
            s += v * 0.0;
        }
        s == 0.0
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        let mut s = 0.0f32;
        for &v in xs {
            s += v * 0.0;
        }
        s == 0.0
    }
}

/// `out[i] = xs[i] * inv` — the per-block normalize lane. IEEE multiply
/// is elementwise, so the SIMD arm is bit-identical to the scalar loop.
pub fn normalize_into(xs: &[f32], inv: f32, out: &mut [f32]) {
    debug_assert_eq!(xs.len(), out.len());
    #[cfg(target_arch = "x86_64")]
    {
        use std::arch::x86_64::*;
        let mut i = 0usize;
        if xs.len() >= 4 {
            // SAFETY: baseline SSE2; loads from `xs` and stores to `out`
            // cover lanes [i, i+4) with `i + 4 <= xs.len()` and
            // `out.len() == xs.len()` (debug-asserted above).
            unsafe {
                let iv = _mm_set1_ps(inv);
                while i + 4 <= xs.len() {
                    let v = _mm_loadu_ps(xs.as_ptr().add(i));
                    _mm_storeu_ps(out.as_mut_ptr().add(i), _mm_mul_ps(v, iv));
                    i += 4;
                }
            }
        }
        for (o, &v) in out[i..].iter_mut().zip(&xs[i..]) {
            *o = v * inv;
        }
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        for (o, &v) in out.iter_mut().zip(xs) {
            *o = v * inv;
        }
    }
}

// ---------------------------------------------------------------------------
// nearest-code counting lane
// ---------------------------------------------------------------------------

/// `codes[i] = #{m in mids : m < xs[i]}` — the nearest-code counting
/// kernel for every book width (up to 255 midpoints, i.e. 8-bit books),
/// before the duplicate-run remap. The vectorized sweep amortizes each
/// midpoint across 16 elements, so it beats the scalar binary search even
/// for wide books where the scalar counting arm does not.
///
/// SSE2 lane layout: 16 elements per group held in four f32x4 registers;
/// per midpoint, four `cmplt` masks are narrowed `i32 → i16 → i8`
/// (saturating packs are exact on 0/-1 masks) and subtracted from a
/// 16-lane u8 accumulator, so one register holds all 16 running counts.
/// The tail (< 16 elements) runs the same count arithmetic scalar.
pub fn count_below_mids(mids: &[f32], xs: &[f32], codes: &mut [u8]) {
    debug_assert_eq!(xs.len(), codes.len());
    debug_assert!(mids.len() <= 255, "count must fit a u8 lane");
    let mut i = 0usize;
    #[cfg(target_arch = "x86_64")]
    {
        use std::arch::x86_64::*;
        // SAFETY: baseline SSE2; each iteration reads xs[i..i+16] and
        // writes codes[i..i+16] under `i + 16 <= xs.len()` with
        // `codes.len() == xs.len()` (debug-asserted above); unaligned
        // load/store intrinsics tolerate any alignment.
        unsafe {
            while i + 16 <= xs.len() {
                let x0 = _mm_loadu_ps(xs.as_ptr().add(i));
                let x1 = _mm_loadu_ps(xs.as_ptr().add(i + 4));
                let x2 = _mm_loadu_ps(xs.as_ptr().add(i + 8));
                let x3 = _mm_loadu_ps(xs.as_ptr().add(i + 12));
                let mut acc = _mm_setzero_si128();
                for &m in mids {
                    let mv = _mm_set1_ps(m);
                    let c0 = _mm_castps_si128(_mm_cmplt_ps(mv, x0));
                    let c1 = _mm_castps_si128(_mm_cmplt_ps(mv, x1));
                    let c2 = _mm_castps_si128(_mm_cmplt_ps(mv, x2));
                    let c3 = _mm_castps_si128(_mm_cmplt_ps(mv, x3));
                    let lo = _mm_packs_epi32(c0, c1);
                    let hi = _mm_packs_epi32(c2, c3);
                    // 16 bytes of 0x00 / 0xFF; subtracting adds 1 per hit
                    acc = _mm_sub_epi8(acc, _mm_packs_epi16(lo, hi));
                }
                _mm_storeu_si128(codes.as_mut_ptr().add(i) as *mut __m128i, acc);
                i += 16;
            }
        }
    }
    for (c, &x) in codes[i..].iter_mut().zip(&xs[i..]) {
        let mut n = 0u8;
        for &m in mids {
            n += (m < x) as u8;
        }
        *c = n;
    }
}

// ---------------------------------------------------------------------------
// pack / unpack lanes
// ---------------------------------------------------------------------------

/// SIMD arm of [`pack_bits`](super::pack::pack_bits): byte-for-byte
/// identical output (the property suite asserts it against both the
/// chunked fast paths and the generic bit-cursor loop).
pub fn pack_bits_simd(codes: &[u8], bits: u32) -> Vec<u8> {
    assert!((1..=8).contains(&bits));
    match bits {
        8 => codes.to_vec(),
        4 => pack4(codes),
        2 => pack2(codes),
        1 => pack1(codes),
        _ => pack_bits_chunked(codes, bits),
    }
}

/// SIMD arm of [`unpack_bits_into`](super::pack::unpack_bits_into).
pub fn unpack_bits_into_simd(packed: &[u8], bits: u32, out: &mut [u8]) {
    assert!((1..=8).contains(&bits));
    match bits {
        8 => out.copy_from_slice(&packed[..out.len()]),
        4 => unpack4(packed, out),
        2 => unpack2(packed, out),
        1 => unpack1(packed, out),
        _ => unpack_bits_into_chunked(packed, bits, out),
    }
}

/// 4-bit pack: 16 codes → 8 bytes per SSE2 step. Each u16 lane holds an
/// (even, odd) code pair; `even | odd << 4` stays below 256, so a
/// saturating `packus` narrows the 8 lanes to the 8 output bytes.
fn pack4(codes: &[u8]) -> Vec<u8> {
    let mut out = vec![0u8; codes.len().div_ceil(2)];
    #[cfg(target_arch = "x86_64")]
    let done = {
        use std::arch::x86_64::*;
        let mut ci = 0usize;
        // SAFETY: baseline SSE2; reads codes[ci..ci+16] under the
        // `ci + 16 <= codes.len()` guard and stores 8 bytes at
        // out[ci/2..ci/2+8], in bounds because out holds
        // ceil(codes.len()/2) >= ci/2 + 8 bytes for every guarded ci.
        unsafe {
            let lomask = _mm_set1_epi16(0x00FF);
            while ci + 16 <= codes.len() {
                let v = _mm_loadu_si128(codes.as_ptr().add(ci) as *const __m128i);
                let even = _mm_and_si128(v, lomask);
                let odd = _mm_srli_epi16::<8>(v);
                let pair = _mm_or_si128(even, _mm_slli_epi16::<4>(odd));
                let b = _mm_packus_epi16(pair, _mm_setzero_si128());
                _mm_storel_epi64(out.as_mut_ptr().add(ci / 2) as *mut __m128i, b);
                ci += 16;
            }
        }
        ci
    };
    #[cfg(not(target_arch = "x86_64"))]
    let done = 0usize;
    for (o, c) in out[done / 2..].iter_mut().zip(codes[done..].chunks(2)) {
        *o = c[0] | (c.get(1).copied().unwrap_or(0) << 4);
    }
    out
}

/// 4-bit unpack: 8 bytes → 16 codes per SSE2 step (zero-extend bytes to
/// u16 lanes, split nibbles, re-interleave as `lo | hi << 8`).
fn unpack4(packed: &[u8], out: &mut [u8]) {
    #[cfg(target_arch = "x86_64")]
    let done = {
        use std::arch::x86_64::*;
        let mut i = 0usize;
        // SAFETY: baseline SSE2; each step reads 8 bytes at packed[i/2]
        // and writes out[i..i+16] under `i + 16 <= out.len()`; callers
        // pass packed.len() >= ceil(out.len()/2) (`packed_len`), so the
        // 8-byte load at i/2 <= out.len()/2 - 8 stays in bounds.
        unsafe {
            let nib = _mm_set1_epi16(0x000F);
            while i + 16 <= out.len() {
                let p = _mm_loadl_epi64(packed.as_ptr().add(i / 2) as *const __m128i);
                let w = _mm_unpacklo_epi8(p, _mm_setzero_si128());
                let lo = _mm_and_si128(w, nib);
                let hi = _mm_and_si128(_mm_srli_epi16::<4>(w), nib);
                let o = _mm_or_si128(lo, _mm_slli_epi16::<8>(hi));
                _mm_storeu_si128(out.as_mut_ptr().add(i) as *mut __m128i, o);
                i += 16;
            }
        }
        i
    };
    #[cfg(not(target_arch = "x86_64"))]
    let done = 0usize;
    for (c, &b) in out[done..].chunks_mut(2).zip(&packed[done / 2..]) {
        c[0] = b & 0x0F;
        if let Some(hi) = c.get_mut(1) {
            *hi = b >> 4;
        }
    }
}

/// 2-bit pack: u64 SWAR, 8 codes (one word) → 2 bytes. Two shift-mask
/// folds gather the 2-bit fields: bytes → nibbles → packed bytes.
fn pack2(codes: &[u8]) -> Vec<u8> {
    let mut out = vec![0u8; codes.len().div_ceil(4)];
    let mut ci = 0usize;
    let mut oi = 0usize;
    while ci + 8 <= codes.len() {
        let x = u64::from_le_bytes(codes[ci..ci + 8].try_into().unwrap());
        let x = (x | (x >> 6)) & 0x000F_000F_000F_000F;
        let x = (x | (x >> 12)) & 0x0000_00FF_0000_00FF;
        out[oi] = x as u8;
        out[oi + 1] = (x >> 32) as u8;
        ci += 8;
        oi += 2;
    }
    for (o, c) in out[oi..].iter_mut().zip(codes[ci..].chunks(4)) {
        for (k, &v) in c.iter().enumerate() {
            *o |= v << (2 * k);
        }
    }
    out
}

/// 2-bit unpack: inverse SWAR spread, 2 bytes → 8 codes.
fn unpack2(packed: &[u8], out: &mut [u8]) {
    let mut ci = 0usize;
    let mut pi = 0usize;
    while ci + 8 <= out.len() {
        let y = (packed[pi] as u64) | ((packed[pi + 1] as u64) << 32);
        let y = (y | (y << 12)) & 0x000F_000F_000F_000F;
        let y = (y | (y << 6)) & 0x0303_0303_0303_0303;
        out[ci..ci + 8].copy_from_slice(&y.to_le_bytes());
        ci += 8;
        pi += 2;
    }
    for (c, &b) in out[ci..].chunks_mut(4).zip(&packed[pi..]) {
        for (k, v) in c.iter_mut().enumerate() {
            *v = (b >> (2 * k)) & 0x03;
        }
    }
}

/// 1-bit pack: the classic multiply-gather — 8 LSBs fan out to bits
/// 56..63 of the product with no cross-term collisions, one byte per word.
fn pack1(codes: &[u8]) -> Vec<u8> {
    let mut out = vec![0u8; codes.len().div_ceil(8)];
    let mut ci = 0usize;
    let mut oi = 0usize;
    while ci + 8 <= codes.len() {
        let x = u64::from_le_bytes(codes[ci..ci + 8].try_into().unwrap()) & 0x0101_0101_0101_0101;
        out[oi] = (x.wrapping_mul(0x0102_0408_1020_4080) >> 56) as u8;
        ci += 8;
        oi += 1;
    }
    for (o, c) in out[oi..].iter_mut().zip(codes[ci..].chunks(8)) {
        for (k, &v) in c.iter().enumerate() {
            *o |= v << k;
        }
    }
    out
}

/// 1-bit unpack: broadcast the byte to all 8 lanes, isolate bit k in
/// byte k, then normalize each nonzero byte to 1 with a carryless
/// `+0x7F >> 7` (a set bit ≤ 0x80 never carries across its byte).
fn unpack1(packed: &[u8], out: &mut [u8]) {
    let mut ci = 0usize;
    let mut pi = 0usize;
    while ci + 8 <= out.len() {
        let spread =
            (packed[pi] as u64).wrapping_mul(0x0101_0101_0101_0101) & 0x8040_2010_0804_0201;
        let y = (spread.wrapping_add(0x7F7F_7F7F_7F7F_7F7F) >> 7) & 0x0101_0101_0101_0101;
        out[ci..ci + 8].copy_from_slice(&y.to_le_bytes());
        ci += 8;
        pi += 1;
    }
    for (c, &b) in out[ci..].chunks_mut(8).zip(&packed[pi..]) {
        for (k, v) in c.iter_mut().enumerate() {
            *v = (b >> k) & 0x01;
        }
    }
}

// ---------------------------------------------------------------------------
// decode lane
// ---------------------------------------------------------------------------

/// Decode lane: `out[i] = table[codes[i]] * scale` for one block. The
/// gather is scalar (SSE2 has no gather); the scale multiply runs 4-wide.
/// IEEE multiply is elementwise, so this is bit-identical to the chunked
/// table loop.
pub fn decode_block(codes: &[u8], table: &[f32; 256], scale: f32, out: &mut [f32]) {
    debug_assert_eq!(codes.len(), out.len());
    #[cfg(target_arch = "x86_64")]
    {
        use std::arch::x86_64::*;
        let mut i = 0usize;
        if codes.len() >= 4 {
            // SAFETY: baseline SSE2; the gather indexes `table[0..256]`
            // with u8 codes (cannot exceed 255) and the 4-wide store to
            // `out` is guarded by `i + 4 <= codes.len()` with
            // `out.len() == codes.len()` (debug-asserted above).
            unsafe {
                let sv = _mm_set1_ps(scale);
                while i + 4 <= codes.len() {
                    let g = _mm_set_ps(
                        table[codes[i + 3] as usize],
                        table[codes[i + 2] as usize],
                        table[codes[i + 1] as usize],
                        table[codes[i] as usize],
                    );
                    _mm_storeu_ps(out.as_mut_ptr().add(i), _mm_mul_ps(g, sv));
                    i += 4;
                }
            }
        }
        for (o, &c) in out[i..].iter_mut().zip(&codes[i..]) {
            *o = table[c as usize] * scale;
        }
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        for (o, &c) in out.iter_mut().zip(codes) {
            *o = table[c as usize] * scale;
        }
    }
}

/// Unpack a whole payload through the SIMD lanes (convenience mirror of
/// [`unpack_bits`](super::pack::unpack_bits)).
pub fn unpack_bits_simd(packed: &[u8], bits: u32, count: usize) -> Vec<u8> {
    debug_assert!(packed.len() >= packed_len(count, bits));
    let mut out = vec![0u8; count];
    unpack_bits_into_simd(packed, bits, &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn absmax_and_finite_match_scalar() {
        let mut rng = Rng::new(11);
        for n in [0usize, 1, 3, 4, 5, 15, 16, 17, 64, 100] {
            let xs: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
            let want = xs.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
            assert_eq!(absmax(&xs).to_bits(), want.to_bits(), "n={n}");
            assert!(all_finite(&xs), "n={n}");
        }
        for bad in [f32::NAN, f32::INFINITY, f32::NEG_INFINITY] {
            for pos in [0usize, 3, 7, 63] {
                let mut xs = vec![0.25f32; 64];
                xs[pos] = bad;
                assert!(!all_finite(&xs), "bad={bad} pos={pos}");
            }
        }
        // -0.0 stays finite and abs-es to +0.0
        assert!(all_finite(&[-0.0f32; 9]));
        assert_eq!(absmax(&[-0.0f32; 9]), 0.0);
    }

    #[test]
    fn normalize_matches_scalar() {
        let mut rng = Rng::new(12);
        for n in [1usize, 4, 7, 33] {
            let xs: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
            let inv = 0.371f32;
            let mut a = vec![0.0f32; n];
            normalize_into(&xs, inv, &mut a);
            for (av, &x) in a.iter().zip(&xs) {
                assert_eq!(av.to_bits(), (x * inv).to_bits());
            }
        }
    }

    #[test]
    #[cfg_attr(miri, ignore)] // property sweep: too slow under Miri's interpreter
    fn count_below_mids_matches_scalar() {
        let mut rng = Rng::new(13);
        // 15 mids = a 4-bit book; 255 mids = the widest (8-bit) book, which
        // the SIMD encode path now routes through this kernel too
        for width in [15usize, 255] {
            let mids: Vec<f32> = {
                let mut m: Vec<f32> = (0..width).map(|_| rng.normal_f32()).collect();
                m.sort_by(|a, b| a.partial_cmp(b).unwrap());
                m
            };
            for n in [0usize, 1, 15, 16, 17, 31, 32, 100] {
                let xs: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
                let mut got = vec![0u8; n];
                count_below_mids(&mids, &xs, &mut got);
                for (&x, &c) in xs.iter().zip(&got) {
                    let want = mids.iter().filter(|&&m| m < x).count() as u8;
                    assert_eq!(c, want, "x={x} width={width}");
                }
            }
        }
    }

    #[test]
    #[cfg_attr(miri, ignore)] // property sweep: too slow under Miri's interpreter
    fn pack_lanes_match_chunked_all_widths() {
        let mut rng = Rng::new(14);
        for bits in [1u32, 2, 3, 4, 8] {
            for n in [0usize, 1, 2, 7, 8, 15, 16, 17, 63, 64, 129, 1000] {
                let codes: Vec<u8> =
                    (0..n).map(|_| rng.below(1usize << bits) as u8).collect();
                let want = pack_bits_chunked(&codes, bits);
                let got = pack_bits_simd(&codes, bits);
                assert_eq!(got, want, "pack bits={bits} n={n}");
                let mut back = vec![0u8; n];
                unpack_bits_into_simd(&got, bits, &mut back);
                assert_eq!(back, codes, "unpack bits={bits} n={n}");
            }
        }
    }

    #[test]
    fn decode_block_matches_scalar() {
        let mut rng = Rng::new(15);
        let mut table = [0.0f32; 256];
        for t in table.iter_mut().take(16) {
            *t = rng.normal_f32();
        }
        for n in [1usize, 3, 4, 5, 64] {
            let codes: Vec<u8> = (0..n).map(|_| rng.below(16) as u8).collect();
            let mut out = vec![0.0f32; n];
            decode_block(&codes, &table, 1.7, &mut out);
            for (o, &c) in out.iter().zip(&codes) {
                assert_eq!(o.to_bits(), (table[c as usize] * 1.7).to_bits());
            }
        }
    }
}
