//! Bit packing for quantized optimizer states.
//!
//! The coordinator *stores* codes packed at their true bitwidth (2 codes per
//! byte at 4-bit, 8 codes in 3 bytes at 3-bit) — this is what makes the
//! memory numbers in Table 2/13 real, not simulated — and unpacks to one
//! code per byte only transiently at the artifact boundary.

/// Pack `codes` (each < 2^bits) into a little-endian bitstream.
pub fn pack_bits(codes: &[u8], bits: u32) -> Vec<u8> {
    assert!((1..=8).contains(&bits));
    let total_bits = codes.len() * bits as usize;
    let mut out = vec![0u8; total_bits.div_ceil(8)];
    let mut bitpos = 0usize;
    for &c in codes {
        debug_assert!(
            (c as u32) < (1u32 << bits),
            "code {c} out of range for {bits}-bit"
        );
        let byte = bitpos / 8;
        let off = bitpos % 8;
        out[byte] |= c << off;
        if off + bits as usize > 8 {
            out[byte + 1] |= c >> (8 - off);
        }
        bitpos += bits as usize;
    }
    out
}

/// Unpack `count` codes from a bitstream produced by `pack_bits`.
pub fn unpack_bits(packed: &[u8], bits: u32, count: usize) -> Vec<u8> {
    assert!((1..=8).contains(&bits));
    let mask = ((1u16 << bits) - 1) as u8;
    let mut out = Vec::with_capacity(count);
    let mut bitpos = 0usize;
    for _ in 0..count {
        let byte = bitpos / 8;
        let off = bitpos % 8;
        let mut v = packed[byte] >> off;
        if off + bits as usize > 8 {
            v |= packed[byte + 1] << (8 - off);
        }
        out.push(v & mask);
        bitpos += bits as usize;
    }
    out
}

/// Bytes needed to store `count` codes at `bits` bits each.
pub fn packed_len(count: usize, bits: u32) -> usize {
    (count * bits as usize).div_ceil(8)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn roundtrip_property_all_widths() {
        for bits in 1..=8u32 {
            prop::check(&format!("pack/unpack roundtrip {bits}-bit"), 20, |rng| {
                let n = rng.below(200);
                let codes: Vec<u8> =
                    (0..n).map(|_| rng.below(1usize << bits) as u8).collect();
                let packed = pack_bits(&codes, bits);
                if packed.len() != packed_len(n, bits) {
                    return Err("length".into());
                }
                let back = unpack_bits(&packed, bits, n);
                if back != codes {
                    return Err(format!("mismatch at bits={bits} n={n}"));
                }
                Ok(())
            });
        }
    }

    #[test]
    fn four_bit_nibble_layout() {
        // two 4-bit codes per byte, low nibble first
        let packed = pack_bits(&[0x3, 0xA, 0xF], 4);
        assert_eq!(packed, vec![0xA3, 0x0F]);
    }

    #[test]
    fn three_bit_density() {
        // 8 codes * 3 bits = 24 bits = 3 bytes exactly
        let codes = [1u8, 2, 3, 4, 5, 6, 7, 0];
        let packed = pack_bits(&codes, 3);
        assert_eq!(packed.len(), 3);
        assert_eq!(unpack_bits(&packed, 3, 8), codes);
    }

    #[test]
    fn eight_bit_is_identity() {
        let codes = [0u8, 127, 255];
        assert_eq!(pack_bits(&codes, 8), codes.to_vec());
    }

    #[test]
    fn empty() {
        assert!(pack_bits(&[], 4).is_empty());
        assert!(unpack_bits(&[], 4, 0).is_empty());
    }
}
