//! Bit packing for quantized optimizer states.
//!
//! The coordinator *stores* codes packed at their true bitwidth (2 codes per
//! byte at 4-bit, 8 codes in 3 bytes at 3-bit) — this is what makes the
//! memory numbers in Table 2/13 real, not simulated — and unpacks to one
//! code per byte only transiently at the artifact boundary.

/// Pack `codes` (each < 2^bits) into a little-endian bitstream.
///
/// Dispatcher: with the `simd` feature this routes byte-aligned widths to
/// the explicit SIMD/SWAR lanes in `quant::simd`; otherwise it runs
/// the chunked fast paths. Every arm emits byte-for-byte identical output
/// (asserted by the three-way property suite), so the feature flag can
/// never change a checkpoint.
pub fn pack_bits(codes: &[u8], bits: u32) -> Vec<u8> {
    #[cfg(debug_assertions)]
    for &c in codes {
        debug_assert!((c as u32) < (1u32 << bits), "code {c} out of range for {bits}-bit");
    }
    #[cfg(feature = "simd")]
    {
        super::simd::pack_bits_simd(codes, bits)
    }
    #[cfg(not(feature = "simd"))]
    {
        pack_bits_chunked(codes, bits)
    }
}

/// Chunked (auto-vectorizable scalar) arm of [`pack_bits`].
///
/// The byte-aligned widths (8/4/2/1-bit) take batched, branch-free fast
/// paths — fixed-width chunks, no running bit cursor — which is what keeps
/// the `StateBuf` encode hot loop auto-vectorizable; odd widths fall back to
/// the generic bit-cursor loop. All paths emit identical bytes.
pub fn pack_bits_chunked(codes: &[u8], bits: u32) -> Vec<u8> {
    assert!((1..=8).contains(&bits));
    match bits {
        8 => codes.to_vec(),
        4 => {
            let mut out = vec![0u8; codes.len().div_ceil(2)];
            for (o, c) in out.iter_mut().zip(codes.chunks(2)) {
                *o = c[0] | (c.get(1).copied().unwrap_or(0) << 4);
            }
            out
        }
        2 => {
            let mut out = vec![0u8; codes.len().div_ceil(4)];
            for (o, c) in out.iter_mut().zip(codes.chunks(4)) {
                for (k, &v) in c.iter().enumerate() {
                    *o |= v << (2 * k);
                }
            }
            out
        }
        1 => {
            let mut out = vec![0u8; codes.len().div_ceil(8)];
            for (o, c) in out.iter_mut().zip(codes.chunks(8)) {
                for (k, &v) in c.iter().enumerate() {
                    *o |= v << k;
                }
            }
            out
        }
        _ => pack_bits_generic(codes, bits),
    }
}

/// Generic bit-cursor packing for widths that straddle byte boundaries.
pub(crate) fn pack_bits_generic(codes: &[u8], bits: u32) -> Vec<u8> {
    let total_bits = codes.len() * bits as usize;
    let mut out = vec![0u8; total_bits.div_ceil(8)];
    let mut bitpos = 0usize;
    for &c in codes {
        let byte = bitpos / 8;
        let off = bitpos % 8;
        out[byte] |= c << off;
        if off + bits as usize > 8 {
            out[byte + 1] |= c >> (8 - off);
        }
        bitpos += bits as usize;
    }
    out
}

/// Unpack codes from a bitstream produced by `pack_bits` into `out`
/// (one code per byte). Dispatcher mirroring [`pack_bits`]: the `simd`
/// feature routes byte-aligned widths to the SIMD/SWAR lanes, otherwise
/// the chunked fast paths run. All arms are bit-identical.
pub fn unpack_bits_into(packed: &[u8], bits: u32, out: &mut [u8]) {
    #[cfg(feature = "simd")]
    {
        super::simd::unpack_bits_into_simd(packed, bits, out)
    }
    #[cfg(not(feature = "simd"))]
    {
        unpack_bits_into_chunked(packed, bits, out)
    }
}

/// Chunked (auto-vectorizable scalar) arm of [`unpack_bits_into`].
/// Byte-aligned widths use batched fast paths mirroring
/// [`pack_bits_chunked`]; this is the decode-side hot path, so it writes
/// into a caller-provided buffer instead of growing a `Vec` element by
/// element.
pub fn unpack_bits_into_chunked(packed: &[u8], bits: u32, out: &mut [u8]) {
    assert!((1..=8).contains(&bits));
    match bits {
        8 => out.copy_from_slice(&packed[..out.len()]),
        4 => {
            for (c, &b) in out.chunks_mut(2).zip(packed) {
                c[0] = b & 0x0F;
                if let Some(hi) = c.get_mut(1) {
                    *hi = b >> 4;
                }
            }
        }
        2 => {
            for (c, &b) in out.chunks_mut(4).zip(packed) {
                for (k, v) in c.iter_mut().enumerate() {
                    *v = (b >> (2 * k)) & 0x03;
                }
            }
        }
        1 => {
            for (c, &b) in out.chunks_mut(8).zip(packed) {
                for (k, v) in c.iter_mut().enumerate() {
                    *v = (b >> k) & 0x01;
                }
            }
        }
        _ => {
            let mask = ((1u16 << bits) - 1) as u8;
            let mut bitpos = 0usize;
            for o in out.iter_mut() {
                let byte = bitpos / 8;
                let off = bitpos % 8;
                let mut v = packed[byte] >> off;
                if off + bits as usize > 8 {
                    v |= packed[byte + 1] << (8 - off);
                }
                *o = v & mask;
                bitpos += bits as usize;
            }
        }
    }
}

/// Unpack `count` codes from a bitstream produced by `pack_bits`.
pub fn unpack_bits(packed: &[u8], bits: u32, count: usize) -> Vec<u8> {
    let mut out = vec![0u8; count];
    unpack_bits_into(packed, bits, &mut out);
    out
}

/// Bytes needed to store `count` codes at `bits` bits each.
pub fn packed_len(count: usize, bits: u32) -> usize {
    (count * bits as usize).div_ceil(8)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    #[cfg_attr(miri, ignore)] // property sweep: too slow under Miri's interpreter
    fn roundtrip_property_all_widths() {
        for bits in 1..=8u32 {
            prop::check(&format!("pack/unpack roundtrip {bits}-bit"), 20, |rng| {
                let n = rng.below(200);
                let codes: Vec<u8> =
                    (0..n).map(|_| rng.below(1usize << bits) as u8).collect();
                let packed = pack_bits(&codes, bits);
                if packed.len() != packed_len(n, bits) {
                    return Err("length".into());
                }
                let back = unpack_bits(&packed, bits, n);
                if back != codes {
                    return Err(format!("mismatch at bits={bits} n={n}"));
                }
                Ok(())
            });
        }
    }

    #[test]
    fn four_bit_nibble_layout() {
        // two 4-bit codes per byte, low nibble first
        let packed = pack_bits(&[0x3, 0xA, 0xF], 4);
        assert_eq!(packed, vec![0xA3, 0x0F]);
    }

    #[test]
    fn three_bit_density() {
        // 8 codes * 3 bits = 24 bits = 3 bytes exactly
        let codes = [1u8, 2, 3, 4, 5, 6, 7, 0];
        let packed = pack_bits(&codes, 3);
        assert_eq!(packed.len(), 3);
        assert_eq!(unpack_bits(&packed, 3, 8), codes);
    }

    #[test]
    fn eight_bit_is_identity() {
        let codes = [0u8, 127, 255];
        assert_eq!(pack_bits(&codes, 8), codes.to_vec());
    }

    #[test]
    fn empty() {
        assert!(pack_bits(&[], 4).is_empty());
        assert!(unpack_bits(&[], 4, 0).is_empty());
    }

    #[test]
    #[cfg_attr(miri, ignore)] // property sweep: too slow under Miri's interpreter
    fn fast_paths_match_generic_layout() {
        // every arm — dispatcher, chunked fast paths, and (when built) the
        // SIMD lanes — must emit byte-for-byte what the generic bit-cursor
        // loop emits (checkpoints depend on the layout)
        let mut rng = crate::util::rng::Rng::new(17);
        for bits in [1u32, 2, 4, 8] {
            for n in [0usize, 1, 2, 3, 7, 15, 16, 17, 64, 129, 1000] {
                let codes: Vec<u8> =
                    (0..n).map(|_| rng.below(1usize << bits) as u8).collect();
                let want = pack_bits_generic(&codes, bits);
                assert_eq!(pack_bits(&codes, bits), want, "dispatch bits={bits} n={n}");
                assert_eq!(
                    pack_bits_chunked(&codes, bits),
                    want,
                    "chunked bits={bits} n={n}"
                );
                #[cfg(feature = "simd")]
                assert_eq!(
                    crate::quant::simd::pack_bits_simd(&codes, bits),
                    want,
                    "simd bits={bits} n={n}"
                );
                let mut back = vec![0u8; n];
                unpack_bits_into_chunked(&want, bits, &mut back);
                assert_eq!(back, codes, "chunked unpack bits={bits} n={n}");
                #[cfg(feature = "simd")]
                {
                    let mut back2 = vec![0u8; n];
                    crate::quant::simd::unpack_bits_into_simd(&want, bits, &mut back2);
                    assert_eq!(back2, codes, "simd unpack bits={bits} n={n}");
                }
            }
        }
    }
}
