//! Quantization codebooks — exact Rust mirror of python/compile/quantizer.py
//! (cross-checked against the paper's Appendix C tables in tests and against
//! the Python implementation via the golden artifacts).

/// Quantization mapping R (paper §2.2 / §3.3 / Appendix C).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Mapping {
    /// Dynamic tree quantization [Dettmers 2016].
    Dt,
    /// Linear square quantization, paper eq. (3).
    Linear2,
    /// Plain linear quantization (reference arm).
    Linear,
}

impl Mapping {
    /// Parse a config/CLI mapping name.
    pub fn parse(s: &str) -> Option<Mapping> {
        match s.to_ascii_lowercase().as_str() {
            "dt" | "dynamic_tree" => Some(Mapping::Dt),
            "linear2" | "linear-2" | "linear_square" => Some(Mapping::Linear2),
            "linear" => Some(Mapping::Linear),
            _ => None,
        }
    }

    /// Canonical config/checkpoint name.
    pub fn name(&self) -> &'static str {
        match self {
            Mapping::Dt => "dt",
            Mapping::Linear2 => "linear2",
            Mapping::Linear => "linear",
        }
    }

    /// [`Mapping::parse`] with a helpful error that lists the valid names —
    /// the config/CLI entry point, so typos name their alternatives.
    pub fn parse_named(s: &str) -> anyhow::Result<Mapping> {
        Mapping::parse(s).ok_or_else(|| {
            anyhow::anyhow!(
                "unknown codebook mapping {s:?}; valid mappings: dt (dynamic tree), \
                 linear2 (linear-square), linear"
            )
        })
    }
}

/// Sorted codebook for (mapping, bits).
pub fn codebook(mapping: Mapping, bits: u32) -> Vec<f32> {
    let out = match mapping {
        Mapping::Dt => dt_codebook(bits),
        Mapping::Linear2 => linear2_codebook(bits),
        Mapping::Linear => linear_codebook(bits),
    };
    debug_assert_eq!(out.len(), 1 << bits);
    out
}

/// DT codebook: {0, 1} ∪ {±q_k·10^{-E}}, b = 2+E+F,
/// q_k = (p_k + p_{k+1})/2, p_j = 0.9·j/2^F + 0.1  (Appendix C).
pub fn dt_codebook(bits: u32) -> Vec<f32> {
    assert!(bits >= 2);
    let mut values: Vec<f64> = vec![0.0, 1.0];
    for e in 0..=(bits - 2) {
        let f = bits - 2 - e;
        let pow = 2usize.pow(f);
        let p: Vec<f64> = (0..=pow).map(|j| 0.9 * j as f64 / pow as f64 + 0.1).collect();
        for k in 0..pow {
            let q = 0.5 * (p[k] + p[k + 1]) * 10f64.powi(-(e as i32));
            values.push(q);
            values.push(-q);
        }
    }
    values.sort_by(|a, b| a.partial_cmp(b).unwrap());
    values.dedup();
    assert_eq!(values.len(), 1 << bits);
    values.into_iter().map(|x| x as f32).collect()
}

/// Linear-2 codebook, paper eq. (3).
pub fn linear2_codebook(bits: u32) -> Vec<f32> {
    let n = 1usize << bits;
    let mid = (1usize << (bits - 1)) - 1;
    (0..n)
        .map(|j| {
            let base = -1.0 + 2.0 * j as f64 / (n - 1) as f64;
            if j < mid {
                -(base * base) as f32
            } else if j == mid {
                0.0
            } else {
                (base * base) as f32
            }
        })
        .collect()
}

/// Plain linear codebook on [-1, 1].
pub fn linear_codebook(bits: u32) -> Vec<f32> {
    let n = 1usize << bits;
    (0..n)
        .map(|j| (-1.0 + 2.0 * j as f64 / (n - 1) as f64) as f32)
        .collect()
}

/// The 16-entry runtime codebook fed to artifacts: 4-bit books verbatim;
/// 3-bit books padded by repeating the final entry (argmin picks the first
/// occurrence, so emitted codes stay < 8 — see aot.py docstring).
pub fn runtime_codebook(mapping: Mapping, bits: u32) -> Vec<f32> {
    assert!(bits == 3 || bits == 4, "runtime artifacts support 3/4-bit");
    let mut cb = codebook(mapping, bits);
    let last = *cb.last().unwrap();
    while cb.len() < 16 {
        cb.push(last);
    }
    cb
}

/// Precomputed decision boundaries for a *sorted* codebook: entry i wins on
/// (mid[i-1], mid[i]] where mid[i] = (cb[i]+cb[i+1])/2. Nearest-neighbour
/// lookup becomes a binary search over 2^b − 1 midpoints (§Perf
/// optimization L3-1; cross-checked against `nearest` by property test).
///
/// Tie semantics: jnp.argmin picks the LOWEST index on exact midpoint ties,
/// i.e. x == mid[i] maps to i, so the search uses `mid[j] < x` strictly.
pub struct Boundaries {
    /// the sorted codebook these boundaries were built from — owned so
    /// [`Boundaries::stochastic_pair`] can never be fed a mismatched book
    cb: Vec<f32>,
    mids: Vec<f32>,
    /// canonical (lowest) index per position — collapses duplicate runs in
    /// padded runtime codebooks so emitted codes always match `nearest`
    /// (critical: 3-bit packing requires codes < 8 even if a rounding
    /// artifact pushes x past the last unique entry). Fixed 256 entries so
    /// a `u8` count indexes it with no bounds check on the lane hot path.
    remap: [u8; 256],
}

/// Books at or below this many midpoints (≤ 5-bit) take the branch-free
/// counting kernel in [`Boundaries::nearest_block`]; wider books binary
/// search per element instead (8 ordered probes beat 255 linear compares).
const COUNTING_MIDS_MAX: usize = 31;

impl Boundaries {
    /// Precompute midpoints + duplicate-run remap for a sorted codebook.
    pub fn new(cb: &[f32]) -> Self {
        debug_assert!(cb.windows(2).all(|w| w[0] <= w[1]), "codebook must be sorted");
        debug_assert!(cb.len() <= 256, "codebooks are at most 8-bit");
        let mut remap = [0u8; 256];
        for i in 1..cb.len() {
            remap[i] = if cb[i] == cb[i - 1] { remap[i - 1] } else { i as u8 };
        }
        Self {
            cb: cb.to_vec(),
            mids: cb.windows(2).map(|w| 0.5 * (w[0] + w[1])).collect(),
            remap,
        }
    }

    /// Nearest codebook index for `x` (jnp.argmin tie semantics).
    #[inline]
    pub fn nearest(&self, x: f32) -> u8 {
        self.remap[self.mids.partition_point(|&m| m < x)]
    }

    /// Nearest codebook index for every element of `xs`, written to the
    /// matching slot of `codes` — the chunked encode hot path.
    ///
    /// For small books (5-bit and below) the code is computed branch-free as
    /// `#{mids strictly below x}`: the midpoint loop runs *outside* a
    /// fixed-width element lane, so the inner `count += (mid < x)` lane
    /// auto-vectorizes with no data-dependent branches. This is exactly the
    /// quantity `partition_point(|m| m < x)` returns, so the chunked path is
    /// bit-identical to [`Boundaries::nearest`] — tie semantics included.
    /// Wide books (8-bit) keep the per-element binary search in a tight loop.
    pub fn nearest_block(&self, xs: &[f32], codes: &mut [u8]) {
        debug_assert_eq!(xs.len(), codes.len());
        if self.mids.len() <= COUNTING_MIDS_MAX {
            codes.fill(0);
            for &m in &self.mids {
                for (c, &x) in codes.iter_mut().zip(xs) {
                    *c += (m < x) as u8;
                }
            }
            for c in codes.iter_mut() {
                *c = self.remap[*c as usize];
            }
        } else {
            for (c, &x) in codes.iter_mut().zip(xs) {
                *c = self.nearest(x);
            }
        }
    }

    /// SIMD arm of [`Boundaries::nearest_block`] (`--features simd`): the
    /// counting kernel runs 16 (SSE2/NEON) or 32 (AVX2) elements per step
    /// through [`count_below_mids_with`](super::simd::count_below_mids_with)
    /// on the given lane, followed by the
    /// same duplicate-run remap pass — for EVERY book width. Unlike the
    /// scalar arm (where 255 linear compares lose to an 8-probe binary
    /// search), the vectorized count amortizes the midpoint sweep across a
    /// whole register of elements at once, so 8-bit books take the counting
    /// kernel too: a 256-entry book is 255 mids, and the count still fits
    /// `u8`. Bit-identical to the scalar arms at any width — the count is
    /// exactly `partition_point(|m| m < x)`.
    #[cfg(feature = "simd")]
    pub fn nearest_block_simd(&self, lane: super::simd::Lane, xs: &[f32], codes: &mut [u8]) {
        debug_assert_eq!(xs.len(), codes.len());
        super::simd::count_below_mids_with(lane, &self.mids, xs, codes);
        for c in codes.iter_mut() {
            *c = self.remap[*c as usize];
        }
    }

    /// SIMD arm of the stochastic-rounding bracket search (`--features
    /// simd`): one `(lo, hi, p)` triple per element of `xs`, bit-identical
    /// to calling [`Boundaries::stochastic_pair`] element-by-element.
    ///
    /// The scalar pair does a per-element binary search over the *codebook
    /// entries* (`partition_point(|c| c < x)`); this arm replaces it with
    /// one vectorized [`count_below_mids_with`](super::simd::count_below_mids_with)
    /// sweep counting `cb[..K-1]` — capped at 255 entries so the running
    /// count fits the kernel's u8 lane even for a full 256-entry book —
    /// and folds the final entry back in scalar (`cb[K-1] < x` can only
    /// matter when all earlier entries already compared below). The
    /// bracket/fraction arithmetic then runs the *same* f32 ops in the
    /// same order as `stochastic_pair`, so triples match bit-for-bit,
    /// clamps and exact codebook hits included. The seeded RNG draw stays
    /// with the caller, in element order — this kernel never consumes
    /// randomness, which is what keeps forced-lane SR streams reproducible.
    ///
    /// `counts` is caller-provided scratch (same length as `xs`).
    #[cfg(feature = "simd")]
    pub fn stochastic_block_simd(
        &self,
        lane: super::simd::Lane,
        xs: &[f32],
        counts: &mut [u8],
        pairs: &mut [(u8, u8, f32)],
    ) {
        debug_assert_eq!(xs.len(), counts.len());
        debug_assert_eq!(xs.len(), pairs.len());
        let cb = &self.cb;
        let k = cb.len();
        debug_assert!(k >= 2, "codebooks have at least 2 entries");
        super::simd::count_below_mids_with(lane, &cb[..k - 1], xs, counts);
        let last = cb[k - 1];
        for ((&x, &n), pr) in xs.iter().zip(counts.iter()).zip(pairs.iter_mut()) {
            let mut hi = n as usize;
            if hi == k - 1 && last < x {
                hi = k;
            }
            *pr = if hi == 0 {
                (self.remap[0], self.remap[0], 0.0)
            } else if hi >= k {
                let end = self.remap[k - 1];
                (end, end, 1.0)
            } else {
                let lo = hi - 1;
                let gap = cb[hi] - cb[lo];
                let p = if gap > 0.0 { (x - cb[lo]) / gap } else { 1.0 };
                (self.remap[lo], self.remap[hi], p)
            };
        }
    }

    /// Codebook neighbours bracketing `x` for stochastic rounding (against
    /// the book this `Boundaries` was built from): `(lo, hi, p)` where `p`
    /// is the probability of rounding *up* to `hi` (the distance fraction,
    /// so the expected dequantized value equals `x` inside the book's
    /// range). Out-of-range values clamp to the end entries with `p` 0/1,
    /// and an exact codebook hit returns itself.
    #[inline]
    pub fn stochastic_pair(&self, x: f32) -> (u8, u8, f32) {
        let cb = &self.cb;
        let hi = cb.partition_point(|&c| c < x);
        if hi == 0 {
            return (self.remap[0], self.remap[0], 0.0);
        }
        if hi >= cb.len() {
            let last = self.remap[cb.len() - 1];
            return (last, last, 1.0);
        }
        let (lo, hi) = (hi - 1, hi);
        let gap = cb[hi] - cb[lo];
        let p = if gap > 0.0 { (x - cb[lo]) / gap } else { 1.0 };
        (self.remap[lo], self.remap[hi], p)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Reference O(K) linear scan with jnp.argmin tie semantics — kept
    /// test-local: every production call site goes through
    /// `Boundaries::nearest`.
    fn nearest_ref(cb: &[f32], x: f32) -> u8 {
        let mut best = 0usize;
        let mut best_d = (x - cb[0]).abs();
        for (i, &c) in cb.iter().enumerate().skip(1) {
            let d = (x - c).abs();
            if d < best_d {
                best_d = d;
                best = i;
            }
        }
        best as u8
    }

    // Appendix C tables, verbatim.
    const DT4: [f32; 16] = [
        -0.8875, -0.6625, -0.4375, -0.2125, -0.0775, -0.0325, -0.0055, 0.0,
        0.0055, 0.0325, 0.0775, 0.2125, 0.4375, 0.6625, 0.8875, 1.0,
    ];
    const DT3: [f32; 8] = [-0.775, -0.325, -0.055, 0.0, 0.055, 0.325, 0.775, 1.0];
    const L24: [f32; 16] = [
        -1.0, -0.7511, -0.5378, -0.36, -0.2178, -0.1111, -0.04, 0.0, 0.0044,
        0.04, 0.1111, 0.2178, 0.36, 0.5378, 0.7511, 1.0,
    ];

    #[test]
    fn dt4_matches_paper() {
        let cb = dt_codebook(4);
        for (a, b) in cb.iter().zip(DT4.iter()) {
            assert!((a - b).abs() < 1e-6, "{a} vs {b}");
        }
    }

    #[test]
    fn dt3_matches_paper() {
        let cb = dt_codebook(3);
        for (a, b) in cb.iter().zip(DT3.iter()) {
            assert!((a - b).abs() < 1e-6, "{a} vs {b}");
        }
    }

    #[test]
    fn linear2_4_matches_paper() {
        let cb = linear2_codebook(4);
        for (a, b) in cb.iter().zip(L24.iter()) {
            assert!((a - b).abs() < 5e-5, "{a} vs {b}");
        }
    }

    #[test]
    fn dt8_has_256_sorted_entries() {
        let cb = dt_codebook(8);
        assert_eq!(cb.len(), 256);
        assert!(cb.windows(2).all(|w| w[0] < w[1]));
        assert_eq!(*cb.last().unwrap(), 1.0);
    }

    #[test]
    fn runtime_codebook_padding() {
        let cb = runtime_codebook(Mapping::Dt, 3);
        assert_eq!(cb.len(), 16);
        assert_eq!(cb[7], 1.0);
        assert_eq!(cb[15], 1.0);
        // codes emitted against the padded book stay below 8
        let b = Boundaries::new(&cb);
        for x in [-1.0f32, -0.2, 0.0, 0.3, 0.99, 1.0] {
            assert!(b.nearest(x) < 8, "{x}");
        }
    }

    #[test]
    fn nearest_ties_take_lowest_index() {
        let cb = vec![-1.0, 0.0, 0.0, 1.0];
        let b = Boundaries::new(&cb);
        assert_eq!(b.nearest(0.0), 1);
        assert_eq!(b.nearest(-0.5), 0); // exact tie -1.0 vs 0.0 -> lowest
        assert_eq!(nearest_ref(&cb, 0.0), 1);
        assert_eq!(nearest_ref(&cb, -0.5), 0);
    }

    #[test]
    #[cfg_attr(miri, ignore)] // property sweep: too slow under Miri's interpreter
    fn boundaries_match_linear_scan() {
        use crate::util::prop;
        for (mapping, bits) in [
            (Mapping::Dt, 4u32),
            (Mapping::Linear2, 4),
            (Mapping::Dt, 8),
            (Mapping::Linear2, 3),
        ] {
            let cb = codebook(mapping, bits);
            let b = Boundaries::new(&cb);
            prop::check(
                &format!("boundaries == argmin {mapping:?}/{bits}"),
                20,
                |rng| {
                    for _ in 0..200 {
                        let x = (rng.normal() * 0.7) as f32;
                        let want = nearest_ref(&cb, x);
                        let got = b.nearest(x);
                        if want != got {
                            // allow only exact-tie flips (equal distances)
                            let dw = (x - cb[want as usize]).abs();
                            let dg = (x - cb[got as usize]).abs();
                            if (dw - dg).abs() > 1e-7 {
                                return Err(format!("x={x}: {want} vs {got}"));
                            }
                        }
                    }
                    Ok(())
                },
            );
        }
    }

    #[test]
    fn boundaries_handle_padded_books() {
        let cb = runtime_codebook(Mapping::Dt, 3);
        let b = Boundaries::new(&cb);
        for x in [-1.0f32, -0.2, 0.0, 0.3, 0.99, 1.0, 2.0] {
            assert!(b.nearest(x) < 8, "{x} -> {}", b.nearest(x));
            assert_eq!(b.nearest(x), nearest_ref(&cb, x), "{x}");
        }
    }

    #[test]
    fn mapping_parse() {
        assert_eq!(Mapping::parse("DT"), Some(Mapping::Dt));
        assert_eq!(Mapping::parse("linear-2"), Some(Mapping::Linear2));
        assert_eq!(Mapping::parse("bogus"), None);
        let err = Mapping::parse_named("bogus").unwrap_err().to_string();
        assert!(err.contains("dt") && err.contains("linear2"), "{err}");
    }

    #[test]
    #[cfg_attr(miri, ignore)] // property sweep: too slow under Miri's interpreter
    fn nearest_block_matches_scalar_nearest() {
        use crate::util::prop;
        // both the counting kernel (≤5-bit) and the binary-search fallback
        // (8-bit) must be bit-identical to the scalar `nearest`
        for (mapping, bits) in [
            (Mapping::Dt, 4u32),
            (Mapping::Linear2, 4),
            (Mapping::Linear2, 3),
            (Mapping::Dt, 8),
        ] {
            let cb = codebook(mapping, bits);
            let b = Boundaries::new(&cb);
            prop::check(&format!("nearest_block == nearest {mapping:?}/{bits}"), 10, |rng| {
                let n = 1 + rng.below(130);
                let xs: Vec<f32> = (0..n).map(|_| (rng.normal() * 0.7) as f32).collect();
                let mut codes = vec![0u8; n];
                b.nearest_block(&xs, &mut codes);
                for (&x, &c) in xs.iter().zip(&codes) {
                    if c != b.nearest(x) {
                        return Err(format!("x={x}: block {c} vs scalar {}", b.nearest(x)));
                    }
                }
                Ok(())
            });
        }
        // padded runtime books: lane codes stay below the true width too
        let cb = runtime_codebook(Mapping::Dt, 3);
        let b = Boundaries::new(&cb);
        let xs = [-1.0f32, -0.2, 0.0, 0.3, 0.99, 1.0, 2.0];
        let mut codes = [0u8; 7];
        b.nearest_block(&xs, &mut codes);
        assert!(codes.iter().all(|&c| c < 8), "{codes:?}");
    }

    #[cfg(feature = "simd")]
    #[test]
    #[cfg_attr(miri, ignore)] // property sweep: too slow under Miri's interpreter
    fn nearest_block_simd_matches_chunked_on_every_lane() {
        use crate::util::prop;
        for (mapping, bits) in [
            (Mapping::Dt, 2u32),
            (Mapping::Dt, 4),
            (Mapping::Linear2, 4),
            (Mapping::Linear2, 3),
            (Mapping::Dt, 8),
        ] {
            let cb = codebook(mapping, bits);
            let b = Boundaries::new(&cb);
            for lane in crate::quant::simd::detected_lanes() {
                prop::check(
                    &format!("simd nearest_block {mapping:?}/{bits} lane={lane}"),
                    10,
                    |rng| {
                        let n = 1 + rng.below(200);
                        let xs: Vec<f32> =
                            (0..n).map(|_| (rng.normal() * 0.7) as f32).collect();
                        let mut chunked = vec![0u8; n];
                        let mut simd = vec![0u8; n];
                        b.nearest_block(&xs, &mut chunked);
                        b.nearest_block_simd(lane, &xs, &mut simd);
                        if chunked != simd {
                            return Err(format!("simd arm diverged at n={n}"));
                        }
                        Ok(())
                    },
                );
            }
        }
    }

    #[cfg(feature = "simd")]
    #[test]
    #[cfg_attr(miri, ignore)] // property sweep: too slow under Miri's interpreter
    fn stochastic_block_simd_matches_scalar_pairs_on_every_lane() {
        use crate::util::prop;
        for (mapping, bits) in [(Mapping::Dt, 4u32), (Mapping::Linear2, 3), (Mapping::Dt, 8)] {
            let cb = codebook(mapping, bits);
            let b = Boundaries::new(&cb);
            for lane in crate::quant::simd::detected_lanes() {
                prop::check(
                    &format!("simd stochastic_block {mapping:?}/{bits} lane={lane}"),
                    10,
                    |rng| {
                        let n = 1 + rng.below(200);
                        let mut xs: Vec<f32> =
                            (0..n).map(|_| (rng.normal() * 0.7) as f32).collect();
                        // force exact hits and out-of-range clamps into the mix
                        if n > 3 {
                            xs[0] = cb[rng.below(cb.len())];
                            xs[1] = -2.0;
                            xs[2] = 2.0;
                        }
                        let mut counts = vec![0u8; n];
                        let mut pairs = vec![(0u8, 0u8, 0f32); n];
                        b.stochastic_block_simd(lane, &xs, &mut counts, &mut pairs);
                        for (&x, &(lo, hi, p)) in xs.iter().zip(&pairs) {
                            let (wl, wh, wp) = b.stochastic_pair(x);
                            if (lo, hi, p.to_bits()) != (wl, wh, wp.to_bits()) {
                                return Err(format!(
                                    "pair diverged at x={x}: got ({lo},{hi},{p}), \
                                     want ({wl},{wh},{wp})"
                                ));
                            }
                        }
                        Ok(())
                    },
                );
            }
        }
    }

    #[test]
    fn stochastic_pair_brackets_and_clamps() {
        let cb = codebook(Mapping::Linear2, 4);
        let b = Boundaries::new(&cb);
        // interior point: bracketed, p is the distance fraction
        let x = 0.5 * (cb[4] + cb[5]);
        let (lo, hi, p) = b.stochastic_pair(x);
        assert_eq!((lo, hi), (4, 5));
        assert!((p - 0.5).abs() < 1e-6, "{p}");
        // exact hit rounds to itself with certainty
        let (lo, hi, p) = b.stochastic_pair(cb[7]);
        assert_eq!(hi, 7);
        assert!(p >= 1.0 || lo == hi, "lo={lo} hi={hi} p={p}");
        // out of range clamps
        assert_eq!(b.stochastic_pair(-2.0).0, 0);
        assert_eq!(b.stochastic_pair(2.0).1 as usize, cb.len() - 1);
    }
}
