//! `StateCodec` — the one quantized-state substrate both optimizer families
//! store through (paper §2.2/§3.3 + Li et al. 2023 "Memory Efficient
//! Optimizers with 4-bit States").
//!
//! A codec owns its codebook, block-wise encode/decode (reusing
//! `quant::blockwise` + `codebook::Boundaries`), exact `state_bytes`
//! accounting, and byte-level serialization: an [`EncodedVec`]'s `bytes` ARE
//! the checkpoint payload, so save → load round-trips are bit-exact by
//! construction (no requantization error on resume).
//!
//! Shipped codecs:
//!  * [`Fp32`] — identity storage (the 32-bit baseline arms);
//!  * [`Bf16`] — round-to-nearest-even truncation (16-bit dense states);
//!  * [`BlockQuant`] — block-64 absmax quantization against a DT / Linear-2 /
//!    linear codebook at 2–8 bits (`q4-linear2`, `q4-dt`, `q8-dt`, ...).
//!
//! Second-order `SideState` and every `FirstOrder` moment buffer hold
//! codec-encoded buffers; `codec_for` maps a (bits, mapping) policy to a
//! codec and `codec_by_name` resolves the names persisted in checkpoints.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use anyhow::{bail, Result};

use super::blockwise::{
    dequantize, matrix_layout, quantize, try_quantize, try_quantize_matrix_cols_with,
    try_quantize_stochastic, QuantizedVec, BLOCK,
};
use super::codebook::{codebook, Mapping};
use super::pack::{pack_bits, packed_len, unpack_bits};
use crate::util::rng::Rng;

/// A codec-encoded state buffer: opaque payload + element count. The byte
/// layout is the owning codec's contract; checkpoints persist `bytes`
/// verbatim.
#[derive(Debug, Clone, PartialEq)]
pub struct EncodedVec {
    /// The serialized payload (the checkpoint bytes).
    pub bytes: Vec<u8>,
    /// Element count of the decoded vector.
    pub len: usize,
}

/// Append an [`EncodedVec`] to `out` as a self-describing wire frame:
/// `len (u32 LE) | nbytes (u32 LE) | bytes`. This is the same framing
/// checkpoint side-state blobs use, reused verbatim as the inter-shard
/// message format — codec bytes ARE the wire format, so a frame costs
/// exactly what the state costs at rest.
pub fn put_frame(out: &mut Vec<u8>, e: &EncodedVec) {
    out.extend((e.len as u32).to_le_bytes());
    out.extend((e.bytes.len() as u32).to_le_bytes());
    out.extend_from_slice(&e.bytes);
}

/// Read one [`put_frame`]-encoded frame from `bytes` starting at `*off`,
/// advancing `*off` past it. Errors on truncated input.
pub fn read_frame(bytes: &[u8], off: &mut usize) -> anyhow::Result<EncodedVec> {
    fn take<'a>(bytes: &'a [u8], off: &mut usize, n: usize) -> anyhow::Result<&'a [u8]> {
        if bytes.len() < *off + n {
            anyhow::bail!("wire frame truncated at byte {}", *off);
        }
        let s = &bytes[*off..*off + n];
        *off += n;
        Ok(s)
    }
    let len = u32::from_le_bytes(take(bytes, off, 4)?.try_into().unwrap()) as usize;
    let nbytes = u32::from_le_bytes(take(bytes, off, 4)?.try_into().unwrap()) as usize;
    let payload = take(bytes, off, nbytes)?.to_vec();
    Ok(EncodedVec { bytes: payload, len })
}

// ---------------------------------------------------------------------------
// CRC-32 + checked frames (checkpoint integrity substrate)
// ---------------------------------------------------------------------------

/// CRC-32 (IEEE 802.3, reflected polynomial `0xEDB88320`) lookup table,
/// built at compile time — no dependencies, bit-stable across platforms.
const CRC32_TABLE: [u32; 256] = {
    let mut table = [0u32; 256];
    let mut i = 0usize;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
};

/// Streaming CRC-32 (IEEE) hasher. Checkpoint frames record one checksum
/// per buffer so a flipped bit is a descriptive error, never a silent
/// zero-decode; the streaming form lets the writer fold chunks in as they
/// are produced (no full-frame staging buffer).
#[derive(Debug, Clone)]
pub struct Crc32 {
    state: u32,
}

impl Crc32 {
    /// Fresh hasher (empty input hashes to 0).
    pub fn new() -> Self {
        Self { state: 0xFFFF_FFFF }
    }

    /// Fold `bytes` into the running checksum.
    pub fn update(&mut self, bytes: &[u8]) {
        let mut c = self.state;
        for &b in bytes {
            c = CRC32_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
        }
        self.state = c;
    }

    /// The checksum of everything folded in so far.
    pub fn finish(&self) -> u32 {
        self.state ^ 0xFFFF_FFFF
    }
}

impl Default for Crc32 {
    fn default() -> Self {
        Self::new()
    }
}

/// One-shot CRC-32 (IEEE) of `bytes` — `crc32(b"123456789") == 0xCBF43926`.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut h = Crc32::new();
    h.update(bytes);
    h.finish()
}

/// [`put_frame`] plus a trailing CRC-32 of the payload bytes (u32 LE):
/// `len | nbytes | bytes | crc32`. The checked form is for frames that
/// cross a trust boundary (files, wire hops that may be replayed later);
/// in-process shard traffic keeps the unchecked framing.
pub fn put_frame_checked(out: &mut Vec<u8>, e: &EncodedVec) {
    put_frame(out, e);
    out.extend(crc32(&e.bytes).to_le_bytes());
}

/// Read one [`put_frame_checked`] frame, verifying the trailing checksum.
/// Truncation and checksum mismatches are descriptive errors naming the
/// byte offset.
pub fn read_frame_checked(bytes: &[u8], off: &mut usize) -> anyhow::Result<EncodedVec> {
    let frame_at = *off;
    let e = read_frame(bytes, off)?;
    if bytes.len() < *off + 4 {
        anyhow::bail!("wire frame checksum truncated at byte {}", *off);
    }
    let want = u32::from_le_bytes(bytes[*off..*off + 4].try_into().unwrap());
    *off += 4;
    let found = crc32(&e.bytes);
    if found != want {
        anyhow::bail!(
            "wire frame at byte {frame_at} failed its checksum: \
             recorded {want:#010x}, computed {found:#010x}"
        );
    }
    Ok(e)
}

/// The byte ranges of a *flat* encoded payload that cover a requested
/// element range — the partial-decode contract behind checkpoint slice
/// serving. The `ranges`, concatenated in order, form a standalone payload
/// for `elem_count` elements starting at `elem_start` (block codecs round
/// the request out to whole blocks), decodable with the stock
/// [`StateCodec::decode`].
#[derive(Debug, Clone, PartialEq)]
pub struct SliceRanges {
    /// Byte ranges into the payload, in concatenation order.
    pub ranges: Vec<std::ops::Range<usize>>,
    /// First element the concatenated ranges decode (≤ requested start).
    pub elem_start: usize,
    /// Elements the concatenated ranges decode (≥ requested count).
    pub elem_count: usize,
}

impl SliceRanges {
    /// Total bytes across all ranges.
    pub fn total_bytes(&self) -> usize {
        self.ranges.iter().map(|r| r.len()).sum()
    }
}

/// Pluggable storage codec for optimizer state vectors.
///
/// Encode → decode round-trips are the storage algorithm itself: exact for
/// [`Fp32`], rounding for [`Bf16`], block-wise codebook quantization for
/// [`BlockQuant`]. An [`EncodedVec`]'s bytes ARE the checkpoint payload.
///
/// ```
/// use shampoo4::quant::{codec_for, Mapping, StateCodec};
///
/// // fp32 is the identity codec: exact, 4 bytes per element
/// let fp32 = codec_for(32, Mapping::Dt);
/// let x = vec![0.25f32, -3.5, 0.0, 7.125];
/// let enc = fp32.encode(&x);
/// assert_eq!(enc.bytes.len(), fp32.state_bytes(x.len()));
/// assert_eq!(fp32.decode(&enc), x);
///
/// // a quantized codec round-trips within its published resolution bound
/// let q4 = codec_for(4, Mapping::Linear2);
/// let enc = q4.encode(&x);
/// assert_eq!(enc.bytes.len(), q4.state_bytes(x.len()));
/// let absmax = 7.125f32;
/// for (orig, back) in x.iter().zip(q4.decode(&enc)) {
///     assert!((orig - back).abs() <= q4.resolution(absmax));
/// }
/// ```
pub trait StateCodec: Send + Sync {
    /// Stable identifier persisted in checkpoints ("fp32", "bf16",
    /// "q4-linear2", ...). `codec_by_name` must round-trip it.
    fn name(&self) -> String;

    /// Storage bits per element (excluding per-block scale overhead).
    fn bits(&self) -> u32;

    /// Exact serialized bytes for a `len`-element buffer — must equal
    /// `encode(x).bytes.len()` for any `x` of that length.
    fn state_bytes(&self, len: usize) -> usize;

    /// Encode a vector into this codec's storage format.
    ///
    /// Quantized codecs panic on non-finite input (silently corrupting the
    /// block is never acceptable); use [`StateCodec::try_encode`] where the
    /// caller can handle the error instead.
    fn encode(&self, x: &[f32]) -> EncodedVec;

    /// Fallible [`StateCodec::encode`]: quantized codecs return a
    /// [`QuantError::NonFinite`](super::QuantError) instead of panicking
    /// when the input contains NaN/±Inf. Exact codecs never fail.
    fn try_encode(&self, x: &[f32]) -> Result<EncodedVec> {
        Ok(self.encode(x))
    }

    /// Decode a payload produced by [`StateCodec::encode`].
    fn decode(&self, e: &EncodedVec) -> Vec<f32>;

    /// Validate a serialized payload before adopting it (checkpoint
    /// ingest): structural length, code range against the codebook, scale
    /// finiteness. The default is the exact dense-length check; codebook
    /// codecs override with the full check so a corrupted byte is a
    /// descriptive error instead of silently decoding to 0.0 (the decode
    /// table is zero-padded to 256 entries).
    fn validate_payload(&self, e: &EncodedVec) -> Result<()> {
        if e.bytes.len() != self.state_bytes(e.len) {
            bail!(
                "payload is {} bytes, codec {} expects {} for {} elems",
                e.bytes.len(),
                self.name(),
                self.state_bytes(e.len),
                e.len
            );
        }
        Ok(())
    }

    /// Upper bound on |decode(encode(x)) − x| for an element living in a
    /// block whose absmax is `absmax` (the codebook-resolution bound; exact
    /// codecs return 0).
    fn resolution(&self, absmax: f32) -> f32;

    /// The 16-entry runtime codebook fed to quantized artifacts; `None` for
    /// codecs with no artifact-side codebook (dense, or bits outside the
    /// 3/4-bit kernel family).
    fn runtime_codebook(&self) -> Option<&[f32]> {
        None
    }

    /// Encode an order-n matrix (row-major) with blocks running down columns
    /// (paper §3.3). Layout-agnostic codecs use plain `encode`.
    fn encode_matrix(&self, a: &[f32], n: usize) -> EncodedVec {
        debug_assert_eq!(a.len(), n * n);
        self.encode(a)
    }

    /// Exact serialized bytes for an `encode_matrix` payload of order n —
    /// column-blocked codecs clamp the block to the order, so this can
    /// differ from `state_bytes(n * n)` when n is smaller than the block.
    fn matrix_state_bytes(&self, n: usize) -> usize {
        self.state_bytes(n * n)
    }

    /// Inverse of `encode_matrix`: row-major order-n matrix.
    fn decode_matrix(&self, e: &EncodedVec, n: usize) -> Vec<f32> {
        debug_assert_eq!(e.len, n * n);
        self.decode(e)
    }

    /// Byte ranges of a flat `len`-element payload that cover elements
    /// `[start, start + count)` — see [`SliceRanges`]. The default is the
    /// whole payload (always correct); exact codecs narrow to the precise
    /// byte span and block codecs to the covering blocks. Only valid for
    /// *flat* payloads ([`StateCodec::encode`] layouts) — column-blocked
    /// [`StateCodec::encode_matrix`] payloads interleave blocks per column
    /// and are not sliceable.
    fn slice_ranges(&self, len: usize, start: usize, count: usize) -> SliceRanges {
        debug_assert!(start + count <= len);
        let _ = (start, count);
        SliceRanges { ranges: vec![0..self.state_bytes(len)], elem_start: 0, elem_count: len }
    }

    /// Decode elements `[start, start + count)` of a flat payload via
    /// [`StateCodec::slice_ranges`] — bit-identical to slicing a full
    /// [`StateCodec::decode`], touching only the covering bytes.
    fn decode_range(&self, e: &EncodedVec, start: usize, count: usize) -> Vec<f32> {
        if count == 0 {
            return Vec::new();
        }
        let sr = self.slice_ranges(e.len, start, count);
        let mut bytes = Vec::with_capacity(sr.total_bytes());
        for r in &sr.ranges {
            bytes.extend_from_slice(&e.bytes[r.clone()]);
        }
        let sub = EncodedVec { bytes, len: sr.elem_count };
        let local = start - sr.elem_start;
        self.decode(&sub)[local..local + count].to_vec()
    }

    /// Split an encoded buffer into the artifact boundary format: codes
    /// one-per-byte, per-block scales, and the block size. Only meaningful
    /// for codebook codecs.
    fn to_artifact(&self, _e: &EncodedVec) -> Result<(Vec<u8>, Vec<f32>, usize)> {
        bail!("codec {} has no artifact code representation", self.name())
    }

    /// Rebuild an encoded buffer from artifact outputs (codes one-per-byte,
    /// per-block scales).
    fn from_artifact(&self, _codes: &[u8], _scales: &[f32]) -> Result<EncodedVec> {
        bail!("codec {} has no artifact code representation", self.name())
    }
}

// ---------------------------------------------------------------------------

/// Identity storage: 4 bytes per element, exact round-trip.
pub struct Fp32;

impl StateCodec for Fp32 {
    fn name(&self) -> String {
        "fp32".into()
    }

    fn bits(&self) -> u32 {
        32
    }

    fn state_bytes(&self, len: usize) -> usize {
        len * 4
    }

    fn encode(&self, x: &[f32]) -> EncodedVec {
        let mut bytes = Vec::with_capacity(x.len() * 4);
        for &v in x {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        EncodedVec { bytes, len: x.len() }
    }

    fn decode(&self, e: &EncodedVec) -> Vec<f32> {
        e.bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect()
    }

    fn resolution(&self, _absmax: f32) -> f32 {
        0.0
    }

    fn slice_ranges(&self, len: usize, start: usize, count: usize) -> SliceRanges {
        debug_assert!(start + count <= len);
        SliceRanges {
            ranges: vec![start * 4..(start + count) * 4],
            elem_start: start,
            elem_count: count,
        }
    }
}

/// Shared fp32 codec instance (the default first-order policy).
pub fn fp32() -> Arc<dyn StateCodec> {
    Arc::new(Fp32)
}

// ---------------------------------------------------------------------------

/// bfloat16 storage: round-to-nearest-even truncation of the high 16 bits.
pub struct Bf16;

#[inline]
fn f32_to_bf16(x: f32) -> u16 {
    let b = x.to_bits();
    if x.is_nan() {
        return ((b >> 16) as u16) | 0x40; // quiet, preserve sign
    }
    let rounded = b.wrapping_add(0x7FFF + ((b >> 16) & 1));
    (rounded >> 16) as u16
}

#[inline]
fn bf16_to_f32(v: u16) -> f32 {
    f32::from_bits((v as u32) << 16)
}

impl StateCodec for Bf16 {
    fn name(&self) -> String {
        "bf16".into()
    }

    fn bits(&self) -> u32 {
        16
    }

    fn state_bytes(&self, len: usize) -> usize {
        len * 2
    }

    fn encode(&self, x: &[f32]) -> EncodedVec {
        let mut bytes = Vec::with_capacity(x.len() * 2);
        for &v in x {
            bytes.extend_from_slice(&f32_to_bf16(v).to_le_bytes());
        }
        EncodedVec { bytes, len: x.len() }
    }

    fn decode(&self, e: &EncodedVec) -> Vec<f32> {
        e.bytes
            .chunks_exact(2)
            .map(|c| bf16_to_f32(u16::from_le_bytes(c.try_into().unwrap())))
            .collect()
    }

    fn resolution(&self, absmax: f32) -> f32 {
        // 7 mantissa bits: relative error ≤ 2^-8 after round-to-nearest
        absmax * (1.0 / 256.0) + f32::MIN_POSITIVE
    }

    fn slice_ranges(&self, len: usize, start: usize, count: usize) -> SliceRanges {
        debug_assert!(start + count <= len);
        SliceRanges {
            ranges: vec![start * 2..(start + count) * 2],
            elem_start: start,
            elem_count: count,
        }
    }
}

// ---------------------------------------------------------------------------

/// Block-wise absmax quantization against a sorted codebook — the paper's
/// storage scheme for both second-order sides and low-bit first-order
/// moments. Byte layout: packed codes at true bitwidth, then per-block f32
/// scales (LE). Trailing partial blocks carry their own scale.
pub struct BlockQuant {
    mapping: Mapping,
    bits: u32,
    block: usize,
    cb: Vec<f32>,
    /// 16-entry padded runtime codebook for the 3/4-bit artifact kernels.
    rcb: Option<Vec<f32>>,
}

impl BlockQuant {
    /// Block-64 codec for (mapping, bits).
    pub fn new(mapping: Mapping, bits: u32) -> Self {
        Self::with_block(mapping, bits, BLOCK)
    }

    /// Codec with an explicit block length (analyses only; the kernels
    /// assume block 64).
    pub fn with_block(mapping: Mapping, bits: u32, block: usize) -> Self {
        assert!((2..=8).contains(&bits), "block-quant supports 2..=8 bits, got {bits}");
        assert!(block >= 1);
        let cb = codebook(mapping, bits);
        let rcb = (bits == 3 || bits == 4)
            .then(|| super::codebook::runtime_codebook(mapping, bits));
        Self { mapping, bits, block, cb, rcb }
    }

    /// 8-bit codec (first-order moments, Dettmers et al. regime).
    pub fn q8(mapping: Mapping) -> Self {
        Self::new(mapping, 8)
    }

    /// The paper's default second-order codec (4-bit Linear-2).
    pub fn q4_linear2() -> Self {
        Self::new(Mapping::Linear2, 4)
    }

    /// 4-bit DT codec (first-order moments / ablations).
    pub fn q4_dt() -> Self {
        Self::new(Mapping::Dt, 4)
    }

    /// Block length of this codec.
    pub fn block(&self) -> usize {
        self.block
    }

    /// The sorted codebook values.
    pub fn codebook(&self) -> &[f32] {
        &self.cb
    }

    fn nblocks(&self, len: usize) -> usize {
        len.div_ceil(self.block)
    }

    fn to_quantized(&self, e: &EncodedVec) -> QuantizedVec {
        let split = packed_len(e.len, self.bits);
        QuantizedVec {
            packed: e.bytes[..split].to_vec(),
            scales: e.bytes[split..]
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                .collect(),
            len: e.len,
            bits: self.bits,
            block: self.block,
            col: None,
        }
    }

    fn from_quantized(&self, q: &QuantizedVec) -> EncodedVec {
        let mut bytes = Vec::with_capacity(q.packed.len() + q.scales.len() * 4);
        bytes.extend_from_slice(&q.packed);
        for &s in &q.scales {
            bytes.extend_from_slice(&s.to_le_bytes());
        }
        EncodedVec { bytes, len: q.len }
    }
}

impl StateCodec for BlockQuant {
    fn name(&self) -> String {
        format!("q{}-{}", self.bits, self.mapping.name())
    }

    fn bits(&self) -> u32 {
        self.bits
    }

    fn state_bytes(&self, len: usize) -> usize {
        packed_len(len, self.bits) + self.nblocks(len) * 4
    }

    fn encode(&self, x: &[f32]) -> EncodedVec {
        self.from_quantized(&quantize(x, &self.cb, self.bits, self.block))
    }

    fn try_encode(&self, x: &[f32]) -> Result<EncodedVec> {
        Ok(self.from_quantized(&try_quantize(x, &self.cb, self.bits, self.block)?))
    }

    fn decode(&self, e: &EncodedVec) -> Vec<f32> {
        dequantize(&self.to_quantized(e), &self.cb)
    }

    fn validate_payload(&self, e: &EncodedVec) -> Result<()> {
        // structural: packed codes, then whole little-endian f32 scales.
        // matrix payloads may carry more scales than the flat layout (the
        // block divides the order, or blocks restart per column), so the
        // check is layout-shape, not an exact byte count.
        let split = packed_len(e.len, self.bits);
        let min_scales = usize::from(e.len > 0);
        let structurally_ok =
            e.bytes.len() >= split + 4 * min_scales && (e.bytes.len() - split) % 4 == 0;
        if !structurally_ok {
            bail!(
                "payload is {} bytes, codec {} expects {} code bytes plus \
                 whole f32 scales for {} elems",
                e.bytes.len(),
                self.name(),
                split,
                e.len
            );
        }
        // code range: anything >= the codebook length would silently decode
        // through the zero-padded region of the 256-entry table as 0.0
        let codes = unpack_bits(&e.bytes[..split], self.bits, e.len);
        if let Some((i, &c)) =
            codes.iter().enumerate().find(|(_, &c)| (c as usize) >= self.cb.len())
        {
            bail!(
                "corrupt payload: code {c} at element {i} out of range for \
                 codec {} ({} codebook entries)",
                self.name(),
                self.cb.len()
            );
        }
        // scales: a NaN/Inf scale corrupts its whole block on decode
        for (bi, chunk) in e.bytes[split..].chunks_exact(4).enumerate() {
            let s = f32::from_le_bytes(chunk.try_into().unwrap());
            if !s.is_finite() {
                bail!(
                    "corrupt payload: non-finite scale {s} in block {bi} \
                     (codec {})",
                    self.name()
                );
            }
        }
        Ok(())
    }

    fn resolution(&self, absmax: f32) -> f32 {
        let max_gap = self.cb.windows(2).map(|w| w[1] - w[0]).fold(0.0f32, f32::max);
        let scale = if absmax > 0.0 { absmax } else { 1.0 };
        0.5 * max_gap * scale + 1e-6
    }

    fn runtime_codebook(&self) -> Option<&[f32]> {
        self.rcb.as_deref()
    }

    /// Covering blocks: code bytes for the block-aligned element span, plus
    /// their per-block scales. Sound because block boundaries land on byte
    /// boundaries whenever `block × bits` is a whole number of bytes — true
    /// for the stock block (64) at every supported bitwidth. Non-aligned
    /// custom blocks fall back to the whole payload.
    fn slice_ranges(&self, len: usize, start: usize, count: usize) -> SliceRanges {
        debug_assert!(start + count <= len);
        if (self.block * self.bits as usize) % 8 != 0 || count == 0 {
            return SliceRanges {
                ranges: vec![0..self.state_bytes(len)],
                elem_start: 0,
                elem_count: len,
            };
        }
        let bytes_per_block = self.block * self.bits as usize / 8;
        let b0 = start / self.block;
        let b1 = (start + count).div_ceil(self.block).min(self.nblocks(len));
        let elem_start = b0 * self.block;
        let elem_count = (b1 * self.block).min(len) - elem_start;
        let split = packed_len(len, self.bits);
        let code_start = b0 * bytes_per_block;
        SliceRanges {
            ranges: vec![
                code_start..code_start + packed_len(elem_count, self.bits),
                split + b0 * 4..split + b1 * 4,
            ],
            elem_start,
            elem_count,
        }
    }

    fn matrix_state_bytes(&self, n: usize) -> usize {
        super::blockwise::matrix_state_bytes(n, self.bits, self.block)
    }

    /// §3.3: blocks run down columns, so encode the transpose's rows. The
    /// block layout follows [`matrix_layout`] — identical to
    /// [`quantize_matrix_cols`](super::quantize_matrix_cols) on every
    /// order, including non-multiples of the block length.
    fn encode_matrix(&self, a: &[f32], n: usize) -> EncodedVec {
        debug_assert_eq!(a.len(), n * n);
        self.from_quantized(
            &try_quantize_matrix_cols_with(a, n, &self.cb, self.bits, self.block)
                .unwrap_or_else(|e| panic!("{e}")),
        )
    }

    fn decode_matrix(&self, e: &EncodedVec, n: usize) -> Vec<f32> {
        debug_assert_eq!(e.len, n * n);
        let mut q = self.to_quantized(e);
        let (block, col) = matrix_layout(n, self.block);
        q.block = block;
        q.col = col;
        let t = dequantize(&q, &self.cb);
        let mut a = vec![0.0f32; n * n];
        for j in 0..n {
            for i in 0..n {
                a[i * n + j] = t[j * n + i];
            }
        }
        a
    }

    fn to_artifact(&self, e: &EncodedVec) -> Result<(Vec<u8>, Vec<f32>, usize)> {
        let q = self.to_quantized(e);
        // the artifact boundary is a rectangular (nblocks, block) code grid,
        // so the buffer must have no partial trailing block
        let nb = q.scales.len();
        if nb == 0 || e.len % nb != 0 {
            bail!("encoded length {} has no uniform block layout", e.len);
        }
        let block = e.len / nb;
        Ok((unpack_bits(&q.packed, self.bits, e.len), q.scales, block))
    }

    fn from_artifact(&self, codes: &[u8], scales: &[f32]) -> Result<EncodedVec> {
        if let Some(&c) = codes.iter().find(|&&c| (c as usize) >= (1usize << self.bits)) {
            bail!("code {c} out of range for {}-bit codec", self.bits);
        }
        let mut bytes = pack_bits(codes, self.bits);
        bytes.reserve(scales.len() * 4);
        for &s in scales {
            bytes.extend_from_slice(&s.to_le_bytes());
        }
        Ok(EncodedVec { bytes, len: codes.len() })
    }
}

// ---------------------------------------------------------------------------

/// Stochastic-rounding wrapper over a [`BlockQuant`] codec (SOLO, "Pushing
/// the Limits of Low-Bit Optimizers"): `encode` rounds each normalized value
/// *up* to its bracketing codebook entry with probability equal to the
/// distance fraction, so the expected dequantized value equals the input —
/// the property that keeps low-bit EMA dynamics unbiased. `decode` is the
/// inner codec's deterministic decode, so checkpoint payloads still restore
/// bit-exactly.
///
/// Reproducibility: the wrapper owns a seed and an encode-call counter; call
/// k draws from the derived stream `Rng::new(seed).fork(k)`
/// (`util/rng.rs`), so a fixed seed replays the exact rounding sequence —
/// two runs with the same seed and the same encode order are bit-identical.
/// The counter is in-memory state, so a *resumed* run continues
/// deterministically but draws a fresh stream rather than replaying the
/// interrupted one.
///
/// Under `--features simd` the encode dispatches through the SIMD lane
/// registry (`try_quantize_stochastic` resolves the active lane): the
/// bracket+fraction pass is vectorized per block while the RNG draw stays
/// with the caller in element order, so every lane — and the scalar
/// fallback — replays the identical seeded stream and produces identical
/// bytes.
pub struct StochasticRound {
    inner: BlockQuant,
    seed: u64,
    calls: AtomicU64,
}

impl StochasticRound {
    /// Stochastic-rounding block codec for (mapping, bits), seeded per
    /// buffer by the codec policy layer.
    pub fn new(mapping: Mapping, bits: u32, seed: u64) -> Self {
        Self::wrap(BlockQuant::new(mapping, bits), seed)
    }

    /// Wrap an existing [`BlockQuant`] codec.
    pub fn wrap(inner: BlockQuant, seed: u64) -> Self {
        Self { inner, seed, calls: AtomicU64::new(0) }
    }

    /// One encode call = one derived rounding stream; the call counter
    /// advances exactly once whether the encode succeeds or fails.
    fn encode_inner(&self, x: &[f32]) -> Result<EncodedVec> {
        // ordering: Relaxed — a monotone stream counter; each caller only
        // needs a unique k, never agreement on who got which k first
        let k = self.calls.fetch_add(1, Ordering::Relaxed);
        let mut base = Rng::new(self.seed);
        let mut rng = base.fork(k);
        Ok(self.inner.from_quantized(&try_quantize_stochastic(
            x,
            &self.inner.cb,
            self.inner.bits,
            self.inner.block,
            &mut rng,
        )?))
    }
}

impl StateCodec for StochasticRound {
    fn name(&self) -> String {
        format!("{}-sr", self.inner.name())
    }

    fn bits(&self) -> u32 {
        self.inner.bits()
    }

    fn state_bytes(&self, len: usize) -> usize {
        self.inner.state_bytes(len)
    }

    fn encode(&self, x: &[f32]) -> EncodedVec {
        self.encode_inner(x).unwrap_or_else(|e| panic!("{e}"))
    }

    fn try_encode(&self, x: &[f32]) -> Result<EncodedVec> {
        self.encode_inner(x)
    }

    fn decode(&self, e: &EncodedVec) -> Vec<f32> {
        self.inner.decode(e)
    }

    fn validate_payload(&self, e: &EncodedVec) -> Result<()> {
        self.inner.validate_payload(e)
    }

    fn slice_ranges(&self, len: usize, start: usize, count: usize) -> SliceRanges {
        self.inner.slice_ranges(len, start, count)
    }

    fn resolution(&self, absmax: f32) -> f32 {
        // stochastic rounding can land on the *far* neighbour, so the bound
        // is the full codebook gap, not half of it
        let max_gap =
            self.inner.cb.windows(2).map(|w| w[1] - w[0]).fold(0.0f32, f32::max);
        let scale = if absmax > 0.0 { absmax } else { 1.0 };
        max_gap * scale + 1e-6
    }
}

// ---------------------------------------------------------------------------

/// The registry's valid codec names, spelled out for error messages —
/// unknown `bits` / `mapping` / policy entries point here instead of failing
/// with a bare "unknown codec".
pub const CODEC_REGISTRY_HELP: &str = "valid codecs: fp32, bf16, and q<bits>-<mapping> \
    with bits 2..=8 and mapping one of dt, linear2, linear (e.g. q4-linear2, q8-dt), \
    plus an optional -sr suffix for stochastic rounding (e.g. q4-dt-sr)";

/// Codec for a (bits, mapping) storage policy: 32 → `Fp32`, 16 → `Bf16`,
/// else block-wise quantization at that bitwidth.
pub fn codec_for(bits: u32, mapping: Mapping) -> Arc<dyn StateCodec> {
    match bits {
        32 => Arc::new(Fp32),
        16 => Arc::new(Bf16),
        b => Arc::new(BlockQuant::new(mapping, b)),
    }
}

/// Resolve a codec name persisted in a checkpoint ("fp32", "bf16",
/// "q4-linear2", "q8-dt", ...).
///
/// Round-trips [`StateCodec::name`], and the resolved codec decodes
/// payloads encoded by the original bit-exactly:
///
/// ```
/// use shampoo4::quant::{codec_for, codec_by_name, Mapping, StateCodec};
///
/// let q4 = codec_for(4, Mapping::Linear2);
/// let enc = q4.encode(&[1.0, -0.5, 0.25]);
/// let restored = codec_by_name(&q4.name()).unwrap();
/// assert_eq!(restored.name(), "q4-linear2");
/// assert_eq!(restored.decode(&enc), q4.decode(&enc));
/// assert!(codec_by_name("q9-martian").is_err());
/// ```
pub fn codec_by_name(name: &str) -> Result<Arc<dyn StateCodec>> {
    let (base, stochastic) = match name.strip_suffix("-sr") {
        Some(b) => (b, true),
        None => (name, false),
    };
    match base {
        "fp32" | "bf16" if stochastic => bail!(
            "state codec {name:?}: stochastic rounding applies to block-quant codecs \
             only; {CODEC_REGISTRY_HELP}"
        ),
        "fp32" => Ok(Arc::new(Fp32)),
        "bf16" => Ok(Arc::new(Bf16)),
        other => {
            let unknown =
                || anyhow::anyhow!("unknown state codec {name:?}; {CODEC_REGISTRY_HELP}");
            let rest = other.strip_prefix('q').ok_or_else(unknown)?;
            let (bits_s, map_s) = rest.split_once('-').ok_or_else(unknown)?;
            let bits: u32 = bits_s.parse().map_err(|_| unknown())?;
            let mapping = Mapping::parse(map_s).ok_or_else(unknown)?;
            if !(2..=8).contains(&bits) {
                bail!("state codec {name:?}: bits out of range; {CODEC_REGISTRY_HELP}");
            }
            if stochastic {
                // checkpoint restores only decode, which is deterministic;
                // the policy layer re-seeds live buffers itself
                Ok(Arc::new(StochasticRound::new(mapping, bits, 0)))
            } else {
                Ok(Arc::new(BlockQuant::new(mapping, bits)))
            }
        }
    }
}

// ---------------------------------------------------------------------------

/// A mutable f32 state vector that lives codec-encoded between uses — the
/// storage cell every `FirstOrder` moment buffer is built on.
pub struct StateBuf {
    codec: Arc<dyn StateCodec>,
    enc: EncodedVec,
}

impl StateBuf {
    /// Zero-initialized buffer of `n` elements.
    pub fn zeros(n: usize, codec: Arc<dyn StateCodec>) -> Self {
        let enc = codec.encode(&vec![0.0f32; n]);
        Self { codec, enc }
    }

    /// Element count.
    pub fn len(&self) -> usize {
        self.enc.len
    }

    /// True when the buffer has no elements.
    pub fn is_empty(&self) -> bool {
        self.enc.len == 0
    }

    /// The owning codec.
    pub fn codec(&self) -> &Arc<dyn StateCodec> {
        &self.codec
    }

    /// The live encoded payload (what a checkpoint persists).
    pub fn encoded(&self) -> &EncodedVec {
        &self.enc
    }

    /// Decode to a working f32 vector.
    pub fn load(&self) -> Vec<f32> {
        self.codec.decode(&self.enc)
    }

    /// Re-encode a working vector back into storage.
    pub fn store(&mut self, x: &[f32]) {
        debug_assert_eq!(x.len(), self.enc.len);
        self.enc = self.codec.encode(x);
    }

    /// Exact storage bytes (the Table 2/13 memory accounting).
    pub fn state_bytes(&self) -> usize {
        self.enc.bytes.len()
    }

    /// Adopt a serialized payload (checkpoint restore). The caller vouches
    /// that `codec_name` matched; lengths are validated here.
    pub fn restore(&mut self, enc: EncodedVec) -> Result<()> {
        if enc.len != self.enc.len {
            bail!("state buffer has {} elems, expected {}", enc.len, self.enc.len);
        }
        if enc.bytes.len() != self.codec.state_bytes(enc.len) {
            bail!(
                "state buffer payload is {} bytes, codec {} expects {}",
                enc.bytes.len(),
                self.codec.name(),
                self.codec.state_bytes(enc.len)
            );
        }
        self.codec.validate_payload(&enc)?;
        self.enc = enc;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn codecs() -> Vec<Arc<dyn StateCodec>> {
        vec![
            Arc::new(Fp32) as Arc<dyn StateCodec>,
            Arc::new(Bf16),
            Arc::new(BlockQuant::q8(Mapping::Dt)),
            Arc::new(BlockQuant::q4_linear2()),
            Arc::new(BlockQuant::q4_dt()),
            Arc::new(BlockQuant::new(Mapping::Linear2, 3)),
        ]
    }

    #[test]
    fn names_round_trip_through_registry() {
        for c in codecs() {
            let back = codec_by_name(&c.name()).unwrap();
            assert_eq!(back.name(), c.name());
            assert_eq!(back.bits(), c.bits());
        }
        assert!(codec_by_name("q9-dt").is_err());
        assert!(codec_by_name("q4-bogus").is_err());
        assert!(codec_by_name("int8").is_err());
    }

    #[test]
    fn unknown_codec_errors_list_the_registry() {
        for bad in ["int8", "q9-dt", "q4-bogus", "fp32-sr"] {
            let err = codec_by_name(bad).unwrap_err().to_string();
            assert!(
                err.contains("fp32") && err.contains("q4-linear2") && err.contains("-sr"),
                "{bad}: error does not name the valid codecs: {err}"
            );
        }
    }

    #[test]
    fn stochastic_round_names_and_restores() {
        let sr = StochasticRound::new(Mapping::Dt, 4, 7);
        assert_eq!(sr.name(), "q4-dt-sr");
        assert_eq!(sr.bits(), 4);
        let mut rng = Rng::new(1);
        let x: Vec<f32> = (0..130).map(|_| rng.normal_f32()).collect();
        let enc = sr.encode(&x);
        assert_eq!(enc.bytes.len(), sr.state_bytes(x.len()));
        // decode is deterministic: the registry codec (any seed) restores
        // the payload bit-exactly
        let restored = codec_by_name("q4-dt-sr").unwrap();
        assert_eq!(restored.name(), "q4-dt-sr");
        let a = sr.decode(&enc);
        let b = restored.decode(&enc);
        assert_eq!(
            a.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            b.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
        // error stays within the published (full-gap) bound
        for (orig, back) in x.iter().zip(&a) {
            let absmax = x.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
            assert!((orig - back).abs() <= sr.resolution(absmax), "{orig} vs {back}");
        }
    }

    #[test]
    fn stochastic_round_fixed_seed_replays_exactly() {
        let mut rng = Rng::new(2);
        let x: Vec<f32> = (0..256).map(|_| rng.normal_f32()).collect();
        let a = StochasticRound::new(Mapping::Linear2, 4, 42);
        let b = StochasticRound::new(Mapping::Linear2, 4, 42);
        // same seed, same call sequence → identical bytes, call after call
        for _ in 0..5 {
            assert_eq!(a.encode(&x).bytes, b.encode(&x).bytes);
        }
        // successive calls draw fresh streams (the EMA sees fresh noise)...
        let c = StochasticRound::new(Mapping::Linear2, 4, 42);
        let first = c.encode(&x).bytes;
        let second = c.encode(&x).bytes;
        assert_ne!(first, second, "per-call streams must differ");
        // ...and different seeds give different streams
        let d = StochasticRound::new(Mapping::Linear2, 4, 43);
        assert_ne!(first, d.encode(&x).bytes);
    }

    #[test]
    fn fp32_is_exact_and_bit_stable() {
        let mut rng = Rng::new(1);
        let x: Vec<f32> = (0..97).map(|_| rng.normal_f32()).collect();
        let e = Fp32.encode(&x);
        assert_eq!(e.bytes.len(), Fp32.state_bytes(x.len()));
        let d = Fp32.decode(&e);
        assert_eq!(
            x.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            d.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn bf16_error_within_relative_bound() {
        let mut rng = Rng::new(2);
        for _ in 0..200 {
            let x = rng.normal_f32() * 10.0;
            let e = Bf16.encode(&[x]);
            let d = Bf16.decode(&e)[0];
            assert!((x - d).abs() <= Bf16.resolution(x.abs()), "{x} vs {d}");
        }
        // bf16 representables round-trip exactly
        for x in [0.0f32, 1.0, -2.5, 0.15625] {
            assert_eq!(Bf16.decode(&Bf16.encode(&[x]))[0], x);
        }
    }

    #[test]
    #[cfg_attr(miri, ignore)] // property sweep: too slow under Miri's interpreter
    fn matrix_roundtrip_keeps_column_blocking() {
        // a huge entry in column 0 must not pollute other columns
        let c = BlockQuant::q4_linear2();
        let n = 64;
        let mut a = vec![0.01f32; n * n];
        a[0] = 100.0;
        let e = c.encode_matrix(&a, n);
        let d = c.decode_matrix(&e, n);
        for i in 0..n {
            for j in 1..n {
                assert!((d[i * n + j] - 0.01).abs() < 0.005, "({i},{j})");
            }
        }
    }

    #[test]
    #[cfg_attr(miri, ignore)] // property sweep: too slow under Miri's interpreter
    fn artifact_boundary_round_trips() {
        let mut rng = Rng::new(3);
        let c = BlockQuant::q4_dt();
        let x: Vec<f32> = (0..256).map(|_| rng.normal_f32()).collect();
        let e = c.encode(&x);
        let (codes, scales, block) = c.to_artifact(&e).unwrap();
        assert_eq!(codes.len(), 256);
        assert_eq!(scales.len(), 4);
        assert_eq!(block, 64);
        let back = c.from_artifact(&codes, &scales).unwrap();
        assert_eq!(back, e);
        assert!(c.from_artifact(&[16u8], &[1.0]).is_err(), "out-of-range code");
    }

    #[test]
    fn runtime_codebooks_only_for_kernel_bitwidths() {
        assert!(BlockQuant::q4_dt().runtime_codebook().is_some());
        assert!(BlockQuant::new(Mapping::Dt, 3).runtime_codebook().is_some());
        assert!(BlockQuant::q8(Mapping::Dt).runtime_codebook().is_none());
        assert!(Fp32.runtime_codebook().is_none());
        assert!(Bf16.runtime_codebook().is_none());
    }

    #[test]
    fn crc32_known_vectors_and_streaming() {
        // the canonical IEEE check value
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
        // streaming over any chunking matches the one-shot hash
        let data: Vec<u8> = (0..=255u8).cycle().take(1000).collect();
        let want = crc32(&data);
        for split in [0usize, 1, 7, 500, 999, 1000] {
            let mut h = Crc32::new();
            h.update(&data[..split]);
            h.update(&data[split..]);
            assert_eq!(h.finish(), want, "split {split}");
        }
    }

    #[test]
    fn checked_frames_round_trip_and_reject_corruption() {
        let q4 = BlockQuant::q4_linear2();
        let mut rng = Rng::new(5);
        let x: Vec<f32> = (0..130).map(|_| rng.normal_f32()).collect();
        let e = q4.encode(&x);
        let mut wire = Vec::new();
        put_frame_checked(&mut wire, &e);
        let mut off = 0;
        let back = read_frame_checked(&wire, &mut off).unwrap();
        assert_eq!(off, wire.len());
        assert_eq!(back, e);
        // flip any payload byte → checksum error naming the offset
        for i in 8..wire.len() - 4 {
            let mut bad = wire.clone();
            bad[i] ^= 0x40;
            let mut off = 0;
            let err = read_frame_checked(&bad, &mut off).unwrap_err().to_string();
            assert!(err.contains("checksum"), "byte {i}: {err}");
        }
        // truncating the checksum itself is an error too
        let mut off = 0;
        assert!(read_frame_checked(&wire[..wire.len() - 2], &mut off).is_err());
    }

    #[test]
    #[cfg_attr(miri, ignore)] // property sweep: too slow under Miri's interpreter
    fn decode_range_matches_full_decode() {
        let mut rng = Rng::new(6);
        let mut all: Vec<Arc<dyn StateCodec>> = codecs();
        all.push(Arc::new(BlockQuant::new(Mapping::Dt, 2)));
        all.push(Arc::new(StochasticRound::new(Mapping::Dt, 4, 9)));
        for codec in all {
            for len in [1usize, 5, 63, 64, 65, 130, 257] {
                let x: Vec<f32> = (0..len).map(|_| rng.normal_f32()).collect();
                let e = codec.encode(&x);
                let full = codec.decode(&e);
                for (start, count) in
                    [(0, len), (0, 1), (len - 1, 1), (len / 3, len - len / 3), (len / 2, 0)]
                {
                    let got = codec.decode_range(&e, start, count);
                    let want = &full[start..start + count];
                    assert_eq!(
                        got.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                        want.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                        "codec {} len {len} start {start} count {count}",
                        codec.name()
                    );
                    if count > 0 {
                        let sr = codec.slice_ranges(len, start, count);
                        assert!(sr.total_bytes() <= e.bytes.len());
                        assert!(sr.elem_start <= start);
                        assert!(sr.elem_start + sr.elem_count >= start + count);
                    }
                }
            }
        }
    }

    #[test]
    fn slice_ranges_narrow_to_covering_blocks() {
        // one mid-payload element of a q4 block-64 buffer needs one block of
        // code bytes (32) + one scale (4), not the whole 2.5 KB payload
        let q4 = BlockQuant::q4_linear2();
        let sr = q4.slice_ranges(4096, 100, 1);
        assert_eq!(sr.elem_start, 64);
        assert_eq!(sr.elem_count, 64);
        assert_eq!(sr.total_bytes(), 32 + 4);
        // exact codecs narrow to the exact span
        let sr = Fp32.slice_ranges(1000, 10, 2);
        assert_eq!(sr.total_bytes(), 8);
        // non-byte-aligned custom blocks fall back to the whole payload
        let odd = BlockQuant::with_block(Mapping::Dt, 3, 5);
        let sr = odd.slice_ranges(50, 10, 2);
        assert_eq!(sr.elem_count, 50);
        assert_eq!(sr.total_bytes(), odd.state_bytes(50));
    }

    #[test]
    #[cfg_attr(miri, ignore)] // property sweep: too slow under Miri's interpreter
    fn statebuf_store_load_and_restore() {
        let mut rng = Rng::new(4);
        let mut b = StateBuf::zeros(130, codec_for(4, Mapping::Dt));
        assert!(b.load().iter().all(|&v| v == 0.0), "zeros must decode to zeros");
        let x: Vec<f32> = (0..130).map(|_| rng.normal_f32()).collect();
        b.store(&x);
        assert_eq!(b.state_bytes(), b.codec().state_bytes(130));
        let snap = b.encoded().clone();
        let mut b2 = StateBuf::zeros(130, codec_for(4, Mapping::Dt));
        b2.restore(snap).unwrap();
        assert_eq!(b.load(), b2.load());
        assert!(b2.restore(EncodedVec { bytes: vec![0; 3], len: 130 }).is_err());
        assert!(b2.restore(EncodedVec { bytes: vec![], len: 0 }).is_err());
    }
}
