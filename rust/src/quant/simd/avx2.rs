//! 256-bit AVX2 kernels (`std::arch::x86_64`), selected at runtime by
//! the lane registry via `is_x86_feature_detected!("avx2")`.
//!
//! Safety pattern: AVX2 is *not* part of the x86_64 baseline, so every
//! public entry point is a safe wrapper that asserts the (std-cached)
//! CPUID probe before calling a single `#[target_feature(enable =
//! "avx2")]` kernel. The dispatcher only routes here when the registry
//! detected AVX2, but the assert keeps the wrappers sound even for a
//! caller that forces the lane on the wrong host.
//!
//! Bit-identity mirrors the SSE2 lane: abs/max/mul/cmp are elementwise
//! or order-insensitive, the counting kernel's saturating packs are
//! exact on 0/-1 masks (with one dword permute undoing the per-128-bit
//! lane interleave the 256-bit packs introduce), and the decode gather
//! reads the same table entries the scalar loop would.

use std::arch::x86_64::*;

/// Panic unless the host really has AVX2 (std caches the CPUID probe,
/// so this is one atomic load on the hot path).
#[inline]
fn require_avx2() {
    assert!(
        std::arch::is_x86_feature_detected!("avx2"),
        "AVX2 lane dispatched on a host without AVX2 (set {}=sse2 or scalar)",
        super::LANE_ENV
    );
}

/// AVX2 arm of [`absmax`](super::absmax): 8-wide `andnot(-0.0)` + `max`.
pub(super) fn absmax(xs: &[f32]) -> f32 {
    require_avx2();
    // SAFETY: AVX2 presence was just asserted by `require_avx2`,
    // satisfying the kernel's target-feature contract; the in-bounds
    // reasoning lives on the kernel itself.
    unsafe { absmax_avx2(xs) }
}

// SAFETY: caller must guarantee AVX2 is available (the safe wrapper
// asserts it); every 8-wide `loadu` reads xs[i..i+8] under the
// `i + 8 <= xs.len()` guard and tolerates any alignment.
#[target_feature(enable = "avx2")]
unsafe fn absmax_avx2(xs: &[f32]) -> f32 {
    let signbit = _mm256_set1_ps(-0.0);
    let mut m = _mm256_setzero_ps();
    let mut i = 0usize;
    while i + 8 <= xs.len() {
        let v = _mm256_loadu_ps(xs.as_ptr().add(i));
        m = _mm256_max_ps(m, _mm256_andnot_ps(signbit, v));
        i += 8;
    }
    // horizontal max: 256 → 128 → scalar (max is order-insensitive)
    let m4 = _mm_max_ps(_mm256_castps256_ps128(m), _mm256_extractf128_ps::<1>(m));
    let m4 = _mm_max_ps(m4, _mm_movehl_ps(m4, m4));
    let m4 = _mm_max_ss(m4, _mm_shuffle_ps::<0x55>(m4, m4));
    let mut r = _mm_cvtss_f32(m4);
    for &v in &xs[i..] {
        r = r.max(v.abs());
    }
    r
}

/// AVX2 arm of [`all_finite`](super::all_finite): 8-wide `v * 0.0`
/// accumulation.
pub(super) fn all_finite(xs: &[f32]) -> bool {
    require_avx2();
    // SAFETY: AVX2 presence was just asserted by `require_avx2`.
    unsafe { all_finite_avx2(xs) }
}

// SAFETY: caller must guarantee AVX2 (the safe wrapper asserts it);
// unaligned 8-wide loads stay in bounds via the `i + 8 <= xs.len()`
// loop guard.
#[target_feature(enable = "avx2")]
unsafe fn all_finite_avx2(xs: &[f32]) -> bool {
    let zero = _mm256_setzero_ps();
    let mut acc = zero;
    let mut i = 0usize;
    while i + 8 <= xs.len() {
        let v = _mm256_loadu_ps(xs.as_ptr().add(i));
        acc = _mm256_add_ps(acc, _mm256_mul_ps(v, zero));
        i += 8;
    }
    // the sum is ±0.0 iff every lane was finite; add order is
    // irrelevant for that predicate (±0.0 sums stay ±0.0, NaN sticks)
    let a = _mm_add_ps(_mm256_castps256_ps128(acc), _mm256_extractf128_ps::<1>(acc));
    let a = _mm_add_ps(a, _mm_movehl_ps(a, a));
    let a = _mm_add_ss(a, _mm_shuffle_ps::<0x55>(a, a));
    let mut s = _mm_cvtss_f32(a);
    for &v in &xs[i..] {
        s += v * 0.0;
    }
    s == 0.0
}

/// AVX2 arm of [`normalize_into`](super::normalize_into): 8-wide
/// broadcast multiply.
pub(super) fn normalize_into(xs: &[f32], inv: f32, out: &mut [f32]) {
    debug_assert_eq!(xs.len(), out.len());
    require_avx2();
    // SAFETY: AVX2 presence was just asserted by `require_avx2`.
    unsafe { normalize_into_avx2(xs, inv, out) }
}

// SAFETY: caller must guarantee AVX2 (the safe wrapper asserts it);
// loads from `xs` and stores to `out` cover lanes [i, i+8) under
// `i + 8 <= xs.len()` with `out.len() == xs.len()` (debug-asserted by
// the wrapper's caller contract).
#[target_feature(enable = "avx2")]
unsafe fn normalize_into_avx2(xs: &[f32], inv: f32, out: &mut [f32]) {
    let iv = _mm256_set1_ps(inv);
    let mut i = 0usize;
    while i + 8 <= xs.len() {
        let v = _mm256_loadu_ps(xs.as_ptr().add(i));
        _mm256_storeu_ps(out.as_mut_ptr().add(i), _mm256_mul_ps(v, iv));
        i += 8;
    }
    for (o, &v) in out[i..].iter_mut().zip(&xs[i..]) {
        *o = v * inv;
    }
}

/// AVX2 arm of [`count_below_mids`](super::count_below_mids).
///
/// Lane layout: 32 elements per group held in four f32x8 registers;
/// per midpoint, four 8-wide `LT_OQ` masks are narrowed i32 → i16 → i8
/// (saturating packs are exact on 0/-1 masks) and subtracted from a
/// 32-lane u8 accumulator. The 256-bit packs interleave per 128-bit
/// lane, but identically on every midpoint iteration, so one dword
/// permute after the loop restores element order. The sub-32 tail
/// reuses the SSE2 kernel (16-wide + scalar).
pub(super) fn count_below_mids(mids: &[f32], xs: &[f32], codes: &mut [u8]) {
    debug_assert_eq!(xs.len(), codes.len());
    debug_assert!(mids.len() <= 255, "count must fit a u8 lane");
    require_avx2();
    // SAFETY: AVX2 presence was just asserted by `require_avx2`.
    unsafe { count_below_mids_avx2(mids, xs, codes) }
}

// SAFETY: caller must guarantee AVX2 (the safe wrapper asserts it);
// each iteration reads xs[i..i+32] and writes codes[i..i+32] under
// `i + 32 <= xs.len()` with `codes.len() == xs.len()` (debug-asserted
// by the wrapper); unaligned load/store intrinsics tolerate any
// alignment.
#[target_feature(enable = "avx2")]
unsafe fn count_below_mids_avx2(mids: &[f32], xs: &[f32], codes: &mut [u8]) {
    // The two pack stages leave the 32 accumulated bytes as dwords
    // [e0-3, e8-11, e16-19, e24-27 | e4-7, e12-15, e20-23, e28-31];
    // gathering dwords [0,4,1,5,2,6,3,7] restores element order.
    let fix = _mm256_setr_epi32(0, 4, 1, 5, 2, 6, 3, 7);
    let mut i = 0usize;
    while i + 32 <= xs.len() {
        let x0 = _mm256_loadu_ps(xs.as_ptr().add(i));
        let x1 = _mm256_loadu_ps(xs.as_ptr().add(i + 8));
        let x2 = _mm256_loadu_ps(xs.as_ptr().add(i + 16));
        let x3 = _mm256_loadu_ps(xs.as_ptr().add(i + 24));
        let mut acc = _mm256_setzero_si256();
        for &m in mids {
            let mv = _mm256_set1_ps(m);
            let c0 = _mm256_castps_si256(_mm256_cmp_ps::<_CMP_LT_OQ>(mv, x0));
            let c1 = _mm256_castps_si256(_mm256_cmp_ps::<_CMP_LT_OQ>(mv, x1));
            let c2 = _mm256_castps_si256(_mm256_cmp_ps::<_CMP_LT_OQ>(mv, x2));
            let c3 = _mm256_castps_si256(_mm256_cmp_ps::<_CMP_LT_OQ>(mv, x3));
            let lo = _mm256_packs_epi32(c0, c1);
            let hi = _mm256_packs_epi32(c2, c3);
            // 32 bytes of 0x00 / 0xFF; subtracting adds 1 per hit
            acc = _mm256_sub_epi8(acc, _mm256_packs_epi16(lo, hi));
        }
        let fixed = _mm256_permutevar8x32_epi32(acc, fix);
        _mm256_storeu_si256(codes.as_mut_ptr().add(i) as *mut __m256i, fixed);
        i += 32;
    }
    super::sse2::count_below_mids(mids, &xs[i..], &mut codes[i..]);
}

/// AVX2 4-bit pack: 32 codes → 16 bytes per step (same nibble algebra
/// as the SSE2 lane, one qword permute to undo the `packus` lane
/// interleave before the 16-byte store).
pub(super) fn pack4(codes: &[u8]) -> Vec<u8> {
    require_avx2();
    // SAFETY: AVX2 presence was just asserted by `require_avx2`.
    unsafe { pack4_avx2(codes) }
}

// SAFETY: caller must guarantee AVX2 (the safe wrapper asserts it);
// reads codes[ci..ci+32] under the `ci + 32 <= codes.len()` guard and
// stores 16 bytes at out[ci/2..ci/2+16], in bounds because out holds
// ceil(codes.len()/2) >= ci/2 + 16 bytes for every guarded ci.
#[target_feature(enable = "avx2")]
unsafe fn pack4_avx2(codes: &[u8]) -> Vec<u8> {
    let mut out = vec![0u8; codes.len().div_ceil(2)];
    let lomask = _mm256_set1_epi16(0x00FF);
    let mut ci = 0usize;
    while ci + 32 <= codes.len() {
        let v = _mm256_loadu_si256(codes.as_ptr().add(ci) as *const __m256i);
        let even = _mm256_and_si256(v, lomask);
        let odd = _mm256_srli_epi16::<8>(v);
        let pair = _mm256_or_si256(even, _mm256_slli_epi16::<4>(odd));
        let b = _mm256_packus_epi16(pair, _mm256_setzero_si256());
        // packus packs per 128-bit lane: qwords are [p0, 0, p1, 0] —
        // pull qword 2 next to qword 0, then store the low 16 bytes
        let packed = _mm256_permute4x64_epi64::<0b0000_1000>(b);
        _mm_storeu_si128(
            out.as_mut_ptr().add(ci / 2) as *mut __m128i,
            _mm256_castsi256_si128(packed),
        );
        ci += 32;
    }
    for (o, c) in out[ci / 2..].iter_mut().zip(codes[ci..].chunks(2)) {
        *o = c[0] | (c.get(1).copied().unwrap_or(0) << 4);
    }
    out
}

/// AVX2 4-bit unpack: 16 bytes → 32 codes per step (`cvtepu8_epi16` is
/// order-preserving, so no permute is needed on this direction).
pub(super) fn unpack4(packed: &[u8], out: &mut [u8]) {
    require_avx2();
    // SAFETY: AVX2 presence was just asserted by `require_avx2`.
    unsafe { unpack4_avx2(packed, out) }
}

// SAFETY: caller must guarantee AVX2 (the safe wrapper asserts it);
// each step reads 16 bytes at packed[i/2] and writes out[i..i+32]
// under `i + 32 <= out.len()`; callers pass packed.len() >=
// ceil(out.len()/2) (`packed_len`), so the 16-byte load at
// i/2 <= out.len()/2 - 16 stays in bounds.
#[target_feature(enable = "avx2")]
unsafe fn unpack4_avx2(packed: &[u8], out: &mut [u8]) {
    let nib = _mm256_set1_epi16(0x000F);
    let mut i = 0usize;
    while i + 32 <= out.len() {
        let p = _mm_loadu_si128(packed.as_ptr().add(i / 2) as *const __m128i);
        let w = _mm256_cvtepu8_epi16(p);
        let lo = _mm256_and_si256(w, nib);
        let hi = _mm256_and_si256(_mm256_srli_epi16::<4>(w), nib);
        let o = _mm256_or_si256(lo, _mm256_slli_epi16::<8>(hi));
        _mm256_storeu_si256(out.as_mut_ptr().add(i) as *mut __m256i, o);
        i += 32;
    }
    super::sse2::unpack4(&packed[i / 2..], &mut out[i..]);
}

/// AVX2 arm of [`decode_block`](super::decode_block): a real 8-wide
/// `i32gather` over the 256-entry table plus an 8-wide scale multiply.
pub(super) fn decode_block(codes: &[u8], table: &[f32; 256], scale: f32, out: &mut [f32]) {
    debug_assert_eq!(codes.len(), out.len());
    require_avx2();
    // SAFETY: AVX2 presence was just asserted by `require_avx2`.
    unsafe { decode_block_avx2(codes, table, scale, out) }
}

// SAFETY: caller must guarantee AVX2 (the safe wrapper asserts it);
// the gather indexes `table[0..256]` with zero-extended u8 codes
// (cannot exceed 255), each 8-byte code load and 8-wide store is
// guarded by `i + 8 <= codes.len()` with `out.len() == codes.len()`
// (debug-asserted by the wrapper).
#[target_feature(enable = "avx2")]
unsafe fn decode_block_avx2(codes: &[u8], table: &[f32; 256], scale: f32, out: &mut [f32]) {
    let sv = _mm256_set1_ps(scale);
    let mut i = 0usize;
    while i + 8 <= codes.len() {
        let idx8 = _mm_loadl_epi64(codes.as_ptr().add(i) as *const __m128i);
        let idx = _mm256_cvtepu8_epi32(idx8);
        let g = _mm256_i32gather_ps::<4>(table.as_ptr(), idx);
        _mm256_storeu_ps(out.as_mut_ptr().add(i), _mm256_mul_ps(g, sv));
        i += 8;
    }
    for (o, &c) in out[i..].iter_mut().zip(&codes[i..]) {
        *o = table[c as usize] * scale;
    }
}
