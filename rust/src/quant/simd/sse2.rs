//! 128-bit SSE2 kernels (`std::arch::x86_64`). SSE2 is part of the
//! x86_64 baseline ISA, so there is no runtime feature detection and no
//! `target_feature` gating — the intrinsics are unconditionally sound to
//! call; every `unsafe` block only has in-bounds pointer arithmetic to
//! justify. Each kernel carries a scalar tail for sub-group lengths and
//! is bit-identical to the scalar/chunked reference (asserted by the
//! N-way property suite in the parent module).

use std::arch::x86_64::*;

/// SSE2 arm of [`absmax`](super::absmax): 4-wide `andnot(-0.0)` + `max`
/// with a `movehl`/`shuffle` horizontal reduction.
pub(super) fn absmax(xs: &[f32]) -> f32 {
    let mut i = 0usize;
    let mut r = 0.0f32;
    if xs.len() >= 4 {
        // SAFETY: SSE2 is part of the x86_64 baseline (no feature
        // detection needed), and every `loadu` reads 4 f32s at offset
        // `i` with `i + 4 <= xs.len()` — always in bounds, and `loadu`
        // tolerates any alignment.
        unsafe {
            let signbit = _mm_set1_ps(-0.0);
            let mut m = _mm_setzero_ps();
            while i + 4 <= xs.len() {
                let v = _mm_loadu_ps(xs.as_ptr().add(i));
                m = _mm_max_ps(m, _mm_andnot_ps(signbit, v));
                i += 4;
            }
            let m = _mm_max_ps(m, _mm_movehl_ps(m, m));
            let m = _mm_max_ss(m, _mm_shuffle_ps::<0x55>(m, m));
            r = _mm_cvtss_f32(m);
        }
    }
    for &v in &xs[i..] {
        r = r.max(v.abs());
    }
    r
}

/// SSE2 arm of [`all_finite`](super::all_finite): 4-wide `v * 0.0`
/// accumulation (the sum is ±0.0 iff every lane was finite).
pub(super) fn all_finite(xs: &[f32]) -> bool {
    let mut i = 0usize;
    let mut s = 0.0f32;
    if xs.len() >= 4 {
        // SAFETY: baseline SSE2; unaligned 4-wide loads stay in bounds
        // via the `i + 4 <= xs.len()` loop guard.
        unsafe {
            let zero = _mm_setzero_ps();
            let mut acc = zero;
            while i + 4 <= xs.len() {
                let v = _mm_loadu_ps(xs.as_ptr().add(i));
                acc = _mm_add_ps(acc, _mm_mul_ps(v, zero));
                i += 4;
            }
            let a = _mm_add_ps(acc, _mm_movehl_ps(acc, acc));
            let a = _mm_add_ss(a, _mm_shuffle_ps::<0x55>(a, a));
            s = _mm_cvtss_f32(a);
        }
    }
    for &v in &xs[i..] {
        s += v * 0.0;
    }
    s == 0.0
}

/// SSE2 arm of [`normalize_into`](super::normalize_into): 4-wide
/// broadcast multiply.
pub(super) fn normalize_into(xs: &[f32], inv: f32, out: &mut [f32]) {
    debug_assert_eq!(xs.len(), out.len());
    let mut i = 0usize;
    if xs.len() >= 4 {
        // SAFETY: baseline SSE2; loads from `xs` and stores to `out`
        // cover lanes [i, i+4) with `i + 4 <= xs.len()` and
        // `out.len() == xs.len()` (debug-asserted above).
        unsafe {
            let iv = _mm_set1_ps(inv);
            while i + 4 <= xs.len() {
                let v = _mm_loadu_ps(xs.as_ptr().add(i));
                _mm_storeu_ps(out.as_mut_ptr().add(i), _mm_mul_ps(v, iv));
                i += 4;
            }
        }
    }
    for (o, &v) in out[i..].iter_mut().zip(&xs[i..]) {
        *o = v * inv;
    }
}

/// SSE2 arm of [`count_below_mids`](super::count_below_mids).
///
/// Lane layout: 16 elements per group held in four f32x4 registers;
/// per midpoint, four `cmplt` masks are narrowed `i32 → i16 → i8`
/// (saturating packs are exact on 0/-1 masks) and subtracted from a
/// 16-lane u8 accumulator, so one register holds all 16 running counts.
/// The tail (< 16 elements) runs the same count arithmetic scalar.
pub(super) fn count_below_mids(mids: &[f32], xs: &[f32], codes: &mut [u8]) {
    debug_assert_eq!(xs.len(), codes.len());
    debug_assert!(mids.len() <= 255, "count must fit a u8 lane");
    let mut i = 0usize;
    // SAFETY: baseline SSE2; each iteration reads xs[i..i+16] and
    // writes codes[i..i+16] under `i + 16 <= xs.len()` with
    // `codes.len() == xs.len()` (debug-asserted above); unaligned
    // load/store intrinsics tolerate any alignment.
    unsafe {
        while i + 16 <= xs.len() {
            let x0 = _mm_loadu_ps(xs.as_ptr().add(i));
            let x1 = _mm_loadu_ps(xs.as_ptr().add(i + 4));
            let x2 = _mm_loadu_ps(xs.as_ptr().add(i + 8));
            let x3 = _mm_loadu_ps(xs.as_ptr().add(i + 12));
            let mut acc = _mm_setzero_si128();
            for &m in mids {
                let mv = _mm_set1_ps(m);
                let c0 = _mm_castps_si128(_mm_cmplt_ps(mv, x0));
                let c1 = _mm_castps_si128(_mm_cmplt_ps(mv, x1));
                let c2 = _mm_castps_si128(_mm_cmplt_ps(mv, x2));
                let c3 = _mm_castps_si128(_mm_cmplt_ps(mv, x3));
                let lo = _mm_packs_epi32(c0, c1);
                let hi = _mm_packs_epi32(c2, c3);
                // 16 bytes of 0x00 / 0xFF; subtracting adds 1 per hit
                acc = _mm_sub_epi8(acc, _mm_packs_epi16(lo, hi));
            }
            _mm_storeu_si128(codes.as_mut_ptr().add(i) as *mut __m128i, acc);
            i += 16;
        }
    }
    super::count_below_mids_scalar(mids, &xs[i..], &mut codes[i..]);
}

/// SSE2 4-bit pack: 16 codes → 8 bytes per step. Each u16 lane holds an
/// (even, odd) code pair; `even | odd << 4` stays below 256, so a
/// saturating `packus` narrows the 8 lanes to the 8 output bytes.
pub(super) fn pack4(codes: &[u8]) -> Vec<u8> {
    let mut out = vec![0u8; codes.len().div_ceil(2)];
    let mut ci = 0usize;
    // SAFETY: baseline SSE2; reads codes[ci..ci+16] under the
    // `ci + 16 <= codes.len()` guard and stores 8 bytes at
    // out[ci/2..ci/2+8], in bounds because out holds
    // ceil(codes.len()/2) >= ci/2 + 8 bytes for every guarded ci.
    unsafe {
        let lomask = _mm_set1_epi16(0x00FF);
        while ci + 16 <= codes.len() {
            let v = _mm_loadu_si128(codes.as_ptr().add(ci) as *const __m128i);
            let even = _mm_and_si128(v, lomask);
            let odd = _mm_srli_epi16::<8>(v);
            let pair = _mm_or_si128(even, _mm_slli_epi16::<4>(odd));
            let b = _mm_packus_epi16(pair, _mm_setzero_si128());
            _mm_storel_epi64(out.as_mut_ptr().add(ci / 2) as *mut __m128i, b);
            ci += 16;
        }
    }
    for (o, c) in out[ci / 2..].iter_mut().zip(codes[ci..].chunks(2)) {
        *o = c[0] | (c.get(1).copied().unwrap_or(0) << 4);
    }
    out
}

/// SSE2 4-bit unpack: 8 bytes → 16 codes per step (zero-extend bytes to
/// u16 lanes, split nibbles, re-interleave as `lo | hi << 8`).
pub(super) fn unpack4(packed: &[u8], out: &mut [u8]) {
    let mut i = 0usize;
    // SAFETY: baseline SSE2; each step reads 8 bytes at packed[i/2]
    // and writes out[i..i+16] under `i + 16 <= out.len()`; callers
    // pass packed.len() >= ceil(out.len()/2) (`packed_len`), so the
    // 8-byte load at i/2 <= out.len()/2 - 8 stays in bounds.
    unsafe {
        let nib = _mm_set1_epi16(0x000F);
        while i + 16 <= out.len() {
            let p = _mm_loadl_epi64(packed.as_ptr().add(i / 2) as *const __m128i);
            let w = _mm_unpacklo_epi8(p, _mm_setzero_si128());
            let lo = _mm_and_si128(w, nib);
            let hi = _mm_and_si128(_mm_srli_epi16::<4>(w), nib);
            let o = _mm_or_si128(lo, _mm_slli_epi16::<8>(hi));
            _mm_storeu_si128(out.as_mut_ptr().add(i) as *mut __m128i, o);
            i += 16;
        }
    }
    super::unpack4_scalar(&packed[i / 2..], &mut out[i..]);
}

/// SSE2 arm of [`decode_block`](super::decode_block): the gather is
/// scalar (SSE2 has no gather); the scale multiply runs 4-wide.
pub(super) fn decode_block(codes: &[u8], table: &[f32; 256], scale: f32, out: &mut [f32]) {
    debug_assert_eq!(codes.len(), out.len());
    let mut i = 0usize;
    if codes.len() >= 4 {
        // SAFETY: baseline SSE2; the gather indexes `table[0..256]`
        // with u8 codes (cannot exceed 255) and the 4-wide store to
        // `out` is guarded by `i + 4 <= codes.len()` with
        // `out.len() == codes.len()` (debug-asserted above).
        unsafe {
            let sv = _mm_set1_ps(scale);
            while i + 4 <= codes.len() {
                let g = _mm_set_ps(
                    table[codes[i + 3] as usize],
                    table[codes[i + 2] as usize],
                    table[codes[i + 1] as usize],
                    table[codes[i] as usize],
                );
                _mm_storeu_ps(out.as_mut_ptr().add(i), _mm_mul_ps(g, sv));
                i += 4;
            }
        }
    }
    for (o, &c) in out[i..].iter_mut().zip(&codes[i..]) {
        *o = table[c as usize] * scale;
    }
}
