//! Explicit SIMD lanes for the quant hot loops (`--features simd`): a
//! runtime-feature-detected **lane registry** with one-time cached
//! dispatch.
//!
//! Every kernel here is a *bit-identical* rewrite of the corresponding
//! chunked kernel in [`blockwise`](super::blockwise) /
//! [`pack`](super::pack) / [`Boundaries::nearest_block`] — the property
//! suite asserts scalar == chunked == every detected lane (N-way) at
//! every bitwidth, mapping, block size, and odd length, so enabling the
//! feature (or hitting a different host CPU) can never change codes,
//! scales, packed bytes, or decoded values.
//!
//! Registry model (stable Rust — no nightly `portable_simd`):
//!  * [`Lane`] names one kernel backend; [`detected_lanes`] probes the
//!    host once per call ([`Lane::Scalar`] always, SSE2 on x86_64 as the
//!    baseline ISA, AVX2 behind `is_x86_feature_detected!`, NEON on
//!    aarch64 as the baseline ISA).
//!  * [`active_lane`] resolves the dispatch lane exactly once per
//!    process (`OnceLock`): the best detected lane, unless the
//!    [`LANE_ENV`] env override pins one (unknown / host-unsupported
//!    names are an error, surfaced cleanly by the CLI via
//!    [`lane_from_env`]).
//!  * Every public kernel has a `*_with`/`*_lane` twin taking an
//!    explicit [`Lane`], which is how the N-way property suite and the
//!    `quant_simd` harness exercise lanes the dispatcher would not pick.
//!  * **2/1-bit pack lanes** are u64 SWAR (shift-mask folds packing 8
//!    codes per word) shared by every vector lane — portable and
//!    branch-free. [`Lane::Scalar`] bypasses them too: it is the pure
//!    chunked fallback, kept dispatchable so CI can force the reference
//!    arms through the very same call sites.
//!
//! Why SIMD can be exact here: the encode pipeline is `abs` / `max` /
//! `mul` / `cmplt` / integer adds — none of which reassociate rounding
//! (f32 max is order-insensitive for finite inputs, and non-finite
//! blocks are rejected before the fold is used). The counting kernel
//! computes `#{mids strictly below x}` exactly like the chunked lane,
//! which is exactly `partition_point(|m| m < x)` — tie semantics
//! included. The same kernel also powers the stochastic-rounding
//! bracket search (`Boundaries::stochastic_block`), counting codebook
//! entries instead of midpoints, so SR encodes vectorize without
//! touching the seeded RNG draw order.
//!
//! Obligations for a future lane (AVX-512, SVE, …): implement the seven
//! per-arch kernels (`absmax`, `all_finite`, `normalize_into`,
//! `count_below_mids`, `pack4`, `unpack4`, `decode_block`) in a new
//! `simd/<lane>.rs`, add the variant + detection + dispatch arms here,
//! add the module to `shampoo-lint`'s unsafe allowlist, and the N-way
//! property suite picks it up from [`detected_lanes`] automatically —
//! bit-identity is the only acceptance bar.
//!
//! [`Boundaries::nearest_block`]: super::codebook::Boundaries::nearest_block

use super::pack::{pack_bits_chunked, packed_len, unpack_bits_into_chunked};

/// 256-bit AVX2 kernels (runtime-detected, never part of the x86_64
/// baseline — see the module's safety pattern).
#[cfg(target_arch = "x86_64")]
pub mod avx2;
/// 128-bit NEON kernels (part of the aarch64 baseline ISA).
#[cfg(target_arch = "aarch64")]
pub mod neon;
/// 128-bit SSE2 kernels (part of the x86_64 baseline ISA).
#[cfg(target_arch = "x86_64")]
pub mod sse2;

// ---------------------------------------------------------------------------
// lane registry
// ---------------------------------------------------------------------------

/// Env var that pins the dispatch lane: `scalar`, `sse2`, `avx2`, or
/// `neon` (case-insensitive). Unknown names, or lanes the host cannot
/// run, are an error — see [`lane_from_env`].
pub const LANE_ENV: &str = "SHAMPOO4_SIMD_LANE";

/// One dispatchable kernel backend. All variants exist on every arch so
/// override parsing and error messages stay uniform; [`detected_lanes`]
/// is the source of truth for what the host can actually run.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Lane {
    /// Pure scalar/chunked reference arms — always available, and kept
    /// dispatchable so forced-lane CI legs exercise the fallback paths.
    Scalar,
    /// 128-bit SSE2 lanes — the x86_64 baseline ISA, no detection needed.
    Sse2,
    /// 256-bit AVX2 lanes — selected via `is_x86_feature_detected!`.
    Avx2,
    /// 128-bit NEON lanes — the aarch64 baseline ISA, no detection needed.
    Neon,
}

impl Lane {
    /// Lane name as accepted by [`LANE_ENV`] and recorded in bench rows.
    pub fn name(self) -> &'static str {
        match self {
            Lane::Scalar => "scalar",
            Lane::Sse2 => "sse2",
            Lane::Avx2 => "avx2",
            Lane::Neon => "neon",
        }
    }

    /// Parse a lane name (case-insensitive). `None` for unknown names.
    pub fn parse(s: &str) -> Option<Lane> {
        match s.to_ascii_lowercase().as_str() {
            "scalar" => Some(Lane::Scalar),
            "sse2" => Some(Lane::Sse2),
            "avx2" => Some(Lane::Avx2),
            "neon" => Some(Lane::Neon),
            _ => None,
        }
    }
}

impl std::fmt::Display for Lane {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Every lane the host can run, in ascending preference order.
/// [`Lane::Scalar`] is always first; the last entry is what
/// [`active_lane`] picks absent an override. The N-way property suite
/// iterates this list, so a new detected lane is automatically under
/// the bit-identity contract.
pub fn detected_lanes() -> Vec<Lane> {
    let mut lanes = vec![Lane::Scalar];
    #[cfg(target_arch = "x86_64")]
    {
        lanes.push(Lane::Sse2);
        if std::arch::is_x86_feature_detected!("avx2") {
            lanes.push(Lane::Avx2);
        }
    }
    #[cfg(target_arch = "aarch64")]
    lanes.push(Lane::Neon);
    lanes
}

/// Validate a would-be override name against the host's detected lanes.
fn validate_lane_name(raw: &str) -> Result<Lane, String> {
    let lane = Lane::parse(raw).ok_or_else(|| {
        format!("{LANE_ENV}={raw:?} is not a lane name (expected scalar, sse2, avx2, or neon)")
    })?;
    let lanes = detected_lanes();
    if !lanes.contains(&lane) {
        let names: Vec<&str> = lanes.iter().map(|l| l.name()).collect();
        return Err(format!(
            "{LANE_ENV}={} is unsupported on this host (detected lanes: {})",
            lane.name(),
            names.join(", ")
        ));
    }
    Ok(lane)
}

/// Read the [`LANE_ENV`] override: `Ok(None)` when unset or empty,
/// `Ok(Some(lane))` for a valid host-supported lane, `Err(message)` for
/// an unknown name or a lane this host cannot run. The CLI calls this
/// before training so a bad override is a clean error, not a panic.
pub fn lane_from_env() -> Result<Option<Lane>, String> {
    let raw = match std::env::var(LANE_ENV) {
        Ok(v) => v,
        Err(_) => return Ok(None),
    };
    let raw = raw.trim();
    if raw.is_empty() {
        return Ok(None);
    }
    validate_lane_name(raw).map(Some)
}

/// The lane every non-`_with` kernel wrapper dispatches through: the
/// best detected lane, or the [`LANE_ENV`] override. Resolved once and
/// cached for the process lifetime, so the hot loops pay one atomic
/// load, not a CPUID probe.
///
/// # Panics
/// Panics if [`LANE_ENV`] names an unknown or host-unsupported lane.
/// Front ends should validate with [`lane_from_env`] first to turn that
/// into a clean error.
pub fn active_lane() -> Lane {
    static ACTIVE: std::sync::OnceLock<Lane> = std::sync::OnceLock::new();
    *ACTIVE.get_or_init(|| match lane_from_env() {
        Ok(Some(forced)) => forced,
        Ok(None) => *detected_lanes()
            .last()
            .expect("detected_lanes always contains Lane::Scalar"),
        Err(msg) => panic!("{msg}"),
    })
}

/// Name of the active lane backend, for bench/JSON provenance.
pub fn simd_arch() -> &'static str {
    match active_lane() {
        Lane::Scalar => "scalar",
        Lane::Sse2 => "sse2+swar",
        Lane::Avx2 => "avx2+swar",
        Lane::Neon => "neon+swar",
    }
}

// ---------------------------------------------------------------------------
// f32 block lanes: absmax, finiteness, normalize
// ---------------------------------------------------------------------------

/// Max |x| over the slice (0.0 for an empty slice), on [`active_lane`].
/// Identical to the scalar `fold(0.0, |m, v| m.max(v.abs()))` for finite
/// inputs — callers must reject non-finite blocks (see [`all_finite`])
/// before trusting it.
pub fn absmax(xs: &[f32]) -> f32 {
    absmax_with(active_lane(), xs)
}

/// [`absmax`] on an explicit lane (the N-way suite and the harness
/// force lanes this way).
pub fn absmax_with(lane: Lane, xs: &[f32]) -> f32 {
    match lane {
        Lane::Scalar => absmax_scalar(xs),
        #[cfg(target_arch = "x86_64")]
        Lane::Sse2 => sse2::absmax(xs),
        #[cfg(target_arch = "x86_64")]
        Lane::Avx2 => avx2::absmax(xs),
        #[cfg(target_arch = "aarch64")]
        Lane::Neon => neon::absmax(xs),
        _ => absmax_scalar(xs),
    }
}

fn absmax_scalar(xs: &[f32]) -> f32 {
    xs.iter().fold(0.0f32, |m, &v| m.max(v.abs()))
}

/// True iff every element is finite, on [`active_lane`]. Branch-free:
/// accumulates `v * 0.0` (exactly ±0.0 for finite `v`, NaN for ±Inf/NaN
/// — a fold LLVM cannot constant-fold away without fast-math) and tests
/// the sum against 0.0.
pub fn all_finite(xs: &[f32]) -> bool {
    all_finite_with(active_lane(), xs)
}

/// [`all_finite`] on an explicit lane.
pub fn all_finite_with(lane: Lane, xs: &[f32]) -> bool {
    match lane {
        Lane::Scalar => all_finite_scalar(xs),
        #[cfg(target_arch = "x86_64")]
        Lane::Sse2 => sse2::all_finite(xs),
        #[cfg(target_arch = "x86_64")]
        Lane::Avx2 => avx2::all_finite(xs),
        #[cfg(target_arch = "aarch64")]
        Lane::Neon => neon::all_finite(xs),
        _ => all_finite_scalar(xs),
    }
}

fn all_finite_scalar(xs: &[f32]) -> bool {
    let mut s = 0.0f32;
    for &v in xs {
        s += v * 0.0;
    }
    s == 0.0
}

/// `out[i] = xs[i] * inv` — the per-block normalize lane, on
/// [`active_lane`]. IEEE multiply is elementwise, so every arm is
/// bit-identical to the scalar loop.
pub fn normalize_into(xs: &[f32], inv: f32, out: &mut [f32]) {
    normalize_into_with(active_lane(), xs, inv, out)
}

/// [`normalize_into`] on an explicit lane.
pub fn normalize_into_with(lane: Lane, xs: &[f32], inv: f32, out: &mut [f32]) {
    debug_assert_eq!(xs.len(), out.len());
    match lane {
        Lane::Scalar => normalize_scalar(xs, inv, out),
        #[cfg(target_arch = "x86_64")]
        Lane::Sse2 => sse2::normalize_into(xs, inv, out),
        #[cfg(target_arch = "x86_64")]
        Lane::Avx2 => avx2::normalize_into(xs, inv, out),
        #[cfg(target_arch = "aarch64")]
        Lane::Neon => neon::normalize_into(xs, inv, out),
        _ => normalize_scalar(xs, inv, out),
    }
}

fn normalize_scalar(xs: &[f32], inv: f32, out: &mut [f32]) {
    for (o, &v) in out.iter_mut().zip(xs) {
        *o = v * inv;
    }
}

// ---------------------------------------------------------------------------
// counting lane (nearest-code + stochastic bracket search)
// ---------------------------------------------------------------------------

/// `codes[i] = #{m in mids : m < xs[i]}` on [`active_lane`] — the
/// strict-below counting kernel behind both the nearest-code encode
/// (every book width up to 255 midpoints, i.e. 8-bit books, before the
/// duplicate-run remap) and the stochastic-rounding bracket search
/// (counting codebook entries). The vectorized sweeps amortize each
/// midpoint across 16 (SSE2/NEON) or 32 (AVX2) elements, so they beat
/// the scalar binary search even for wide books where the scalar
/// counting arm does not.
pub fn count_below_mids(mids: &[f32], xs: &[f32], codes: &mut [u8]) {
    count_below_mids_with(active_lane(), mids, xs, codes)
}

/// [`count_below_mids`] on an explicit lane.
pub fn count_below_mids_with(lane: Lane, mids: &[f32], xs: &[f32], codes: &mut [u8]) {
    debug_assert_eq!(xs.len(), codes.len());
    debug_assert!(mids.len() <= 255, "count must fit a u8 lane");
    match lane {
        Lane::Scalar => count_below_mids_scalar(mids, xs, codes),
        #[cfg(target_arch = "x86_64")]
        Lane::Sse2 => sse2::count_below_mids(mids, xs, codes),
        #[cfg(target_arch = "x86_64")]
        Lane::Avx2 => avx2::count_below_mids(mids, xs, codes),
        #[cfg(target_arch = "aarch64")]
        Lane::Neon => neon::count_below_mids(mids, xs, codes),
        _ => count_below_mids_scalar(mids, xs, codes),
    }
}

pub(super) fn count_below_mids_scalar(mids: &[f32], xs: &[f32], codes: &mut [u8]) {
    for (c, &x) in codes.iter_mut().zip(xs) {
        let mut n = 0u8;
        for &m in mids {
            n += (m < x) as u8;
        }
        *c = n;
    }
}

// ---------------------------------------------------------------------------
// pack / unpack lanes
// ---------------------------------------------------------------------------

/// SIMD arm of [`pack_bits`](super::pack::pack_bits) on
/// [`active_lane`]: byte-for-byte identical output (the property suite
/// asserts it against both the chunked fast paths and the generic
/// bit-cursor loop).
pub fn pack_bits_simd(codes: &[u8], bits: u32) -> Vec<u8> {
    pack_bits_lane(active_lane(), codes, bits)
}

/// [`pack_bits_simd`] on an explicit lane. [`Lane::Scalar`] routes every
/// width through the chunked reference; vector lanes share the u64 SWAR
/// 2/1-bit folds and dispatch the nibble lane per arch.
pub fn pack_bits_lane(lane: Lane, codes: &[u8], bits: u32) -> Vec<u8> {
    assert!((1..=8).contains(&bits));
    if lane == Lane::Scalar {
        return pack_bits_chunked(codes, bits);
    }
    match bits {
        8 => codes.to_vec(),
        4 => pack4_lane(lane, codes),
        2 => pack2(codes),
        1 => pack1(codes),
        _ => pack_bits_chunked(codes, bits),
    }
}

/// SIMD arm of [`unpack_bits_into`](super::pack::unpack_bits_into) on
/// [`active_lane`].
pub fn unpack_bits_into_simd(packed: &[u8], bits: u32, out: &mut [u8]) {
    unpack_bits_into_lane(active_lane(), packed, bits, out)
}

/// [`unpack_bits_into_simd`] on an explicit lane.
pub fn unpack_bits_into_lane(lane: Lane, packed: &[u8], bits: u32, out: &mut [u8]) {
    assert!((1..=8).contains(&bits));
    if lane == Lane::Scalar {
        return unpack_bits_into_chunked(packed, bits, out);
    }
    match bits {
        8 => out.copy_from_slice(&packed[..out.len()]),
        4 => unpack4_lane(lane, packed, out),
        2 => unpack2(packed, out),
        1 => unpack1(packed, out),
        _ => unpack_bits_into_chunked(packed, bits, out),
    }
}

fn pack4_lane(lane: Lane, codes: &[u8]) -> Vec<u8> {
    match lane {
        #[cfg(target_arch = "x86_64")]
        Lane::Sse2 => sse2::pack4(codes),
        #[cfg(target_arch = "x86_64")]
        Lane::Avx2 => avx2::pack4(codes),
        #[cfg(target_arch = "aarch64")]
        Lane::Neon => neon::pack4(codes),
        _ => pack4_scalar(codes),
    }
}

fn unpack4_lane(lane: Lane, packed: &[u8], out: &mut [u8]) {
    match lane {
        #[cfg(target_arch = "x86_64")]
        Lane::Sse2 => sse2::unpack4(packed, out),
        #[cfg(target_arch = "x86_64")]
        Lane::Avx2 => avx2::unpack4(packed, out),
        #[cfg(target_arch = "aarch64")]
        Lane::Neon => neon::unpack4(packed, out),
        _ => unpack4_scalar(packed, out),
    }
}

/// Scalar 4-bit pack — the shared tail loop, doubled as the full
/// implementation on arches with no vector nibble lane.
pub(super) fn pack4_scalar(codes: &[u8]) -> Vec<u8> {
    let mut out = vec![0u8; codes.len().div_ceil(2)];
    for (o, c) in out.iter_mut().zip(codes.chunks(2)) {
        *o = c[0] | (c.get(1).copied().unwrap_or(0) << 4);
    }
    out
}

/// Scalar 4-bit unpack (see [`pack4_scalar`]).
pub(super) fn unpack4_scalar(packed: &[u8], out: &mut [u8]) {
    for (c, &b) in out.chunks_mut(2).zip(packed) {
        c[0] = b & 0x0F;
        if let Some(hi) = c.get_mut(1) {
            *hi = b >> 4;
        }
    }
}

/// 2-bit pack: u64 SWAR, 8 codes (one word) → 2 bytes. Two shift-mask
/// folds gather the 2-bit fields: bytes → nibbles → packed bytes.
/// Portable — shared by every vector lane.
fn pack2(codes: &[u8]) -> Vec<u8> {
    let mut out = vec![0u8; codes.len().div_ceil(4)];
    let mut ci = 0usize;
    let mut oi = 0usize;
    while ci + 8 <= codes.len() {
        let x = u64::from_le_bytes(codes[ci..ci + 8].try_into().unwrap());
        let x = (x | (x >> 6)) & 0x000F_000F_000F_000F;
        let x = (x | (x >> 12)) & 0x0000_00FF_0000_00FF;
        out[oi] = x as u8;
        out[oi + 1] = (x >> 32) as u8;
        ci += 8;
        oi += 2;
    }
    for (o, c) in out[oi..].iter_mut().zip(codes[ci..].chunks(4)) {
        for (k, &v) in c.iter().enumerate() {
            *o |= v << (2 * k);
        }
    }
    out
}

/// 2-bit unpack: inverse SWAR spread, 2 bytes → 8 codes.
fn unpack2(packed: &[u8], out: &mut [u8]) {
    let mut ci = 0usize;
    let mut pi = 0usize;
    while ci + 8 <= out.len() {
        let y = (packed[pi] as u64) | ((packed[pi + 1] as u64) << 32);
        let y = (y | (y << 12)) & 0x000F_000F_000F_000F;
        let y = (y | (y << 6)) & 0x0303_0303_0303_0303;
        out[ci..ci + 8].copy_from_slice(&y.to_le_bytes());
        ci += 8;
        pi += 2;
    }
    for (c, &b) in out[ci..].chunks_mut(4).zip(&packed[pi..]) {
        for (k, v) in c.iter_mut().enumerate() {
            *v = (b >> (2 * k)) & 0x03;
        }
    }
}

/// 1-bit pack: the classic multiply-gather — 8 LSBs fan out to bits
/// 56..63 of the product with no cross-term collisions, one byte per word.
fn pack1(codes: &[u8]) -> Vec<u8> {
    let mut out = vec![0u8; codes.len().div_ceil(8)];
    let mut ci = 0usize;
    let mut oi = 0usize;
    while ci + 8 <= codes.len() {
        let x = u64::from_le_bytes(codes[ci..ci + 8].try_into().unwrap()) & 0x0101_0101_0101_0101;
        out[oi] = (x.wrapping_mul(0x0102_0408_1020_4080) >> 56) as u8;
        ci += 8;
        oi += 1;
    }
    for (o, c) in out[oi..].iter_mut().zip(codes[ci..].chunks(8)) {
        for (k, &v) in c.iter().enumerate() {
            *o |= v << k;
        }
    }
    out
}

/// 1-bit unpack: broadcast the byte to all 8 lanes, isolate bit k in
/// byte k, then normalize each nonzero byte to 1 with a carryless
/// `+0x7F >> 7` (a set bit ≤ 0x80 never carries across its byte).
fn unpack1(packed: &[u8], out: &mut [u8]) {
    let mut ci = 0usize;
    let mut pi = 0usize;
    while ci + 8 <= out.len() {
        let spread =
            (packed[pi] as u64).wrapping_mul(0x0101_0101_0101_0101) & 0x8040_2010_0804_0201;
        let y = (spread.wrapping_add(0x7F7F_7F7F_7F7F_7F7F) >> 7) & 0x0101_0101_0101_0101;
        out[ci..ci + 8].copy_from_slice(&y.to_le_bytes());
        ci += 8;
        pi += 1;
    }
    for (c, &b) in out[ci..].chunks_mut(8).zip(&packed[pi..]) {
        for (k, v) in c.iter_mut().enumerate() {
            *v = (b >> k) & 0x01;
        }
    }
}

// ---------------------------------------------------------------------------
// decode lane
// ---------------------------------------------------------------------------

/// Decode lane on [`active_lane`]: `out[i] = table[codes[i]] * scale`
/// for one block. IEEE multiply is elementwise, so every arm is
/// bit-identical to the chunked table loop.
pub fn decode_block(codes: &[u8], table: &[f32; 256], scale: f32, out: &mut [f32]) {
    decode_block_with(active_lane(), codes, table, scale, out)
}

/// [`decode_block`] on an explicit lane.
pub fn decode_block_with(
    lane: Lane,
    codes: &[u8],
    table: &[f32; 256],
    scale: f32,
    out: &mut [f32],
) {
    debug_assert_eq!(codes.len(), out.len());
    match lane {
        Lane::Scalar => decode_block_scalar(codes, table, scale, out),
        #[cfg(target_arch = "x86_64")]
        Lane::Sse2 => sse2::decode_block(codes, table, scale, out),
        #[cfg(target_arch = "x86_64")]
        Lane::Avx2 => avx2::decode_block(codes, table, scale, out),
        #[cfg(target_arch = "aarch64")]
        Lane::Neon => neon::decode_block(codes, table, scale, out),
        _ => decode_block_scalar(codes, table, scale, out),
    }
}

fn decode_block_scalar(codes: &[u8], table: &[f32; 256], scale: f32, out: &mut [f32]) {
    for (o, &c) in out.iter_mut().zip(codes) {
        *o = table[c as usize] * scale;
    }
}

/// Unpack a whole payload through the SIMD lanes (convenience mirror of
/// [`unpack_bits`](super::pack::unpack_bits)).
pub fn unpack_bits_simd(packed: &[u8], bits: u32, count: usize) -> Vec<u8> {
    debug_assert!(packed.len() >= packed_len(count, bits));
    let mut out = vec![0u8; count];
    unpack_bits_into_simd(packed, bits, &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn registry_reports_consistent_lanes() {
        let lanes = detected_lanes();
        assert_eq!(lanes[0], Lane::Scalar, "scalar is always detected");
        let active = active_lane();
        assert!(lanes.contains(&active), "active {active} not in {lanes:?}");
        if let Ok(Some(forced)) = lane_from_env() {
            assert_eq!(active, forced, "env override must win the dispatch");
        }
        for l in &lanes {
            assert_eq!(Lane::parse(l.name()), Some(*l), "name/parse round-trip");
        }
        #[cfg(target_arch = "x86_64")]
        assert!(lanes.contains(&Lane::Sse2), "sse2 is the x86_64 baseline");
        #[cfg(target_arch = "aarch64")]
        assert!(lanes.contains(&Lane::Neon), "neon is the aarch64 baseline");
    }

    #[test]
    fn lane_override_validation() {
        assert_eq!(Lane::parse("AVX2"), Some(Lane::Avx2));
        assert_eq!(Lane::parse("mmx"), None);
        assert!(validate_lane_name("warp9").is_err());
        assert_eq!(validate_lane_name("scalar").unwrap(), Lane::Scalar);
        #[cfg(target_arch = "x86_64")]
        {
            assert_eq!(validate_lane_name("SSE2").unwrap(), Lane::Sse2);
            let err = validate_lane_name("neon").unwrap_err();
            assert!(err.contains("unsupported on this host"), "{err}");
            assert!(err.contains("detected lanes"), "{err}");
        }
        #[cfg(target_arch = "aarch64")]
        {
            assert_eq!(validate_lane_name("neon").unwrap(), Lane::Neon);
            assert!(validate_lane_name("sse2").is_err());
        }
    }

    #[test]
    fn absmax_and_finite_match_scalar_on_every_lane() {
        let mut rng = Rng::new(11);
        for lane in detected_lanes() {
            for n in [0usize, 1, 3, 4, 5, 7, 8, 15, 16, 17, 31, 32, 33, 64, 100] {
                let xs: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
                let want = xs.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
                assert_eq!(
                    absmax_with(lane, &xs).to_bits(),
                    want.to_bits(),
                    "lane={lane} n={n}"
                );
                assert!(all_finite_with(lane, &xs), "lane={lane} n={n}");
            }
            for bad in [f32::NAN, f32::INFINITY, f32::NEG_INFINITY] {
                for pos in [0usize, 3, 7, 31, 63] {
                    let mut xs = vec![0.25f32; 64];
                    xs[pos] = bad;
                    assert!(!all_finite_with(lane, &xs), "lane={lane} bad={bad} pos={pos}");
                }
            }
            // -0.0 stays finite and abs-es to +0.0
            assert!(all_finite_with(lane, &[-0.0f32; 9]));
            assert_eq!(absmax_with(lane, &[-0.0f32; 9]), 0.0);
        }
    }

    #[test]
    fn normalize_matches_scalar_on_every_lane() {
        let mut rng = Rng::new(12);
        for lane in detected_lanes() {
            for n in [1usize, 4, 7, 31, 33, 64] {
                let xs: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
                let inv = 0.371f32;
                let mut a = vec![0.0f32; n];
                normalize_into_with(lane, &xs, inv, &mut a);
                for (av, &x) in a.iter().zip(&xs) {
                    assert_eq!(av.to_bits(), (x * inv).to_bits(), "lane={lane} n={n}");
                }
            }
        }
    }

    #[test]
    #[cfg_attr(miri, ignore)] // property sweep: too slow under Miri's interpreter
    fn count_below_mids_matches_scalar_on_every_lane() {
        let mut rng = Rng::new(13);
        // 15 mids = a 4-bit book; 255 mids = the widest (8-bit) book, which
        // the SIMD encode path routes through this kernel too. Lengths
        // straddle the 16-wide (SSE2/NEON) and 32-wide (AVX2) group sizes.
        for width in [15usize, 255] {
            let mids: Vec<f32> = {
                let mut m: Vec<f32> = (0..width).map(|_| rng.normal_f32()).collect();
                m.sort_by(|a, b| a.partial_cmp(b).unwrap());
                m
            };
            for lane in detected_lanes() {
                for n in [0usize, 1, 15, 16, 17, 31, 32, 33, 63, 64, 100] {
                    let xs: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
                    let mut got = vec![0u8; n];
                    count_below_mids_with(lane, &mids, &xs, &mut got);
                    for (&x, &c) in xs.iter().zip(&got) {
                        let want = mids.iter().filter(|&&m| m < x).count() as u8;
                        assert_eq!(c, want, "lane={lane} x={x} width={width}");
                    }
                }
            }
        }
    }

    #[test]
    #[cfg_attr(miri, ignore)] // property sweep: too slow under Miri's interpreter
    fn pack_lanes_match_chunked_all_widths_on_every_lane() {
        let mut rng = Rng::new(14);
        for lane in detected_lanes() {
            for bits in [1u32, 2, 3, 4, 8] {
                for n in [0usize, 1, 2, 7, 8, 15, 16, 17, 31, 32, 33, 63, 64, 129, 1000] {
                    let codes: Vec<u8> =
                        (0..n).map(|_| rng.below(1usize << bits) as u8).collect();
                    let want = pack_bits_chunked(&codes, bits);
                    let got = pack_bits_lane(lane, &codes, bits);
                    assert_eq!(got, want, "pack lane={lane} bits={bits} n={n}");
                    let mut back = vec![0u8; n];
                    unpack_bits_into_lane(lane, &got, bits, &mut back);
                    assert_eq!(back, codes, "unpack lane={lane} bits={bits} n={n}");
                }
            }
        }
    }

    #[test]
    fn decode_block_matches_scalar_on_every_lane() {
        let mut rng = Rng::new(15);
        let mut table = [0.0f32; 256];
        for t in table.iter_mut().take(16) {
            *t = rng.normal_f32();
        }
        for lane in detected_lanes() {
            for n in [1usize, 3, 4, 5, 7, 8, 9, 64] {
                let codes: Vec<u8> = (0..n).map(|_| rng.below(16) as u8).collect();
                let mut out = vec![0.0f32; n];
                decode_block_with(lane, &codes, &table, 1.7, &mut out);
                for (o, &c) in out.iter().zip(&codes) {
                    assert_eq!(
                        o.to_bits(),
                        (table[c as usize] * 1.7).to_bits(),
                        "lane={lane} n={n}"
                    );
                }
            }
        }
    }
}
