//! 128-bit NEON kernels (`std::arch::aarch64`). NEON/ASIMD is part of
//! the aarch64 baseline ISA, so — like the SSE2 lane — there is no
//! runtime feature detection and no `target_feature` gating: the
//! intrinsics are unconditionally sound to call, and every `unsafe`
//! block only has in-bounds pointer arithmetic to justify.
//!
//! Bit-identity mirrors the SSE2 lane: abs/max/mul/cmp are elementwise
//! or order-insensitive, and the counting kernel narrows its 0/all-ones
//! masks with `vmovn` (plain low-half truncation), which is exact and —
//! unlike the x86 saturating packs — order-preserving, so no permute
//! fixup is needed.

use std::arch::aarch64::*;

/// NEON arm of [`absmax`](super::absmax): 4-wide `vabs` + `vmax` with a
/// `vmaxv` horizontal reduction (max is order-insensitive).
pub(super) fn absmax(xs: &[f32]) -> f32 {
    let mut i = 0usize;
    let mut r = 0.0f32;
    if xs.len() >= 4 {
        // SAFETY: NEON is part of the aarch64 baseline (no feature
        // detection needed), and every `vld1q` reads 4 f32s at offset
        // `i` with `i + 4 <= xs.len()` — always in bounds, and NEON
        // loads tolerate any alignment.
        unsafe {
            let mut m = vdupq_n_f32(0.0);
            while i + 4 <= xs.len() {
                let v = vld1q_f32(xs.as_ptr().add(i));
                m = vmaxq_f32(m, vabsq_f32(v));
                i += 4;
            }
            r = vmaxvq_f32(m);
        }
    }
    for &v in &xs[i..] {
        r = r.max(v.abs());
    }
    r
}

/// NEON arm of [`all_finite`](super::all_finite): 4-wide `v * 0.0`
/// accumulation (the sum is ±0.0 iff every lane was finite; add order
/// is irrelevant for that predicate).
pub(super) fn all_finite(xs: &[f32]) -> bool {
    let mut i = 0usize;
    let mut s = 0.0f32;
    if xs.len() >= 4 {
        // SAFETY: baseline NEON; unaligned 4-wide loads stay in bounds
        // via the `i + 4 <= xs.len()` loop guard.
        unsafe {
            let zero = vdupq_n_f32(0.0);
            let mut acc = zero;
            while i + 4 <= xs.len() {
                let v = vld1q_f32(xs.as_ptr().add(i));
                acc = vaddq_f32(acc, vmulq_f32(v, zero));
                i += 4;
            }
            s = vaddvq_f32(acc);
        }
    }
    for &v in &xs[i..] {
        s += v * 0.0;
    }
    s == 0.0
}

/// NEON arm of [`normalize_into`](super::normalize_into): 4-wide
/// broadcast multiply.
pub(super) fn normalize_into(xs: &[f32], inv: f32, out: &mut [f32]) {
    debug_assert_eq!(xs.len(), out.len());
    let mut i = 0usize;
    if xs.len() >= 4 {
        // SAFETY: baseline NEON; loads from `xs` and stores to `out`
        // cover lanes [i, i+4) with `i + 4 <= xs.len()` and
        // `out.len() == xs.len()` (debug-asserted above).
        unsafe {
            let iv = vdupq_n_f32(inv);
            while i + 4 <= xs.len() {
                let v = vld1q_f32(xs.as_ptr().add(i));
                vst1q_f32(out.as_mut_ptr().add(i), vmulq_f32(v, iv));
                i += 4;
            }
        }
    }
    for (o, &v) in out[i..].iter_mut().zip(&xs[i..]) {
        *o = v * inv;
    }
}

/// NEON arm of [`count_below_mids`](super::count_below_mids).
///
/// Lane layout: 16 elements per group held in four f32x4 registers;
/// per midpoint, four `vclt` masks (0 / all-ones u32) are narrowed
/// u32 → u16 → u8 with `vmovn` (low-half truncation — exact on masks
/// and order-preserving) and subtracted from a 16-lane u8 accumulator.
/// The tail (< 16 elements) runs the same count arithmetic scalar.
pub(super) fn count_below_mids(mids: &[f32], xs: &[f32], codes: &mut [u8]) {
    debug_assert_eq!(xs.len(), codes.len());
    debug_assert!(mids.len() <= 255, "count must fit a u8 lane");
    let mut i = 0usize;
    // SAFETY: baseline NEON; each iteration reads xs[i..i+16] and
    // writes codes[i..i+16] under `i + 16 <= xs.len()` with
    // `codes.len() == xs.len()` (debug-asserted above).
    unsafe {
        while i + 16 <= xs.len() {
            let x0 = vld1q_f32(xs.as_ptr().add(i));
            let x1 = vld1q_f32(xs.as_ptr().add(i + 4));
            let x2 = vld1q_f32(xs.as_ptr().add(i + 8));
            let x3 = vld1q_f32(xs.as_ptr().add(i + 12));
            let mut acc = vdupq_n_u8(0);
            for &m in mids {
                let mv = vdupq_n_f32(m);
                let c0 = vcltq_f32(mv, x0);
                let c1 = vcltq_f32(mv, x1);
                let c2 = vcltq_f32(mv, x2);
                let c3 = vcltq_f32(mv, x3);
                let lo = vcombine_u16(vmovn_u32(c0), vmovn_u32(c1));
                let hi = vcombine_u16(vmovn_u32(c2), vmovn_u32(c3));
                // 16 bytes of 0x00 / 0xFF; subtracting adds 1 per hit
                let b = vcombine_u8(vmovn_u16(lo), vmovn_u16(hi));
                acc = vsubq_u8(acc, b);
            }
            vst1q_u8(codes.as_mut_ptr().add(i), acc);
            i += 16;
        }
    }
    super::count_below_mids_scalar(mids, &xs[i..], &mut codes[i..]);
}

/// NEON 4-bit pack: 16 codes → 8 bytes per step (`vuzp` splits the
/// even/odd code streams; `even | odd << 4` merges each pair).
pub(super) fn pack4(codes: &[u8]) -> Vec<u8> {
    let mut out = vec![0u8; codes.len().div_ceil(2)];
    let mut ci = 0usize;
    // SAFETY: baseline NEON; reads codes[ci..ci+16] under the
    // `ci + 16 <= codes.len()` guard and stores 8 bytes at
    // out[ci/2..ci/2+8], in bounds because out holds
    // ceil(codes.len()/2) >= ci/2 + 8 bytes for every guarded ci.
    unsafe {
        while ci + 16 <= codes.len() {
            let v = vld1q_u8(codes.as_ptr().add(ci));
            let even = vuzp1q_u8(v, v);
            let odd = vuzp2q_u8(v, v);
            let b = vorrq_u8(even, vshlq_n_u8::<4>(odd));
            vst1_u8(out.as_mut_ptr().add(ci / 2), vget_low_u8(b));
            ci += 16;
        }
    }
    for (o, c) in out[ci / 2..].iter_mut().zip(codes[ci..].chunks(2)) {
        *o = c[0] | (c.get(1).copied().unwrap_or(0) << 4);
    }
    out
}

/// NEON 4-bit unpack: 8 bytes → 16 codes per step (split nibbles, then
/// `vzip` re-interleaves the low/high streams into element order).
pub(super) fn unpack4(packed: &[u8], out: &mut [u8]) {
    let mut i = 0usize;
    // SAFETY: baseline NEON; each step reads 8 bytes at packed[i/2]
    // and writes out[i..i+16] under `i + 16 <= out.len()`; callers
    // pass packed.len() >= ceil(out.len()/2) (`packed_len`), so the
    // 8-byte load at i/2 <= out.len()/2 - 8 stays in bounds.
    unsafe {
        while i + 16 <= out.len() {
            let p = vld1_u8(packed.as_ptr().add(i / 2));
            let lo = vand_u8(p, vdup_n_u8(0x0F));
            let hi = vshr_n_u8::<4>(p);
            let z = vcombine_u8(vzip1_u8(lo, hi), vzip2_u8(lo, hi));
            vst1q_u8(out.as_mut_ptr().add(i), z);
            i += 16;
        }
    }
    super::unpack4_scalar(&packed[i / 2..], &mut out[i..]);
}

/// NEON arm of [`decode_block`](super::decode_block): the gather is
/// scalar (no NEON table gather at 256 entries); the scale multiply
/// runs 4-wide.
pub(super) fn decode_block(codes: &[u8], table: &[f32; 256], scale: f32, out: &mut [f32]) {
    debug_assert_eq!(codes.len(), out.len());
    let mut i = 0usize;
    if codes.len() >= 4 {
        // SAFETY: baseline NEON; the gather indexes `table[0..256]`
        // with u8 codes (cannot exceed 255) and the 4-wide store to
        // `out` is guarded by `i + 4 <= codes.len()` with
        // `out.len() == codes.len()` (debug-asserted above).
        unsafe {
            let sv = vdupq_n_f32(scale);
            while i + 4 <= codes.len() {
                let g = [
                    table[codes[i] as usize],
                    table[codes[i + 1] as usize],
                    table[codes[i + 2] as usize],
                    table[codes[i + 3] as usize],
                ];
                let v = vld1q_f32(g.as_ptr());
                vst1q_f32(out.as_mut_ptr().add(i), vmulq_f32(v, sv));
                i += 4;
            }
        }
    }
    for (o, &c) in out[i..].iter_mut().zip(&codes[i..]) {
        *o = table[c as usize] * scale;
    }
}
