//! The codec policy layer: maps a typed buffer role (first-order momentum,
//! second moment, second-order left/right sides, ...) to a [`StateCodec`]
//! spec, so *which* state is quantized and *how* is configured per buffer —
//! the paper's central observation (eigenvector matrix over preconditioner,
//! linear-square over DT), Li et al.'s per-moment bitwidths (m at 4-bit,
//! v at 8-bit), and SOLO's stochastic rounding, all as one resolver.
//!
//! Resolution order (first match wins):
//!
//! 1. a policy entry for the exact role (`[quant.policy] m = "q4-linear2"`
//!    in TOML, overridden by `--quant-policy m=q4,...` on the CLI);
//! 2. for the side roles, an `eigen` entry covering both sides at once;
//! 3. the legacy single-knob fallback (`first_order.bits`/`.mapping` for
//!    first-order roles, `quant.bits`/`.mapping` for second-order roles) —
//!    which is why configs and checkpoints that predate the policy layer
//!    keep working unchanged.
//!
//! Stochastic-rounding specs (`-sr` suffix) build one [`StochasticRound`]
//! codec *per buffer*, each seeded from the run seed and the buffer's role
//! through `util/rng.rs` — fixed run seed ⇒ reproducible rounding streams.

use std::sync::Arc;

use anyhow::{bail, Result};

use super::codebook::Mapping;
use super::codec::{Bf16, BlockQuant, Fp32, StateCodec, StochasticRound, CODEC_REGISTRY_HELP};
use crate::util::rng::Rng;

/// The typed role of one optimizer state buffer — what the policy resolver
/// keys on.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum BufferRole {
    /// First-order momentum / first moment (AdamW m, SGDM momentum).
    Momentum,
    /// Second moment / accumulator (AdamW v, Adagrad accumulator,
    /// schedule-free v).
    SecondMoment,
    /// Left (row-side) second-order preconditioner state.
    LeftSide,
    /// Right (column-side) second-order preconditioner state.
    RightSide,
    /// Both second-order sides at once (the eigenvector-matrix storage of
    /// the paper); a `LeftSide`/`RightSide` entry overrides it per side.
    EigenVectors,
}

/// Valid policy role names, for error messages.
pub const ROLE_HELP: &str = "valid roles: m | momentum, v | second_moment, \
    left | left_side, right | right_side, eigen | eigenvectors";

impl BufferRole {
    /// Parse a policy key (`m`, `v`, `left`, `eigen`, ...).
    pub fn parse(s: &str) -> Result<BufferRole> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "m" | "momentum" => Self::Momentum,
            "v" | "second_moment" | "secondmoment" => Self::SecondMoment,
            "left" | "left_side" => Self::LeftSide,
            "right" | "right_side" => Self::RightSide,
            "eigen" | "eigenvectors" => Self::EigenVectors,
            other => bail!("unknown quant policy role {other:?}; {ROLE_HELP}"),
        })
    }

    /// Canonical policy-key name.
    pub fn name(&self) -> &'static str {
        match self {
            Self::Momentum => "m",
            Self::SecondMoment => "v",
            Self::LeftSide => "left",
            Self::RightSide => "right",
            Self::EigenVectors => "eigen",
        }
    }

    /// Whether this role stores second-order (preconditioner-side) state.
    pub fn is_second_order(&self) -> bool {
        matches!(self, Self::LeftSide | Self::RightSide | Self::EigenVectors)
    }

    /// Stable tag mixed into the per-buffer stochastic-rounding seed.
    fn seed_tag(&self) -> u64 {
        match self {
            Self::Momentum => 1,
            Self::SecondMoment => 2,
            Self::LeftSide => 3,
            Self::RightSide => 4,
            Self::EigenVectors => 5,
        }
    }
}

/// A parsed codec specification: everything needed to build a codec for one
/// buffer, minus the per-buffer seed.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CodecSpec {
    /// Storage bits: 32 = fp32, 16 = bf16, 2–8 = block quantization.
    pub bits: u32,
    /// Codebook mapping (ignored by the dense 16/32-bit codecs).
    pub mapping: Mapping,
    /// Wrap the block codec in [`StochasticRound`].
    pub stochastic: bool,
}

impl CodecSpec {
    /// Deterministic spec from the legacy single-knob (bits, mapping) pair.
    pub fn plain(bits: u32, mapping: Mapping) -> Self {
        Self { bits, mapping, stochastic: false }
    }

    /// Parse a codec name (`fp32`, `bf16`, `q4-linear2`, `q8-dt`,
    /// `q4-dt-sr`, ...). The shorthand `q4` (no mapping) takes
    /// `default_mapping`, so `--quant-policy m=q4,v=q8` works without
    /// spelling the codebook out.
    pub fn parse(s: &str, default_mapping: Mapping) -> Result<CodecSpec> {
        let (base, stochastic) = match s.strip_suffix("-sr") {
            Some(b) => (b, true),
            None => (s, false),
        };
        let spec = match base {
            "fp32" => Self::plain(32, default_mapping),
            "bf16" => Self::plain(16, default_mapping),
            other => {
                let unknown = || {
                    anyhow::anyhow!("unknown codec {s:?} in quant policy; {CODEC_REGISTRY_HELP}")
                };
                let rest = other.strip_prefix('q').ok_or_else(unknown)?;
                let (bits_s, mapping) = match rest.split_once('-') {
                    Some((b, m)) => (b, Mapping::parse(m).ok_or_else(unknown)?),
                    None => (rest, default_mapping),
                };
                let bits: u32 = bits_s.parse().map_err(|_| unknown())?;
                if !(2..=8).contains(&bits) {
                    bail!("codec {s:?} in quant policy: bits out of range; {CODEC_REGISTRY_HELP}");
                }
                Self { bits, mapping, stochastic: false }
            }
        };
        if stochastic && spec.bits > 8 {
            bail!(
                "codec {s:?} in quant policy: stochastic rounding applies to block-quant \
                 codecs only; {CODEC_REGISTRY_HELP}"
            );
        }
        Ok(Self { stochastic, ..spec })
    }

    /// Canonical codec name ([`StateCodec::name`] of the built codec).
    pub fn name(&self) -> String {
        let sr = if self.stochastic { "-sr" } else { "" };
        match self.bits {
            32 => "fp32".into(),
            16 => "bf16".into(),
            b => format!("q{b}-{}{sr}", self.mapping.name()),
        }
    }

    /// Build the codec. `seed` feeds the stochastic-rounding stream and is
    /// ignored by deterministic codecs.
    pub fn build(&self, seed: u64) -> Arc<dyn StateCodec> {
        match self.bits {
            32 => Arc::new(Fp32),
            16 => Arc::new(Bf16),
            b if self.stochastic => Arc::new(StochasticRound::new(self.mapping, b, seed)),
            b => Arc::new(BlockQuant::new(self.mapping, b)),
        }
    }
}

/// The per-run codec policy: role → spec entries (later entries override
/// earlier ones, so CLI overrides layer on top of TOML) plus the run seed
/// that stochastic-rounding buffers derive their streams from.
#[derive(Debug, Clone, Default)]
pub struct CodecPolicy {
    entries: Vec<(BufferRole, CodecSpec)>,
    seed: u64,
}

impl CodecPolicy {
    /// Policy from explicit entries and the run seed.
    pub fn new(entries: Vec<(BufferRole, CodecSpec)>, seed: u64) -> Self {
        Self { entries, seed }
    }

    /// Whether any role has a policy entry.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Add or override an entry (last write wins on lookup).
    pub fn set(&mut self, role: BufferRole, spec: CodecSpec) {
        self.entries.push((role, spec));
    }

    /// The effective entry for `role`, if any: the most recent exact-role
    /// entry, or — for the side roles — the most recent `eigen` entry.
    pub fn lookup(&self, role: BufferRole) -> Option<CodecSpec> {
        let last = |r: BufferRole| {
            self.entries.iter().rev().find(|(er, _)| *er == r).map(|&(_, s)| s)
        };
        last(role).or_else(|| {
            matches!(role, BufferRole::LeftSide | BufferRole::RightSide)
                .then(|| last(BufferRole::EigenVectors))
                .flatten()
        })
    }

    /// Resolve `role` to a spec: policy entry (with the `eigen` fallback for
    /// sides) or the caller's legacy single-knob `fallback`.
    pub fn resolve(&self, role: BufferRole, fallback: CodecSpec) -> CodecSpec {
        self.lookup(role).unwrap_or(fallback)
    }

    /// Resolve and build the codec for one buffer. Stochastic-rounding
    /// buffers get a role-distinct seed derived from the run seed through
    /// `util/rng.rs`, so every buffer draws an independent, reproducible
    /// rounding stream.
    pub fn codec(&self, role: BufferRole, fallback: CodecSpec) -> Arc<dyn StateCodec> {
        self.resolve(role, fallback).build(self.buffer_seed(role))
    }

    /// The derived stochastic-rounding seed for a role's buffer.
    pub fn buffer_seed(&self, role: BufferRole) -> u64 {
        Rng::new(self.seed).fork(role.seed_tag()).next_u64()
    }

    /// Canonical `role=codec` summary of the explicit entries, in fixed
    /// role order (m, v, left, right, eigen) so equal policies always
    /// produce equal strings — checkpoint-header observability; empty when
    /// no policy is set.
    pub fn summary(&self) -> String {
        let parts: Vec<String> = [
            BufferRole::Momentum,
            BufferRole::SecondMoment,
            BufferRole::LeftSide,
            BufferRole::RightSide,
            BufferRole::EigenVectors,
        ]
        .iter()
        .filter_map(|&r| {
            // only exact entries: the summary records what was configured,
            // resolution (eigen → sides) happens at build time
            self.entries
                .iter()
                .rev()
                .find(|(er, _)| *er == r)
                .map(|(_, s)| format!("{}={}", r.name(), s.name()))
        })
        .collect();
        parts.join(",")
    }
}

/// Parse one `role = "codec"` policy entry (shared by the TOML table and
/// the CLI override). The shorthand mapping default is role-dependent:
/// first-order roles default to `first_default`, second-order roles to
/// `second_default` — matching the legacy knobs they override.
pub fn parse_policy_entry(
    role_s: &str,
    spec_s: &str,
    first_default: Mapping,
    second_default: Mapping,
) -> Result<(BufferRole, CodecSpec)> {
    let role = BufferRole::parse(role_s)?;
    let default = if role.is_second_order() { second_default } else { first_default };
    let spec = CodecSpec::parse(spec_s.trim(), default)?;
    Ok((role, spec))
}

/// Parse a CLI `--quant-policy` value: comma-separated `role=codec` pairs,
/// e.g. `m=q4,v=q8` or `m=q4-dt-sr,eigen=q4-linear2`.
pub fn parse_policy_overrides(
    s: &str,
    first_default: Mapping,
    second_default: Mapping,
) -> Result<Vec<(BufferRole, CodecSpec)>> {
    let mut out = Vec::new();
    for pair in s.split(',').filter(|p| !p.trim().is_empty()) {
        let Some((role_s, spec_s)) = pair.split_once('=') else {
            bail!(
                "--quant-policy entry {pair:?} is not role=codec (e.g. m=q4,v=q8); {ROLE_HELP}"
            );
        };
        out.push(parse_policy_entry(role_s.trim(), spec_s, first_default, second_default)?);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roles_parse_with_aliases() {
        assert_eq!(BufferRole::parse("m").unwrap(), BufferRole::Momentum);
        assert_eq!(BufferRole::parse("momentum").unwrap(), BufferRole::Momentum);
        assert_eq!(BufferRole::parse("V").unwrap(), BufferRole::SecondMoment);
        assert_eq!(BufferRole::parse("eigenvectors").unwrap(), BufferRole::EigenVectors);
        let err = BufferRole::parse("w").unwrap_err().to_string();
        assert!(err.contains("second_moment"), "{err}");
    }

    #[test]
    fn specs_parse_shorthand_and_full_names() {
        let s = CodecSpec::parse("q4", Mapping::Dt).unwrap();
        assert_eq!((s.bits, s.mapping, s.stochastic), (4, Mapping::Dt, false));
        let s = CodecSpec::parse("q8-linear2", Mapping::Dt).unwrap();
        assert_eq!((s.bits, s.mapping), (8, Mapping::Linear2));
        let s = CodecSpec::parse("q4-dt-sr", Mapping::Linear2).unwrap();
        assert!(s.stochastic);
        assert_eq!(s.name(), "q4-dt-sr");
        let s = CodecSpec::parse("q4-sr", Mapping::Dt).unwrap();
        assert!(s.stochastic);
        assert_eq!(s.name(), "q4-dt-sr");
        assert_eq!(CodecSpec::parse("fp32", Mapping::Dt).unwrap().bits, 32);
        assert_eq!(CodecSpec::parse("bf16", Mapping::Dt).unwrap().bits, 16);
        for bad in ["q1", "q9-dt", "int8", "fp32-sr", "q4-bogus"] {
            let err = CodecSpec::parse(bad, Mapping::Dt).unwrap_err().to_string();
            assert!(err.contains("valid codecs"), "{bad}: {err}");
        }
    }

    #[test]
    fn built_codec_names_match_specs() {
        for name in ["fp32", "bf16", "q4-linear2", "q8-dt", "q4-dt-sr", "q3-linear2"] {
            let spec = CodecSpec::parse(name, Mapping::Dt).unwrap();
            assert_eq!(spec.build(0).name(), name, "spec {name}");
            assert_eq!(spec.name(), name);
        }
    }

    #[test]
    fn resolution_order_role_then_eigen_then_fallback() {
        let mut p = CodecPolicy::new(Vec::new(), 0);
        let fb = CodecSpec::plain(32, Mapping::Dt);
        // empty policy: everything falls back to the single knob
        assert_eq!(p.resolve(BufferRole::Momentum, fb), fb);
        assert_eq!(p.resolve(BufferRole::LeftSide, fb), fb);
        // eigen covers both sides...
        p.set(BufferRole::EigenVectors, CodecSpec::parse("q4-linear2", Mapping::Dt).unwrap());
        assert_eq!(p.resolve(BufferRole::LeftSide, fb).name(), "q4-linear2");
        assert_eq!(p.resolve(BufferRole::RightSide, fb).name(), "q4-linear2");
        // ...but an exact side entry wins over eigen
        p.set(BufferRole::LeftSide, CodecSpec::parse("bf16", Mapping::Dt).unwrap());
        assert_eq!(p.resolve(BufferRole::LeftSide, fb).name(), "bf16");
        assert_eq!(p.resolve(BufferRole::RightSide, fb).name(), "q4-linear2");
        // first-order roles never see the eigen entry
        assert_eq!(p.resolve(BufferRole::Momentum, fb), fb);
        // later entries override earlier ones (CLI over TOML)
        p.set(BufferRole::LeftSide, CodecSpec::parse("fp32", Mapping::Dt).unwrap());
        assert_eq!(p.resolve(BufferRole::LeftSide, fb).name(), "fp32");
    }

    #[test]
    fn cli_overrides_parse() {
        let entries = parse_policy_overrides("m=q4,v=q8", Mapping::Dt, Mapping::Linear2).unwrap();
        assert_eq!(entries.len(), 2);
        assert_eq!(entries[0].0, BufferRole::Momentum);
        assert_eq!(entries[0].1.name(), "q4-dt");
        assert_eq!(entries[1].1.name(), "q8-dt");
        // second-order shorthand takes the second-order default mapping
        let entries =
            parse_policy_overrides("eigen=q4", Mapping::Dt, Mapping::Linear2).unwrap();
        assert_eq!(entries[0].1.name(), "q4-linear2");
        assert!(parse_policy_overrides("m:q4", Mapping::Dt, Mapping::Dt).is_err());
        assert!(parse_policy_overrides("w=q4", Mapping::Dt, Mapping::Dt).is_err());
        assert!(parse_policy_overrides("", Mapping::Dt, Mapping::Dt).unwrap().is_empty());
    }

    #[test]
    fn buffer_seeds_are_role_distinct_and_reproducible() {
        let p = CodecPolicy::new(Vec::new(), 7);
        let q = CodecPolicy::new(Vec::new(), 7);
        assert_eq!(p.buffer_seed(BufferRole::Momentum), q.buffer_seed(BufferRole::Momentum));
        assert_ne!(
            p.buffer_seed(BufferRole::Momentum),
            p.buffer_seed(BufferRole::SecondMoment)
        );
        let r = CodecPolicy::new(Vec::new(), 8);
        assert_ne!(p.buffer_seed(BufferRole::Momentum), r.buffer_seed(BufferRole::Momentum));
    }

    #[test]
    fn summary_is_canonical() {
        let mut p = CodecPolicy::new(Vec::new(), 0);
        assert_eq!(p.summary(), "");
        p.set(BufferRole::SecondMoment, CodecSpec::parse("q8", Mapping::Dt).unwrap());
        p.set(BufferRole::Momentum, CodecSpec::parse("q4", Mapping::Dt).unwrap());
        assert_eq!(p.summary(), "m=q4-dt,v=q8-dt");
        // override keeps one entry per role
        p.set(BufferRole::Momentum, CodecSpec::parse("fp32", Mapping::Dt).unwrap());
        assert_eq!(p.summary(), "m=fp32,v=q8-dt");
    }
}
