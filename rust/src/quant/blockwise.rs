//! Block-wise quantizer — exact Rust mirror of the L1 Pallas kernel
//! (python/compile/kernels/quant.py): absmax normalization per block of 64,
//! nearest-codebook-entry argmin with lowest-index ties.
//!
//! Used (a) natively by the error-analysis harness (8-bit rows of Table 7
//! never touch artifacts) and (b) by the coordinator to create/unpack the
//! packed state buffers it feeds the artifacts.

use super::codebook::Boundaries;
use super::pack::{pack_bits, packed_len, unpack_bits, unpack_bits_into};

/// Default quantization block length (paper §3.3; matches the kernels).
pub const BLOCK: usize = 64;

/// Quantized vector: packed codes + one f32 scale per block.
#[derive(Debug, Clone)]
pub struct QuantizedVec {
    /// Codes packed at true bitwidth.
    pub packed: Vec<u8>,
    /// Per-block absmax scales.
    pub scales: Vec<f32>,
    /// Original element count.
    pub len: usize,
    /// Bits per code.
    pub bits: u32,
    /// Block length the scales apply to.
    pub block: usize,
}

impl QuantizedVec {
    /// Exact storage bytes of this state (the paper's memory accounting).
    pub fn state_bytes(&self) -> usize {
        self.packed.len() + self.scales.len() * 4
    }

    /// Unpack codes to one-per-byte (artifact boundary format).
    pub fn codes_u8(&self) -> Vec<u8> {
        unpack_bits(&self.packed, self.bits, self.len)
    }
}

/// Quantize with blocks of `block` consecutive elements. Matrix callers
/// arrange column-major layout so blocks stay within one column of an
/// eigenvector matrix (paper §3.3); a trailing partial block (flat
/// first-order moments whose length is not a block multiple) carries its
/// own scale.
///
/// This is the chunked encode hot path: per block the elements are
/// normalized into a flat block-major scratch lane, codes come from the
/// branch-free [`Boundaries::nearest_block`] kernel, and the whole code
/// vector is packed in one batched [`pack_bits`] call. Bit-identical to
/// [`quantize_scalar`] (property-tested), just auto-vectorizable.
pub fn quantize(x: &[f32], cb: &[f32], bits: u32, block: usize) -> QuantizedVec {
    assert!(block >= 1, "block must be >= 1");
    assert!(cb.len() >= (1usize << bits));
    let bounds = Boundaries::new(cb);
    let mut codes = vec![0u8; x.len()];
    let mut scales = Vec::with_capacity(x.len().div_ceil(block));
    let mut normed = vec![0.0f32; block.min(x.len())];
    for (blk, cblk) in x.chunks(block).zip(codes.chunks_mut(block)) {
        let absmax = blk.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        let scale = if absmax > 0.0 { absmax } else { 1.0 };
        let inv = 1.0 / scale;
        scales.push(scale);
        // same arithmetic as the scalar path (v * inv, then the strict
        // midpoint compare) so codes cannot drift by rounding
        let lane = &mut normed[..blk.len()];
        for (n, &v) in lane.iter_mut().zip(blk) {
            *n = v * inv;
        }
        bounds.nearest_block(lane, cblk);
    }
    QuantizedVec {
        packed: pack_bits(&codes, bits),
        scales,
        len: x.len(),
        bits,
        block,
    }
}

/// Reference scalar encoder (the pre-chunking implementation): one
/// element at a time through [`Boundaries::nearest`]. Kept as the
/// equivalence baseline for the chunked [`quantize`] — property tests
/// assert bit-identical output, `hotpath_micro` benchmarks the gap.
pub fn quantize_scalar(x: &[f32], cb: &[f32], bits: u32, block: usize) -> QuantizedVec {
    assert!(block >= 1, "block must be >= 1");
    assert!(cb.len() >= (1usize << bits));
    let mut codes = Vec::with_capacity(x.len());
    let mut scales = Vec::with_capacity(x.len().div_ceil(block));
    let bounds = Boundaries::new(cb);
    for blk in x.chunks(block) {
        let absmax = blk.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        let scale = if absmax > 0.0 { absmax } else { 1.0 };
        let inv = 1.0 / scale;
        scales.push(scale);
        for &v in blk {
            codes.push(bounds.nearest(v * inv));
        }
    }
    QuantizedVec {
        packed: pack_bits(&codes, bits),
        scales,
        len: x.len(),
        bits,
        block,
    }
}

/// Stochastic-rounding quantize (SOLO / "Pushing the Limits of Low-Bit
/// Optimizers" regime): instead of rounding to the nearest codebook entry,
/// each normalized value rounds *up* to its bracketing entry with
/// probability equal to the distance fraction, so the expected dequantized
/// value equals the input inside the codebook's range. The caller owns the
/// RNG — fixed seed ⇒ exactly reproducible codes ([`StochasticRound`]
/// derives one stream per buffer).
///
/// [`StochasticRound`]: super::codec::StochasticRound
pub fn quantize_stochastic(
    x: &[f32],
    cb: &[f32],
    bits: u32,
    block: usize,
    rng: &mut crate::util::rng::Rng,
) -> QuantizedVec {
    assert!(block >= 1, "block must be >= 1");
    assert!(cb.len() >= (1usize << bits));
    let bounds = Boundaries::new(cb);
    let mut codes = Vec::with_capacity(x.len());
    let mut scales = Vec::with_capacity(x.len().div_ceil(block));
    for blk in x.chunks(block) {
        let absmax = blk.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        let scale = if absmax > 0.0 { absmax } else { 1.0 };
        let inv = 1.0 / scale;
        scales.push(scale);
        for &v in blk {
            let (lo, hi, p) = bounds.stochastic_pair(v * inv);
            let up = (rng.uniform() as f32) < p;
            codes.push(if up { hi } else { lo });
        }
    }
    QuantizedVec {
        packed: pack_bits(&codes, bits),
        scales,
        len: x.len(),
        bits,
        block,
    }
}

/// Dequantize: R(codes) ⊙ scales.
///
/// Chunked decode hot path: batched unpack into a flat code scratch, then a
/// per-block multiply lane against a 256-entry lookup table (a `u8` code
/// indexes it with no bounds check, so the loop is branch-free and
/// auto-vectorizable). No per-element `i / block` division, no `Vec::push`.
pub fn dequantize(q: &QuantizedVec, cb: &[f32]) -> Vec<f32> {
    let mut table = [0.0f32; 256];
    let k = cb.len().min(256);
    table[..k].copy_from_slice(&cb[..k]);
    let mut codes = vec![0u8; q.len];
    unpack_bits_into(&q.packed, q.bits, &mut codes);
    let mut out = vec![0.0f32; q.len];
    for ((oblk, cblk), &scale) in
        out.chunks_mut(q.block).zip(codes.chunks(q.block)).zip(&q.scales)
    {
        for (o, &c) in oblk.iter_mut().zip(cblk) {
            *o = table[c as usize] * scale;
        }
    }
    out
}

/// Reference scalar decoder (the pre-chunking implementation) — the
/// equivalence baseline for the chunked [`dequantize`].
pub fn dequantize_scalar(q: &QuantizedVec, cb: &[f32]) -> Vec<f32> {
    let codes = q.codes_u8();
    let mut out = Vec::with_capacity(q.len);
    for (i, &c) in codes.iter().enumerate() {
        out.push(cb[c as usize] * q.scales[i / q.block]);
    }
    out
}

/// Quantize a square order-n matrix (row-major) with blocks running down
/// columns (§3.3): we quantize the transpose's rows. Block = min(64, n).
pub fn quantize_matrix_cols(a: &[f32], n: usize, cb: &[f32], bits: u32) -> QuantizedVec {
    assert_eq!(a.len(), n * n);
    let block = BLOCK.min(n);
    // matrices must fill whole blocks (flat vectors may end with a partial
    // block, but the (nblocks, block) artifact grid cannot)
    assert_eq!(a.len() % block, 0, "len {} % block {block}", a.len());
    // transpose to column-major so each block of 64 is within a column
    let mut t = vec![0.0f32; n * n];
    for i in 0..n {
        for j in 0..n {
            t[j * n + i] = a[i * n + j];
        }
    }
    quantize(&t, cb, bits, block)
}

/// Inverse of `quantize_matrix_cols`: returns row-major order-n matrix.
pub fn dequantize_matrix_cols(q: &QuantizedVec, n: usize, cb: &[f32]) -> Vec<f32> {
    let t = dequantize(q, cb);
    let mut a = vec![0.0f32; n * n];
    for j in 0..n {
        for i in 0..n {
            a[i * n + j] = t[j * n + i];
        }
    }
    a
}

/// Memory model: bytes for an order-n matrix state at `bits` with per-block
/// f32 scales — the "32/(4+0.5) ≈ 7x" arithmetic of Appendix G.
pub fn matrix_state_bytes(n: usize, bits: u32, block: usize) -> usize {
    let elems = n * n;
    packed_len(elems, bits) + elems.div_ceil(block.min(n).max(1)) * 4
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::codebook::{codebook, Mapping};
    use crate::util::prop;

    #[test]
    fn roundtrip_error_bounded() {
        let cb = codebook(Mapping::Linear2, 4);
        let max_gap = cb.windows(2).map(|w| w[1] - w[0]).fold(0.0f32, f32::max);
        prop::check("quantize roundtrip bound", 20, |rng| {
            let nblocks = 1 + rng.below(8);
            let x: Vec<f32> = (0..nblocks * 64).map(|_| rng.normal_f32()).collect();
            let q = quantize(&x, &cb, 4, 64);
            let d = dequantize(&q, &cb);
            for (b, chunk) in x.chunks(64).enumerate() {
                let scale = q.scales[b];
                for (i, (&xv, &dv)) in chunk.iter().zip(&d[b * 64..]).enumerate() {
                    let bound = 0.5 * max_gap * scale + 1e-6;
                    if (xv - dv).abs() > bound {
                        return Err(format!("block {b} elem {i}: {xv} vs {dv}"));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn zero_blocks_are_exact() {
        let cb = codebook(Mapping::Linear2, 4);
        let x = vec![0.0f32; 128];
        let q = quantize(&x, &cb, 4, 64);
        assert_eq!(q.scales, vec![1.0, 1.0]);
        assert_eq!(dequantize(&q, &cb), x);
    }

    #[test]
    fn trailing_partial_block_gets_own_scale() {
        let cb = codebook(Mapping::Linear2, 4);
        let mut x = vec![0.01f32; 100]; // one full block + a 36-element tail
        x[99] = 50.0; // huge tail entry must not pollute the first block
        let q = quantize(&x, &cb, 4, 64);
        assert_eq!(q.scales.len(), 2);
        assert_eq!(q.state_bytes(), 50 + 2 * 4);
        let d = dequantize(&q, &cb);
        for i in 0..64 {
            assert!((d[i] - 0.01).abs() < 0.005, "elem {i}: {}", d[i]);
        }
        assert!((d[99] - 50.0).abs() < 1.0, "{}", d[99]);
    }

    #[test]
    fn state_bytes_accounting() {
        let cb = codebook(Mapping::Linear2, 4);
        let x = vec![0.5f32; 64 * 64];
        let q = quantize(&x, &cb, 4, 64);
        // 4096 codes at 4-bit = 2048 bytes; 64 scales * 4 = 256 bytes
        assert_eq!(q.state_bytes(), 2048 + 256);
        assert_eq!(matrix_state_bytes(64, 4, 64), 2048 + 256);
        // the Appendix-G ratio: 32-bit / (4-bit + 0.5 overhead) ≈ 7.1x
        let fp32 = 64 * 64 * 4;
        let ratio = fp32 as f64 / q.state_bytes() as f64;
        assert!((ratio - 7.1).abs() < 0.2, "{ratio}");
    }

    #[test]
    fn matrix_cols_roundtrip_matches_python_layout() {
        // column with huge entry must not pollute other columns (same test
        // as python tests/test_quant_kernels.py::test_column_blocking)
        let cb = codebook(Mapping::Linear2, 4);
        let n = 64;
        let mut a = vec![0.01f32; n * n];
        a[0] = 100.0; // a[0,0]
        let q = quantize_matrix_cols(&a, n, &cb, 4);
        let d = dequantize_matrix_cols(&q, n, &cb);
        for i in 0..n {
            for j in 1..n {
                assert!((d[i * n + j] - 0.01).abs() < 0.005, "({i},{j})");
            }
        }
    }

    #[test]
    fn three_bit_roundtrip() {
        let cb = codebook(Mapping::Dt, 3);
        prop::check("3-bit roundtrip stores 3 bits", 10, |rng| {
            let x: Vec<f32> = (0..128).map(|_| rng.normal_f32()).collect();
            let q = quantize(&x, &cb, 3, 64);
            if q.packed.len() != 48 {
                return Err(format!("packed {} bytes", q.packed.len()));
            }
            let d = dequantize(&q, &cb);
            // every dequantized value is a scaled codebook entry
            for (b, chunk) in d.chunks(64).enumerate() {
                for &v in chunk {
                    let normed = v / q.scales[b];
                    if !cb.iter().any(|&c| (c - normed).abs() < 1e-5) {
                        return Err(format!("{normed} not in codebook"));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn chunked_matches_scalar_bit_for_bit() {
        // the chunked encode/decode kernels are a pure performance rewrite:
        // packed bytes, scales, and decoded values must be identical to the
        // scalar reference at every bitwidth, block size, and odd length
        for (mapping, bits) in
            [(Mapping::Linear2, 4u32), (Mapping::Dt, 3), (Mapping::Dt, 8), (Mapping::Dt, 2)]
        {
            let cb = codebook(mapping, bits);
            prop::check(&format!("chunked == scalar {mapping:?}/{bits}"), 15, |rng| {
                let n = 1 + rng.below(400);
                let block = [7, 32, 64, 100][rng.below(4)];
                let x: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
                let q = quantize(&x, &cb, bits, block);
                let qs = quantize_scalar(&x, &cb, bits, block);
                if q.packed != qs.packed || q.scales != qs.scales {
                    return Err(format!("encode diverged at n={n} block={block}"));
                }
                let d = dequantize(&q, &cb);
                let ds = dequantize_scalar(&qs, &cb);
                let bits_of = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
                if bits_of(&d) != bits_of(&ds) {
                    return Err(format!("decode diverged at n={n} block={block}"));
                }
                Ok(())
            });
        }
    }

    #[test]
    fn stochastic_quantize_is_seeded_and_in_book() {
        let cb = codebook(Mapping::Linear2, 4);
        let mut rng_a = crate::util::rng::Rng::new(5);
        let mut rng_b = crate::util::rng::Rng::new(5);
        let mut data_rng = crate::util::rng::Rng::new(6);
        let x: Vec<f32> = (0..200).map(|_| data_rng.normal_f32()).collect();
        let qa = quantize_stochastic(&x, &cb, 4, 64, &mut rng_a);
        let qb = quantize_stochastic(&x, &cb, 4, 64, &mut rng_b);
        // fixed seed ⇒ identical codes
        assert_eq!(qa.packed, qb.packed);
        assert_eq!(qa.scales, qb.scales);
        // every decoded value is a scaled codebook entry
        let d = dequantize(&qa, &cb);
        for (b, chunk) in d.chunks(64).enumerate() {
            for &v in chunk {
                let normed = v / qa.scales[b];
                assert!(cb.iter().any(|&c| (c - normed).abs() < 1e-6), "{normed}");
            }
        }
        // a different seed draws a different rounding stream
        let mut rng_c = crate::util::rng::Rng::new(99);
        let qc = quantize_stochastic(&x, &cb, 4, 64, &mut rng_c);
        assert_ne!(qa.packed, qc.packed, "distinct seeds should round differently");
    }

    #[test]
    fn eight_bit_much_tighter_than_four() {
        let cb8 = codebook(Mapping::Dt, 8);
        let cb4 = codebook(Mapping::Dt, 4);
        let mut rng = crate::util::rng::Rng::new(3);
        let x: Vec<f32> = (0..256).map(|_| rng.normal_f32()).collect();
        let err = |bits: u32, cb: &[f32]| {
            let q = quantize(&x, cb, bits, 64);
            let d = dequantize(&q, cb);
            x.iter()
                .zip(&d)
                .map(|(a, b)| ((a - b) as f64).powi(2))
                .sum::<f64>()
                .sqrt()
        };
        assert!(err(8, &cb8) < 0.2 * err(4, &cb4));
    }
}
