//! Block-wise quantizer — exact Rust mirror of the L1 Pallas kernel
//! (python/compile/kernels/quant.py): absmax normalization per block of 64,
//! nearest-codebook-entry argmin with lowest-index ties.
//!
//! Used (a) natively by the error-analysis harness (8-bit rows of Table 7
//! never touch artifacts) and (b) by the coordinator to create/unpack the
//! packed state buffers it feeds the artifacts.
//!
//! Three interchangeable encode/decode arms share one contract:
//!  * **scalar** — the reference implementation, one element at a time;
//!  * **chunked** — branch-free block lanes that auto-vectorize;
//!  * **simd** (`--features simd`) — explicit vector kernels behind the
//!    lane registry in `quant::simd` (SSE2/AVX2 on x86_64, NEON on
//!    aarch64, plus portable SWAR packs); the active lane is resolved
//!    once per encode/decode call, and `_lane` twins
//!    (`try_quantize_lane_layout`, `dequantize_lane`,
//!    `try_quantize_stochastic_lane`) pin a specific lane for tests
//!    and benches.
//!
//! The property suite asserts scalar == chunked == *every detected SIMD
//! lane* bit-for-bit (packed bytes, scales, decoded values) at every
//! bitwidth, mapping, block size, and odd length — the N-way equivalence
//! contract; `quantize`/`dequantize` dispatch to the fastest arm
//! compiled in.
//!
//! Non-finite inputs are a typed error, not silent corruption: a NaN
//! element would vanish from the absmax fold (`f32::max` drops NaN) and
//! encode as code 0, and an Inf element would drive `scale = inf`,
//! `inv = 0`, collapsing its whole block to `nearest(0.0)`. Every encoder
//! arm therefore gates each block on finiteness and returns
//! [`QuantError::NonFinite`] (the infallible wrappers panic with the same
//! message — fail loud, never corrupt).

use super::codebook::Boundaries;
use super::pack::{pack_bits_chunked, packed_len, unpack_bits, unpack_bits_into_chunked};

/// Default quantization block length (paper §3.3; matches the kernels).
pub const BLOCK: usize = 64;

/// Smallest divisor block [`matrix_layout`] will accept before falling back
/// to per-column chunking: a tiny block means one f32 scale per few
/// elements, which defeats the Appendix-G memory arithmetic (a 1-element
/// block stores *more* than fp32).
pub const MATRIX_BLOCK_MIN: usize = 8;

/// Typed quantization error.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum QuantError {
    /// A block contained NaN or ±Inf; encoding it would silently corrupt
    /// the whole block (see the module docs), so the encoder refuses.
    NonFinite {
        /// Index of the offending block (scale slot).
        block: usize,
        /// Flat element index of the first non-finite value.
        index: usize,
        /// The offending value (NaN or ±Inf).
        value: f32,
    },
}

impl std::fmt::Display for QuantError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            QuantError::NonFinite { block, index, value } => write!(
                f,
                "non-finite value {value} at element {index} (block {block}): \
                 refusing to quantize — NaN/Inf would silently corrupt the block"
            ),
        }
    }
}

impl std::error::Error for QuantError {}

/// Quantized vector: packed codes + one f32 scale per block.
#[derive(Debug, Clone)]
pub struct QuantizedVec {
    /// Codes packed at true bitwidth.
    pub packed: Vec<u8>,
    /// Per-block absmax scales.
    pub scales: Vec<f32>,
    /// Original element count.
    pub len: usize,
    /// Bits per code.
    pub bits: u32,
    /// Block length the scales apply to.
    pub block: usize,
    /// Column-chunked layout: `Some(c)` means the flat data is a sequence
    /// of length-`c` columns and blocks restart at every column boundary
    /// (each column ends with its own partial block). `None` is the flat
    /// layout: consecutive blocks of `block` with at most one trailing
    /// partial. [`matrix_layout`] picks `Some` only when no usable divisor
    /// block exists (e.g. prime n > 64), keeping §3.3's one-column-per-block
    /// contract on every matrix shape.
    pub col: Option<usize>,
}

impl QuantizedVec {
    /// Exact storage bytes of this state (the paper's memory accounting).
    pub fn state_bytes(&self) -> usize {
        self.packed.len() + self.scales.len() * 4
    }

    /// Unpack codes to one-per-byte (artifact boundary format).
    pub fn codes_u8(&self) -> Vec<u8> {
        unpack_bits(&self.packed, self.bits, self.len)
    }
}

/// Visit every quantization block of a layout as `(block_index, start,
/// len)`, stopping at the first error. With `col: Some(c)` blocks restart
/// at each column boundary; `c` must divide `len`.
fn try_for_blocks<E>(
    len: usize,
    block: usize,
    col: Option<usize>,
    mut f: impl FnMut(usize, usize, usize) -> Result<(), E>,
) -> Result<(), E> {
    if len == 0 {
        return Ok(());
    }
    let seg = match col {
        Some(c) => {
            assert!(c > 0 && len % c == 0, "column {c} must divide len {len}");
            c
        }
        None => len,
    };
    let mut bi = 0usize;
    let mut seg_start = 0usize;
    while seg_start < len {
        let seg_end = (seg_start + seg).min(len);
        let mut s = seg_start;
        while s < seg_end {
            let blen = block.min(seg_end - s);
            f(bi, s, blen)?;
            bi += 1;
            s += blen;
        }
        seg_start = seg_end;
    }
    Ok(())
}

/// Infallible [`try_for_blocks`].
fn for_blocks(
    len: usize,
    block: usize,
    col: Option<usize>,
    mut f: impl FnMut(usize, usize, usize),
) {
    let _ = try_for_blocks(len, block, col, |bi, s, l| {
        f(bi, s, l);
        Ok::<(), std::convert::Infallible>(())
    });
}

/// Number of scales a layout produces (exact, including partial blocks).
pub fn layout_scale_count(len: usize, block: usize, col: Option<usize>) -> usize {
    match col {
        None => len.div_ceil(block),
        Some(c) => (len / c) * c.div_ceil(block),
    }
}

/// Locate the first non-finite element of a block for the error report.
fn nonfinite_err(blk: &[f32], block: usize, start: usize) -> QuantError {
    for (i, &v) in blk.iter().enumerate() {
        if !v.is_finite() {
            return QuantError::NonFinite { block, index: start + i, value: v };
        }
    }
    QuantError::NonFinite { block, index: start, value: f32::NAN }
}

/// Branch-free finiteness gate: `v * 0.0` is ±0.0 for every finite `v` and
/// NaN for NaN/±Inf, and NaN propagates through the sum — so the fold is
/// 0.0 iff the block is entirely finite (LLVM cannot fold `x * 0.0` away
/// without fast-math, which this crate never enables).
fn block_is_finite(blk: &[f32]) -> bool {
    let mut nf = 0.0f32;
    for &v in blk {
        nf += v * 0.0;
    }
    nf == 0.0
}

// ---------------------------------------------------------------------------
// encode arms
// ---------------------------------------------------------------------------

/// Quantize with blocks of `block` consecutive elements — dispatches to the
/// SIMD arm when compiled with `--features simd`, the chunked arm
/// otherwise (all arms are bit-identical). Matrix callers arrange
/// column-major layout so blocks stay within one column of an eigenvector
/// matrix (paper §3.3); a trailing partial block (flat first-order moments
/// whose length is not a block multiple) carries its own scale.
///
/// # Panics
/// On non-finite input (NaN/±Inf), with the [`QuantError::NonFinite`]
/// message. Use [`try_quantize`] to handle the error instead.
pub fn quantize(x: &[f32], cb: &[f32], bits: u32, block: usize) -> QuantizedVec {
    try_quantize(x, cb, bits, block).unwrap_or_else(|e| panic!("{e}"))
}

/// Fallible [`quantize`]: returns [`QuantError::NonFinite`] instead of
/// silently corrupting blocks that contain NaN/±Inf.
pub fn try_quantize(
    x: &[f32],
    cb: &[f32],
    bits: u32,
    block: usize,
) -> Result<QuantizedVec, QuantError> {
    try_quantize_layout(x, cb, bits, block, None)
}

/// [`try_quantize`] with an explicit column layout (see
/// [`QuantizedVec::col`]) — the matrix entry point.
pub fn try_quantize_layout(
    x: &[f32],
    cb: &[f32],
    bits: u32,
    block: usize,
    col: Option<usize>,
) -> Result<QuantizedVec, QuantError> {
    #[cfg(feature = "simd")]
    {
        try_quantize_simd_layout(x, cb, bits, block, col)
    }
    #[cfg(not(feature = "simd"))]
    {
        try_quantize_chunked_layout(x, cb, bits, block, col)
    }
}

/// Chunked encode arm (infallible wrapper — panics on non-finite input).
pub fn quantize_chunked(x: &[f32], cb: &[f32], bits: u32, block: usize) -> QuantizedVec {
    try_quantize_chunked(x, cb, bits, block).unwrap_or_else(|e| panic!("{e}"))
}

/// Chunked encode arm: per block the elements are normalized into a flat
/// block-major scratch lane, codes come from the branch-free
/// [`Boundaries::nearest_block`] kernel, and the whole code vector is
/// packed in one batched [`pack_bits_chunked`] call. Bit-identical to
/// [`try_quantize_scalar`] (property-tested), just auto-vectorizable.
pub fn try_quantize_chunked(
    x: &[f32],
    cb: &[f32],
    bits: u32,
    block: usize,
) -> Result<QuantizedVec, QuantError> {
    try_quantize_chunked_layout(x, cb, bits, block, None)
}

/// [`try_quantize_chunked`] with an explicit column layout.
pub fn try_quantize_chunked_layout(
    x: &[f32],
    cb: &[f32],
    bits: u32,
    block: usize,
    col: Option<usize>,
) -> Result<QuantizedVec, QuantError> {
    assert!(block >= 1, "block must be >= 1");
    assert!(cb.len() >= (1usize << bits));
    let bounds = Boundaries::new(cb);
    let mut codes = vec![0u8; x.len()];
    let mut scales = Vec::with_capacity(layout_scale_count(x.len(), block, col));
    let mut normed = vec![0.0f32; block.min(x.len())];
    try_for_blocks(x.len(), block, col, |bi, start, blen| {
        let blk = &x[start..start + blen];
        if !block_is_finite(blk) {
            return Err(nonfinite_err(blk, bi, start));
        }
        let absmax = blk.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        let scale = if absmax > 0.0 { absmax } else { 1.0 };
        let inv = 1.0 / scale;
        scales.push(scale);
        // same arithmetic as the scalar path (v * inv, then the strict
        // midpoint compare) so codes cannot drift by rounding
        let lane = &mut normed[..blen];
        for (n, &v) in lane.iter_mut().zip(blk) {
            *n = v * inv;
        }
        bounds.nearest_block(lane, &mut codes[start..start + blen]);
        Ok(())
    })?;
    Ok(QuantizedVec {
        packed: pack_bits_chunked(&codes, bits),
        scales,
        len: x.len(),
        bits,
        block,
        col,
    })
}

/// SIMD encode arm (infallible wrapper — panics on non-finite input).
#[cfg(feature = "simd")]
pub fn quantize_simd(x: &[f32], cb: &[f32], bits: u32, block: usize) -> QuantizedVec {
    try_quantize_simd(x, cb, bits, block).unwrap_or_else(|e| panic!("{e}"))
}

/// SIMD encode arm: absmax / finiteness / normalize run through the f32
/// lanes in [`simd`](super::simd), nearest codes through
/// [`Boundaries::nearest_block_simd`], packing through the SIMD/SWAR pack
/// lanes. Bit-identical to the scalar and chunked arms (property-tested).
#[cfg(feature = "simd")]
pub fn try_quantize_simd(
    x: &[f32],
    cb: &[f32],
    bits: u32,
    block: usize,
) -> Result<QuantizedVec, QuantError> {
    try_quantize_simd_layout(x, cb, bits, block, None)
}

/// [`try_quantize_simd`] with an explicit column layout — resolves
/// [`active_lane`](super::simd::active_lane) once per call, so the hot
/// loop never re-reads the registry.
#[cfg(feature = "simd")]
pub fn try_quantize_simd_layout(
    x: &[f32],
    cb: &[f32],
    bits: u32,
    block: usize,
    col: Option<usize>,
) -> Result<QuantizedVec, QuantError> {
    try_quantize_lane_layout(x, cb, bits, block, col, super::simd::active_lane())
}

/// Lane-forced encode (infallible wrapper — panics on non-finite input).
#[cfg(feature = "simd")]
pub fn quantize_lane(
    x: &[f32],
    cb: &[f32],
    bits: u32,
    block: usize,
    lane: super::simd::Lane,
) -> QuantizedVec {
    try_quantize_lane_layout(x, cb, bits, block, None, lane).unwrap_or_else(|e| panic!("{e}"))
}

/// [`try_quantize_simd_layout`] on an explicit [`Lane`](super::simd::Lane)
/// — how the N-way property suite and the `quant_simd` harness pin lanes
/// regardless of what the host dispatcher would pick. Every lane is
/// bit-identical to the scalar/chunked arms (property-tested).
#[cfg(feature = "simd")]
pub fn try_quantize_lane_layout(
    x: &[f32],
    cb: &[f32],
    bits: u32,
    block: usize,
    col: Option<usize>,
    lane: super::simd::Lane,
) -> Result<QuantizedVec, QuantError> {
    use super::simd;
    assert!(block >= 1, "block must be >= 1");
    assert!(cb.len() >= (1usize << bits));
    let bounds = Boundaries::new(cb);
    let mut codes = vec![0u8; x.len()];
    let mut scales = Vec::with_capacity(layout_scale_count(x.len(), block, col));
    let mut normed = vec![0.0f32; block.min(x.len())];
    try_for_blocks(x.len(), block, col, |bi, start, blen| {
        let blk = &x[start..start + blen];
        if !simd::all_finite_with(lane, blk) {
            return Err(nonfinite_err(blk, bi, start));
        }
        let absmax = simd::absmax_with(lane, blk);
        let scale = if absmax > 0.0 { absmax } else { 1.0 };
        let inv = 1.0 / scale;
        scales.push(scale);
        let buf = &mut normed[..blen];
        simd::normalize_into_with(lane, blk, inv, buf);
        bounds.nearest_block_simd(lane, buf, &mut codes[start..start + blen]);
        Ok(())
    })?;
    Ok(QuantizedVec {
        packed: simd::pack_bits_lane(lane, &codes, bits),
        scales,
        len: x.len(),
        bits,
        block,
        col,
    })
}

/// Reference scalar encoder (the pre-chunking implementation): one
/// element at a time through [`Boundaries::nearest`]. Kept as the
/// equivalence baseline for the chunked and SIMD arms — property tests
/// assert bit-identical output, the throughput harness benchmarks the gap.
///
/// # Panics
/// On non-finite input; use [`try_quantize_scalar`] to handle the error.
pub fn quantize_scalar(x: &[f32], cb: &[f32], bits: u32, block: usize) -> QuantizedVec {
    try_quantize_scalar(x, cb, bits, block).unwrap_or_else(|e| panic!("{e}"))
}

/// Fallible [`quantize_scalar`].
pub fn try_quantize_scalar(
    x: &[f32],
    cb: &[f32],
    bits: u32,
    block: usize,
) -> Result<QuantizedVec, QuantError> {
    try_quantize_scalar_layout(x, cb, bits, block, None)
}

/// [`try_quantize_scalar`] with an explicit column layout.
pub fn try_quantize_scalar_layout(
    x: &[f32],
    cb: &[f32],
    bits: u32,
    block: usize,
    col: Option<usize>,
) -> Result<QuantizedVec, QuantError> {
    assert!(block >= 1, "block must be >= 1");
    assert!(cb.len() >= (1usize << bits));
    let mut codes = vec![0u8; x.len()];
    let mut scales = Vec::with_capacity(layout_scale_count(x.len(), block, col));
    let bounds = Boundaries::new(cb);
    try_for_blocks(x.len(), block, col, |bi, start, blen| {
        let blk = &x[start..start + blen];
        if !block_is_finite(blk) {
            return Err(nonfinite_err(blk, bi, start));
        }
        let absmax = blk.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        let scale = if absmax > 0.0 { absmax } else { 1.0 };
        let inv = 1.0 / scale;
        scales.push(scale);
        for (c, &v) in codes[start..start + blen].iter_mut().zip(blk) {
            *c = bounds.nearest(v * inv);
        }
        Ok(())
    })?;
    Ok(QuantizedVec {
        packed: pack_bits_chunked(&codes, bits),
        scales,
        len: x.len(),
        bits,
        block,
        col,
    })
}

/// Stochastic-rounding quantize (SOLO / "Pushing the Limits of Low-Bit
/// Optimizers" regime): instead of rounding to the nearest codebook entry,
/// each normalized value rounds *up* to its bracketing entry with
/// probability equal to the distance fraction, so the expected dequantized
/// value equals the input inside the codebook's range. The caller owns the
/// RNG — fixed seed ⇒ exactly reproducible codes ([`StochasticRound`]
/// derives one stream per buffer).
///
/// # Panics
/// On non-finite input; use [`try_quantize_stochastic`] to handle it.
///
/// [`StochasticRound`]: super::codec::StochasticRound
pub fn quantize_stochastic(
    x: &[f32],
    cb: &[f32],
    bits: u32,
    block: usize,
    rng: &mut crate::util::rng::Rng,
) -> QuantizedVec {
    try_quantize_stochastic(x, cb, bits, block, rng).unwrap_or_else(|e| panic!("{e}"))
}

/// Fallible [`quantize_stochastic`]: same finiteness gate as the
/// deterministic arms. The RNG stream position is only advanced for
/// blocks that pass the gate, and the error is returned before any draw
/// for the offending block.
///
/// Dispatches to the active-lane SIMD arm under `--features simd` (the
/// bracket + fraction pass vectorizes; the per-element uniform draw stays
/// in element order, so any lane reproduces the scalar stream bit-for-bit
/// from the same seed), and to the scalar reference otherwise.
pub fn try_quantize_stochastic(
    x: &[f32],
    cb: &[f32],
    bits: u32,
    block: usize,
    rng: &mut crate::util::rng::Rng,
) -> Result<QuantizedVec, QuantError> {
    #[cfg(feature = "simd")]
    {
        try_quantize_stochastic_lane(x, cb, bits, block, rng, super::simd::active_lane())
    }
    #[cfg(not(feature = "simd"))]
    {
        try_quantize_stochastic_scalar(x, cb, bits, block, rng)
    }
}

/// Reference scalar SR encoder: per-element
/// [`stochastic_pair`](Boundaries::stochastic_pair) bracket search, one
/// uniform draw per element. The equivalence baseline for every SIMD lane
/// (the forced-lane × seed reproducibility test pins them to this stream).
pub fn try_quantize_stochastic_scalar(
    x: &[f32],
    cb: &[f32],
    bits: u32,
    block: usize,
    rng: &mut crate::util::rng::Rng,
) -> Result<QuantizedVec, QuantError> {
    assert!(block >= 1, "block must be >= 1");
    assert!(cb.len() >= (1usize << bits));
    let bounds = Boundaries::new(cb);
    let mut codes = vec![0u8; x.len()];
    let mut scales = Vec::with_capacity(x.len().div_ceil(block));
    try_for_blocks(x.len(), block, None, |bi, start, blen| {
        let blk = &x[start..start + blen];
        if !block_is_finite(blk) {
            return Err(nonfinite_err(blk, bi, start));
        }
        let absmax = blk.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        let scale = if absmax > 0.0 { absmax } else { 1.0 };
        let inv = 1.0 / scale;
        scales.push(scale);
        for (c, &v) in codes[start..start + blen].iter_mut().zip(blk) {
            let (lo, hi, p) = bounds.stochastic_pair(v * inv);
            let up = (rng.uniform() as f32) < p;
            *c = if up { hi } else { lo };
        }
        Ok(())
    })?;
    Ok(QuantizedVec {
        packed: pack_bits_chunked(&codes, bits),
        scales,
        len: x.len(),
        bits,
        block,
        col: None,
    })
}

/// [`try_quantize_stochastic`] on an explicit lane: the per-block bracket
/// + fraction pass runs through
/// [`stochastic_block_simd`](Boundaries::stochastic_block_simd) (a
/// vectorized counting sweep replaces the per-element binary search), then
/// one uniform draw per element resolves each bracket **in element
/// order** — the same stream positions as the scalar arm, so a fixed seed
/// yields bit-identical codes on every lane.
///
/// [`Lane::Scalar`](super::simd::Lane::Scalar) routes straight to
/// [`try_quantize_stochastic_scalar`].
#[cfg(feature = "simd")]
pub fn try_quantize_stochastic_lane(
    x: &[f32],
    cb: &[f32],
    bits: u32,
    block: usize,
    rng: &mut crate::util::rng::Rng,
    lane: super::simd::Lane,
) -> Result<QuantizedVec, QuantError> {
    use super::simd;
    if lane == simd::Lane::Scalar {
        return try_quantize_stochastic_scalar(x, cb, bits, block, rng);
    }
    assert!(block >= 1, "block must be >= 1");
    assert!(cb.len() >= (1usize << bits));
    let bounds = Boundaries::new(cb);
    let mut codes = vec![0u8; x.len()];
    let mut scales = Vec::with_capacity(x.len().div_ceil(block));
    let scratch = block.min(x.len());
    let mut normed = vec![0.0f32; scratch];
    let mut counts = vec![0u8; scratch];
    let mut pairs = vec![(0u8, 0u8, 0.0f32); scratch];
    try_for_blocks(x.len(), block, None, |bi, start, blen| {
        let blk = &x[start..start + blen];
        if !simd::all_finite_with(lane, blk) {
            return Err(nonfinite_err(blk, bi, start));
        }
        let absmax = simd::absmax_with(lane, blk);
        let scale = if absmax > 0.0 { absmax } else { 1.0 };
        let inv = 1.0 / scale;
        scales.push(scale);
        let nb = &mut normed[..blen];
        simd::normalize_into_with(lane, blk, inv, nb);
        let prs = &mut pairs[..blen];
        bounds.stochastic_block_simd(lane, nb, &mut counts[..blen], prs);
        for (c, &(lo, hi, p)) in codes[start..start + blen].iter_mut().zip(prs.iter()) {
            let up = (rng.uniform() as f32) < p;
            *c = if up { hi } else { lo };
        }
        Ok(())
    })?;
    Ok(QuantizedVec {
        packed: simd::pack_bits_lane(lane, &codes, bits),
        scales,
        len: x.len(),
        bits,
        block,
        col: None,
    })
}

// ---------------------------------------------------------------------------
// decode arms
// ---------------------------------------------------------------------------

/// Dequantize: R(codes) ⊙ scales — dispatches to the SIMD arm when
/// compiled with `--features simd`, the chunked arm otherwise.
pub fn dequantize(q: &QuantizedVec, cb: &[f32]) -> Vec<f32> {
    #[cfg(feature = "simd")]
    {
        dequantize_simd(q, cb)
    }
    #[cfg(not(feature = "simd"))]
    {
        dequantize_chunked(q, cb)
    }
}

/// Chunked decode arm: batched unpack into a flat code scratch, then a
/// per-block multiply lane against a 256-entry lookup table (a `u8` code
/// indexes it with no bounds check, so the loop is branch-free and
/// auto-vectorizable). No per-element `i / block` division, no `Vec::push`.
pub fn dequantize_chunked(q: &QuantizedVec, cb: &[f32]) -> Vec<f32> {
    debug_assert_eq!(q.scales.len(), layout_scale_count(q.len, q.block, q.col));
    let mut table = [0.0f32; 256];
    let k = cb.len().min(256);
    table[..k].copy_from_slice(&cb[..k]);
    let mut codes = vec![0u8; q.len];
    unpack_bits_into_chunked(&q.packed, q.bits, &mut codes);
    let mut out = vec![0.0f32; q.len];
    for_blocks(q.len, q.block, q.col, |bi, start, blen| {
        let scale = q.scales[bi];
        for (o, &c) in out[start..start + blen].iter_mut().zip(&codes[start..start + blen]) {
            *o = table[c as usize] * scale;
        }
    });
    out
}

/// SIMD decode arm: SIMD/SWAR unpack lanes, then the vectorized
/// [`decode_block`](super::simd::decode_block) multiply per block on the
/// active lane. Bit-identical to the chunked arm.
#[cfg(feature = "simd")]
pub fn dequantize_simd(q: &QuantizedVec, cb: &[f32]) -> Vec<f32> {
    dequantize_lane(q, cb, super::simd::active_lane())
}

/// [`dequantize_simd`] on an explicit [`Lane`](super::simd::Lane) — the
/// forced-lane decode twin used by the N-way property suite and the
/// `quant_simd` harness.
#[cfg(feature = "simd")]
pub fn dequantize_lane(q: &QuantizedVec, cb: &[f32], lane: super::simd::Lane) -> Vec<f32> {
    use super::simd;
    debug_assert_eq!(q.scales.len(), layout_scale_count(q.len, q.block, q.col));
    let mut table = [0.0f32; 256];
    let k = cb.len().min(256);
    table[..k].copy_from_slice(&cb[..k]);
    let mut codes = vec![0u8; q.len];
    simd::unpack_bits_into_lane(lane, &q.packed, q.bits, &mut codes);
    let mut out = vec![0.0f32; q.len];
    for_blocks(q.len, q.block, q.col, |bi, start, blen| {
        simd::decode_block_with(
            lane,
            &codes[start..start + blen],
            &table,
            q.scales[bi],
            &mut out[start..start + blen],
        );
    });
    out
}

/// Reference scalar decoder (the pre-chunking implementation) — the
/// equivalence baseline for the chunked and SIMD decode arms.
pub fn dequantize_scalar(q: &QuantizedVec, cb: &[f32]) -> Vec<f32> {
    debug_assert_eq!(q.scales.len(), layout_scale_count(q.len, q.block, q.col));
    let codes = q.codes_u8();
    let mut out = vec![0.0f32; q.len];
    for_blocks(q.len, q.block, q.col, |bi, start, blen| {
        let scale = q.scales[bi];
        for (o, &c) in out[start..start + blen].iter_mut().zip(&codes[start..start + blen]) {
            *o = cb[c as usize] * scale;
        }
    });
    out
}

// ---------------------------------------------------------------------------
// matrix layout
// ---------------------------------------------------------------------------

/// Pick the block layout for an order-`n` matrix quantized down its
/// columns with preferred block length `pref` (normally [`BLOCK`]):
///
/// * `n <= pref` → one block per column (`(n, None)`), as before;
/// * otherwise the **largest divisor of `n` that is ≤ `pref`**, so blocks
///   tile columns exactly (`n = 128 → 64`, `96 → 48`, `100 → 50`) and the
///   flat layout stays identical to the historical one whenever
///   `pref` already divides `n`;
/// * if the best divisor is degenerate (< [`MATRIX_BLOCK_MIN`], e.g. a
///   prime `n = 101`) → per-column chunking (`(pref, Some(n))`): blocks of
///   `pref` restart at every column boundary and each column ends with its
///   own partial block.
///
/// Every choice keeps the §3.3 contract — no block ever straddles a column
/// boundary — for *all* `n`, where the old `min(64, n)` rule panicked
/// (n = 100) or silently straddled columns (n = 96).
pub fn matrix_layout(n: usize, pref: usize) -> (usize, Option<usize>) {
    let pref = pref.max(1);
    if n == 0 {
        return (pref, None);
    }
    if n <= pref {
        return (n, None);
    }
    let mut best = 1usize;
    for d in 1..=pref {
        if n % d == 0 {
            best = d;
        }
    }
    if best >= MATRIX_BLOCK_MIN {
        (best, None)
    } else {
        (pref, Some(n))
    }
}

/// Quantize a square order-n matrix (row-major) with blocks running down
/// columns (§3.3): we quantize the transpose's rows, with the block layout
/// chosen by [`matrix_layout`] so blocks never straddle columns at any `n`.
///
/// # Panics
/// On non-finite input; use [`try_quantize_matrix_cols`] to handle it.
pub fn quantize_matrix_cols(a: &[f32], n: usize, cb: &[f32], bits: u32) -> QuantizedVec {
    try_quantize_matrix_cols(a, n, cb, bits).unwrap_or_else(|e| panic!("{e}"))
}

/// Fallible [`quantize_matrix_cols`] (preferred block = [`BLOCK`]).
pub fn try_quantize_matrix_cols(
    a: &[f32],
    n: usize,
    cb: &[f32],
    bits: u32,
) -> Result<QuantizedVec, QuantError> {
    try_quantize_matrix_cols_with(a, n, cb, bits, BLOCK)
}

/// [`try_quantize_matrix_cols`] with an explicit preferred block length.
pub fn try_quantize_matrix_cols_with(
    a: &[f32],
    n: usize,
    cb: &[f32],
    bits: u32,
    pref: usize,
) -> Result<QuantizedVec, QuantError> {
    assert_eq!(a.len(), n * n);
    let (block, col) = matrix_layout(n, pref);
    // transpose to column-major so blocks run down columns
    let mut t = vec![0.0f32; n * n];
    for i in 0..n {
        for j in 0..n {
            t[j * n + i] = a[i * n + j];
        }
    }
    try_quantize_layout(&t, cb, bits, block, col)
}

/// Inverse of `quantize_matrix_cols`: returns row-major order-n matrix.
pub fn dequantize_matrix_cols(q: &QuantizedVec, n: usize, cb: &[f32]) -> Vec<f32> {
    let t = dequantize(q, cb);
    let mut a = vec![0.0f32; n * n];
    for j in 0..n {
        for i in 0..n {
            a[i * n + j] = t[j * n + i];
        }
    }
    a
}

/// Memory model: bytes for an order-n matrix state at `bits` with per-block
/// f32 scales — the "32/(4+0.5) ≈ 7x" arithmetic of Appendix G. `pref` is
/// the *preferred* block; the actual layout (and so the scale count)
/// follows [`matrix_layout`], keeping this in lock-step with
/// [`quantize_matrix_cols`] on every shape.
pub fn matrix_state_bytes(n: usize, bits: u32, pref: usize) -> usize {
    let elems = n * n;
    let (block, col) = matrix_layout(n, pref);
    packed_len(elems, bits) + layout_scale_count(elems, block, col) * 4
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::codebook::{codebook, Mapping};
    use crate::util::prop;

    #[test]
    #[cfg_attr(miri, ignore)] // property sweep: too slow under Miri's interpreter
    fn roundtrip_error_bounded() {
        let cb = codebook(Mapping::Linear2, 4);
        let max_gap = cb.windows(2).map(|w| w[1] - w[0]).fold(0.0f32, f32::max);
        prop::check("quantize roundtrip bound", 20, |rng| {
            let nblocks = 1 + rng.below(8);
            let x: Vec<f32> = (0..nblocks * 64).map(|_| rng.normal_f32()).collect();
            let q = quantize(&x, &cb, 4, 64);
            let d = dequantize(&q, &cb);
            for (b, chunk) in x.chunks(64).enumerate() {
                let scale = q.scales[b];
                for (i, (&xv, &dv)) in chunk.iter().zip(&d[b * 64..]).enumerate() {
                    let bound = 0.5 * max_gap * scale + 1e-6;
                    if (xv - dv).abs() > bound {
                        return Err(format!("block {b} elem {i}: {xv} vs {dv}"));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn zero_blocks_are_exact() {
        let cb = codebook(Mapping::Linear2, 4);
        let x = vec![0.0f32; 128];
        let q = quantize(&x, &cb, 4, 64);
        assert_eq!(q.scales, vec![1.0, 1.0]);
        assert_eq!(dequantize(&q, &cb), x);
    }

    #[test]
    fn trailing_partial_block_gets_own_scale() {
        let cb = codebook(Mapping::Linear2, 4);
        let mut x = vec![0.01f32; 100]; // one full block + a 36-element tail
        x[99] = 50.0; // huge tail entry must not pollute the first block
        let q = quantize(&x, &cb, 4, 64);
        assert_eq!(q.scales.len(), 2);
        assert_eq!(q.state_bytes(), 50 + 2 * 4);
        let d = dequantize(&q, &cb);
        for i in 0..64 {
            assert!((d[i] - 0.01).abs() < 0.005, "elem {i}: {}", d[i]);
        }
        assert!((d[99] - 50.0).abs() < 1.0, "{}", d[99]);
    }

    #[test]
    fn state_bytes_accounting() {
        let cb = codebook(Mapping::Linear2, 4);
        let x = vec![0.5f32; 64 * 64];
        let q = quantize(&x, &cb, 4, 64);
        // 4096 codes at 4-bit = 2048 bytes; 64 scales * 4 = 256 bytes
        assert_eq!(q.state_bytes(), 2048 + 256);
        assert_eq!(matrix_state_bytes(64, 4, 64), 2048 + 256);
        // the Appendix-G ratio: 32-bit / (4-bit + 0.5 overhead) ≈ 7.1x
        let fp32 = 64 * 64 * 4;
        let ratio = fp32 as f64 / q.state_bytes() as f64;
        assert!((ratio - 7.1).abs() < 0.2, "{ratio}");
    }

    #[test]
    fn matrix_cols_roundtrip_matches_python_layout() {
        // column with huge entry must not pollute other columns (same test
        // as python tests/test_quant_kernels.py::test_column_blocking)
        let cb = codebook(Mapping::Linear2, 4);
        let n = 64;
        let mut a = vec![0.01f32; n * n];
        a[0] = 100.0; // a[0,0]
        let q = quantize_matrix_cols(&a, n, &cb, 4);
        let d = dequantize_matrix_cols(&q, n, &cb);
        for i in 0..n {
            for j in 1..n {
                assert!((d[i * n + j] - 0.01).abs() < 0.005, "({i},{j})");
            }
        }
    }

    #[test]
    fn matrix_layout_picks_divisors_then_columns() {
        assert_eq!(matrix_layout(64, 64), (64, None));
        assert_eq!(matrix_layout(32, 64), (32, None));
        assert_eq!(matrix_layout(128, 64), (64, None)); // historical layout kept
        assert_eq!(matrix_layout(96, 64), (48, None));
        assert_eq!(matrix_layout(100, 64), (50, None));
        assert_eq!(matrix_layout(101, 64), (64, Some(101))); // prime: per-column
        assert_eq!(matrix_layout(0, 64), (64, None));
        // scale accounting follows the layout
        assert_eq!(layout_scale_count(96 * 96, 48, None), 96 * 2);
        assert_eq!(layout_scale_count(101 * 101, 64, Some(101)), 101 * 2);
    }

    #[test]
    #[cfg_attr(miri, ignore)] // property sweep: too slow under Miri's interpreter
    fn matrix_cols_column_blocking_regression_non_multiple_of_64() {
        // the old `block = min(64, n)` rule panicked at n=100 and straddled
        // column boundaries at n=96 — a huge entry in column 0 must never
        // leak into any other column, at every layout class
        let cb = codebook(Mapping::Linear2, 4);
        for n in [96usize, 100, 101] {
            let mut a = vec![0.01f32; n * n];
            a[0] = 100.0; // a[0,0]: column 0 only
            let q = quantize_matrix_cols(&a, n, &cb, 4);
            assert_eq!(
                q.state_bytes(),
                matrix_state_bytes(n, 4, 64),
                "n={n}: accounting out of sync"
            );
            let d = dequantize_matrix_cols(&q, n, &cb);
            for i in 0..n {
                for j in 1..n {
                    assert!(
                        (d[i * n + j] - 0.01).abs() < 0.005,
                        "n={n} ({i},{j}): {} polluted by column 0",
                        d[i * n + j]
                    );
                }
            }
            assert!((d[0] - 100.0).abs() < 2.0, "n={n}: lost the spike: {}", d[0]);
        }
    }

    #[test]
    fn nonfinite_inputs_are_typed_errors_in_every_encoder() {
        let cb = codebook(Mapping::Linear2, 4);
        for bad in [f32::NAN, f32::INFINITY, f32::NEG_INFINITY] {
            for pos in [0usize, 63, 64, 99] {
                let mut x = vec![0.25f32; 100];
                x[pos] = bad;
                let expect_block = pos / 64;
                let check = |r: Result<QuantizedVec, QuantError>, arm: &str| match r {
                    Err(QuantError::NonFinite { block, index, .. }) => {
                        assert_eq!(index, pos, "{arm}: wrong index for {bad} at {pos}");
                        assert_eq!(block, expect_block, "{arm}: wrong block");
                    }
                    Ok(_) => panic!("{arm}: accepted {bad} at {pos}"),
                };
                check(try_quantize(&x, &cb, 4, 64), "dispatch");
                check(try_quantize_chunked(&x, &cb, 4, 64), "chunked");
                check(try_quantize_scalar(&x, &cb, 4, 64), "scalar");
                #[cfg(feature = "simd")]
                check(try_quantize_simd(&x, &cb, 4, 64), "simd");
                let mut rng = crate::util::rng::Rng::new(7);
                check(try_quantize_stochastic(&x, &cb, 4, 64, &mut rng), "stochastic");
                // the matrix path transposes, so only assert that it refuses
                assert!(
                    try_quantize_matrix_cols(&x, 10, &cb, 4).is_err(),
                    "matrix accepted {bad} at {pos}"
                );
            }
        }
        // the error message is descriptive and the infallible wrapper panics
        let e = try_quantize(&[f32::NAN], &cb, 4, 64).unwrap_err();
        assert!(e.to_string().contains("non-finite"), "{e}");
        let caught = std::panic::catch_unwind(|| quantize(&[f32::INFINITY], &cb, 4, 64));
        assert!(caught.is_err(), "infallible wrapper must fail loud");
    }

    #[test]
    fn three_bit_roundtrip() {
        let cb = codebook(Mapping::Dt, 3);
        prop::check("3-bit roundtrip stores 3 bits", 10, |rng| {
            let x: Vec<f32> = (0..128).map(|_| rng.normal_f32()).collect();
            let q = quantize(&x, &cb, 3, 64);
            if q.packed.len() != 48 {
                return Err(format!("packed {} bytes", q.packed.len()));
            }
            let d = dequantize(&q, &cb);
            // every dequantized value is a scaled codebook entry
            for (b, chunk) in d.chunks(64).enumerate() {
                for &v in chunk {
                    let normed = v / q.scales[b];
                    if !cb.iter().any(|&c| (c - normed).abs() < 1e-5) {
                        return Err(format!("{normed} not in codebook"));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    #[cfg_attr(miri, ignore)] // property sweep: too slow under Miri's interpreter
    fn all_arms_bit_identical() {
        // the chunked and SIMD kernels are pure performance rewrites:
        // packed bytes, scales, and decoded values must be identical to the
        // scalar reference at every bitwidth, block size, odd length, and
        // column layout — the three-way equivalence contract
        for (mapping, bits) in
            [(Mapping::Linear2, 4u32), (Mapping::Dt, 3), (Mapping::Dt, 8), (Mapping::Dt, 2)]
        {
            let cb = codebook(mapping, bits);
            prop::check(&format!("arms identical {mapping:?}/{bits}"), 15, |rng| {
                let n = 1 + rng.below(400);
                let block = [7, 32, 64, 100][rng.below(4)];
                let x: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
                let qs = try_quantize_scalar(&x, &cb, bits, block).unwrap();
                let qc = try_quantize_chunked(&x, &cb, bits, block).unwrap();
                let qd = try_quantize(&x, &cb, bits, block).unwrap();
                let same = |a: &QuantizedVec, b: &QuantizedVec| {
                    a.packed == b.packed && a.scales == b.scales
                };
                if !same(&qc, &qs) {
                    return Err(format!("chunked diverged at n={n} block={block}"));
                }
                if !same(&qd, &qs) {
                    return Err(format!("dispatch diverged at n={n} block={block}"));
                }
                #[cfg(feature = "simd")]
                for lane in crate::quant::simd::detected_lanes() {
                    let qv = try_quantize_lane_layout(&x, &cb, bits, block, None, lane).unwrap();
                    if !same(&qv, &qs) {
                        return Err(format!("{lane} diverged at n={n} block={block}"));
                    }
                }
                let bits_of = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
                let ds = bits_of(&dequantize_scalar(&qs, &cb));
                if bits_of(&dequantize_chunked(&qc, &cb)) != ds {
                    return Err(format!("chunked decode diverged at n={n} block={block}"));
                }
                if bits_of(&dequantize(&qd, &cb)) != ds {
                    return Err(format!("dispatch decode diverged at n={n} block={block}"));
                }
                #[cfg(feature = "simd")]
                for lane in crate::quant::simd::detected_lanes() {
                    if bits_of(&dequantize_lane(&qc, &cb, lane)) != ds {
                        return Err(format!("{lane} decode diverged at n={n} block={block}"));
                    }
                }
                Ok(())
            });
        }
    }

    #[test]
    #[cfg_attr(miri, ignore)] // property sweep: too slow under Miri's interpreter
    fn column_layout_arms_bit_identical() {
        // the per-column fallback layout (prime n) must also be identical
        // across arms, including partial blocks at every column end
        let cb = codebook(Mapping::Dt, 4);
        for n in [5usize, 101] {
            let mut rng = crate::util::rng::Rng::new(21);
            let x: Vec<f32> = (0..n * n).map(|_| rng.normal_f32()).collect();
            let (block, col) = matrix_layout(n, 64);
            let qs = try_quantize_scalar_layout(&x, &cb, 4, block, col).unwrap();
            let qc = try_quantize_chunked_layout(&x, &cb, 4, block, col).unwrap();
            assert_eq!(qs.packed, qc.packed, "n={n}");
            assert_eq!(qs.scales, qc.scales, "n={n}");
            #[cfg(feature = "simd")]
            for lane in crate::quant::simd::detected_lanes() {
                let qv = try_quantize_lane_layout(&x, &cb, 4, block, col, lane).unwrap();
                assert_eq!(qs.packed, qv.packed, "n={n} {lane}");
                assert_eq!(qs.scales, qv.scales, "n={n} {lane}");
            }
            let bits_of = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
            assert_eq!(
                bits_of(&dequantize_chunked(&qc, &cb)),
                bits_of(&dequantize_scalar(&qs, &cb)),
                "n={n} decode"
            );
        }
    }

    #[test]
    fn stochastic_quantize_is_seeded_and_in_book() {
        let cb = codebook(Mapping::Linear2, 4);
        let mut rng_a = crate::util::rng::Rng::new(5);
        let mut rng_b = crate::util::rng::Rng::new(5);
        let mut data_rng = crate::util::rng::Rng::new(6);
        let x: Vec<f32> = (0..200).map(|_| data_rng.normal_f32()).collect();
        let qa = quantize_stochastic(&x, &cb, 4, 64, &mut rng_a);
        let qb = quantize_stochastic(&x, &cb, 4, 64, &mut rng_b);
        // fixed seed ⇒ identical codes
        assert_eq!(qa.packed, qb.packed);
        assert_eq!(qa.scales, qb.scales);
        // every decoded value is a scaled codebook entry
        let d = dequantize(&qa, &cb);
        for (b, chunk) in d.chunks(64).enumerate() {
            for &v in chunk {
                let normed = v / qa.scales[b];
                assert!(cb.iter().any(|&c| (c - normed).abs() < 1e-6), "{normed}");
            }
        }
        // a different seed draws a different rounding stream
        let mut rng_c = crate::util::rng::Rng::new(99);
        let qc = quantize_stochastic(&x, &cb, 4, 64, &mut rng_c);
        assert_ne!(qa.packed, qc.packed, "distinct seeds should round differently");
    }

    #[test]
    #[cfg(feature = "simd")]
    #[cfg_attr(miri, ignore)] // lane × seed × mapping sweep: too slow under Miri
    fn stochastic_lanes_bit_identical_to_scalar_across_seeds() {
        // the vectorized SR bracket pass must not perturb the seeded RNG
        // stream: for every detected lane and every seed, the lane-forced
        // encode reproduces the scalar reference bit-for-bit (packed bytes
        // AND scales), including odd lengths with partial tail blocks
        for (mapping, bits) in [(Mapping::Linear2, 4u32), (Mapping::Dt, 8), (Mapping::Dt, 2)] {
            let cb = codebook(mapping, bits);
            for (n, block) in [(333usize, 64usize), (64, 64), (17, 7)] {
                let mut data_rng = crate::util::rng::Rng::new(11);
                let x: Vec<f32> = (0..n).map(|_| data_rng.normal_f32()).collect();
                for seed in [1u64, 42, 1234] {
                    let mut rng_s = crate::util::rng::Rng::new(seed);
                    let qs =
                        try_quantize_stochastic_scalar(&x, &cb, bits, block, &mut rng_s).unwrap();
                    for lane in crate::quant::simd::detected_lanes() {
                        let mut rng_l = crate::util::rng::Rng::new(seed);
                        let ql =
                            try_quantize_stochastic_lane(&x, &cb, bits, block, &mut rng_l, lane)
                                .unwrap();
                        let tag = format!("{mapping:?}/{bits} n={n} seed={seed} {lane}");
                        assert_eq!(qs.packed, ql.packed, "{tag} packed");
                        assert_eq!(qs.scales, ql.scales, "{tag} scales");
                    }
                    // the dispatcher (whatever lane it picks) is on the same stream
                    let mut rng_d = crate::util::rng::Rng::new(seed);
                    let qd = try_quantize_stochastic(&x, &cb, bits, block, &mut rng_d).unwrap();
                    assert_eq!(qs.packed, qd.packed, "dispatch n={n} seed={seed}");
                }
            }
        }
    }

    #[test]
    #[cfg_attr(miri, ignore)] // property sweep: too slow under Miri's interpreter
    fn eight_bit_much_tighter_than_four() {
        let cb8 = codebook(Mapping::Dt, 8);
        let cb4 = codebook(Mapping::Dt, 4);
        let mut rng = crate::util::rng::Rng::new(3);
        let x: Vec<f32> = (0..256).map(|_| rng.normal_f32()).collect();
        let err = |bits: u32, cb: &[f32]| {
            let q = quantize(&x, cb, bits, 64);
            let d = dequantize(&q, cb);
            x.iter()
                .zip(&d)
                .map(|(a, b)| ((a - b) as f64).powi(2))
                .sum::<f64>()
                .sqrt()
        };
        assert!(err(8, &cb8) < 0.2 * err(4, &cb4));
    }
}
