//! Quantization substrate: codebooks (DT / Linear-2 / linear), bit packing
//! at true bitwidth, the block-wise quantizer — the exact Rust mirror of
//! the L1 Pallas kernels, cross-checked via golden artifacts — and the
//! [`codec::StateCodec`] layer both optimizer families store state through.

/// Block-wise absmax quantize/dequantize kernels.
pub mod blockwise;
/// Codebooks (DT / Linear-2 / linear) + decision boundaries.
pub mod codebook;
/// The `StateCodec` storage layer.
pub mod codec;
/// True-bitwidth code packing.
pub mod pack;

pub use blockwise::{
    dequantize, dequantize_matrix_cols, matrix_state_bytes, quantize,
    quantize_matrix_cols, QuantizedVec, BLOCK,
};
pub use codebook::{codebook, runtime_codebook, Boundaries, Mapping};
pub use codec::{
    codec_by_name, codec_for, fp32, Bf16, BlockQuant, EncodedVec, Fp32, StateBuf,
    StateCodec,
};
pub use pack::{pack_bits, packed_len, unpack_bits};
