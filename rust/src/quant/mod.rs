//! Quantization substrate: codebooks (DT / Linear-2 / linear), bit packing
//! at true bitwidth, the block-wise quantizer — the exact Rust mirror of
//! the L1 Pallas kernels, cross-checked via golden artifacts — and the
//! [`codec::StateCodec`] layer both optimizer families store state through.

/// Block-wise absmax quantize/dequantize kernels.
pub mod blockwise;
/// Codebooks (DT / Linear-2 / linear) + decision boundaries.
pub mod codebook;
/// The `StateCodec` storage layer.
pub mod codec;
/// True-bitwidth code packing.
pub mod pack;
/// The per-buffer codec policy resolver (role → codec spec).
pub mod policy;
/// Runtime-detected SIMD lane registry for the hot loops
/// (`--features simd`).
#[cfg(feature = "simd")]
pub mod simd;

pub use blockwise::{
    dequantize, dequantize_chunked, dequantize_matrix_cols, dequantize_scalar,
    layout_scale_count, matrix_layout, matrix_state_bytes, quantize, quantize_chunked,
    quantize_matrix_cols, quantize_scalar, quantize_stochastic, try_quantize,
    try_quantize_chunked, try_quantize_matrix_cols, try_quantize_scalar,
    try_quantize_stochastic, try_quantize_stochastic_scalar, QuantError, QuantizedVec,
    BLOCK, MATRIX_BLOCK_MIN,
};
#[cfg(feature = "simd")]
pub use blockwise::{
    dequantize_lane, dequantize_simd, quantize_lane, quantize_simd, try_quantize_lane_layout,
    try_quantize_simd, try_quantize_stochastic_lane,
};
#[cfg(feature = "simd")]
pub use simd::{active_lane, detected_lanes, lane_from_env, Lane, LANE_ENV};
pub use codebook::{codebook, runtime_codebook, Boundaries, Mapping};
pub use codec::{
    codec_by_name, codec_for, crc32, fp32, put_frame, put_frame_checked, read_frame,
    read_frame_checked, Bf16, BlockQuant, Crc32, EncodedVec, Fp32, SliceRanges, StateBuf,
    StateCodec, StochasticRound, CODEC_REGISTRY_HELP,
};
pub use pack::{
    pack_bits, pack_bits_chunked, packed_len, unpack_bits, unpack_bits_into,
    unpack_bits_into_chunked,
};
pub use policy::{
    parse_policy_entry, parse_policy_overrides, BufferRole, CodecPolicy, CodecSpec,
    ROLE_HELP,
};
