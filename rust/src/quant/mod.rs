//! Quantization substrate: codebooks (DT / Linear-2 / linear), bit packing
//! at true bitwidth, and the block-wise quantizer — the exact Rust mirror
//! of the L1 Pallas kernels, cross-checked via golden artifacts.

pub mod blockwise;
pub mod codebook;
pub mod pack;

pub use blockwise::{
    dequantize, dequantize_matrix_cols, matrix_state_bytes, quantize,
    quantize_matrix_cols, QuantizedVec, BLOCK,
};
pub use codebook::{codebook, nearest, runtime_codebook, Mapping};
pub use pack::{pack_bits, packed_len, unpack_bits};
