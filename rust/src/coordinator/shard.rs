//! Sharded block engine: partition second-order blocks across N shard
//! workers, each owning its own [`Backend`](crate::runtime::Backend)
//! instance and its own slice of [`SideState`] pairs, with codec-encoded
//! bytes as the inter-shard message format.
//!
//! # Assignment
//!
//! Blocks are assigned deterministically round-robin over the partitioner's
//! output: block `i` belongs to shard [`shard_for`]`(i, shards)` =
//! `i % shards`. The assignment is a pure function of the block index and
//! the shard count, checkpoints store second-order state in global block
//! order (shard-agnostic), and a restore re-syncs every shard — so a
//! checkpoint written at one shard count resumes at any other.
//!
//! # Wire format
//!
//! Messages reuse the codec byte layouts that already exist for
//! checkpoints — the paper's compressed representation IS the wire format,
//! so a 4-bit eigenbasis costs on the wire what it costs at rest
//! (4–8× less than fp32 would):
//!
//! * **Request** (coordinator → shard, one buffer per refresh round):
//!   `n_entries (u32 LE)`, then per entry `block_idx (u32 LE) | flags (u8:
//!   bit0 = PU, bit1 = PIRU)` and, when PU is set, `stat_tag (u8)` followed
//!   by the statistics as [`put_frame`] frames — `0` = one fp32-codec
//!   gradient-block frame (Shampoo/CASPR; grams run shard-side), `1` = two
//!   fp32 layer-statistics frames (K-FAC/AdaBK). Gradients ship lossless so
//!   sharded PU is bit-identical to in-process PU.
//! * **Reply** (shard → coordinator, one buffer per round): `n_entries
//!   (u32 LE)`, then per entry `block_idx (u32 LE) | refreshed_invroot (u8)
//!   | pu_secs (f64 LE) | piru_secs (f64 LE)` followed by the refreshed
//!   left and right sides as [`SideState::serialize`] bytes — raw codec
//!   payloads, byte-exact with the shard's own state.
//!
//! # Barriers and determinism
//!
//! At most one round is in flight, and the coordinator swaps a round's
//! results into its front copies in ascending block order at the same
//! deterministic barriers the in-process pipeline uses ([`SecondOrder`]
//! routes both engines through the same submit/complete seam). Each shard
//! runs its blocks through its own [`Scheduler`] with an index-ordered
//! merge. PU/PIRU are pure functions of `(state, stat)` per block, every
//! shard starts from identical state (serialize → deserialize round-trips
//! are byte-exact), and stats ship lossless — so a sharded run is
//! **bit-identical** to the single-process run at any shard count.
//!
//! [`SecondOrder`]: crate::coordinator::SecondOrder

use std::path::{Path, PathBuf};
use std::sync::mpsc;
use std::thread::JoinHandle;

use anyhow::{anyhow, Context, Result};

use crate::config::{SecondOrderConfig, SecondOrderKind};
use crate::coordinator::model::ModelHandle;
use crate::coordinator::scheduler::{ScheduleError, Scheduler, StepTimings};
use crate::coordinator::second_order::{capture_stat, refresh_pu, BlockPre, StatInput};
use crate::coordinator::state::{run_invroot, SideState};
use crate::quant::{fp32, put_frame, read_frame};
use crate::runtime::backend_by_name;
use crate::util::timer::Stopwatch;

/// Deterministic block → shard assignment: round-robin over the
/// partitioner's block order. A pure function of `(block_idx, shards)`, so
/// any process can recompute the placement from the checkpointed shard
/// count alone.
pub fn shard_for(block_idx: usize, shards: usize) -> usize {
    block_idx % shards.max(1)
}

/// Request flag: this entry carries a PU statistics payload.
const FLAG_PU: u8 = 1;
/// Request flag: this entry's inverse roots are due.
const FLAG_PIRU: u8 = 1 << 1;

/// Coordinator → shard messages. Senders dropping is the shutdown signal.
enum ToShard {
    /// Replace the shard's owned states: concatenated
    /// [`SideState::serialize`] pairs for its blocks, in ascending global
    /// block order (initial sync and checkpoint restore).
    Load(Vec<u8>),
    /// One refresh round's framed request bytes (module-level wire format).
    Refresh(Vec<u8>),
}

/// One shard worker: its request sender and join handle.
struct ShardHandle {
    tx: Option<mpsc::Sender<ToShard>>,
    join: Option<JoinHandle<()>>,
}

/// Bookkeeping for one in-flight refresh round.
struct InFlightRound {
    /// Trainer step at which the round was submitted (staleness clock).
    submit_step: usize,
    /// Shards that were sent a request this round and have not replied.
    outstanding: usize,
    /// Replies drained so far (the adaptive poll feeds this).
    received: Vec<(usize, Result<Vec<u8>>)>,
}

/// The sharded block engine: N worker threads, each with its own backend
/// and its own slice of block states, driven by codec-byte messages.
pub struct ShardSet {
    shards: Vec<ShardHandle>,
    reply_rx: mpsc::Receiver<(usize, Result<Vec<u8>>)>,
    inflight: Option<InFlightRound>,
    /// refresh rounds submitted so far
    rounds: u64,
    /// total actual bytes on the wire (requests + replies)
    wire_bytes: u64,
    /// reply/state traffic as actually sent (raw codec bytes)
    state_bytes: u64,
    /// what the same state traffic would cost under an fp32 wire format
    state_fp32_bytes: u64,
}

impl ShardSet {
    /// Spawn `cfg.shards` workers — each constructs its own backend from
    /// `(backend_name, artifact_dir)` on its own thread (its own executable
    /// cache, which is what unblocks multi-device PJRT) — and sync the
    /// initial block states to them.
    pub fn new(
        cfg: &SecondOrderConfig,
        backend_name: &str,
        artifact_dir: &Path,
        blocks: &[BlockPre],
    ) -> Result<Self> {
        let n = cfg.shards.max(1);
        let (reply_tx, reply_rx) = mpsc::channel();
        let mut shards = Vec::with_capacity(n);
        for shard_id in 0..n {
            let (tx, rx) = mpsc::channel::<ToShard>();
            let reply = reply_tx.clone();
            let backend_name = backend_name.to_string();
            let artifact_dir = PathBuf::from(artifact_dir);
            let (beta, eps, kind, parallelism) =
                (cfg.beta, cfg.eps, cfg.kind, cfg.parallelism);
            let join = std::thread::Builder::new()
                .name(format!("shampoo4-shard-{shard_id}"))
                .spawn(move || {
                    shard_main(
                        shard_id,
                        rx,
                        reply,
                        &backend_name,
                        &artifact_dir,
                        beta,
                        eps,
                        kind,
                        parallelism,
                    )
                })
                .context("spawning shard worker")?;
            shards.push(ShardHandle { tx: Some(tx), join: Some(join) });
        }
        let mut set = Self {
            shards,
            reply_rx,
            inflight: None,
            rounds: 0,
            wire_bytes: 0,
            state_bytes: 0,
            state_fp32_bytes: 0,
        };
        set.sync_states(blocks).context("initial shard state sync")?;
        Ok(set)
    }

    /// Configured shard count.
    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    /// `(total wire bytes, state bytes as codec, state bytes as fp32,
    /// rounds)` shipped so far — the `BENCH_shard.json` columns. The
    /// compression ratio is `state_fp32 / state`: request traffic is
    /// format-invariant (gradients are fp32 frames either way), so the
    /// ratio is computed on the state payloads where the codec matters.
    pub fn wire_stats(&self) -> (u64, u64, u64, u64) {
        (self.wire_bytes, self.state_bytes, self.state_fp32_bytes, self.rounds)
    }

    /// Whether a refresh round is in flight.
    pub fn round_in_flight(&self) -> bool {
        self.inflight.is_some()
    }

    /// The in-flight round's submission step, if any.
    pub fn submit_step(&self) -> Option<usize> {
        self.inflight.as_ref().map(|fl| fl.submit_step)
    }

    /// Push every shard's slice of `blocks` (ascending block order) as a
    /// `Load` message and wait for all acks — used at construction and
    /// after a checkpoint restore, so shard state is always byte-exact with
    /// the coordinator's front copies. Must not be called with a round in
    /// flight.
    pub fn sync_states(&mut self, blocks: &[BlockPre]) -> Result<()> {
        assert!(
            self.inflight.is_none(),
            "sync_states while a refresh round is in flight (missing barrier)"
        );
        let n = self.shards.len();
        let mut payloads: Vec<Vec<u8>> = vec![Vec::new(); n];
        for (bi, bp) in blocks.iter().enumerate() {
            let p = &mut payloads[shard_for(bi, n)];
            p.extend((bi as u32).to_le_bytes());
            p.extend(bp.left.serialize());
            p.extend(bp.right.serialize());
        }
        let mut outstanding = 0usize;
        for (sid, payload) in payloads.into_iter().enumerate() {
            self.send(sid, ToShard::Load(payload))?;
            outstanding += 1;
        }
        let mut first_err: Option<(usize, anyhow::Error)> = None;
        for _ in 0..outstanding {
            match self.reply_rx.recv() {
                Ok((sid, Err(e))) => {
                    if first_err.as_ref().is_none_or(|(s, _)| sid < *s) {
                        first_err = Some((sid, e));
                    }
                }
                Ok((_, Ok(_))) => {}
                Err(_) => return Err(anyhow!("a shard worker died during state sync")),
            }
        }
        if let Some((sid, e)) = first_err {
            return Err(e.context(format!("loading state into shard {sid}")));
        }
        Ok(())
    }

    /// Submit one refresh round: PU for every block when `pu` carries the
    /// step's model/grads/stats, plus PIRU for the `piru_due` cohort. Builds
    /// one codec-byte request per involved shard (gradients as lossless
    /// fp32 frames) and returns as soon as they are sent — the round
    /// completes at [`ShardSet::complete_round`].
    #[allow(clippy::type_complexity)]
    pub fn submit_round(
        &mut self,
        pu: Option<(&ModelHandle, &[Vec<f32>], &[Vec<f32>])>,
        kfac_mode: bool,
        blocks: &[BlockPre],
        piru_due: &[usize],
        step: usize,
    ) -> Result<()> {
        assert!(
            self.inflight.is_none(),
            "submit_round while a round is still in flight (missing barrier)"
        );
        let do_pu = pu.is_some();
        let involved: Vec<usize> = if do_pu {
            (0..blocks.len()).collect()
        } else {
            piru_due.to_vec()
        };
        if involved.is_empty() {
            return Ok(());
        }
        let mut piru = vec![false; blocks.len()];
        for &i in piru_due {
            piru[i] = true;
        }
        let n = self.shards.len();
        let grad_codec = fp32();
        // per-shard request: entry count placeholder, then entries in
        // ascending block order (involved is sorted for both branches)
        let mut reqs: Vec<(u32, Vec<u8>)> = vec![(0, Vec::new()); n];
        for &bi in &involved {
            let (count, buf) = &mut reqs[shard_for(bi, n)];
            *count += 1;
            buf.extend((bi as u32).to_le_bytes());
            let mut flags = 0u8;
            if do_pu {
                flags |= FLAG_PU;
            }
            if piru[bi] {
                flags |= FLAG_PIRU;
            }
            buf.push(flags);
            if let Some((model, grads, stats)) = pu {
                match capture_stat(kfac_mode, bi, &blocks[bi], model, grads, stats) {
                    StatInput::Grad(g) => {
                        buf.push(0);
                        put_frame(buf, &grad_codec.encode(&g));
                    }
                    StatInput::Layer { lx, ry } => {
                        buf.push(1);
                        put_frame(buf, &grad_codec.encode(&lx));
                        put_frame(buf, &grad_codec.encode(&ry));
                    }
                }
            }
        }
        let mut outstanding = 0usize;
        for (sid, (count, body)) in reqs.into_iter().enumerate() {
            if count == 0 {
                continue;
            }
            let mut msg = Vec::with_capacity(4 + body.len());
            msg.extend(count.to_le_bytes());
            msg.extend(body);
            self.wire_bytes += msg.len() as u64;
            self.send(sid, ToShard::Refresh(msg))?;
            outstanding += 1;
        }
        self.rounds += 1;
        self.inflight = Some(InFlightRound {
            submit_step: step,
            outstanding,
            received: Vec::new(),
        });
        Ok(())
    }

    /// Non-blocking poll: drain any replies already available and report
    /// whether every involved shard has replied (the adaptive-lag barrier).
    pub fn try_drain(&mut self) -> bool {
        match self.inflight.as_mut() {
            None => false,
            Some(fl) => {
                while let Ok(msg) = self.reply_rx.try_recv() {
                    fl.received.push(msg);
                }
                fl.received.len() >= fl.outstanding
            }
        }
    }

    /// Completion barrier: block until every involved shard has replied,
    /// then decode the replies and swap the refreshed sides into `blocks`
    /// in ascending block order. With `timings` (the pipelined engine),
    /// wait time lands in `pipeline_stall_secs` and the shards' per-block
    /// PU/PIRU seconds in `pu_secs`/`piru_secs`; the synchronous engine
    /// passes `None` because the trainer already wall-clocks the call.
    pub fn complete_round(
        &mut self,
        blocks: &mut [BlockPre],
        mut timings: Option<&mut StepTimings>,
    ) -> Result<()> {
        let Some(mut fl) = self.inflight.take() else {
            return Ok(());
        };
        let t = Stopwatch::start();
        while fl.received.len() < fl.outstanding {
            match self.reply_rx.recv() {
                Ok(msg) => fl.received.push(msg),
                Err(_) => {
                    if let Some(tm) = timings.as_deref_mut() {
                        tm.pipeline_stall_secs += t.secs();
                    }
                    return Err(anyhow!("a shard worker died before replying"));
                }
            }
        }
        if let Some(tm) = timings.as_deref_mut() {
            tm.pipeline_stall_secs += t.secs();
        }
        let mut first_err: Option<(usize, anyhow::Error)> = None;
        let mut updates: Vec<(usize, bool, f64, f64, SideState, SideState)> = Vec::new();
        for (sid, res) in fl.received {
            match res {
                Ok(reply) => {
                    self.wire_bytes += reply.len() as u64;
                    self.state_bytes += reply.len() as u64;
                    match decode_reply(&reply) {
                        Ok(mut entries) => updates.append(&mut entries),
                        Err(e) => {
                            if first_err.as_ref().is_none_or(|(s, _)| sid < *s) {
                                first_err = Some((sid, e));
                            }
                        }
                    }
                }
                Err(e) => {
                    if first_err.as_ref().is_none_or(|(s, _)| sid < *s) {
                        first_err = Some((sid, e));
                    }
                }
            }
        }
        if let Some((sid, e)) = first_err {
            return Err(e.context(format!("sharded refresh round on shard {sid}")));
        }
        updates.sort_by_key(|u| u.0);
        for (bi, refreshed, pu_secs, piru_secs, left, right) in updates {
            let bp = blocks
                .get_mut(bi)
                .ok_or_else(|| anyhow!("shard reply names unknown block {bi}"))?;
            // fp32-equivalent reply cost: same per-entry header, raw f32
            // payloads instead of codec bytes
            self.state_fp32_bytes +=
                (4 + 1 + 16 + left.fp32_wire_bytes() + right.fp32_wire_bytes()) as u64;
            if let Some(tm) = timings.as_deref_mut() {
                tm.pu_secs += pu_secs;
                tm.piru_secs += piru_secs;
            }
            bp.left = left;
            bp.right = right;
            if refreshed {
                bp.inv_cache = None;
            }
        }
        Ok(())
    }

    /// Error-path barrier: wait the in-flight round out (shard workers
    /// always finish the round they are on) and discard the results.
    pub fn abort_round(&mut self) {
        if let Some(fl) = self.inflight.take() {
            let mut outstanding = fl.outstanding - fl.received.len();
            while outstanding > 0 {
                if self.reply_rx.recv().is_err() {
                    break; // every worker gone: nothing left running
                }
                outstanding -= 1;
            }
        }
    }

    fn send(&self, shard: usize, msg: ToShard) -> Result<()> {
        // a None sender means Drop already began — callers racing shutdown
        // get the same typed error as a worker that exited early, instead of
        // a panic inside the coordinator
        let Some(tx) = self.shards[shard].tx.as_ref() else {
            return Err(ScheduleError::ShardDisconnected { shard }.into());
        };
        tx.send(msg).map_err(|_| ScheduleError::ShardDisconnected { shard }.into())
    }
}

impl Drop for ShardSet {
    /// Graceful shutdown: drain any in-flight round, close every request
    /// sender (the workers' recv loop ends), and join the threads. Workers
    /// own their backends and states outright, so no borrowed resource is
    /// at stake — this is cleanliness, not soundness.
    fn drop(&mut self) {
        self.abort_round();
        for s in self.shards.iter_mut() {
            s.tx = None;
        }
        for s in self.shards.iter_mut() {
            if let Some(j) = s.join.take() {
                let _ = j.join();
            }
        }
    }
}

/// Slice `n` bytes at `*off` out of a wire buffer, advancing the cursor;
/// `what` labels the buffer ("request"/"reply") in truncation errors.
fn take<'a>(bytes: &'a [u8], off: &mut usize, n: usize, what: &str) -> Result<&'a [u8]> {
    if bytes.len() < *off + n {
        anyhow::bail!("shard {what} truncated at byte {}", *off);
    }
    let s = &bytes[*off..*off + n];
    *off += n;
    Ok(s)
}

/// Decode one shard reply into `(block_idx, refreshed_invroot, pu_secs,
/// piru_secs, left, right)` entries.
#[allow(clippy::type_complexity)]
fn decode_reply(bytes: &[u8]) -> Result<Vec<(usize, bool, f64, f64, SideState, SideState)>> {
    let mut off = 0usize;
    let n = u32::from_le_bytes(take(bytes, &mut off, 4, "reply")?.try_into().unwrap()) as usize;
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        let bi =
            u32::from_le_bytes(take(bytes, &mut off, 4, "reply")?.try_into().unwrap()) as usize;
        let refreshed = take(bytes, &mut off, 1, "reply")?[0] != 0;
        let pu_secs = f64::from_le_bytes(take(bytes, &mut off, 8, "reply")?.try_into().unwrap());
        let piru_secs = f64::from_le_bytes(take(bytes, &mut off, 8, "reply")?.try_into().unwrap());
        let (left, used) = SideState::deserialize(&bytes[off..])?;
        off += used;
        let (right, used) = SideState::deserialize(&bytes[off..])?;
        off += used;
        out.push((bi, refreshed, pu_secs, piru_secs, left, right));
    }
    if off != bytes.len() {
        anyhow::bail!("shard reply has {} trailing bytes", bytes.len() - off);
    }
    Ok(out)
}

/// One block owned by a shard worker. The side pair is `Some` between
/// rounds and moves into the round's [`Work`] items while it runs (which
/// also makes a duplicate request entry a hard error instead of a silent
/// state clobber).
struct OwnedBlock {
    idx: usize,
    states: Option<(SideState, SideState)>,
}

/// Per-entry work item for one refresh round, fanned over the shard's own
/// scheduler (index-ordered merge, so intra-shard parallelism keeps the
/// bit-identity contract).
struct Work {
    pos: usize,
    stat: Option<StatInput>,
    do_piru: bool,
    left: SideState,
    right: SideState,
    pu_secs: f64,
    piru_secs: f64,
}

/// Shard worker main loop: build the shard's own backend, then serve
/// `Load`/`Refresh` messages until every sender is gone. Every message gets
/// exactly one reply; panics inside a round are caught and reported as that
/// round's error, so the coordinator's barrier can never hang.
#[allow(clippy::too_many_arguments)]
fn shard_main(
    shard_id: usize,
    rx: mpsc::Receiver<ToShard>,
    reply: mpsc::Sender<(usize, Result<Vec<u8>>)>,
    backend_name: &str,
    artifact_dir: &Path,
    beta: f32,
    eps: f32,
    kind: SecondOrderKind,
    parallelism: usize,
) {
    let rt = match backend_by_name(backend_name, artifact_dir) {
        Ok(rt) => rt,
        Err(e) => {
            // reply with the construction error to every message so the
            // coordinator surfaces it at the next barrier
            let e = format!("shard {shard_id}: backend construction failed: {e:#}");
            for _ in rx {
                let _ = reply.send((shard_id, Err(anyhow!(e.clone()))));
            }
            return;
        }
    };
    let scheduler = Scheduler::new(parallelism);
    let mut owned: Vec<OwnedBlock> = Vec::new();
    for msg in rx {
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| match msg {
            ToShard::Load(bytes) => {
                owned = load_states(&bytes)?;
                Ok(Vec::new())
            }
            ToShard::Refresh(bytes) => {
                process_round(rt.as_ref(), &scheduler, &mut owned, &bytes, beta, eps, kind)
            }
        }));
        let res = match res {
            Ok(r) => r,
            Err(_) => Err(anyhow!("shard {shard_id} worker panicked during a round")),
        };
        if reply.send((shard_id, res)).is_err() {
            return; // coordinator gone
        }
    }
}

/// Parse a `Load` payload into the shard's owned blocks.
fn load_states(bytes: &[u8]) -> Result<Vec<OwnedBlock>> {
    let mut owned = Vec::new();
    let mut off = 0usize;
    while off < bytes.len() {
        if bytes.len() < off + 4 {
            anyhow::bail!("shard load payload truncated at byte {off}");
        }
        let idx = u32::from_le_bytes(bytes[off..off + 4].try_into().unwrap()) as usize;
        off += 4;
        let (left, used) = SideState::deserialize(&bytes[off..])?;
        off += used;
        let (right, used) = SideState::deserialize(&bytes[off..])?;
        off += used;
        owned.push(OwnedBlock { idx, states: Some((left, right)) });
    }
    Ok(owned)
}

/// Execute one refresh round against the shard's owned states and build
/// the reply buffer (reply wire format in the module docs).
fn process_round(
    rt: &dyn crate::runtime::Backend,
    scheduler: &Scheduler,
    owned: &mut Vec<OwnedBlock>,
    req: &[u8],
    beta: f32,
    eps: f32,
    kind: SecondOrderKind,
) -> Result<Vec<u8>> {
    let mut off = 0usize;
    let n = u32::from_le_bytes(take(req, &mut off, 4, "request")?.try_into().unwrap()) as usize;
    let mut work: Vec<Work> = Vec::with_capacity(n);
    let grad_codec = fp32();
    for _ in 0..n {
        let bi =
            u32::from_le_bytes(take(req, &mut off, 4, "request")?.try_into().unwrap()) as usize;
        let flags = take(req, &mut off, 1, "request")?[0];
        let stat = if flags & FLAG_PU != 0 {
            let tag = take(req, &mut off, 1, "request")?[0];
            Some(match tag {
                0 => StatInput::Grad(grad_codec.decode(&read_frame(req, &mut off)?)),
                1 => StatInput::Layer {
                    lx: grad_codec.decode(&read_frame(req, &mut off)?),
                    ry: grad_codec.decode(&read_frame(req, &mut off)?),
                },
                other => anyhow::bail!("shard request: unknown stat tag {other}"),
            })
        } else {
            None
        };
        let pos = owned
            .iter()
            .position(|b| b.idx == bi)
            .ok_or_else(|| anyhow!("shard request names block {bi} this shard does not own"))?;
        // move the states into the work item; they return to the store
        // after the round
        let (left, right) = owned[pos]
            .states
            .take()
            .ok_or_else(|| anyhow!("shard request names block {bi} twice in one round"))?;
        work.push(Work {
            pos,
            stat,
            do_piru: flags & FLAG_PIRU != 0,
            left,
            right,
            pu_secs: 0.0,
            piru_secs: 0.0,
        });
    }
    if off != req.len() {
        anyhow::bail!("shard request has {} trailing bytes", req.len() - off);
    }
    let round = scheduler.par_map_mut(&mut work, |_, w| {
        if let Some(stat) = w.stat.take() {
            let t = Stopwatch::start();
            refresh_pu(rt, &mut w.left, &mut w.right, stat, beta, kind)?;
            w.pu_secs = t.secs();
        }
        if w.do_piru {
            let t = Stopwatch::start();
            run_invroot(rt, &mut w.left, eps, kind)?;
            run_invroot(rt, &mut w.right, eps, kind)?;
            w.piru_secs = t.secs();
        }
        Ok(())
    });
    // whatever happened, put the states back before surfacing errors, so a
    // failed round leaves the shard consistent (unvisited items keep their
    // pre-round state)
    let mut reply = Vec::new();
    reply.extend((work.len() as u32).to_le_bytes());
    for w in work {
        let bi = owned[w.pos].idx;
        reply.extend((bi as u32).to_le_bytes());
        reply.push(w.do_piru as u8);
        reply.extend(w.pu_secs.to_le_bytes());
        reply.extend(w.piru_secs.to_le_bytes());
        reply.extend(w.left.serialize());
        reply.extend(w.right.serialize());
        owned[w.pos].states = Some((w.left, w.right));
    }
    round?;
    Ok(reply)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn assignment_is_round_robin_and_total() {
        for shards in 1..=5 {
            let mut counts = vec![0usize; shards];
            for bi in 0..23 {
                let s = shard_for(bi, shards);
                assert!(s < shards);
                counts[s] += 1;
            }
            let (min, max) = (
                counts.iter().min().copied().unwrap(),
                counts.iter().max().copied().unwrap(),
            );
            assert!(max - min <= 1, "round-robin must balance: {counts:?}");
        }
        assert_eq!(shard_for(7, 0), 0, "degenerate shard count clamps");
    }
}
