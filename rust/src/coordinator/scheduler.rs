//! The parallel block engine's scheduler: a **persistent** worker pool
//! (long-lived threads fed by a channel-style job queue) that fans
//! independent per-block tasks (PU / PIRU / precondition — Algorithm 3's
//! blocks are embarrassingly parallel) across `second.parallelism` workers,
//! plus the staggered inverse-root cohort plan and the per-stage wall-time
//! accounting ([`StepTimings`]).
//!
//! Two execution modes share the pool:
//!
//!  * **Fan-out** ([`Scheduler::par_map_mut`]): the caller blocks while the
//!    pool (plus the calling thread itself) drains an indexed task queue and
//!    merges results in index order. Threads are *reused* across calls —
//!    nothing is spawned per phase, unlike the scoped-thread engine this
//!    replaced.
//!  * **Background** ([`Scheduler::spawn`]): detached jobs (the cross-step
//!    PU/PIRU pipeline) run on the pool while the trainer keeps stepping;
//!    the submitter owns the completion barrier.
//!
//! Determinism contract: tasks are pure functions of `(index, item)`, workers
//! pull from a shared queue in arbitrary order, and results are merged into
//! an index-ordered `Vec` — so `parallelism = N` is bit-identical to
//! `parallelism = 1`. Errors are reported deterministically too: the
//! lowest-index failure wins.
//!
//! Lifecycle (see `docs/ARCHITECTURE.md` for the full diagram):
//!
//! ```text
//! Scheduler::new(N) ──► WorkerPool spawns N−1 threads ──► threads park on
//!   the queue condvar ──► par_map_mut/spawn push jobs + notify ──► threads
//!   run jobs (panics contained per job) ──► Drop: shutdown flag + notify_all
//!   ──► threads finish the queue, exit ──► Drop joins every handle.
//! ```

use std::any::Any;
use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, PoisonError};
use std::thread::JoinHandle;

use anyhow::Result;

/// Typed failures surfaced by the scheduler and the engines built on it,
/// instead of panics inside worker threads or stringly-typed `anyhow!`s.
///
/// Lock-poison inside the pool itself is *recovered*, not errored: every
/// pool critical section is a panic-atomic push/pop/assignment (the guarded
/// state cannot be observed half-updated), so
/// `unwrap_or_else(PoisonError::into_inner)` is sound there and keeps
/// `Drop`-path shutdown panic-safe. What cannot be recovered — a task that
/// never produced a result, a shard worker whose channel closed early —
/// becomes one of these variants and propagates as an error the trainer can
/// report.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ScheduleError {
    /// A fan-out task was never started because an earlier task (at a lower
    /// or unrelated index) already failed and aborted the queue.
    TaskSkipped {
        /// Index of the task that was skipped.
        index: usize,
    },
    /// A fan-out task produced no result and no failure was recorded — an
    /// engine invariant breach (every drained task must fill its slot).
    TaskAbandoned {
        /// Index of the task whose result slot stayed empty.
        index: usize,
    },
    /// A background job needed the persistent pool but it has no threads.
    NoPoolThreads,
    /// A shard worker's request channel or reply channel disconnected while
    /// the coordinator still had traffic for it (worker thread exited early).
    ShardDisconnected {
        /// Index of the shard whose worker went away.
        shard: usize,
    },
}

impl std::fmt::Display for ScheduleError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::TaskSkipped { index } => {
                write!(f, "scheduler: task {index} skipped after an earlier task failed")
            }
            Self::TaskAbandoned { index } => {
                write!(f, "scheduler: task {index} never completed")
            }
            Self::NoPoolThreads => {
                write!(f, "scheduler: persistent pool refused a background job (no threads)")
            }
            Self::ShardDisconnected { shard } => {
                write!(f, "shard {shard} worker exited early")
            }
        }
    }
}

impl std::error::Error for ScheduleError {}

/// A queued unit of work for the persistent pool.
type Job = Box<dyn FnOnce() + Send + 'static>;

/// State shared between the pool handle and its worker threads.
struct PoolShared {
    /// FIFO job queue; workers block on `cv` while it is empty.
    queue: Mutex<VecDeque<Job>>,
    /// Wakes parked workers when a job lands or shutdown begins.
    cv: Condvar,
    /// Set (under the queue lock) by `Drop`; workers exit once the queue
    /// drains.
    shutdown: AtomicBool,
    /// Jobs queued or currently running — lets fan-out callers recruit only
    /// *idle* threads as helpers instead of queuing behind long background
    /// pipeline jobs.
    pending: AtomicUsize,
}

/// A pool of long-lived worker threads fed by a shared job queue.
///
/// Threads are spawned once at construction and live until the pool is
/// dropped; submitting work is a queue push + condvar notify, never a thread
/// spawn. On drop the pool performs a *graceful* shutdown: the queue is
/// drained (already-submitted jobs still run), then every thread exits and
/// is joined. A panicking job is contained to that job — the worker thread
/// survives and keeps serving the queue.
pub struct WorkerPool {
    shared: Arc<PoolShared>,
    handles: Vec<JoinHandle<()>>,
}

impl WorkerPool {
    /// Spawn `threads` persistent workers (0 is allowed: a queue-less pool
    /// that callers treat as "run everything inline").
    pub fn new(threads: usize) -> Self {
        let shared = Arc::new(PoolShared {
            queue: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
            pending: AtomicUsize::new(0),
        });
        let handles = (0..threads)
            .map(|i| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("shampoo4-pool-{i}"))
                    .spawn(move || worker_loop(&shared))
                    .expect("spawning pool worker thread")
            })
            .collect();
        Self { shared, handles }
    }

    /// Number of persistent worker threads.
    pub fn threads(&self) -> usize {
        self.handles.len()
    }

    /// Jobs queued or currently running (approximate — racy by nature).
    pub fn pending(&self) -> usize {
        // ordering: advisory snapshot for helper-count sizing; staleness only
        // shifts how many helpers fan out, never the merged result
        self.shared.pending.load(Ordering::Relaxed)
    }

    /// Queue a job. Panics if called on a zero-thread pool (the job would
    /// never run); callers gate on [`WorkerPool::threads`].
    fn submit(&self, job: Job) {
        assert!(!self.handles.is_empty(), "submit on a zero-thread pool");
        // ordering: SeqCst pairs with the worker-side fetch_sub so `pending`
        // can never under-count a job that is already visible in the queue
        self.shared.pending.fetch_add(1, Ordering::SeqCst);
        // poison recovery: the only critical section is a panic-atomic
        // push_back, so a poisoned queue is still structurally sound
        let mut q = self.shared.queue.lock().unwrap_or_else(PoisonError::into_inner);
        q.push_back(job);
        drop(q);
        self.shared.cv.notify_one();
    }
}

impl std::fmt::Debug for WorkerPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("WorkerPool").field("threads", &self.handles.len()).finish()
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        // set the flag under the queue lock: a worker between its shutdown
        // check and `cv.wait` holds that lock, so the store (and the notify
        // that follows) cannot slip into that window and be missed
        {
            // poison recovery: we only hold the lock to order the store, and
            // shutdown must proceed even if a worker panicked mid-job
            let _q = self.shared.queue.lock().unwrap_or_else(PoisonError::into_inner);
            // ordering: SeqCst store under the queue lock — see the comment
            // above; the matching load sits in `worker_loop`
            self.shared.shutdown.store(true, Ordering::SeqCst);
        }
        self.shared.cv.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Worker body: pop-run until shutdown *and* the queue is empty.
fn worker_loop(shared: &PoolShared) {
    loop {
        let job = {
            // poison recovery: a sibling worker panicking between pop and
            // run poisons nothing structural (pop_front is panic-atomic), so
            // the surviving workers keep serving the queue
            let mut q = shared.queue.lock().unwrap_or_else(PoisonError::into_inner);
            loop {
                if let Some(job) = q.pop_front() {
                    break job;
                }
                // ordering: SeqCst load pairs with the store in `Drop`, made
                // under this same lock, so a set flag is always observed here
                if shared.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                q = shared.cv.wait(q).unwrap_or_else(PoisonError::into_inner);
            }
        };
        // contain panics to the job: fan-out tasks re-raise them on the
        // submitting thread; background jobs surface them as a dropped
        // result channel at the pipeline barrier
        let _ = catch_unwind(AssertUnwindSafe(job));
        // ordering: SeqCst pairs with submit's fetch_add; the decrement must
        // not be visible before the job's effects are done
        shared.pending.fetch_sub(1, Ordering::SeqCst);
    }
}

/// Count-down latch: fan-out callers wait until every helper job has left
/// the shared task state (decrement happens in a drop guard, so panicking
/// helpers still count down).
struct Latch {
    remaining: Mutex<usize>,
    cv: Condvar,
}

impl Latch {
    fn new(n: usize) -> Self {
        Self { remaining: Mutex::new(n), cv: Condvar::new() }
    }

    fn arrive(&self) {
        // poison recovery: the decrement is panic-atomic, and `arrive` runs
        // from drop guards during unwinds — it must never double-panic
        let mut r = self.remaining.lock().unwrap_or_else(PoisonError::into_inner);
        *r -= 1;
        if *r == 0 {
            self.cv.notify_all();
        }
    }

    fn wait(&self) {
        // poison recovery: the count is valid even if a helper panicked (its
        // ArriveOnDrop guard still decremented during the unwind)
        let mut r = self.remaining.lock().unwrap_or_else(PoisonError::into_inner);
        while *r > 0 {
            r = self.cv.wait(r).unwrap_or_else(PoisonError::into_inner);
        }
    }
}

/// Decrements its latch when dropped — even during a panic unwind.
struct ArriveOnDrop(Arc<Latch>);

impl Drop for ArriveOnDrop {
    fn drop(&mut self) {
        self.0.arrive();
    }
}

/// `&dyn Fn` with the lifetime erased so helper jobs can live on the
/// 'static pool queue. Soundness: `par_map_mut` blocks on the latch until
/// every helper has finished with the pointee before returning.
struct ErasedTask(*const (dyn Fn() + Sync));

// SAFETY: the pointee is `Sync` and outlives every use (latch-guarded).
unsafe impl Send for ErasedTask {}

/// Handle to the parallel block engine for one run: a worker count plus a
/// shared [`WorkerPool`]. `Clone` shares the pool (Arc), so the trainer, the
/// second-order orchestrator, and the first-order chunked update all feed
/// the *same* persistent threads.
///
/// `parallelism = 1` degenerates to a plain serial loop with zero threads
/// and zero queue traffic.
#[derive(Debug, Clone)]
pub struct Scheduler {
    workers: usize,
    pool: Option<Arc<WorkerPool>>,
}

impl Scheduler {
    /// Engine with `parallelism` concurrent lanes: the calling thread plus
    /// `parallelism − 1` persistent pool threads. `parallelism = 1` creates
    /// no pool at all (the inline fast path).
    pub fn new(parallelism: usize) -> Self {
        let workers = parallelism.max(1);
        let pool = (workers > 1).then(|| Arc::new(WorkerPool::new(workers - 1)));
        Self { workers, pool }
    }

    /// Engine for pipelined runs: like [`Scheduler::new`] but guarantees at
    /// least one pool thread so background PU/PIRU jobs can overlap the
    /// model step even at `parallelism = 1`.
    pub fn pipelined(parallelism: usize) -> Self {
        let workers = parallelism.max(1);
        let pool = Arc::new(WorkerPool::new(workers.saturating_sub(1).max(1)));
        Self { workers, pool: Some(pool) }
    }

    /// A poolless serial scheduler (the default for contexts without an
    /// engine, e.g. `FirstOrder::step` called outside the trainer).
    pub fn inline() -> Self {
        Self { workers: 1, pool: None }
    }

    /// Configured concurrent lanes (1 = serial).
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Persistent pool threads backing this scheduler (0 = everything runs
    /// inline on the caller).
    pub fn pool_threads(&self) -> usize {
        self.pool.as_ref().map(|p| p.threads()).unwrap_or(0)
    }

    /// Submit a detached background job to the persistent pool. Returns
    /// `false` (job not queued, closure dropped) when the pool has no
    /// threads — the caller must then run the work inline.
    ///
    /// The job must be `'static`: background submitters own their data
    /// (cloned block states) and are responsible for a completion barrier
    /// before any borrowed resource they erased goes away (see
    /// `SecondOrder`'s pipeline for the one such use).
    pub fn spawn(&self, job: Box<dyn FnOnce() + Send + 'static>) -> bool {
        match &self.pool {
            Some(pool) if pool.threads() > 0 => {
                pool.submit(job);
                true
            }
            _ => false,
        }
    }

    /// Run `f(index, &mut item)` over every item, fanning across the pool,
    /// and merge the results in index order. `f` must be a pure function of
    /// its arguments (plus shared read-only captures) for the determinism
    /// contract to hold.
    ///
    /// The calling thread participates in the drain, and only *idle* pool
    /// threads are recruited as helpers — when background pipeline jobs
    /// occupy the pool, the fan-out shrinks (down to the plain caller-side
    /// loop) instead of queuing behind them, so this call never stalls on
    /// unrelated work. With `parallelism = 1` (or a single item, or zero
    /// idle threads) this is exactly the serial loop — no pool interaction,
    /// no allocation beyond the result `Vec`. Helper count never changes
    /// the merged result, so all of this stays bit-deterministic.
    ///
    /// Error path: the lowest-index failure is returned either way, and no
    /// *new* tasks start after a failure is observed — but tasks already in
    /// flight on other workers run to completion, so items past the failing
    /// index may or may not have been visited (the serial path stops at the
    /// failure). Callers treat any error as fatal to the run. A panicking
    /// task aborts the queue and the panic resumes on the calling thread.
    pub fn par_map_mut<T, R, F>(&self, items: &mut [T], f: F) -> Result<Vec<R>>
    where
        T: Send,
        R: Send,
        F: Fn(usize, &mut T) -> Result<R> + Sync,
    {
        let n = items.len();
        let idle = self
            .pool
            .as_ref()
            .map(|p| p.threads().saturating_sub(p.pending()))
            .unwrap_or(0);
        let helpers = self.workers.saturating_sub(1).min(idle).min(n.saturating_sub(1));
        if self.workers <= 1 || n <= 1 || helpers == 0 {
            return items.iter_mut().enumerate().map(|(i, t)| f(i, t)).collect();
        }
        let queue = Mutex::new(items.iter_mut().enumerate());
        let slots: Vec<Mutex<Option<Result<R>>>> = (0..n).map(|_| Mutex::new(None)).collect();
        let abort = AtomicBool::new(false);
        let panic_slot: Mutex<Option<Box<dyn Any + Send>>> = Mutex::new(None);
        let drain = || {
            loop {
                // ordering: Relaxed — the abort flag is a best-effort "stop
                // starting new tasks" hint; the merge below is what decides
                // the returned error, deterministically
                if abort.load(Ordering::Relaxed) {
                    break;
                }
                // take the queue lock only to pop, never while running f;
                // poison recovery: `next()` on the shared iterator is
                // panic-atomic (task panics happen outside this lock)
                let next = queue.lock().unwrap_or_else(PoisonError::into_inner).next();
                let Some((i, item)) = next else { break };
                match catch_unwind(AssertUnwindSafe(|| f(i, item))) {
                    Ok(r) => {
                        if r.is_err() {
                            // ordering: Relaxed — see the load above
                            abort.store(true, Ordering::Relaxed);
                        }
                        // poison recovery: a plain assignment cannot leave
                        // the slot half-written
                        *slots[i].lock().unwrap_or_else(PoisonError::into_inner) = Some(r);
                    }
                    Err(payload) => {
                        // ordering: Relaxed — see the load above
                        abort.store(true, Ordering::Relaxed);
                        let mut p = panic_slot.lock().unwrap_or_else(PoisonError::into_inner);
                        p.get_or_insert(payload);
                    }
                }
            }
        };

        let latch = Arc::new(Latch::new(helpers));
        {
            let task: &(dyn Fn() + Sync) = &drain;
            // SAFETY: every helper job holds an `ArriveOnDrop` guard that it
            // drops only after its last use of `task`; we block on the latch
            // below before `drain`/`queue`/`slots` leave scope, so the
            // erased reference never outlives its pointee.
            let task = ErasedTask(unsafe {
                std::mem::transmute::<&(dyn Fn() + Sync), &'static (dyn Fn() + Sync)>(task)
            });
            let pool = self.pool.as_ref().expect("pool_threads > 0 implies a pool");
            for _ in 0..helpers {
                let guard = ArriveOnDrop(Arc::clone(&latch));
                let task = ErasedTask(task.0);
                pool.submit(Box::new(move || {
                    let _done = guard;
                    // SAFETY: see above — the latch keeps the pointee alive.
                    let run: &(dyn Fn() + Sync) = unsafe { &*task.0 };
                    run();
                }));
            }
        }
        drain(); // the caller is a full worker too
        latch.wait();
        // poison recovery (both into_inner calls): the latch has been waited
        // out, every helper is done, and the guarded values are plain
        // `Option`s that cannot be half-written
        if let Some(payload) = panic_slot.into_inner().unwrap_or_else(PoisonError::into_inner) {
            std::panic::resume_unwind(payload);
        }
        let mut out = Vec::with_capacity(n);
        for (i, slot) in slots.into_iter().enumerate() {
            match slot.into_inner().unwrap_or_else(PoisonError::into_inner) {
                Some(Ok(r)) => out.push(r),
                Some(Err(e)) => return Err(e),
                None => {
                    // ordering: Relaxed — post-barrier read; the latch wait
                    // above is the synchronizing edge
                    if abort.load(Ordering::Relaxed) {
                        return Err(ScheduleError::TaskSkipped { index: i }.into());
                    }
                    return Err(ScheduleError::TaskAbandoned { index: i }.into());
                }
            }
        }
        Ok(out)
    }
}

/// Interval offset (in `[0, t2)`) at which block `block_idx` of `num_blocks`
/// runs its inverse-root update when staggering is enabled: blocks are spread
/// round-robin across the T2 interval so every block still refreshes once per
/// interval, but no single step pays the whole inverse-root bill.
pub fn stagger_phase(block_idx: usize, num_blocks: usize, t2: usize) -> usize {
    if num_blocks == 0 || t2 == 0 {
        return 0;
    }
    (block_idx % num_blocks) * t2 / num_blocks
}

/// Cumulative per-stage wall time over a training run, plus the worst single
/// step — the number the staggered PIRU schedule and the cross-step pipeline
/// exist to flatten.
#[derive(Debug, Clone, Default)]
pub struct StepTimings {
    /// steps accounted (resume-aware: only steps this `train` call ran)
    pub steps: u64,
    /// model fwd/bwd artifact time
    pub model_step_secs: f64,
    /// preconditioner updates (gram + PU), every T1; for pipelined runs this
    /// is background-thread time, accounted when the refresh lands
    pub pu_secs: f64,
    /// inverse-root updates (PIRU), every T2 or staggered; background-thread
    /// time for pipelined runs
    pub piru_secs: f64,
    /// gradient preconditioning, every step
    pub precond_secs: f64,
    /// native first-order update, every step
    pub first_order_secs: f64,
    /// main-thread time blocked at pipeline completion barriers (0 when the
    /// pipeline is off or refreshes land before they are needed)
    pub pipeline_stall_secs: f64,
    /// asynchronous refreshes submitted to the persistent pool
    pub pipeline_refreshes: u64,
    /// refreshes swapped in ahead of the lag bound by the adaptive barrier
    /// (`shampoo.pipeline_adaptive`): the pool had gone idle, so the results
    /// landed at the next step instead of waiting out `pipeline_max_lag`
    pub pipeline_early_completes: u64,
    /// wall time of the slowest step (excludes eval/metrics I/O)
    pub max_step_secs: f64,
    /// which step was slowest
    pub max_step_index: usize,
    /// refresh rounds dispatched to the sharded block engine (0 = unsharded)
    pub shard_rounds: u64,
    /// total actual bytes on the shard wire (codec-encoded requests +
    /// replies)
    pub shard_wire_bytes: u64,
    /// the state traffic (refreshed back-buffers shipped back by the
    /// shards), as actually sent: raw codec bytes
    pub shard_state_bytes: u64,
    /// what the same state traffic would cost under an fp32 wire format —
    /// `shard_state_fp32_bytes / shard_state_bytes` is the wire-format
    /// compression ratio reported in `BENCH_shard.json`. (Request traffic
    /// is excluded from the ratio: gradients ship as lossless fp32 frames
    /// under either format, so only the state payloads differ.)
    pub shard_state_fp32_bytes: u64,
}

impl StepTimings {
    /// Record one completed optimizer step's wall time.
    pub fn note_step(&mut self, step: usize, secs: f64) {
        self.steps += 1;
        if secs > self.max_step_secs {
            self.max_step_secs = secs;
            self.max_step_index = step;
        }
    }

    /// Total second-order time (PU + PIRU + precondition).
    pub fn second_order_secs(&self) -> f64 {
        self.pu_secs + self.piru_secs + self.precond_secs
    }

    /// One-line human summary for the CLI and benches.
    pub fn summary(&self) -> String {
        let pipeline = if self.pipeline_refreshes > 0 {
            let early = if self.pipeline_early_completes > 0 {
                format!(" ({} early)", self.pipeline_early_completes)
            } else {
                String::new()
            };
            format!(
                " | pipe {} refreshes{early}, {:.2}s stalled",
                self.pipeline_refreshes, self.pipeline_stall_secs
            )
        } else {
            String::new()
        };
        let shard = if self.shard_rounds > 0 {
            format!(
                " | shard {} rounds, {:.1} KiB wire (state {:.1}x vs fp32)",
                self.shard_rounds,
                self.shard_wire_bytes as f64 / 1024.0,
                self.shard_state_fp32_bytes as f64 / self.shard_state_bytes.max(1) as f64
            )
        } else {
            String::new()
        };
        format!(
            "model {:.2}s | pu {:.2}s | piru {:.2}s | precond {:.2}s | F {:.2}s | \
             max step {:.1} ms (step {}){pipeline}{shard}",
            self.model_step_secs,
            self.pu_secs,
            self.piru_secs,
            self.precond_secs,
            self.first_order_secs,
            self.max_step_secs * 1e3,
            self.max_step_index
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use anyhow::bail;
    use std::collections::HashSet;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn schedule_errors_are_typed_and_downcastable() {
        // the merge layer reports skipped/abandoned tasks as ScheduleError,
        // and anyhow callers can still recover the typed value
        let e: anyhow::Error = ScheduleError::TaskSkipped { index: 7 }.into();
        assert_eq!(
            e.downcast_ref::<ScheduleError>(),
            Some(&ScheduleError::TaskSkipped { index: 7 })
        );
        assert!(e.to_string().contains("task 7 skipped"));
        assert!(ScheduleError::NoPoolThreads.to_string().contains("no threads"));
        assert!(
            ScheduleError::ShardDisconnected { shard: 2 }.to_string().contains("shard 2")
        );
    }

    #[test]
    fn skipped_tasks_surface_as_typed_errors() {
        // force the skip path: enough items that an early failure leaves
        // later tasks unvisited on the parallel engine, then check the
        // returned error is either the task's own error (lowest index) —
        // never a panic from inside a worker
        let mut items: Vec<usize> = (0..64).collect();
        let err = Scheduler::new(4)
            .par_map_mut(&mut items, |i, _| {
                if i == 0 {
                    bail!("task 0 failed")
                }
                Ok(i)
            })
            .unwrap_err();
        assert_eq!(err.to_string(), "task 0 failed");
    }

    #[test]
    fn serial_and_parallel_merge_identically() {
        let base: Vec<usize> = (0..97).collect();
        let mut a = base.clone();
        let mut b = base.clone();
        let serial = Scheduler::new(1).par_map_mut(&mut a, |i, x| Ok(*x * 3 + i)).unwrap();
        let parallel = Scheduler::new(8).par_map_mut(&mut b, |i, x| Ok(*x * 3 + i)).unwrap();
        assert_eq!(serial, parallel);
        assert_eq!(serial[10], 40);
    }

    #[test]
    fn results_are_index_ordered_despite_uneven_tasks() {
        // later (cheap) tasks finish before earlier (slow) ones; the merge
        // must still come back in index order
        let mut items: Vec<usize> = (0..16).collect();
        let out = Scheduler::new(4)
            .par_map_mut(&mut items, |i, x| {
                if i < 4 {
                    std::thread::sleep(std::time::Duration::from_millis(5));
                }
                Ok(*x)
            })
            .unwrap();
        assert_eq!(out, (0..16).collect::<Vec<_>>());
    }

    #[test]
    fn mutations_land_in_place() {
        let mut items = vec![1i32; 12];
        Scheduler::new(3)
            .par_map_mut(&mut items, |i, x| {
                *x += i as i32;
                Ok(())
            })
            .unwrap();
        assert_eq!(items[0], 1);
        assert_eq!(items[11], 12);
    }

    #[test]
    fn lowest_index_error_wins() {
        for workers in [1, 4] {
            let mut items: Vec<usize> = (0..32).collect();
            let err = Scheduler::new(workers)
                .par_map_mut(&mut items, |i, _| {
                    if i == 7 || i == 21 {
                        bail!("task {i} failed")
                    }
                    Ok(i)
                })
                .unwrap_err();
            assert_eq!(err.to_string(), "task 7 failed");
        }
    }

    #[test]
    fn pool_actually_fans_out() {
        let peak = AtomicUsize::new(0);
        let live = AtomicUsize::new(0);
        let mut items = vec![0u8; 8];
        Scheduler::new(4)
            .par_map_mut(&mut items, |_, _| {
                let now = live.fetch_add(1, Ordering::SeqCst) + 1;
                peak.fetch_max(now, Ordering::SeqCst);
                std::thread::sleep(std::time::Duration::from_millis(10));
                live.fetch_sub(1, Ordering::SeqCst);
                Ok(())
            })
            .unwrap();
        assert!(peak.load(Ordering::SeqCst) > 1, "no concurrent execution observed");
    }

    #[test]
    fn parallelism_one_is_inline_with_zero_threads() {
        // the default config must pay zero pool overhead: no threads exist
        // and every task runs on the calling thread itself
        let sched = Scheduler::new(1);
        assert_eq!(sched.pool_threads(), 0);
        let caller = std::thread::current().id();
        let mut items = vec![0u8; 16];
        let ids = sched
            .par_map_mut(&mut items, |_, _| Ok(std::thread::current().id()))
            .unwrap();
        assert!(ids.iter().all(|&id| id == caller), "task escaped the calling thread");
        // a detached spawn is refused rather than silently dropped on a
        // zero-thread pool
        assert!(!sched.spawn(Box::new(|| {})));
        assert!(!Scheduler::inline().spawn(Box::new(|| {})));
    }

    #[test]
    fn pool_threads_persist_across_calls() {
        // the tentpole: the same long-lived threads serve every phase — two
        // fan-outs must observe overlapping pool-thread identities
        let sched = Scheduler::new(4);
        assert_eq!(sched.pool_threads(), 3);
        let caller = std::thread::current().id();
        let observe = |sched: &Scheduler| -> HashSet<std::thread::ThreadId> {
            let mut items = vec![0u8; 64];
            sched
                .par_map_mut(&mut items, |_, _| {
                    std::thread::sleep(std::time::Duration::from_millis(1));
                    Ok(std::thread::current().id())
                })
                .unwrap()
                .into_iter()
                .filter(|&id| id != caller)
                .collect()
        };
        let first = observe(&sched);
        let second = observe(&sched);
        assert!(!first.is_empty(), "no pool thread ever ran a task");
        assert!(
            first.intersection(&second).next().is_some(),
            "pool threads were not reused across calls: {first:?} vs {second:?}"
        );
    }

    #[test]
    fn background_spawn_runs_and_pool_drains_on_drop() {
        let sched = Scheduler::pipelined(1);
        assert_eq!(sched.pool_threads(), 1, "pipelined(1) still needs a background lane");
        let (tx, rx) = std::sync::mpsc::channel();
        assert!(sched.spawn(Box::new(move || {
            tx.send(42u32).unwrap();
        })));
        assert_eq!(rx.recv_timeout(std::time::Duration::from_secs(5)).unwrap(), 42);
        // graceful shutdown: jobs already queued still run before the drop
        // returns and every thread is joined
        let flag = Arc::new(AtomicBool::new(false));
        let f2 = Arc::clone(&flag);
        assert!(sched.spawn(Box::new(move || f2.store(true, Ordering::SeqCst))));
        drop(sched);
        assert!(flag.load(Ordering::SeqCst), "queued job was lost at shutdown");
    }

    #[test]
    fn task_panic_resumes_on_caller() {
        let sched = Scheduler::new(4);
        let mut items: Vec<usize> = (0..32).collect();
        let caught = catch_unwind(AssertUnwindSafe(|| {
            let _ = sched.par_map_mut(&mut items, |i, _| {
                if i == 5 {
                    panic!("task 5 exploded");
                }
                Ok(i)
            });
        }));
        assert!(caught.is_err(), "panic must propagate to the submitting thread");
        // ...and the pool must still be usable afterwards
        let out = sched.par_map_mut(&mut items, |i, x| Ok(*x + i)).unwrap();
        assert_eq!(out.len(), 32);
    }

    #[test]
    fn stagger_spreads_blocks_across_interval() {
        // 4 blocks over T2=20: phases 0, 5, 10, 15 — one cohort each
        let phases: Vec<usize> = (0..4).map(|i| stagger_phase(i, 4, 20)).collect();
        assert_eq!(phases, vec![0, 5, 10, 15]);
        // more blocks than steps in the interval: phases stay in [0, t2)
        for i in 0..50 {
            assert!(stagger_phase(i, 50, 8) < 8);
        }
        // every block gets exactly one phase per interval
        let mut counts = vec![0usize; 8];
        for i in 0..50 {
            counts[stagger_phase(i, 50, 8)] += 1;
        }
        assert_eq!(counts.iter().sum::<usize>(), 50);
        // round-robin balance: no step hosts more than ceil(n/t2)+slack
        assert!(*counts.iter().max().unwrap() <= 7);
    }

    #[test]
    fn timings_track_max_step() {
        let mut t = StepTimings::default();
        t.note_step(1, 0.010);
        t.note_step(2, 0.050);
        t.note_step(3, 0.020);
        assert_eq!(t.steps, 3);
        assert_eq!(t.max_step_index, 2);
        assert!((t.max_step_secs - 0.050).abs() < 1e-12);
        assert!(t.summary().contains("max step"));
        assert!(!t.summary().contains("pipe"), "no pipeline section when unused");
        t.pipeline_refreshes = 3;
        assert!(t.summary().contains("pipe 3 refreshes"));
    }
}
