//! The parallel block engine's scheduler: a std-only scoped-thread worker
//! pool that fans independent per-block tasks (PU / PIRU / precondition —
//! Algorithm 3's blocks are embarrassingly parallel) across
//! `second.parallelism` workers, plus the staggered inverse-root cohort plan
//! and the per-stage wall-time accounting (`StepTimings`).
//!
//! Determinism contract: tasks are pure functions of `(index, item)`, workers
//! pull from a shared queue in arbitrary order, and results are merged into
//! an index-ordered `Vec` — so `parallelism = N` is bit-identical to
//! `parallelism = 1`. Errors are reported deterministically too: the
//! lowest-index failure wins.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;

use anyhow::{bail, Result};

/// Worker pool for per-block fan-out. `parallelism = 1` degenerates to a
/// plain serial loop with zero thread overhead.
#[derive(Debug, Clone)]
pub struct Scheduler {
    workers: usize,
}

impl Scheduler {
    pub fn new(parallelism: usize) -> Self {
        Self { workers: parallelism.max(1) }
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Run `f(index, &mut item)` over every item, fanning across the pool,
    /// and merge the results in index order. `f` must be a pure function of
    /// its arguments (plus shared read-only captures) for the determinism
    /// contract to hold.
    ///
    /// Error path: the lowest-index failure is returned either way, and no
    /// *new* tasks start after a failure is observed — but tasks already in
    /// flight on other workers run to completion, so items past the failing
    /// index may or may not have been visited (the serial path stops at the
    /// failure). Callers treat any error as fatal to the run.
    pub fn par_map_mut<T, R, F>(&self, items: &mut [T], f: F) -> Result<Vec<R>>
    where
        T: Send,
        R: Send,
        F: Fn(usize, &mut T) -> Result<R> + Sync,
    {
        let n = items.len();
        if self.workers <= 1 || n <= 1 {
            return items.iter_mut().enumerate().map(|(i, t)| f(i, t)).collect();
        }
        let queue = Mutex::new(items.iter_mut().enumerate());
        let slots: Vec<Mutex<Option<Result<R>>>> = (0..n).map(|_| Mutex::new(None)).collect();
        let abort = AtomicBool::new(false);
        std::thread::scope(|s| {
            for _ in 0..self.workers.min(n) {
                s.spawn(|| {
                    loop {
                        if abort.load(Ordering::Relaxed) {
                            break;
                        }
                        // take the queue lock only to pop, never while running f
                        let next = queue.lock().expect("task queue lock").next();
                        let Some((i, item)) = next else { break };
                        let r = f(i, item);
                        if r.is_err() {
                            abort.store(true, Ordering::Relaxed);
                        }
                        *slots[i].lock().expect("result slot lock") = Some(r);
                    }
                });
            }
        });
        let mut out = Vec::with_capacity(n);
        for (i, slot) in slots.into_iter().enumerate() {
            match slot.into_inner().expect("result slot lock") {
                Some(Ok(r)) => out.push(r),
                Some(Err(e)) => return Err(e),
                None => {
                    if abort.load(Ordering::Relaxed) {
                        bail!("scheduler: task {i} skipped after an earlier task failed")
                    }
                    bail!("scheduler: task {i} never completed")
                }
            }
        }
        Ok(out)
    }
}

/// Interval offset (in `[0, t2)`) at which block `block_idx` of `num_blocks`
/// runs its inverse-root update when staggering is enabled: blocks are spread
/// round-robin across the T2 interval so every block still refreshes once per
/// interval, but no single step pays the whole inverse-root bill.
pub fn stagger_phase(block_idx: usize, num_blocks: usize, t2: usize) -> usize {
    if num_blocks == 0 || t2 == 0 {
        return 0;
    }
    (block_idx % num_blocks) * t2 / num_blocks
}

/// Cumulative per-stage wall time over a training run, plus the worst single
/// step — the number the staggered PIRU schedule exists to flatten.
#[derive(Debug, Clone, Default)]
pub struct StepTimings {
    /// steps accounted (resume-aware: only steps this `train` call ran)
    pub steps: u64,
    /// model fwd/bwd artifact time
    pub model_step_secs: f64,
    /// preconditioner updates (gram + PU), every T1
    pub pu_secs: f64,
    /// inverse-root updates (PIRU), every T2 or staggered
    pub piru_secs: f64,
    /// gradient preconditioning, every step
    pub precond_secs: f64,
    /// native first-order update, every step
    pub first_order_secs: f64,
    /// wall time of the slowest step (excludes eval/metrics I/O)
    pub max_step_secs: f64,
    /// which step was slowest
    pub max_step_index: usize,
}

impl StepTimings {
    /// Record one completed optimizer step's wall time.
    pub fn note_step(&mut self, step: usize, secs: f64) {
        self.steps += 1;
        if secs > self.max_step_secs {
            self.max_step_secs = secs;
            self.max_step_index = step;
        }
    }

    /// Total second-order time (PU + PIRU + precondition).
    pub fn second_order_secs(&self) -> f64 {
        self.pu_secs + self.piru_secs + self.precond_secs
    }

    /// One-line human summary for the CLI and benches.
    pub fn summary(&self) -> String {
        format!(
            "model {:.2}s | pu {:.2}s | piru {:.2}s | precond {:.2}s | F {:.2}s | \
             max step {:.1} ms (step {})",
            self.model_step_secs,
            self.pu_secs,
            self.piru_secs,
            self.precond_secs,
            self.first_order_secs,
            self.max_step_secs * 1e3,
            self.max_step_index
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn serial_and_parallel_merge_identically() {
        let base: Vec<usize> = (0..97).collect();
        let mut a = base.clone();
        let mut b = base.clone();
        let serial = Scheduler::new(1).par_map_mut(&mut a, |i, x| Ok(*x * 3 + i)).unwrap();
        let parallel = Scheduler::new(8).par_map_mut(&mut b, |i, x| Ok(*x * 3 + i)).unwrap();
        assert_eq!(serial, parallel);
        assert_eq!(serial[10], 40);
    }

    #[test]
    fn results_are_index_ordered_despite_uneven_tasks() {
        // later (cheap) tasks finish before earlier (slow) ones; the merge
        // must still come back in index order
        let mut items: Vec<usize> = (0..16).collect();
        let out = Scheduler::new(4)
            .par_map_mut(&mut items, |i, x| {
                if i < 4 {
                    std::thread::sleep(std::time::Duration::from_millis(5));
                }
                Ok(*x)
            })
            .unwrap();
        assert_eq!(out, (0..16).collect::<Vec<_>>());
    }

    #[test]
    fn mutations_land_in_place() {
        let mut items = vec![1i32; 12];
        Scheduler::new(3)
            .par_map_mut(&mut items, |i, x| {
                *x += i as i32;
                Ok(())
            })
            .unwrap();
        assert_eq!(items[0], 1);
        assert_eq!(items[11], 12);
    }

    #[test]
    fn lowest_index_error_wins() {
        for workers in [1, 4] {
            let mut items: Vec<usize> = (0..32).collect();
            let err = Scheduler::new(workers)
                .par_map_mut(&mut items, |i, _| {
                    if i == 7 || i == 21 {
                        bail!("task {i} failed")
                    }
                    Ok(i)
                })
                .unwrap_err();
            assert_eq!(err.to_string(), "task 7 failed");
        }
    }

    #[test]
    fn pool_actually_fans_out() {
        let peak = AtomicUsize::new(0);
        let live = AtomicUsize::new(0);
        let mut items = vec![0u8; 8];
        Scheduler::new(4)
            .par_map_mut(&mut items, |_, _| {
                let now = live.fetch_add(1, Ordering::SeqCst) + 1;
                peak.fetch_max(now, Ordering::SeqCst);
                std::thread::sleep(std::time::Duration::from_millis(10));
                live.fetch_sub(1, Ordering::SeqCst);
                Ok(())
            })
            .unwrap();
        assert!(peak.load(Ordering::SeqCst) > 1, "no concurrent execution observed");
    }

    #[test]
    fn stagger_spreads_blocks_across_interval() {
        // 4 blocks over T2=20: phases 0, 5, 10, 15 — one cohort each
        let phases: Vec<usize> = (0..4).map(|i| stagger_phase(i, 4, 20)).collect();
        assert_eq!(phases, vec![0, 5, 10, 15]);
        // more blocks than steps in the interval: phases stay in [0, t2)
        for i in 0..50 {
            assert!(stagger_phase(i, 50, 8) < 8);
        }
        // every block gets exactly one phase per interval
        let mut counts = vec![0usize; 8];
        for i in 0..50 {
            counts[stagger_phase(i, 50, 8)] += 1;
        }
        assert_eq!(counts.iter().sum::<usize>(), 50);
        // round-robin balance: no step hosts more than ceil(n/t2)+slack
        assert!(*counts.iter().max().unwrap() <= 7);
    }

    #[test]
    fn timings_track_max_step() {
        let mut t = StepTimings::default();
        t.note_step(1, 0.010);
        t.note_step(2, 0.050);
        t.note_step(3, 0.020);
        assert_eq!(t.steps, 3);
        assert_eq!(t.max_step_index, 2);
        assert!((t.max_step_secs - 0.050).abs() < 1e-12);
        assert!(t.summary().contains("max step"));
    }
}
