//! Model handle: host-side parameter buffers + marshaling into the model
//! step/eval artifacts. Initialization mirrors python/compile/model.py
//! (same distribution families; bit-identical init is not required — the
//! compute graphs are identical).

use anyhow::{bail, Context, Result};

use crate::data::{corpus::BigramCorpus, vision::VisionDataset, Batch};
use crate::runtime::{Backend, HostTensor, ModelSpec};
use crate::util::rng::Rng;

/// Host-side parameters of one model + its step/eval marshaling.
pub struct ModelHandle {
    /// Manifest key of the model.
    pub name: String,
    /// The backend's spec for it.
    pub spec: ModelSpec,
    /// Parameter buffers, one flat vec per tensor.
    pub params: Vec<Vec<f32>>,
    /// Shapes matching `params`.
    pub shapes: Vec<Vec<usize>>,
    /// Names matching `params`.
    pub names: Vec<String>,
}

impl ModelHandle {
    /// Initialize the named model's parameters from `seed`.
    pub fn new(rt: &dyn Backend, name: &str, seed: u64) -> Result<Self> {
        let spec = rt
            .manifest()
            .models
            .get(name)
            .with_context(|| format!("unknown model {name}"))?
            .clone();
        let shapes: Vec<Vec<usize>> = spec.params.iter().map(|p| p.shape.clone()).collect();
        let names: Vec<String> = spec.params.iter().map(|p| p.name.clone()).collect();
        let mut rng = Rng::new(seed ^ 0x0DE1_0001);
        let params = match spec.kind.as_str() {
            "mlp" => init_mlp(&names, &shapes, &mut rng),
            "tlm" => init_tlm(&names, &shapes, spec.params.len(), &mut rng),
            other => bail!("unknown model kind {other}"),
        };
        Ok(Self { name: name.to_string(), spec, params, shapes, names })
    }

    /// Total scalar parameters.
    pub fn param_count(&self) -> usize {
        self.params.iter().map(|p| p.len()).sum()
    }

    /// Parameter bytes (fp32).
    pub fn params_bytes(&self) -> usize {
        self.param_count() * 4
    }

    fn param_tensors(&self, params: &[Vec<f32>]) -> Vec<HostTensor> {
        params
            .iter()
            .zip(&self.shapes)
            .map(|(p, s)| HostTensor::f32(s, p.clone()))
            .collect()
    }

    fn batch_tensors(&self, batch: &Batch) -> Result<Vec<HostTensor>> {
        Ok(match batch {
            Batch::Vision { x, y, batch, dim } => vec![
                HostTensor::f32(&[*batch, *dim], x.clone()),
                HostTensor::i32(&[*batch], y.clone()),
            ],
            Batch::Tokens { tokens, batch, seq_plus1 } => vec![HostTensor::i32(
                &[*batch, *seq_plus1],
                tokens.clone(),
            )],
        })
    }

    /// Run the fwd/bwd step artifact: returns (loss, grads, kfac_stats).
    /// kfac_stats is empty for transformer models.
    pub fn step(
        &self,
        rt: &dyn Backend,
        batch: &Batch,
    ) -> Result<(f32, Vec<Vec<f32>>, Vec<Vec<f32>>)> {
        let mut inputs = self.param_tensors(&self.params);
        inputs.extend(self.batch_tensors(batch)?);
        let outs = rt.execute(&self.spec.step, &inputs)?;
        let loss = outs[0].as_f32()?[0];
        let np = self.params.len();
        let mut grads = Vec::with_capacity(np);
        for o in &outs[1..1 + np] {
            grads.push(o.clone().into_f32()?);
        }
        let mut stats = Vec::new();
        for o in &outs[1 + np..] {
            stats.push(o.clone().into_f32()?);
        }
        Ok((loss, grads, stats))
    }

    /// Run the eval artifact with given parameters (may differ from the
    /// training iterate, e.g. schedule-free averages).
    /// Returns (loss, correct-or-None).
    pub fn eval(
        &self,
        rt: &dyn Backend,
        params: &[Vec<f32>],
        batch: &Batch,
    ) -> Result<(f32, Option<usize>)> {
        let mut inputs = self.param_tensors(params);
        inputs.extend(self.batch_tensors(batch)?);
        let outs = rt.execute(&self.spec.eval, &inputs)?;
        let loss = outs[0].as_f32()?[0];
        let correct = if outs.len() > 1 {
            Some(outs[1].as_i32()?[0] as usize)
        } else {
            None
        };
        Ok((loss, correct))
    }

    /// Build the data source matching this model.
    pub fn data_source(&self, seed: u64) -> DataSource {
        match self.spec.kind.as_str() {
            "mlp" => DataSource::Vision(VisionDataset::new(
                self.spec.dims[0],
                self.spec.classes,
                seed,
            )),
            _ => DataSource::Corpus(BigramCorpus::new(self.spec.vocab, seed)),
        }
    }

    /// Draw the model's batch shape from `src` (train or held-out split).
    pub fn make_batch(&self, src: &DataSource, test: bool, index: u64) -> Batch {
        match src {
            DataSource::Vision(ds) => {
                let split = if test {
                    crate::data::vision::Split::Test
                } else {
                    crate::data::vision::Split::Train
                };
                let (x, y) = ds.batch(self.spec.batch, split, index);
                Batch::Vision { x, y, batch: self.spec.batch, dim: self.spec.dims[0] }
            }
            DataSource::Corpus(c) => {
                let toks = c.batch(self.spec.batch, self.spec.seq + 1, test, index);
                Batch::Tokens {
                    tokens: toks,
                    batch: self.spec.batch,
                    seq_plus1: self.spec.seq + 1,
                }
            }
        }
    }
}

/// The synthetic dataset matching a model family.
pub enum DataSource {
    /// Classification features (MLP models).
    Vision(VisionDataset),
    /// Token stream (transformer LMs).
    Corpus(BigramCorpus),
}

fn init_mlp(names: &[String], shapes: &[Vec<usize>], rng: &mut Rng) -> Vec<Vec<f32>> {
    names
        .iter()
        .zip(shapes)
        .map(|(name, shape)| {
            if name.starts_with('w') && shape.len() == 2 {
                let std = (2.0 / shape[0] as f64).sqrt() as f32;
                rng.normal_vec(shape.iter().product())
                    .into_iter()
                    .map(|x| x * std)
                    .collect()
            } else {
                vec![0.0; shape.iter().product()]
            }
        })
        .collect()
}

fn init_tlm(names: &[String], shapes: &[Vec<usize>], _np: usize, rng: &mut Rng) -> Vec<Vec<f32>> {
    // depth-scaled init like python tlm_init
    let n_layers = names
        .iter()
        .filter(|n| n.ends_with(".wqkv"))
        .count()
        .max(1);
    names
        .iter()
        .zip(shapes)
        .map(|(name, shape)| {
            let n: usize = shape.iter().product();
            if name.ends_with("_g") {
                vec![1.0; n]
            } else if name.ends_with("_b") {
                vec![0.0; n]
            } else {
                let std = if name.ends_with(".wo") || name.ends_with(".w2") {
                    0.02 / (2.0 * n_layers as f64).sqrt()
                } else {
                    0.02
                } as f32;
                rng.normal_vec(n).into_iter().map(|x| x * std).collect()
            }
        })
        .collect()
}
