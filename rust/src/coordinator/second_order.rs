//! Second-order orchestration: owns every preconditioner block, schedules
//! PU (every T1) and PIRU (every T2, optionally staggered into per-step
//! cohorts) through the AOT artifacts, and preconditions gradients (every
//! step) — Algorithm 3 driven from Rust.
//!
//! The per-block loops are task-graph submissions to the parallel block
//! engine (`coordinator::scheduler`): each block's left/right pair is one
//! task, fanned across `cfg.parallelism` workers with an index-ordered merge,
//! so any parallelism level is bit-identical to the serial run.

use anyhow::{anyhow, Result};

use crate::config::{SecondOrderConfig, SecondOrderKind};
use crate::coordinator::model::ModelHandle;
use crate::coordinator::partition::{extract_block, partition, scatter_block, Block};
use crate::coordinator::scheduler::{stagger_phase, Scheduler};
use crate::coordinator::state::{run_invroot, run_pu, SideState};
use crate::linalg::Mat;
use crate::quant::codec_for;
use crate::runtime::{Backend, HostTensor};

pub struct BlockPre {
    pub block: Block,
    pub left: SideState,
    pub right: SideState,
    /// cached artifact-input tensors for the inverse roots (§Perf L3-2):
    /// rebuilt only when PIRU runs (every T2), not on every step's
    /// precondition — saves the nibble-unpack + clone per block per step.
    inv_cache: Option<Vec<HostTensor>>,
}

pub struct SecondOrder {
    pub cfg: SecondOrderConfig,
    pub blocks: Vec<BlockPre>,
    /// K-FAC/AdaBK mode: whole-layer preconditioners fed by activation /
    /// gradient statistics instead of GGᵀ (Algorithm 5).
    pub kfac_mode: bool,
    /// counts of host-fallback preconditions (observability)
    pub host_fallbacks: u64,
    /// the parallel block engine's worker pool
    scheduler: Scheduler,
}

impl SecondOrder {
    pub fn new(cfg: &SecondOrderConfig, model: &ModelHandle, buckets: &[usize]) -> Result<Self> {
        if !matches!(cfg.quant.bits, 3 | 4 | 16 | 32) {
            return Err(anyhow!(
                "second-order quant.bits must be 3 or 4 (quantized kernels) or 16/32 \
                 (dense), got {}",
                cfg.quant.bits
            ));
        }
        let codec = codec_for(cfg.quant.bits, cfg.quant.mapping);
        let kfac_mode = matches!(cfg.kind, SecondOrderKind::KFac | SecondOrderKind::AdaBk);
        let blocks = if kfac_mode {
            if model.spec.kind != "mlp" {
                return Err(anyhow!(
                    "K-FAC/AdaBK requires the MLP model (activation statistics)"
                ));
            }
            // whole-layer preconditioners; MLP dims are bucket-exact
            let mut kfac_buckets = buckets.to_vec();
            for &d in &model.spec.dims {
                if !kfac_buckets.contains(&d) {
                    kfac_buckets.push(d);
                }
            }
            kfac_buckets.sort_unstable();
            let max = *kfac_buckets.last().unwrap();
            let weight_shapes: Vec<Vec<usize>> = model
                .shapes
                .iter()
                .map(|s| if s.len() == 2 { s.clone() } else { vec![] })
                .collect();
            partition(&weight_shapes, &kfac_buckets, max)
        } else {
            partition(&model.shapes, buckets, cfg.max_order)
        };
        let blocks = blocks
            .into_iter()
            .map(|b| BlockPre {
                left: SideState::new(b.bm, cfg, &codec),
                right: SideState::new(b.bn, cfg, &codec),
                block: b,
                inv_cache: None,
            })
            .collect();
        Ok(Self {
            cfg: cfg.clone(),
            blocks,
            kfac_mode,
            host_fallbacks: 0,
            scheduler: Scheduler::new(cfg.parallelism),
        })
    }

    /// Serialize every block's (left, right) state for checkpoints —
    /// raw codec bytes, so restore is bit-exact.
    pub fn serialize_state(&self) -> Vec<u8> {
        let mut out = Vec::new();
        for bp in &self.blocks {
            out.extend(bp.left.serialize());
            out.extend(bp.right.serialize());
        }
        out
    }

    /// Restore a blob written by [`SecondOrder::serialize_state`] into this
    /// (identically configured) instance. The whole blob is parsed and
    /// validated before any block is touched (atomic restore); cached
    /// precondition inputs are invalidated, and the next step rebuilds them
    /// from the restored state.
    pub fn restore_state(&mut self, bytes: &[u8]) -> Result<()> {
        let mut off = 0usize;
        let mut restored = Vec::with_capacity(self.blocks.len() * 2);
        for (bi, bp) in self.blocks.iter().enumerate() {
            for side in [&bp.left, &bp.right] {
                let (s, used) = SideState::deserialize(&bytes[off..])?;
                if s.order() != side.order()
                    || s.arm_name() != side.arm_name()
                    || s.codec_name() != side.codec_name()
                {
                    return Err(anyhow!(
                        "checkpoint second-order block {bi} is {}@{} ({}), run expects \
                         {}@{} ({})",
                        s.arm_name(),
                        s.order(),
                        s.codec_name(),
                        side.arm_name(),
                        side.order(),
                        side.codec_name()
                    ));
                }
                restored.push(s);
                off += used;
            }
        }
        if off != bytes.len() {
            return Err(anyhow!(
                "second-order checkpoint blob has {} trailing bytes",
                bytes.len() - off
            ));
        }
        let mut it = restored.into_iter();
        for bp in self.blocks.iter_mut() {
            bp.left = it.next().expect("one side per parsed entry");
            bp.right = it.next().expect("one side per parsed entry");
            bp.inv_cache = None;
        }
        Ok(())
    }

    /// Worker count of the block engine (1 = serial).
    pub fn parallelism(&self) -> usize {
        self.scheduler.workers()
    }

    pub fn state_bytes(&self) -> usize {
        self.blocks
            .iter()
            .map(|b| b.left.state_bytes() + b.right.state_bytes())
            .sum()
    }

    /// PU for every block (Algorithm 3 line 6). For Shampoo/CASPR the
    /// statistics are GGᵀ/GᵀG of the current block gradient (via the gram
    /// artifact); for K-FAC/AdaBK they are the layer statistics from the
    /// model step (`stats[2i]` = XᵀX/bs, `stats[2i+1]` = δYᵀδY·bs).
    pub fn update_preconditioners(
        &mut self,
        rt: &dyn Backend,
        model: &ModelHandle,
        grads: &[Vec<f32>],
        stats: &[Vec<f32>],
    ) -> Result<()> {
        let beta = self.cfg.beta;
        let kind = self.cfg.kind;
        let kfac_mode = self.kfac_mode;
        self.scheduler.par_map_mut(&mut self.blocks, |bi, bp| {
            let (m, n) = (bp.block.bm, bp.block.bn);
            let (l_stat, r_stat) = if kfac_mode {
                // layer index = bi (one block per 2-D weight, in order)
                let r = &stats[2 * bi]; // XᵀX/bs  (in, in)
                let l = &stats[2 * bi + 1]; // δYᵀδY·bs (out, out)
                (HostTensor::f32(&[m, m], r.clone()), HostTensor::f32(&[n, n], l.clone()))
            } else {
                let g = extract_block(
                    &grads[bp.block.param_idx],
                    &model.shapes[bp.block.param_idx],
                    &bp.block,
                );
                let outs = rt.execute(&format!("gram_{m}x{n}"), &[HostTensor::f32(&[m, n], g)])?;
                (outs[0].clone(), outs[1].clone())
            };
            run_pu(rt, &mut bp.left, l_stat, beta, kind)?;
            run_pu(rt, &mut bp.right, r_stat, beta, kind)
        })?;
        Ok(())
    }

    /// PIRU / inverse-root for every block (Algorithm 3 line 10).
    pub fn update_invroots(&mut self, rt: &dyn Backend) -> Result<()> {
        let all: Vec<usize> = (0..self.blocks.len()).collect();
        self.update_invroots_subset(rt, &all)
    }

    /// PIRU / inverse-root for a cohort of blocks (staggered scheduling runs
    /// one cohort per step; batch mode passes every index at the T2 boundary).
    pub fn update_invroots_subset(&mut self, rt: &dyn Backend, idxs: &[usize]) -> Result<()> {
        if idxs.is_empty() {
            return Ok(());
        }
        let eps = self.cfg.eps;
        let kind = self.cfg.kind;
        let mut selected = vec![false; self.blocks.len()];
        for &i in idxs {
            selected[i] = true;
        }
        let mut cohort: Vec<&mut BlockPre> = self
            .blocks
            .iter_mut()
            .enumerate()
            .filter(|(i, _)| selected[*i])
            .map(|(_, bp)| bp)
            .collect();
        self.scheduler.par_map_mut(&mut cohort, |_, bp| {
            run_invroot(rt, &mut bp.left, eps, kind)?;
            run_invroot(rt, &mut bp.right, eps, kind)?;
            bp.inv_cache = None; // invalidate cached precondition inputs
            Ok(())
        })?;
        Ok(())
    }

    /// Which blocks' inverse roots are due at (1-based) trainer step `step`.
    /// Batch mode: every block at T2 boundaries. Staggered mode: round-robin
    /// cohorts spread across the T2 interval (`scheduler::stagger_phase`), so
    /// each block still refreshes once per interval but no step pays for all
    /// of them at once.
    pub fn invroot_due(&self, step: usize) -> Vec<usize> {
        let t2 = self.cfg.update_invroot_every.max(1);
        let n = self.blocks.len();
        if !self.cfg.stagger_invroots {
            return if step % t2 == 0 { (0..n).collect() } else { Vec::new() };
        }
        let phase = step % t2;
        (0..n).filter(|&i| stagger_phase(i, n, t2) == phase).collect()
    }

    /// Precondition all gradients in place (Algorithm 3 lines 13–14).
    ///
    /// Two phases: the per-block transforms run as parallel tasks over a
    /// read-only view of the gradients (the cached artifact inputs are
    /// `Arc`-backed, so re-submitting them each step shares the state buffers
    /// instead of deep-copying them), then the disjoint results are scattered
    /// back serially in block-index order.
    pub fn precondition(
        &mut self,
        rt: &dyn Backend,
        model: &ModelHandle,
        grads: &mut [Vec<f32>],
    ) -> Result<()> {
        let caspr = self.cfg.kind == SecondOrderKind::Caspr;
        let grads_ro: &[Vec<f32>] = grads;
        let results = self.scheduler.par_map_mut(&mut self.blocks, |_, bp| {
            let (m, n) = (bp.block.bm, bp.block.bn);
            let shape = &model.shapes[bp.block.param_idx];
            let g = extract_block(&grads_ro[bp.block.param_idx], shape, &bp.block);

            let artifact = match (bp.left.is_dense(), bp.right.is_dense()) {
                (true, true) => {
                    let name = if caspr {
                        format!("caspr32_{m}x{n}")
                    } else {
                        format!("precond32_{m}x{n}")
                    };
                    rt.has_artifact(&name).then_some(name)
                }
                (true, false) | (false, true) => None,
                (false, false) => {
                    let name = if caspr {
                        format!("caspr4_{m}x{n}")
                    } else {
                        format!("precond4_{m}x{n}")
                    };
                    rt.has_artifact(&name).then_some(name)
                }
            };

            match artifact {
                Some(name) => {
                    if bp.inv_cache.is_none() {
                        let mut state = bp.left.invroot_inputs()?;
                        state.extend(bp.right.invroot_inputs()?);
                        if let Some(rcb) = bp.left.runtime_codebook() {
                            state.push(HostTensor::f32(&[16], rcb.to_vec()));
                        }
                        bp.inv_cache = Some(state);
                    }
                    let mut inputs = vec![HostTensor::f32(&[m, n], g)];
                    inputs.extend(bp.inv_cache.as_ref().unwrap().iter().cloned());
                    let mut outs = rt.execute(&name, &inputs)?;
                    Ok((outs.remove(0).into_f32()?, false))
                }
                None => {
                    // host mirror: mixed arms or no matching artifact pair
                    let gt = precondition_host(
                        &g,
                        m,
                        n,
                        &bp.left.invroot_host(0),
                        &bp.right.invroot_host(0),
                        caspr,
                    );
                    Ok((gt, true))
                }
            }
        })?;
        for (bp, (gt, fellback)) in self.blocks.iter().zip(results) {
            if fellback {
                self.host_fallbacks += 1;
            }
            let shape = &model.shapes[bp.block.param_idx];
            scatter_block(&mut grads[bp.block.param_idx], shape, &bp.block, &gt);
        }
        Ok(())
    }
}

/// Host mirror of precond32/caspr32 + grafting — delegates to the single
/// implementation in `runtime::host::ops` so the artifact path and this
/// mixed-arm fallback can never numerically diverge.
pub fn precondition_host(
    g: &[f32],
    m: usize,
    n: usize,
    lhat: &Mat,
    rhat: &Mat,
    caspr: bool,
) -> Vec<f32> {
    let gm = Mat::from_vec(m, n, g.to_vec());
    let mut outs = crate::runtime::host::ops::precond_dense(&gm, lhat, rhat, caspr);
    outs.remove(0).into_f32().expect("precond_dense emits one f32 tensor")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn host_precondition_identity() {
        let g: Vec<f32> = (0..12).map(|i| i as f32).collect();
        let out = precondition_host(&g, 3, 4, &Mat::eye(3), &Mat::eye(4), false);
        for (a, b) in out.iter().zip(&g) {
            assert!((a - b).abs() < 1e-5);
        }
        // CASPR with identity states: J = 2G, Ĝ = 4G, grafted back to ‖G‖
        let out = precondition_host(&g, 3, 4, &Mat::eye(3), &Mat::eye(4), true);
        for (a, b) in out.iter().zip(&g) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn host_precondition_grafts_norm() {
        let g = vec![1.0f32; 16];
        let out = precondition_host(&g, 4, 4, &Mat::eye(4).scale(10.0), &Mat::eye(4), false);
        let norm: f32 = out.iter().map(|x| x * x).sum::<f32>().sqrt();
        assert!((norm - 4.0).abs() < 1e-3); // ‖G‖_F preserved
    }
}
