//! Second-order orchestration: owns every preconditioner block, schedules
//! PU (every T1) and PIRU (every T2, optionally staggered into per-step
//! cohorts) through the AOT artifacts, and preconditions gradients (every
//! step) — Algorithm 3 driven from Rust.
//!
//! The per-block loops are task-graph submissions to the parallel block
//! engine (`coordinator::scheduler`): each block's left/right pair is one
//! task, fanned across `cfg.parallelism` workers with an index-ordered merge,
//! so any parallelism level is bit-identical to the serial run.
//!
//! With `shampoo.pipeline` on, PU/PIRU refreshes additionally run
//! *asynchronously*: [`SecondOrder::submit_refresh`] clones each due block's
//! side pair (the double-buffer back copies, [`RefreshedBlock`]) and queues
//! one background job per block on the persistent pool; subsequent model
//! steps overlap the refresh, preconditioning with the unchanged front
//! copies, until [`SecondOrder::complete_pipeline`] swaps the results in at
//! a deterministic barrier (next refresh due, `pipeline_max_lag` reached, or
//! end of training). Barrier steps are pure functions of the step index, so
//! pipelined runs are bit-reproducible at any parallelism.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};

use anyhow::{anyhow, Result};

use crate::config::{SecondOrderConfig, SecondOrderKind};
use crate::coordinator::model::ModelHandle;
use std::path::Path;

use crate::coordinator::partition::{extract_block, partition, scatter_block, Block};
use crate::coordinator::scheduler::{stagger_phase, ScheduleError, Scheduler, StepTimings};
use crate::coordinator::shard::ShardSet;
use crate::coordinator::state::{run_invroot, run_pu, RefreshedBlock, SideState};
use crate::linalg::Mat;
use crate::quant::{BufferRole, CodecPolicy, CodecSpec};
use crate::runtime::{Backend, HostTensor};
use crate::util::timer::Stopwatch;

/// One partitioned parameter block and its left/right preconditioner pair.
pub struct BlockPre {
    /// The block's coordinates inside its parameter tensor.
    pub block: Block,
    /// Left (row-side) preconditioner state.
    pub left: SideState,
    /// Right (column-side) preconditioner state.
    pub right: SideState,
    /// cached artifact-input tensors for the inverse roots (§Perf L3-2):
    /// rebuilt only when PIRU runs (every T2), not on every step's
    /// precondition — saves the nibble-unpack + clone per block per step.
    /// `pub(crate)` so the sharded engine can invalidate it when it swaps
    /// a refreshed root in.
    pub(crate) inv_cache: Option<Vec<HostTensor>>,
}

/// Statistics payload for one block's PU — captured on the coordinator
/// thread, consumed by [`refresh_pu`] (synchronously, on a pool thread for
/// pipelined refreshes, or shard-side after an fp32 wire trip).
pub(crate) enum StatInput {
    /// Shampoo/CASPR: the block's raw gradient; the gram artifact runs
    /// where the PU runs (so for pipelined refreshes the GGᵀ cost overlaps
    /// the model step too).
    Grad(Vec<f32>),
    /// K-FAC/AdaBK: layer statistics from the model step
    /// (`lx` = XᵀX/bs of order m, `ry` = δYᵀδY·bs of order n).
    Layer { lx: Vec<f32>, ry: Vec<f32> },
}

/// Capture the PU statistics payload for block `bi` (`bp`) — the ONE place
/// the stats-to-side mapping is written, shared by the synchronous engine,
/// the pipeline's submission path, and the shard coordinator's request
/// builder.
pub(crate) fn capture_stat(
    kfac_mode: bool,
    bi: usize,
    bp: &BlockPre,
    model: &ModelHandle,
    grads: &[Vec<f32>],
    stats: &[Vec<f32>],
) -> StatInput {
    if kfac_mode {
        // layer index = bi (one block per 2-D weight, in order)
        StatInput::Layer {
            lx: stats[2 * bi].clone(),     // XᵀX/bs  (m, m)
            ry: stats[2 * bi + 1].clone(), // δYᵀδY·bs (n, n)
        }
    } else {
        StatInput::Grad(extract_block(
            &grads[bp.block.param_idx],
            &model.shapes[bp.block.param_idx],
            &bp.block,
        ))
    }
}

/// Apply one block's PU (Algorithm 3 line 6) to its side pair — the ONE
/// implementation the synchronous engine, the pipelined background jobs,
/// and the shard workers all execute, so no path can numerically diverge.
pub(crate) fn refresh_pu(
    rt: &dyn Backend,
    left: &mut SideState,
    right: &mut SideState,
    stat: StatInput,
    beta: f32,
    kind: SecondOrderKind,
) -> Result<()> {
    let (m, n) = (left.order(), right.order());
    let (l_stat, r_stat) = match stat {
        StatInput::Layer { lx, ry } => {
            (HostTensor::f32(&[m, m], lx), HostTensor::f32(&[n, n], ry))
        }
        StatInput::Grad(g) => {
            let outs = rt.execute(&format!("gram_{m}x{n}"), &[HostTensor::f32(&[m, n], g)])?;
            (outs[0].clone(), outs[1].clone())
        }
    };
    run_pu(rt, left, l_stat, beta, kind)?;
    run_pu(rt, right, r_stat, beta, kind)
}

/// Drop guard carried by every background refresh job: if the job unwinds
/// before reporting, the guard raises the shared abort flag (so the other
/// per-block jobs stop early) and sends a block-identified error in its
/// place — the completion barrier then surfaces "block N panicked" instead
/// of a generic dropped-channel failure.
struct ReportOnPanic {
    tx: Option<mpsc::Sender<(usize, Result<RefreshedBlock>)>>,
    bi: usize,
    abort: Arc<AtomicBool>,
}

impl Drop for ReportOnPanic {
    fn drop(&mut self) {
        if let Some(tx) = self.tx.take() {
            // ordering: Relaxed — best-effort "stop starting work" hint; the
            // completion barrier, not this flag, decides the surfaced error
            self.abort.store(true, Ordering::Relaxed);
            let _ = tx.send((
                self.bi,
                Err(anyhow!("background refresh job for block {} panicked", self.bi)),
            ));
        }
    }
}

/// Bookkeeping for one asynchronous refresh: the result channel, how many
/// per-block jobs are still out, and the shared abort flag background jobs
/// check before starting expensive work.
struct InFlightRefresh {
    /// Trainer step at which the refresh was submitted (staleness clock).
    submit_step: usize,
    rx: mpsc::Receiver<(usize, Result<RefreshedBlock>)>,
    outstanding: usize,
    /// Results drained so far — the adaptive-lag path polls them in with
    /// `try_recv` each step, so the blocking barrier only waits for the
    /// stragglers.
    received: Vec<(usize, Result<RefreshedBlock>)>,
    abort: Arc<AtomicBool>,
}

/// Orchestrates every preconditioner block (Algorithm 3/5 from Rust).
pub struct SecondOrder {
    /// The run's second-order configuration.
    pub cfg: SecondOrderConfig,
    /// All partitioned blocks with their preconditioner pairs.
    pub blocks: Vec<BlockPre>,
    /// K-FAC/AdaBK mode: whole-layer preconditioners fed by activation /
    /// gradient statistics instead of GGᵀ (Algorithm 5).
    pub kfac_mode: bool,
    /// counts of host-fallback preconditions (observability)
    pub host_fallbacks: u64,
    /// the parallel block engine's worker pool
    scheduler: Scheduler,
    /// the pipelined engine's current in-flight refresh, if any
    inflight: Option<InFlightRefresh>,
    /// the sharded block engine (`shampoo.shards > 1`): every refresh —
    /// synchronous or pipelined — routes through its codec-byte rounds
    /// instead of the in-process paths above
    shards: Option<ShardSet>,
}

impl SecondOrder {
    /// Build the preconditioner blocks for `model` under `cfg`'s policy and
    /// stand up the parallel block engine (a persistent pool; with
    /// `cfg.pipeline` it keeps at least one background lane even at
    /// `parallelism = 1`). Each side's storage codec resolves through the
    /// per-buffer `policy` (`LeftSide`/`RightSide` roles, `eigen` covering
    /// both, the `quant.bits`/`.mapping` single knob as the fallback).
    ///
    /// With `cfg.shards > 1` this also spawns the sharded block engine: one
    /// worker per shard, each constructing its own backend from
    /// `(backend_name, artifact_dir)` and owning its round-robin slice of
    /// the block states; every refresh then travels as codec bytes.
    pub fn new(
        cfg: &SecondOrderConfig,
        policy: &CodecPolicy,
        model: &ModelHandle,
        buckets: &[usize],
        backend_name: &str,
        artifact_dir: &Path,
    ) -> Result<Self> {
        let fallback = CodecSpec::plain(cfg.quant.bits, cfg.quant.mapping);
        let side_codec = |role: BufferRole| {
            let spec = policy.resolve(role, fallback);
            if !matches!(spec.bits, 3 | 4 | 16 | 32) {
                return Err(anyhow!(
                    "second-order {} codec {} unsupported: sides need 3/4-bit (quantized \
                     kernels) or 16/32-bit (dense) storage",
                    role.name(),
                    spec.name()
                ));
            }
            if spec.stochastic {
                return Err(anyhow!(
                    "second-order {} codec {}: stochastic rounding applies to first-order \
                     moment buffers only",
                    role.name(),
                    spec.name()
                ));
            }
            Ok(spec.build(policy.buffer_seed(role)))
        };
        let left_codec = side_codec(BufferRole::LeftSide)?;
        let right_codec = side_codec(BufferRole::RightSide)?;
        let kfac_mode = matches!(cfg.kind, SecondOrderKind::KFac | SecondOrderKind::AdaBk);
        let blocks = if kfac_mode {
            if model.spec.kind != "mlp" {
                return Err(anyhow!(
                    "K-FAC/AdaBK requires the MLP model (activation statistics)"
                ));
            }
            // whole-layer preconditioners; MLP dims are bucket-exact
            let mut kfac_buckets = buckets.to_vec();
            for &d in &model.spec.dims {
                if !kfac_buckets.contains(&d) {
                    kfac_buckets.push(d);
                }
            }
            kfac_buckets.sort_unstable();
            let max = *kfac_buckets.last().unwrap();
            let weight_shapes: Vec<Vec<usize>> = model
                .shapes
                .iter()
                .map(|s| if s.len() == 2 { s.clone() } else { vec![] })
                .collect();
            partition(&weight_shapes, &kfac_buckets, max)
        } else {
            partition(&model.shapes, buckets, cfg.max_order)
        };
        let blocks: Vec<BlockPre> = blocks
            .into_iter()
            .map(|b| BlockPre {
                left: SideState::new(b.bm, cfg, &left_codec),
                right: SideState::new(b.bn, cfg, &right_codec),
                block: b,
                inv_cache: None,
            })
            .collect();
        let scheduler = if cfg.pipeline {
            Scheduler::pipelined(cfg.parallelism)
        } else {
            Scheduler::new(cfg.parallelism)
        };
        let shards = if cfg.shards > 1 && !blocks.is_empty() {
            Some(ShardSet::new(cfg, backend_name, artifact_dir, &blocks)?)
        } else {
            None
        };
        Ok(Self {
            cfg: cfg.clone(),
            blocks,
            kfac_mode,
            host_fallbacks: 0,
            scheduler,
            inflight: None,
            shards,
        })
    }

    /// Number of shard workers the refreshes fan across (1 = the
    /// in-process engines).
    pub fn shard_count(&self) -> usize {
        self.shards.as_ref().map_or(1, |s| s.num_shards())
    }

    /// Wire accounting of the sharded engine, if it is active: `(total
    /// wire bytes, state bytes as codec, state bytes as fp32, rounds)`.
    pub fn shard_wire_stats(&self) -> Option<(u64, u64, u64, u64)> {
        self.shards.as_ref().map(|s| s.wire_stats())
    }

    /// The engine handle — `Clone`s share the same persistent pool, so the
    /// trainer reuses these threads for the chunked first-order update.
    pub fn scheduler(&self) -> &Scheduler {
        &self.scheduler
    }

    /// Serialize every block's (left, right) state for checkpoints —
    /// raw codec bytes, so restore is bit-exact.
    pub fn serialize_state(&self) -> Vec<u8> {
        let mut out = Vec::new();
        for bp in &self.blocks {
            out.extend(bp.left.serialize());
            out.extend(bp.right.serialize());
        }
        out
    }

    /// Restore a blob written by [`SecondOrder::serialize_state`] into this
    /// (identically configured) instance. The whole blob is parsed and
    /// validated before any block is touched (atomic restore); cached
    /// precondition inputs are invalidated, and the next step rebuilds them
    /// from the restored state.
    pub fn restore_state(&mut self, bytes: &[u8]) -> Result<()> {
        let sides = self.parse_state(bytes)?;
        self.validate_sides(&sides)?;
        self.apply_sides(sides)
    }

    /// Parse a [`SecondOrder::serialize_state`] blob into per-block
    /// (left, right) side pairs. Pure: no engine state is touched. The
    /// blob must contain exactly `blocks.len()` pairs — trailing bytes are
    /// a descriptive error, not silently ignored.
    pub fn parse_state(&self, bytes: &[u8]) -> Result<Vec<(SideState, SideState)>> {
        let mut off = 0usize;
        let mut out = Vec::with_capacity(self.blocks.len());
        for _ in 0..self.blocks.len() {
            let (l, used) = SideState::deserialize(&bytes[off..])?;
            off += used;
            let (r, used) = SideState::deserialize(&bytes[off..])?;
            off += used;
            out.push((l, r));
        }
        if off != bytes.len() {
            return Err(anyhow!(
                "second-order checkpoint blob has {} trailing bytes",
                bytes.len() - off
            ));
        }
        Ok(out)
    }

    /// Check parsed side pairs against this engine's configuration: pair
    /// count, then per-block arm kind, matrix order, and storage codec.
    /// Pure — callers run this *before* [`SecondOrder::apply_sides`] so a
    /// mismatched checkpoint can never half-apply.
    pub fn validate_sides(&self, sides: &[(SideState, SideState)]) -> Result<()> {
        if sides.len() != self.blocks.len() {
            return Err(anyhow!(
                "checkpoint has {} second-order blocks, run expects {}",
                sides.len(),
                self.blocks.len()
            ));
        }
        for (bi, ((l, r), bp)) in sides.iter().zip(&self.blocks).enumerate() {
            for (s, side) in [(l, &bp.left), (r, &bp.right)] {
                if s.order() != side.order()
                    || s.arm_name() != side.arm_name()
                    || s.codec_name() != side.codec_name()
                {
                    return Err(anyhow!(
                        "checkpoint second-order block {bi} is {}@{} ({}), run expects \
                         {}@{} ({})",
                        s.arm_name(),
                        s.order(),
                        s.codec_name(),
                        side.arm_name(),
                        side.order(),
                        side.codec_name()
                    ));
                }
            }
        }
        Ok(())
    }

    /// Swap validated side pairs in ([`SecondOrder::validate_sides`] must
    /// have passed), invalidate cached precondition inputs, and re-sync the
    /// shard workers' copies: the pairs are in global block order
    /// (shard-agnostic), so a checkpoint saved at any shard count restores
    /// at any other. The only failure mode left here is shard re-sync IO.
    pub fn apply_sides(&mut self, sides: Vec<(SideState, SideState)>) -> Result<()> {
        debug_assert_eq!(sides.len(), self.blocks.len());
        for (bp, (l, r)) in self.blocks.iter_mut().zip(sides) {
            bp.left = l;
            bp.right = r;
            bp.inv_cache = None;
        }
        if let Some(sh) = self.shards.as_mut() {
            sh.sync_states(&self.blocks)?;
        }
        Ok(())
    }

    /// Worker count of the block engine (1 = serial).
    pub fn parallelism(&self) -> usize {
        self.scheduler.workers()
    }

    /// Exact bytes of all second-order state (Table 2/13 accounting).
    pub fn state_bytes(&self) -> usize {
        self.blocks
            .iter()
            .map(|b| b.left.state_bytes() + b.right.state_bytes())
            .sum()
    }

    /// PU for every block (Algorithm 3 line 6). For Shampoo/CASPR the
    /// statistics are GGᵀ/GᵀG of the current block gradient (via the gram
    /// artifact); for K-FAC/AdaBK they are the layer statistics from the
    /// model step (`stats[2i]` = XᵀX/bs, `stats[2i+1]` = δYᵀδY·bs).
    pub fn update_preconditioners(
        &mut self,
        rt: &dyn Backend,
        model: &ModelHandle,
        grads: &[Vec<f32>],
        stats: &[Vec<f32>],
    ) -> Result<()> {
        let beta = self.cfg.beta;
        let kind = self.cfg.kind;
        let kfac_mode = self.kfac_mode;
        if let Some(sh) = self.shards.as_mut() {
            // synchronous sharded round: submit + complete back to back.
            // `rt` is unused — each shard runs its own backend instance.
            sh.submit_round(Some((model, grads, stats)), kfac_mode, &self.blocks, &[], 0)?;
            return sh.complete_round(&mut self.blocks, None);
        }
        self.scheduler.par_map_mut(&mut self.blocks, |bi, bp| {
            let stat = capture_stat(kfac_mode, bi, bp, model, grads, stats);
            refresh_pu(rt, &mut bp.left, &mut bp.right, stat, beta, kind)
        })?;
        Ok(())
    }

    /// PIRU / inverse-root for every block (Algorithm 3 line 10).
    pub fn update_invroots(&mut self, rt: &dyn Backend) -> Result<()> {
        let all: Vec<usize> = (0..self.blocks.len()).collect();
        self.update_invroots_subset(rt, &all)
    }

    /// PIRU / inverse-root for a cohort of blocks (staggered scheduling runs
    /// one cohort per step; batch mode passes every index at the T2 boundary).
    pub fn update_invroots_subset(&mut self, rt: &dyn Backend, idxs: &[usize]) -> Result<()> {
        if idxs.is_empty() {
            return Ok(());
        }
        if let Some(sh) = self.shards.as_mut() {
            sh.submit_round(None, self.kfac_mode, &self.blocks, idxs, 0)?;
            return sh.complete_round(&mut self.blocks, None);
        }
        let eps = self.cfg.eps;
        let kind = self.cfg.kind;
        let mut selected = vec![false; self.blocks.len()];
        for &i in idxs {
            selected[i] = true;
        }
        let mut cohort: Vec<&mut BlockPre> = self
            .blocks
            .iter_mut()
            .enumerate()
            .filter(|(i, _)| selected[*i])
            .map(|(_, bp)| bp)
            .collect();
        self.scheduler.par_map_mut(&mut cohort, |_, bp| {
            run_invroot(rt, &mut bp.left, eps, kind)?;
            run_invroot(rt, &mut bp.right, eps, kind)?;
            bp.inv_cache = None; // invalidate cached precondition inputs
            Ok(())
        })?;
        Ok(())
    }

    /// Which blocks' inverse roots are due at (1-based) trainer step `step`.
    /// Batch mode: every block at T2 boundaries. Staggered mode: round-robin
    /// cohorts spread across the T2 interval (`scheduler::stagger_phase`), so
    /// each block still refreshes once per interval but no step pays for all
    /// of them at once.
    pub fn invroot_due(&self, step: usize) -> Vec<usize> {
        let t2 = self.cfg.update_invroot_every.max(1);
        let n = self.blocks.len();
        if !self.cfg.stagger_invroots {
            return if step % t2 == 0 { (0..n).collect() } else { Vec::new() };
        }
        let phase = step % t2;
        (0..n).filter(|&i| stagger_phase(i, n, t2) == phase).collect()
    }

    // ---- cross-step pipeline -------------------------------------------

    /// Whether the asynchronous PU/PIRU pipeline is active for this run.
    pub fn pipelined(&self) -> bool {
        self.cfg.pipeline && self.scheduler.pool_threads() > 0
    }

    /// Whether the in-flight refresh (if any) has hit the bounded-staleness
    /// limit at trainer step `step` and must be completed this step.
    pub fn inflight_lag_reached(&self, step: usize) -> bool {
        let submit_step = if let Some(sh) = self.shards.as_ref() {
            sh.submit_step()
        } else {
            self.inflight.as_ref().map(|fl| fl.submit_step)
        };
        submit_step.is_some_and(|s| step >= s + self.cfg.pipeline_max_lag)
    }

    /// Submit this refresh step's PU (`do_pu`, all blocks) and/or PIRU
    /// (`piru_due` cohort) work as one background job per block on the
    /// persistent pool, then return immediately — the trainer keeps
    /// stepping while the pool computes. Each job owns a cloned back copy
    /// of its block's side pair ([`RefreshedBlock`]); the front copies stay
    /// untouched and keep serving `precondition` until
    /// [`SecondOrder::complete_pipeline`] swaps the results in.
    ///
    /// The caller must have completed any previous refresh first (the
    /// barrier keeps at most one refresh in flight, which also serializes
    /// the PU EMA chain exactly like the synchronous engine).
    ///
    /// `pub(crate)`: this function erases `rt`'s lifetime for the detached
    /// jobs, so it is only sound under the trainer's drain-before-return
    /// discipline ([`Trainer::train`](crate::coordinator::Trainer::train)
    /// aborts + drains on every exit path, and [`SecondOrder`]'s `Drop`
    /// backstops the rest). Exposing it publicly would let safe code
    /// outlive the borrow.
    pub(crate) fn submit_refresh(
        &mut self,
        rt: &dyn Backend,
        model: &ModelHandle,
        grads: &[Vec<f32>],
        stats: &[Vec<f32>],
        do_pu: bool,
        piru_due: &[usize],
        step: usize,
    ) -> Result<()> {
        assert!(
            self.inflight.is_none(),
            "submit_refresh while a refresh is still in flight (missing barrier)"
        );
        if let Some(sh) = self.shards.as_mut() {
            // sharded pipelining: the round runs on the shard workers' own
            // backends, so no lifetime erasure of `rt` is needed — the
            // request ships and the trainer keeps stepping until the same
            // deterministic barrier calls `complete_pipeline`
            return sh.submit_round(
                do_pu.then_some((model, grads, stats)),
                self.kfac_mode,
                &self.blocks,
                piru_due,
                step,
            );
        }
        let involved: Vec<usize> = if do_pu {
            (0..self.blocks.len()).collect()
        } else {
            piru_due.to_vec()
        };
        if involved.is_empty() {
            return Ok(());
        }
        let mut piru = vec![false; self.blocks.len()];
        for &i in piru_due {
            piru[i] = true;
        }
        // SAFETY: background jobs borrow the backend for the duration of the
        // refresh only. The trainer guarantees every job has completed (or
        // been drained via `abort_inflight`) before `train` returns — i.e.
        // strictly within the lifetime of `rt` — so the erased reference
        // never outlives its pointee.
        let rt_static: &'static dyn Backend =
            unsafe { std::mem::transmute::<&dyn Backend, &'static dyn Backend>(rt) };
        let abort = Arc::new(AtomicBool::new(false));
        let (tx, rx) = mpsc::channel();
        let (beta, eps, kind) = (self.cfg.beta, self.cfg.eps, self.cfg.kind);
        let kfac_mode = self.kfac_mode;
        let mut submitted = 0usize;
        for &bi in &involved {
            let bp = &self.blocks[bi];
            let stat = do_pu.then(|| capture_stat(kfac_mode, bi, bp, model, grads, stats));
            let mut left = bp.left.clone();
            let mut right = bp.right.clone();
            let do_piru = piru[bi];
            let job_tx = tx.clone();
            let job_abort = Arc::clone(&abort);
            let queued = self.scheduler.spawn(Box::new(move || {
                // (body runs on a pool thread)
                let rt = rt_static;
                let mut report = ReportOnPanic {
                    tx: Some(job_tx),
                    bi,
                    abort: Arc::clone(&job_abort),
                };
                let work = (|| -> Result<RefreshedBlock> {
                    // ordering: Relaxed — early-exit hint only; a stale read
                    // just means this job does work the barrier discards
                    if job_abort.load(Ordering::Relaxed) {
                        return Err(anyhow!("refresh aborted before block {bi} started"));
                    }
                    let mut pu_secs = 0.0;
                    let mut piru_secs = 0.0;
                    if let Some(stat) = stat {
                        let t = Stopwatch::start();
                        refresh_pu(rt, &mut left, &mut right, stat, beta, kind)?;
                        pu_secs = t.secs();
                    }
                    if do_piru {
                        let t = Stopwatch::start();
                        run_invroot(rt, &mut left, eps, kind)?;
                        run_invroot(rt, &mut right, eps, kind)?;
                        piru_secs = t.secs();
                    }
                    Ok(RefreshedBlock {
                        block_idx: bi,
                        left,
                        right,
                        refreshed_invroot: do_piru,
                        pu_secs,
                        piru_secs,
                    })
                })();
                // normal completion: defuse the panic guard and report.
                // (the receiver may already be gone on the abort path)
                if let Some(tx) = report.tx.take() {
                    let _ = tx.send((bi, work));
                }
            }));
            if !queued {
                // unreachable in practice: `pipelined()` gates submission on
                // pool_threads > 0 and the pool never shrinks. Still, drain
                // the jobs already queued before erroring out, so none can
                // outlive the borrowed backend.
                self.inflight = Some(InFlightRefresh {
                    submit_step: step,
                    rx,
                    outstanding: submitted,
                    received: Vec::new(),
                    abort,
                });
                self.abort_inflight();
                return Err(ScheduleError::NoPoolThreads.into());
            }
            submitted += 1;
        }
        drop(tx); // jobs hold the only remaining senders
        self.inflight = Some(InFlightRefresh {
            submit_step: step,
            rx,
            outstanding: submitted,
            received: Vec::new(),
            abort,
        });
        Ok(())
    }

    /// Completion barrier: block until every job of the in-flight refresh
    /// (if any) has reported, then swap the refreshed back copies over the
    /// front copies in block-index order. Main-thread wait time lands in
    /// `timings.pipeline_stall_secs`; the jobs' own PU/PIRU seconds land in
    /// `timings.pu_secs` / `timings.piru_secs`.
    ///
    /// On a job failure the lowest-index error is returned and *no* result
    /// is swapped in; the abort flag stops still-queued jobs early and the
    /// barrier still drains every outstanding job before returning, so no
    /// background work outlives the error.
    pub fn complete_pipeline(&mut self, timings: &mut StepTimings) -> Result<()> {
        if let Some(sh) = self.shards.as_mut() {
            return sh.complete_round(&mut self.blocks, Some(timings));
        }
        let Some(mut fl) = self.inflight.take() else {
            return Ok(());
        };
        let t = Stopwatch::start();
        // block only for the stragglers — results the adaptive poll already
        // drained into `received` cost no wait here
        while fl.received.len() < fl.outstanding {
            match fl.rx.recv() {
                Ok(msg) => {
                    if msg.1.is_err() {
                        // ordering: Relaxed — stop-starting-work hint; the
                        // error merge below decides what surfaces
                        fl.abort.store(true, Ordering::Relaxed);
                    }
                    fl.received.push(msg);
                }
                // a sender dropped without reporting — should be impossible
                // (panicking jobs report through their ReportOnPanic guard);
                // kept as a backstop so the barrier can never hang blame-less
                Err(_) => {
                    timings.pipeline_stall_secs += t.secs();
                    return Err(anyhow!(
                        "pipeline: a background refresh job died before reporting"
                    ));
                }
            }
        }
        timings.pipeline_stall_secs += t.secs();
        let mut updates: Vec<RefreshedBlock> = Vec::with_capacity(fl.outstanding);
        let mut first_err: Option<(usize, anyhow::Error)> = None;
        for (bi, res) in fl.received {
            match res {
                Ok(rb) => updates.push(rb),
                Err(e) => {
                    if first_err.as_ref().is_none_or(|(b, _)| bi < *b) {
                        first_err = Some((bi, e));
                    }
                }
            }
        }
        if let Some((bi, e)) = first_err {
            return Err(e.context(format!("pipelined refresh of block {bi}")));
        }
        updates.sort_by_key(|rb| rb.block_idx);
        for rb in updates {
            timings.pu_secs += rb.pu_secs;
            timings.piru_secs += rb.piru_secs;
            let bp = &mut self.blocks[rb.block_idx];
            bp.left = rb.left;
            bp.right = rb.right;
            if rb.refreshed_invroot {
                bp.inv_cache = None; // the front root was just replaced
            }
        }
        Ok(())
    }

    /// Adaptive-lag barrier (`shampoo.pipeline_adaptive`): a *non-blocking*
    /// [`SecondOrder::complete_pipeline`]. Polls the in-flight refresh's
    /// channel; if every background job has already reported — the pool went
    /// idle — the results swap in now (returning `true`) instead of waiting
    /// out the full `pipeline_max_lag` bound. If anything is still running,
    /// nothing changes and no time is spent waiting.
    ///
    /// The early swap step depends on pool timing, so adaptive runs trade
    /// the pipeline's bit-reproducibility for fresher roots (quality stays
    /// in the same staleness-tolerance regime — the roots are never *older*
    /// than the deterministic schedule's).
    pub fn try_complete_pipeline(&mut self, timings: &mut StepTimings) -> Result<bool> {
        if let Some(sh) = self.shards.as_mut() {
            if !sh.round_in_flight() || !sh.try_drain() {
                return Ok(false);
            }
            sh.complete_round(&mut self.blocks, Some(timings))?;
            return Ok(true);
        }
        let all_reported = match self.inflight.as_mut() {
            None => return Ok(false),
            Some(fl) => {
                while let Ok(msg) = fl.rx.try_recv() {
                    if msg.1.is_err() {
                        // stop still-queued jobs early; the completion below
                        // (or the next blocking barrier) surfaces the error
                        // ordering: Relaxed — same hint-only contract as the
                        // blocking barrier's store
                        fl.abort.store(true, Ordering::Relaxed);
                    }
                    fl.received.push(msg);
                }
                fl.received.len() >= fl.outstanding
            }
        };
        if !all_reported {
            return Ok(false);
        }
        self.complete_pipeline(timings)?;
        Ok(true)
    }

    /// Error-path shutdown: raise the abort flag, wait for every in-flight
    /// job to exit, and discard their results. Called by the trainer when a
    /// step fails (or panics) so no background job outlives the borrowed
    /// backend; a no-op when nothing is in flight.
    pub fn abort_inflight(&mut self) {
        if let Some(sh) = self.shards.as_mut() {
            sh.abort_round();
        }
        if let Some(fl) = self.inflight.take() {
            // ordering: Relaxed — hint to skip work; the recv loop below is
            // the real synchronization (drains every live job)
            fl.abort.store(true, Ordering::Relaxed);
            let mut outstanding = fl.outstanding - fl.received.len();
            while outstanding > 0 {
                if fl.rx.recv().is_err() {
                    break; // every sender gone: nothing left running
                }
                outstanding -= 1;
            }
        }
    }

    /// Precondition all gradients in place (Algorithm 3 lines 13–14).
    ///
    /// Two phases: the per-block transforms run as parallel tasks over a
    /// read-only view of the gradients (the cached artifact inputs are
    /// `Arc`-backed, so re-submitting them each step shares the state buffers
    /// instead of deep-copying them), then the disjoint results are scattered
    /// back serially in block-index order.
    pub fn precondition(
        &mut self,
        rt: &dyn Backend,
        model: &ModelHandle,
        grads: &mut [Vec<f32>],
    ) -> Result<()> {
        let caspr = self.cfg.kind == SecondOrderKind::Caspr;
        let grads_ro: &[Vec<f32>] = grads;
        let results = self.scheduler.par_map_mut(&mut self.blocks, |_, bp| {
            let (m, n) = (bp.block.bm, bp.block.bn);
            let shape = &model.shapes[bp.block.param_idx];
            let g = extract_block(&grads_ro[bp.block.param_idx], shape, &bp.block);

            let artifact = match (bp.left.is_dense(), bp.right.is_dense()) {
                (true, true) => {
                    let name = if caspr {
                        format!("caspr32_{m}x{n}")
                    } else {
                        format!("precond32_{m}x{n}")
                    };
                    rt.has_artifact(&name).then_some(name)
                }
                (true, false) | (false, true) => None,
                (false, false) => {
                    let name = if caspr {
                        format!("caspr4_{m}x{n}")
                    } else {
                        format!("precond4_{m}x{n}")
                    };
                    rt.has_artifact(&name).then_some(name)
                }
            };

            match artifact {
                Some(name) => {
                    if bp.inv_cache.is_none() {
                        let mut state = bp.left.invroot_inputs()?;
                        state.extend(bp.right.invroot_inputs()?);
                        if let Some(rcb) = bp.left.runtime_codebook() {
                            state.push(HostTensor::f32(&[16], rcb.to_vec()));
                        }
                        bp.inv_cache = Some(state);
                    }
                    let mut inputs = vec![HostTensor::f32(&[m, n], g)];
                    inputs.extend(bp.inv_cache.as_ref().unwrap().iter().cloned());
                    let mut outs = rt.execute(&name, &inputs)?;
                    Ok((outs.remove(0).into_f32()?, false))
                }
                None => {
                    // host mirror: mixed arms or no matching artifact pair
                    let gt = precondition_host(
                        &g,
                        m,
                        n,
                        &bp.left.invroot_host(0),
                        &bp.right.invroot_host(0),
                        caspr,
                    );
                    Ok((gt, true))
                }
            }
        })?;
        for (bp, (gt, fellback)) in self.blocks.iter().zip(results) {
            if fellback {
                self.host_fallbacks += 1;
            }
            let shape = &model.shapes[bp.block.param_idx];
            scatter_block(&mut grads[bp.block.param_idx], shape, &bp.block, &gt);
        }
        Ok(())
    }
}

impl Drop for SecondOrder {
    /// Backstop for the pipeline's safety contract: if a `SecondOrder` is
    /// ever dropped with a refresh still in flight, wait the jobs out (they
    /// check the abort flag, so this is short) before the backend they
    /// borrow can go away. Normal runs never hit this — `Trainer::train`
    /// drains on every exit path.
    fn drop(&mut self) {
        self.abort_inflight();
    }
}

/// Host mirror of precond32/caspr32 + grafting — delegates to the single
/// implementation in `runtime::host::ops` so the artifact path and this
/// mixed-arm fallback can never numerically diverge.
pub fn precondition_host(
    g: &[f32],
    m: usize,
    n: usize,
    lhat: &Mat,
    rhat: &Mat,
    caspr: bool,
) -> Vec<f32> {
    let gm = Mat::from_vec(m, n, g.to_vec());
    let mut outs = crate::runtime::host::ops::precond_dense(&gm, lhat, rhat, caspr);
    outs.remove(0).into_f32().expect("precond_dense emits one f32 tensor")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn host_precondition_identity() {
        let g: Vec<f32> = (0..12).map(|i| i as f32).collect();
        let out = precondition_host(&g, 3, 4, &Mat::eye(3), &Mat::eye(4), false);
        for (a, b) in out.iter().zip(&g) {
            assert!((a - b).abs() < 1e-5);
        }
        // CASPR with identity states: J = 2G, Ĝ = 4G, grafted back to ‖G‖
        let out = precondition_host(&g, 3, 4, &Mat::eye(3), &Mat::eye(4), true);
        for (a, b) in out.iter().zip(&g) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn host_precondition_grafts_norm() {
        let g = vec![1.0f32; 16];
        let out = precondition_host(&g, 4, 4, &Mat::eye(4).scale(10.0), &Mat::eye(4), false);
        let norm: f32 = out.iter().map(|x| x * x).sum::<f32>().sqrt();
        assert!((norm - 4.0).abs() < 1e-3); // ‖G‖_F preserved
    }
}
