//! L3 coordinator: the training framework around the paper's optimizer.
//!
//! * `checkpoint`   — the streaming per-buffer-framed checkpoint format
//!                    (manifest + checksums, atomic commit, delta chains)
//!                    and the concurrent read-only `StateServer`
//! * `partition`    — Shampoo blocking of parameters into bucket orders
//! * `state`        — quantized / dense / naive preconditioner block states
//! * `second_order` — Algorithm 3 orchestration over the AOT artifacts,
//!                    fanned across the parallel block engine
//! * `scheduler`    — the parallel block engine: persistent worker pool,
//!                    cross-step pipelining, staggered inverse-root
//!                    cohorts, per-stage timings
//! * `shard`        — the sharded block engine: blocks partitioned
//!                    round-robin across N backend shards, codec bytes as
//!                    the wire format
//! * `model`        — parameter buffers + model step/eval marshaling
//! * `trainer`      — the training loop, eval, metrics, checkpoints
//! * `shadow`       — 32-bit shadow for dynamic quant-error (Figs 7/8)
//! * `memory`       — analytic planner (Table 13) sharing the live
//!                    byte-accounting model

/// The streaming checkpoint format (framed buffers + manifest, atomic
/// commit, delta chains) and the read-only concurrent `StateServer`.
pub mod checkpoint;
/// Analytic memory planner (Table 13).
pub mod memory;
/// Parameter buffers + model step/eval marshaling.
pub mod model;
/// Shampoo blocking of parameters into bucket orders.
pub mod partition;
/// The parallel block engine: persistent pool, pipeline, timings.
pub mod scheduler;
/// Algorithm-3 orchestration over the artifacts.
pub mod second_order;
/// The sharded block engine: blocks partitioned across N backend shards,
/// codec bytes as the wire format.
pub mod shard;
/// 32-bit shadow preconditioner for dynamic quant-error (Figs 7/8).
pub mod shadow;
/// Per-block preconditioner states + the pipeline's double buffer.
pub mod state;
/// The training loop, eval, metrics, checkpoints.
pub mod trainer;

pub use checkpoint::{CheckpointError, CheckpointFile, StateServer};
pub use model::ModelHandle;
pub use scheduler::{ScheduleError, Scheduler, StepTimings};
pub use second_order::SecondOrder;
pub use shard::ShardSet;
pub use trainer::{EvalPoint, MemoryReport, TrainResult, Trainer};
