//! L3 coordinator: the training framework around the paper's optimizer.
//!
//! * `partition`    — Shampoo blocking of parameters into bucket orders
//! * `state`        — quantized / dense / naive preconditioner block states
//! * `second_order` — Algorithm 3 orchestration over the AOT artifacts,
//!                    fanned across the parallel block engine
//! * `scheduler`    — the parallel block engine: scoped-thread worker pool,
//!                    staggered inverse-root cohorts, per-stage timings
//! * `model`        — parameter buffers + model step/eval marshaling
//! * `trainer`      — the training loop, eval, metrics, checkpoints
//! * `shadow`       — 32-bit shadow for dynamic quant-error (Figs 7/8)
//! * `memory`       — analytic planner (Table 13) sharing the live
//!                    byte-accounting model

pub mod memory;
pub mod model;
pub mod partition;
pub mod scheduler;
pub mod second_order;
pub mod shadow;
pub mod state;
pub mod trainer;

pub use model::ModelHandle;
pub use scheduler::{Scheduler, StepTimings};
pub use second_order::SecondOrder;
pub use trainer::{EvalPoint, MemoryReport, TrainResult, Trainer};
