//! Per-block preconditioner state: the quantized (ours), dense (32-bit
//! baseline), and naive (quantize-A) arms of the paper, with exact byte
//! accounting and the host-side mirror used when no artifact pair matches.

use anyhow::{anyhow, Result};

use crate::config::{QuantConfig, SecondOrderConfig, SecondOrderKind};
use crate::linalg::{bjorck, Mat};
use crate::quant::{
    dequantize_matrix_cols, quantize_matrix_cols, runtime_codebook, QuantizedVec,
};
use crate::runtime::{Backend, HostTensor};

/// One side (L or R) of a block's preconditioner pair.
#[derive(Debug, Clone)]
pub enum SideState {
    /// Ours: eigenvalues + quantized eigenbasis; inverse root as 32-bit
    /// diagonal + quantized off-diagonal (Algorithms 1–3).
    Quantized {
        lam: Vec<f32>,
        codes: QuantizedVec,
        inv_diag: Vec<f32>,
        inv_codes: QuantizedVec,
    },
    /// 32-bit baseline (Algorithm 4): dense L and L̂.
    Dense { l: Mat, lhat: Mat },
    /// Naive arm (§3.1): A quantized directly (diag in 32-bit), inverse
    /// root also quantized; Schur–Newton recomputes it.
    Naive {
        diag: Vec<f32>,
        codes: QuantizedVec,
        inv_diag: Vec<f32>,
        inv_codes: QuantizedVec,
    },
}

impl SideState {
    pub fn new(n: usize, cfg: &SecondOrderConfig, cb: &[f32]) -> SideState {
        let q = &cfg.quant;
        let quantizable = q.bits < 32 && n * n >= q.min_quant_elems;
        if !quantizable {
            return SideState::Dense {
                l: Mat::eye(n).scale(cfg.eps),
                lhat: Mat::eye(n),
            };
        }
        if q.quantize_eigen {
            let eye = Mat::eye(n);
            let codes = quantize_matrix_cols(&eye.data, n, cb, q.bits);
            let zeros = vec![0.0f32; n * n];
            let inv_codes = quantize_matrix_cols(&zeros, n, cb, q.bits);
            SideState::Quantized {
                lam: vec![cfg.eps; n],
                codes,
                inv_diag: vec![1.0; n],
                inv_codes,
            }
        } else {
            // naive: A₀ = ε·I stored as (diag, quantized zeros)
            let zeros = vec![0.0f32; n * n];
            let codes = quantize_matrix_cols(&zeros, n, cb, q.bits);
            let inv_codes = quantize_matrix_cols(&zeros, n, cb, q.bits);
            SideState::Naive {
                diag: vec![cfg.eps; n],
                codes,
                inv_diag: vec![1.0; n],
                inv_codes,
            }
        }
    }

    pub fn order(&self) -> usize {
        match self {
            SideState::Quantized { lam, .. } => lam.len(),
            SideState::Dense { l, .. } => l.rows,
            SideState::Naive { diag, .. } => diag.len(),
        }
    }

    /// Exact state bytes (preconditioner + inverse root).
    pub fn state_bytes(&self) -> usize {
        match self {
            SideState::Quantized { lam, codes, inv_diag, inv_codes } => {
                lam.len() * 4
                    + codes.state_bytes()
                    + inv_diag.len() * 4
                    + inv_codes.state_bytes()
            }
            SideState::Dense { l, lhat } => (l.data.len() + lhat.data.len()) * 4,
            SideState::Naive { diag, codes, inv_diag, inv_codes } => {
                diag.len() * 4
                    + codes.state_bytes()
                    + inv_diag.len() * 4
                    + inv_codes.state_bytes()
            }
        }
    }

    /// Host-side reconstruction of Â (the inverse root) — used by the
    /// fallback preconditioner and the shadow/error analyses.
    pub fn invroot_host(&self, cb: &[f32], rectify: usize) -> Mat {
        match self {
            SideState::Dense { lhat, .. } => lhat.clone(),
            SideState::Quantized { inv_diag, inv_codes, .. }
            | SideState::Naive { inv_diag, inv_codes, .. } => {
                let n = inv_diag.len();
                let off = dequantize_matrix_cols(inv_codes, n, cb);
                let mut m = Mat::from_vec(n, n, off);
                for i in 0..n {
                    m[(i, i)] = inv_diag[i];
                }
                let _ = rectify; // Â is not an orthogonal matrix; no OR here
                m
            }
        }
    }

    /// Host-side reconstruction of the preconditioner A itself
    /// (shadow-mode NRE/AE, Figures 7/8).
    pub fn precond_host(&self, cb: &[f32], rectify: usize) -> Mat {
        match self {
            SideState::Dense { l, .. } => l.clone(),
            SideState::Quantized { lam, codes, .. } => {
                let n = lam.len();
                let v0 = dequantize_matrix_cols(codes, n, cb);
                let mut v = Mat::from_vec(n, n, v0);
                if rectify > 0 {
                    v = bjorck(&v, rectify);
                }
                Mat::sandwich(&v, lam)
            }
            SideState::Naive { diag, codes, .. } => {
                let n = diag.len();
                let off = dequantize_matrix_cols(codes, n, cb);
                let mut m = Mat::from_vec(n, n, off);
                m.symmetrize();
                for i in 0..n {
                    m[(i, i)] = diag[i];
                }
                m
            }
        }
    }

    // ---- artifact marshaling -------------------------------------------

    /// Inputs encoding this side's *preconditioner* state for pu artifacts.
    pub fn pu_inputs(&self) -> Result<Vec<HostTensor>> {
        match self {
            SideState::Quantized { lam, codes, .. } => Ok(quant_state_tensors(lam, codes)),
            SideState::Naive { diag, codes, .. } => Ok(quant_state_tensors(diag, codes)),
            SideState::Dense { l, .. } => Ok(vec![HostTensor::f32(
                &[l.rows, l.cols],
                l.data.clone(),
            )]),
        }
    }

    /// Inputs encoding this side's *inverse root* for precond artifacts.
    pub fn invroot_inputs(&self) -> Result<Vec<HostTensor>> {
        match self {
            SideState::Quantized { inv_diag, inv_codes, .. }
            | SideState::Naive { inv_diag, inv_codes, .. } => {
                Ok(quant_state_tensors(inv_diag, inv_codes))
            }
            SideState::Dense { lhat, .. } => Ok(vec![HostTensor::f32(
                &[lhat.rows, lhat.cols],
                lhat.data.clone(),
            )]),
        }
    }

    /// Update the preconditioner state from pu artifact outputs.
    pub fn absorb_pu(&mut self, outs: &[HostTensor], bits: u32) -> Result<()> {
        match self {
            SideState::Quantized { lam, codes, .. } => {
                *lam = outs[0].clone().into_f32()?;
                *codes = quantized_from_tensors(&outs[1], &outs[2], bits)?;
            }
            SideState::Naive { diag, codes, .. } => {
                *diag = outs[0].clone().into_f32()?;
                *codes = quantized_from_tensors(&outs[1], &outs[2], bits)?;
            }
            SideState::Dense { l, .. } => {
                let n = l.rows;
                l.data = outs[0].clone().into_f32()?;
                assert_eq!(l.data.len(), n * n);
            }
        }
        Ok(())
    }

    /// Update the inverse-root state from piru / invroot artifact outputs.
    pub fn absorb_invroot(&mut self, outs: &[HostTensor], bits: u32) -> Result<()> {
        match self {
            SideState::Quantized { inv_diag, inv_codes, .. }
            | SideState::Naive { inv_diag, inv_codes, .. } => {
                *inv_diag = outs[0].clone().into_f32()?;
                *inv_codes = quantized_from_tensors(&outs[1], &outs[2], bits)?;
            }
            SideState::Dense { lhat, .. } => {
                let n = lhat.rows;
                lhat.data = outs[0].clone().into_f32()?;
                assert_eq!(lhat.data.len(), n * n);
            }
        }
        Ok(())
    }

    pub fn is_dense(&self) -> bool {
        matches!(self, SideState::Dense { .. })
    }
}

fn quant_state_tensors(diag: &[f32], q: &QuantizedVec) -> Vec<HostTensor> {
    let nb = q.scales.len();
    let blk = q.block;
    vec![
        HostTensor::f32(&[diag.len()], diag.to_vec()),
        HostTensor::u8(&[nb, blk], q.codes_u8()),
        HostTensor::f32(&[nb], q.scales.clone()),
    ]
}

fn quantized_from_tensors(
    codes: &HostTensor,
    scales: &HostTensor,
    bits: u32,
) -> Result<QuantizedVec> {
    let blk = *codes
        .shape
        .last()
        .ok_or_else(|| anyhow!("codes tensor must be 2-D"))?;
    let raw = codes.as_u8()?;
    Ok(QuantizedVec {
        packed: crate::quant::pack_bits(raw, bits),
        scales: scales.as_f32()?.to_vec(),
        len: raw.len(),
        bits,
        block: blk,
    })
}

/// Which artifact family a side uses at a given order.
pub fn artifact_arm(side: &SideState) -> &'static str {
    match side {
        SideState::Quantized { .. } => "quant",
        SideState::Dense { .. } => "dense",
        SideState::Naive { .. } => "naive",
    }
}

/// Build the runtime codebook for a quant config.
pub fn codebook_for(q: &QuantConfig) -> Vec<f32> {
    if q.bits >= 32 {
        // unused; return a dummy 16-entry book
        return vec![0.0; 16];
    }
    runtime_codebook(q.mapping, q.bits)
}

/// The exponent tag piru/invroot artifacts use for a second-order kind.
pub fn exponent_tag(kind: SecondOrderKind) -> &'static str {
    match kind.alpha() {
        1 => "_e1",
        2 => "_e2",
        _ => "",
    }
}

/// Execute the appropriate PU artifact for one side.
pub fn run_pu(
    rt: &dyn Backend,
    side: &mut SideState,
    m_stat: HostTensor,
    beta: f32,
    cb: &[f32],
    kind: SecondOrderKind,
    bits: u32,
) -> Result<()> {
    let n = side.order();
    let kfac_like = matches!(kind, SecondOrderKind::KFac | SecondOrderKind::AdaBk);
    let mut inputs = side.pu_inputs()?;
    inputs.push(m_stat);
    inputs.push(HostTensor::scalar_f32(beta));
    let name = match side {
        SideState::Quantized { .. } => {
            inputs.push(HostTensor::f32(&[16], cb.to_vec()));
            if kfac_like && n == 128 {
                "pu_kfac_128".to_string()
            } else {
                format!("pu_{n}")
            }
        }
        SideState::Naive { .. } => {
            inputs.push(HostTensor::f32(&[16], cb.to_vec()));
            format!("pu_naive_{n}")
        }
        SideState::Dense { .. } => format!("pu_dense_{n}"),
    };
    let outs = rt.execute(&name, &inputs)?;
    side.absorb_pu(&outs, bits)
}

/// Execute the appropriate PIRU / inverse-root artifact for one side.
pub fn run_invroot(
    rt: &dyn Backend,
    side: &mut SideState,
    eps: f32,
    cb: &[f32],
    kind: SecondOrderKind,
    bits: u32,
) -> Result<()> {
    let n = side.order();
    let tag = exponent_tag(kind);
    let mut inputs = match side {
        SideState::Dense { .. } => side.pu_inputs()?, // dense: (l,)
        _ => side.pu_inputs()?,                       // quant/naive: (diag, codes, scales)
    };
    inputs.push(HostTensor::scalar_f32(eps));
    let name = match side {
        SideState::Quantized { .. } => {
            inputs.push(HostTensor::f32(&[16], cb.to_vec()));
            format!("piru{tag}_{n}")
        }
        SideState::Naive { .. } => {
            inputs.push(HostTensor::f32(&[16], cb.to_vec()));
            // naive inverse root is Schur–Newton at s = -1/4 only (the
            // naive arm is a Shampoo ablation; K-FAC naive is not a paper
            // configuration)
            format!("invroot_naive_{n}")
        }
        SideState::Dense { .. } => format!("invroot_dense{tag}_{n}"),
    };
    let outs = rt.execute(&name, &inputs)?;
    side.absorb_invroot(&outs, bits)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SecondOrderConfig;
    use crate::quant::Mapping;

    fn cfg(bits: u32, eigen: bool) -> SecondOrderConfig {
        let mut c = SecondOrderConfig::default();
        c.quant.bits = bits;
        c.quant.quantize_eigen = eigen;
        c.quant.mapping = Mapping::Linear2;
        c
    }

    #[test]
    fn small_matrices_stay_dense() {
        let c = cfg(4, true);
        let cb = codebook_for(&c.quant);
        let s = SideState::new(32, &c, &cb); // 32² = 1024 < 4096
        assert!(s.is_dense());
        let s = SideState::new(64, &c, &cb); // 64² = 4096: quantized
        assert!(!s.is_dense());
    }

    #[test]
    fn init_states_reconstruct_identity_scaled() {
        let c = cfg(4, true);
        let cb = codebook_for(&c.quant);
        let s = SideState::new(64, &c, &cb);
        // A₀ ≈ ε·I ; Â₀ = I
        let a = s.precond_host(&cb, 0);
        let eye_eps = Mat::eye(64).scale(c.eps);
        assert!(a.sub(&eye_eps).frobenius() < 1e-4);
        let ah = s.invroot_host(&cb, 0);
        assert!(ah.sub(&Mat::eye(64)).frobenius() < 1e-6);
    }

    #[test]
    fn naive_init_reconstructs_identity_scaled() {
        let c = cfg(4, false);
        let cb = codebook_for(&c.quant);
        let s = SideState::new(64, &c, &cb);
        assert!(matches!(s, SideState::Naive { .. }));
        let a = s.precond_host(&cb, 0);
        assert!(a.sub(&Mat::eye(64).scale(c.eps)).frobenius() < 1e-4);
    }

    #[test]
    fn state_bytes_scale_with_bits() {
        let cb4 = codebook_for(&cfg(4, true).quant);
        let s4 = SideState::new(128, &cfg(4, true), &cb4);
        let s32 = SideState::new(128, &cfg(32, true), &cb4);
        // 4-bit: 2 quantized matrices + 2 f32 vectors ≈ (2·(8192+1024) + 1024)
        // 32-bit: 2 dense matrices = 2·65536 B
        let b4 = s4.state_bytes();
        let b32 = s32.state_bytes();
        assert!(b32 as f64 / b4 as f64 > 6.0, "{b32} / {b4}");
    }

    #[test]
    fn pu_inputs_shapes() {
        let c = cfg(4, true);
        let cb = codebook_for(&c.quant);
        let s = SideState::new(64, &c, &cb);
        let ins = s.pu_inputs().unwrap();
        assert_eq!(ins.len(), 3);
        assert_eq!(ins[0].shape, vec![64]);
        assert_eq!(ins[1].shape, vec![64, 64]); // 4096/64 blocks × 64
        assert_eq!(ins[2].shape, vec![64]);
    }
}
