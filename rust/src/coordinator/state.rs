//! Per-block preconditioner state: the quantized (ours), dense (32-bit /
//! bf16 baseline), and naive (quantize-A) arms of the paper, with exact byte
//! accounting and the host-side mirror used when no artifact pair matches.
//!
//! A [`SideState`] is a thin wrapper over `StateCodec`-encoded buffers: the
//! codec owns the codebook, block layout, byte accounting, and checkpoint
//! serialization, so no codebook plumbing leaks into the orchestration
//! layer and saved second-order state round-trips bit-exactly.

use std::sync::Arc;

use anyhow::{anyhow, bail, Result};

use crate::config::{SecondOrderConfig, SecondOrderKind};
use crate::linalg::{bjorck, Mat};
use crate::quant::{codec_by_name, fp32, EncodedVec, StateCodec};
use crate::runtime::{Backend, HostTensor};

/// One side (L or R) of a block's preconditioner pair.
///
/// `Clone` exists for the pipelined engine's double-buffer protocol: an
/// asynchronous refresh snapshots the *front* copy (the one that keeps
/// serving `precondition`), updates the clone on a pool thread, and hands
/// it back as a [`RefreshedBlock`] to be swapped in at the completion
/// barrier.
#[derive(Clone)]
pub struct SideState {
    codec: Arc<dyn StateCodec>,
    arm: SideArm,
}

#[derive(Clone)]
enum SideArm {
    /// Ours: eigenvalues + codec-encoded eigenbasis; inverse root as 32-bit
    /// diagonal + codec-encoded off-diagonal (Algorithms 1–3).
    Quantized {
        lam: Vec<f32>,
        codes: EncodedVec,
        inv_diag: Vec<f32>,
        inv_codes: EncodedVec,
    },
    /// Dense baseline (Algorithm 4): full L and L̂ stored through the codec
    /// (`Fp32` for the 32-bit arm, `Bf16` for the 16-bit arm).
    Dense { n: usize, l: EncodedVec, lhat: EncodedVec },
    /// Naive arm (§3.1): A quantized directly (diag in 32-bit), inverse
    /// root also quantized; Schur–Newton recomputes it.
    Naive {
        diag: Vec<f32>,
        codes: EncodedVec,
        inv_diag: Vec<f32>,
        inv_codes: EncodedVec,
    },
}

impl SideState {
    /// Build the initial state for an order-n side under `cfg`'s policy,
    /// storing through `codec` — the codec is resolved per side by the
    /// codec policy layer (`LeftSide`/`RightSide` roles, `eigen` fallback),
    /// so this reads the *codec's* bitwidth, never a global knob. Small
    /// matrices (below `min_quant_elems`) stay 32-bit dense regardless.
    pub fn new(n: usize, cfg: &SecondOrderConfig, codec: &Arc<dyn StateCodec>) -> SideState {
        let q = &cfg.quant;
        let quantizable = codec.runtime_codebook().is_some()
            && codec.bits() < 16
            && n * n >= q.min_quant_elems;
        if !quantizable {
            // dense arm: a 16-bit codec stores bf16 (when the matrix is
            // big enough to be policy-governed), small matrices stay fp32
            let big = n * n >= q.min_quant_elems;
            let side_codec: Arc<dyn StateCodec> =
                if codec.bits() == 16 && big { codec.clone() } else { fp32() };
            let l = side_codec.encode_matrix(&Mat::eye(n).scale(cfg.eps).data, n);
            let lhat = side_codec.encode_matrix(&Mat::eye(n).data, n);
            return SideState { codec: side_codec, arm: SideArm::Dense { n, l, lhat } };
        }
        let zeros = vec![0.0f32; n * n];
        if q.quantize_eigen {
            let codes = codec.encode_matrix(&Mat::eye(n).data, n);
            let inv_codes = codec.encode_matrix(&zeros, n);
            SideState {
                codec: codec.clone(),
                arm: SideArm::Quantized {
                    lam: vec![cfg.eps; n],
                    codes,
                    inv_diag: vec![1.0; n],
                    inv_codes,
                },
            }
        } else {
            // naive: A₀ = ε·I stored as (diag, quantized zeros)
            let codes = codec.encode_matrix(&zeros, n);
            let inv_codes = codec.encode_matrix(&zeros, n);
            SideState {
                codec: codec.clone(),
                arm: SideArm::Naive {
                    diag: vec![cfg.eps; n],
                    codes,
                    inv_diag: vec![1.0; n],
                    inv_codes,
                },
            }
        }
    }

    /// Matrix order n of this side.
    pub fn order(&self) -> usize {
        match &self.arm {
            SideArm::Quantized { lam, .. } => lam.len(),
            SideArm::Dense { n, .. } => *n,
            SideArm::Naive { diag, .. } => diag.len(),
        }
    }

    /// Exact state bytes (preconditioner + inverse root).
    pub fn state_bytes(&self) -> usize {
        match &self.arm {
            SideArm::Quantized { lam, codes, inv_diag, inv_codes } => {
                lam.len() * 4
                    + codes.bytes.len()
                    + inv_diag.len() * 4
                    + inv_codes.bytes.len()
            }
            SideArm::Dense { l, lhat, .. } => l.bytes.len() + lhat.bytes.len(),
            SideArm::Naive { diag, codes, inv_diag, inv_codes } => {
                diag.len() * 4
                    + codes.bytes.len()
                    + inv_diag.len() * 4
                    + inv_codes.bytes.len()
            }
        }
    }

    /// Bytes a *hypothetical fp32 wire format* would need to ship this side:
    /// every payload (eigenvalues/diagonal, eigenbasis or preconditioner
    /// matrix, inverse-root diagonal + off-diagonal) as raw f32, ignoring
    /// the storage codec. The shard engine reports this next to the actual
    /// codec-byte wire size so the compression ratio of the codec-bytes-as-
    /// wire-format invariant is measurable (`BENCH_shard.json`).
    pub fn fp32_wire_bytes(&self) -> usize {
        let n = self.order();
        match &self.arm {
            // lam (n) + basis (n×n) + inv_diag (n) + inv off-diag (n×n)
            SideArm::Quantized { .. } | SideArm::Naive { .. } => 4 * (n + n * n + n + n * n),
            // L (n×n) + L̂ (n×n)
            SideArm::Dense { .. } => 4 * 2 * n * n,
        }
    }

    /// Which artifact family this side uses ("quant" / "dense" / "naive").
    pub fn arm_name(&self) -> &'static str {
        match &self.arm {
            SideArm::Quantized { .. } => "quant",
            SideArm::Dense { .. } => "dense",
            SideArm::Naive { .. } => "naive",
        }
    }

    /// The storage codec's checkpoint identifier.
    pub fn codec_name(&self) -> String {
        self.codec.name()
    }

    /// The 16-entry runtime codebook quantized artifacts take as input;
    /// `None` on dense arms.
    pub fn runtime_codebook(&self) -> Option<&[f32]> {
        match &self.arm {
            SideArm::Dense { .. } => None,
            _ => self.codec.runtime_codebook(),
        }
    }

    /// Host-side reconstruction of Â (the inverse root) — used by the
    /// fallback preconditioner and the shadow/error analyses.
    pub fn invroot_host(&self, rectify: usize) -> Mat {
        let n = self.order();
        match &self.arm {
            SideArm::Dense { lhat, .. } => {
                Mat::from_vec(n, n, self.codec.decode_matrix(lhat, n))
            }
            SideArm::Quantized { inv_diag, inv_codes, .. }
            | SideArm::Naive { inv_diag, inv_codes, .. } => {
                let off = self.codec.decode_matrix(inv_codes, n);
                let mut m = Mat::from_vec(n, n, off);
                for i in 0..n {
                    m[(i, i)] = inv_diag[i];
                }
                let _ = rectify; // Â is not an orthogonal matrix; no OR here
                m
            }
        }
    }

    /// Host-side reconstruction of the preconditioner A itself
    /// (shadow-mode NRE/AE, Figures 7/8).
    pub fn precond_host(&self, rectify: usize) -> Mat {
        let n = self.order();
        match &self.arm {
            SideArm::Dense { l, .. } => Mat::from_vec(n, n, self.codec.decode_matrix(l, n)),
            SideArm::Quantized { lam, codes, .. } => {
                let mut v = Mat::from_vec(n, n, self.codec.decode_matrix(codes, n));
                if rectify > 0 {
                    v = bjorck(&v, rectify);
                }
                Mat::sandwich(&v, lam)
            }
            SideArm::Naive { diag, codes, .. } => {
                let mut m = Mat::from_vec(n, n, self.codec.decode_matrix(codes, n));
                m.symmetrize();
                for i in 0..n {
                    m[(i, i)] = diag[i];
                }
                m
            }
        }
    }

    // ---- artifact marshaling -------------------------------------------

    /// Inputs encoding this side's *preconditioner* state for pu artifacts.
    pub fn pu_inputs(&self) -> Result<Vec<HostTensor>> {
        match &self.arm {
            SideArm::Quantized { lam, codes, .. } => {
                quant_state_tensors(lam, codes, self.codec.as_ref())
            }
            SideArm::Naive { diag, codes, .. } => {
                quant_state_tensors(diag, codes, self.codec.as_ref())
            }
            SideArm::Dense { n, l, .. } => Ok(vec![HostTensor::f32(
                &[*n, *n],
                self.codec.decode_matrix(l, *n),
            )]),
        }
    }

    /// Inputs encoding this side's *inverse root* for precond artifacts.
    pub fn invroot_inputs(&self) -> Result<Vec<HostTensor>> {
        match &self.arm {
            SideArm::Quantized { inv_diag, inv_codes, .. }
            | SideArm::Naive { inv_diag, inv_codes, .. } => {
                quant_state_tensors(inv_diag, inv_codes, self.codec.as_ref())
            }
            SideArm::Dense { n, lhat, .. } => Ok(vec![HostTensor::f32(
                &[*n, *n],
                self.codec.decode_matrix(lhat, *n),
            )]),
        }
    }

    /// Update the preconditioner state from pu artifact outputs.
    pub fn absorb_pu(&mut self, outs: &[HostTensor]) -> Result<()> {
        match &mut self.arm {
            SideArm::Quantized { lam, codes, .. } => {
                *lam = outs[0].clone().into_f32()?;
                *codes = self.codec.from_artifact(outs[1].as_u8()?, outs[2].as_f32()?)?;
            }
            SideArm::Naive { diag, codes, .. } => {
                *diag = outs[0].clone().into_f32()?;
                *codes = self.codec.from_artifact(outs[1].as_u8()?, outs[2].as_f32()?)?;
            }
            SideArm::Dense { n, l, .. } => {
                let data = outs[0].clone().into_f32()?;
                if data.len() != *n * *n {
                    bail!("dense pu output has {} elems, expected {}", data.len(), *n * *n);
                }
                *l = self.codec.encode_matrix(&data, *n);
            }
        }
        Ok(())
    }

    /// Update the inverse-root state from piru / invroot artifact outputs.
    pub fn absorb_invroot(&mut self, outs: &[HostTensor]) -> Result<()> {
        match &mut self.arm {
            SideArm::Quantized { inv_diag, inv_codes, .. }
            | SideArm::Naive { inv_diag, inv_codes, .. } => {
                *inv_diag = outs[0].clone().into_f32()?;
                *inv_codes =
                    self.codec.from_artifact(outs[1].as_u8()?, outs[2].as_f32()?)?;
            }
            SideArm::Dense { n, lhat, .. } => {
                let data = outs[0].clone().into_f32()?;
                if data.len() != *n * *n {
                    bail!(
                        "dense invroot output has {} elems, expected {}",
                        data.len(),
                        *n * *n
                    );
                }
                *lhat = self.codec.encode_matrix(&data, *n);
            }
        }
        Ok(())
    }

    /// True for the dense (fp32/bf16) arm.
    pub fn is_dense(&self) -> bool {
        matches!(self.arm, SideArm::Dense { .. })
    }

    // ---- checkpoint serialization --------------------------------------

    /// Serialize for checkpoints: arm tag + codec name + order + the raw
    /// codec payloads (no requantization — byte-exact round-trip).
    pub fn serialize(&self) -> Vec<u8> {
        let mut out = Vec::new();
        self.serialize_into(&mut out);
        out
    }

    /// Streaming variant of [`SideState::serialize`]: append this side's
    /// bytes to `out`. The checkpoint writer's per-frame emit seam — one
    /// side at a time, never the whole engine's state in one blob.
    pub fn serialize_into(&self, out: &mut Vec<u8>) {
        out.push(match &self.arm {
            SideArm::Quantized { .. } => 0u8,
            SideArm::Dense { .. } => 1,
            SideArm::Naive { .. } => 2,
        });
        let name = self.codec.name();
        out.push(name.len() as u8);
        out.extend_from_slice(name.as_bytes());
        put_u32(out, self.order());
        match &self.arm {
            SideArm::Quantized { lam, codes, inv_diag, inv_codes }
            | SideArm::Naive { diag: lam, codes, inv_diag, inv_codes } => {
                put_f32s(out, lam);
                put_enc(out, codes);
                put_f32s(out, inv_diag);
                put_enc(out, inv_codes);
            }
            SideArm::Dense { l, lhat, .. } => {
                put_enc(out, l);
                put_enc(out, lhat);
            }
        }
    }

    /// Inverse of [`SideState::serialize`]. Returns the state and the bytes
    /// consumed (sides are concatenated in checkpoint blobs).
    pub fn deserialize(bytes: &[u8]) -> Result<(SideState, usize)> {
        let mut r = Reader { b: bytes, i: 0 };
        let tag = r.u8()?;
        let name_len = r.u8()? as usize;
        let name = String::from_utf8(r.bytes(name_len)?.to_vec())
            .map_err(|_| anyhow!("checkpoint side-state codec name is not UTF-8"))?;
        let codec = codec_by_name(&name)?;
        let n = r.u32()?;
        let arm = match tag {
            0 | 2 => {
                let diag = r.f32s()?;
                let codes = r.enc()?;
                let inv_diag = r.f32s()?;
                let inv_codes = r.enc()?;
                if diag.len() != n || inv_diag.len() != n {
                    bail!("side-state diagonal length mismatch for order {n}");
                }
                if codes.len != n * n || inv_codes.len != n * n {
                    bail!("side-state code length mismatch for order {n}");
                }
                if tag == 0 {
                    SideArm::Quantized { lam: diag, codes, inv_diag, inv_codes }
                } else {
                    SideArm::Naive { diag, codes, inv_diag, inv_codes }
                }
            }
            1 => {
                let l = r.enc()?;
                let lhat = r.enc()?;
                if l.len != n * n || lhat.len != n * n {
                    bail!("dense side-state length mismatch for order {n}");
                }
                SideArm::Dense { n, l, lhat }
            }
            other => bail!("unknown side-state arm tag {other}"),
        };
        // payload lengths must match what the named codec would produce for
        // an order-n matrix (column-blocked codecs clamp the block to n)
        let side = SideState { codec, arm };
        let check = |e: &EncodedVec| -> Result<()> {
            if e.bytes.len() != side.codec.matrix_state_bytes(n) {
                bail!(
                    "side-state payload is {} bytes, codec {} expects {}",
                    e.bytes.len(),
                    side.codec.name(),
                    side.codec.matrix_state_bytes(n)
                );
            }
            // byte-level ingest validation: out-of-range codes / non-finite
            // scales are a descriptive error, not a silent 0.0 decode
            side.codec.validate_payload(e)?;
            Ok(())
        };
        match &side.arm {
            SideArm::Quantized { codes, inv_codes, .. }
            | SideArm::Naive { codes, inv_codes, .. } => {
                check(codes)?;
                check(inv_codes)?;
            }
            SideArm::Dense { l, lhat, .. } => {
                check(l)?;
                check(lhat)?;
            }
        }
        Ok((side, r.i))
    }
}

/// The back buffer of the pipelined engine's per-block double-buffer: a
/// freshly refreshed (PU and/or PIRU) copy of one block's side pair,
/// produced by a background job on the persistent pool.
///
/// Swap protocol (`docs/ARCHITECTURE.md` has the diagram):
///
/// 1. At a refresh step the coordinator clones each due block's `SideState`
///    pair (the front copies stay in place and keep serving `precondition`)
///    and submits one background job per block.
/// 2. Each job updates its private back copy — EMA preconditioner update
///    and, when due, the inverse root — and sends the result home over a
///    channel as a `RefreshedBlock`.
/// 3. At the completion barrier (next refresh due, `pipeline_max_lag`
///    reached, or end of training) the coordinator thread receives every
///    pending `RefreshedBlock` and *moves* it over the front copy.
///
/// Because the swap is a plain move on the coordinator thread between two
/// `precondition` calls, a reader can never observe a half-updated inverse
/// root — the root is either the old one or the new one, never a mix.
pub struct RefreshedBlock {
    /// Index of the block in `SecondOrder::blocks`.
    pub block_idx: usize,
    /// Refreshed left side (back buffer, ready to swap in).
    pub left: SideState,
    /// Refreshed right side (back buffer, ready to swap in).
    pub right: SideState,
    /// Whether the inverse roots were recomputed (invalidates the cached
    /// precondition inputs on swap).
    pub refreshed_invroot: bool,
    /// Background-thread seconds spent in the preconditioner update.
    pub pu_secs: f64,
    /// Background-thread seconds spent in the inverse-root update.
    pub piru_secs: f64,
}

// ---- serialization helpers ------------------------------------------------

fn put_u32(out: &mut Vec<u8>, v: usize) {
    out.extend_from_slice(&(v as u32).to_le_bytes());
}

fn put_f32s(out: &mut Vec<u8>, v: &[f32]) {
    put_u32(out, v.len());
    for &x in v {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

fn put_enc(out: &mut Vec<u8>, e: &EncodedVec) {
    put_u32(out, e.len);
    put_u32(out, e.bytes.len());
    out.extend_from_slice(&e.bytes);
}

struct Reader<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Reader<'a> {
    fn bytes(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.i + n > self.b.len() {
            bail!("side-state blob truncated at byte {}", self.i);
        }
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.bytes(1)?[0])
    }

    fn u32(&mut self) -> Result<usize> {
        let s = self.bytes(4)?;
        Ok(u32::from_le_bytes(s.try_into().unwrap()) as usize)
    }

    fn f32s(&mut self) -> Result<Vec<f32>> {
        let n = self.u32()?;
        let s = self.bytes(n * 4)?;
        Ok(s.chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    fn enc(&mut self) -> Result<EncodedVec> {
        let len = self.u32()?;
        let nbytes = self.u32()?;
        Ok(EncodedVec { bytes: self.bytes(nbytes)?.to_vec(), len })
    }
}

fn quant_state_tensors(
    diag: &[f32],
    enc: &EncodedVec,
    codec: &dyn StateCodec,
) -> Result<Vec<HostTensor>> {
    let (codes, scales, block) = codec.to_artifact(enc)?;
    let nb = scales.len();
    Ok(vec![
        HostTensor::f32(&[diag.len()], diag.to_vec()),
        HostTensor::u8(&[nb, block], codes),
        HostTensor::f32(&[nb], scales),
    ])
}

/// The exponent tag piru/invroot artifacts use for a second-order kind.
pub fn exponent_tag(kind: SecondOrderKind) -> &'static str {
    match kind.alpha() {
        1 => "_e1",
        2 => "_e2",
        _ => "",
    }
}

fn codebook_tensor(side: &SideState) -> Result<HostTensor> {
    let rcb = side.runtime_codebook().ok_or_else(|| {
        anyhow!("codec {} has no runtime codebook for artifacts", side.codec_name())
    })?;
    Ok(HostTensor::f32(&[16], rcb.to_vec()))
}

/// Execute the appropriate PU artifact for one side.
pub fn run_pu(
    rt: &dyn Backend,
    side: &mut SideState,
    m_stat: HostTensor,
    beta: f32,
    kind: SecondOrderKind,
) -> Result<()> {
    let n = side.order();
    let kfac_like = matches!(kind, SecondOrderKind::KFac | SecondOrderKind::AdaBk);
    let mut inputs = side.pu_inputs()?;
    inputs.push(m_stat);
    inputs.push(HostTensor::scalar_f32(beta));
    let name = match side.arm_name() {
        "quant" => {
            inputs.push(codebook_tensor(side)?);
            if kfac_like && n == 128 {
                "pu_kfac_128".to_string()
            } else {
                format!("pu_{n}")
            }
        }
        "naive" => {
            inputs.push(codebook_tensor(side)?);
            format!("pu_naive_{n}")
        }
        _ => format!("pu_dense_{n}"),
    };
    let outs = rt.execute(&name, &inputs)?;
    side.absorb_pu(&outs)
}

/// Execute the appropriate PIRU / inverse-root artifact for one side.
pub fn run_invroot(
    rt: &dyn Backend,
    side: &mut SideState,
    eps: f32,
    kind: SecondOrderKind,
) -> Result<()> {
    let n = side.order();
    let tag = exponent_tag(kind);
    let mut inputs = side.pu_inputs()?; // dense: (l,) ; quant/naive: (diag, codes, scales)
    inputs.push(HostTensor::scalar_f32(eps));
    let name = match side.arm_name() {
        "quant" => {
            inputs.push(codebook_tensor(side)?);
            format!("piru{tag}_{n}")
        }
        "naive" => {
            inputs.push(codebook_tensor(side)?);
            // naive inverse root is Schur–Newton at s = -1/4 only (the
            // naive arm is a Shampoo ablation; K-FAC naive is not a paper
            // configuration)
            format!("invroot_naive_{n}")
        }
        _ => format!("invroot_dense{tag}_{n}"),
    };
    let outs = rt.execute(&name, &inputs)?;
    side.absorb_invroot(&outs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SecondOrderConfig;
    use crate::quant::{codec_for, Mapping};

    fn cfg(bits: u32, eigen: bool) -> SecondOrderConfig {
        let mut c = SecondOrderConfig::default();
        c.quant.bits = bits;
        c.quant.quantize_eigen = eigen;
        c.quant.mapping = Mapping::Linear2;
        c
    }

    fn side(n: usize, c: &SecondOrderConfig) -> SideState {
        let codec = codec_for(c.quant.bits, c.quant.mapping);
        SideState::new(n, c, &codec)
    }

    #[test]
    fn small_matrices_stay_dense() {
        let c = cfg(4, true);
        let s = side(32, &c); // 32² = 1024 < 4096
        assert!(s.is_dense());
        assert_eq!(s.codec_name(), "fp32");
        let s = side(64, &c); // 64² = 4096: quantized
        assert!(!s.is_dense());
        assert_eq!(s.codec_name(), "q4-linear2");
    }

    #[test]
    fn init_states_reconstruct_identity_scaled() {
        let c = cfg(4, true);
        let s = side(64, &c);
        // A₀ ≈ ε·I ; Â₀ = I
        let a = s.precond_host(0);
        let eye_eps = Mat::eye(64).scale(c.eps);
        assert!(a.sub(&eye_eps).frobenius() < 1e-4);
        let ah = s.invroot_host(0);
        assert!(ah.sub(&Mat::eye(64)).frobenius() < 1e-6);
    }

    #[test]
    fn naive_init_reconstructs_identity_scaled() {
        let c = cfg(4, false);
        let s = side(64, &c);
        assert_eq!(s.arm_name(), "naive");
        let a = s.precond_host(0);
        assert!(a.sub(&Mat::eye(64).scale(c.eps)).frobenius() < 1e-4);
    }

    #[test]
    fn state_bytes_scale_with_bits() {
        let s4 = side(128, &cfg(4, true));
        let s32 = side(128, &cfg(32, true));
        // 4-bit: 2 quantized matrices + 2 f32 vectors ≈ (2·(8192+1024) + 1024)
        // 32-bit: 2 dense matrices = 2·65536 B
        let b4 = s4.state_bytes();
        let b32 = s32.state_bytes();
        assert!(b32 as f64 / b4 as f64 > 6.0, "{b32} / {b4}");
        // bf16 dense arm: exactly half the fp32 dense bytes
        let s16 = side(128, &cfg(16, true));
        assert!(s16.is_dense());
        assert_eq!(s16.codec_name(), "bf16");
        assert_eq!(s16.state_bytes() * 2, s32.state_bytes());
    }

    #[test]
    fn pu_inputs_shapes() {
        let c = cfg(4, true);
        let s = side(64, &c);
        let ins = s.pu_inputs().unwrap();
        assert_eq!(ins.len(), 3);
        assert_eq!(ins[0].shape, vec![64]);
        assert_eq!(ins[1].shape, vec![64, 64]); // 4096/64 blocks × 64
        assert_eq!(ins[2].shape, vec![64]);
    }

    #[test]
    fn serialize_round_trips_every_arm() {
        for c in [cfg(4, true), cfg(4, false), cfg(32, true), cfg(16, true)] {
            let s = side(64, &c);
            let blob = s.serialize();
            let (back, used) = SideState::deserialize(&blob).unwrap();
            assert_eq!(used, blob.len());
            assert_eq!(back.arm_name(), s.arm_name());
            assert_eq!(back.codec_name(), s.codec_name());
            assert_eq!(back.order(), 64);
            assert_eq!(back.state_bytes(), s.state_bytes());
            // byte-exact: re-serialization is identical
            assert_eq!(back.serialize(), blob);
        }
        assert!(SideState::deserialize(&[9, 0]).is_err());
    }

    #[test]
    fn sub_block_orders_serialize_round_trip() {
        // min_quant_elems below 32² quantizes an order-32 side; its column
        // blocks are 32-long, so the payload check must use the clamped
        // matrix block accounting
        let mut c = cfg(4, true);
        c.quant.min_quant_elems = 512;
        let s = side(32, &c);
        assert!(!s.is_dense());
        let blob = s.serialize();
        let (back, used) = SideState::deserialize(&blob).unwrap();
        assert_eq!(used, blob.len());
        assert_eq!(back.order(), 32);
        assert_eq!(back.state_bytes(), s.state_bytes());
    }
}
