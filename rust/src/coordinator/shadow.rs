//! Shadow tracker: maintains a 32-bit preconditioner for one tracked block
//! alongside the quantized run and measures the dynamic quantization errors
//! of Figures 7/8 (NRE/AE of L₄ vs L₃₂ and of their inverse 4-th roots).

use anyhow::Result;

use crate::config::SecondOrderConfig;
use crate::coordinator::model::ModelHandle;
use crate::coordinator::partition::extract_block;
use crate::coordinator::second_order::SecondOrder;
use crate::errors::{angle_error_deg, nre};
use crate::linalg::{invroot_eigh, Mat};
use crate::runtime::{Backend, HostTensor};

/// One measurement of the quantized-vs-32-bit preconditioner errors.
#[derive(Debug, Clone)]
pub struct ShadowRow {
    /// Trainer step of the measurement.
    pub step: usize,
    /// NRE of L₄ vs L₃₂.
    pub nre_precond: f64,
    /// Angle error (degrees) of L₄ vs L₃₂.
    pub ae_precond_deg: f64,
    /// NRE of the inverse roots.
    pub nre_invroot: f64,
    /// Angle error (degrees) of the inverse roots.
    pub ae_invroot_deg: f64,
}

/// Maintains the 32-bit shadow preconditioner for one tracked block.
pub struct ShadowTracker {
    /// index of the tracked block in SecondOrder::blocks
    pub block_idx: usize,
    /// 32-bit shadow left preconditioner
    l32: Mat,
    beta: f32,
    eps: f32,
    rectify: usize,
}

impl ShadowTracker {
    /// Track the first quantized block (the paper tracks one 1200×1200 left
    /// preconditioner of a Swin-Tiny parameter; we track the first
    /// max-bucket block).
    pub fn new(second: &SecondOrder, cfg: &SecondOrderConfig) -> Option<Self> {
        let idx = second.blocks.iter().position(|b| !b.left.is_dense())?;
        let n = second.blocks[idx].block.bm;
        Some(Self {
            block_idx: idx,
            l32: Mat::eye(n).scale(cfg.eps),
            beta: cfg.beta,
            eps: cfg.eps,
            rectify: if cfg.quant.rectify { 1 } else { 0 },
        })
    }

    /// Mirror the PU EMA on the 32-bit shadow using the same statistics.
    pub fn update_shadow(
        &mut self,
        rt: &dyn Backend,
        second: &SecondOrder,
        model: &ModelHandle,
        grads: &[Vec<f32>],
        stats: &[Vec<f32>],
    ) -> Result<()> {
        let bp = &second.blocks[self.block_idx];
        let (m, n) = (bp.block.bm, bp.block.bn);
        let l_stat: Vec<f32> = if second.kfac_mode {
            stats[2 * self.block_idx].clone()
        } else {
            let g = extract_block(
                &grads[bp.block.param_idx],
                &model.shapes[bp.block.param_idx],
                &bp.block,
            );
            let outs = rt.execute(&format!("gram_{m}x{n}"), &[HostTensor::f32(&[m, n], g)])?;
            outs[0].clone().into_f32()?
        };
        let stat = Mat::from_vec(m, m, l_stat);
        self.l32 = self.l32.scale(self.beta).add(&stat.scale(1.0 - self.beta));
        Ok(())
    }

    /// Measure NRE/AE of the quantized L and its inverse root against the
    /// 32-bit shadow (host-exact eigendecomposition for the reference).
    pub fn measure(&self, step: usize, second: &SecondOrder) -> Result<Option<ShadowRow>> {
        let bp = &second.blocks[self.block_idx];
        let l4 = bp.left.precond_host(self.rectify);
        let nre_p = nre(&l4, &self.l32);
        let ae_p = angle_error_deg(&l4, &self.l32);

        // inverse roots with the paper's dampening (ε·λmax ridge)
        let lam_max = crate::linalg::power_iteration(&self.l32, 20).max(1e-30);
        let ref32 = invroot_eigh(
            &self.l32.add_scaled_eye(lam_max * self.eps),
            4.0,
            1e-30,
        );
        let inv4 = bp.left.invroot_host(0);
        Ok(Some(ShadowRow {
            step,
            nre_precond: nre_p,
            ae_precond_deg: ae_p,
            nre_invroot: nre(&inv4, &ref32),
            ae_invroot_deg: angle_error_deg(&inv4, &ref32),
        }))
    }
}
