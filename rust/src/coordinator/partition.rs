//! Parameter partitioner: Shampoo blocking (Anil et al. / paper §2.1).
//!
//! Each 2-D parameter is split into row×col blocks of at most `max_order`,
//! and each block is padded up to the smallest *bucket* order (manifest
//! buckets, default {32, 64, 128}) so a bounded set of AOT artifacts covers
//! every shape. 1-D parameters (biases, LayerNorm gains) are not
//! preconditioned — they go straight to F, as in practical Shampoo.

/// One preconditioned block of a parameter matrix.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Block {
    /// Which parameter tensor the block belongs to.
    pub param_idx: usize,
    /// Row offset within the parameter matrix.
    pub row0: usize,
    /// Column offset within the parameter matrix.
    pub col0: usize,
    /// Actual content rows.
    pub rows: usize,
    /// Actual content columns.
    pub cols: usize,
    /// Padded bucket order for the row side (rows ≤ bm).
    pub bm: usize,
    /// Padded bucket order for the column side (cols ≤ bn).
    pub bn: usize,
}

impl Block {
    /// True when the block carries zero padding up to its bucket orders.
    pub fn padded(&self) -> bool {
        self.rows != self.bm || self.cols != self.bn
    }
}

/// Partition a set of parameter shapes into blocks.
///
/// `buckets` must be sorted ascending; `max_order` is the largest allowed
/// bucket (blocks are split so both dims ≤ max_order).
///
/// The output *order* is a contract, not an incident: blocks are emitted
/// param-major, then row-major within each parameter, deterministically for
/// a given (shapes, buckets, max_order). Checkpoint blobs serialize
/// second-order state in this order, and the sharded block engine's
/// round-robin assignment ([`shard_for`](crate::coordinator::shard::shard_for))
/// keys off the block's index in it — which is what makes checkpoints
/// shard-count-portable.
pub fn partition(
    shapes: &[Vec<usize>],
    buckets: &[usize],
    max_order: usize,
) -> Vec<Block> {
    assert!(!buckets.is_empty());
    assert!(buckets.windows(2).all(|w| w[0] < w[1]), "buckets must ascend");
    let cap = max_order.min(*buckets.last().unwrap());
    let mut out = Vec::new();
    for (pi, shape) in shapes.iter().enumerate() {
        if shape.len() != 2 || shape[0] < 2 || shape[1] < 2 {
            continue; // 1-D / scalar / degenerate: F only
        }
        let (r, c) = (shape[0], shape[1]);
        for row0 in (0..r).step_by(cap) {
            let rows = cap.min(r - row0);
            for col0 in (0..c).step_by(cap) {
                let cols = cap.min(c - col0);
                out.push(Block {
                    param_idx: pi,
                    row0,
                    col0,
                    rows,
                    cols,
                    bm: bucket_for(rows, buckets),
                    bn: bucket_for(cols, buckets),
                });
            }
        }
    }
    out
}

/// Smallest bucket ≥ n (n must not exceed the largest bucket).
pub fn bucket_for(n: usize, buckets: &[usize]) -> usize {
    for &b in buckets {
        if b >= n {
            return b;
        }
    }
    panic!("dimension {n} exceeds largest bucket {:?}", buckets.last())
}

/// Extract a zero-padded block from a row-major parameter/grad buffer.
pub fn extract_block(src: &[f32], shape: &[usize], b: &Block) -> Vec<f32> {
    let c = shape[1];
    let mut out = vec![0.0f32; b.bm * b.bn];
    for i in 0..b.rows {
        let srow = (b.row0 + i) * c + b.col0;
        out[i * b.bn..i * b.bn + b.cols]
            .copy_from_slice(&src[srow..srow + b.cols]);
    }
    out
}

/// Write a padded block's content region back into the parameter buffer.
pub fn scatter_block(dst: &mut [f32], shape: &[usize], b: &Block, data: &[f32]) {
    assert_eq!(data.len(), b.bm * b.bn);
    let c = shape[1];
    for i in 0..b.rows {
        let drow = (b.row0 + i) * c + b.col0;
        dst[drow..drow + b.cols].copy_from_slice(&data[i * b.bn..i * b.bn + b.cols]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    const BUCKETS: &[usize] = &[32, 64, 128];

    #[test]
    fn exact_multiple_shapes_unpadded() {
        let blocks = partition(&[vec![256, 128]], BUCKETS, 128);
        assert_eq!(blocks.len(), 2);
        assert!(blocks.iter().all(|b| !b.padded() && b.bm == 128 && b.bn == 128));
    }

    #[test]
    fn remainders_get_padded_buckets() {
        let blocks = partition(&[vec![150, 40]], BUCKETS, 128);
        // rows: 128 + 22 ; cols: 40
        assert_eq!(blocks.len(), 2);
        assert_eq!((blocks[0].bm, blocks[0].bn), (128, 64));
        assert_eq!((blocks[1].rows, blocks[1].bm), (22, 32));
    }

    #[test]
    fn one_d_params_skipped() {
        let blocks = partition(&[vec![128], vec![128, 128], vec![]], BUCKETS, 128);
        assert_eq!(blocks.len(), 1);
        assert_eq!(blocks[0].param_idx, 1);
    }

    #[test]
    fn partition_covers_every_element_once() {
        prop::check("blocks tile the matrix exactly", 20, |rng| {
            let r = 2 + rng.below(300);
            let c = 2 + rng.below(300);
            let blocks = partition(&[vec![r, c]], BUCKETS, 128);
            let mut seen = vec![0u8; r * c];
            for b in &blocks {
                if b.rows > b.bm || b.cols > b.bn {
                    return Err("content exceeds bucket".into());
                }
                for i in 0..b.rows {
                    for j in 0..b.cols {
                        let idx = (b.row0 + i) * c + (b.col0 + j);
                        seen[idx] += 1;
                    }
                }
            }
            if seen.iter().any(|&s| s != 1) {
                return Err(format!("coverage broken for {r}x{c}"));
            }
            Ok(())
        });
    }

    #[test]
    fn extract_scatter_roundtrip() {
        prop::check("extract/scatter roundtrip", 20, |rng| {
            let r = 2 + rng.below(200);
            let c = 2 + rng.below(200);
            let src: Vec<f32> = (0..r * c).map(|_| rng.normal_f32()).collect();
            let shape = vec![r, c];
            let blocks = partition(&[shape.clone()], BUCKETS, 128);
            let mut dst = vec![0.0f32; r * c];
            for b in &blocks {
                let blk = extract_block(&src, &shape, b);
                // padding region must be zero
                for i in 0..b.bm {
                    for j in 0..b.bn {
                        if (i >= b.rows || j >= b.cols) && blk[i * b.bn + j] != 0.0 {
                            return Err("padding not zero".into());
                        }
                    }
                }
                scatter_block(&mut dst, &shape, b, &blk);
            }
            prop::assert_close(&dst, &src, 0.0, 0.0)
        });
    }

    #[test]
    fn bucket_for_picks_smallest() {
        assert_eq!(bucket_for(1, BUCKETS), 32);
        assert_eq!(bucket_for(32, BUCKETS), 32);
        assert_eq!(bucket_for(33, BUCKETS), 64);
        assert_eq!(bucket_for(128, BUCKETS), 128);
    }

    #[test]
    #[should_panic]
    fn bucket_overflow_panics() {
        bucket_for(129, BUCKETS);
    }
}
