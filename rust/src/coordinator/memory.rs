//! Analytic memory planner — regenerates Table 13 (LLaMA2-7B batch-size /
//! OOM table) with the *same* byte-accounting model the live state manager
//! uses, validated against live measurements at small scale in the
//! integration tests.

use crate::quant::packed_len;

/// A parameter matrix in the planned model.
#[derive(Debug, Clone)]
pub struct PlannedParam {
    /// Parameter name.
    pub name: String,
    /// Matrix rows.
    pub rows: usize,
    /// Matrix columns.
    pub cols: usize,
    /// participates in Shampoo preconditioning (2-D weights)
    pub preconditioned: bool,
}

/// Transformer-family model shape for planning (LLaMA-style).
#[derive(Debug, Clone)]
pub struct PlannedModel {
    /// Display name.
    pub name: String,
    /// Vocabulary size.
    pub vocab: usize,
    /// Hidden width.
    pub d_model: usize,
    /// Transformer layers.
    pub n_layers: usize,
    /// MLP width.
    pub d_ff: usize,
    /// Planned context length.
    pub seq: usize,
}

impl PlannedModel {
    /// The paper's Table 13 subject.
    pub fn llama2_7b() -> Self {
        Self {
            name: "LLaMA2-7B".into(),
            vocab: 32000,
            d_model: 4096,
            n_layers: 32,
            d_ff: 11008,
            seq: 256, // the paper's Table 13 context length
        }
    }

    /// Enumerate every parameter matrix of the planned model.
    pub fn params(&self) -> Vec<PlannedParam> {
        let d = self.d_model;
        let f = self.d_ff;
        let mut out = vec![PlannedParam {
            name: "embed".into(),
            rows: self.vocab,
            cols: d,
            preconditioned: true,
        }];
        for i in 0..self.n_layers {
            for (nm, r, c) in [
                ("wq", d, d),
                ("wk", d, d),
                ("wv", d, d),
                ("wo", d, d),
                // LLaMA SwiGLU MLP: gate, up, down
                ("w_gate", d, f),
                ("w_up", d, f),
                ("w_down", f, d),
            ] {
                out.push(PlannedParam {
                    name: format!("l{i}.{nm}"),
                    rows: r,
                    cols: c,
                    preconditioned: true,
                });
            }
            // norms
            out.push(PlannedParam {
                name: format!("l{i}.norms"),
                rows: 2 * d,
                cols: 1,
                preconditioned: false,
            });
        }
        out.push(PlannedParam {
            name: "lm_head".into(),
            rows: self.vocab,
            cols: d,
            preconditioned: true,
        });
        out
    }

    /// Total scalar parameters.
    pub fn param_count(&self) -> usize {
        self.params().iter().map(|p| p.rows * p.cols).sum()
    }
}

/// Optimizer-state memory model.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum OptimizerPlan {
    /// AdamW at `bits` per state element (8-bit AdamW per the paper).
    Adam {
        /// Bits per Adam state element.
        bits: u32,
    },
    /// AdamW + Shampoo: Adam states at `adam_bits`, Shampoo states at
    /// `shampoo_bits` (32 = dense; 4 = ours), block size 64 scales.
    AdamShampoo {
        /// Bits per Adam state element.
        adam_bits: u32,
        /// Bits per Shampoo state element (32 = dense, 4 = ours).
        shampoo_bits: u32,
        /// Largest preconditioner block order.
        max_order: usize,
    },
    /// AdamW under a per-buffer codec policy (Li et al.'s m-at-4-bit /
    /// v-at-8-bit regime): each moment at its own bitwidth, optionally
    /// stacked under Shampoo (`shampoo_bits` 0 = none).
    AdamPolicy {
        /// Bits for the first moment m.
        m_bits: u32,
        /// Bits for the second moment v.
        v_bits: u32,
        /// Bits per Shampoo state element; 0 disables the second order.
        shampoo_bits: u32,
        /// Largest preconditioner block order.
        max_order: usize,
    },
}

/// Bytes for Shampoo preconditioner states of a (rows × cols) matrix
/// blocked to `max_order`: per block, L and R plus their inverse roots.
pub fn shampoo_block_bytes(rows: usize, cols: usize, bits: u32, max_order: usize) -> usize {
    let mut total = 0usize;
    let rblocks = rows.div_ceil(max_order);
    let cblocks = cols.div_ceil(max_order);
    for bi in 0..rblocks {
        let m = (rows - bi * max_order).min(max_order);
        for bj in 0..cblocks {
            let n = (cols - bj * max_order).min(max_order);
            for order in [m, n] {
                if bits >= 32 || order * order < 4096 {
                    // dense: L + L̂
                    total += 2 * order * order * 4;
                } else {
                    // quantized: (λ + codes + scales) + (diag + codes + scales)
                    let block = 64.min(order);
                    let scales = (order * order / block) * 4;
                    let codes = packed_len(order * order, bits);
                    total += 2 * (order * 4 + codes + scales);
                }
            }
        }
    }
    total
}

/// Planned byte totals for one optimizer configuration.
#[derive(Debug, Clone)]
pub struct MemoryPlan {
    /// Model parameter bytes (fp32).
    pub params_bytes: usize,
    /// Gradient bytes (fp32).
    pub grads_bytes: usize,
    /// Adam state bytes at the planned bitwidth.
    pub adam_bytes: usize,
    /// Shampoo state bytes at the planned bitwidth.
    pub shampoo_bytes: usize,
    /// Activation bytes per batch sample.
    pub activation_bytes_per_sample: usize,
}

impl MemoryPlan {
    /// Total bytes at a batch size.
    pub fn total_at_batch(&self, batch: usize) -> usize {
        self.params_bytes
            + self.grads_bytes
            + self.adam_bytes
            + self.shampoo_bytes
            + self.activation_bytes_per_sample * batch
    }

    /// Largest batch that fits a byte budget (0 if even batch=1 OOMs).
    pub fn max_batch(&self, budget: usize) -> usize {
        let fixed = self.params_bytes + self.grads_bytes + self.adam_bytes + self.shampoo_bytes;
        if fixed >= budget {
            return 0;
        }
        (budget - fixed) / self.activation_bytes_per_sample.max(1)
    }
}

/// Build the memory plan for a model + optimizer under bf16 params/grads
/// (the paper's LLaMA runs use bf16 with gradient checkpointing).
pub fn plan(model: &PlannedModel, opt: OptimizerPlan) -> MemoryPlan {
    let n_params = model.param_count();
    let params_bytes = n_params * 2; // bf16
    let grads_bytes = n_params * 2;
    let all_shampoo = |bits: u32, max_order: usize| {
        let mut sh = 0usize;
        for p in model.params() {
            if p.preconditioned && p.cols > 1 {
                sh += shampoo_block_bytes(p.rows, p.cols, bits, max_order);
            }
        }
        sh
    };
    let (adam_bytes, shampoo_bytes) = match opt {
        OptimizerPlan::Adam { bits } => (2 * moment_bytes(n_params, bits), 0),
        OptimizerPlan::AdamShampoo { adam_bits, shampoo_bits, max_order } => {
            (2 * moment_bytes(n_params, adam_bits), all_shampoo(shampoo_bits, max_order))
        }
        OptimizerPlan::AdamPolicy { m_bits, v_bits, shampoo_bits, max_order } => {
            let adam = moment_bytes(n_params, m_bits) + moment_bytes(n_params, v_bits);
            let sh =
                if shampoo_bits > 0 { all_shampoo(shampoo_bits, max_order) } else { 0 };
            (adam, sh)
        }
    };
    // activation memory per sample with gradient checkpointing:
    // ~ layers · seq · d · (a few live tensors) + logits seq·vocab
    let act = model.n_layers * model.seq * model.d_model * 2 * 4
        + model.seq * model.vocab * 2 * 3
        + model.seq * model.d_ff * 2 * 4;
    MemoryPlan {
        params_bytes,
        grads_bytes,
        adam_bytes,
        shampoo_bytes,
        activation_bytes_per_sample: act,
    }
}

/// Bytes for ONE n-element moment buffer at `bits` — the accounting every
/// Adam arm (uniform or per-buffer policy) shares: block-64 absmax scales
/// for quantized states, none for bf16/fp32.
fn moment_bytes(n: usize, bits: u32) -> usize {
    let payload = packed_len(n, bits);
    if bits < 16 {
        payload + (n / 64) * 4
    } else {
        payload
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn llama7b_param_count() {
        let m = PlannedModel::llama2_7b();
        let n = m.param_count();
        // ~6.9B (embeddings + 32 layers)
        assert!(n > 6_000_000_000 && n < 7_500_000_000, "{n}");
    }

    #[test]
    fn shampoo_bytes_ratio_4_vs_32() {
        let b32 = shampoo_block_bytes(4096, 4096, 32, 2048);
        let b4 = shampoo_block_bytes(4096, 4096, 4, 2048);
        let ratio = b32 as f64 / b4 as f64;
        // Appendix G: ≈ 32/(4+0.5) ≈ 7.1 (diag/λ vectors shave a little)
        assert!(ratio > 6.0 && ratio < 7.5, "{ratio}");
    }

    #[test]
    fn table13_shape_holds() {
        // 32-bit Shampoo OOMs at batch 2 on 80 GiB; 4-bit fits 64 but not 256
        let budget = 81920usize * 1024 * 1024;
        let m = PlannedModel::llama2_7b();
        let adam8 = plan(&m, OptimizerPlan::Adam { bits: 8 });
        assert!(adam8.max_batch(budget) >= 128, "{}", adam8.max_batch(budget));

        let sh32 = plan(
            &m,
            OptimizerPlan::AdamShampoo { adam_bits: 8, shampoo_bits: 32, max_order: 2048 },
        );
        assert!(sh32.max_batch(budget) < 2, "{}", sh32.max_batch(budget));

        let sh4 = plan(
            &m,
            OptimizerPlan::AdamShampoo { adam_bits: 8, shampoo_bits: 4, max_order: 2048 },
        );
        let mb = sh4.max_batch(budget);
        assert!(mb >= 64 && mb < 256, "{mb}");
    }

    #[test]
    fn mixed_policy_plan_sits_between_uniform_arms() {
        let m = PlannedModel::llama2_7b();
        let uniform = |bits| plan(&m, OptimizerPlan::Adam { bits });
        let mixed = plan(
            &m,
            OptimizerPlan::AdamPolicy { m_bits: 4, v_bits: 8, shampoo_bits: 0, max_order: 2048 },
        );
        assert!(mixed.adam_bytes > uniform(4).adam_bytes, "m4v8 must cost more than q4/q4");
        assert!(mixed.adam_bytes < uniform(8).adam_bytes, "m4v8 must cost less than q8/q8");
        assert_eq!(mixed.shampoo_bytes, 0);
        // stacking 4-bit Shampoo adds exactly the AdamShampoo second-order bytes
        let stacked = plan(
            &m,
            OptimizerPlan::AdamPolicy { m_bits: 4, v_bits: 8, shampoo_bits: 4, max_order: 2048 },
        );
        let reference = plan(
            &m,
            OptimizerPlan::AdamShampoo { adam_bits: 8, shampoo_bits: 4, max_order: 2048 },
        );
        assert_eq!(stacked.shampoo_bytes, reference.shampoo_bytes);
        assert_eq!(stacked.adam_bytes, mixed.adam_bytes);
    }

    #[test]
    fn small_matrices_stay_dense_in_plan() {
        // order 32 block: dense both ways
        assert_eq!(
            shampoo_block_bytes(32, 32, 4, 2048),
            shampoo_block_bytes(32, 32, 32, 2048)
        );
    }
}
